"""CVMM — conditional vector-matrix multiplication — as Pallas kernels.

This is the paper's kernel contribution (App. B.1, Eq. 26) re-thought for
TPU instead of mechanically ported from CUDA:

    CVMM(V, S, M)[n, l] = sum_m V[n, m] * M[S[n], m, l]

The CUDA kernel sorts tokens by expert index so consecutive threadblocks
reuse the same expert matrix from global memory.  On TPU the analogous
resource is VMEM: we want each expert matrix M[e] staged into VMEM once
and hit by a whole tile of tokens through the MXU.  Strategy implemented
here:

* ``cvmm``: grid (token tiles, N_E).  Each grid step stages one expert
  matrix [M, L] plus one token tile [TN, M] into VMEM, performs a single
  MXU matmul, and accumulates rows masked by ``S == e`` into the output
  tile.  The expert axis is the *minor* (fastest-varying) grid dimension
  so the [TN, L] accumulator stays resident in VMEM across all experts.
  Exact for any load distribution (no token dropping, no sorting), at the
  cost of N_E/K× redundant FLOPs — the TPU analogue of the paper's
  pre-sorting-free fallback.

* capacity-based *grouped* dispatch (python/compile/layers/moe.py) — the
  TPU-idiomatic equivalent of the CUDA kernel's sort-by-expert
  preprocessing: tokens are scattered into a dense [N_E, C, M] buffer so
  each expert's matmul is one contiguous MXU-shaped block.  See DESIGN.md
  §Hardware-Adaptation.

Backward passes are Pallas kernels too (the gradient w.r.t. the expert
matrices is itself a CVMM-transpose, mirroring the paper's reuse of the
same CUDA kernel for fwd and bwd).

All kernels run with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); BlockSpecs are written exactly as they would be for a real
TPU so the VMEM-footprint analysis in DESIGN.md is faithful.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Token-tile size: 128 matches the MXU systolic array's 128x128 shape and
# keeps [TN, M] tile + [M, L] matrix + [TN, L] accumulator within a
# ~16 MiB VMEM budget for the paper's dimensions (see DESIGN.md §Perf).
DEFAULT_TOKEN_TILE = 128


def _cvmm_kernel(s_ref, v_ref, m_ref, o_ref):
    """One (token tile t, expert e) grid step of masked-accumulate CVMM.

    s_ref: [TN] expert indices; v_ref: [TN, M] token tile;
    m_ref: [1, M, L] expert e's matrix; o_ref: [TN, L] accumulator.
    """
    e = pl.program_id(1)  # expert = minor grid dim

    @pl.when(e == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    mask = (s_ref[...] == e)
    prod = jnp.dot(v_ref[...], m_ref[0],
                   preferred_element_type=o_ref.dtype)
    o_ref[...] += jnp.where(mask[:, None], prod, 0)


def _pallas_cvmm(v, s, m, token_tile):
    n, dm = v.shape
    ne, dm2, dl = m.shape
    assert dm == dm2, (v.shape, m.shape)
    tn = min(token_tile, max(8, n))
    # Pad N to a tile multiple; padded rows get expert index -1 which
    # matches no expert and therefore contributes zeros.
    n_pad = (-n) % tn
    if n_pad:
        v = jnp.pad(v, ((0, n_pad), (0, 0)))
        s = jnp.pad(s, (0, n_pad), constant_values=-1)
    n_tiles = (n + n_pad) // tn
    out = pl.pallas_call(
        _cvmm_kernel,
        grid=(n_tiles, ne),
        in_specs=[
            pl.BlockSpec((tn,), lambda t, e: (t,)),
            pl.BlockSpec((tn, dm), lambda t, e: (t, 0)),
            pl.BlockSpec((1, dm, dl), lambda t, e: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tn, dl), lambda t, e: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((n + n_pad, dl), v.dtype),
        interpret=True,
    )(s, v, m)
    return out[:n]


def _grad_w_kernel(s_ref, v_ref, g_ref, o_ref):
    """Backward-w CVMM: dM[e] = sum over token tiles of V^T @ (G | S==e).

    Grid (N_E, token tiles) with the tile index minor so each expert's
    [M, L] gradient accumulator stays in VMEM across all token tiles.
    """
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    e = pl.program_id(0)
    mask = (s_ref[...] == e)
    gm = jnp.where(mask[:, None], g_ref[...], 0)
    o_ref[0] += jnp.dot(v_ref[...].T, gm,
                        preferred_element_type=o_ref.dtype)


def cvmm_grad_w(v: jax.Array, s: jax.Array, g: jax.Array, ne: int,
                token_tile: int = DEFAULT_TOKEN_TILE) -> jax.Array:
    """dCVMM/dM: [NE, M, L] from v [N, M], s [N], upstream g [N, L]."""
    n, dm = v.shape
    _, dl = g.shape
    tn = min(token_tile, max(8, n))
    n_pad = (-n) % tn
    if n_pad:
        v = jnp.pad(v, ((0, n_pad), (0, 0)))
        g = jnp.pad(g, ((0, n_pad), (0, 0)))
        s = jnp.pad(s, (0, n_pad), constant_values=-1)
    n_tiles = (n + n_pad) // tn
    return pl.pallas_call(
        _grad_w_kernel,
        grid=(ne, n_tiles),
        in_specs=[
            pl.BlockSpec((tn,), lambda e, t: (t,)),
            pl.BlockSpec((tn, dm), lambda e, t: (t, 0)),
            pl.BlockSpec((tn, dl), lambda e, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((1, dm, dl), lambda e, t: (e, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((ne, dm, dl), v.dtype),
        interpret=True,
    )(s, v, g)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _cvmm_vjp(v, s, m, token_tile):
    return _pallas_cvmm(v, s, m, token_tile)


def _cvmm_fwd_rule(v, s, m, token_tile):
    return _pallas_cvmm(v, s, m, token_tile), (v, s, m)


def _cvmm_bwd_rule(token_tile, res, g):
    v, s, m = res
    # dV[n] = g[n] @ M[s[n]]^T -> CVMM against transposed expert matrices.
    mt = jnp.swapaxes(m, 1, 2)
    dv = _pallas_cvmm(g, s, mt, token_tile)
    dm = cvmm_grad_w(v, s, g, m.shape[0], token_tile)
    return dv, None, dm


_cvmm_vjp.defvjp(_cvmm_fwd_rule, _cvmm_bwd_rule)


def cvmm(v: jax.Array, s: jax.Array, m: jax.Array,
         token_tile: int = DEFAULT_TOKEN_TILE) -> jax.Array:
    """Differentiable conditional vector-matrix multiply.

    out[n] = v[n] @ m[s[n]] for v [N, M], s [N] int32, m [NE, M, L].
    """
    return _cvmm_vjp(v, s, m.astype(v.dtype), token_tile)
