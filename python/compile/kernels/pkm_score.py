"""Product-key candidate search as a Pallas kernel (paper Sec. 3.2).

Given the two half-scores u_a = W_a x_a and u_b = W_b x_b (each [N, S],
S = sqrt(d_ff) sub-keys), the full score table is the "additive outer
product" u[b*S + a] = u_b[b] + u_a[a].  The kernel exploits the paper's
key observation: the top-K of the S^2 table is contained in the K x K
candidate sums of the per-half top-K — so only K^2 << S^2 sums are formed.

Per row tile, both half-score rows live in VMEM; the candidate table is
[TN, K, K] which for K<=64 stays well under VMEM budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..compat import take_along_last, top_k as compat_top_k

DEFAULT_ROW_TILE = 128


def _pkm_topk_kernel(ua_ref, ub_ref, val_ref, idx_ref, *, knn: int, s: int):
    ua = ua_ref[...]                              # [TN, S]
    ub = ub_ref[...]
    kk = min(knn, s)
    va, ia = compat_top_k(ua, kk)                # [TN, kk]
    vb, ib = compat_top_k(ub, kk)
    cand = vb[:, :, None] + va[:, None, :]        # [TN, kk, kk]
    cidx = ib[:, :, None] * s + ia[:, None, :]    # global flat index
    tn = cand.shape[0]
    cand = cand.reshape(tn, kk * kk)
    cidx = cidx.reshape(tn, kk * kk)
    v, i = compat_top_k(cand, knn)
    val_ref[...] = v
    idx_ref[...] = take_along_last(cidx, i).astype(jnp.int32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def pkm_topk(ua: jax.Array, ub: jax.Array, knn: int,
             row_tile: int = DEFAULT_ROW_TILE):
    """Top-knn of the product-key score table.

    ua, ub: [N, S] -> (scores [N, knn] float, indices [N, knn] int32),
    indices flattened as b * S + a.

    Differentiable w.r.t. (ua, ub) through the selected scores: the VJP
    scatter-adds each upstream gradient into both of its constituent
    half-score positions (score = ub[b] + ua[a]), done on flattened
    arrays so the lowering stays free of batched scatters.
    """
    return _pkm_topk_impl(ua, ub, knn, row_tile)


def _pkm_topk_impl(ua: jax.Array, ub: jax.Array, knn: int,
                   row_tile: int = DEFAULT_ROW_TILE):
    n, s = ua.shape
    assert ub.shape == (n, s)
    assert knn <= s * s
    tn = min(row_tile, max(8, n))
    n_pad = (-n) % tn
    if n_pad:
        pad = ((0, n_pad), (0, 0))
        # pad with -inf so padded rows never pollute real rows (they are
        # sliced off anyway; -inf keeps top_k well defined).
        ua = jnp.pad(ua, pad, constant_values=-jnp.inf)
        ub = jnp.pad(ub, pad, constant_values=-jnp.inf)
    grid = ((n + n_pad) // tn,)
    val, idx = pl.pallas_call(
        functools.partial(_pkm_topk_kernel, knn=knn, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, s), lambda t: (t, 0)),
            pl.BlockSpec((tn, s), lambda t: (t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tn, knn), lambda t: (t, 0)),
            pl.BlockSpec((tn, knn), lambda t: (t, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + n_pad, knn), ua.dtype),
            jax.ShapeDtypeStruct((n + n_pad, knn), jnp.int32),
        ],
        interpret=True,
    )(ua, ub)
    return val[:n], idx[:n]


def _pkm_topk_fwd(ua, ub, knn, row_tile):
    val, idx = _pkm_topk_impl(ua, ub, knn, row_tile)
    return (val, idx), (idx, ua.shape)


def _pkm_topk_bwd(knn, row_tile, res, g):
    idx, shape = res
    gval, _ = g
    n, s = shape
    ia = (idx % s).astype(jnp.int32)
    ib = (idx // s).astype(jnp.int32)
    offs = (jnp.arange(n, dtype=jnp.int32) * s)[:, None]
    flat_a = (ia + offs).reshape(-1)
    flat_b = (ib + offs).reshape(-1)
    gflat = gval.reshape(-1)
    dua = jnp.zeros((n * s,), gval.dtype).at[flat_a].add(gflat)
    dub = jnp.zeros((n * s,), gval.dtype).at[flat_b].add(gflat)
    return dua.reshape(n, s), dub.reshape(n, s)


pkm_topk.defvjp(_pkm_topk_fwd, _pkm_topk_bwd)
