"""Top-K activation function as a Pallas kernel (paper Sec. 3.1, Eq. 6-7).

Keeps the K largest entries of each row of the up-projection output
``u = ReLU(W1 x)`` and zeroes the rest, so the down-projection only sees
K active channels.  On real hardware the down-projection would consume
the (value, index) pairs; under XLA we materialize the masked row (the
dense down-projection is fused by XLA anyway) — the kernel's value is the
row-local top-k selection itself, tiled so each [TN, D] row block lives
in VMEM once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..compat import top_k as compat_top_k

DEFAULT_ROW_TILE = 128


def _topk_mask_kernel(u_ref, o_ref, *, k: int):
    u = u_ref[...]
    # per-row k-th largest value as threshold; ties toward lower index
    # handled by the strict ">=" on sorted values (matches lax.top_k).
    kth = compat_top_k(u, k)[0][:, -1:]
    o_ref[...] = jnp.where(u >= kth, u, 0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def topk_mask(u: jax.Array, k: int,
              row_tile: int = DEFAULT_ROW_TILE) -> jax.Array:
    """Zero all but the top-k entries of each row. u: [N, D] -> [N, D].

    Note on ties: rows where the k-th and (k+1)-th values are exactly
    equal keep *both* (threshold semantics).  With continuous activations
    this has probability zero; the reference oracle (ref.topk_mask_ref)
    breaks ties by index, and tests use inputs without ties.

    VJP: the standard straight-through-the-selection subgradient —
    upstream gradient passes through kept positions, zero elsewhere
    (the threshold's dependence on u is ignored, as in lax.top_k).
    """
    return _topk_mask_impl(u, k, row_tile)


def _topk_mask_fwd(u, k, row_tile):
    out = _topk_mask_impl(u, k, row_tile)
    return out, (out != 0)


def _topk_mask_bwd(k, row_tile, keep, g):
    return (jnp.where(keep, g, 0),)


def _topk_mask_impl(u: jax.Array, k: int,
                    row_tile: int = DEFAULT_ROW_TILE) -> jax.Array:
    n, d = u.shape
    tn = min(row_tile, max(8, n))
    n_pad = (-n) % tn
    if n_pad:
        u = jnp.pad(u, ((0, n_pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_topk_mask_kernel, k=k),
        grid=((n + n_pad) // tn,),
        in_specs=[pl.BlockSpec((tn, d), lambda t: (t, 0))],
        out_specs=pl.BlockSpec((tn, d), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((n + n_pad, d), u.dtype),
        interpret=True,
    )(u)
    return out[:n]


topk_mask.defvjp(_topk_mask_fwd, _topk_mask_bwd)
