"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has an exact, obviously-correct jnp
implementation here.  pytest (python/tests/test_kernels.py) sweeps shapes
and dtypes with hypothesis and asserts allclose between kernel and oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cvmm_ref(v: jax.Array, s: jax.Array, m: jax.Array) -> jax.Array:
    """Conditional vector-matrix multiplication (paper Eq. 26).

    v: [N, M] batch of vectors; s: [N] int expert indices in [0, NE);
    m: [NE, M, L] per-expert matrices.  Returns [N, L] with
    out[n] = v[n] @ m[s[n]].
    """
    return jnp.einsum("nm,nml->nl", v, m[s])


def cvmm_grad_w_ref(v: jax.Array, s: jax.Array, g: jax.Array,
                    n_experts: int) -> jax.Array:
    """Gradient of CVMM w.r.t. the expert matrices.

    v: [N, M], s: [N], g: [N, L] upstream gradient.
    Returns [NE, M, L]: dW[e] = sum_{n: s[n]==e} v[n]^T g[n].
    """
    onehot = jax.nn.one_hot(s, n_experts, dtype=v.dtype)  # [N, NE]
    return jnp.einsum("ne,nm,nl->eml", onehot, v, g)


def topk_mask_ref(u: jax.Array, k: int) -> jax.Array:
    """Keep the k largest entries of each row of u, zero the rest.

    Ties are broken toward lower indices (jax.lax.top_k order).
    u: [..., D] -> same shape.
    """
    _, idx = jax.lax.top_k(u, k)
    # scatter per-row: build one-hot sum over the top-k indices
    oh = jax.nn.one_hot(idx, u.shape[-1], dtype=u.dtype)  # [..., k, D]
    keep = jnp.clip(oh.sum(axis=-2), 0, 1)
    return u * keep


def pkm_scores_ref(ua: jax.Array, ub: jax.Array, knn: int):
    """Product-key top-k (paper Sec. 3.2, exact full-cartesian version).

    ua, ub: [N, S] half-scores.  The full score table is
    u[n, i] = ub[n, i // S] + ua[n, i mod S] for i in [0, S*S).
    Returns (scores [N, knn], indices [N, knn]) of the top-knn entries of u.
    """
    n, s = ua.shape
    full = ub[:, :, None] + ua[:, None, :]        # [N, S(b), S(a)]
    flat = full.reshape(n, s * s)                 # index = b * S + a
    return jax.lax.top_k(flat, knn)


def pkm_scores_fast_ref(ua: jax.Array, ub: jax.Array, knn: int):
    """The accelerated PKM candidate search: top-knn on each half first,
    then top-knn over the knn^2 candidate sums.  Provably returns the same
    set as pkm_scores_ref (the max sum uses a top element of each half).
    """
    n, s = ua.shape
    kk = min(knn, s)
    va, ia = jax.lax.top_k(ua, kk)                # [N, kk]
    vb, ib = jax.lax.top_k(ub, kk)
    cand = vb[:, :, None] + va[:, None, :]        # [N, kk(b), kk(a)]
    cidx = ib[:, :, None] * s + ia[:, None, :]    # global flat index
    cand = cand.reshape(n, kk * kk)
    cidx = cidx.reshape(n, kk * kk)
    v, i = jax.lax.top_k(cand, knn)
    return v, jnp.take_along_axis(cidx, i, axis=1)


def moe_dispatch_ref(x: jax.Array, sel_idx: jax.Array, sel_val: jax.Array,
                     w1: jax.Array, w2: jax.Array) -> jax.Array:
    """Exact σ-MoE feedforward (paper Eq. 11) via CVMM oracles.

    x: [N, D]; sel_idx: [N, K] expert indices; sel_val: [N, K] gate values;
    w1: [NE, D, G]; w2: [NE, G, D].  Returns [N, D].
    """
    n, k = sel_idx.shape
    xr = jnp.repeat(x, k, axis=0)                 # [N*K, D]
    sr = sel_idx.reshape(n * k)
    h = jax.nn.relu(cvmm_ref(xr, sr, w1))         # [N*K, G]
    h = h * sel_val.reshape(n * k, 1)
    y = cvmm_ref(h, sr, w2)                       # [N*K, D]
    return y.reshape(n, k, -1).sum(axis=1)
