"""AOT lowering: JAX functions -> HLO text artifacts + manifest.json.

Run once at build time (``make artifacts``); never on the request path.

Interchange format is HLO *text*, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the Rust ``xla`` crate) rejects; the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Per preset this writes::

    artifacts/<preset>/init.hlo.txt
    artifacts/<preset>/train_step.hlo.txt
    artifacts/<preset>/eval_step.hlo.txt
    artifacts/<preset>/step_fwd.hlo.txt
    artifacts/<preset>/prefill.hlo.txt
    artifacts/<preset>/reset_lanes.hlo.txt
    artifacts/<preset>/snapshot_lanes.hlo.txt
    artifacts/<preset>/restore_lanes.hlo.txt
    artifacts/<preset>/manifest.json

manifest.json describes every function's flattened input/output buffers
(name, shape, dtype in pytree order) plus the model config and the
analytic FLOPs summary, so the Rust runtime can address buffers by name.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import sys
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import api, flops
from .configs import (ModelConfig, TrainConfig, all_presets, config_to_dict,
                      get_preset)

# Presets built by a bare `make artifacts` — everything tests, examples
# and benches need.  Other presets can be built with --preset.
DEFAULT_PRESETS = [
    "tiny-moe", "tiny-dense", "tiny-topk", "tiny-pkm",
    "tiny-moe-softmax_renorm", "tiny-moe-switch",
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side always unwraps exactly one tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _leaf_name(path) -> str:
    parts: List[str] = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def spec_of_tree(tree: Any) -> List[Dict[str, Any]]:
    """Flatten a pytree of arrays into [{name, shape, dtype}] in the exact
    order jax.jit flattens arguments/results."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        out.append({
            "name": _leaf_name(path),
            "shape": list(leaf.shape),
            "dtype": str(leaf.dtype),
        })
    return out


def abstractify(tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def lower_fn(fn, args: Tuple) -> Tuple[str, List[Dict], List[Dict]]:
    """Lower fn(*args) and return (hlo_text, input_spec, output_spec).

    jax.jit prunes arguments that are provably unused (e.g. the RNG seed
    when all dropout rates are 0); the manifest reports only the *kept*
    inputs, in the exact order the compiled executable expects them.
    """
    spec_args = abstractify(args)
    lowered = jax.jit(fn).lower(*spec_args)
    out_shape = jax.eval_shape(fn, *spec_args)
    in_spec = spec_of_tree(args)
    kept = lowered._lowering.compile_args.get("kept_var_idx")
    if kept is not None:
        kept = sorted(kept)
        in_spec = [in_spec[i] for i in kept]
    return to_hlo_text(lowered), in_spec, spec_of_tree(out_shape)


def build_preset(name: str, out_dir: str, batch_size: int | None = None,
                 total_steps: int = 100_000,
                 eval_mem_factor: int = 4,
                 serve_batch: int = 4,
                 prefill_chunk: int = 16,
                 force: bool = False) -> str:
    cfg = get_preset(name)
    tcfg = TrainConfig(total_steps=total_steps)
    if batch_size is not None:
        tcfg.batch_size = batch_size
    else:
        # Scaled-down default batch for the tiny/small presets.
        tcfg.batch_size = 16 if name.startswith(("tiny", "small")) else 32
    eval_mem_len = eval_mem_factor * cfg.context

    preset_dir = os.path.join(out_dir, name)
    os.makedirs(preset_dir, exist_ok=True)
    stamp_path = os.path.join(preset_dir, ".stamp")
    stamp = _input_stamp(cfg, tcfg, eval_mem_len, serve_batch,
                         prefill_chunk)
    if not force and os.path.exists(stamp_path):
        with open(stamp_path) as f:
            if f.read().strip() == stamp:
                print(f"[aot] {name}: up to date")
                return preset_dir

    print(f"[aot] building {name} (batch={tcfg.batch_size}) ...")
    args = api.example_args(cfg, tcfg, eval_mem_len, serve_batch,
                            prefill_chunk)
    # MoE presets emit prefill logits at all C positions ([B, C, V])
    # so the serving engine can verify speculative drafts through the
    # same dispatch; dense/topk/pkm keep the last-position [B, V]
    # signature (and old artifacts parse as verify_logits=False).
    verify_logits = cfg.ff_variant == "moe"
    fns = {
        "init": api.make_init(cfg),
        "train_step": api.make_train_step(cfg, tcfg),
        "eval_step": api.make_eval_step(cfg, eval_mem_len),
        "step_fwd": api.make_step_fwd(cfg, cfg.mem_len),
        # chunked prompt ingestion for serving (validity-masked)
        "prefill": api.make_prefill(cfg, cfg.mem_len,
                                    verify_logits=verify_logits),
        # on-device per-lane memory zeroing for serving admission
        "reset_lanes": api.make_reset_lanes(cfg),
        # prefix cache: per-lane post-prefill memory gather + the
        # masked scatter seeding a cache-hit lane (serving only)
        "snapshot_lanes": api.make_snapshot_lanes(cfg),
        "restore_lanes": api.make_restore_lanes(cfg),
    }
    manifest: Dict[str, Any] = {
        "preset": name,
        "config": config_to_dict(cfg),
        "train_config": dataclasses.asdict(tcfg),
        "eval_mem_len": eval_mem_len,
        "serve_batch": serve_batch,
        "prefill_chunk": prefill_chunk,
        # Expert-utilization telemetry: MoE presets append a per-layer
        # expert-count output [layers, n_experts] to step_fwd/prefill;
        # the serving engine reads this block to size its histograms.
        # None for dense/topk/pkm presets (two-output signature).
        "expert_counts": ({"layers": cfg.n_layers,
                           "n_experts": cfg.moe.n_experts,
                           "k": cfg.moe.k}
                          if cfg.ff_variant == "moe" else None),
        # Adaptive expert sparsity: MoE step_fwd/prefill take a trailing
        # runtime expert_k int32 scalar in [1, expert_k_max]; the
        # scheduler degrades it under queue pressure.  None for non-MoE
        # presets (old signature, no runtime-k input).
        "expert_k_max": (cfg.moe.k if cfg.ff_variant == "moe" else None),
        # Speculative decode: when true, prefill output "0" is the full
        # per-position logits [B, C, V] (verifier for drafted tokens);
        # when false/absent the old last-valid gather [B, V] applies.
        "verify_logits": verify_logits,
        # Prefix cache: when true, snapshot_lanes/restore_lanes are
        # present and the serving engine may snapshot post-prefill lane
        # memory and seed cache-hit lanes from it.  False/absent on old
        # artifacts — the engine falls back bit-for-bit to cold prefill.
        "prefix_cache": True,
        "flops": flops.summarize(cfg),
        "functions": {},
    }
    for fname, fn in fns.items():
        hlo, in_spec, out_spec = lower_fn(fn, args[fname])
        path = os.path.join(preset_dir, f"{fname}.hlo.txt")
        with open(path, "w") as f:
            f.write(hlo)
        manifest["functions"][fname] = {
            "file": f"{fname}.hlo.txt",
            "inputs": in_spec,
            "outputs": out_spec,
        }
        print(f"[aot]   {fname}: {len(in_spec)} in, {len(out_spec)} out, "
              f"{len(hlo)//1024} KiB HLO")
    with open(os.path.join(preset_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    with open(stamp_path, "w") as f:
        f.write(stamp)
    return preset_dir


def _input_stamp(cfg: ModelConfig, tcfg: TrainConfig, eval_mem_len: int,
                 serve_batch: int, prefill_chunk: int) -> str:
    """Hash of everything that affects the artifacts: configs + the
    compile-package sources."""
    h = hashlib.sha256()
    h.update(json.dumps(dataclasses.asdict(cfg), sort_keys=True).encode())
    h.update(json.dumps(dataclasses.asdict(tcfg), sort_keys=True).encode())
    h.update(f"{eval_mem_len}|{serve_batch}|{prefill_chunk}".encode())
    pkg_dir = os.path.dirname(os.path.abspath(__file__))
    for root, _, files in sorted(os.walk(pkg_dir)):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(root, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--preset", action="append", default=None,
                    help="preset name (repeatable); default: the standard "
                         "test/example set")
    ap.add_argument("--batch-size", type=int, default=None)
    ap.add_argument("--total-steps", type=int, default=100_000)
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="serving prefill chunk width C (tokens per "
                         "prefill dispatch per lane)")
    ap.add_argument("--list", action="store_true",
                    help="list available presets and exit")
    ap.add_argument("--force", action="store_true",
                    help="rebuild even if stamps are current")
    args = ap.parse_args(argv)

    if args.list:
        for n in sorted(all_presets()):
            print(n)
        return

    presets = args.preset or DEFAULT_PRESETS
    for name in presets:
        build_preset(name, args.out, batch_size=args.batch_size,
                     total_steps=args.total_steps,
                     prefill_chunk=args.prefill_chunk, force=args.force)


if __name__ == "__main__":
    main()
