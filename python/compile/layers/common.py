"""Shared L2 building blocks: layernorm, dropout, initializers.

Parameters are plain nested dicts of jnp arrays (no flax/haiku — the AOT
path needs a stable, dependency-free flattening order that the Rust side
can mirror from the manifest).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def layer_norm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layer_norm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]


def dropout(rng: jax.Array, x: jax.Array, rate: float,
            deterministic: bool) -> jax.Array:
    """Inverted dropout; identity when rate == 0 or deterministic."""
    if rate <= 0.0 or deterministic:
        return x
    keep = 1.0 - rate
    mask = jax.random.bernoulli(rng, keep, x.shape)
    return jnp.where(mask, x / keep, 0)


def normal_init(rng: jax.Array, shape, std: float) -> jax.Array:
    return std * jax.random.normal(rng, shape, jnp.float32)


def row_normalized_init(rng: jax.Array, shape, std: float) -> jax.Array:
    """σ-MoE selection-matrix init (paper Sec. 5): sample N(0,1), rescale
    every row to unit norm, then rescale the whole matrix to std.  Scores
    then depend only on the angle between x and the row, not on a random
    per-row magnitude."""
    w = jax.random.normal(rng, shape, jnp.float32)
    w = w / (jnp.linalg.norm(w, axis=-1, keepdims=True) + 1e-12)
    # after row normalization each row has norm 1; scale so the matrix has
    # the desired elementwise std: row norm sqrt(fan_in)*std.
    return w * (std * jnp.sqrt(jnp.asarray(shape[-1], jnp.float32)))


def dense_std(d_in: int, n_layers: int) -> float:
    """Pre-layernorm dense init std sqrt(2 / (d_in * n_layers)) —
    the scheme the paper applies identically to experts (Sec. 5)."""
    import math
    return math.sqrt(2.0 / (d_in * n_layers))
