"""Dense and Top-K feedforward blocks (paper Sec. 2 & 3.1).

Both return ``(y, aux)`` where aux carries the per-layer statistics used
by the analysis tooling (active channel counts, Fig. 1/4/5) and a zero
regularization loss, so every FF variant shares one interface:

    ff(params, x2d, rng, deterministic) -> (y2d, {"reg": scalar, ...})
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..kernels.topk_act import topk_mask
from .common import Params, dense_std, dropout, normal_init


def dense_ff_init(rng: jax.Array, d_model: int, d_ff: int,
                  n_layers: int) -> Params:
    k1, k2 = jax.random.split(rng)
    return {
        "w1": normal_init(k1, (d_model, d_ff), dense_std(d_model, n_layers)),
        "b1": jnp.zeros((d_ff,), jnp.float32),
        "w2": normal_init(k2, (d_ff, d_model), dense_std(d_ff, n_layers)),
        "b2": jnp.zeros((d_model,), jnp.float32),
    }


def dense_ff(p: Params, x: jax.Array, rng: jax.Array, drop_rate: float,
             deterministic: bool) -> Tuple[jax.Array, dict]:
    """Standard 2-layer MLP, Eq. 1-2.  x: [N, D] -> [N, D]."""
    u = jax.nn.relu(x @ p["w1"] + p["b1"])
    active = (u > 0).sum(axis=-1).astype(jnp.float32)   # Fig. 1 statistic
    h = dropout(rng, u, drop_rate, deterministic)
    y = h @ p["w2"] + p["b2"]
    return y, {"reg": jnp.zeros((), jnp.float32),
               "active_channels": active.mean(),
               "active_channels_std": active.std()}


def topk_ff(p: Params, x: jax.Array, rng: jax.Array, k: int,
            drop_rate: float, deterministic: bool) -> Tuple[jax.Array, dict]:
    """Top-K activation MLP, Eq. 6-7: keep the K largest channels of u.

    Same parameters as the dense block (it *is* the dense block with a
    sparsified activation) — Tab. 1 compares them parameter-equal.
    """
    u = jax.nn.relu(x @ p["w1"] + p["b1"])
    u = topk_mask(u, k)
    active = (u > 0).sum(axis=-1).astype(jnp.float32)
    h = dropout(rng, u, drop_rate, deterministic)
    y = h @ p["w2"] + p["b2"]
    return y, {"reg": jnp.zeros((), jnp.float32),
               "active_channels": active.mean(),
               "active_channels_std": active.std()}
