"""L2 building blocks: attention, feedforward variants, MoE, PKM."""
