"""Product-Key Memory layer (paper Sec. 3.2, App. A.3).

Modifications relative to Lample et al. (2019), following the paper:
no batch-norm, no input projection (the input is split directly into the
two half-keys), the same learning rate as the rest of the network, and —
the paper's contribution — a choice of ReLU instead of softmax as the
candidate activation.  Multi-head: each head has its own sub-key
matrices and selects knn values from a shared value table.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs import PKMConfig
from ..kernels.pkm_score import pkm_topk
from .common import Params, dense_std, dropout, normal_init


def pkm_init(rng: jax.Array, d_model: int, cfg: PKMConfig,
             n_layers: int) -> Params:
    s, h = cfg.n_subkeys, cfg.heads
    n_values = s * s
    k1, k2, k3 = jax.random.split(rng, 3)
    half = d_model // 2
    if cfg.custom_init:
        # "PKM + init" (Tab. 6): init as if keys/values formed the dense
        # block of width n_values.
        std_k = dense_std(half, n_layers)
        std_v = dense_std(n_values, n_layers)
    else:
        std_k = dense_std(half, n_layers)
        std_v = dense_std(n_values, n_layers)
    # keys: [H, 2, S, half] — two sub-key sets per head
    return {
        "keys": normal_init(k1, (h, 2, s, half), std_k),
        "values": normal_init(k2, (n_values, d_model), std_v),
    }


def pkm_ff(p: Params, x: jax.Array, rng: jax.Array, cfg: PKMConfig,
           deterministic: bool) -> Tuple[jax.Array, dict]:
    """x: [N, D] -> [N, D] through the product-key memory."""
    n, d = x.shape
    s, hh, knn = cfg.n_subkeys, cfg.heads, cfg.knn
    half = d // 2
    xa, xb = x[:, :half], x[:, half:]

    y = jnp.zeros_like(x)
    total_active = jnp.zeros((), jnp.float32)
    for h in range(hh):
        ua = xa @ p["keys"][h, 0].T                       # [N, S]
        ub = xb @ p["keys"][h, 1].T
        scores, idx = pkm_topk(ua, ub, knn)               # [N, knn]
        if cfg.activation == "relu":
            w = jax.nn.relu(scores)
        elif cfg.activation == "softmax":
            w = jax.nn.softmax(scores, axis=-1)
        else:
            raise ValueError(f"unknown pkm activation {cfg.activation!r}")
        vals = p["values"][idx]                           # [N, knn, D]
        y = y + jnp.einsum("nk,nkd->nd", w, vals)
        total_active = total_active + (w > 0).sum(axis=-1).astype(
            jnp.float32).mean()

    return y, {"reg": jnp.zeros((), jnp.float32),
               "active_channels": total_active,
               "active_channels_std": jnp.zeros((), jnp.float32)}
