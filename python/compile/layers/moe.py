"""σ-MoE layer and all ablation variants (paper Sec. 3.3, 4, 5).

One implementation parameterized by MoEConfig covers:

* σ-MoE (ours): sigmoid selection, top-K, entropy regularization
  (Eq. 20-21), expert dropout (Eq. 22), dense-equivalent init.
* softmax_renorm: softmax then top-K then re-normalize
  (≡ Sparsely-Gated MoE of Shazeer et al., "softmax (renorm.)" row).
* softmax: softmax, top-K, no renorm ("softmax before top-k" row —
  equivalently Switch-style scoring generalized to K>1).
* switch: softmax + top-1 + Switch load-balancing loss (Eq. 15-17).
* sbase: sigmoid weighting with Sinkhorn-balanced assignment during
  training (Clark et al. 2022's S-BASE; Eq. 18-19 approximated by
  Sinkhorn iterations), argmax/top-K routing at eval.

The expert computation itself goes through the CVMM Pallas kernel
(kernels/cvmm.py) — the same kernel for forward and both backward
passes, as in the paper's CUDA implementation.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..compat import take_along_last, top_k as compat_top_k
from ..configs import MoEConfig
from ..kernels.cvmm import cvmm
from .common import (Params, dense_std, dropout, normal_init,
                     row_normalized_init)


def moe_init(rng: jax.Array, d_model: int, cfg: MoEConfig,
             n_layers: int) -> Params:
    """Expert + selection parameters.

    init == "ours" (paper Sec. 5): experts are initialized exactly like
    the dense baseline's W1/W2 — std based on d_model and d_ff = N_E*G,
    *not* on the per-expert width G.  The selection matrix W3 uses the
    row-normalized scheme.  init == "standard" uses per-expert fan-in
    (the Tab. 4 "standard init" ablation).
    """
    ne, g, k = cfg.n_experts, cfg.group_size, cfg.k
    d_ff = ne * g
    k1, k2, k3 = jax.random.split(rng, 3)
    if cfg.init == "ours":
        std1 = dense_std(d_model, n_layers)
        std2 = dense_std(d_ff, n_layers)
        # Each expert's selector is a *row* of W3 in the paper's notation
        # (a column of our [d_model, NE] layout): normalize those.
        w3 = row_normalized_init(k3, (ne, d_model), std1).T
    elif cfg.init == "standard":
        # per-expert Glorot-ish fan-in, the scheme the paper argues against
        std1 = dense_std(d_model, n_layers)
        std2 = dense_std(g, n_layers)
        w3 = normal_init(k3, (d_model, ne), std1)
    else:
        raise ValueError(f"unknown moe init {cfg.init!r}")
    return {
        "w1": normal_init(k1, (ne, d_model, g), std1),
        "w2": normal_init(k2, (ne, g, d_model), std2),
        "w3": w3,
    }


def _selection(cfg: MoEConfig, logits: jax.Array, rng: jax.Array,
               deterministic: bool,
               expert_k: jax.Array | None = None):
    """Compute gate values + top-K expert indices for each token.

    logits: [N, NE].  Returns (sel_val [N, K], sel_idx [N, K], probs
    [N, NE]) where probs is the softmax distribution used by the
    regularizers (Eq. 20) regardless of the gating activation.

    ``expert_k`` (int32 scalar, optional — the serving runtime-k path)
    zeroes the gates of top-K slots ``>= expert_k`` *before* any
    renormalization, so a dispatch compiled for static K can run any
    effective k in [1, K] without changing shapes (SEER-MoE-style
    top-k reduction as a graceful-degradation knob).  ``where``, not
    multiplication, so a NaN gate in a masked slot cannot leak; with
    ``expert_k == K`` the all-true mask is the identity and the result
    is bit-for-bit the fixed-K computation.
    """
    k = cfg.k
    probs = jax.nn.softmax(logits, axis=-1)

    if cfg.selection == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    elif cfg.selection in ("softmax", "softmax_renorm", "switch"):
        scores = probs
    elif cfg.selection == "sbase":
        scores = jax.nn.sigmoid(logits)
    else:
        raise ValueError(f"unknown selection {cfg.selection!r}")

    route = scores
    if cfg.selection == "sbase" and not deterministic:
        # Sinkhorn-balanced routing: iterate row/column normalization of
        # the (stop-gradient) score matrix over the whole batch, then
        # top-K on the balanced plan.  Weighting still uses sigmoid
        # scores (Clark et al. 2022).
        plan = jax.lax.stop_gradient(jax.nn.softmax(logits, axis=-1))
        for _ in range(cfg.sinkhorn_iters):
            plan = plan / (plan.sum(axis=0, keepdims=True) + 1e-9)
            plan = plan / (plan.sum(axis=1, keepdims=True) + 1e-9)
        route = plan

    if cfg.expert_dropout > 0.0 and not deterministic:
        # Expert dropout (Eq. 22): zero whole experts without rescaling,
        # shared across the batch is NOT what Eq. 22 says — m is sampled
        # per token.  Masked experts can't be selected.
        mask = jax.random.bernoulli(rng, 1.0 - cfg.expert_dropout,
                                    route.shape)
        route = route * mask
        scores = scores * mask

    _, sel_idx = compat_top_k(route, k)                  # [N, K]
    sel_val = take_along_last(scores, sel_idx)

    if expert_k is not None:
        slot = jnp.arange(k, dtype=jnp.int32)[None, :]   # [1, K]
        sel_val = jnp.where(slot < expert_k, sel_val, 0.0)

    if cfg.selection == "softmax_renorm":
        # with a runtime k the renorm runs over active slots only (the
        # masked gates are exact zeros and stay zero after division)
        sel_val = sel_val / (sel_val.sum(axis=-1, keepdims=True) + 1e-9)

    return sel_val, sel_idx, probs


def _regularization(cfg: MoEConfig, probs: jax.Array,
                    sel_idx: jax.Array) -> jax.Array:
    """Load-balancing loss (to be *added* to the LM loss, scaled by γ)."""
    ne = cfg.n_experts
    if cfg.regularization == "none" or cfg.reg_gamma == 0.0:
        return jnp.zeros((), jnp.float32)
    if cfg.regularization == "entropy":
        # Eq. 20-21: maximize entropy of the batch-mean softmax
        # distribution == minimize sum p log p.
        p = probs.mean(axis=0)
        return cfg.reg_gamma * jnp.sum(p * jnp.log(p + 1e-10))
    if cfg.regularization == "switch":
        # Eq. 15-17: N_E * f . p with f the fraction of tokens routed to
        # each expert (over all K slots) and p the mean selection prob.
        n = sel_idx.shape[0] * sel_idx.shape[1]
        f = jnp.zeros((ne,), jnp.float32).at[sel_idx.reshape(-1)].add(1.0)
        f = f / n
        p = probs.mean(axis=0)
        return cfg.reg_gamma * ne * jnp.dot(f, p)
    raise ValueError(f"unknown regularization {cfg.regularization!r}")


def grouped_dispatch(x: jax.Array, sel_idx: jax.Array, sel_val: jax.Array,
                     w1: jax.Array, w2: jax.Array,
                     capacity_factor: float) -> jax.Array:
    """Capacity-based grouped expert execution — the TPU-idiomatic
    equivalent of the CUDA kernel's sort-by-expert preprocessing
    (DESIGN.md §Hardware-Adaptation).

    Tokens are scattered into a dense [NE, C, D] buffer (C = capacity),
    each expert runs one contiguous batched matmul, and results gather
    back.  Exact iff no expert receives more than C tokens; overflowing
    tokens are dropped (zero contribution), which is why the exact CVMM
    path remains the default for trained comparisons.
    """
    n, d = x.shape
    ne = w1.shape[0]
    k = sel_idx.shape[1]
    rows = n * k
    flat_e = sel_idx.reshape(rows).astype(jnp.int32)       # expert per row
    cap = max(1, int(capacity_factor * rows / ne))
    # position of each row within its expert's buffer (rank among equal e)
    onehot = jax.nn.one_hot(flat_e, ne, dtype=jnp.int32)   # [rows, NE]
    pos = (jnp.cumsum(onehot, axis=0) - 1)                 # inclusive rank
    slot = jnp.sum(pos * onehot, axis=1)                   # [rows]
    keep = slot < cap
    # scatter rows into [NE * C, D] (dropped rows write to a trash slot)
    flat_idx = jnp.where(keep, flat_e * cap + slot, ne * cap)
    xr = jnp.repeat(x, k, axis=0)                          # [rows, D]
    buf = jnp.zeros((ne * cap + 1, d), x.dtype).at[flat_idx].add(xr)
    buf = buf[:-1].reshape(ne, cap, d)
    h = jax.nn.relu(jnp.einsum("ecd,edg->ecg", buf, w1))   # [NE, C, G]
    out = jnp.einsum("ecg,egd->ecd", h, w2)                # [NE, C, D]
    out_flat = out.reshape(ne * cap, d)
    gathered = jnp.where(keep[:, None],
                         out_flat[jnp.clip(flat_idx, 0, ne * cap - 1)], 0)
    gathered = gathered * sel_val.reshape(rows, 1)
    return gathered.reshape(n, k, d).sum(axis=1)


def moe_ff(p: Params, x: jax.Array, rng: jax.Array, cfg: MoEConfig,
           deterministic: bool,
           expert_k: jax.Array | None = None) -> Tuple[jax.Array, dict]:
    """σ-MoE feedforward (Eq. 11).  x: [N, D] -> [N, D].

    aux: reg loss, per-expert usage counts [NE] (Fig. 3/7), mean selection
    probability [NE], and the co-occurrence count matrix [NE, NE] (Fig. 6).

    ``expert_k`` (optional int32 scalar) reduces the effective top-k at
    runtime by zeroing the gates of trailing selection slots (see
    ``_selection``); the usage statistics count active slots only, so
    serving telemetry reflects the degraded k.
    """
    n, d = x.shape
    ne, g, k = cfg.n_experts, cfg.group_size, cfg.k
    r1, r2 = jax.random.split(rng)

    logits = x @ p["w3"]                                   # [N, NE]
    sel_val, sel_idx, probs = _selection(cfg, logits, r1, deterministic,
                                         expert_k)
    reg = _regularization(cfg, probs, sel_idx)

    # Expert execution through the CVMM kernel: replicate each token K
    # times, one row per selected expert.
    xr = jnp.repeat(x, k, axis=0)                          # [N*K, D]
    sr = sel_idx.reshape(n * k).astype(jnp.int32)
    h = jax.nn.relu(cvmm(xr, sr, p["w1"]))                 # [N*K, G]
    hs = h * sel_val.reshape(n * k, 1)
    if cfg.standard_dropout > 0.0 and not deterministic:
        hs = dropout(r2, hs, cfg.standard_dropout, deterministic)
    if cfg.kernel == "grouped" and deterministic \
            and cfg.standard_dropout == 0.0:
        # capacity-dispatch path (semantics-validation + TPU-shape bench;
        # h from the CVMM above still feeds the activity statistics).
        y = grouped_dispatch(x, sel_idx, sel_val, p["w1"], p["w2"],
                             cfg.capacity_factor)
    else:
        y = cvmm(hs, sr, p["w2"])                          # [N*K, D]
        y = y.reshape(n, k, d).sum(axis=1)

    onehot = jax.nn.one_hot(sel_idx, ne, dtype=jnp.float32)  # [N, K, NE]
    if expert_k is not None:
        # usage statistics count active slots only, so the expert
        # telemetry on /metrics reflects the degraded k
        slot = jnp.arange(k, dtype=jnp.int32)[None, :, None]
        onehot = jnp.where(slot < expert_k, onehot, 0.0)
    usage = onehot.sum(axis=(0, 1))                        # counts per expert
    sel_weight = (onehot * sel_val[..., None]).sum(axis=(0, 1))
    tok = onehot.sum(axis=1)                               # [N, NE]
    cooc = tok.T @ tok                                     # [NE, NE]
    active = (h > 0).sum(axis=-1).astype(jnp.float32).reshape(n, k)
    return y, {
        "reg": reg,
        "usage": usage,
        "sel_weight": sel_weight,
        "mean_prob": probs.mean(axis=0),
        "cooccurrence": cooc,
        # per-token selection counts [N, NE] (usage before the token-axis
        # reduction): the serving stack's expert-utilization telemetry
        # masks padding rows and sums these — kept separate from `usage`
        # so eval/train statistics are untouched
        "tok_usage": tok,
        "active_channels": active.sum(axis=-1).mean(),
        "active_channels_std": active.sum(axis=-1).std(),
    }
