"""Transformer-XL relative multi-head self-attention with segment memory.

Follows Dai et al. (2019): content/position attention split with the
global content bias u and position bias v, relative sinusoidal position
encodings, and the left-shift trick for the BD term.  The XL memory (the
previous segment's layer inputs) is passed in and the updated memory is
returned, so the Rust coordinator owns the recurrence state.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .common import Params, dropout, normal_init


def rel_pos_encoding(klen: int, d_model: int) -> jax.Array:
    """Sinusoidal encodings for relative distances klen-1 .. 0."""
    pos = jnp.arange(klen - 1, -1, -1, dtype=jnp.float32)
    inv = 1.0 / (10000 ** (jnp.arange(0, d_model, 2, jnp.float32) / d_model))
    ang = pos[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def attention_init(rng: jax.Array, d_model: int, n_heads: int,
                   head_dim: int, n_layers: int) -> Params:
    std = math.sqrt(2.0 / (d_model * n_layers))
    ks = jax.random.split(rng, 6)
    dh = n_heads * head_dim
    return {
        "wq": normal_init(ks[0], (d_model, dh), std),
        "wk": normal_init(ks[1], (d_model, dh), std),
        "wv": normal_init(ks[2], (d_model, dh), std),
        "wr": normal_init(ks[3], (d_model, dh), std),   # rel-pos projection
        "wo": normal_init(ks[4], (dh, d_model), std),
        "u": jnp.zeros((n_heads, head_dim), jnp.float32),  # content bias
        "v": jnp.zeros((n_heads, head_dim), jnp.float32),  # position bias
    }


def _rel_shift(x: jax.Array) -> jax.Array:
    """BD-term left shift (Dai et al. 2019, App. B).

    x: [B, H, T, K] scored against reversed relative positions; shifts row
    i left by (K - T - i) so that column j aligns with distance i - j + M.
    """
    b, h, t, k = x.shape
    x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (1, 0)))
    x = x.reshape(b, h, k + 1, t)
    x = x[:, :, 1:, :]
    return x.reshape(b, h, t, k)


def attention(p: Params, x: jax.Array, mem: jax.Array, rng: jax.Array,
              n_heads: int, head_dim: int, attn_dropout: float,
              deterministic: bool) -> jax.Array:
    """x: [B, T, D]; mem: [B, M, D] previous-segment activations."""
    b, t, d = x.shape
    m = mem.shape[1]
    klen = t + m
    cat = jnp.concatenate([jax.lax.stop_gradient(mem), x], axis=1)

    def split(h):
        return h.reshape(b, -1, n_heads, head_dim)

    q = split(x @ p["wq"])                       # [B, T, H, d]
    k = split(cat @ p["wk"])                     # [B, K, H, d]
    v = split(cat @ p["wv"])
    r = rel_pos_encoding(klen, d) @ p["wr"]      # [K, H*d]
    r = r.reshape(klen, n_heads, head_dim)

    scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, jnp.float32))
    # content term (AC): (q + u) . k
    ac = jnp.einsum("bthd,bkhd->bhtk", q + p["u"][None, None], k)
    # position term (BD): (q + v) . r, then rel-shift
    bd = jnp.einsum("bthd,khd->bhtk", q + p["v"][None, None], r)
    bd = _rel_shift(bd)
    score = (ac + bd) * scale

    # causal mask: query i (global pos m+i) attends to keys j <= m+i
    qpos = jnp.arange(t)[:, None] + m
    kpos = jnp.arange(klen)[None, :]
    mask = (kpos <= qpos)[None, None]
    score = jnp.where(mask, score, -1e30)
    att = jax.nn.softmax(score, axis=-1)
    att = dropout(rng, att, attn_dropout, deterministic)

    out = jnp.einsum("bhtk,bkhd->bthd", att, v).reshape(b, t, -1)
    return out @ p["wo"]


def update_memory(x: jax.Array, mem: jax.Array, mem_len: int) -> jax.Array:
    """New memory = last mem_len positions of [mem | x] (stop-gradient)."""
    cat = jnp.concatenate([mem, x], axis=1)
    return jax.lax.stop_gradient(cat[:, -mem_len:])
