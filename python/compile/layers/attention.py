"""Transformer-XL relative multi-head self-attention with segment memory.

Follows Dai et al. (2019): content/position attention split with the
global content bias u and position bias v, relative sinusoidal position
encodings, and the left-shift trick for the BD term.  The XL memory (the
previous segment's layer inputs) is passed in and the updated memory is
returned, so the Rust coordinator owns the recurrence state.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .common import Params, dropout, normal_init


def rel_pos_encoding(klen: int, d_model: int) -> jax.Array:
    """Sinusoidal encodings for relative distances klen-1 .. 0."""
    pos = jnp.arange(klen - 1, -1, -1, dtype=jnp.float32)
    inv = 1.0 / (10000 ** (jnp.arange(0, d_model, 2, jnp.float32) / d_model))
    ang = pos[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def attention_init(rng: jax.Array, d_model: int, n_heads: int,
                   head_dim: int, n_layers: int) -> Params:
    std = math.sqrt(2.0 / (d_model * n_layers))
    ks = jax.random.split(rng, 6)
    dh = n_heads * head_dim
    return {
        "wq": normal_init(ks[0], (d_model, dh), std),
        "wk": normal_init(ks[1], (d_model, dh), std),
        "wv": normal_init(ks[2], (d_model, dh), std),
        "wr": normal_init(ks[3], (d_model, dh), std),   # rel-pos projection
        "wo": normal_init(ks[4], (dh, d_model), std),
        "u": jnp.zeros((n_heads, head_dim), jnp.float32),  # content bias
        "v": jnp.zeros((n_heads, head_dim), jnp.float32),  # position bias
    }


def _rel_shift(x: jax.Array) -> jax.Array:
    """BD-term left shift (Dai et al. 2019, App. B).

    x: [B, H, T, K] scored against reversed relative positions; shifts row
    i left by (K - T - i) so that column j aligns with distance i - j + M.
    """
    b, h, t, k = x.shape
    x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (1, 0)))
    x = x.reshape(b, h, k + 1, t)
    x = x[:, :, 1:, :]
    return x.reshape(b, h, t, k)


def attention(p: Params, x: jax.Array, mem: jax.Array, rng: jax.Array,
              n_heads: int, head_dim: int, attn_dropout: float,
              deterministic: bool,
              active_len: jax.Array | None = None) -> jax.Array:
    """x: [B, T, D]; mem: [B, M, D] previous-segment activations.

    ``active_len`` ([B] int32, optional) marks the per-lane number of
    valid positions in ``x`` for chunked prefill: key positions at or
    beyond a lane's active length are masked out of every query's
    attention (``where``-select to -inf, not multiplication, so a NaN
    score at a padded position cannot leak through softmax).  The causal
    mask already keeps *valid* queries from seeing *later* padded keys;
    this extra mask is what makes the padded positions inert for every
    query row, valid or not.
    """
    b, t, d = x.shape
    m = mem.shape[1]
    klen = t + m
    cat = jnp.concatenate([jax.lax.stop_gradient(mem), x], axis=1)

    def split(h):
        return h.reshape(b, -1, n_heads, head_dim)

    q = split(x @ p["wq"])                       # [B, T, H, d]
    k = split(cat @ p["wk"])                     # [B, K, H, d]
    v = split(cat @ p["wv"])
    r = rel_pos_encoding(klen, d) @ p["wr"]      # [K, H*d]
    r = r.reshape(klen, n_heads, head_dim)

    scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, jnp.float32))
    # content term (AC): (q + u) . k
    ac = jnp.einsum("bthd,bkhd->bhtk", q + p["u"][None, None], k)
    # position term (BD): (q + v) . r, then rel-shift
    bd = jnp.einsum("bthd,khd->bhtk", q + p["v"][None, None], r)
    bd = _rel_shift(bd)
    score = (ac + bd) * scale

    # causal mask: query i (global pos m+i) attends to keys j <= m+i
    qpos = jnp.arange(t)[:, None] + m
    kpos = jnp.arange(klen)[None, :]
    mask = (kpos <= qpos)[None, None]
    if active_len is not None:
        # chunked prefill: keys in the x-portion past a lane's active
        # length are invalid for every query of that lane
        key_valid = kpos[None] < (m + active_len[:, None, None])
        mask = mask & key_valid[:, :, None, :]
        # step_fwd-equivalence window: fed one token at a time, a query
        # sees at most the M previous inputs (the XL memory).  Without
        # this band an in-chunk query at offset j would see M + j keys,
        # making logits depend on how the prompt was chunked.
        mask = mask & (kpos >= qpos - m)[None, None]
    score = jnp.where(mask, score, -1e30)
    att = jax.nn.softmax(score, axis=-1)
    att = dropout(rng, att, attn_dropout, deterministic)

    out = jnp.einsum("bhtk,bkhd->bthd", att, v).reshape(b, t, -1)
    return out @ p["wo"]


def update_memory(x: jax.Array, mem: jax.Array, mem_len: int) -> jax.Array:
    """New memory = last mem_len positions of [mem | x] (stop-gradient)."""
    cat = jnp.concatenate([mem, x], axis=1)
    return jax.lax.stop_gradient(cat[:, -mem_len:])


def update_memory_ragged(x: jax.Array, mem: jax.Array, mem_len: int,
                         active_len: jax.Array) -> jax.Array:
    """Per-lane ragged memory update for chunked prefill.

    Lane ``i``'s new memory is the last ``mem_len`` positions of
    ``[mem_i | x_i[:active_len_i]]`` — a lane with ``active_len == 0``
    (idle, or mid-decode during someone else's prefill) keeps its
    memory bit-for-bit.  Static shapes force this to be a per-lane
    shifted *gather* over ``[mem | x]`` rather than a slice: lane ``i``
    reads rows ``[M - mem_len + L_i, M + L_i)`` of the concatenation,
    which never touches ``x`` rows at or past ``L_i``.  The invalid
    ``x`` rows are additionally ``where``-zeroed (select, not multiply:
    ``NaN * 0`` is ``NaN``) so numeric garbage in padding can never be
    written, even if the index arithmetic is ever loosened.

    Implemented as a flat row gather (``jnp.take`` on a 2-D reshape)
    because ``jnp.take_along_axis`` lowers to a batched gather the
    0.5.1-era HLO converter on the Rust side rejects (see compat.py).
    """
    b, t, d = x.shape
    m = mem.shape[1]
    assert m >= mem_len, (m, mem_len)   # start index below must be >= 0
    pos = jnp.arange(t, dtype=jnp.int32)[None, :, None]
    x = jnp.where(pos < active_len[:, None, None], x, 0.0)
    cat = jnp.concatenate([mem, x], axis=1)          # [B, M+T, D]
    start = (m - mem_len) + active_len.astype(jnp.int32)
    rows = start[:, None] + jnp.arange(mem_len, dtype=jnp.int32)[None, :]
    flat_rows = (jnp.arange(b, dtype=jnp.int32) * (m + t))[:, None] + rows
    out = jnp.take(cat.reshape(b * (m + t), d), flat_rows.reshape(-1),
                   axis=0)
    return jax.lax.stop_gradient(out.reshape(b, mem_len, d))
