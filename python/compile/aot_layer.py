"""Single-layer fwd+bwd artifacts for the Fig. 2/8-11 scaling sweeps.

The paper's Fig. 2 measures execution time and memory of one MLP vs one
MoE layer's forward+backward pass while sweeping d_model (and Figs. 9-11
sweep N_E, G).  This module lowers exactly that computation — one FF
block, loss = sum(y), returning input+weight gradients — for a grid of
configurations, so the Rust bench harness can time them on the CPU PJRT
backend and report the *scaling shape*.

Output: artifacts/layerbench/<name>.hlo.txt + layerbench.json manifest.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from . import aot
from .configs import MoEConfig
from .layers import feedforward as ffl
from .layers import moe as moel

# |B| = batch * seq the paper uses is 32768; scaled default here.
DEFAULT_TOKENS = 2048


def dense_case(d_model: int, d_ff: int, n_tokens: int):
    def fn(w1, b1, w2, b2, x):
        p = {"w1": w1, "b1": b1, "w2": w2, "b2": b2}

        def loss(x, w1, w2):
            y, _ = ffl.dense_ff({**p, "w1": w1, "w2": w2}, x,
                                jax.random.PRNGKey(0), 0.0, True)
            return y.sum()

        g = jax.grad(loss, argnums=(0, 1, 2))(x, w1, w2)
        return g

    args = (
        jnp.zeros((d_model, d_ff), jnp.float32),
        jnp.zeros((d_ff,), jnp.float32),
        jnp.zeros((d_ff, d_model), jnp.float32),
        jnp.zeros((d_model,), jnp.float32),
        jnp.zeros((n_tokens, d_model), jnp.float32),
    )
    return fn, args


def moe_case(d_model: int, n_experts: int, g: int, k: int, n_tokens: int):
    cfg = MoEConfig(n_experts=n_experts, group_size=g, k=k,
                    selection="sigmoid", regularization="none")

    def fn(w1, w2, w3, x):
        def loss(x, w1, w2, w3):
            y, _ = moel.moe_ff({"w1": w1, "w2": w2, "w3": w3}, x,
                               jax.random.PRNGKey(0), cfg, True)
            return y.sum()

        return jax.grad(loss, argnums=(0, 1, 2, 3))(x, w1, w2, w3)

    args = (
        jnp.zeros((n_experts, d_model, g), jnp.float32),
        jnp.zeros((n_experts, g, d_model), jnp.float32),
        jnp.zeros((d_model, n_experts), jnp.float32),
        jnp.zeros((n_tokens, d_model), jnp.float32),
    )
    return fn, args


def build(out_dir: str, n_tokens: int) -> None:
    os.makedirs(out_dir, exist_ok=True)
    cases: List[Dict[str, Any]] = []

    # Fig. 2 sweep: d_model with d_ff = 4*d_model, G=128 (scaled), K=4.
    for d_model in (128, 256, 512):
        d_ff = 4 * d_model
        g = 128
        ne = d_ff // g
        cases.append({"name": f"dense_d{d_model}", "kind": "dense",
                      "d_model": d_model, "d_ff": d_ff,
                      "tokens": n_tokens})
        cases.append({"name": f"moe_d{d_model}", "kind": "moe",
                      "d_model": d_model, "n_experts": ne, "g": g,
                      "k": min(4, ne), "tokens": n_tokens})
    # Fig. 9 sweep: N_E at fixed d_model=256, G=64, K=4
    for ne in (4, 8, 16, 32):
        cases.append({"name": f"moe_ne{ne}", "kind": "moe",
                      "d_model": 256, "n_experts": ne, "g": 64, "k": 4,
                      "tokens": n_tokens})
    # Fig. 10 sweep: G at fixed d_model=256, N_E=16, K=4
    for g in (16, 32, 64, 128):
        cases.append({"name": f"moe_g{g}", "kind": "moe",
                      "d_model": 256, "n_experts": 16, "g": g, "k": 4,
                      "tokens": n_tokens})

    manifest = []
    for c in cases:
        if c["kind"] == "dense":
            fn, args = dense_case(c["d_model"], c["d_ff"], c["tokens"])
        else:
            fn, args = moe_case(c["d_model"], c["n_experts"], c["g"],
                                c["k"], c["tokens"])
        hlo, in_spec, out_spec = aot.lower_fn(fn, args)
        fname = f"{c['name']}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(hlo)
        manifest.append({**c, "file": fname, "inputs": in_spec,
                         "outputs": out_spec})
        print(f"[aot_layer] {c['name']}: {len(hlo)//1024} KiB")
    with open(os.path.join(out_dir, "layerbench.json"), "w") as f:
        json.dump({"tokens": n_tokens, "cases": manifest}, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/layerbench")
    ap.add_argument("--tokens", type=int, default=DEFAULT_TOKENS)
    args = ap.parse_args()
    build(args.out, args.tokens)


if __name__ == "__main__":
    main()
