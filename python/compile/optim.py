"""Adam + cosine LR decay + global-norm gradient clipping (paper App. B).

Written dependency-free (no optax) so the optimizer state is a plain
(m, v) tree pair that the AOT manifest can describe to the Rust side.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from .configs import TrainConfig


def init_opt_state(params: Any) -> Tuple[Any, Any]:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return zeros, jax.tree_util.tree_map(jnp.zeros_like, params)


def cosine_lr(cfg: TrainConfig, step: jax.Array) -> jax.Array:
    """Cosine decay from cfg.lr to 0 over total_steps, linear warmup."""
    step_f = step.astype(jnp.float32)
    total = jnp.asarray(cfg.total_steps, jnp.float32)
    if cfg.warmup_steps > 0:
        warm = jnp.asarray(cfg.warmup_steps, jnp.float32)
        warm_frac = jnp.minimum(step_f / warm, 1.0)
    else:
        warm_frac = 1.0
    prog = jnp.clip(step_f / total, 0.0, 1.0)
    return cfg.lr * warm_frac * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                        for g in leaves))


def clip_by_global_norm(tree: Any, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, tree), gn


def adam_update(cfg: TrainConfig, params: Any, grads: Any, m: Any, v: Any,
                step: jax.Array):
    """One Adam step with bias correction.  step is 0-based."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    t = step.astype(jnp.float32) + 1.0
    b1, b2 = cfg.adam_b1, cfg.adam_b2
    lr = cosine_lr(cfg, step)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    new_m = jax.tree_util.tree_map(
        lambda mm, g: b1 * mm + (1 - b1) * g, m, grads)
    new_v = jax.tree_util.tree_map(
        lambda vv, g: b2 * vv + (1 - b2) * (g * g), v, grads)

    def upd(p, mm, vv):
        mhat = mm / bc1
        vhat = vv / bc2
        return p - lr * mhat / (jnp.sqrt(vhat) + cfg.adam_eps)

    new_params = jax.tree_util.tree_map(upd, params, new_m, new_v)
    return new_params, new_m, new_v, gnorm, lr
