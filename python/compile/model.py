"""L2: the Transformer-XL language model assembled from layer variants.

Pre-layernorm Transformer-XL (paper Sec. 6): every MLP block — all
n_layers of them, not every n-th — is replaced by the configured
approximation (dense | topk | pkm | moe).  The model is a pure function
of (params, mems, tokens, rng); all state lives outside (in Rust).
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import compat
from .configs import ModelConfig
from .layers import attention as att
from .layers import feedforward as ffl
from .layers import moe as moel
from .layers import pkm as pkml
from .layers.common import (Params, dense_std, dropout, layer_norm,
                            layer_norm_init, normal_init)


def init_params(rng: jax.Array, cfg: ModelConfig) -> Params:
    """Initialize the full parameter tree (nested dicts, stable order)."""
    keys = jax.random.split(rng, cfg.n_layers + 2)
    emb_std = dense_std(cfg.d_model, 1)
    params: Params = {
        "embed": normal_init(keys[0], (cfg.vocab_size, cfg.d_model),
                             emb_std),
        "out_bias": jnp.zeros((cfg.vocab_size,), jnp.float32),
        "ln_final": layer_norm_init(cfg.d_model),
        "layers": [],
    }
    if not cfg.tied_embeddings:
        params["unembed"] = normal_init(
            keys[1], (cfg.d_model, cfg.vocab_size), emb_std)
    for i in range(cfg.n_layers):
        lk = jax.random.split(keys[2 + i], 3)
        layer: Params = {
            "ln1": layer_norm_init(cfg.d_model),
            "ln2": layer_norm_init(cfg.d_model),
            "att": att.attention_init(lk[0], cfg.d_model, cfg.n_heads,
                                      cfg.head_dim, cfg.n_layers),
        }
        if cfg.ff_variant in ("dense", "topk"):
            layer["ff"] = ffl.dense_ff_init(lk[1], cfg.d_model, cfg.d_ff,
                                            cfg.n_layers)
        elif cfg.ff_variant == "moe":
            layer["ff"] = moel.moe_init(lk[1], cfg.d_model, cfg.moe,
                                        cfg.n_layers)
        elif cfg.ff_variant == "pkm":
            layer["ff"] = pkml.pkm_init(lk[1], cfg.d_model, cfg.pkm,
                                        cfg.n_layers)
        else:
            raise ValueError(f"unknown ff variant {cfg.ff_variant!r}")
        params["layers"].append(layer)
    return params


def _apply_ff(cfg: ModelConfig, p: Params, x2d: jax.Array, rng: jax.Array,
              deterministic: bool,
              expert_k: jax.Array | None = None) -> Tuple[jax.Array, dict]:
    if cfg.ff_variant == "dense":
        return ffl.dense_ff(p, x2d, rng, cfg.dropout, deterministic)
    if cfg.ff_variant == "topk":
        return ffl.topk_ff(p, x2d, rng, cfg.topk.k, cfg.dropout,
                           deterministic)
    if cfg.ff_variant == "moe":
        return moel.moe_ff(p, x2d, rng, cfg.moe, deterministic,
                           expert_k=expert_k)
    if cfg.ff_variant == "pkm":
        return pkml.pkm_ff(p, x2d, rng, cfg.pkm, deterministic)
    raise ValueError(cfg.ff_variant)


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array,
            mems: List[jax.Array], rng: jax.Array,
            deterministic: bool, mem_len: int,
            active_len: jax.Array | None = None,
            expert_k: jax.Array | None = None):
    """Run the LM over one segment.

    tokens: [B, T] int32; mems: n_layers arrays [B, M, D].
    Returns (logits [B, T, V], new_mems, aux) where aux aggregates the
    per-layer regularization losses and statistics.

    ``active_len`` ([B] int32, optional — the chunked-prefill path)
    marks how many leading positions of each lane's ``tokens`` row are
    real; the rest are padding.  Padded positions still flow through
    the dense math (static shapes), but they are masked out of
    attention keys and the per-lane memory update, so a lane's logits
    at positions ``< active_len`` and its new memory are identical to
    feeding only its valid tokens.  ``active_len == 0`` leaves a
    lane's memory untouched (decode lanes riding along in a mixed
    prefill batch).

    ``expert_k`` (int32 scalar, optional) reduces the σ-MoE effective
    top-k at runtime (layers/moe.py); ignored by non-MoE variants.
    """
    b, t = tokens.shape
    x = params["embed"][tokens]                    # [B, T, D]
    rngs = jax.random.split(rng, cfg.n_layers * 3 + 1)
    x = dropout(rngs[-1], x, cfg.dropout, deterministic)

    new_mems: List[jax.Array] = []
    reg_total = jnp.zeros((), jnp.float32)
    stats: Dict[str, Any] = {"usage": [], "mean_prob": [],
                             "sel_weight": [], "cooccurrence": [],
                             "tok_usage": [],
                             "active_channels": [],
                             "active_channels_std": []}
    for i, lp in enumerate(params["layers"]):
        r_att, r_ff, r_do = rngs[3 * i], rngs[3 * i + 1], rngs[3 * i + 2]
        mem = mems[i]
        if active_len is None:
            new_mems.append(att.update_memory(x, mem, mem_len))
        else:
            new_mems.append(att.update_memory_ragged(x, mem, mem_len,
                                                     active_len))
        # pre-LN attention block
        h = layer_norm(lp["ln1"], x)
        mem_n = layer_norm(lp["ln1"], mem)
        a = att.attention(lp["att"], h, mem_n, r_att, cfg.n_heads,
                          cfg.head_dim, cfg.attn_dropout, deterministic,
                          active_len=active_len)
        a = dropout(r_do, a, cfg.dropout, deterministic)
        x = x + a
        # pre-LN feedforward block (flattened to [B*T, D])
        h = layer_norm(lp["ln2"], x).reshape(b * t, -1)
        y, aux = _apply_ff(cfg, lp["ff"], h, r_ff, deterministic,
                           expert_k=expert_k)
        y = dropout(r_ff, y.reshape(b, t, -1), cfg.dropout, deterministic)
        x = x + y
        reg_total = reg_total + aux["reg"]
        for key in ("usage", "mean_prob", "sel_weight", "cooccurrence",
                    "tok_usage"):
            if key in aux:
                stats[key].append(aux[key])
        stats["active_channels"].append(aux.get(
            "active_channels", jnp.zeros((), jnp.float32)))
        stats["active_channels_std"].append(aux.get(
            "active_channels_std", jnp.zeros((), jnp.float32)))

    x = layer_norm(params["ln_final"], x)
    unembed = (params["embed"].T if cfg.tied_embeddings
               else params["unembed"])
    logits = x @ unembed + params["out_bias"]
    aux_out: Dict[str, Any] = {
        "reg": reg_total,
        "active_channels": jnp.stack(stats["active_channels"]),
        "active_channels_std": jnp.stack(stats["active_channels_std"]),
    }
    for key in ("usage", "mean_prob", "sel_weight", "cooccurrence",
                "tok_usage"):
        if stats[key]:
            aux_out[key] = jnp.stack(stats[key])     # [L, ...]
    return logits, new_mems, aux_out


def lm_loss(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean token cross-entropy (nats).  logits [B,T,V], targets [B,T]."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -compat.take_along_last(logp, targets[..., None])
    return nll.mean()
