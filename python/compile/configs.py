"""Model/training configurations for the σ-MoE reproduction.

Mirrors the paper's Tables 8 & 9 (Csordás et al., EMNLP 2023 Findings):
dense baselines on WikiText-103 (47M "WT-S", 238M "WT-S*-dense", 262M
"WT-B") and Enwik8 (41M "E8"), plus the MoE / PKM / Top-K counterparts.

Paper-scale presets exist so that the analytic FLOPs/memory tables
(Tab. 7, "% FLOPs" column of Tab. 3) are computed at the paper's true
sizes.  The `tiny-*` presets are the scaled-down configurations that are
actually trained end-to-end on this CPU-only testbed (see DESIGN.md
§Substitutions).
"""

from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


@dataclass
class MoEConfig:
    """σ-MoE and ablation-variant hyperparameters (paper Sec. 5, Tab. 9)."""

    n_experts: int = 16           # N_E
    group_size: int = 128         # G (expert width); N_E * G = d_ff
    k: int = 4                    # number of experts selected per token
    # Selection function variant (paper Tab. 4 / Tab. 10 ablations):
    #   sigmoid          -- ours (σ-MoE)
    #   softmax_renorm   -- softmax, top-k, re-normalize ("softmax after top-k")
    #   softmax          -- softmax, no renorm ("softmax before top-k")
    #   switch           -- Switch Transformer style (softmax, top-1 semantics)
    #   sbase            -- S-BASE: sigmoid weighting + Sinkhorn-balanced routing
    selection: str = "sigmoid"
    # Regularization: entropy (ours, Eq. 21), switch (Eq. 17), none
    regularization: str = "entropy"
    reg_gamma: float = 0.001      # γ, load-balance loss scale
    expert_dropout: float = 0.0   # δ, Eq. 22 (0 disables)
    # If > 0, use standard dropout on expert outputs instead of expert
    # dropout (the "standard dropout" ablation row).
    standard_dropout: float = 0.0
    # Initialization: ours (dense-equivalent, Sec. 5) or standard (per-expert
    # fan-in, the "standard init" ablation row).
    init: str = "ours"
    sinkhorn_iters: int = 3       # for selection == "sbase"
    # CVMM kernel strategy: "dense" (masked accumulation over all
    # experts; exact for any load — the default, matching the paper's
    # no-token-dropping semantics) or "grouped" (capacity-based dispatch
    # + per-expert contiguous batched matmul; the TPU adaptation of the
    # paper's sort-by-expert CUDA preprocessing — exact iff no expert
    # overflows its capacity).
    kernel: str = "dense"
    capacity_factor: float = 2.0  # μ for kernel == "grouped"


@dataclass
class PKMConfig:
    """Product-key memory hyperparameters (paper Sec. 3.2, App. A.3)."""

    n_subkeys: int = 46           # sqrt(d_ff); n_subkeys**2 values
    knn: int = 32                 # top-k candidates kept
    heads: int = 4
    activation: str = "relu"      # relu (ours) | softmax (original PKM)
    custom_init: bool = False     # "PKM + init" row of Tab. 6


@dataclass
class TopKConfig:
    """Top-K activation function on the MLP (paper Sec. 3.1, Tab. 1)."""

    k: int = 128


@dataclass
class ModelConfig:
    """Transformer-XL language model configuration (paper Tab. 8)."""

    name: str = "tiny-moe"
    vocab_size: int = 2048
    d_model: int = 128
    d_ff: int = 512
    n_layers: int = 4
    n_heads: int = 4
    head_dim: int = 32
    context: int = 64             # training segment length T
    mem_len: int = 64             # XL memory length (train); eval uses 4*context
    dropout: float = 0.0
    attn_dropout: float = 0.0
    # Feedforward block variant: dense | topk | pkm | moe
    ff_variant: str = "moe"
    moe: MoEConfig = field(default_factory=MoEConfig)
    pkm: PKMConfig = field(default_factory=PKMConfig)
    topk: TopKConfig = field(default_factory=TopKConfig)
    # Dataset flavor this config targets (affects nothing in the graph, but
    # recorded in the manifest so the Rust side picks tokenizer/metric):
    #   word  -> perplexity;  char -> bits/character
    unit: str = "word"
    tied_embeddings: bool = False

    def validate(self) -> None:
        if self.ff_variant == "moe":
            assert self.moe.n_experts * self.moe.group_size == self.d_ff, (
                f"N_E*G ({self.moe.n_experts}*{self.moe.group_size}) "
                f"must equal d_ff ({self.d_ff})"
            )
            assert self.moe.k <= self.moe.n_experts
        if self.ff_variant == "pkm":
            assert self.pkm.n_subkeys >= 2
        assert self.d_model % 2 == 0, "PKM splits the input in two halves"


@dataclass
class TrainConfig:
    """Optimization hyperparameters (paper App. B)."""

    batch_size: int = 32
    lr: float = 2.5e-4
    total_steps: int = 100_000    # cosine decay horizon
    warmup_steps: int = 0
    grad_clip: float = 0.25
    adam_b1: float = 0.9
    adam_b2: float = 0.999
    adam_eps: float = 1e-8


def _sq(n: float) -> int:
    return int(round(math.sqrt(n)))


def _moe(d_model: int, d_ff: int, n_layers: int, n_experts: int, g: int,
         k: int, context: int, vocab: int, n_heads: int, head_dim: int,
         name: str, unit: str = "word", **moe_kw: Any) -> ModelConfig:
    return ModelConfig(
        name=name, vocab_size=vocab, d_model=d_model, d_ff=n_experts * g,
        n_layers=n_layers, n_heads=n_heads, head_dim=head_dim,
        context=context, mem_len=context, ff_variant="moe",
        moe=MoEConfig(n_experts=n_experts, group_size=g, k=k, **moe_kw),
        unit=unit)


def paper_presets() -> Dict[str, ModelConfig]:
    """Paper-scale configurations (Tab. 8/9) — used for analytic FLOPs
    tables and artifact generation, *not* trained on this testbed."""
    p: Dict[str, ModelConfig] = {}
    # WikiText-103 small: 47M params, d_model 412, d_ff 2053 (note: the
    # paper's dense d_ff=2053 is slightly above 16*128=2048 to match MoE
    # parameter counts including the selection matrix W3).
    p["wt103-s-dense"] = ModelConfig(
        name="wt103-s-dense", vocab_size=8000, d_model=412, d_ff=2053,
        n_layers=16, n_heads=10, head_dim=41, context=256, mem_len=256,
        dropout=0.1, ff_variant="dense", unit="word")
    p["wt103-s-moe"] = _moe(412, 2048, 16, 16, 128, 4, 256, 8000, 10, 41,
                            "wt103-s-moe")
    # WT-S*: naive N_E scaling to 128 experts (238M params)
    p["wt103-s-star-moe"] = _moe(412, 16384, 16, 128, 128, 4, 256, 8000,
                                 10, 41, "wt103-s-star-moe",
                                 expert_dropout=0.05)
    p["wt103-s-star-dense"] = ModelConfig(
        name="wt103-s-star-dense", vocab_size=8000, d_model=412, d_ff=16480,
        n_layers=16, n_heads=10, head_dim=41, context=256, mem_len=256,
        dropout=0.1, ff_variant="dense", unit="word")
    # WikiText-103 big: 262M params
    p["wt103-b-dense"] = ModelConfig(
        name="wt103-b-dense", vocab_size=8000, d_model=1024, d_ff=4110,
        n_layers=18, n_heads=16, head_dim=64, context=512, mem_len=512,
        dropout=0.2, ff_variant="dense", unit="word")
    p["wt103-b-moe"] = _moe(1024, 4096, 18, 32, 128, 4, 512, 8000, 16, 64,
                            "wt103-b-moe", expert_dropout=0.2)
    # Enwik8: 41M params, character-level
    p["enwik8-dense"] = ModelConfig(
        name="enwik8-dense", vocab_size=256, d_model=512, d_ff=2053,
        n_layers=12, n_heads=8, head_dim=64, context=512, mem_len=512,
        dropout=0.1, ff_variant="dense", unit="char")
    p["enwik8-moe"] = _moe(512, 2048, 12, 16, 128, 4, 512, 256, 8, 64,
                           "enwik8-moe", unit="char", expert_dropout=0.05,
                           reg_gamma=0.0001)
    return p


def tiny_presets() -> Dict[str, ModelConfig]:
    """Scaled-down configurations trained end-to-end on this testbed.

    The scaling preserves the paper's structural ratios: d_ff = 4*d_model
    (up to expert granularity), N_E*G = d_ff, K/N_E = the paper's FLOP
    fraction (25% for small models), every MLP block replaced.
    """
    p: Dict[str, ModelConfig] = {}
    # ~2.5M params: the default quick config for tests and examples.
    p["tiny-dense"] = ModelConfig(
        name="tiny-dense", vocab_size=2048, d_model=128, d_ff=516,
        n_layers=4, n_heads=4, head_dim=32, context=64, mem_len=64,
        ff_variant="dense")
    p["tiny-moe"] = _moe(128, 512, 4, 16, 32, 4, 64, 2048, 4, 32,
                         "tiny-moe")
    p["tiny-topk"] = ModelConfig(
        name="tiny-topk", vocab_size=2048, d_model=128, d_ff=516,
        n_layers=4, n_heads=4, head_dim=32, context=64, mem_len=64,
        ff_variant="topk", topk=TopKConfig(k=128))
    p["tiny-pkm"] = ModelConfig(
        name="tiny-pkm", vocab_size=2048, d_model=128, d_ff=529,
        n_layers=4, n_heads=4, head_dim=32, context=64, mem_len=64,
        ff_variant="pkm", pkm=PKMConfig(n_subkeys=23, knn=32, heads=2))
    # Ablation variants of tiny-moe (paper Tab. 4 / Tab. 10, scaled):
    for sel in ("softmax_renorm", "softmax", "switch", "sbase"):
        c = _moe(128, 512, 4, 16, 32, 4, 64, 2048, 4, 32,
                 f"tiny-moe-{sel}", selection=sel)
        if sel == "switch":
            c.moe.k = 1
            c.moe.group_size = 128
            c.moe.n_experts = 4
            c.moe.regularization = "switch"
            c.moe.reg_gamma = 0.01
        p[c.name] = c
    p["tiny-moe-noreg"] = _moe(128, 512, 4, 16, 32, 4, 64, 2048, 4, 32,
                               "tiny-moe-noreg", regularization="none",
                               reg_gamma=0.0)
    p["tiny-moe-stdinit"] = _moe(128, 512, 4, 16, 32, 4, 64, 2048, 4, 32,
                                 "tiny-moe-stdinit", init="standard")
    p["tiny-moe-dropout"] = _moe(128, 512, 4, 16, 32, 4, 64, 2048, 4, 32,
                                 "tiny-moe-dropout", expert_dropout=0.05)
    # (G, K) sweep at constant G*K (Tab. 10 second block):
    p["tiny-moe-k8-g16"] = _moe(128, 512, 4, 32, 16, 8, 64, 2048, 4, 32,
                                "tiny-moe-k8-g16")
    p["tiny-moe-k2-g64"] = _moe(128, 512, 4, 8, 64, 2, 64, 2048, 4, 32,
                                "tiny-moe-k2-g64")
    p["tiny-moe-k1-g128"] = _moe(128, 512, 4, 4, 128, 1, 64, 2048, 4, 32,
                                 "tiny-moe-k1-g128")
    # Character-level tiny model (enwik8-like synthetic byte stream):
    p["tiny-char-dense"] = ModelConfig(
        name="tiny-char-dense", vocab_size=256, d_model=128, d_ff=516,
        n_layers=4, n_heads=4, head_dim=32, context=128, mem_len=128,
        ff_variant="dense", unit="char")
    p["tiny-char-moe"] = _moe(128, 512, 4, 16, 32, 4, 128, 256, 4, 32,
                              "tiny-char-moe", unit="char")
    # A mid-size config (~12M params) for the end-to-end example run:
    p["small-dense"] = ModelConfig(
        name="small-dense", vocab_size=4096, d_model=256, d_ff=1036,
        n_layers=6, n_heads=4, head_dim=64, context=128, mem_len=128,
        ff_variant="dense")
    p["small-moe"] = _moe(256, 1024, 6, 16, 64, 4, 128, 4096, 4, 64,
                          "small-moe")
    return p


def all_presets() -> Dict[str, ModelConfig]:
    p = dict(tiny_presets())
    p.update(paper_presets())
    return p


def get_preset(name: str) -> ModelConfig:
    presets = all_presets()
    if name not in presets:
        raise KeyError(
            f"unknown preset {name!r}; available: {sorted(presets)}")
    cfg = presets[name]
    cfg.validate()
    return cfg


def config_to_dict(cfg: ModelConfig) -> Dict[str, Any]:
    return dataclasses.asdict(cfg)


def config_to_json(cfg: ModelConfig) -> str:
    return json.dumps(config_to_dict(cfg), indent=2, sort_keys=True)
