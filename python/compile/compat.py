"""HLO-compatibility helpers.

The Rust side runs xla_extension 0.5.1 whose HLO *text parser* predates
some ops modern JAX emits.  Notably ``jax.lax.top_k`` lowers to a native
``topk(..., k=K, largest=true)`` instruction that the old parser rejects.
This module provides drop-in replacements that lower to classic HLO
(sort + slice), which round-trips cleanly.

The pure-jnp oracles in kernels/ref.py intentionally keep
``jax.lax.top_k`` so tests cross-validate the two implementations.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def top_k(x: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Values and indices of the k largest entries along the last axis.

    Matches jax.lax.top_k semantics: descending values, ties broken by
    lower index (achieved by stable-sorting -x).  Lowers to HLO ``sort``
    + ``slice`` only, and carries a custom VJP (1-D scatter-add) because
    the built-in sort transpose lowers to a batched gather the 0.5.1-era
    converter rejects.
    """
    return _top_k_impl(x, k)


def _top_k_impl(x: jax.Array, k: int):
    d = x.shape[-1]
    idx = jnp.broadcast_to(jax.lax.iota(jnp.int32, d), x.shape)
    # stable ascending sort on -x == descending on x with index tiebreak.
    neg, sidx = jax.lax.sort((-x, idx), dimension=-1, is_stable=True,
                             num_keys=1)
    vals = -neg[..., :k]
    return vals, sidx[..., :k]


def _top_k_fwd(x, k):
    vals, idx = _top_k_impl(x, k)
    return (vals, idx), (idx, x.shape)


def _top_k_bwd(k, res, g):
    idx, shape = res
    gvals, _ = g
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    offs = (jnp.arange(rows, dtype=jnp.int32) * d)[:, None]
    flat_idx = (idx.reshape(rows, k) + offs).reshape(-1)
    dx = jnp.zeros((rows * d,), gvals.dtype).at[flat_idx].add(
        gvals.reshape(-1))
    return (dx.reshape(shape),)


top_k.defvjp(_top_k_fwd, _top_k_bwd)


def take_along_last(x: jax.Array, idx: jax.Array) -> jax.Array:
    """``jnp.take_along_axis(x, idx, axis=-1)`` without batched gather.

    Modern JAX lowers take_along_axis to a gather with
    ``operand_batching_dims`` which the 0.5.1-era StableHLO→XLA converter
    rejects; flattening to a 1-D gather side-steps it.
    x: [..., D], idx: [..., K] int -> [..., K].
    """
    d = x.shape[-1]
    k = idx.shape[-1]
    lead = x.shape[:-1]
    assert idx.shape[:-1] == lead, (x.shape, idx.shape)
    flat = x.reshape(-1)
    rows = 1
    for s in lead:
        rows *= s
    fidx = idx.reshape(rows, k)
    offs = (jnp.arange(rows, dtype=fidx.dtype) * d)[:, None]
    out = jnp.take(flat, (fidx + offs).reshape(-1), axis=0)
    return out.reshape(*lead, k)


def argmax_onehot(x: jax.Array) -> jax.Array:
    """One-hot of the per-row argmax, via classic reduce ops."""
    m = x.max(axis=-1, keepdims=True)
    first = jnp.cumsum((x == m).astype(jnp.int32), axis=-1) == 1
    return (first & (x == m)).astype(x.dtype)
