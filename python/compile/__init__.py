"""Build-time Python package: JAX model (L2) + Pallas kernels (L1) + AOT lowering.

Never imported at runtime — `make artifacts` runs aot.py once; the Rust
coordinator (L3) loads the resulting HLO text through PJRT.
"""
