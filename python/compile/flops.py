"""Analytic FLOPs / parameter / activation-memory model.

Regenerates the paper's resource columns: "% FLOPs" of Tab. 3, the
FLOPs/memory fractions of Tab. 7, and feeds the roofline discussion in
DESIGN.md §Perf.  Counts follow the paper's convention: the MLP-block
fraction counts multiply-accumulates in the feedforward path only, and
the expert-selection projection (d_model x N_E) is reported separately
(the paper calls it negligible).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from .configs import ModelConfig


@dataclass
class FFCost:
    """Per-token cost of one feedforward block (forward pass)."""

    flops: float          # MACs * 2
    act_memory: float     # floats materialized per token
    params: float
    selector_flops: float = 0.0

    def as_dict(self) -> Dict[str, float]:
        return {"flops": self.flops, "act_memory": self.act_memory,
                "params": self.params,
                "selector_flops": self.selector_flops}


def dense_ff_cost(d_model: int, d_ff: int) -> FFCost:
    return FFCost(flops=2.0 * 2 * d_model * d_ff,
                  act_memory=float(d_ff),
                  params=2.0 * d_model * d_ff + d_ff + d_model)


def topk_ff_cost(d_model: int, d_ff: int, k: int) -> FFCost:
    # Up-projection is full; only the down-projection is sparse (Sec. 3.1).
    return FFCost(flops=2.0 * d_model * d_ff + 2.0 * d_model * k,
                  act_memory=float(d_ff),
                  params=2.0 * d_model * d_ff + d_ff + d_model)


def moe_ff_cost(d_model: int, n_experts: int, g: int, k: int) -> FFCost:
    d_ff = n_experts * g
    return FFCost(flops=2.0 * 2 * d_model * g * k,
                  act_memory=float(g * k),
                  params=2.0 * d_model * d_ff + d_model * n_experts,
                  selector_flops=2.0 * d_model * n_experts)


def pkm_ff_cost(d_model: int, n_subkeys: int, knn: int,
                heads: int) -> FFCost:
    half = d_model / 2
    score = 2.0 * half * n_subkeys * 2          # two half projections
    combine = 2.0 * knn * knn                   # candidate sums + topk
    readout = 2.0 * knn * d_model
    return FFCost(flops=heads * (score + combine + readout),
                  act_memory=float(heads * (2 * n_subkeys + knn)),
                  params=(heads * 2 * n_subkeys * half
                          + n_subkeys * n_subkeys * d_model))


def ff_cost(cfg: ModelConfig) -> FFCost:
    if cfg.ff_variant == "dense":
        return dense_ff_cost(cfg.d_model, cfg.d_ff)
    if cfg.ff_variant == "topk":
        return topk_ff_cost(cfg.d_model, cfg.d_ff, cfg.topk.k)
    if cfg.ff_variant == "moe":
        return moe_ff_cost(cfg.d_model, cfg.moe.n_experts,
                           cfg.moe.group_size, cfg.moe.k)
    if cfg.ff_variant == "pkm":
        return pkm_ff_cost(cfg.d_model, cfg.pkm.n_subkeys, cfg.pkm.knn,
                           cfg.pkm.heads)
    raise ValueError(cfg.ff_variant)


def attention_cost(cfg: ModelConfig, seq: int, mem: int) -> float:
    """Per-token attention FLOPs (projections + score/value matmuls)."""
    dh = cfg.n_heads * cfg.head_dim
    proj = 2.0 * cfg.d_model * dh * 4
    klen = seq + mem
    scores = 2.0 * dh * klen * 2
    return proj + scores


def model_params(cfg: ModelConfig) -> float:
    ff = ff_cost(cfg).params
    dh = cfg.n_heads * cfg.head_dim
    att = 5.0 * cfg.d_model * dh + 2 * cfg.n_heads * cfg.head_dim
    ln = 4.0 * cfg.d_model
    per_layer = ff + att + ln
    emb = cfg.vocab_size * cfg.d_model * (1 if cfg.tied_embeddings else 2)
    return cfg.n_layers * per_layer + emb + cfg.vocab_size + 2 * cfg.d_model


def ff_fraction_vs_dense(cfg: ModelConfig,
                         dense_cfg: ModelConfig) -> Dict[str, float]:
    """Tab. 7: relative FLOPs and activation memory of the FF block vs the
    parameter-matched dense baseline."""
    a, b = ff_cost(cfg), ff_cost(dense_cfg)
    return {
        "flops_fraction": a.flops / b.flops,
        "memory_fraction": a.act_memory / b.act_memory,
        "selector_flops_fraction": a.selector_flops / b.flops,
    }


def summarize(cfg: ModelConfig) -> Dict[str, float]:
    c = ff_cost(cfg)
    return {
        "total_params": model_params(cfg),
        "ff_flops_per_token": c.flops,
        "ff_act_memory_per_token": c.act_memory,
        "ff_params_per_layer": c.params,
        "selector_flops_per_token": c.selector_flops,
        "attention_flops_per_token": attention_cost(
            cfg, cfg.context, cfg.mem_len),
    }
