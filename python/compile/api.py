"""Top-level pure functions that get AOT-lowered to HLO artifacts.

Six entry points per model configuration:

* ``init``        (seed)                          -> params
* ``train_step``  (params, m, v, mems, tokens, step, seed)
                  -> (loss, gnorm, lr, params', m', v', mems', stats)
* ``eval_step``   (params, mems, tokens)          -> (loss_sum, n, mems', stats)
* ``step_fwd``    (params, mems, tokens)          -> (logits_last, mems')
* ``prefill``     (params, mems, tokens[B,C], active_len[B])
                  -> (logits_last, mems')  (chunked, validity-masked)

MoE presets append a per-layer expert-counts output to ``step_fwd`` /
``prefill`` and take a trailing ``expert_k`` int32 scalar — the
runtime effective top-k (adaptive expert sparsity under load).
* ``reset_lanes``    (mems, keep)          -> mems'  (lane-masked)
* ``snapshot_lanes`` (mems, src)           -> payload  (prefix-cache
                  ragged per-lane memory gather, [L, B, M, D])
* ``restore_lanes``  (mems, payload, keep) -> mems'  (cache-hit seed)

All inputs/outputs are pytrees; jax.jit flattens them in deterministic
pytree order, which aot.py records (names, shapes, dtypes) in
manifest.json so the Rust runtime can address every buffer by name.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import compat
from . import model as M
from . import optim
from .configs import ModelConfig, TrainConfig


def _zero_mems(cfg: ModelConfig, batch: int, mem_len: int):
    return [jnp.zeros((batch, mem_len, cfg.d_model), jnp.float32)
            for _ in range(cfg.n_layers)]


def make_init(cfg: ModelConfig):
    def init(seed: jax.Array):
        rng = jax.random.PRNGKey(seed)
        return M.init_params(rng, cfg)
    return init


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """tokens: [B, T+1] — inputs are [:, :-1], targets [:, 1:]."""

    def train_step(params, m, v, mems, tokens, step, seed):
        rng = jax.random.fold_in(jax.random.PRNGKey(seed), step)

        def loss_fn(p):
            inp = tokens[:, :-1]
            tgt = tokens[:, 1:]
            logits, new_mems, aux = M.forward(
                p, cfg, inp, mems, rng, deterministic=False,
                mem_len=cfg.mem_len)
            lm = M.lm_loss(logits, tgt)
            return lm + aux["reg"], (lm, new_mems, aux)

        grads, (lm, new_mems, aux) = jax.grad(
            loss_fn, has_aux=True)(params)
        new_params, new_m, new_v, gnorm, lr = optim.adam_update(
            tcfg, params, grads, m, v, step)
        stats = {"active_channels": aux["active_channels"]}
        if "usage" in aux:
            stats["usage"] = aux["usage"]
            stats["sel_weight"] = aux["sel_weight"]
            stats["mean_prob"] = aux["mean_prob"]
        return (lm, gnorm, lr, new_params, new_m, new_v, new_mems, stats)

    return train_step


def make_eval_step(cfg: ModelConfig, eval_mem_len: int):
    """Deterministic eval over one segment with the longer XL memory the
    paper uses at test time (4x context)."""

    def eval_step(params, mems, tokens):
        inp = tokens[:, :-1]
        tgt = tokens[:, 1:]
        rng = jax.random.PRNGKey(0)
        logits, new_mems, aux = M.forward(
            params, cfg, inp, mems, rng, deterministic=True,
            mem_len=eval_mem_len)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -compat.take_along_last(logp, tgt[..., None])
        stats = {
            "active_channels": aux["active_channels"],
            "active_channels_std": aux["active_channels_std"],
        }
        for key in ("usage", "sel_weight", "mean_prob", "cooccurrence"):
            if key in aux:
                stats[key] = aux[key]
        n = jnp.asarray(nll.size, jnp.float32)
        return (nll.sum(), n, new_mems, stats)

    return eval_step


def make_step_fwd(cfg: ModelConfig, mem_len: int):
    """Single-token incremental forward for serving: T=1, returns the
    next-token logits and the updated memory.

    For MoE presets a third output is appended: per-layer expert
    selection counts ``[n_layers, n_experts]`` float32 — a pure
    reduction of the router's already-computed top-K one-hot, so the
    logits and memories are bit-for-bit identical to the two-output
    signature (the telemetry test asserts this).  Non-MoE presets keep
    the two-output signature; the Rust engine treats the counts output
    as optional and falls back cleanly (``expert_stats_unavailable``).

    MoE presets additionally take a trailing ``expert_k`` int32 scalar
    — the runtime effective top-k (clipped to ``[1, K]``).  Gates of
    selection slots ``>= expert_k`` are zeroed before any renorm
    (layers/moe.py), so a program compiled for static K serves any
    ``k <= K``; ``expert_k == K`` is the bit-for-bit identity (the
    adaptive-k test pins this).  Non-MoE presets keep the old
    signature.
    """

    if cfg.ff_variant != "moe":
        def step_fwd(params, mems, tokens):
            rng = jax.random.PRNGKey(0)
            logits, new_mems, _ = M.forward(
                params, cfg, tokens, mems, rng, deterministic=True,
                mem_len=mem_len)
            return (logits[:, -1, :], new_mems)
        return step_fwd

    def step_fwd(params, mems, tokens, expert_k):
        rng = jax.random.PRNGKey(0)
        ek = jnp.clip(expert_k.astype(jnp.int32), 1, cfg.moe.k)
        logits, new_mems, aux = M.forward(
            params, cfg, tokens, mems, rng, deterministic=True,
            mem_len=mem_len, expert_k=ek)
        counts = aux["tok_usage"].sum(axis=1)          # [L, NE]
        return (logits[:, -1, :], new_mems, counts)

    return step_fwd


def make_prefill(cfg: ModelConfig, mem_len: int,
                 verify_logits: bool = False):
    """Chunked prompt ingestion for serving: feed up to ``C`` tokens per
    lane in one dispatch instead of one ``step_fwd`` call per token.

    ``tokens`` is ``[B, C]`` int32 and ``active_len`` ``[B]`` int32 —
    lane ``i``'s first ``active_len[i]`` positions are real prompt
    tokens, the rest padding.  The per-position validity mask derived
    from ``active_len`` gates attention keys, the XL-memory write, and
    which position's logits are returned:

    * ``active_len == C``      — a full chunk (more prompt pending);
    * ``0 < active_len < C``   — the prompt's ragged tail, or a decode
      lane riding along with its last sampled token (``active_len=1``,
      exactly ``step_fwd`` semantics);
    * ``active_len == 0``      — idle lane: memory is passed through
      bit-for-bit and the (meaningless) row of ``logits_last`` is the
      caller's to ignore.

    Returns ``(logits_last [B, V], new_mems)`` where ``logits_last[i]``
    is the logits at lane ``i``'s last *valid* position — the
    next-token distribution after its final fed token.  All masking is
    ``where``/gather-select, never multiplication, so NaN/Inf in padded
    positions or in an idle lane's memory stays contained to that lane
    (see EXPERIMENTS.md §Prefill).

    For MoE presets a third output is appended: per-layer expert
    selection counts ``[n_layers, n_experts]`` float32.  Padded
    positions flow through the dense routing math but are masked out of
    the counts (``where``, not multiplication), so the counts sum to
    exactly ``sum(active_len) * K`` per layer and NaN in a padded row
    cannot poison the telemetry.  The logits/memory outputs are
    untouched by the extra reduction.

    MoE presets additionally take a trailing ``expert_k`` int32 scalar
    (runtime effective top-k, clipped to ``[1, K]``) — see
    ``make_step_fwd``; with it the counts sum to exactly
    ``sum(active_len) * expert_k`` per layer.  Non-MoE presets keep
    the old signature.

    ``verify_logits=True`` changes output ``0`` from the last-valid
    gather ``[B, V]`` to the *full* per-position logits ``[B, C, V]``
    — the verifier a speculative decoder needs: K drafted tokens per
    lane ride one prefill-shaped dispatch and position ``j``'s row is
    the model's true next-token distribution after the first ``j + 1``
    fed tokens, so the engine can accept the longest matching draft
    prefix host-side.  Rows at positions ``>= active_len[i]`` are the
    padded positions' (meaningless, possibly non-finite) rows and are
    the caller's to ignore — the same per-lane containment contract as
    the last-position gather.  The forward pass is untouched:
    ``logits[i, active_len[i]-1]`` is bit-for-bit the row the
    ``verify_logits=False`` gather returns (pinned in
    ``test_prefill.py``), so a verify-capable artifact serves ordinary
    chunked prefill by gathering host-side.  Old artifacts and dense
    presets keep the ``[B, V]`` signature.
    """

    def _last_valid_rows(logits, active_len, b, c):
        # logits[i, active_len[i] - 1, :] via a flat row gather
        # (take_along_axis lowers to a batched gather the 0.5.1-era
        # HLO converter rejects; see compat.py)
        last = jnp.clip(active_len - 1, 0, c - 1)
        rows = jnp.arange(b, dtype=jnp.int32) * c + last
        return jnp.take(logits.reshape(b * c, -1), rows, axis=0)

    if cfg.ff_variant != "moe":
        def prefill(params, mems, tokens, active_len):
            b, c = tokens.shape
            active_len = jnp.clip(active_len.astype(jnp.int32), 0, c)
            rng = jax.random.PRNGKey(0)
            logits, new_mems, _ = M.forward(
                params, cfg, tokens, mems, rng, deterministic=True,
                mem_len=mem_len, active_len=active_len)
            if verify_logits:
                return (logits, new_mems)
            return (_last_valid_rows(logits, active_len, b, c), new_mems)
        return prefill

    def prefill(params, mems, tokens, active_len, expert_k):
        b, c = tokens.shape
        active_len = jnp.clip(active_len.astype(jnp.int32), 0, c)
        ek = jnp.clip(expert_k.astype(jnp.int32), 1, cfg.moe.k)
        rng = jax.random.PRNGKey(0)
        logits, new_mems, aux = M.forward(
            params, cfg, tokens, mems, rng, deterministic=True,
            mem_len=mem_len, active_len=active_len, expert_k=ek)
        logits_last = (logits if verify_logits
                       else _last_valid_rows(logits, active_len, b, c))
        tu = aux["tok_usage"]                          # [L, B*C, NE]
        nl, _, ne = tu.shape
        valid = (jnp.arange(c, dtype=jnp.int32)[None, :]
                 < active_len[:, None])                # [B, C]
        tu = jnp.where(valid.reshape(1, b * c, 1), tu, 0.0)
        counts = tu.reshape(nl, b * c, ne).sum(axis=1)  # [L, NE]
        return (logits_last, new_mems, counts)

    return prefill


def make_reset_lanes(cfg: ModelConfig):
    """Per-lane XL-memory reset for continuous-batching admission.

    ``keep`` is a ``[B]`` float mask: 1.0 preserves a lane's memory rows,
    0.0 zeroes them (fresh sequence).  Runs entirely on device so the
    serving engine never round-trips the ``[B, M, D]`` memory slots
    through the host when a lane is recycled (EMNLP repro
    EXPERIMENTS.md §Perf, formerly a known limitation).

    ``where`` rather than multiplication: a lane whose memory picked up
    NaN/Inf must come back as literal zeros (NaN * 0 is NaN), matching
    the host fallback's zero-fill exactly.
    """

    def reset_lanes(mems, keep):
        mask = keep[:, None, None] > 0
        return [jnp.where(mask, m, 0.0) for m in mems]

    return reset_lanes


def make_snapshot_lanes(cfg: ModelConfig):
    """Per-lane ragged gather of post-prefill XL memory for the serving
    prefix cache: lane slot ``i`` of the output holds the memory rows of
    lane ``src[i]`` (``src`` is ``[B]`` int32; a snapshotting lane
    passes its own index), or literal zeros when ``src[i] < 0`` (lane
    not snapshotted in this dispatch).

    The output is one stacked ``[n_layers, B, mem_len, d_model]``
    buffer — the cache-entry payload the engine downloads once per
    snapshot and re-uploads on a cache-hit admission
    (``restore_lanes``).  The same ragged gather is the paging
    primitive for prompts longer than ``mem_len``: any lane's banded
    attention window can be lifted out and re-seeded chunk-by-chunk.

    ``where`` rather than multiplication: a NaN-poisoned lane that is
    *not* selected must contribute literal zeros to the payload
    (NaN * 0 is NaN), so one corrupt lane cannot poison a cache entry
    gathered from a healthy one.
    """

    def snapshot_lanes(mems, src):
        idx = jnp.maximum(src, 0)
        sel = (src >= 0)[:, None, None]
        rows = [jnp.where(sel, jnp.take(m, idx, axis=0), 0.0)
                for m in mems]
        return (jnp.stack(rows, axis=0),)

    return snapshot_lanes


def make_restore_lanes(cfg: ModelConfig):
    """Masked scatter of a cached payload back into lane memory — the
    cache-hit admission path: ``payload`` is the
    ``[n_layers, B, mem_len, d_model]`` buffer a ``snapshot_lanes``
    dispatch produced (each restored lane's rows staged at its own
    batch slot), ``keep`` a ``[B]`` float mask: 1.0 preserves the
    lane's existing memory, 0.0 adopts the payload rows.

    ``where`` rather than multiplication, exactly like ``reset_lanes``:
    a restored lane must come back as the payload's literal bits even
    when its previous occupant left NaN/Inf behind, and an untouched
    lane's (possibly non-finite) state must pass through bit-for-bit.
    """

    def restore_lanes(mems, payload, keep):
        mask = keep[:, None, None] > 0
        return [jnp.where(mask, m, payload[l])
                for l, m in enumerate(mems)]

    return restore_lanes


def example_args(cfg: ModelConfig, tcfg: TrainConfig,
                 eval_mem_len: int, serve_batch: int = 1,
                 prefill_chunk: int = 16):
    """Concrete example arguments (real arrays — also used to seed the
    numeric cross-check in tests) for each entry point."""
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    m, v = optim.init_opt_state(params)
    b = tcfg.batch_size
    mems = _zero_mems(cfg, b, cfg.mem_len)
    tokens = jnp.zeros((b, cfg.context + 1), jnp.int32)
    step = jnp.zeros((), jnp.int32)
    seed = jnp.zeros((), jnp.uint32)
    emems = _zero_mems(cfg, b, eval_mem_len)
    smems = _zero_mems(cfg, serve_batch, mem_len=cfg.mem_len)
    stok = jnp.zeros((serve_batch, 1), jnp.int32)
    keep = jnp.ones((serve_batch,), jnp.float32)
    ptok = jnp.zeros((serve_batch, prefill_chunk), jnp.int32)
    active = jnp.full((serve_batch,), prefill_chunk, jnp.int32)
    src = jnp.zeros((serve_batch,), jnp.int32)
    payload = jnp.zeros(
        (cfg.n_layers, serve_batch, cfg.mem_len, cfg.d_model), jnp.float32)
    out = {
        "init": (seed,),
        "train_step": (params, m, v, mems, tokens, step, seed),
        "eval_step": (params, emems, tokens),
        "step_fwd": (params, smems, stok),
        "reset_lanes": (smems, keep),
        "prefill": (params, smems, ptok, active),
        "snapshot_lanes": (smems, src),
        "restore_lanes": (smems, payload, keep),
    }
    if cfg.ff_variant == "moe":
        # runtime effective top-k scalar (serving-only input); the
        # example value is the compile-time K = identity behavior
        ek = jnp.asarray(cfg.moe.k, jnp.int32)
        out["step_fwd"] = (params, smems, stok, ek)
        out["prefill"] = (params, smems, ptok, active, ek)
    return out
