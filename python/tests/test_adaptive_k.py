"""Adaptive expert top-k: the runtime ``expert_k`` scalar input on MoE
``step_fwd``/``prefill`` must be the *bit-for-bit* identity at
``expert_k == K`` (the all-true slot mask is a no-op ``where``), reduce
the per-layer selection counts to exactly ``valid_tokens * k`` for any
``k < K``, clip out-of-range values into ``[1, K]``, and — for the
softmax_renorm ablation — renormalize over active slots only."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import api
from compile import model as M
from compile.configs import MoEConfig, ModelConfig
from compile.layers import moe as moel

CHUNK = 4


def tiny_cfg(selection="sigmoid"):
    return ModelConfig(
        name="t-moe", vocab_size=64, d_model=16, d_ff=32, n_layers=3,
        n_heads=2, head_dim=8, context=8, mem_len=8, ff_variant="moe",
        moe=MoEConfig(n_experts=4, group_size=8, k=2,
                      selection=selection))


def setup(cfg, batch, seed=0):
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    mems = [jnp.asarray(rng.normal(size=(batch, cfg.mem_len,
                                         cfg.d_model)), jnp.float32)
            for _ in range(cfg.n_layers)]
    return params, mems


def fixed_k_step_fwd(cfg, mem_len):
    """Today's fixed-K program, reconstructed inline — the bit-for-bit
    baseline the expert_k == K runtime path must reproduce."""
    def step_fwd(params, mems, tokens):
        rng = jax.random.PRNGKey(0)
        logits, new_mems, aux = M.forward(
            params, cfg, tokens, mems, rng, deterministic=True,
            mem_len=mem_len)
        counts = aux["tok_usage"].sum(axis=1)
        return (logits[:, -1, :], new_mems, counts)
    return step_fwd


def fixed_k_prefill(cfg, mem_len):
    def prefill(params, mems, tokens, active_len):
        b, c = tokens.shape
        active_len = jnp.clip(active_len.astype(jnp.int32), 0, c)
        rng = jax.random.PRNGKey(0)
        logits, new_mems, aux = M.forward(
            params, cfg, tokens, mems, rng, deterministic=True,
            mem_len=mem_len, active_len=active_len)
        last = jnp.clip(active_len - 1, 0, c - 1)
        rows = jnp.arange(b, dtype=jnp.int32) * c + last
        logits_last = jnp.take(logits.reshape(b * c, -1), rows, axis=0)
        tu = aux["tok_usage"]
        nl, _, ne = tu.shape
        valid = (jnp.arange(c, dtype=jnp.int32)[None, :]
                 < active_len[:, None])
        tu = jnp.where(valid.reshape(1, b * c, 1), tu, 0.0)
        return (logits_last, new_mems, tu.reshape(nl, b * c, ne).sum(axis=1))
    return prefill


def test_step_fwd_expert_k_max_is_bit_identical_to_fixed_k():
    cfg = tiny_cfg()
    b = 3
    params, mems = setup(cfg, b, seed=5)
    toks = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (b, 1)),
        jnp.int32)
    new = jax.jit(api.make_step_fwd(cfg, cfg.mem_len))
    old = jax.jit(fixed_k_step_fwd(cfg, cfg.mem_len))
    ek = jnp.asarray(cfg.moe.k, jnp.int32)
    logits_n, mems_n, counts_n = new(params, mems, toks, ek)
    logits_o, mems_o, counts_o = old(params, mems, toks)
    np.testing.assert_array_equal(np.asarray(logits_n),
                                  np.asarray(logits_o))
    for l, (mn, mo) in enumerate(zip(mems_n, mems_o)):
        np.testing.assert_array_equal(np.asarray(mn), np.asarray(mo),
                                      err_msg=f"layer {l} memory")
    np.testing.assert_array_equal(np.asarray(counts_n),
                                  np.asarray(counts_o))


def test_prefill_expert_k_max_is_bit_identical_to_fixed_k():
    cfg = tiny_cfg()
    b = 3
    params, mems = setup(cfg, b, seed=9)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, CHUNK)),
                       jnp.int32)
    active = jnp.asarray([CHUNK, 2, 0], jnp.int32)
    new = jax.jit(api.make_prefill(cfg, cfg.mem_len))
    old = jax.jit(fixed_k_prefill(cfg, cfg.mem_len))
    ek = jnp.asarray(cfg.moe.k, jnp.int32)
    logits_n, mems_n, counts_n = new(params, mems, toks, active, ek)
    logits_o, mems_o, counts_o = old(params, mems, toks, active)
    np.testing.assert_array_equal(np.asarray(logits_n),
                                  np.asarray(logits_o))
    for l, (mn, mo) in enumerate(zip(mems_n, mems_o)):
        np.testing.assert_array_equal(np.asarray(mn), np.asarray(mo),
                                      err_msg=f"layer {l} memory")
    np.testing.assert_array_equal(np.asarray(counts_n),
                                  np.asarray(counts_o))


def test_degraded_k_masks_counts_and_changes_output():
    cfg = tiny_cfg()
    b = 3
    params, mems = setup(cfg, b, seed=7)
    toks = jnp.asarray(
        np.random.default_rng(4).integers(0, cfg.vocab_size, (b, 1)),
        jnp.int32)
    step = jax.jit(api.make_step_fwd(cfg, cfg.mem_len))
    full, _, counts_full = step(params, mems, toks,
                                jnp.asarray(cfg.moe.k, jnp.int32))
    deg, _, counts_deg = step(params, mems, toks,
                              jnp.asarray(1, jnp.int32))
    # every token now selects exactly 1 expert per layer
    c = np.asarray(counts_deg)
    np.testing.assert_array_equal(c.sum(axis=1), np.full(cfg.n_layers, b))
    np.testing.assert_array_equal(
        np.asarray(counts_full).sum(axis=1),
        np.full(cfg.n_layers, b * cfg.moe.k))
    # gating through fewer experts is a different (still finite) function
    assert np.all(np.isfinite(np.asarray(deg)))
    assert not np.array_equal(np.asarray(deg), np.asarray(full))


def test_degraded_k_prefill_counts_scale_with_valid_tokens():
    cfg = tiny_cfg()
    b = 3
    params, mems = setup(cfg, b, seed=11)
    toks = jnp.asarray(
        np.random.default_rng(6).integers(0, cfg.vocab_size, (b, CHUNK)),
        jnp.int32)
    active = jnp.asarray([CHUNK, 2, 0], jnp.int32)
    pre = jax.jit(api.make_prefill(cfg, cfg.mem_len))
    logits, _, counts = pre(params, mems, toks, active,
                            jnp.asarray(1, jnp.int32))
    valid = int(np.asarray(active).sum())
    np.testing.assert_array_equal(
        np.asarray(counts).sum(axis=1), np.full(cfg.n_layers, valid))
    assert np.all(np.isfinite(np.asarray(logits)[:2]))


def test_out_of_range_expert_k_is_clipped():
    # the engine validates at the HTTP boundary; the program itself
    # clips defensively so a stray scalar can never select <1 or >K
    cfg = tiny_cfg()
    b = 2
    params, mems = setup(cfg, b, seed=13)
    toks = jnp.zeros((b, 1), jnp.int32)
    step = jax.jit(api.make_step_fwd(cfg, cfg.mem_len))
    lo, _, counts_lo = step(params, mems, toks, jnp.asarray(0, jnp.int32))
    one, _, counts_one = step(params, mems, toks,
                              jnp.asarray(1, jnp.int32))
    hi, _, counts_hi = step(params, mems, toks,
                            jnp.asarray(99, jnp.int32))
    full, _, counts_full = step(params, mems, toks,
                                jnp.asarray(cfg.moe.k, jnp.int32))
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(one))
    np.testing.assert_array_equal(np.asarray(counts_lo),
                                  np.asarray(counts_one))
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(full))
    np.testing.assert_array_equal(np.asarray(counts_hi),
                                  np.asarray(counts_full))


def test_softmax_renorm_renormalizes_over_active_slots():
    # with k degraded to 1 the surviving gate must renormalize to ~1,
    # and the masked slots stay exact zeros
    cfg = tiny_cfg(selection="softmax_renorm").moe
    rng = np.random.default_rng(17)
    logits = jnp.asarray(rng.normal(size=(8, cfg.n_experts)), jnp.float32)
    sel_val, sel_idx, _ = moel._selection(
        cfg, logits, jax.random.PRNGKey(0), deterministic=True,
        expert_k=jnp.asarray(1, jnp.int32))
    v = np.asarray(sel_val)
    np.testing.assert_allclose(v[:, 0], 1.0, rtol=1e-4)
    np.testing.assert_array_equal(v[:, 1:], 0.0)
    # identity at expert_k == K: bitwise equal to the unmasked path
    sel_full, _, _ = moel._selection(
        cfg, logits, jax.random.PRNGKey(0), deterministic=True,
        expert_k=jnp.asarray(cfg.k, jnp.int32))
    sel_none, _, _ = moel._selection(
        cfg, logits, jax.random.PRNGKey(0), deterministic=True)
    np.testing.assert_array_equal(np.asarray(sel_full),
                                  np.asarray(sel_none))
