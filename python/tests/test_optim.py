"""Optimizer math vs a straightforward numpy Adam."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import optim
from compile.configs import TrainConfig


def test_adam_single_step_matches_numpy():
    cfg = TrainConfig(lr=1e-3, total_steps=10**9, grad_clip=1e9)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    m, v = optim.init_opt_state(p)
    np_, nm, nv, gnorm, lr = optim.adam_update(cfg, p, g, m, v,
                                               jnp.asarray(0))
    # numpy reference, t=1
    gm = np.array([0.1, 0.2, -0.3])
    m1 = 0.1 * gm
    v1 = 0.001 * gm ** 2
    mhat = m1 / (1 - 0.9)
    vhat = v1 / (1 - 0.999)
    want = np.array([1.0, -2.0, 3.0]) - float(lr) * mhat / (
        np.sqrt(vhat) + cfg.adam_eps)
    np.testing.assert_allclose(np_["w"], want, rtol=1e-5)
    np.testing.assert_allclose(gnorm, np.linalg.norm(gm), rtol=1e-5)


def test_grad_clip_scales_to_max_norm():
    cfg = TrainConfig(grad_clip=0.25)
    g = {"a": jnp.array([3.0, 4.0])}  # norm 5
    clipped, gn = optim.clip_by_global_norm(g, cfg.grad_clip)
    np.testing.assert_allclose(gn, 5.0, rtol=1e-6)
    np.testing.assert_allclose(
        jnp.linalg.norm(clipped["a"]), 0.25, rtol=1e-5)


def test_grad_clip_noop_below_threshold():
    g = {"a": jnp.array([0.1, 0.0])}
    clipped, gn = optim.clip_by_global_norm(g, 0.25)
    np.testing.assert_allclose(clipped["a"], g["a"], rtol=1e-6)


def test_cosine_schedule_endpoints():
    cfg = TrainConfig(lr=2.5e-4, total_steps=1000)
    np.testing.assert_allclose(
        optim.cosine_lr(cfg, jnp.asarray(0)), 2.5e-4, rtol=1e-6)
    np.testing.assert_allclose(
        optim.cosine_lr(cfg, jnp.asarray(500)), 1.25e-4, rtol=1e-5)
    np.testing.assert_allclose(
        optim.cosine_lr(cfg, jnp.asarray(1000)), 0.0, atol=1e-10)
    # clamps past the horizon
    np.testing.assert_allclose(
        optim.cosine_lr(cfg, jnp.asarray(2000)), 0.0, atol=1e-10)


def test_warmup():
    cfg = TrainConfig(lr=1e-3, total_steps=10000, warmup_steps=100)
    lr0 = float(optim.cosine_lr(cfg, jnp.asarray(0)))
    lr50 = float(optim.cosine_lr(cfg, jnp.asarray(50)))
    lr100 = float(optim.cosine_lr(cfg, jnp.asarray(100)))
    assert lr0 == 0.0
    assert 0 < lr50 < lr100


def test_adam_converges_on_quadratic():
    cfg = TrainConfig(lr=0.05, total_steps=10**9, grad_clip=1e9)
    p = {"w": jnp.array([5.0])}
    m, v = optim.init_opt_state(p)
    for t in range(300):
        g = {"w": 2 * p["w"]}
        p, m, v, _, _ = optim.adam_update(cfg, p, g, m, v, jnp.asarray(t))
    assert abs(float(p["w"][0])) < 0.05
