"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py.
"""

import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not in the offline test environment")

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile.kernels import cvmm, pkm_score, ref, topk_act

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=12, deadline=None,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape,
                                     jnp.float32)


# --------------------------------------------------------------------- CVMM

@settings(**SETTINGS)
@given(
    n=st.integers(1, 70),
    m=st.integers(1, 24),
    l=st.integers(1, 24),
    ne=st.integers(1, 9),
    tile=st.sampled_from([8, 16, 128]),
)
def test_cvmm_matches_ref(n, m, l, ne, tile):
    v = rand(0, (n, m))
    s = jax.random.randint(jax.random.PRNGKey(1), (n,), 0, ne)
    mats = rand(2, (ne, m, l))
    out = cvmm.cvmm(v, s, mats, token_tile=tile)
    np.testing.assert_allclose(out, ref.cvmm_ref(v, s, mats),
                               rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(n=st.integers(2, 40), ne=st.integers(1, 6))
def test_cvmm_grads_match_ref(n, ne):
    m, l = 12, 10
    v = rand(3, (n, m))
    s = jax.random.randint(jax.random.PRNGKey(4), (n,), 0, ne)
    mats = rand(5, (ne, m, l))

    def f_kernel(v, mats):
        return (cvmm.cvmm(v, s, mats, token_tile=16) ** 2).sum()

    def f_ref(v, mats):
        return (ref.cvmm_ref(v, s, mats) ** 2).sum()

    gv1, gm1 = jax.grad(f_kernel, argnums=(0, 1))(v, mats)
    gv2, gm2 = jax.grad(f_ref, argnums=(0, 1))(v, mats)
    np.testing.assert_allclose(gv1, gv2, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(gm1, gm2, rtol=1e-3, atol=1e-4)


def test_cvmm_expert_minus_one_rows_are_zero():
    # padding-index semantics: s == -1 contributes zeros
    v = rand(6, (5, 4))
    s = jnp.array([0, -1, 1, -1, 0], jnp.int32)
    mats = rand(7, (2, 4, 3))
    out = cvmm.cvmm(v, s, mats)
    np.testing.assert_allclose(out[1], np.zeros(3), atol=1e-6)
    np.testing.assert_allclose(out[3], np.zeros(3), atol=1e-6)


def test_cvmm_grad_w_direct():
    n, m, l, ne = 33, 7, 5, 4
    v = rand(8, (n, m))
    s = jax.random.randint(jax.random.PRNGKey(9), (n,), 0, ne)
    g = rand(10, (n, l))
    dw = cvmm.cvmm_grad_w(v, s, g, ne, token_tile=8)
    np.testing.assert_allclose(dw, ref.cvmm_grad_w_ref(v, s, g, ne),
                               rtol=1e-4, atol=1e-5)


def test_cvmm_single_expert_equals_matmul():
    v = rand(11, (20, 8))
    s = jnp.zeros((20,), jnp.int32)
    mats = rand(12, (1, 8, 6))
    np.testing.assert_allclose(cvmm.cvmm(v, s, mats), v @ mats[0],
                               rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------------- Top-K

@settings(**SETTINGS)
@given(n=st.integers(1, 50), d=st.integers(2, 64), frac=st.floats(0.1, 1.0))
def test_topk_mask_matches_ref(n, d, frac):
    k = max(1, int(d * frac))
    u = rand(13, (n, d))
    out = topk_act.topk_mask(u, k)
    np.testing.assert_allclose(out, ref.topk_mask_ref(u, k),
                               rtol=1e-6, atol=1e-7)


def test_topk_mask_keeps_exactly_k():
    u = rand(14, (30, 40))
    out = np.asarray(topk_act.topk_mask(u, 5))
    counts = (out != 0).sum(axis=1)
    assert (counts == 5).all()


def test_topk_mask_full_k_is_identity():
    u = rand(15, (9, 16))
    np.testing.assert_allclose(topk_act.topk_mask(u, 16), u)


# --------------------------------------------------------------------- PKM

@settings(**SETTINGS)
@given(n=st.integers(1, 30), s_dim=st.integers(2, 20), knn=st.integers(1, 12))
def test_pkm_topk_matches_full_table(n, s_dim, knn):
    knn = min(knn, s_dim * s_dim)
    ua = rand(16, (n, s_dim))
    ub = rand(17, (n, s_dim))
    v1, i1 = pkm_score.pkm_topk(ua, ub, knn)
    v2, i2 = ref.pkm_scores_ref(ua, ub, knn)
    np.testing.assert_allclose(v1, v2, rtol=1e-5, atol=1e-6)
    # same *set* of indices (ordering among exact ties may differ)
    np.testing.assert_allclose(np.sort(np.asarray(v1), axis=1),
                               np.sort(np.asarray(v2), axis=1),
                               rtol=1e-5, atol=1e-6)


def test_pkm_fast_ref_equals_full_ref():
    ua = rand(18, (11, 9))
    ub = rand(19, (11, 9))
    v1, i1 = ref.pkm_scores_fast_ref(ua, ub, 6)
    v2, i2 = ref.pkm_scores_ref(ua, ub, 6)
    np.testing.assert_allclose(v1, v2, rtol=1e-5)
    np.testing.assert_array_equal(np.sort(i1, 1), np.sort(i2, 1))


def test_pkm_index_decomposition():
    # index = b * S + a must address ub[b] + ua[a]
    s_dim = 7
    ua = rand(20, (4, s_dim))
    ub = rand(21, (4, s_dim))
    v, i = pkm_score.pkm_topk(ua, ub, 5)
    ia = np.asarray(i) % s_dim
    ib = np.asarray(i) // s_dim
    recomputed = np.take_along_axis(np.asarray(ub), ib, 1) + \
        np.take_along_axis(np.asarray(ua), ia, 1)
    np.testing.assert_allclose(np.asarray(v), recomputed, rtol=1e-5)


# --------------------------------------------------------- MoE dispatch ref

def test_moe_dispatch_ref_selfconsistent():
    n, d, ne, g, k = 13, 8, 4, 6, 2
    x = rand(22, (n, d))
    w1 = rand(23, (ne, d, g))
    w2 = rand(24, (ne, g, d))
    idx = jax.random.randint(jax.random.PRNGKey(25), (n, k), 0, ne)
    val = jax.nn.sigmoid(rand(26, (n, k)))
    y = ref.moe_dispatch_ref(x, idx, val, w1, w2)
    # brute force
    want = np.zeros((n, d), np.float32)
    for i in range(n):
        for j in range(k):
            e = int(idx[i, j])
            h = np.maximum(np.asarray(x[i]) @ np.asarray(w1[e]), 0)
            want[i] += float(val[i, j]) * (h @ np.asarray(w2[e]))
    np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)
