"""The AOT'd per-lane memory reset: masking semantics + the flattened
buffer-name contract the Rust serving engine addresses slots by."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, api
from compile.configs import MoEConfig, ModelConfig


def tiny_cfg():
    return ModelConfig(
        name="t-moe", vocab_size=64, d_model=16, d_ff=32, n_layers=3,
        n_heads=2, head_dim=8, context=8, mem_len=8, ff_variant="moe",
        moe=MoEConfig(n_experts=4, group_size=8, k=2))


def test_reset_lanes_zeroes_only_masked_lanes():
    cfg = tiny_cfg()
    b, m = 4, cfg.mem_len
    rng = jax.random.PRNGKey(0)
    mems = [jax.random.normal(jax.random.fold_in(rng, i),
                              (b, m, cfg.d_model))
            for i in range(cfg.n_layers)]
    keep = jnp.asarray([1.0, 0.0, 1.0, 0.0], jnp.float32)
    out = api.make_reset_lanes(cfg)(mems, keep)
    assert len(out) == cfg.n_layers
    for before, after in zip(mems, out):
        np.testing.assert_allclose(after[0], before[0])
        np.testing.assert_allclose(after[2], before[2])
        assert np.all(np.asarray(after[1]) == 0.0)
        assert np.all(np.asarray(after[3]) == 0.0)


def test_reset_lanes_clears_nan_poisoned_lane():
    """A diverged lane (NaN/Inf memory) must come back as literal
    zeros, exactly like the Rust host fallback's zero-fill — a
    multiplicative mask would propagate NaN * 0 = NaN."""
    cfg = tiny_cfg()
    mems = [jnp.full((2, cfg.mem_len, cfg.d_model), jnp.nan)
            for _ in range(cfg.n_layers)]
    keep = jnp.asarray([0.0, 1.0], jnp.float32)
    out = api.make_reset_lanes(cfg)(mems, keep)
    for after in out:
        assert np.all(np.asarray(after[0]) == 0.0)
        assert np.all(np.isnan(np.asarray(after[1])))


def test_reset_lanes_manifest_names_match_engine_contract():
    """The Rust engine maps reset input ``0.<layer>`` onto step_fwd's
    memory input ``1.<layer>`` and feeds output ``<layer>`` back; the
    flattened names/shapes must follow that convention exactly."""
    cfg = tiny_cfg()
    serve_batch = 2
    smems = [jnp.zeros((serve_batch, cfg.mem_len, cfg.d_model), jnp.float32)
             for _ in range(cfg.n_layers)]
    keep = jnp.ones((serve_batch,), jnp.float32)
    _, in_spec, out_spec = aot.lower_fn(
        api.make_reset_lanes(cfg), (smems, keep))
    in_names = [b["name"] for b in in_spec]
    assert in_names == [f"0.{i}" for i in range(cfg.n_layers)] + ["1"]
    assert in_spec[-1]["shape"] == [serve_batch]
    assert in_spec[-1]["dtype"] == "float32"
    out_names = [b["name"] for b in out_spec]
    assert out_names == [str(i) for i in range(cfg.n_layers)]
    for b in out_spec:
        assert b["shape"] == [serve_batch, cfg.mem_len, cfg.d_model]
