"""HLO-compat helpers vs their modern-JAX equivalents."""

import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not in the offline test environment")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile import compat

SETTINGS = dict(max_examples=20, deadline=None)


@settings(**SETTINGS)
@given(n=st.integers(1, 30), d=st.integers(1, 50), k=st.integers(1, 50))
def test_top_k_matches_lax(n, d, k):
    k = min(k, d)
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    v1, i1 = compat.top_k(x, k)
    v2, i2 = jax.lax.top_k(x, k)
    np.testing.assert_allclose(v1, v2)
    np.testing.assert_array_equal(i1, i2)


def test_top_k_tie_breaking_matches_lax():
    x = jnp.array([[1.0, 3.0, 3.0, 2.0, 3.0]])
    v1, i1 = compat.top_k(x, 3)
    v2, i2 = jax.lax.top_k(x, 3)
    np.testing.assert_array_equal(i1, i2)  # lower index wins ties


def test_top_k_grad_matches_lax():
    x = jax.random.normal(jax.random.PRNGKey(1), (7, 13))

    def f(fn):
        return jax.grad(lambda x: (fn(x, 4)[0] ** 3).sum())(x)

    np.testing.assert_allclose(f(compat.top_k), f(jax.lax.top_k),
                               rtol=1e-5, atol=1e-6)


def test_top_k_3d():
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 4, 9))
    v1, i1 = compat.top_k(x, 2)
    v2, i2 = jax.lax.top_k(x, 2)
    np.testing.assert_allclose(v1, v2)
    np.testing.assert_array_equal(i1, i2)


@settings(**SETTINGS)
@given(n=st.integers(1, 10), d=st.integers(1, 20), k=st.integers(1, 8))
def test_take_along_last_matches_jnp(n, d, k):
    x = jax.random.normal(jax.random.PRNGKey(3), (n, d))
    idx = jax.random.randint(jax.random.PRNGKey(4), (n, k), 0, d)
    np.testing.assert_allclose(
        compat.take_along_last(x, idx),
        jnp.take_along_axis(x, idx, axis=-1))


def test_take_along_last_grad():
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 7))
    idx = jax.random.randint(jax.random.PRNGKey(6), (4, 3), 0, 7)

    def loss(fn):
        return jax.grad(lambda x: (fn(x) ** 2).sum())(x)

    g1 = loss(lambda x: compat.take_along_last(x, idx))
    g2 = loss(lambda x: jnp.take_along_axis(x, idx, axis=-1))
    np.testing.assert_allclose(g1, g2, rtol=1e-6)


def test_take_along_last_3d():
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 3, 11))
    idx = jax.random.randint(jax.random.PRNGKey(8), (2, 3, 4), 0, 11)
    np.testing.assert_allclose(
        compat.take_along_last(x, idx),
        jnp.take_along_axis(x, idx, axis=-1))


def test_argmax_onehot():
    x = jnp.array([[0.1, 0.9, 0.3], [0.5, 0.5, 0.2]])
    oh = compat.argmax_onehot(x)
    np.testing.assert_allclose(oh, [[0, 1, 0], [1, 0, 0]])
