"""Prefix-cache snapshot/restore: lifting a lane's post-prefill XL
memory out through ``snapshot_lanes`` and seeding a fresh lane with it
through ``restore_lanes`` must be *bitwise* equivalent to having
prefilled the whole prompt continuously — the invariant the Rust
engine's cache-hit path pins end to end — plus the masking/containment
semantics and the flattened buffer-name contract the engine addresses
the programs by."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, api
from compile.configs import MoEConfig, ModelConfig

CHUNK = 4


def tiny_cfg():
    return ModelConfig(
        name="t-moe", vocab_size=64, d_model=16, d_ff=32, n_layers=3,
        n_heads=2, head_dim=8, context=8, mem_len=8, ff_variant="moe",
        moe=MoEConfig(n_experts=4, group_size=8, k=2))


def setup(cfg, batch):
    params = api.M.init_params(jax.random.PRNGKey(0), cfg)
    mems = [jnp.zeros((batch, cfg.mem_len, cfg.d_model), jnp.float32)
            for _ in range(cfg.n_layers)]
    pre_fn = api.make_prefill(cfg, cfg.mem_len)
    ek = jnp.asarray(cfg.moe.k, jnp.int32)
    pre = jax.jit(lambda p, m, t, a: pre_fn(p, m, t, a, ek))
    snap = jax.jit(api.make_snapshot_lanes(cfg))
    rest = jax.jit(api.make_restore_lanes(cfg))
    return params, mems, pre, snap, rest


def feed_chunked(pre, params, mems, prompts, chunk):
    """Drain ragged prompts through [B, chunk] prefill dispatches,
    returning each lane's last-dispatch logits row and the memories."""
    b = len(prompts)
    off = [0] * b
    final_logits = [None] * b
    while any(off[i] < len(prompts[i]) for i in range(b)):
        toks = np.zeros((b, chunk), np.int32)
        active = np.zeros((b,), np.int32)
        finished = []
        for i, p in enumerate(prompts):
            k = min(chunk, len(p) - off[i])
            toks[i, :k] = p[off[i]:off[i] + k]
            active[i] = k
            off[i] += k
            if k > 0 and off[i] == len(p):
                finished.append(i)
        out = pre(params, mems, jnp.asarray(toks), jnp.asarray(active))
        logits, mems = out[0], out[1]
        for i in finished:
            final_logits[i] = logits[i]
    return final_logits, mems


def test_snapshot_gathers_selected_lanes_and_zeroes_the_rest():
    cfg = tiny_cfg()
    b = 4
    key = jax.random.PRNGKey(2)
    mems = [jax.random.normal(jax.random.fold_in(key, l),
                              (b, cfg.mem_len, cfg.d_model))
            for l in range(cfg.n_layers)]
    # NaN-poison lane 3; it is not selected, so the payload must stay
    # finite (where-select, never multiplication)
    mems = [m.at[3].set(jnp.nan) for m in mems]
    src = jnp.asarray([0, -1, 2, -1], jnp.int32)
    (payload,) = api.make_snapshot_lanes(cfg)(mems, src)
    assert payload.shape == (cfg.n_layers, b, cfg.mem_len, cfg.d_model)
    for l in range(cfg.n_layers):
        np.testing.assert_array_equal(np.asarray(payload[l, 0]),
                                      np.asarray(mems[l][0]))
        np.testing.assert_array_equal(np.asarray(payload[l, 2]),
                                      np.asarray(mems[l][2]))
        assert np.all(np.asarray(payload[l, 1]) == 0.0)
        assert np.all(np.asarray(payload[l, 3]) == 0.0)
    assert np.all(np.isfinite(np.asarray(payload)))


def test_restore_adopts_payload_rows_and_keeps_the_rest():
    cfg = tiny_cfg()
    b = 3
    key = jax.random.PRNGKey(4)
    mems = [jax.random.normal(jax.random.fold_in(key, l),
                              (b, cfg.mem_len, cfg.d_model))
            for l in range(cfg.n_layers)]
    payload = jax.random.normal(
        key, (cfg.n_layers, b, cfg.mem_len, cfg.d_model))
    # lane 1's previous occupant diverged — restore must adopt the
    # payload's literal bits over the NaNs, not blend them
    mems = [m.at[1].set(jnp.nan) for m in mems]
    keep = jnp.asarray([1.0, 0.0, 0.0], jnp.float32)
    out = api.make_restore_lanes(cfg)(mems, payload, keep)
    for l in range(cfg.n_layers):
        np.testing.assert_array_equal(np.asarray(out[l][0]),
                                      np.asarray(mems[l][0]))
        np.testing.assert_array_equal(np.asarray(out[l][1]),
                                      np.asarray(payload[l, 1]))
        np.testing.assert_array_equal(np.asarray(out[l][2]),
                                      np.asarray(payload[l, 2]))


def test_snapshot_restore_tail_prefill_is_bitwise_continuous_prefill():
    # the serving cache-hit invariant: prefill(prefix) -> snapshot ->
    # fresh lane -> restore -> prefill(tail) must equal one continuous
    # chunked prefill of prefix+tail, bit for bit (logits and memory),
    # for tails straddling the chunk boundary
    cfg = tiny_cfg()
    b = 2
    rng = np.random.default_rng(9)
    prefix = list(rng.integers(0, cfg.vocab_size, 2 * CHUNK))
    for tail_len in [1, CHUNK - 1, CHUNK, CHUNK + 1]:
        tail = list(rng.integers(0, cfg.vocab_size, tail_len))
        rider = list(rng.integers(0, cfg.vocab_size, 3))
        params, mems0, pre, snap, rest = setup(cfg, b)

        # cold reference: lane 0 prefills prefix+tail continuously
        # (lane 1 rides along with an unrelated prompt both times)
        cold_logits, cold_mems = feed_chunked(
            pre, params, mems0, [prefix + tail, rider], CHUNK)

        # warm path: prefill the prefix alone, snapshot lane 0...
        _, warm_mems = feed_chunked(
            pre, params, mems0, [prefix, rider], CHUNK)
        (payload,) = snap(warm_mems, jnp.asarray([0, -1], jnp.int32))
        # ...host round-trip (the cache stores the payload bytes)...
        payload = jnp.asarray(np.asarray(payload))
        # ...then seed a fresh engine's lane 0 from the cache and
        # prefill only the tail.  Lane 1 re-prefills its rider prompt
        # so both runs issue identically-shaped dispatches.
        _, fresh_mems = feed_chunked(
            pre, params, mems0, [rider[:1], rider], CHUNK)
        seeded = rest(fresh_mems, payload,
                      jnp.asarray([0.0, 1.0], jnp.float32))
        # the restore replaced lane 0 wholesale; lane 1 untouched
        for l in range(cfg.n_layers):
            np.testing.assert_array_equal(
                np.asarray(seeded[l][1]), np.asarray(fresh_mems[l][1]))
        warm_logits, warm_out = feed_chunked(
            pre, params, seeded, [tail, rider], CHUNK)

        np.testing.assert_array_equal(
            np.asarray(warm_logits[0]), np.asarray(cold_logits[0]),
            err_msg=f"tail {tail_len}: cache-hit logits diverge")
        for l, (mw, mc) in enumerate(zip(warm_out, cold_mems)):
            np.testing.assert_array_equal(
                np.asarray(mw[0]), np.asarray(mc[0]),
                err_msg=f"tail {tail_len} layer {l} memory diverges")


def test_prefix_cache_manifest_names_match_engine_contract():
    """The Rust engine maps snapshot inputs ``0.<layer>`` onto the
    step_fwd memory state ``1.<layer>``, uploads ``1`` (src [B] int32),
    downloads output ``0`` ([L, B, M, D] payload); restore additionally
    uploads ``1`` (payload) + ``2`` (keep [B] f32) and feeds the
    per-layer outputs back buffer-to-buffer like reset_lanes."""
    cfg = tiny_cfg()
    serve_batch = 2
    smems = [jnp.zeros((serve_batch, cfg.mem_len, cfg.d_model),
                       jnp.float32) for _ in range(cfg.n_layers)]
    src = jnp.zeros((serve_batch,), jnp.int32)
    _, in_spec, out_spec = aot.lower_fn(
        api.make_snapshot_lanes(cfg), (smems, src))
    assert [b["name"] for b in in_spec] == (
        [f"0.{i}" for i in range(cfg.n_layers)] + ["1"])
    assert in_spec[-1]["shape"] == [serve_batch]
    assert in_spec[-1]["dtype"] == "int32"
    assert [b["name"] for b in out_spec] == ["0"]
    payload_shape = [cfg.n_layers, serve_batch, cfg.mem_len, cfg.d_model]
    assert out_spec[0]["shape"] == payload_shape
    assert out_spec[0]["dtype"] == "float32"

    payload = jnp.zeros(payload_shape, jnp.float32)
    keep = jnp.ones((serve_batch,), jnp.float32)
    _, in_spec, out_spec = aot.lower_fn(
        api.make_restore_lanes(cfg), (smems, payload, keep))
    assert [b["name"] for b in in_spec] == (
        [f"0.{i}" for i in range(cfg.n_layers)] + ["1", "2"])
    assert in_spec[-2]["shape"] == payload_shape
    assert in_spec[-1]["shape"] == [serve_batch]
    assert in_spec[-1]["dtype"] == "float32"
    assert [b["name"] for b in out_spec] == [
        str(i) for i in range(cfg.n_layers)]
    for b_ in out_spec:
        assert b_["shape"] == [serve_batch, cfg.mem_len, cfg.d_model]
