"""Full-model behaviour for every FF variant + flops model + presets."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import api, flops
from compile import model as M
from compile.configs import (ModelConfig, MoEConfig, PKMConfig, TopKConfig,
                             TrainConfig, all_presets, get_preset)


def tiny_cfg(variant, **kw):
    base = dict(name=f"t-{variant}", vocab_size=64, d_model=16, d_ff=32,
                n_layers=2, n_heads=2, head_dim=8, context=8, mem_len=8,
                ff_variant=variant)
    base.update(kw)
    if variant == "moe":
        base.setdefault("moe", MoEConfig(n_experts=4, group_size=8, k=2))
    if variant == "pkm":
        base["d_ff"] = 36
        base.setdefault("pkm", PKMConfig(n_subkeys=6, knn=4, heads=2))
    if variant == "topk":
        base.setdefault("topk", TopKConfig(k=8))
    return ModelConfig(**base)


@pytest.mark.parametrize("variant", ["dense", "topk", "moe", "pkm"])
def test_forward_shapes_and_loss(variant):
    cfg = tiny_cfg(variant)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    b, t = 3, cfg.context
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, t), 0,
                                cfg.vocab_size)
    mems = [jnp.zeros((b, cfg.mem_len, cfg.d_model))
            for _ in range(cfg.n_layers)]
    logits, new_mems, aux = M.forward(params, cfg, tokens, mems,
                                      jax.random.PRNGKey(2), True,
                                      cfg.mem_len)
    assert logits.shape == (b, t, cfg.vocab_size)
    assert len(new_mems) == cfg.n_layers
    assert new_mems[0].shape == (b, cfg.mem_len, cfg.d_model)
    loss = M.lm_loss(logits, tokens)
    # at init the loss must be in the vicinity of ln(V) (the tiny test
    # dims make the init variance relatively large, hence the loose bound)
    assert abs(float(loss) - math.log(cfg.vocab_size)) < 2.5


@pytest.mark.parametrize("variant", ["dense", "topk", "moe", "pkm"])
def test_gradients_flow_everywhere(variant):
    cfg = tiny_cfg(variant)
    params = M.init_params(jax.random.PRNGKey(3), cfg)
    b, t = 2, cfg.context
    tokens = jax.random.randint(jax.random.PRNGKey(4), (b, t), 0,
                                cfg.vocab_size)
    mems = [jnp.zeros((b, cfg.mem_len, cfg.d_model))
            for _ in range(cfg.n_layers)]

    def loss_fn(p):
        logits, _, aux = M.forward(p, cfg, tokens, mems,
                                   jax.random.PRNGKey(5), False,
                                   cfg.mem_len)
        return M.lm_loss(logits, tokens) + aux["reg"]

    grads = jax.grad(loss_fn)(params)
    leaves = jax.tree_util.tree_flatten_with_path(grads)[0]
    dead = [jax.tree_util.keystr(path)
            for path, g in leaves
            if float(jnp.max(jnp.abs(g))) == 0.0
            and "out_bias" not in jax.tree_util.keystr(path)
            and "ln" not in jax.tree_util.keystr(path)
            and ".u" not in jax.tree_util.keystr(path)
            and ".v" not in jax.tree_util.keystr(path)
            and "bias" not in jax.tree_util.keystr(path)]
    assert not dead, f"dead gradients: {dead}"


def test_deterministic_eval_is_reproducible():
    cfg = tiny_cfg("moe")
    params = M.init_params(jax.random.PRNGKey(6), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(7), (2, cfg.context),
                                0, cfg.vocab_size)
    mems = [jnp.zeros((2, cfg.mem_len, cfg.d_model))
            for _ in range(cfg.n_layers)]
    l1, _, _ = M.forward(params, cfg, tokens, mems, jax.random.PRNGKey(8),
                         True, cfg.mem_len)
    l2, _, _ = M.forward(params, cfg, tokens, mems, jax.random.PRNGKey(9),
                         True, cfg.mem_len)
    np.testing.assert_allclose(l1, l2)


def test_train_step_reduces_loss_on_constant_data():
    cfg = tiny_cfg("moe")
    tcfg = TrainConfig(batch_size=2, lr=3e-3, total_steps=10_000)
    ts = jax.jit(api.make_train_step(cfg, tcfg))
    args = api.example_args(cfg, tcfg, 2 * cfg.context)
    params, m, v, mems, _, _, _ = args["train_step"]
    tokens = jax.random.randint(jax.random.PRNGKey(10),
                                (2, cfg.context + 1), 0, 8)
    first = last = None
    for step in range(12):
        out = ts(params, m, v, mems, tokens, jnp.asarray(step),
                 jnp.asarray(0, jnp.uint32))
        loss, _, _, params, m, v, mems, _ = out
        if first is None:
            first = float(loss)
        last = float(loss)
    assert last < first - 0.5, (first, last)


def test_eval_step_counts_tokens():
    cfg = tiny_cfg("dense")
    tcfg = TrainConfig(batch_size=2)
    es = jax.jit(api.make_eval_step(cfg, 2 * cfg.context))
    args = api.example_args(cfg, tcfg, 2 * cfg.context)
    params, emems, tokens = args["eval_step"]
    # example_args ships all-zero tokens (shape donors for AOT lowering);
    # a run of one repeated target is a single adversarial sample for the
    # init-NLL bound below, so evaluate on vocab-spanning random tokens
    tokens = jax.random.randint(jax.random.PRNGKey(11), tokens.shape,
                                0, cfg.vocab_size)
    s, n, _, _ = es(params, emems, tokens)
    assert float(n) == 2 * cfg.context
    assert float(s) / float(n) == pytest.approx(math.log(cfg.vocab_size),
                                                abs=2.5)


def test_step_fwd_next_token_logits():
    cfg = tiny_cfg("moe")
    tcfg = TrainConfig(batch_size=2)
    fwd = jax.jit(api.make_step_fwd(cfg, cfg.mem_len))
    args = api.example_args(cfg, tcfg, 2 * cfg.context, serve_batch=3)
    # MoE presets take a trailing runtime expert_k scalar
    params, smems, stok, ek = args["step_fwd"]
    logits, new_mems, counts = fwd(params, smems, stok, ek)
    assert logits.shape == (3, cfg.vocab_size)
    assert new_mems[0].shape == smems[0].shape
    # MoE presets append per-layer expert-selection counts
    assert counts.shape == (cfg.n_layers, cfg.moe.n_experts)


# --------------------------------------------------------------- presets

def test_all_presets_validate():
    for name, cfg in all_presets().items():
        cfg.validate()


def test_parameter_matching_tiny():
    """tiny-dense and tiny-moe must be parameter-matched within 1%."""
    d = flops.model_params(get_preset("tiny-dense"))
    m = flops.model_params(get_preset("tiny-moe"))
    assert abs(d - m) / d < 0.01, (d, m)


def test_parameter_matching_paper_scale():
    """The paper-scale presets must land near the advertised counts."""
    p47 = flops.model_params(get_preset("wt103-s-dense"))
    assert 40e6 < p47 < 55e6, p47
    p262 = flops.model_params(get_preset("wt103-b-dense"))
    assert 240e6 < p262 < 285e6, p262
    p41 = flops.model_params(get_preset("enwik8-dense"))
    assert 36e6 < p41 < 46e6, p41


def test_flops_fractions_match_paper():
    """Tab. 3 '% FLOPs' column: 25% small, 12.5% big; Tab. 7 3.1% WT-S*."""
    s = flops.ff_fraction_vs_dense(get_preset("wt103-s-moe"),
                                   get_preset("wt103-s-dense"))
    assert abs(s["flops_fraction"] - 0.25) < 0.01, s
    b = flops.ff_fraction_vs_dense(get_preset("wt103-b-moe"),
                                   get_preset("wt103-b-dense"))
    assert abs(b["flops_fraction"] - 0.125) < 0.005, b
    star = flops.ff_fraction_vs_dense(get_preset("wt103-s-star-moe"),
                                      get_preset("wt103-s-star-dense"))
    assert abs(star["flops_fraction"] - 0.031) < 0.002, star


def test_moe_flops_independent_of_n_experts():
    """App. A.5: MoE cost depends on G and K, not N_E (selector aside)."""
    a = flops.moe_ff_cost(512, 16, 128, 4)
    b = flops.moe_ff_cost(512, 64, 128, 4)
    assert a.flops == b.flops
    assert b.selector_flops > a.selector_flops
