"""σ-MoE layer semantics: selection variants, regularizers, expert
dropout, initialization, and the dense-equivalence property."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import MoEConfig
from compile.kernels import ref
from compile.layers import moe
from compile.layers.common import dense_std


def mk_params(key, d, ne, g):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(key), 3)
    return {
        "w1": 0.3 * jax.random.normal(k1, (ne, d, g)),
        "w2": 0.3 * jax.random.normal(k2, (ne, g, d)),
        "w3": 0.3 * jax.random.normal(k3, (d, ne)),
    }


def run(cfg, p, x, deterministic=True, seed=0):
    return moe.moe_ff(p, x, jax.random.PRNGKey(seed), cfg, deterministic)


def test_moe_matches_dispatch_ref():
    d, ne, g, k, n = 16, 8, 4, 2, 24
    cfg = MoEConfig(n_experts=ne, group_size=g, k=k, selection="sigmoid",
                    regularization="none")
    p = mk_params(0, d, ne, g)
    x = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    y, aux = run(cfg, p, x)
    logits = x @ p["w3"]
    scores = jax.nn.sigmoid(logits)
    _, idx = jax.lax.top_k(scores, k)
    val = jnp.take_along_axis(scores, idx, axis=1)
    want = ref.moe_dispatch_ref(x, idx, val, p["w1"], p["w2"])
    np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)


def test_all_experts_selected_equals_dense():
    """K = N_E with unit gates == the dense MLP with concatenated experts
    (the paper's Sec. 3 equivalence)."""
    d, ne, g, n = 12, 4, 8, 10
    cfg = MoEConfig(n_experts=ne, group_size=g, k=ne, selection="sigmoid",
                    regularization="none")
    p = mk_params(2, d, ne, g)
    # force gates to 1: huge positive logits
    p["w3"] = jnp.zeros_like(p["w3"])
    x = jax.random.normal(jax.random.PRNGKey(3), (n, d))
    y, _ = run(cfg, p, x)
    # dense equivalent: W1 [d, ne*g], W2 [ne*g, d], gate 0.5 (sigmoid(0))
    w1 = jnp.concatenate([p["w1"][e] for e in range(ne)], axis=1)
    w2 = jnp.concatenate([p["w2"][e] for e in range(ne)], axis=0)
    dense = (0.5 * jax.nn.relu(x @ w1)) @ w2
    np.testing.assert_allclose(y, dense, rtol=1e-4, atol=1e-4)


def test_sigmoid_gates_do_not_compete():
    """Increasing one expert's logit must not change the other selected
    expert's gate value (the paper's core argument for sigmoid)."""
    d, ne, g = 8, 4, 4
    cfg = MoEConfig(n_experts=ne, group_size=g, k=2, selection="sigmoid",
                    regularization="none")
    p = mk_params(4, d, ne, g)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, d))
    logits = x @ p["w3"]
    s = jax.nn.sigmoid(logits)
    _, idx = jax.lax.top_k(s, 2)
    # bump w3 toward the top expert: other gate unchanged under sigmoid
    e_top = int(idx[0, 0])
    e_other = int(idx[0, 1])
    p2 = dict(p)
    p2["w3"] = p["w3"].at[:, e_top].multiply(2.0)
    s2 = jax.nn.sigmoid(x @ p2["w3"])
    np.testing.assert_allclose(s[0, e_other], s2[0, e_other], rtol=1e-6)
    # whereas softmax would redistribute mass:
    sm1 = jax.nn.softmax(x @ p["w3"])[0, e_other]
    sm2 = jax.nn.softmax(x @ p2["w3"])[0, e_other]
    assert not np.allclose(sm1, sm2, rtol=1e-6)


def test_softmax_renorm_gates_sum_to_one():
    cfg = MoEConfig(n_experts=8, group_size=4, k=4,
                    selection="softmax_renorm", regularization="none")
    p = mk_params(6, 16, 8, 4)
    x = jax.random.normal(jax.random.PRNGKey(7), (9, 16))
    logits = x @ p["w3"]
    val, idx, probs = moe._selection(cfg, logits, jax.random.PRNGKey(0),
                                     True)
    np.testing.assert_allclose(val.sum(axis=-1), np.ones(9), rtol=1e-5)


def test_switch_selects_top1():
    cfg = MoEConfig(n_experts=8, group_size=4, k=1, selection="switch",
                    regularization="switch", reg_gamma=0.01)
    p = mk_params(8, 16, 8, 4)
    x = jax.random.normal(jax.random.PRNGKey(9), (5, 16))
    logits = x @ p["w3"]
    val, idx, probs = moe._selection(cfg, logits, jax.random.PRNGKey(0),
                                     True)
    assert idx.shape == (5, 1)
    np.testing.assert_array_equal(np.asarray(idx[:, 0]),
                                  np.asarray(jnp.argmax(logits, axis=-1)))
    # switch gate value is the softmax prob of the selected expert
    np.testing.assert_allclose(
        val[:, 0], jnp.max(jax.nn.softmax(logits, -1), axis=-1), rtol=1e-5)


def test_sbase_sinkhorn_balances_routing():
    """With Sinkhorn routing, expert assignment counts must be (nearly)
    uniform across a random batch, unlike raw top-1."""
    ne = 8
    cfg = MoEConfig(n_experts=ne, group_size=4, k=1, selection="sbase",
                    regularization="none", sinkhorn_iters=20)
    p = mk_params(10, 16, ne, 4)
    # skewed logits so that raw argmax collapses
    x = jax.random.normal(jax.random.PRNGKey(11), (256, 16))
    p["w3"] = p["w3"].at[:, 0].add(3.0)
    logits = x @ p["w3"]
    raw_counts = np.bincount(
        np.asarray(jnp.argmax(logits, -1)), minlength=ne)
    _, idx, _ = moe._selection(cfg, logits, jax.random.PRNGKey(0),
                               deterministic=False)
    sk_counts = np.bincount(np.asarray(idx[:, 0]), minlength=ne)
    assert raw_counts.max() > 2 * sk_counts.max() or \
        sk_counts.std() < raw_counts.std()
    # deterministic (eval) mode ignores sinkhorn:
    _, idx_det, _ = moe._selection(cfg, logits, jax.random.PRNGKey(0),
                                   deterministic=True)
    np.testing.assert_array_equal(np.asarray(idx_det[:, 0]),
                                  np.argmax(np.asarray(
                                      jax.nn.sigmoid(logits)), -1))


def test_expert_dropout_masks_experts():
    """With δ→1-ε, almost all experts are masked; selections must avoid
    dropped experts and gates of dropped experts are zero."""
    ne = 16
    cfg = MoEConfig(n_experts=ne, group_size=2, k=2, selection="sigmoid",
                    regularization="none", expert_dropout=0.5)
    p = mk_params(12, 8, ne, 2)
    x = jax.random.normal(jax.random.PRNGKey(13), (64, 8))
    logits = x @ p["w3"]
    val, idx, _ = moe._selection(cfg, logits, jax.random.PRNGKey(3),
                                 deterministic=False)
    # no rescaling: every nonzero gate equals the raw sigmoid score
    sig = np.asarray(jax.nn.sigmoid(logits))
    val = np.asarray(val)
    idx = np.asarray(idx)
    nz = val > 0
    for i in range(val.shape[0]):
        for j in range(val.shape[1]):
            if nz[i, j]:
                np.testing.assert_allclose(val[i, j], sig[i, idx[i, j]],
                                           rtol=1e-5)


def test_entropy_regularizer_sign_and_minimum():
    cfg = MoEConfig(n_experts=4, group_size=2, k=1,
                    regularization="entropy", reg_gamma=1.0)
    uniform = jnp.full((10, 4), 0.25)
    sel_idx = jnp.zeros((10, 1), jnp.int32)
    r_uniform = moe._regularization(cfg, uniform, sel_idx)
    peaked = jnp.tile(jnp.array([[0.97, 0.01, 0.01, 0.01]]), (10, 1))
    r_peaked = moe._regularization(cfg, peaked, sel_idx)
    # entropy reg = sum p log p: minimized (most negative) at uniform
    assert r_uniform < r_peaked


def test_switch_regularizer_uniform_is_one():
    """N_E * f·p == 1 under perfectly uniform routing (Fedus et al.)."""
    ne = 4
    cfg = MoEConfig(n_experts=ne, group_size=2, k=1,
                    regularization="switch", reg_gamma=1.0)
    probs = jnp.full((8, ne), 1.0 / ne)
    sel_idx = jnp.arange(8, dtype=jnp.int32).reshape(8, 1) % ne
    r = moe._regularization(cfg, probs, sel_idx)
    np.testing.assert_allclose(r, 1.0, rtol=1e-6)


def test_init_ours_vs_standard_scale():
    d, ne, g, nl = 64, 8, 32, 6
    p_ours = moe.moe_init(jax.random.PRNGKey(0),
                          d, MoEConfig(n_experts=ne, group_size=g,
                                       init="ours"), nl)
    p_std = moe.moe_init(jax.random.PRNGKey(0),
                         d, MoEConfig(n_experts=ne, group_size=g,
                                      init="standard"), nl)
    # ours: W2 std based on d_ff = ne*g; standard: based on g (larger)
    s_ours = float(jnp.std(p_ours["w2"]))
    s_std = float(jnp.std(p_std["w2"]))
    assert s_std > s_ours * 2
    np.testing.assert_allclose(s_ours, dense_std(ne * g, nl), rtol=0.05)
    np.testing.assert_allclose(s_std, dense_std(g, nl), rtol=0.05)
    # selection rows all same norm for ours
    norms = jnp.linalg.norm(p_ours["w3"], axis=0)
    np.testing.assert_allclose(norms, norms[0] * jnp.ones_like(norms),
                               rtol=1e-4)


def test_usage_stats_shapes_and_counts():
    d, ne, g, k, n = 8, 4, 4, 2, 20
    cfg = MoEConfig(n_experts=ne, group_size=g, k=k,
                    regularization="none")
    p = mk_params(14, d, ne, g)
    x = jax.random.normal(jax.random.PRNGKey(15), (n, d))
    _, aux = run(cfg, p, x)
    assert aux["usage"].shape == (ne,)
    np.testing.assert_allclose(aux["usage"].sum(), n * k)
    assert aux["cooccurrence"].shape == (ne, ne)
    # diagonal of co-occurrence counts each expert's token count
    np.testing.assert_allclose(jnp.diag(aux["cooccurrence"]).sum(), n * k)
