"""Expert-utilization telemetry: the expert-count-returning
``step_fwd``/``prefill`` signatures must be *bit-for-bit* logit- and
memory-equivalent to the two-output signatures (the counts are a pure
extra reduction of the router's one-hot — never a perturbation of the
model math), counts must sum to ``valid_tokens * K`` per layer, and
non-MoE presets must keep the two-output signature so old artifacts
fall back cleanly on the Rust side (``expert_stats_unavailable``)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, api
from compile import model as M
from compile.configs import MoEConfig, ModelConfig

CHUNK = 4


def tiny_cfg(variant="moe"):
    return ModelConfig(
        name=f"t-{variant}", vocab_size=64, d_model=16, d_ff=32,
        n_layers=3, n_heads=2, head_dim=8, context=8, mem_len=8,
        ff_variant=variant,
        moe=MoEConfig(n_experts=4, group_size=8, k=2))


def setup(cfg, batch, seed=0):
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    mems = [jnp.asarray(rng.normal(size=(batch, cfg.mem_len,
                                         cfg.d_model)), jnp.float32)
            for _ in range(cfg.n_layers)]
    return params, mems


def old_step_fwd(cfg, mem_len):
    """The pre-telemetry two-output signature, reconstructed inline —
    the bit-equivalence baseline."""
    def step_fwd(params, mems, tokens):
        rng = jax.random.PRNGKey(0)
        logits, new_mems, _ = M.forward(
            params, cfg, tokens, mems, rng, deterministic=True,
            mem_len=mem_len)
        return (logits[:, -1, :], new_mems)
    return step_fwd


def old_prefill(cfg, mem_len):
    def prefill(params, mems, tokens, active_len):
        b, c = tokens.shape
        active_len = jnp.clip(active_len.astype(jnp.int32), 0, c)
        rng = jax.random.PRNGKey(0)
        logits, new_mems, _ = M.forward(
            params, cfg, tokens, mems, rng, deterministic=True,
            mem_len=mem_len, active_len=active_len)
        last = jnp.clip(active_len - 1, 0, c - 1)
        rows = jnp.arange(b, dtype=jnp.int32) * c + last
        logits_last = jnp.take(logits.reshape(b * c, -1), rows, axis=0)
        return (logits_last, new_mems)
    return prefill


def test_step_fwd_logits_bit_identical_to_old_signature():
    cfg = tiny_cfg()
    b = 3
    params, mems = setup(cfg, b, seed=5)
    toks = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (b, 1)),
        jnp.int32)
    new = jax.jit(api.make_step_fwd(cfg, cfg.mem_len))
    old = jax.jit(old_step_fwd(cfg, cfg.mem_len))
    ek = jnp.asarray(cfg.moe.k, jnp.int32)
    logits_n, mems_n, counts = new(params, mems, toks, ek)
    logits_o, mems_o = old(params, mems, toks)
    np.testing.assert_array_equal(np.asarray(logits_n),
                                  np.asarray(logits_o))
    for l, (mn, mo) in enumerate(zip(mems_n, mems_o)):
        np.testing.assert_array_equal(np.asarray(mn), np.asarray(mo),
                                      err_msg=f"layer {l} memory")
    # every token selects exactly K experts in every layer
    c = np.asarray(counts)
    assert c.shape == (cfg.n_layers, cfg.moe.n_experts)
    np.testing.assert_array_equal(c.sum(axis=1),
                                  np.full(cfg.n_layers, b * cfg.moe.k))
    assert np.all(c >= 0)


def test_prefill_logits_bit_identical_and_counts_mask_padding():
    cfg = tiny_cfg()
    b = 3
    params, mems = setup(cfg, b, seed=9)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, CHUNK)),
                       jnp.int32)
    active = jnp.asarray([CHUNK, 2, 0], jnp.int32)
    new = jax.jit(api.make_prefill(cfg, cfg.mem_len))
    old = jax.jit(old_prefill(cfg, cfg.mem_len))
    ek = jnp.asarray(cfg.moe.k, jnp.int32)
    logits_n, mems_n, counts = new(params, mems, toks, active, ek)
    logits_o, mems_o = old(params, mems, toks, active)
    np.testing.assert_array_equal(np.asarray(logits_n),
                                  np.asarray(logits_o))
    for l, (mn, mo) in enumerate(zip(mems_n, mems_o)):
        np.testing.assert_array_equal(np.asarray(mn), np.asarray(mo),
                                      err_msg=f"layer {l} memory")
    # padded positions route through the dense math but are masked out
    # of the counts: per layer, counts sum to sum(active_len) * K
    c = np.asarray(counts)
    valid = int(np.asarray(active).sum())
    np.testing.assert_array_equal(
        c.sum(axis=1), np.full(cfg.n_layers, valid * cfg.moe.k))


def test_prefill_counts_survive_nan_poisoned_idle_lane():
    # an idle lane with NaN memory must not poison the counts (masking
    # is where-based, and the one-hot is computed from indices, but the
    # padded rows' logits may be NaN — the mask must drop them)
    cfg = tiny_cfg()
    b = 2
    params, mems = setup(cfg, b, seed=3)
    mems = [m.at[1].set(jnp.nan) for m in mems]
    toks = jnp.zeros((b, CHUNK), jnp.int32)
    active = jnp.asarray([CHUNK, 0], jnp.int32)
    pre = jax.jit(api.make_prefill(cfg, cfg.mem_len))
    ek = jnp.asarray(cfg.moe.k, jnp.int32)
    _, _, counts = pre(params, mems, toks, active, ek)
    c = np.asarray(counts)
    assert np.all(np.isfinite(c))
    np.testing.assert_array_equal(
        c.sum(axis=1), np.full(cfg.n_layers, CHUNK * cfg.moe.k))


def test_non_moe_presets_keep_two_output_signature():
    # dense artifacts must lower to the old 2-output contract so the
    # Rust engine's fallback (expert_stats_unavailable) stays reachable
    cfg = tiny_cfg("dense")
    b = 2
    params, mems = setup(cfg, b)
    stok = jnp.zeros((b, 1), jnp.int32)
    out = jax.jit(api.make_step_fwd(cfg, cfg.mem_len))(
        params, mems, stok)
    assert len(out) == 2
    _, _, out_spec = aot.lower_fn(
        api.make_step_fwd(cfg, cfg.mem_len), (params, mems, stok))
    names = [b_["name"] for b_ in out_spec]
    assert names == ["0"] + [f"1.{i}" for i in range(cfg.n_layers)]


def test_step_fwd_manifest_appends_counts_output():
    cfg = tiny_cfg()
    b = 2
    params, mems = setup(cfg, b)
    stok = jnp.zeros((b, 1), jnp.int32)
    ek = jnp.asarray(cfg.moe.k, jnp.int32)
    _, in_spec, out_spec = aot.lower_fn(
        api.make_step_fwd(cfg, cfg.mem_len), (params, mems, stok, ek))
    names = [b_["name"] for b_ in out_spec]
    assert names == (["0"] + [f"1.{i}" for i in range(cfg.n_layers)]
                     + ["2"])
    assert out_spec[-1]["shape"] == [cfg.n_layers, cfg.moe.n_experts]
    assert out_spec[-1]["dtype"] == "float32"
    # ...and the trailing runtime expert_k scalar input "3"
    assert in_spec[-1]["name"] == "3"
    assert in_spec[-1]["shape"] == []
    assert in_spec[-1]["dtype"] == "int32"
