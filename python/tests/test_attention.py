"""Transformer-XL attention: rel-shift, causality, memory recurrence."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.layers import attention as att


def test_rel_shift_against_direct():
    """The shifted BD term must satisfy bd[b,h,i,j] = x[b,h,i, K-1-(i+M-j)]
    i.e. score of query i against relative distance (i + M - j)."""
    b, h, t, m = 1, 1, 4, 3
    k = t + m
    x = jnp.arange(b * h * t * k, dtype=jnp.float32).reshape(b, h, t, k)
    y = np.asarray(att._rel_shift(x))
    xn = np.asarray(x)
    for i in range(t):
        for j in range(k):
            dist = i + m - j  # relative distance of key j from query i
            if 0 <= dist < k:
                # column index in the unshifted tensor: reversed encodings
                src = k - 1 - dist
                np.testing.assert_allclose(y[0, 0, i, j], xn[0, 0, i, src])


def test_causality():
    """Perturbing a future token must not change past outputs."""
    d, h, hd, t, b = 16, 2, 8, 6, 2
    p = att.attention_init(jax.random.PRNGKey(0), d, h, hd, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, d))
    mem = jnp.zeros((b, 0, d))
    rng = jax.random.PRNGKey(0)
    y1 = att.attention(p, x, mem, rng, h, hd, 0.0, True)
    x2 = x.at[:, -1].add(10.0)
    y2 = att.attention(p, x2, mem, rng, h, hd, 0.0, True)
    np.testing.assert_allclose(y1[:, :-1], y2[:, :-1], rtol=1e-4,
                               atol=1e-5)
    assert not np.allclose(y1[:, -1], y2[:, -1], rtol=1e-3)


def test_memory_extends_context():
    """Attention over [mem | x] must differ from zero memory, and match
    processing the concatenated sequence's tail."""
    d, h, hd, t, m, b = 16, 2, 8, 4, 4, 1
    p = att.attention_init(jax.random.PRNGKey(2), d, h, hd, 2)
    full = jax.random.normal(jax.random.PRNGKey(3), (b, m + t, d))
    mem, x = full[:, :m], full[:, m:]
    rng = jax.random.PRNGKey(0)
    y_mem = att.attention(p, x, mem, rng, h, hd, 0.0, True)
    # process the whole sequence in one go; the last t outputs must agree
    y_full = att.attention(p, full, jnp.zeros((b, 0, d)), rng, h, hd,
                           0.0, True)
    np.testing.assert_allclose(y_mem, y_full[:, m:], rtol=1e-4, atol=1e-5)


def test_update_memory_keeps_tail():
    b, t, m, d = 2, 5, 3, 4
    x = jnp.arange(b * t * d, dtype=jnp.float32).reshape(b, t, d)
    mem = -jnp.ones((b, m, d))
    new = att.update_memory(x, mem, m)
    assert new.shape == (b, m, d)
    np.testing.assert_allclose(new, np.asarray(x[:, -m:]))


def test_update_memory_longer_than_segment():
    """mem_len > T keeps the old tail plus all of x."""
    b, t, m, d = 1, 2, 5, 3
    x = jnp.ones((b, t, d))
    mem = jnp.zeros((b, m, d))
    new = att.update_memory(x, mem, m)
    assert new.shape == (b, m, d)
    np.testing.assert_allclose(new[:, -t:], np.ones((b, t, d)))
    np.testing.assert_allclose(new[:, :-t], np.zeros((b, m - t, d)))


def test_rel_pos_encoding_shape_and_range():
    enc = att.rel_pos_encoding(10, 16)
    assert enc.shape == (10, 16)
    assert float(jnp.max(jnp.abs(enc))) <= 1.0 + 1e-6
