"""Chunked prefill: the validity-masked multi-token prompt path must be
token-for-token equivalent to feeding the same prompt through the
single-token ``step_fwd`` semantics — logits at every sampled position
AND the per-lane XL memory state — for ragged lengths straddling chunk
boundaries, mixed prefill/decode batches, and NaN-poisoned lanes."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, api
from compile.configs import MoEConfig, ModelConfig

CHUNK = 4


def tiny_cfg():
    return ModelConfig(
        name="t-moe", vocab_size=64, d_model=16, d_ff=32, n_layers=3,
        n_heads=2, head_dim=8, context=8, mem_len=8, ff_variant="moe",
        moe=MoEConfig(n_experts=4, group_size=8, k=2))


def setup(cfg, batch):
    params = api.M.init_params(jax.random.PRNGKey(0), cfg)
    mems = [jnp.zeros((batch, cfg.mem_len, cfg.d_model), jnp.float32)
            for _ in range(cfg.n_layers)]
    step_fn = api.make_step_fwd(cfg, cfg.mem_len)
    pre_fn = api.make_prefill(cfg, cfg.mem_len)
    # bind the runtime expert_k scalar to its identity value K so the
    # helpers keep the pre-adaptive-k call shape
    ek = jnp.asarray(cfg.moe.k, jnp.int32)
    step = jax.jit(lambda p, m, t: step_fn(p, m, t, ek))
    pre = jax.jit(lambda p, m, t, a: pre_fn(p, m, t, a, ek))
    return params, mems, step, pre


def feed_single(step, params, mems, prompts):
    """Reference: one step_fwd call per token, all lanes in lockstep
    (prompts must share a length here).  MoE presets return a trailing
    expert-counts output — indexed unpacking keeps this helper working
    for both signatures."""
    logits = None
    for j in range(len(prompts[0])):
        toks = jnp.asarray([[p[j]] for p in prompts], jnp.int32)
        out = step(params, mems, toks)
        logits, mems = out[0], out[1]
    return logits, mems


def feed_chunked(pre, params, mems, prompts, chunk):
    """Drain ragged prompts through [B, chunk] prefill dispatches; a
    lane whose prompt is exhausted rides with active_len=0.  Returns
    each lane's logits from the dispatch that consumed its last prompt
    token (the row the engine samples the first continuation from)."""
    b = len(prompts)
    off = [0] * b
    final_logits = [None] * b
    while any(off[i] < len(prompts[i]) for i in range(b)):
        toks = np.zeros((b, chunk), np.int32)
        active = np.zeros((b,), np.int32)
        finished = []
        for i, p in enumerate(prompts):
            k = min(chunk, len(p) - off[i])
            toks[i, :k] = p[off[i]:off[i] + k]
            active[i] = k
            off[i] += k
            if k > 0 and off[i] == len(p):
                finished.append(i)
        out = pre(params, mems, jnp.asarray(toks), jnp.asarray(active))
        logits, mems = out[0], out[1]
        for i in finished:
            final_logits[i] = logits[i]
    return final_logits, mems


def test_chunked_prefill_matches_single_token_across_boundaries():
    # ragged lengths straddling the chunk boundary: C-1, C, C+1, 2C+3
    cfg = tiny_cfg()
    lengths = [CHUNK - 1, CHUNK, CHUNK + 1, 2 * CHUNK + 3]
    rng = np.random.default_rng(7)
    prompts = [list(rng.integers(0, cfg.vocab_size, n)) for n in lengths]
    params, mems, step, pre = setup(cfg, len(lengths))

    logits_c, mems_c = feed_chunked(pre, params, mems, prompts, CHUNK)

    # per-lane single-token reference (lane i alone in a batch of 1)
    for i, p in enumerate(prompts):
        params1, mems1, step1, _ = setup(cfg, 1)
        ref_logits, ref_mems = feed_single(step1, params, mems1, [p])
        np.testing.assert_allclose(
            np.asarray(logits_c[i]), np.asarray(ref_logits[0]),
            rtol=2e-4, atol=2e-5,
            err_msg=f"lane {i} (len {len(p)}) logits diverge")
        for l, (mc, mr) in enumerate(zip(mems_c, ref_mems)):
            np.testing.assert_allclose(
                np.asarray(mc[i]), np.asarray(mr[0]),
                rtol=2e-4, atol=2e-5,
                err_msg=f"lane {i} layer {l} memory diverges")


def test_decode_lane_rides_prefill_with_active_len_one():
    # a decode-phase lane fed as a 1-active chunk must match step_fwd
    # exactly (same program shape the engine uses for mixed pumps)
    cfg = tiny_cfg()
    b = 2
    params, mems, step, pre = setup(cfg, b)
    rng = np.random.default_rng(3)
    warm = [list(rng.integers(0, cfg.vocab_size, 3)) for _ in range(b)]
    _, mems = feed_single(step, params, mems, warm)

    tok = jnp.asarray([[5], [9]], jnp.int32)
    ref = step(params, mems, tok)
    ref_logits, ref_mems = ref[0], ref[1]

    ptoks = np.zeros((b, CHUNK), np.int32)
    ptoks[0, 0], ptoks[1, 0] = 5, 9
    out = pre(params, mems, jnp.asarray(ptoks),
              jnp.asarray([1, 1], np.int32))
    pre_logits, pre_mems = out[0], out[1]
    np.testing.assert_allclose(np.asarray(pre_logits),
                               np.asarray(ref_logits), rtol=2e-4,
                               atol=2e-5)
    for mc, mr in zip(pre_mems, ref_mems):
        np.testing.assert_allclose(np.asarray(mc), np.asarray(mr),
                                   rtol=2e-4, atol=2e-5)


def test_idle_lane_memory_is_bit_for_bit_untouched():
    # active_len == 0 must pass memory through unchanged — including a
    # NaN-poisoned lane, whose garbage must not leak into other lanes
    cfg = tiny_cfg()
    b = 3
    params, mems, step, pre = setup(cfg, b)
    key = jax.random.PRNGKey(1)
    mems = [jax.random.normal(jax.random.fold_in(key, l),
                              (b, cfg.mem_len, cfg.d_model))
            for l in range(cfg.n_layers)]
    # poison lane 2's memory
    mems = [m.at[2].set(jnp.nan) for m in mems]

    toks = np.zeros((b, CHUNK), np.int32)
    toks[0, :2] = [7, 8]
    res = pre(params, mems, jnp.asarray(toks),
              jnp.asarray([2, 0, 0], np.int32))
    logits, out = res[0], res[1]
    for l, (before, after) in enumerate(zip(mems, out)):
        # idle healthy lane: identical bits
        np.testing.assert_array_equal(np.asarray(after[1]),
                                      np.asarray(before[1]))
        # poisoned idle lane keeps its NaNs (its own state, contained)
        assert np.all(np.isnan(np.asarray(after[2])))
        # active lane's new memory is finite — no cross-lane leakage
        assert np.all(np.isfinite(np.asarray(after[0]))), f"layer {l}"
    assert np.all(np.isfinite(np.asarray(logits[0])))


def test_prefill_manifest_names_match_engine_contract():
    """The Rust engine maps prefill inputs ``0.*``/``1.*`` onto the
    step_fwd device state, uploads ``2`` (tokens [B, C]), ``3``
    (active_len [B]) and — MoE presets — ``4`` (expert_k scalar),
    reads output ``0`` (logits_last) and feeds outputs ``1.*`` back
    buffer-to-buffer."""
    cfg = tiny_cfg()
    serve_batch = 2
    smems = [jnp.zeros((serve_batch, cfg.mem_len, cfg.d_model),
                       jnp.float32) for _ in range(cfg.n_layers)]
    ptok = jnp.zeros((serve_batch, CHUNK), jnp.int32)
    active = jnp.full((serve_batch,), CHUNK, jnp.int32)
    ek = jnp.asarray(cfg.moe.k, jnp.int32)
    params = api.M.init_params(jax.random.PRNGKey(0), cfg)
    _, in_spec, out_spec = aot.lower_fn(
        api.make_prefill(cfg, cfg.mem_len),
        (params, smems, ptok, active, ek))
    in_names = [b["name"] for b in in_spec]
    assert in_names[-3:] == ["2", "3", "4"]
    assert all(n.startswith(("0.", "1.")) for n in in_names[:-3])
    mem_inputs = [b for b in in_spec if b["name"].startswith("1.")]
    assert [b["name"] for b in mem_inputs] == [
        f"1.{i}" for i in range(cfg.n_layers)]
    tok_spec = in_spec[-3]
    assert tok_spec["shape"] == [serve_batch, CHUNK]
    assert tok_spec["dtype"] == "int32"
    act_spec = in_spec[-2]
    assert act_spec["shape"] == [serve_batch]
    assert act_spec["dtype"] == "int32"
    ek_spec = in_spec[-1]
    assert ek_spec["shape"] == []
    assert ek_spec["dtype"] == "int32"
    out_names = [b["name"] for b in out_spec]
    # MoE presets carry a trailing expert-counts output "2"; the engine
    # treats it as optional (absent on dense/topk/pkm artifacts)
    assert out_names == (["0"]
                         + [f"1.{i}" for i in range(cfg.n_layers)]
                         + ["2"])
    assert out_spec[0]["shape"] == [serve_batch, cfg.vocab_size]
    for b_, sm in zip(out_spec[1:-1], smems):
        assert b_["shape"] == list(sm.shape)
    assert out_spec[-1]["shape"] == [cfg.n_layers, cfg.moe.n_experts]
    assert out_spec[-1]["dtype"] == "float32"


def setup_verify(cfg, batch):
    """Like ``setup`` but the prefill variant returns logits at all C
    positions (``verify_logits=True``) — the speculative verifier."""
    params = api.M.init_params(jax.random.PRNGKey(0), cfg)
    mems = [jnp.zeros((batch, cfg.mem_len, cfg.d_model), jnp.float32)
            for _ in range(cfg.n_layers)]
    step_fn = api.make_step_fwd(cfg, cfg.mem_len)
    ver_fn = api.make_prefill(cfg, cfg.mem_len, verify_logits=True)
    ek = jnp.asarray(cfg.moe.k, jnp.int32)
    step = jax.jit(lambda p, m, t: step_fn(p, m, t, ek))
    ver = jax.jit(lambda p, m, t, a: ver_fn(p, m, t, a, ek))
    return params, mems, step, ver


def test_verify_logits_every_position_matches_token_by_token():
    # speculative acceptance reads row j as "the next-token distribution
    # after fed token j" — each valid row must match what step_fwd would
    # have produced feeding the same tokens one at a time
    cfg = tiny_cfg()
    b = 2
    params, mems, step, ver = setup_verify(cfg, b)
    rng = np.random.default_rng(11)
    prompts = [list(rng.integers(0, cfg.vocab_size, CHUNK))
               for _ in range(b)]

    toks = jnp.asarray(prompts, jnp.int32)
    active = jnp.full((b,), CHUNK, jnp.int32)
    out = ver(params, mems, toks, active)
    all_logits, ver_mems = np.asarray(out[0]), out[1]
    assert all_logits.shape == (b, CHUNK, cfg.vocab_size)

    ref_mems = mems
    for j in range(CHUNK):
        tok = jnp.asarray([[p[j]] for p in prompts], jnp.int32)
        r = step(params, ref_mems, tok)
        ref_logits, ref_mems = r[0], r[1]
        np.testing.assert_allclose(
            all_logits[:, j], np.asarray(ref_logits),
            rtol=2e-4, atol=2e-5, err_msg=f"position {j} diverges")
    for l, (mv, mr) in enumerate(zip(ver_mems, ref_mems)):
        np.testing.assert_allclose(
            np.asarray(mv), np.asarray(mr), rtol=2e-4, atol=2e-5,
            err_msg=f"layer {l} memory diverges")


def test_verify_logits_last_valid_row_is_bitwise_the_legacy_gather():
    # rollback correctness hinges on the verify program being the same
    # computation as legacy prefill: the row at active_len-1 and the
    # memory feedback must be bit-for-bit identical, ragged included
    cfg = tiny_cfg()
    lens = [CHUNK, CHUNK - 1, 1]
    b = len(lens)
    params, mems, _, pre = setup(cfg, b)
    _, _, _, ver = setup_verify(cfg, b)
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, CHUNK)),
                       jnp.int32)
    active = jnp.asarray(lens, jnp.int32)

    legacy = pre(params, mems, toks, active)
    full = ver(params, mems, toks, active)
    for i, n in enumerate(lens):
        np.testing.assert_array_equal(
            np.asarray(full[0])[i, n - 1], np.asarray(legacy[0])[i],
            err_msg=f"lane {i} (active {n}) last-valid row differs")
    for l, (mv, ml) in enumerate(zip(full[1], legacy[1])):
        np.testing.assert_array_equal(
            np.asarray(mv), np.asarray(ml),
            err_msg=f"layer {l} memory feedback differs")
    # expert-count accounting is unchanged by the wider logits output
    np.testing.assert_array_equal(np.asarray(full[2]),
                                  np.asarray(legacy[2]))


def test_verify_prefill_manifest_keeps_contract_with_wider_logits():
    # same input contract as legacy prefill; output "0" widens to
    # [B, C, V] — the shape the engine sniffs to enable speculation
    cfg = tiny_cfg()
    serve_batch = 2
    smems = [jnp.zeros((serve_batch, cfg.mem_len, cfg.d_model),
                       jnp.float32) for _ in range(cfg.n_layers)]
    ptok = jnp.zeros((serve_batch, CHUNK), jnp.int32)
    active = jnp.full((serve_batch,), CHUNK, jnp.int32)
    ek = jnp.asarray(cfg.moe.k, jnp.int32)
    params = api.M.init_params(jax.random.PRNGKey(0), cfg)
    _, in_spec, out_spec = aot.lower_fn(
        api.make_prefill(cfg, cfg.mem_len, verify_logits=True),
        (params, smems, ptok, active, ek))
    in_names = [b["name"] for b in in_spec]
    assert in_names[-3:] == ["2", "3", "4"]
    out_names = [b["name"] for b in out_spec]
    assert out_names == (["0"]
                         + [f"1.{i}" for i in range(cfg.n_layers)]
                         + ["2"])
    assert out_spec[0]["shape"] == [serve_batch, CHUNK, cfg.vocab_size]
    assert out_spec[0]["dtype"] == "float32"
