"""Grouped (capacity-based) expert dispatch vs the exact CVMM oracle."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.configs import MoEConfig
from compile.kernels import ref
from compile.layers import moe


def setup(n=40, d=12, ne=4, g=6, k=2, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (n, d))
    w1 = 0.3 * jax.random.normal(ks[1], (ne, d, g))
    w2 = 0.3 * jax.random.normal(ks[2], (ne, g, d))
    idx = jax.random.randint(ks[3], (n, k), 0, ne)
    val = jax.nn.sigmoid(jax.random.normal(ks[4], (n, k)))
    return x, w1, w2, idx, val


def test_grouped_matches_exact_with_ample_capacity():
    x, w1, w2, idx, val = setup()
    y = moe.grouped_dispatch(x, idx, val, w1, w2, capacity_factor=4.0)
    want = ref.moe_dispatch_ref(x, idx, val, w1, w2)
    np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)


def test_grouped_full_capacity_always_exact():
    # capacity >= all rows per expert -> exact regardless of skew
    x, w1, w2, idx, val = setup(n=16, ne=3, k=2)
    idx = jnp.zeros_like(idx)  # fully collapsed routing
    y = moe.grouped_dispatch(x, idx, val, w1, w2,
                             capacity_factor=3.0)  # cap = 32/3*3 >= 32
    want = ref.moe_dispatch_ref(x, idx, val, w1, w2)
    np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)


def test_grouped_drops_overflow_tokens():
    """With capacity 1 and all tokens routed to expert 0, only the first
    row survives — the documented Switch-style overflow semantics."""
    x, w1, w2, idx, val = setup(n=8, ne=4, k=1)
    idx = jnp.zeros_like(idx)
    y = moe.grouped_dispatch(x, idx, val, w1, w2,
                             capacity_factor=4.0 / 8.0)  # cap = 4/8*8/4=... cap=int(0.5*8/4)=1
    want = ref.moe_dispatch_ref(x, idx, val, w1, w2)
    # row 0 exact, some later row dropped to zero
    np.testing.assert_allclose(y[0], want[0], rtol=1e-4, atol=1e-4)
    dropped = [i for i in range(8)
               if np.allclose(np.asarray(y[i]), 0, atol=1e-7)]
    assert len(dropped) == 7, dropped


def test_moe_ff_grouped_equals_dense_kernel_at_eval():
    cfg_d = MoEConfig(n_experts=4, group_size=6, k=2, kernel="dense",
                      regularization="none")
    cfg_g = MoEConfig(n_experts=4, group_size=6, k=2, kernel="grouped",
                      capacity_factor=4.0, regularization="none")
    x, w1, w2, _, _ = setup(d=12, ne=4, g=6)
    p = {"w1": w1, "w2": w2,
         "w3": 0.3 * jax.random.normal(jax.random.PRNGKey(9), (12, 4))}
    y_d, _ = moe.moe_ff(p, x, jax.random.PRNGKey(0), cfg_d, True)
    y_g, _ = moe.moe_ff(p, x, jax.random.PRNGKey(0), cfg_g, True)
    np.testing.assert_allclose(y_d, y_g, rtol=1e-4, atol=1e-4)
