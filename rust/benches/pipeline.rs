//! L3 pipeline benchmark: synthetic-corpus generation, batcher window
//! assembly, tokenizer throughput — establishes that the data path is
//! far from being the training bottleneck — plus the continuous-batching
//! decode loop over the device-resident engine and the chunked-prefill
//! A/B (EXPERIMENTS.md §Perf, §Prefill; prefill rows land in
//! BENCH_serve.json).

use std::sync::mpsc;
use std::time::{Duration, Instant};

use sigma_moe::bench_util::{bench, write_bench_json};
use sigma_moe::data::{self, CharTokenizer, WordTokenizer};
use sigma_moe::json::{self, Json};
use sigma_moe::runtime::{Client, ModelBundle};
use sigma_moe::serving::{
    Engine, EngineBackend, GenRequest, MockBackend, Sampler, StreamEvent,
};
use sigma_moe::tensor::HostTensor;

/// Decode-loop throughput: tokens/sec and host↔device bytes per pump
/// over the device-resident `step_fwd` engine.  Skipped when artifacts
/// are not built.
fn bench_decode_loop() {
    let dir = sigma_moe::artifacts_root().join("tiny-moe");
    if !dir.join("manifest.json").exists() {
        eprintln!("decode loop: tiny-moe artifacts not built; skipping");
        return;
    }
    let client = Client::cpu().expect("pjrt client");
    let bundle = ModelBundle::load_subset(&client, &dir, &["init", "step_fwd"])
        .expect("bundle");
    let init = bundle.program("init").unwrap();
    let out = init.run(&[HostTensor::scalar_u32(1)]).unwrap();
    let params: Vec<(String, HostTensor)> = init
        .spec
        .outputs
        .iter()
        .map(|b| b.name.clone())
        .zip(out)
        .collect();
    let mut engine = Engine::new(&bundle, &params, 7).expect("engine");
    let mut corpus = data::by_name(
        "wikitext", bundle.manifest.model.vocab_size, 7).unwrap();
    let n_req = engine.n_lanes() * 2;
    let mut rxs = Vec::new();
    for _ in 0..n_req {
        rxs.push(engine.submit(GenRequest {
            prompt: corpus.take_vec(8),
            max_new_tokens: 24,
            sampler: Sampler::greedy(),
            ..Default::default()
        }));
    }
    let xfer0 = engine.transfer_stats();
    let t0 = std::time::Instant::now();
    let results = engine.run_to_completion(rxs).expect("decode");
    let wall = t0.elapsed().as_secs_f64();
    let xfer = engine.transfer_stats().since(&xfer0);
    let total_new: usize = results.iter().map(|r| r.tokens.len()).sum();
    println!(
        "decode loop: {} reqs | {:.1} tok/s | {:.2} steps/s | {} | occupancy {:.2}",
        results.len(),
        total_new as f64 / wall,
        engine.steps_executed as f64 / wall,
        xfer.report_per_step(engine.steps_executed),
        engine.stats()["mean_batch_occupancy"],
    );
}

/// Chunked vs single-token prompt ingestion over the device-free mock:
/// identical 256-token-prompt request sets at C=1 vs C=16, reporting
/// dispatches/prompt, TTFT (pumps to first token x the simulated step
/// delay), and tok/s.  One BENCH_serve.json row per chunk width.
fn bench_prefill_mock() -> Vec<Json> {
    const PROMPT_LEN: usize = 256;
    const GEN: usize = 16;
    const LANES: usize = 4;
    const REQS: usize = 8;
    const STEP_DELAY: Duration = Duration::from_micros(200);
    let mut rows = Vec::new();
    let mut per_prompt = Vec::new();
    for &chunk in &[1usize, 16] {
        let mut b = MockBackend::new(LANES, 512)
            .with_prefill_chunk(chunk)
            .with_step_delay(STEP_DELAY);
        // one shared event channel: the first Token event dates TTFT
        let (tx, rx) = mpsc::channel();
        for i in 0..REQS {
            b.submit_streaming(
                GenRequest {
                    prompt: vec![(i % 100) as i32; PROMPT_LEN],
                    max_new_tokens: GEN,
                    sampler: Sampler::greedy(),
                    ..Default::default()
                },
                tx.clone(),
            );
        }
        drop(tx);
        let t0 = Instant::now();
        let mut ttft = None;
        let mut pumps_to_first = 0u64;
        while b.pump().expect("mock pump") > 0 {
            if ttft.is_none() {
                pumps_to_first = b.steps_executed;
                while let Ok(ev) = rx.try_recv() {
                    if matches!(ev, StreamEvent::Token(_)) {
                        ttft = Some(t0.elapsed());
                        break;
                    }
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        // a wave is one full batch of lanes running a prompt to
        // completion; dispatches/prompt = pumps per wave
        let waves = (REQS / LANES).max(1) as f64;
        let dpp = b.steps_executed as f64 / waves;
        per_prompt.push(dpp);
        let ttft_ms = ttft.map_or(0.0, |d| d.as_secs_f64() * 1e3);
        println!(
            "prefill mock C={chunk:>2}: {} dispatches total | \
             {dpp:.0} dispatches/256-tok prompt | ttft {ttft_ms:.1} ms \
             ({pumps_to_first} pumps) | {:.0} tok/s",
            b.steps_executed,
            (REQS * GEN) as f64 / wall,
        );
        rows.push(json::obj(vec![
            ("mode", json::s("mock-prefill-ab")),
            ("prefill_chunk", json::num(chunk as f64)),
            ("prompt_len", json::num(PROMPT_LEN as f64)),
            ("max_new", json::num(GEN as f64)),
            ("requests", json::num(REQS as f64)),
            ("lanes", json::num(LANES as f64)),
            ("dispatches_total", json::num(b.steps_executed as f64)),
            ("dispatches_per_prompt", json::num(dpp)),
            ("ttft_ms", json::num(ttft_ms)),
            ("pumps_to_first_token", json::num(pumps_to_first as f64)),
            (
                "tokens_per_sec",
                json::num((REQS * GEN) as f64 / wall),
            ),
            ("wall_s", json::num(wall)),
        ]));
    }
    println!(
        "prefill mock: C=16 uses {:.1}x fewer dispatches per prompt \
         than C=1",
        per_prompt[0] / per_prompt[1].max(1.0),
    );
    rows
}

/// Speculative decode A/B over the device-free mock at batch 1: the
/// same decode-heavy request with drafting off vs K=3, on two
/// workloads.  "repetitive" uses a tiny vocabulary, which makes the
/// mock's deterministic stream periodic (step 7 mod vocab) — the
/// regime n-gram prompt-lookup drafting exists for, where accepted
/// drafts collapse several decode pumps into one verify dispatch.
/// "random" uses a vocabulary wide enough that no n-gram ever repeats
/// within the budget, so the drafter stays cold and the engine must
/// fall back to the plain single-token path at identical dispatch
/// count — the "a cold drafter costs nothing" half of the claim.  One
/// BENCH_serve.json row per (workload, K), speculating rows carrying
/// the accepted-length histogram.
fn bench_speculate_mock(rows: &mut Vec<Json>) {
    const GEN: usize = 192;
    const CHUNK: usize = 8;
    const K: usize = 3;
    const STEP_DELAY: Duration = Duration::from_micros(200);
    for (workload, vocab) in [("repetitive", 10usize), ("random", 512)] {
        let mut tps = Vec::new();
        let mut pumps = Vec::new();
        for &k in &[0usize, K] {
            let mut b = MockBackend::new(1, vocab)
                .with_prefill_chunk(CHUNK)
                .with_step_delay(STEP_DELAY)
                .with_speculate(k);
            let (tx, rx) = mpsc::channel();
            b.submit_streaming(
                GenRequest {
                    prompt: vec![1, 2, 3],
                    max_new_tokens: GEN,
                    sampler: Sampler::greedy(),
                    ..Default::default()
                },
                tx,
            );
            let t0 = Instant::now();
            while b.pump().expect("mock pump") > 0 {}
            let wall = t0.elapsed().as_secs_f64().max(1e-9);
            let toks = rx
                .try_iter()
                .filter(|ev| matches!(ev, StreamEvent::Token(_)))
                .count();
            assert_eq!(toks, GEN, "speculation must not change the stream");
            tps.push(GEN as f64 / wall);
            pumps.push(b.steps_executed);
            let stats = b.stats();
            let g = |key: &str| stats.get(key).copied().unwrap_or(0.0);
            let hist: Vec<Json> = (0..=k)
                .map(|n| json::num(g(&format!("spec_hist_{n}"))))
                .collect();
            println!(
                "speculate mock [{workload}] K={k}: {} pumps for {GEN} \
                 tokens | {:.0} tok/s | {} rounds | accept rate {:.2} \
                 | {} rollbacks",
                b.steps_executed,
                GEN as f64 / wall,
                b.spec_rounds,
                g("spec_accept_rate"),
                b.spec_rollbacks,
            );
            rows.push(json::obj(vec![
                ("mode", json::s("mock-speculate-ab")),
                ("workload", json::s(workload)),
                ("speculate", json::num(k as f64)),
                ("vocab", json::num(vocab as f64)),
                ("max_new", json::num(GEN as f64)),
                ("lanes", json::num(1.0)),
                ("pumps", json::num(b.steps_executed as f64)),
                ("tokens_per_sec", json::num(GEN as f64 / wall)),
                ("spec_rounds", json::num(b.spec_rounds as f64)),
                ("spec_drafted", json::num(b.spec_drafted as f64)),
                ("spec_accepted", json::num(b.spec_accepted as f64)),
                ("spec_accept_rate", json::num(g("spec_accept_rate"))),
                ("spec_rollbacks", json::num(b.spec_rollbacks as f64)),
                ("spec_accept_hist", json::arr(hist)),
                ("wall_s", json::num(wall)),
            ]));
        }
        println!(
            "speculate mock [{workload}]: K={K} -> {:.2}x decode tok/s \
             vs K=0 ({} vs {} pumps)",
            tps[1] / tps[0].max(1e-9),
            pumps[1],
            pumps[0],
        );
    }
}

/// Prefix-cache A/B over the device-free mock at batch 1: two requests
/// sharing a long prompt head, served cold (no cache) vs warm (the
/// first request's chunk-boundary snapshots seed the second).  The
/// warm second request must finish prefill in ⌈tail/C⌉ dispatches
/// instead of ⌈len/C⌉ — the acceptance bound the cache exists for —
/// while emitting the bitwise-identical token stream.  One
/// BENCH_serve.json row per (leg, request).
fn bench_prefix_mock(rows: &mut Vec<Json>) {
    use sigma_moe::serving::PrefixCache;
    use std::sync::Arc;
    const CHUNK: usize = 8;
    const GEN: usize = 16;
    const HEAD: usize = 64;
    const STEP_DELAY: Duration = Duration::from_micros(200);
    let head: Vec<i32> = (0..HEAD as i32).collect();
    let prompt = |tail: i32| {
        let mut p = head.clone();
        p.extend([100 + tail, 101 + tail, 102 + tail]);
        p
    };
    let mut streams: Vec<Vec<Vec<i32>>> = Vec::new();
    for leg in ["cold", "warm"] {
        let cache = Arc::new(PrefixCache::new(1 << 20));
        let mut b = MockBackend::new(1, 512)
            .with_prefill_chunk(CHUNK)
            .with_step_delay(STEP_DELAY);
        if leg == "warm" {
            b = b.with_prefix_cache(cache.clone());
        }
        let mut leg_streams = Vec::new();
        for (i, tail) in [0i32, 7].into_iter().enumerate() {
            let (tx, rx) = mpsc::channel();
            let before = b.steps_executed;
            b.submit_streaming(
                GenRequest {
                    prompt: prompt(tail),
                    max_new_tokens: GEN,
                    sampler: Sampler::greedy(),
                    ..Default::default()
                },
                tx,
            );
            let t0 = Instant::now();
            while b.pump().expect("mock pump") > 0 {}
            let wall = t0.elapsed().as_secs_f64().max(1e-9);
            let pumps = b.steps_executed - before;
            let toks: Vec<i32> = rx
                .try_iter()
                .filter_map(|ev| match ev {
                    StreamEvent::Token(t) => Some(t),
                    _ => None,
                })
                .collect();
            assert_eq!(toks.len(), GEN, "{leg} request {i} stream length");
            leg_streams.push(toks);
            let (hits, misses) = cache.hit_miss();
            println!(
                "prefix mock [{leg}] request {i}: {pumps} pumps for \
                 {GEN} tokens | {:.0} tok/s | cache {hits} hit(s) / \
                 {misses} miss(es)",
                GEN as f64 / wall,
            );
            rows.push(json::obj(vec![
                ("mode", json::s("mock-prefix-ab")),
                ("leg", json::s(leg)),
                ("request", json::num(i as f64)),
                ("prompt_len", json::num(prompt(tail).len() as f64)),
                ("prefill_chunk", json::num(CHUNK as f64)),
                ("max_new", json::num(GEN as f64)),
                ("pumps", json::num(pumps as f64)),
                ("tokens_per_sec", json::num(GEN as f64 / wall)),
                ("prefix_cache_hits", json::num(hits as f64)),
                ("prefix_cache_misses", json::num(misses as f64)),
                ("wall_s", json::num(wall)),
            ]));
        }
        streams.push(leg_streams);
    }
    assert_eq!(
        streams[0], streams[1],
        "warm streams must be bitwise identical to cold"
    );
    // cold: ⌈67/8⌉ = 9 prefill dispatches inside the pump count;
    // warm request 1 restores the 64-token boundary and pays only the
    // 3-token tail: ⌈3/8⌉ = 1 — assert the ≤ ⌈tail/C⌉ + 1 bound
    let pumps_of = |row: &Json| {
        row.get("pumps").unwrap().as_f64().unwrap() as u64
    };
    let cold = pumps_of(&rows[rows.len() - 3]);
    let warm = pumps_of(&rows[rows.len() - 1]);
    assert!(
        warm + 8 <= cold,
        "warm request saved no prefill work: {warm} vs {cold} pumps"
    );
    println!(
        "prefix mock: warm hit {warm} pumps vs {cold} cold \
         (8 prefill dispatches saved)"
    );
}

/// Chunked vs single-token prompt ingestion on the real device-resident
/// engine: the same bundle/params with and without the `prefill`
/// program (the subset load without it exercises the fallback path).
/// Skipped when artifacts are not built.
fn bench_prefill_device(rows: &mut Vec<Json>) {
    let dir = sigma_moe::artifacts_root().join("tiny-moe");
    if !dir.join("manifest.json").exists() {
        eprintln!("prefill device A/B: tiny-moe artifacts not built; skipping");
        return;
    }
    // both sides or neither: a one-row "A/B" would mislead, and the
    // fallback side's wall time is wasted without its comparison
    match sigma_moe::runtime::Manifest::load(&dir) {
        Ok(m) if m.functions.contains_key("prefill") => {}
        _ => {
            eprintln!(
                "prefill device A/B: artifacts predate the prefill \
                 program; skipping"
            );
            return;
        }
    }
    const PROMPT_LEN: usize = 256;
    const GEN: usize = 16;
    for with_prefill in [false, true] {
        let client = Client::cpu().expect("pjrt client");
        let mut names = vec!["init", "step_fwd"];
        if with_prefill {
            names.push("prefill");
        }
        let bundle = ModelBundle::load_subset(&client, &dir, &names)
            .expect("bundle");
        let init = bundle.program("init").unwrap();
        let out = init.run(&[HostTensor::scalar_u32(1)]).unwrap();
        let params: Vec<(String, HostTensor)> = init
            .spec
            .outputs
            .iter()
            .map(|b| b.name.clone())
            .zip(out)
            .collect();
        let mut engine = Engine::new(&bundle, &params, 7).expect("engine");
        let chunk = engine.prefill_chunk();
        let mut corpus = data::by_name(
            "wikitext",
            bundle.manifest.model.vocab_size,
            7,
        )
        .unwrap();
        let mut rxs = Vec::new();
        for _ in 0..engine.n_lanes() {
            rxs.push(engine.submit(GenRequest {
                prompt: corpus.take_vec(PROMPT_LEN),
                max_new_tokens: GEN,
                sampler: Sampler::greedy(),
                ..Default::default()
            }));
        }
        let xfer0 = engine.transfer_stats();
        let t0 = Instant::now();
        let results = engine.run_to_completion(rxs).expect("prefill run");
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        let xfer = engine.transfer_stats().since(&xfer0);
        let total_new: usize =
            results.iter().map(|r| r.tokens.len()).sum();
        println!(
            "prefill device C={chunk:>2}: {} dispatches for {} \
             256-tok prompts | {:.1} tok/s | {} | occupancy {:.2}",
            engine.steps_executed,
            results.len(),
            total_new as f64 / wall,
            xfer.report_per_step(engine.steps_executed),
            engine.stats()["mean_batch_occupancy"],
        );
        rows.push(json::obj(vec![
            ("mode", json::s("device-prefill-ab")),
            ("prefill_chunk", json::num(chunk as f64)),
            ("prompt_len", json::num(PROMPT_LEN as f64)),
            ("max_new", json::num(GEN as f64)),
            ("requests", json::num(results.len() as f64)),
            (
                "dispatches_total",
                json::num(engine.steps_executed as f64),
            ),
            (
                "dispatches_per_prompt",
                json::num(engine.steps_executed as f64),
            ),
            ("tokens_per_sec", json::num(total_new as f64 / wall)),
            ("h2d_bytes", json::num(xfer.h2d_bytes as f64)),
            ("d2h_bytes", json::num(xfer.d2h_bytes as f64)),
            ("wall_s", json::num(wall)),
        ]));
    }
}

fn main() {
    println!("== data pipeline throughput ==");

    // corpus generation
    for name in ["wikitext", "enwik8"] {
        let mut c = data::by_name(name, 2048, 1).unwrap();
        let n = 65_536;
        let s = bench(&format!("corpus::{name} {n} tokens"), 1, 20, || {
            let _ = c.take_vec(n);
        });
        println!(
            "{}   {:>8.2} Mtok/s",
            s.report(),
            n as f64 / s.mean.as_secs_f64() / 1e6
        );
    }

    // batcher window assembly (the per-step data cost during training)
    let mut b = data::batcher_for("wikitext", 2048, 16, 64, 2).unwrap();
    let s = bench("batcher::next_window 16x64", 2, 200, || {
        let _ = b.next_window().unwrap();
    });
    println!(
        "{}   {:>8.2} Mtok/s",
        s.report(),
        (16.0 * 64.0) / s.mean.as_secs_f64() / 1e6
    );

    // tokenizers
    let text = {
        let mut c = data::by_name("enwik8", 256, 3).unwrap();
        CharTokenizer.decode(&c.take_vec(100_000))
    };
    let ct = CharTokenizer;
    let s = bench("tokenizer::char encode 100k chars", 1, 50, || {
        let _ = ct.encode(&text);
    });
    println!(
        "{}   {:>8.2} MB/s",
        s.report(),
        text.len() as f64 / s.mean.as_secs_f64() / 1e6
    );

    let wt = WordTokenizer::build(&text, 4096).unwrap();
    let s = bench("tokenizer::word encode 100k chars", 1, 50, || {
        let _ = wt.encode(&text);
    });
    println!(
        "{}   {:>8.2} MB/s",
        s.report(),
        text.len() as f64 / s.mean.as_secs_f64() / 1e6
    );

    println!("== continuous-batching decode loop ==");
    bench_decode_loop();

    println!("== chunked prefill A/B ==");
    let mut rows = bench_prefill_mock();
    println!("== speculative decode A/B ==");
    bench_speculate_mock(&mut rows);
    println!("== prefix cache A/B ==");
    bench_prefix_mock(&mut rows);
    bench_prefill_device(&mut rows);
    if let Err(e) =
        write_bench_json("BENCH_serve.json", "sigma-moe/serve/v1", rows)
    {
        eprintln!("BENCH_serve.json not written: {e}");
    } else {
        println!("prefill rows written to BENCH_serve.json");
    }
}
