//! L3 pipeline benchmark: synthetic-corpus generation, batcher window
//! assembly, tokenizer throughput — establishes that the data path is
//! far from being the training bottleneck — plus the continuous-batching
//! decode loop over the device-resident engine (EXPERIMENTS.md §Perf).

use sigma_moe::bench_util::bench;
use sigma_moe::data::{self, CharTokenizer, WordTokenizer};
use sigma_moe::runtime::{Client, ModelBundle};
use sigma_moe::serving::{Engine, GenRequest, Sampler};
use sigma_moe::tensor::HostTensor;

/// Decode-loop throughput: tokens/sec and host↔device bytes per pump
/// over the device-resident `step_fwd` engine.  Skipped when artifacts
/// are not built.
fn bench_decode_loop() {
    let dir = sigma_moe::artifacts_root().join("tiny-moe");
    if !dir.join("manifest.json").exists() {
        eprintln!("decode loop: tiny-moe artifacts not built; skipping");
        return;
    }
    let client = Client::cpu().expect("pjrt client");
    let bundle = ModelBundle::load_subset(&client, &dir, &["init", "step_fwd"])
        .expect("bundle");
    let init = bundle.program("init").unwrap();
    let out = init.run(&[HostTensor::scalar_u32(1)]).unwrap();
    let params: Vec<(String, HostTensor)> = init
        .spec
        .outputs
        .iter()
        .map(|b| b.name.clone())
        .zip(out)
        .collect();
    let mut engine = Engine::new(&bundle, &params, 7).expect("engine");
    let mut corpus = data::by_name(
        "wikitext", bundle.manifest.model.vocab_size, 7).unwrap();
    let n_req = engine.n_lanes() * 2;
    let mut rxs = Vec::new();
    for _ in 0..n_req {
        rxs.push(engine.submit(GenRequest {
            prompt: corpus.take_vec(8),
            max_new_tokens: 24,
            sampler: Sampler::greedy(),
        }));
    }
    let xfer0 = engine.transfer_stats();
    let t0 = std::time::Instant::now();
    let results = engine.run_to_completion(rxs).expect("decode");
    let wall = t0.elapsed().as_secs_f64();
    let xfer = engine.transfer_stats().since(&xfer0);
    let total_new: usize = results.iter().map(|r| r.tokens.len()).sum();
    println!(
        "decode loop: {} reqs | {:.1} tok/s | {:.2} steps/s | {} | occupancy {:.2}",
        results.len(),
        total_new as f64 / wall,
        engine.steps_executed as f64 / wall,
        xfer.report_per_step(engine.steps_executed),
        engine.stats()["mean_batch_occupancy"],
    );
}

fn main() {
    println!("== data pipeline throughput ==");

    // corpus generation
    for name in ["wikitext", "enwik8"] {
        let mut c = data::by_name(name, 2048, 1).unwrap();
        let n = 65_536;
        let s = bench(&format!("corpus::{name} {n} tokens"), 1, 20, || {
            let _ = c.take_vec(n);
        });
        println!(
            "{}   {:>8.2} Mtok/s",
            s.report(),
            n as f64 / s.mean.as_secs_f64() / 1e6
        );
    }

    // batcher window assembly (the per-step data cost during training)
    let mut b = data::batcher_for("wikitext", 2048, 16, 64, 2).unwrap();
    let s = bench("batcher::next_window 16x64", 2, 200, || {
        let _ = b.next_window().unwrap();
    });
    println!(
        "{}   {:>8.2} Mtok/s",
        s.report(),
        (16.0 * 64.0) / s.mean.as_secs_f64() / 1e6
    );

    // tokenizers
    let text = {
        let mut c = data::by_name("enwik8", 256, 3).unwrap();
        CharTokenizer.decode(&c.take_vec(100_000))
    };
    let ct = CharTokenizer;
    let s = bench("tokenizer::char encode 100k chars", 1, 50, || {
        let _ = ct.encode(&text);
    });
    println!(
        "{}   {:>8.2} MB/s",
        s.report(),
        text.len() as f64 / s.mean.as_secs_f64() / 1e6
    );

    let wt = WordTokenizer::build(&text, 4096).unwrap();
    let s = bench("tokenizer::word encode 100k chars", 1, 50, || {
        let _ = wt.encode(&text);
    });
    println!(
        "{}   {:>8.2} MB/s",
        s.report(),
        text.len() as f64 / s.mean.as_secs_f64() / 1e6
    );

    println!("== continuous-batching decode loop ==");
    bench_decode_loop();
}
