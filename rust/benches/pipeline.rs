//! L3 data-pipeline benchmark: synthetic-corpus generation, batcher
//! window assembly, tokenizer throughput — establishes that the data
//! path is far from being the training bottleneck (EXPERIMENTS.md §Perf).

use sigma_moe::bench_util::bench;
use sigma_moe::data::{self, CharTokenizer, WordTokenizer};

fn main() {
    println!("== data pipeline throughput ==");

    // corpus generation
    for name in ["wikitext", "enwik8"] {
        let mut c = data::by_name(name, 2048, 1).unwrap();
        let n = 65_536;
        let s = bench(&format!("corpus::{name} {n} tokens"), 1, 20, || {
            let _ = c.take_vec(n);
        });
        println!(
            "{}   {:>8.2} Mtok/s",
            s.report(),
            n as f64 / s.mean.as_secs_f64() / 1e6
        );
    }

    // batcher window assembly (the per-step data cost during training)
    let mut b = data::batcher_for("wikitext", 2048, 16, 64, 2).unwrap();
    let s = bench("batcher::next_window 16x64", 2, 200, || {
        let _ = b.next_window().unwrap();
    });
    println!(
        "{}   {:>8.2} Mtok/s",
        s.report(),
        (16.0 * 64.0) / s.mean.as_secs_f64() / 1e6
    );

    // tokenizers
    let text = {
        let mut c = data::by_name("enwik8", 256, 3).unwrap();
        CharTokenizer.decode(&c.take_vec(100_000))
    };
    let ct = CharTokenizer;
    let s = bench("tokenizer::char encode 100k chars", 1, 50, || {
        let _ = ct.encode(&text);
    });
    println!(
        "{}   {:>8.2} MB/s",
        s.report(),
        text.len() as f64 / s.mean.as_secs_f64() / 1e6
    );

    let wt = WordTokenizer::build(&text, 4096).unwrap();
    let s = bench("tokenizer::word encode 100k chars", 1, 50, || {
        let _ = wt.encode(&text);
    });
    println!(
        "{}   {:>8.2} MB/s",
        s.report(),
        text.len() as f64 / s.mean.as_secs_f64() / 1e6
    );
}
