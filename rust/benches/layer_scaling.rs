//! Fig. 2/8-11 reproduction: execution time (and analytic activation
//! memory) of a single MLP vs MoE feedforward layer's forward+backward
//! pass, swept over d_model, N_E, and G.
//!
//! Prerequisite: `make layerbench` (AOT-lowers the single-layer cases).
//! Absolute times are CPU-PJRT; the paper's claim that we check is the
//! *shape*: MoE time/memory ≈ flat in N_E, linear in G and d_model, and
//! far below the dense layer at matched d_ff.

use sigma_moe::bench_util::bench_budget;
use sigma_moe::json::Json;
use sigma_moe::runtime::{Client, FunctionSpec, Program};
use sigma_moe::tensor::{DType, HostTensor};
use std::time::Duration;

fn main() {
    let root = sigma_moe::artifacts_root().join("layerbench");
    let manifest_path = root.join("layerbench.json");
    let Ok(text) = std::fs::read_to_string(&manifest_path) else {
        eprintln!(
            "layer_scaling: {} missing — run `make layerbench`; skipping",
            manifest_path.display()
        );
        return;
    };
    let manifest = Json::parse(&text).expect("layerbench.json");
    let tokens = manifest.get("tokens").unwrap().as_usize().unwrap();
    let client = Client::cpu().expect("pjrt client");

    println!("== Fig. 2/8-11: single FF layer fwd+bwd, |B| = {tokens} ==");
    println!("(CPU PJRT; compare *scaling shape* with the paper, not ms)");
    for case in manifest.get("cases").unwrap().as_arr().unwrap() {
        let name = case.get("name").unwrap().as_str().unwrap();
        let file = case.get("file").unwrap().as_str().unwrap();
        let kind = case.get("kind").unwrap().as_str().unwrap();

        let parse_bufs = |key: &str| -> Vec<sigma_moe::runtime::BufferSpec> {
            case.get(key)
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|b| sigma_moe::runtime::BufferSpec {
                    name: b.get("name").unwrap().as_str().unwrap().to_string(),
                    shape: b
                        .get("shape")
                        .unwrap()
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|d| d.as_usize().unwrap())
                        .collect(),
                    dtype: DType::parse(
                        b.get("dtype").unwrap().as_str().unwrap(),
                    )
                    .unwrap(),
                })
                .collect()
        };
        let spec = FunctionSpec {
            file: file.to_string(),
            inputs: parse_bufs("inputs"),
            outputs: parse_bufs("outputs"),
        };
        let prog = Program::load(&client, name, &root.join(file), spec)
            .expect("compile layer case");

        // deterministic pseudo-random inputs
        let inputs: Vec<HostTensor> = prog
            .spec
            .inputs
            .iter()
            .map(|b| {
                let n: usize = b.shape.iter().product();
                let vals: Vec<f32> = (0..n)
                    .map(|i| {
                        ((i.wrapping_mul(2654435761)) % 1000) as f32 / 1000.0
                            - 0.5
                    })
                    .collect();
                HostTensor::from_f32(&b.shape, &vals).unwrap()
            })
            .collect();

        let s = bench_budget(name, 1, 50, Duration::from_secs(6), || {
            prog.run(&inputs).expect("run layer case");
        });
        // analytic activation memory per token (paper's dashed lines)
        let act_mem = match kind {
            "dense" => case.get("d_ff").unwrap().as_f64().unwrap(),
            _ => {
                case.get("g").unwrap().as_f64().unwrap()
                    * case.get("k").unwrap().as_f64().unwrap()
            }
        };
        println!("{}   act-mem/token {:>6.0} floats", s.report(), act_mem);
    }
}
