//! Serving-stack overhead benchmark — artifact-free by design.
//!
//! Measures the non-device layers the HTTP frontend adds in front of
//! `step_fwd`: scheduler enqueue/take throughput per policy, HTTP
//! request parsing, chunk framing, and an end-to-end open-loop run of
//! the full client/server/scheduler stack over the mock engine.  The
//! end-to-end row lands in BENCH_serve_frontend.json (schema
//! sigma-moe/serve/v1, mode "mock-bench") — a *separate* file from
//! BENCH_serve.json so this bench can never clobber the real-engine
//! rows `sigma-moe loadgen` writes there against `serve --http`.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use sigma_moe::bench_util::{bench, write_bench_json};
use sigma_moe::serving::loadgen::{self, LoadgenCfg};
use sigma_moe::serving::server::{parse_completion, read_request, ServerConfig};
use sigma_moe::serving::{GenRequest, Policy, Sampler, Scheduler};

fn bench_scheduler() {
    for policy in [Policy::Fifo, Policy::ShortestPrompt, Policy::Deadline] {
        let sched = Scheduler::new(1 << 14, policy);
        let req = GenRequest {
            prompt: vec![1; 16],
            max_new_tokens: 32,
            sampler: Sampler::greedy(),
            ..Default::default()
        };
        let n = 1024;
        let s = bench(
            &format!("scheduler::enqueue+take x{n} ({})", policy.as_str()),
            2,
            20,
            || {
                let (tx, _rx) = mpsc::channel();
                for _ in 0..n {
                    sched
                        .enqueue(
                            req.clone(),
                            Some(Duration::from_secs(60)),
                            tx.clone(),
                        )
                        .unwrap();
                }
                let now = Instant::now();
                while sched.take_next(now).is_some() {}
            },
        );
        println!(
            "{}   {:>8.2} Kreq/s",
            s.report(),
            n as f64 / s.mean.as_secs_f64() / 1e3
        );
    }
}

fn bench_http_parse() {
    let body = r#"{"prompt": [1,2,3,4,5,6,7,8], "max_tokens": 32,
                   "temperature": 0.8, "top_k": 50, "stream": true}"#;
    let raw = format!(
        "POST /v1/completions HTTP/1.1\r\nHost: bench\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    let cfg = ServerConfig::default();
    let n = 1024;
    let s = bench(&format!("http::read+parse x{n}"), 2, 30, || {
        for _ in 0..n {
            let req = read_request(&mut std::io::Cursor::new(raw.as_bytes()))
                .unwrap()
                .unwrap();
            let parsed = parse_completion(&req.body, &cfg).unwrap();
            assert_eq!(parsed.gen.prompt.len(), 8);
        }
    });
    println!(
        "{}   {:>8.2} Kreq/s",
        s.report(),
        n as f64 / s.mean.as_secs_f64() / 1e3
    );
}

fn bench_end_to_end() -> sigma_moe::json::Json {
    let cfg = LoadgenCfg {
        requests: 128,
        rps: 400.0,
        prompt_len: (4, 12),
        max_new: (4, 16),
        vocab: 256,
        stream_fraction: 0.5,
        seed: 7,
        timeout: Duration::from_secs(60),
        ..Default::default()
    };
    let t0 = Instant::now();
    let mut row = loadgen::dry_run(&cfg, 8, 1).expect("dry run");
    if let sigma_moe::json::Json::Obj(m) = &mut row {
        m.insert(
            "mode".into(),
            sigma_moe::json::s("mock-bench"),
        );
    }
    println!(
        "end-to-end mock serve: 128 reqs in {:.2}s -> {}",
        t0.elapsed().as_secs_f64(),
        row.get("tokens_per_sec")
            .map(|v| format!("{v} tok/s"))
            .unwrap_or_default(),
    );
    row
}

fn main() {
    println!("== serving frontend overhead (no device) ==");
    bench_scheduler();
    bench_http_parse();
    let row = bench_end_to_end();
    let out =
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve_frontend.json");
    write_bench_json(out, "sigma-moe/serve/v1", vec![row])
        .expect("write BENCH_serve_frontend.json");
    println!("wrote {out}");
}
