//! End-to-end train-step benchmark: wall time of the full optimization
//! step for each artifact preset, split into on-device execute vs host
//! (literal upload + readback), with derived tokens/sec — the L3
//! hot-path profile recorded in EXPERIMENTS.md §Perf.

use sigma_moe::bench_util::bench_budget;
use sigma_moe::coordinator::Trainer;
use sigma_moe::data;
use sigma_moe::runtime::{Client, ModelBundle};
use std::time::Duration;

fn main() {
    let client = Client::cpu().expect("pjrt client");
    let presets = ["tiny-dense", "tiny-moe", "tiny-topk", "tiny-pkm"];
    println!("== train_step wall time per preset ==");
    for preset in presets {
        let dir = sigma_moe::artifacts_root().join(preset);
        let bundle = match ModelBundle::load(&client, &dir) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("{preset}: skipped ({e})");
                continue;
            }
        };
        let m = &bundle.manifest;
        let mut trainer = Trainer::new(&bundle, 1).expect("trainer");
        let mut batcher = data::batcher_for(
            "wikitext",
            m.model.vocab_size,
            m.batch_size,
            m.model.context,
            1,
        )
        .expect("batcher");
        let tokens = m.batch_size * m.model.context;

        let s = bench_budget(preset, 1, 30, Duration::from_secs(8), || {
            let w = batcher.next_window().unwrap();
            trainer.step_on(w).unwrap();
        });
        let exec = bundle
            .program("train_step")
            .unwrap()
            .mean_exec_time()
            .unwrap_or(Duration::ZERO);
        let host = s.mean.saturating_sub(exec);
        println!(
            "{}   {:>8.0} tok/s   exec {:.3?} / host {:.3?}",
            s.report(),
            tokens as f64 / s.mean.as_secs_f64(),
            exec,
            host
        );
    }
}
