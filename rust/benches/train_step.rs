//! End-to-end train-step benchmark: wall time of the full optimization
//! step for each artifact preset, A/B'd between the seed host-round-trip
//! path (`Program::run`: upload params+opt+mems, download everything)
//! and the device-resident path (`Trainer::step_on` over
//! `Program::run_buffers`), split into on-device execute vs host
//! transfer, with derived tokens/sec and bytes-moved/step — the L3
//! hot-path profile recorded in EXPERIMENTS.md §Perf and emitted as
//! machine-readable BENCH_train.json for cross-PR tracking.

use sigma_moe::bench_util::{bench_budget, write_bench_json, Summary};
use sigma_moe::coordinator::Trainer;
use sigma_moe::data;
use sigma_moe::json::{self, Json};
use sigma_moe::runtime::{Client, ModelBundle};
use sigma_moe::tensor::HostTensor;
use std::time::Duration;

fn result_json(
    preset: &str,
    mode: &str,
    s: &Summary,
    tokens_per_step: usize,
    exec: Duration,
    h2d_per_step: f64,
    d2h_per_step: f64,
) -> Json {
    let step_s = s.mean.as_secs_f64().max(1e-12);
    json::obj(vec![
        ("preset", json::s(preset)),
        ("mode", json::s(mode)),
        ("timing", s.to_json()),
        ("steps_per_sec", json::num(1.0 / step_s)),
        ("tokens_per_sec", json::num(tokens_per_step as f64 / step_s)),
        ("exec_s_per_step", json::num(exec.as_secs_f64())),
        ("h2d_bytes_per_step", json::num(h2d_per_step)),
        ("d2h_bytes_per_step", json::num(d2h_per_step)),
    ])
}

fn main() {
    let client = Client::cpu().expect("pjrt client");
    let presets = ["tiny-dense", "tiny-moe", "tiny-topk", "tiny-pkm"];
    let mut results: Vec<Json> = Vec::new();
    println!("== train_step wall time per preset (seed path vs device-resident) ==");
    for preset in presets {
        let dir = sigma_moe::artifacts_root().join(preset);
        let bundle = match ModelBundle::load(&client, &dir) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("{preset}: skipped ({e})");
                continue;
            }
        };
        let m = &bundle.manifest;
        let tokens = m.batch_size * m.model.context;
        let ts = bundle.program("train_step").unwrap();

        // pre-generated window pool so neither mode times the batcher;
        // both modes pay one token-tensor clone per step
        let mut batcher = data::batcher_for(
            "wikitext",
            m.model.vocab_size,
            m.batch_size,
            m.model.context,
            1,
        )
        .expect("batcher");
        let windows: Vec<HostTensor> = (0..32)
            .map(|_| batcher.next_window().unwrap())
            .collect();

        // -- A: seed path — full host round trip through Program::run.
        // Feedback wiring doesn't change the transfer profile, so a
        // fixed input state (zero params, real tokens) measures the same
        // per-step cost the seed Trainer paid.
        let mut host_inputs: Vec<HostTensor> = ts
            .spec
            .inputs
            .iter()
            .map(|b| HostTensor::zeros(b.dtype, &b.shape))
            .collect();
        let tok_idx = ts
            .spec
            .inputs
            .iter()
            .position(|b| b.name == "4")
            .expect("tokens input '4'");
        let exec0 = ts.exec_time.get();
        let n0 = ts.exec_count.get();
        let mut wi = 0usize;
        let s_host = bench_budget(
            &format!("{preset} host-roundtrip"),
            1,
            30,
            Duration::from_secs(8),
            || {
                host_inputs[tok_idx] = windows[wi % windows.len()].clone();
                wi += 1;
                ts.run(&host_inputs).unwrap();
            },
        );
        let exec_host = (ts.exec_time.get() - exec0) / (ts.exec_count.get() - n0).max(1) as u32;
        let h2d_host = ts.spec.total_input_bytes() as f64;
        let d2h_host = ts.spec.total_output_bytes() as f64;
        println!(
            "{}   {:>8.0} tok/s   exec {:.3?} / host {:.3?}   moves {:.2} MB/step",
            s_host.report(),
            tokens as f64 / s_host.mean.as_secs_f64(),
            exec_host,
            s_host.mean.saturating_sub(exec_host),
            (h2d_host + d2h_host) / 1e6,
        );
        results.push(result_json(
            preset, "host_roundtrip", &s_host, tokens, exec_host, h2d_host,
            d2h_host,
        ));

        // -- B: device-resident path through Trainer::step_on, fed from
        // the same window pool.
        let mut trainer = Trainer::new(&bundle, 1).expect("trainer");
        let exec0 = ts.exec_time.get();
        let n0 = ts.exec_count.get();
        let xfer0 = trainer.transfer_stats();
        let mut wi = 0usize;
        let s_dev = bench_budget(
            &format!("{preset} device-resident"),
            1,
            30,
            Duration::from_secs(8),
            || {
                let w = windows[wi % windows.len()].clone();
                wi += 1;
                trainer.step_on(w).unwrap();
            },
        );
        let steps = (ts.exec_count.get() - n0).max(1);
        let exec_dev = (ts.exec_time.get() - exec0) / steps as u32;
        let xfer = trainer.transfer_stats().since(&xfer0);
        let h2d_dev = xfer.h2d_bytes as f64 / steps as f64;
        let d2h_dev = xfer.d2h_bytes as f64 / steps as f64;
        println!(
            "{}   {:>8.0} tok/s   exec {:.3?} / host {:.3?}   moves {:.2} MB/step   speedup x{:.2}",
            s_dev.report(),
            tokens as f64 / s_dev.mean.as_secs_f64(),
            exec_dev,
            s_dev.mean.saturating_sub(exec_dev),
            (h2d_dev + d2h_dev) / 1e6,
            s_host.mean.as_secs_f64() / s_dev.mean.as_secs_f64().max(1e-12),
        );
        results.push(result_json(
            preset, "device_resident", &s_dev, tokens, exec_dev, h2d_dev,
            d2h_dev,
        ));
    }
    if results.is_empty() {
        eprintln!("no presets benchmarked (artifacts missing) — BENCH_train.json not written");
        return;
    }
    // cargo bench runs with cwd = rust/; the tracked file lives at the
    // repo root
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_train.json");
    write_bench_json(out, "sigma-moe/train-step/v1", results)
        .expect("write BENCH_train.json");
    println!("wrote {out}");
}
