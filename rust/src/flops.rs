//! Analytic FLOPs / parameter / activation-memory model — the Rust twin
//! of `python/compile/flops.py` (cross-checked against the manifest's
//! values in tests).  Regenerates the paper's "% FLOPs" column (Tab. 3)
//! and the fraction table (Tab. 7).

/// Feedforward variant cost summary (per token, forward pass).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FfCost {
    pub flops: f64,
    pub act_memory: f64,
    pub params: f64,
    pub selector_flops: f64,
}

pub fn dense_ff(d_model: usize, d_ff: usize) -> FfCost {
    FfCost {
        flops: 2.0 * 2.0 * d_model as f64 * d_ff as f64,
        act_memory: d_ff as f64,
        params: 2.0 * d_model as f64 * d_ff as f64 + d_ff as f64
            + d_model as f64,
        selector_flops: 0.0,
    }
}

pub fn topk_ff(d_model: usize, d_ff: usize, k: usize) -> FfCost {
    FfCost {
        flops: 2.0 * d_model as f64 * d_ff as f64
            + 2.0 * d_model as f64 * k as f64,
        act_memory: d_ff as f64,
        params: 2.0 * d_model as f64 * d_ff as f64 + d_ff as f64
            + d_model as f64,
        selector_flops: 0.0,
    }
}

pub fn moe_ff(d_model: usize, n_experts: usize, g: usize, k: usize) -> FfCost {
    let d_ff = (n_experts * g) as f64;
    FfCost {
        flops: 2.0 * 2.0 * d_model as f64 * g as f64 * k as f64,
        act_memory: (g * k) as f64,
        params: 2.0 * d_model as f64 * d_ff
            + d_model as f64 * n_experts as f64,
        selector_flops: 2.0 * d_model as f64 * n_experts as f64,
    }
}

pub fn pkm_ff(d_model: usize, n_subkeys: usize, knn: usize,
              heads: usize) -> FfCost {
    let half = d_model as f64 / 2.0;
    let s = n_subkeys as f64;
    let score = 2.0 * half * s * 2.0;
    let combine = 2.0 * (knn * knn) as f64;
    let readout = 2.0 * knn as f64 * d_model as f64;
    FfCost {
        flops: heads as f64 * (score + combine + readout),
        act_memory: heads as f64 * (2.0 * s + knn as f64),
        params: heads as f64 * 2.0 * s * half + s * s * d_model as f64,
        selector_flops: 0.0,
    }
}

/// "% FLOPs" of a MoE FF block relative to a dense block (paper Tab. 3
/// reports K/N_E when d_ff matches: e.g. 25% for K=4, N_E=16).
pub fn moe_fraction(
    d_model: usize,
    n_experts: usize,
    g: usize,
    k: usize,
    dense_d_ff: usize,
) -> f64 {
    moe_ff(d_model, n_experts, g, k).flops / dense_ff(d_model, dense_d_ff).flops
}

/// One row of the paper's Tab. 7: FLOPs + memory fractions vs dense.
#[derive(Debug, Clone)]
pub struct FractionRow {
    pub label: String,
    pub g: usize,
    pub k: usize,
    pub flops_fraction: f64,
    pub memory_fraction: f64,
}

/// Regenerate Tab. 7 for a model family (dense d_ff vs expert configs).
pub fn table7_rows(
    d_model: usize,
    dense_d_ff: usize,
    configs: &[(&str, usize, usize)], // (label, G, K)
) -> Vec<FractionRow> {
    let dense = dense_ff(d_model, dense_d_ff);
    configs
        .iter()
        .map(|(label, g, k)| {
            let ne = dense_d_ff.div_ceil(*g).max(1);
            let m = moe_ff(d_model, ne, *g, *k);
            FractionRow {
                label: label.to_string(),
                g: *g,
                k: *k,
                flops_fraction: m.flops / dense.flops,
                memory_fraction: m.act_memory / dense.act_memory,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_fraction_small() {
        // WT-S: d_model 412, dense d_ff 2048-ish, MoE G=128 K=4 NE=16
        let f = moe_fraction(412, 16, 128, 4, 2048);
        assert!((f - 0.25).abs() < 1e-9, "{f}");
    }

    #[test]
    fn paper_fraction_big() {
        // WT-B: NE=32, K=4 -> 12.5%
        let f = moe_fraction(1024, 32, 128, 4, 4096);
        assert!((f - 0.125).abs() < 1e-9, "{f}");
    }

    #[test]
    fn paper_fraction_star() {
        // WT-S*: NE=128, K=4 -> 3.125% (Tab. 7 prints 3.1%)
        let f = moe_fraction(412, 128, 128, 4, 128 * 128);
        assert!((f - 0.03125).abs() < 1e-9, "{f}");
    }

    #[test]
    fn table7_k_sweep_matches_paper() {
        // Tab. 7 K-sweep rows at G=128, dense d_ff = 2048: 6.2%, 12.5%,
        // 25%, 50% for K = 1, 2, 4, 8.
        let rows = table7_rows(
            412,
            2048,
            &[("K=1", 128, 1), ("K=2", 128, 2), ("K=4", 128, 4),
              ("K=8", 128, 8)],
        );
        let want = [0.0625, 0.125, 0.25, 0.5];
        for (r, w) in rows.iter().zip(want) {
            assert!((r.flops_fraction - w).abs() < 1e-9,
                    "{}: {} != {w}", r.label, r.flops_fraction);
        }
    }

    #[test]
    fn moe_cost_independent_of_ne() {
        let a = moe_ff(512, 16, 128, 4);
        let b = moe_ff(512, 64, 128, 4);
        assert_eq!(a.flops, b.flops);
        assert!(b.selector_flops > a.selector_flops);
    }

    #[test]
    fn gk_constant_product_has_constant_cost() {
        // Tab. 10 second block: (G, K) with constant G*K cost the same.
        let a = moe_ff(412, 32, 64, 8);
        let b = moe_ff(412, 8, 256, 2);
        assert_eq!(a.flops, b.flops);
        assert_eq!(a.act_memory, b.act_memory);
    }
}
