//! Deterministic PRNG substrate (no `rand` crate in the offline vendor
//! set): SplitMix64 for seeding, Xoshiro256++ as the workhorse, plus the
//! samplers the synthetic-corpus generators need (uniform, categorical,
//! Zipf, Gaussian).

/// SplitMix64 — used to expand a single u64 seed into stream seeds.
#[derive(Debug, Clone)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        Rng { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Derive an independent stream (for per-worker determinism).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_f64() * n as f64) as usize % n
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Bernoulli with probability p.
    pub fn coin(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

/// Zipf-distributed sampler over {0, .., n-1} with exponent `s`
/// (precomputed CDF; the heavy-tailed unigram backbone of the synthetic
/// "wikitext-like" corpus — see DESIGN.md §Substitutions).
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for v in &mut cdf {
            *v /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let x = rng.next_f64();
        match self
            .cdf
            .binary_search_by(|v| v.partial_cmp(&x).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn zipf_is_heavy_tailed_and_ordered() {
        let z = Zipf::new(100, 1.1);
        let mut r = Rng::new(6);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        // rank 0 much more frequent than rank 50
        assert!(counts[0] > 10 * counts[50].max(1));
        // top-10 should cover a majority of the mass for s=1.1, n=100
        let top10: usize = counts[..10].iter().sum();
        assert!(top10 * 2 > 50_000, "{top10}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(8);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 2 * counts[0]);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(9);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
