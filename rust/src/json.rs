//! Minimal dependency-free JSON parser + writer.
//!
//! The build environment is fully offline and `serde_json` is not in the
//! vendored crate set, so the manifest/config/checkpoint plumbing uses
//! this small, well-tested implementation instead.  Supports the complete
//! JSON grammar (objects, arrays, strings with escapes incl. \uXXXX,
//! numbers, bools, null); numbers are parsed as f64 (adequate for the
//! manifest: shapes fit exactly in f64).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse or access error.
#[derive(Debug, thiserror::Error)]
pub enum JsonError {
    #[error("json parse error at byte {pos}: {msg}")]
    Parse { pos: usize, msg: String },
    #[error("json access error: {0}")]
    Access(String),
}

type Result<T> = std::result::Result<T, JsonError>;

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| JsonError::Access(format!("missing key {key:?}"))),
            _ => Err(JsonError::Access(format!(
                "expected object while looking up {key:?}"
            ))),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(JsonError::Access(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(JsonError::Access(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        let f = self.as_f64()?;
        if f.fract() != 0.0 || f.abs() > 2f64.powi(53) {
            return Err(JsonError::Access(format!("expected integer, got {f}")));
        }
        Ok(f as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let i = self.as_i64()?;
        if i < 0 {
            return Err(JsonError::Access(format!("expected unsigned, got {i}")));
        }
        Ok(i as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(JsonError::Access(format!("expected bool, got {other:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(JsonError::Access(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => Err(JsonError::Access(format!("expected object, got {other:?}"))),
        }
    }

    /// Serialize compactly (sorted keys, round-trips through parse()).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors used by checkpoint/metrics writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            cp
                        };
                        s.push(
                            char::from_u32(c)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    let len = utf8_len(c);
                    if len == 1 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump();
                        }
                        let chunk = std::str::from_utf8(&self.src[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"\\q\"").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x",true,null],"n":-3,"o":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"σ-MoE\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "σ-MoE");
    }
}
