//! Host-side tensors: the interchange type between the data pipeline,
//! checkpoints and the PJRT runtime.
//!
//! Deliberately minimal: dense row-major arrays of f32 / i32 / u32 —
//! exactly the dtypes the AOT'd graphs use.

use crate::error::{Error, Result};

/// Element type of a [`HostTensor`]; mirrors the XLA primitive types the
/// artifacts use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn size_bytes(self) -> usize {
        4
    }

    /// Parse a numpy-style dtype string from the manifest.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" | "f32" => Ok(DType::F32),
            "int32" | "i32" => Ok(DType::I32),
            "uint32" | "u32" => Ok(DType::U32),
            other => Err(Error::Manifest(format!("unsupported dtype {other:?}"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::I32 => "int32",
            DType::U32 => "uint32",
        }
    }

    pub fn to_xla(self) -> xla::ElementType {
        match self {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
            DType::U32 => xla::ElementType::U32,
        }
    }
}

/// A dense row-major host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    /// Raw little-endian bytes, `element_count * 4` long.
    pub data: Vec<u8>,
}

impl HostTensor {
    pub fn zeros(dtype: DType, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        HostTensor { dtype, shape: shape.to_vec(), data: vec![0u8; n * 4] }
    }

    pub fn from_f32(shape: &[usize], vals: &[f32]) -> Result<Self> {
        Self::from_bytes(DType::F32, shape, bytes_of_f32(vals))
    }

    pub fn from_i32(shape: &[usize], vals: &[i32]) -> Result<Self> {
        Self::from_bytes(DType::I32, shape, bytes_of_i32(vals))
    }

    pub fn from_u32(shape: &[usize], vals: &[u32]) -> Result<Self> {
        Self::from_bytes(DType::U32, shape, bytes_of_u32(vals))
    }

    pub fn scalar_f32(v: f32) -> Self {
        Self::from_f32(&[], &[v]).expect("scalar")
    }

    pub fn scalar_i32(v: i32) -> Self {
        Self::from_i32(&[], &[v]).expect("scalar")
    }

    pub fn scalar_u32(v: u32) -> Self {
        Self::from_u32(&[], &[v]).expect("scalar")
    }

    fn from_bytes(dtype: DType, shape: &[usize], data: Vec<u8>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if data.len() != n * dtype.size_bytes() {
            return Err(Error::Shape(format!(
                "data length {} does not match shape {:?}",
                data.len(),
                shape
            )));
        }
        Ok(HostTensor { dtype, shape: shape.to_vec(), data })
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            return Err(Error::Shape(format!("tensor is {:?}, not F32", self.dtype)));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            return Err(Error::Shape(format!("tensor is {:?}, not I32", self.dtype)));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// First element as f32 (for scalar outputs such as the loss).
    pub fn scalar_as_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        v.first()
            .copied()
            .ok_or_else(|| Error::Shape("empty tensor".into()))
    }

    /// Convert to an XLA literal for PJRT execution.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            self.dtype.to_xla(),
            &self.shape,
            &self.data,
        )?)
    }

    /// Convert back from an XLA literal.
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
        let dtype = match shape.ty() {
            xla::ElementType::F32 => DType::F32,
            xla::ElementType::S32 => DType::I32,
            xla::ElementType::U32 => DType::U32,
            other => {
                return Err(Error::Shape(format!(
                    "unsupported literal element type {other:?}"
                )))
            }
        };
        // one typed staging buffer + one LE conversion pass (no zeroed
        // byte vector that the conversion would immediately overwrite)
        let n = lit.element_count();
        let data = match dtype {
            DType::F32 => {
                let mut tmp = vec![0f32; n];
                lit.copy_raw_to(&mut tmp)?;
                bytes_of_f32(&tmp)
            }
            DType::I32 => {
                let mut tmp = vec![0i32; n];
                lit.copy_raw_to(&mut tmp)?;
                bytes_of_i32(&tmp)
            }
            DType::U32 => {
                let mut tmp = vec![0u32; n];
                lit.copy_raw_to(&mut tmp)?;
                bytes_of_u32(&tmp)
            }
        };
        Ok(HostTensor { dtype, shape: dims, data })
    }
}

fn bytes_of_f32(vals: &[f32]) -> Vec<u8> {
    vals.iter().flat_map(|v| v.to_le_bytes()).collect()
}

fn bytes_of_i32(vals: &[i32]) -> Vec<u8> {
    vals.iter().flat_map(|v| v.to_le_bytes()).collect()
}

fn bytes_of_u32(vals: &[u32]) -> Vec<u8> {
    vals.iter().flat_map(|v| v.to_le_bytes()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = HostTensor::from_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.element_count(), 6);
        assert_eq!(t.as_f32().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(HostTensor::from_f32(&[2, 2], &[1., 2., 3.]).is_err());
    }

    #[test]
    fn zeros_and_scalar() {
        let z = HostTensor::zeros(DType::I32, &[4]);
        assert_eq!(z.as_i32().unwrap(), vec![0; 4]);
        assert_eq!(HostTensor::scalar_f32(2.5).scalar_as_f32().unwrap(), 2.5);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("float32").unwrap(), DType::F32);
        assert_eq!(DType::parse("int32").unwrap(), DType::I32);
        assert!(DType::parse("float64").is_err());
    }
}
