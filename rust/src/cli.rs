//! Tiny declarative CLI argument parser (clap is not in the offline
//! vendor set).  Supports `--flag`, `--key value`, `--key=value`,
//! required/optional/defaulted options, and auto-generated help.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// One declared option.
#[derive(Debug, Clone)]
struct Opt {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_flag: bool,
    required: bool,
}

/// Declarative argument parser.
#[derive(Debug, Default)]
pub struct Args {
    program: String,
    about: &'static str,
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(about: &'static str) -> Self {
        Args { about, ..Default::default() }
    }

    pub fn opt(mut self, name: &'static str, default: &str,
               help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
            required: false,
        });
        self
    }

    pub fn required(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_flag: false,
                             required: true });
        self
    }

    pub fn optional(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_flag: false,
                             required: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_flag: true,
                             required: false });
        self
    }

    /// Parse an explicit argv (no program name).  Returns Err on unknown
    /// options, missing required options or missing values.
    pub fn parse_from(mut self, argv: &[String]) -> Result<Parsed> {
        let mut it = argv.iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(Error::Config(self.help_text()));
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (stripped, None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| {
                        Error::Config(format!(
                            "unknown option --{key}\n\n{}",
                            self.help_text()
                        ))
                    })?
                    .clone();
                let value = if opt.is_flag {
                    inline_val.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline_val {
                    v
                } else {
                    it.next()
                        .ok_or_else(|| {
                            Error::Config(format!("--{key} needs a value"))
                        })?
                        .clone()
                };
                self.values.insert(key.to_string(), value);
            } else {
                self.positional.push(arg.clone());
            }
        }
        for o in &self.opts {
            if o.required && !self.values.contains_key(o.name) {
                return Err(Error::Config(format!(
                    "missing required option --{}\n\n{}",
                    o.name,
                    self.help_text()
                )));
            }
            if let Some(d) = &o.default {
                self.values.entry(o.name.to_string()).or_insert(d.clone());
            }
        }
        Ok(Parsed { values: self.values, positional: self.positional })
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{}\n\noptions:\n", self.about);
        for o in &self.opts {
            let kind = if o.is_flag {
                String::new()
            } else if let Some(d) = &o.default {
                format!(" <value, default {d}>")
            } else if o.required {
                " <value, required>".to_string()
            } else {
                " <value>".to_string()
            };
            s.push_str(&format!("  --{}{kind}\n      {}\n", o.name, o.help));
        }
        let _ = &self.program;
        s
    }
}

/// Parsed arguments with typed accessors.
#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| Error::Config(format!("option --{name} not set")))
    }

    pub fn usize(&self, name: &str) -> Result<usize> {
        self.str(name)?.parse().map_err(|e| {
            Error::Config(format!("--{name}: not an integer: {e}"))
        })
    }

    pub fn u64(&self, name: &str) -> Result<u64> {
        self.str(name)?.parse().map_err(|e| {
            Error::Config(format!("--{name}: not an integer: {e}"))
        })
    }

    pub fn i64(&self, name: &str) -> Result<i64> {
        self.str(name)?.parse().map_err(|e| {
            Error::Config(format!("--{name}: not an integer: {e}"))
        })
    }

    pub fn f64(&self, name: &str) -> Result<f64> {
        self.str(name)?.parse().map_err(|e| {
            Error::Config(format!("--{name}: not a float: {e}"))
        })
    }

    pub fn flag(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    /// Typed accessor for an option that may legitimately be unset
    /// (declared with [`Args::optional`]): `Ok(None)` when absent,
    /// `Err` when present but unparsable.
    fn opt_parsed<T: std::str::FromStr>(
        &self,
        name: &str,
        what: &str,
    ) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        self.get(name)
            .map(|s| {
                s.parse().map_err(|e| {
                    Error::Config(format!("--{name}: not {what}: {e}"))
                })
            })
            .transpose()
    }

    pub fn opt_usize(&self, name: &str) -> Result<Option<usize>> {
        self.opt_parsed(name, "an integer")
    }

    pub fn opt_u64(&self, name: &str) -> Result<Option<u64>> {
        self.opt_parsed(name, "an integer")
    }

    pub fn opt_f64(&self, name: &str) -> Result<Option<f64>> {
        self.opt_parsed(name, "a float")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let p = Args::new("t")
            .opt("steps", "100", "steps")
            .opt("lr", "0.1", "lr")
            .parse_from(&argv(&["--steps", "5"]))
            .unwrap();
        assert_eq!(p.usize("steps").unwrap(), 5);
        assert_eq!(p.f64("lr").unwrap(), 0.1);
    }

    #[test]
    fn equals_syntax_and_flags() {
        let p = Args::new("t")
            .opt("name", "x", "n")
            .flag("verbose", "v")
            .parse_from(&argv(&["--name=abc", "--verbose"]))
            .unwrap();
        assert_eq!(p.str("name").unwrap(), "abc");
        assert!(p.flag("verbose"));
    }

    #[test]
    fn required_enforced() {
        let r = Args::new("t")
            .required("preset", "preset name")
            .parse_from(&argv(&[]));
        assert!(r.is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let r = Args::new("t").parse_from(&argv(&["--nope", "1"]));
        assert!(r.is_err());
    }

    #[test]
    fn positional_collected() {
        let p = Args::new("t")
            .opt("a", "1", "a")
            .parse_from(&argv(&["cmd1", "--a", "2", "cmd2"]))
            .unwrap();
        assert_eq!(p.positional, vec!["cmd1", "cmd2"]);
    }

    #[test]
    fn missing_value_is_error() {
        let r = Args::new("t").opt("a", "1", "a").parse_from(&argv(&["--a"]));
        assert!(r.is_err());
    }

    #[test]
    fn optional_typed_accessors() {
        let p = Args::new("t")
            .optional("n", "n")
            .optional("x", "x")
            .optional("m", "m")
            .parse_from(&argv(&["--n", "5", "--x", "2.5"]))
            .unwrap();
        assert_eq!(p.opt_usize("n").unwrap(), Some(5));
        assert_eq!(p.opt_f64("x").unwrap(), Some(2.5));
        assert_eq!(p.opt_u64("m").unwrap(), None);
        let bad = Args::new("t")
            .optional("n", "n")
            .parse_from(&argv(&["--n", "five"]))
            .unwrap();
        assert!(bad.opt_usize("n").is_err());
    }
}
