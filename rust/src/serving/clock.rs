//! Injectable time for the serving stack.
//!
//! Every layer of the serving stack — scheduler deadlines, router
//! heartbeats, quarantine/re-admission backoff, histogram timestamps —
//! reads time through one [`Clock`] handle instead of calling
//! [`Instant::now`] directly.  Production wires in [`WallClock`]
//! (identical behaviour to before); the record/replay and chaos
//! harnesses wire in a [`SimClock`] whose time only moves when the
//! harness advances it, which makes deadline expiry, heartbeat
//! staleness, and backoff windows exact functions of the test schedule
//! rather than of host scheduling jitter.
//!
//! `Instant` is an opaque monotonic point, so a simulated clock cannot
//! fabricate one from nothing; [`SimClock`] anchors itself at a real
//! instant on construction and returns `base + virtual_offset`.  All
//! arithmetic downstream (`duration_since`, deadline comparisons) then
//! behaves as if that much time had truly passed, while no thread ever
//! sleeps.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A monotonic time source the serving stack reads instead of
/// [`Instant::now`].  Implementations must be cheap and thread-safe:
/// the placer, every engine driver, and every connection thread share
/// one handle.
pub trait Clock: Send + Sync {
    /// The current instant on this clock.
    fn now(&self) -> Instant;

    /// Milliseconds elapsed since the clock's epoch (construction).
    /// Heartbeats and journal timestamps use this directly so traces
    /// carry small logical numbers, not opaque instants.
    fn now_ms(&self) -> u64;

    /// Sleep for `d` on this clock.  The wall clock really sleeps; the
    /// simulated clock just advances itself, so single-threaded
    /// replays burn no real time.
    fn sleep(&self, d: Duration);
}

/// Shared clock handle, as stored by every serving component.
pub type SharedClock = Arc<dyn Clock>;

/// The production clock: real time, real sleeps.
#[derive(Debug)]
pub struct WallClock {
    epoch: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        WallClock { epoch: Instant::now() }
    }

    /// The default clock used by constructors that don't take one.
    pub fn shared() -> SharedClock {
        Arc::new(WallClock::new())
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> Instant {
        Instant::now()
    }

    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// A simulated clock for deterministic replay: time stands still until
/// [`SimClock::advance`] (or a [`Clock::sleep`]) moves it.  Anchored at
/// a real instant so downstream `Instant` arithmetic keeps working.
#[derive(Debug)]
pub struct SimClock {
    base: Instant,
    offset_us: AtomicU64,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock { base: Instant::now(), offset_us: AtomicU64::new(0) }
    }

    pub fn shared() -> Arc<SimClock> {
        Arc::new(SimClock::new())
    }

    /// Advance virtual time by `d`.
    pub fn advance(&self, d: Duration) {
        self.offset_us
            .fetch_add(d.as_micros() as u64, Ordering::SeqCst);
    }

    /// Microseconds of virtual time elapsed since construction.
    pub fn elapsed_us(&self) -> u64 {
        self.offset_us.load(Ordering::SeqCst)
    }
}

impl Default for SimClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SimClock {
    fn now(&self) -> Instant {
        self.base + Duration::from_micros(self.elapsed_us())
    }

    fn now_ms(&self) -> u64 {
        self.elapsed_us() / 1000
    }

    fn sleep(&self, d: Duration) {
        self.advance(d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_moves_forward() {
        let c = WallClock::new();
        let a = c.now();
        std::thread::sleep(Duration::from_millis(2));
        assert!(c.now() > a);
        assert!(c.now_ms() <= 10_000);
    }

    #[test]
    fn sim_clock_only_moves_when_advanced() {
        let c = SimClock::new();
        let a = c.now();
        std::thread::sleep(Duration::from_millis(2));
        assert_eq!(c.now(), a, "sim time must not follow wall time");
        assert_eq!(c.now_ms(), 0);
        c.advance(Duration::from_millis(1500));
        assert_eq!(c.now_ms(), 1500);
        assert_eq!(c.now(), a + Duration::from_millis(1500));
        // sleep is just an advance
        c.sleep(Duration::from_millis(500));
        assert_eq!(c.now_ms(), 2000);
    }

    #[test]
    fn sim_clock_is_shareable_across_threads() {
        let c = SimClock::shared();
        let c2: SharedClock = c.clone();
        let t = {
            let c = c.clone();
            std::thread::spawn(move || c.advance(Duration::from_secs(1)))
        };
        t.join().unwrap();
        assert_eq!(c2.now_ms(), 1000);
    }
}
