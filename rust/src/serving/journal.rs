//! Decision journal for deterministic record/replay.
//!
//! Every load-bearing decision the serving stack makes — admission,
//! placement, pump outcome, heartbeat, quarantine, failover, retry,
//! re-admission — is recorded as one logically-timestamped event in a
//! bounded in-memory ring.  The ring costs nothing observable on the
//! hot path (one short mutex hold per decision, and nothing at all for
//! the disabled journal production wires in) and can be flushed to a
//! JSONL trace at any point: on an invariant failure, or explicitly by
//! `loadgen --record` / the `chaos` subcommand.
//!
//! Trace format (`sigma-moe/trace/v1`): line 1 is a header object
//! carrying the schema tag, the run seed, and the full run
//! configuration — everything needed to re-execute the run.  Every
//! following line is one event:
//!
//! ```text
//! {"cfg":{...},"schema":"sigma-moe/trace/v1","seed":42}
//! {"engine":0,"id":0,"kind":"place","seq":3,"t_ms":12}
//! ```
//!
//! Events carry `seq` (a global monotonic sequence number) and `t_ms`
//! (milliseconds on the injected [`Clock`](super::clock::Clock) —
//! *logical* time under a `SimClock`).  Keys are emitted sorted (the
//! JSON writer is `BTreeMap`-backed), so two runs that make the same
//! decisions at the same logical times produce byte-identical event
//! streams — which is exactly the property `loadgen --replay` asserts.

use std::path::Path;
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::json::{self, Json};
use crate::serving::clock::{Clock, SharedClock};

/// Trace schema tag written into every header.
pub const TRACE_SCHEMA: &str = "sigma-moe/trace/v1";

/// Default ring capacity: enough for a full chaos run while bounding a
/// runaway recorder to a few MB.
pub const DEFAULT_CAPACITY: usize = 1 << 16;

struct Inner {
    /// Compact-serialized events in arrival order (ring-evicted from
    /// the front at capacity).
    lines: std::collections::VecDeque<String>,
    /// Events evicted from the ring (reported in the header on flush so
    /// a truncated trace is never mistaken for a complete one).
    evicted: u64,
    seq: u64,
}

/// Thread-safe bounded decision recorder shared by the scheduler, the
/// router, and the chaos harness.
pub struct Journal {
    enabled: bool,
    capacity: usize,
    clock: SharedClock,
    /// Header metadata (seed + run config), set once by the harness.
    meta: Mutex<Json>,
    inner: Mutex<Inner>,
}

impl Journal {
    /// A recording journal timestamping events on `clock`.
    pub fn new(clock: SharedClock) -> Self {
        Journal::with_capacity(clock, DEFAULT_CAPACITY)
    }

    pub fn with_capacity(clock: SharedClock, capacity: usize) -> Self {
        Journal {
            enabled: true,
            capacity: capacity.max(1),
            clock,
            meta: Mutex::new(Json::Null),
            inner: Mutex::new(Inner {
                lines: std::collections::VecDeque::new(),
                evicted: 0,
                seq: 0,
            }),
        }
    }

    /// The no-op journal production paths wire in: `record` returns
    /// before touching any lock.
    pub fn disabled(clock: SharedClock) -> Self {
        Journal {
            enabled: false,
            capacity: 1,
            clock,
            meta: Mutex::new(Json::Null),
            inner: Mutex::new(Inner {
                lines: std::collections::VecDeque::new(),
                evicted: 0,
                seq: 0,
            }),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Attach header metadata (`seed`, `cfg`, ...) merged into the
    /// trace header on flush.
    pub fn set_meta(&self, meta: Json) {
        *self.meta.lock().unwrap() = meta;
    }

    /// Record one decision.  `fields` must not contain `kind`, `seq`,
    /// or `t_ms` (the journal owns those).
    pub fn record(&self, kind: &str, fields: Vec<(&str, Json)>) {
        if !self.enabled {
            return;
        }
        let t_ms = self.clock.now_ms();
        let mut obj = fields;
        obj.push(("kind", json::s(kind)));
        obj.push(("t_ms", json::num(t_ms as f64)));
        let mut inner = self.inner.lock().unwrap();
        obj.push(("seq", json::num(inner.seq as f64)));
        inner.seq += 1;
        let line = json::obj(obj).to_string_compact();
        if inner.lines.len() >= self.capacity {
            inner.lines.pop_front();
            inner.evicted += 1;
        }
        inner.lines.push_back(line);
    }

    /// Number of events currently held (post-eviction).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().lines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total events ever recorded (including ring-evicted ones).
    pub fn total_recorded(&self) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner.seq
    }

    /// Events evicted from the ring (dropped from any future flush).
    /// Non-zero means a flushed trace is truncated — `/metrics` exposes
    /// this so an operator can size the ring before relying on a trace,
    /// and replay refuses truncated traces outright.
    pub fn dropped_events(&self) -> u64 {
        self.inner.lock().unwrap().evicted
    }

    /// The event stream alone (no header), one compact JSON object per
    /// line.  This is the byte stream replay diffs.
    pub fn events_jsonl(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = String::new();
        for line in &inner.lines {
            out.push_str(line);
            out.push('\n');
        }
        out
    }

    /// Header line: the meta object plus schema tag and eviction count.
    pub fn header_json(&self) -> Json {
        let meta = self.meta.lock().unwrap().clone();
        let inner = self.inner.lock().unwrap();
        let mut fields: Vec<(String, Json)> = match meta {
            Json::Obj(m) => m.into_iter().collect(),
            Json::Null => Vec::new(),
            other => vec![("meta".to_string(), other)],
        };
        fields.push(("schema".to_string(), json::s(TRACE_SCHEMA)));
        fields.push(("events".to_string(), json::num(inner.lines.len() as f64)));
        fields.push(("evicted".to_string(), json::num(inner.evicted as f64)));
        fields.push(("truncated".to_string(), Json::Bool(inner.evicted > 0)));
        Json::Obj(fields.into_iter().collect())
    }

    /// Full trace: header line + events.
    pub fn to_trace(&self) -> String {
        let mut out = self.header_json().to_string_compact();
        out.push('\n');
        out.push_str(&self.events_jsonl());
        out
    }

    /// Flush the trace to `path` (creating parent directories).
    pub fn write_trace(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_trace())?;
        Ok(())
    }
}

/// A parsed trace file: the header object plus the raw event lines
/// (kept as strings so replay can diff byte-for-byte without
/// re-serialization concerns).
pub struct Trace {
    pub header: Json,
    pub event_lines: Vec<String>,
}

impl Trace {
    pub fn parse(text: &str) -> Result<Trace> {
        let mut lines = text.lines();
        let header_line = lines.next().ok_or_else(|| {
            Error::Serving("empty trace file".to_string())
        })?;
        let header = Json::parse(header_line).map_err(|e| {
            Error::Serving(format!("bad trace header: {e}"))
        })?;
        let schema = header
            .get("schema")
            .and_then(|s| s.as_str().map(str::to_string))
            .map_err(|e| Error::Serving(format!("bad trace header: {e}")))?;
        if schema != TRACE_SCHEMA {
            return Err(Error::Serving(format!(
                "trace schema {schema:?} != {TRACE_SCHEMA:?}"
            )));
        }
        let mut event_lines = Vec::new();
        for (i, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            Json::parse(line).map_err(|e| {
                Error::Serving(format!("bad trace event on line {}: {e}", i + 2))
            })?;
            event_lines.push(line.to_string());
        }
        Ok(Trace { header, event_lines })
    }

    pub fn read(path: &Path) -> Result<Trace> {
        let text = std::fs::read_to_string(path)?;
        Trace::parse(&text)
    }

    /// The event stream as one JSONL string (for diffing against a
    /// replayed journal's [`Journal::events_jsonl`]).
    pub fn events_jsonl(&self) -> String {
        let mut out = String::new();
        for line in &self.event_lines {
            out.push_str(line);
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::clock::SimClock;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn records_are_sequenced_and_logically_timestamped() {
        let clock = SimClock::shared();
        let j = Journal::new(clock.clone());
        j.record("admit", vec![("id", json::num(0.0))]);
        clock.advance(Duration::from_millis(7));
        j.record("place", vec![("id", json::num(0.0)), ("engine", json::num(1.0))]);
        let text = j.events_jsonl();
        let rows: Vec<&str> = text.lines().collect();
        assert_eq!(rows.len(), 2);
        assert_eq!(
            rows[0],
            r#"{"id":0,"kind":"admit","seq":0,"t_ms":0}"#
        );
        assert_eq!(
            rows[1],
            r#"{"engine":1,"id":0,"kind":"place","seq":1,"t_ms":7}"#
        );
    }

    #[test]
    fn disabled_journal_records_nothing() {
        let j = Journal::disabled(SimClock::shared());
        j.record("admit", vec![]);
        assert!(j.is_empty());
        assert!(!j.is_enabled());
    }

    #[test]
    fn ring_evicts_oldest_and_reports_it() {
        let j = Journal::with_capacity(SimClock::shared(), 2);
        for i in 0..5 {
            j.record("pump", vec![("n", json::num(i as f64))]);
        }
        assert_eq!(j.len(), 2);
        assert_eq!(j.total_recorded(), 5);
        assert_eq!(j.dropped_events(), 3);
        let h = j.header_json();
        assert_eq!(h.get("evicted").unwrap().as_f64().unwrap(), 3.0);
        assert!(h.get("truncated").unwrap().as_bool().unwrap());
        // the survivors are the two newest
        assert!(j.events_jsonl().contains("\"seq\":4"));
        assert!(!j.events_jsonl().contains("\"seq\":0"));
    }

    #[test]
    fn trace_roundtrips_through_parse() {
        let clock = SimClock::shared();
        let j = Journal::new(clock.clone());
        j.set_meta(json::obj(vec![
            ("seed", json::num(42.0)),
            ("cfg", json::obj(vec![("engines", json::num(2.0))])),
        ]));
        j.record("admit", vec![("id", json::num(0.0))]);
        clock.advance(Duration::from_millis(3));
        j.record("done", vec![("id", json::num(0.0))]);
        let text = j.to_trace();
        let trace = Trace::parse(&text).unwrap();
        assert_eq!(
            trace.header.get("seed").unwrap().as_f64().unwrap(),
            42.0
        );
        // a complete (non-evicting) journal flushes an untruncated trace
        assert!(!trace.header.get("truncated").unwrap().as_bool().unwrap());
        assert_eq!(trace.event_lines.len(), 2);
        assert_eq!(trace.events_jsonl(), j.events_jsonl());
        // wrong schema is refused
        let bad = text.replace("trace/v1", "trace/v9");
        assert!(Trace::parse(&bad).is_err());
    }

    #[test]
    fn write_trace_creates_parent_dirs() {
        let dir = std::env::temp_dir().join("sigma_moe_journal_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("nested").join("trace.jsonl");
        let j = Journal::new(Arc::new(SimClock::new()));
        j.record("beat", vec![("engine", json::num(0.0))]);
        j.write_trace(&path).unwrap();
        let trace = Trace::read(&path).unwrap();
        assert_eq!(trace.event_lines.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
