//! Open-loop load generator for the HTTP serving frontend.
//!
//! Replays synthetic traffic with Poisson arrivals (exponential
//! inter-arrival at a target request rate — open loop: arrival times
//! are fixed up front and do *not* wait for responses, so queueing
//! shows up as latency, the honest way to measure a serving system)
//! and a configurable prompt-length / generation-length / streaming
//! mix.  Each request runs on its own thread with a hand-rolled HTTP
//! client (chunked-transfer decoding included); results aggregate into
//! latency + time-to-first-token histograms and a machine-readable
//! `BENCH_serve.json` row via [`crate::bench_util::write_bench_json`].
//!
//! `dry_run` spins the whole stack — scheduler, HTTP server, chunked
//! streaming, report — over the in-process [`MockBackend`] so CI can
//! smoke-test request generation and report writing with no device.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::json::{self, Json};
use crate::rng::Rng;
use crate::serving::mock::{MockBackend, MockFault, MOCK_TOP_K};
use crate::serving::router::{self, RouterCfg};
use crate::serving::scheduler::{DegradeCfg, Histogram};
use crate::serving::server::{self, ServerConfig};
use crate::serving::telemetry;

/// Prompt-length distribution of the synthetic plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PromptDist {
    /// Every prompt is exactly the range maximum (`--prompt-max`).
    Fixed,
    /// Uniform over the inclusive `prompt_len` range (the default).
    Uniform,
    /// Log-normal shaped into the `prompt_len` range — many short
    /// prompts with a heavy long tail, the shape that makes chunked
    /// prefill's per-length TTFT rows informative.  μ/σ are set so the
    /// geometric mean of the range is the median and ±2σ spans the
    /// range; samples clamp into it.
    Lognormal,
    /// One seed-fixed common prefix (`shared_prefix_overlap` of the
    /// range maximum) followed by a per-request random tail — the
    /// workload a prefix cache exists for.  Lengths stay uniform over
    /// the range and every prompt keeps at least one unique-tail slot.
    SharedPrefix,
}

impl PromptDist {
    pub fn parse(s: &str) -> Result<PromptDist> {
        match s {
            "fixed" => Ok(PromptDist::Fixed),
            "uniform" => Ok(PromptDist::Uniform),
            "lognormal" => Ok(PromptDist::Lognormal),
            "shared-prefix" => Ok(PromptDist::SharedPrefix),
            other => Err(Error::Config(format!(
                "unknown prompt distribution {other:?} \
                 (expected fixed | uniform | lognormal | shared-prefix)"
            ))),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            PromptDist::Fixed => "fixed",
            PromptDist::Uniform => "uniform",
            PromptDist::Lognormal => "lognormal",
            PromptDist::SharedPrefix => "shared-prefix",
        }
    }
}

/// Load-generation parameters.
#[derive(Debug, Clone)]
pub struct LoadgenCfg {
    pub requests: usize,
    /// Target offered load, requests/second (Poisson arrivals).
    pub rps: f64,
    /// Prompt-length range (inclusive); how lengths are drawn from it
    /// is `prompt_dist`.
    pub prompt_len: (usize, usize),
    /// Prompt-length distribution over `prompt_len`.
    pub prompt_dist: PromptDist,
    /// Uniform `max_tokens` range (inclusive).
    pub max_new: (usize, usize),
    /// Prompt token ids are drawn uniformly from `[0, vocab)`.
    pub vocab: usize,
    /// Fraction of requests that use chunked streaming.
    pub stream_fraction: f64,
    pub temperature: f64,
    pub top_k: usize,
    pub greedy: bool,
    pub deadline_ms: Option<u64>,
    pub seed: u64,
    /// Per-request client timeout.
    pub timeout: Duration,
    /// Reuse HTTP connections across requests (keep-alive + a shared
    /// connection pool) instead of one connection per request.
    pub keep_alive: bool,
    /// Dry-run only: the mock engines' chunked-prefill width C (and
    /// the scheduler's prompt-cost unit).  Live runs measure whatever
    /// the server at `--addr` is running.
    pub prefill_chunk: usize,
    /// Dry-run only: run the mock fleet with request-lifecycle +
    /// expert telemetry (the production default).  The off position
    /// exists for the A/B row that prices always-on telemetry.
    pub telemetry: bool,
    /// Dry-run only: speculative draft length K per lane per verify
    /// round on the mock engines (`0` = plain single-token decode).
    /// Live runs speculate with whatever the server at `--addr` was
    /// started with.
    pub speculate: usize,
    /// `shared-prefix` workload: fraction of the prompt-length maximum
    /// covered by the common prefix.
    pub shared_prefix_overlap: f64,
    /// Arm the (dry-run) mock fleet's prefix cache with this byte
    /// budget (`None` = cold prefill) — and switch the report row to
    /// carry cache hit-rate and TTFT hit-vs-miss columns.
    pub prefix_cache: Option<u64>,
}

impl Default for LoadgenCfg {
    fn default() -> Self {
        LoadgenCfg {
            requests: 32,
            rps: 8.0,
            prompt_len: (4, 16),
            prompt_dist: PromptDist::Uniform,
            max_new: (8, 32),
            vocab: 2048,
            stream_fraction: 0.5,
            temperature: 0.8,
            top_k: 50,
            greedy: false,
            deadline_ms: None,
            seed: 1,
            timeout: Duration::from_secs(120),
            keep_alive: false,
            prefill_chunk: 16,
            telemetry: true,
            speculate: 0,
            shared_prefix_overlap: 0.5,
            prefix_cache: None,
        }
    }
}

/// One scheduled request of the open-loop plan.
#[derive(Debug, Clone)]
pub struct Planned {
    /// Arrival offset from the start of the run.
    pub at: Duration,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub stream: bool,
}

fn uniform_incl(rng: &mut Rng, range: (usize, usize)) -> usize {
    let lo = range.0.max(1);
    let hi = range.1.max(lo);
    lo + rng.below(hi - lo + 1)
}

/// One prompt length drawn per `dist` from the inclusive `range`.
fn sample_prompt_len(
    rng: &mut Rng,
    dist: PromptDist,
    range: (usize, usize),
) -> usize {
    let lo = range.0.max(1);
    let hi = range.1.max(lo);
    match dist {
        PromptDist::Fixed => hi,
        PromptDist::Uniform | PromptDist::SharedPrefix => {
            uniform_incl(rng, range)
        }
        PromptDist::Lognormal => {
            let (ln_lo, ln_hi) = ((lo as f64).ln(), (hi as f64).ln());
            let mu = 0.5 * (ln_lo + ln_hi);
            let sigma = ((ln_hi - ln_lo) / 4.0).max(1e-9);
            let x = (mu + sigma * rng.normal()).exp();
            (x.round() as usize).clamp(lo, hi)
        }
    }
}

/// Deterministic open-loop schedule: Poisson arrivals at `cfg.rps`,
/// `prompt_dist`-drawn prompt lengths, uniform generation lengths,
/// Bernoulli streaming mix.
pub fn plan(cfg: &LoadgenCfg) -> Vec<Planned> {
    let mut rng = Rng::new(cfg.seed);
    let rate = cfg.rps.max(1e-9);
    // `shared-prefix` draws its one common prefix up front so every
    // request agrees on it; the other distributions draw nothing here,
    // keeping their per-request RNG streams unchanged.
    let shared: Vec<i32> = if cfg.prompt_dist == PromptDist::SharedPrefix {
        let hi = cfg.prompt_len.1.max(cfg.prompt_len.0.max(1));
        let want = ((hi as f64) * cfg.shared_prefix_overlap.clamp(0.0, 1.0))
            .round() as usize;
        (0..want.min(hi.saturating_sub(1)))
            .map(|_| rng.below(cfg.vocab.max(2)) as i32)
            .collect()
    } else {
        Vec::new()
    };
    let mut t = 0.0f64;
    (0..cfg.requests)
        .map(|_| {
            // exponential inter-arrival: -ln(1 - U) / rate
            t += -(1.0 - rng.next_f64()).ln() / rate;
            let plen =
                sample_prompt_len(&mut rng, cfg.prompt_dist, cfg.prompt_len);
            let prompt: Vec<i32> = if cfg.prompt_dist
                == PromptDist::SharedPrefix
            {
                // common head, ≥ 1 random-tail token
                let keep = shared.len().min(plen.saturating_sub(1));
                shared[..keep]
                    .iter()
                    .copied()
                    .chain(
                        (0..plen - keep)
                            .map(|_| rng.below(cfg.vocab.max(2)) as i32),
                    )
                    .collect()
            } else {
                (0..plen)
                    .map(|_| rng.below(cfg.vocab.max(2)) as i32)
                    .collect()
            };
            Planned {
                at: Duration::from_secs_f64(t),
                prompt,
                max_new: uniform_incl(&mut rng, cfg.max_new),
                stream: rng.coin(cfg.stream_fraction),
            }
        })
        .collect()
}

/// Arrival-order mirror of the server's chunk-boundary cache probe:
/// request *i* is predicted to hit iff some chunk-aligned prefix of its
/// prompt already appeared (as a chunk-aligned prefix) in requests
/// `0..i`.  Used to split client-side TTFT into hit/miss histograms —
/// the authoritative rate still comes from the server's cache section.
fn predict_cache_hits(planned: &[Planned], chunk: usize) -> Vec<bool> {
    let chunk = chunk.max(1);
    let mut seen: std::collections::HashSet<&[i32]> =
        std::collections::HashSet::new();
    planned
        .iter()
        .map(|p| {
            let len = p.prompt.len();
            // longest snapshot boundary strictly below the prompt end
            let top = if len > 1 { (len - 1) / chunk * chunk } else { 0 };
            let mut hit = false;
            let mut b = top;
            while b >= chunk {
                hit |= seen.contains(&p.prompt[..b]);
                seen.insert(&p.prompt[..b]);
                b -= chunk;
            }
            hit
        })
        .collect()
}

/// The `/v1/completions` body for one planned request.
pub fn completion_body(p: &Planned, cfg: &LoadgenCfg) -> Json {
    let mut fields = vec![
        (
            "prompt",
            json::arr(p.prompt.iter().map(|&t| json::num(t as f64)).collect()),
        ),
        ("max_tokens", json::num(p.max_new as f64)),
        ("temperature", json::num(cfg.temperature)),
        ("top_k", json::num(cfg.top_k as f64)),
        ("stream", Json::Bool(p.stream)),
    ];
    if cfg.greedy {
        fields.push(("greedy", Json::Bool(true)));
    }
    if let Some(ms) = cfg.deadline_ms {
        fields.push(("deadline_ms", json::num(ms as f64)));
    }
    json::obj(fields)
}

/// Client-side view of one finished request.
#[derive(Debug, Clone)]
pub struct ReqOutcome {
    pub status: u16,
    /// 200 and no mid-stream error line.
    pub ok: bool,
    /// 429 backpressure.
    pub rejected: bool,
    /// deadline/shutdown drop (503 or an `{"error": ...}` stream line).
    pub dropped: bool,
    pub latency: Duration,
    /// Time to first streamed token (streaming requests only).
    pub ttft: Option<Duration>,
    pub tokens: usize,
}

fn read_line(r: &mut impl BufRead) -> Result<String> {
    let mut buf = Vec::new();
    let n = r.by_ref().take(64 * 1024).read_until(b'\n', &mut buf)?;
    if n == 0 {
        return Err(Error::Serving("unexpected eof from server".into()));
    }
    while matches!(buf.last(), Some(b'\n') | Some(b'\r')) {
        buf.pop();
    }
    String::from_utf8(buf)
        .map_err(|_| Error::Serving("non-utf8 response line".into()))
}

/// Parse an HTTP response head; returns (status, headers with
/// lowercased names).  Public so tests (and other clients of the
/// serving frontend) don't re-implement status/header parsing.
pub fn read_head(
    r: &mut impl BufRead,
) -> Result<(u16, Vec<(String, String)>)> {
    let status_line = read_line(r)?;
    let status = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| {
            Error::Serving(format!("bad status line {status_line:?}"))
        })?;
    let mut headers = Vec::new();
    loop {
        let line = read_line(r)?;
        if line.is_empty() {
            return Ok((status, headers));
        }
        if let Some((k, v)) = line.split_once(':') {
            headers
                .push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
        }
    }
}

fn header<'h>(headers: &'h [(String, String)], name: &str) -> Option<&'h str> {
    headers
        .iter()
        .find(|(k, _)| k == name)
        .map(|(_, v)| v.as_str())
}

/// Decode a chunked-transfer body, invoking `on_chunk` with each data
/// chunk as it arrives (for time-to-first-token measurement); returns
/// the reassembled body.
pub fn read_chunked(
    r: &mut impl BufRead,
    mut on_chunk: impl FnMut(&[u8]),
) -> Result<Vec<u8>> {
    let mut body = Vec::new();
    loop {
        let size_line = read_line(r)?;
        let size_hex = size_line.split(';').next().unwrap_or("").trim();
        let size = usize::from_str_radix(size_hex, 16).map_err(|_| {
            Error::Serving(format!("bad chunk size {size_line:?}"))
        })?;
        if size == 0 {
            // trailer section: lines until the final empty line
            loop {
                if read_line(r)?.is_empty() {
                    return Ok(body);
                }
            }
        }
        if size > 16 * 1024 * 1024 {
            return Err(Error::Serving("chunk too large".into()));
        }
        let mut chunk = vec![0u8; size];
        r.read_exact(&mut chunk)?;
        let mut crlf = [0u8; 2];
        r.read_exact(&mut crlf)?;
        if &crlf != b"\r\n" {
            return Err(Error::Serving("chunk missing CRLF".into()));
        }
        on_chunk(&chunk);
        body.extend_from_slice(&chunk);
    }
}

/// POST one completion request on an already-connected stream and
/// consume the whole response (streaming or unary).  `t0` is the
/// latency epoch (set before connecting so connect time counts).
/// Returns the outcome plus the stream when it can be reused
/// (keep-alive requested and the server didn't answer
/// `Connection: close`).
fn exchange(
    stream: TcpStream,
    body: &Json,
    timeout: Duration,
    keep_alive: bool,
    t0: Instant,
) -> Result<(ReqOutcome, Option<TcpStream>)> {
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let _ = stream.set_nodelay(true);
    let host = stream.peer_addr()?;
    let payload = body.to_string_compact();
    let mut writer = stream.try_clone()?;
    writer.write_all(
        format!(
            "POST /v1/completions HTTP/1.1\r\nHost: {host}\r\n\
             Content-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: {}\r\n\r\n{payload}",
            payload.len(),
            if keep_alive { "keep-alive" } else { "close" }
        )
        .as_bytes(),
    )?;
    let mut r = BufReader::new(stream);
    let (status, headers) = read_head(&mut r)?;
    let chunked = header(&headers, "transfer-encoding")
        .is_some_and(|v| v.eq_ignore_ascii_case("chunked"));
    let mut tokens = 0usize;
    let mut ttft = None;
    let mut dropped = false;
    if chunked {
        let mut line_buf: Vec<u8> = Vec::new();
        read_chunked(&mut r, |chunk| {
            line_buf.extend_from_slice(chunk);
            while let Some(pos) = line_buf.iter().position(|&b| b == b'\n') {
                let line: Vec<u8> = line_buf.drain(..=pos).collect();
                let Ok(text) = std::str::from_utf8(&line) else { continue };
                let Ok(doc) = Json::parse(text.trim()) else { continue };
                if doc.opt("token").is_some() {
                    tokens += 1;
                    ttft.get_or_insert_with(|| t0.elapsed());
                } else if doc.opt("error").is_some() {
                    dropped = true;
                }
            }
        })?;
    } else {
        let len: usize = header(&headers, "content-length")
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| {
                Error::Serving("response missing content-length".into())
            })?;
        let mut buf = vec![0u8; len];
        r.read_exact(&mut buf)?;
        if status == 200 {
            let doc = Json::parse(
                std::str::from_utf8(&buf)
                    .map_err(|_| Error::Serving("non-utf8 body".into()))?,
            )
            .map_err(|e| Error::Serving(format!("bad response json: {e}")))?;
            tokens = doc
                .opt("tokens")
                .and_then(|t| t.as_arr().ok())
                .map_or(0, |a| a.len());
        }
    }
    let server_close = header(&headers, "connection")
        .is_some_and(|v| v.eq_ignore_ascii_case("close"));
    let outcome = ReqOutcome {
        status,
        ok: status == 200 && !dropped,
        rejected: status == 429,
        dropped: dropped || status == 503,
        latency: t0.elapsed(),
        ttft,
        tokens,
    };
    // the framed body is fully consumed, so no read-ahead is lost here
    let reuse = (keep_alive && !server_close).then(|| r.into_inner());
    Ok((outcome, reuse))
}

/// POST one completion request over a fresh `Connection: close`
/// connection, measuring client-side latency and TTFT.
pub fn send_completion(
    addr: &SocketAddr,
    body: &Json,
    timeout: Duration,
) -> Result<ReqOutcome> {
    let t0 = Instant::now();
    let stream = TcpStream::connect_timeout(addr, timeout)?;
    exchange(stream, body, timeout, false, t0).map(|(o, _)| o)
}

/// A keep-alive connection pool shared by loadgen worker threads:
/// completed exchanges return their connection for the next request to
/// reuse, amortizing connect cost the way a production client would.
pub struct ConnPool {
    addr: SocketAddr,
    idle: Mutex<Vec<TcpStream>>,
}

impl ConnPool {
    pub fn new(addr: SocketAddr) -> Self {
        ConnPool { addr, idle: Mutex::new(Vec::new()) }
    }

    /// Connections currently idle in the pool.
    pub fn idle_count(&self) -> usize {
        self.idle.lock().unwrap().len()
    }

    /// True when a pooled connection is still usable.  A server that
    /// idle-closed the connection yields an immediate EOF on a
    /// non-blocking peek (stray unread bytes also disqualify it);
    /// probing *before* any request bytes are written means a stale
    /// connection costs one reconnect and never a re-sent request — a
    /// request is sent at most once, so a failure mid-exchange can
    /// never double-execute server-side and skew the measured load.
    fn connection_alive(stream: &TcpStream) -> bool {
        if stream.set_nonblocking(true).is_err() {
            return false;
        }
        let mut probe = [0u8; 1];
        let alive = match stream.peek(&mut probe) {
            // Ok(0) is EOF; Ok(1) is protocol garbage — both unusable
            Ok(_) => false,
            Err(e) => e.kind() == std::io::ErrorKind::WouldBlock,
        };
        let _ = stream.set_nonblocking(false);
        alive
    }

    /// Send one completion request, preferring a pooled connection
    /// (discarding it up front if the server idle-closed it).  The
    /// request goes over the wire exactly once; exchange failures are
    /// returned, never retried.
    pub fn send(
        &self,
        body: &Json,
        timeout: Duration,
    ) -> Result<ReqOutcome> {
        let t0 = Instant::now();
        let pooled = self.idle.lock().unwrap().pop();
        let stream = match pooled {
            Some(s) if Self::connection_alive(&s) => s,
            // stale (or empty pool): fresh connection
            _ => TcpStream::connect_timeout(&self.addr, timeout)?,
        };
        let (outcome, reuse) = exchange(stream, body, timeout, true, t0)?;
        if let Some(s) = reuse {
            self.idle.lock().unwrap().push(s);
        }
        Ok(outcome)
    }
}

/// Prompt-length buckets for the per-bucket TTFT report rows:
/// power-of-two edges (the last bucket is open-ended).
const PROMPT_BUCKETS: [(&str, usize); 9] = [
    ("1-8", 8),
    ("9-16", 16),
    ("17-32", 32),
    ("33-64", 64),
    ("65-128", 128),
    ("129-256", 256),
    ("257-512", 512),
    ("513-1024", 1024),
    (">1024", usize::MAX),
];

fn prompt_bucket_idx(len: usize) -> usize {
    PROMPT_BUCKETS
        .iter()
        .position(|&(_, hi)| len <= hi)
        .unwrap_or(PROMPT_BUCKETS.len() - 1)
}

/// Fetch and parse `GET /metrics`.
pub fn fetch_metrics(addr: &SocketAddr) -> Result<Json> {
    let stream =
        TcpStream::connect_timeout(addr, Duration::from_secs(5))?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut writer = stream.try_clone()?;
    writer.write_all(
        format!(
            "GET /metrics HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n"
        )
        .as_bytes(),
    )?;
    let mut r = BufReader::new(stream);
    let (status, headers) = read_head(&mut r)?;
    if status != 200 {
        return Err(Error::Serving(format!("/metrics answered {status}")));
    }
    let len: usize = header(&headers, "content-length")
        .and_then(|v| v.parse().ok())
        .ok_or_else(|| Error::Serving("missing content-length".into()))?;
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Json::parse(
        std::str::from_utf8(&buf)
            .map_err(|_| Error::Serving("non-utf8 metrics".into()))?,
    )
    .map_err(Error::from)
}

/// Fetch the Prometheus text exposition (`GET /metrics?format=prom`).
/// Returns the raw body so callers can parse/assert exposition shape
/// (the CI smoke does) or hand it to an actual scraper.
pub fn fetch_metrics_prom(addr: &SocketAddr) -> Result<String> {
    let stream =
        TcpStream::connect_timeout(addr, Duration::from_secs(5))?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut writer = stream.try_clone()?;
    writer.write_all(
        format!(
            "GET /metrics?format=prom HTTP/1.1\r\nHost: {addr}\r\n\
             Connection: close\r\n\r\n"
        )
        .as_bytes(),
    )?;
    let mut r = BufReader::new(stream);
    let (status, headers) = read_head(&mut r)?;
    if status != 200 {
        return Err(Error::Serving(format!(
            "/metrics?format=prom answered {status}"
        )));
    }
    let ctype = header(&headers, "content-type").unwrap_or("");
    if !ctype.starts_with("text/plain") {
        return Err(Error::Serving(format!(
            "prom exposition content-type {ctype:?}"
        )));
    }
    let len: usize = header(&headers, "content-length")
        .and_then(|v| v.parse().ok())
        .ok_or_else(|| Error::Serving("missing content-length".into()))?;
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    String::from_utf8(buf)
        .map_err(|_| Error::Serving("non-utf8 prom exposition".into()))
}

/// Plain `GET <path>` returning (status, body) without judging the
/// status — trace lookups legitimately 404 for evicted ids.
pub fn fetch_path(
    addr: &SocketAddr,
    path: &str,
) -> Result<(u16, String)> {
    let stream =
        TcpStream::connect_timeout(addr, Duration::from_secs(5))?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut writer = stream.try_clone()?;
    writer.write_all(
        format!(
            "GET {path} HTTP/1.1\r\nHost: {addr}\r\n\
             Connection: close\r\n\r\n"
        )
        .as_bytes(),
    )?;
    let mut r = BufReader::new(stream);
    let (status, headers) = read_head(&mut r)?;
    let len: usize = header(&headers, "content-length")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    let body = String::from_utf8(buf)
        .map_err(|_| Error::Serving("non-utf8 response body".into()))?;
    Ok((status, body))
}

/// Flatten the server's `experts` / `stages` metrics sections into
/// top-level bench-row columns, so BENCH_serve.json diffs surface
/// routing collapse or stage-latency regressions without digging
/// through the embedded `server_metrics` document.
fn telemetry_columns(server_metrics: &Json) -> Vec<(&'static str, Json)> {
    let mut cols = Vec::new();
    if let Some(layers) = server_metrics
        .opt("experts")
        .and_then(|e| e.opt("fleet"))
        .and_then(|f| f.opt("layers"))
        .and_then(|l| l.as_arr().ok())
        .filter(|l| !l.is_empty())
    {
        let get = |row: &Json, key: &str| {
            row.opt(key).and_then(|v| v.as_f64().ok()).unwrap_or(0.0)
        };
        let selections: f64 =
            layers.iter().map(|r| get(r, "tokens_k")).sum();
        let imbalance = layers
            .iter()
            .map(|r| get(r, "imbalance"))
            .fold(0.0f64, f64::max);
        let entropy = layers
            .iter()
            .map(|r| get(r, "entropy"))
            .fold(f64::INFINITY, f64::min);
        let dead: f64 = layers.iter().map(|r| get(r, "dead_experts")).sum();
        cols.push(("expert_selections", json::num(selections)));
        cols.push(("expert_imbalance_max", json::num(imbalance)));
        cols.push((
            "expert_entropy_min",
            json::num(if entropy.is_finite() { entropy } else { 0.0 }),
        ));
        cols.push(("expert_dead", json::num(dead)));
    }
    if let Some(stages) = server_metrics.opt("stages") {
        for (col, section) in [
            ("server_queue_wait_p99_ms", "queue_wait"),
            ("server_ttft_p99_ms", "ttft"),
            ("server_inter_token_p99_ms", "inter_token"),
        ] {
            if let Some(v) = stages
                .opt(section)
                .and_then(|h| h.opt("p99_ms"))
                .and_then(|v| v.as_f64().ok())
            {
                cols.push((col, json::num(v)));
            }
        }
    }
    cols
}

/// Execute the open-loop plan against a live server; returns one
/// `BENCH_serve.json` result row.
pub fn run(addr: SocketAddr, cfg: &LoadgenCfg, mode: &str) -> Result<Json> {
    let planned = plan(cfg);
    let n = planned.len();
    let predicted = if cfg.prefix_cache.is_some() {
        predict_cache_hits(&planned, cfg.prefill_chunk)
    } else {
        vec![false; n]
    };
    let (tx, rx) = mpsc::channel();
    let pool = cfg.keep_alive.then(|| Arc::new(ConnPool::new(addr)));
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(n);
    // pacing loop: the plan is sorted by arrival time, so spawning each
    // request's thread at its arrival instant keeps live threads
    // bounded by in-flight requests — a 10k-request run must not stand
    // up a 10k-thread fleet at t=0 and perturb the latencies it measures
    for (p, hit) in planned.into_iter().zip(predicted) {
        let elapsed = t0.elapsed();
        if p.at > elapsed {
            std::thread::sleep(p.at - elapsed);
        }
        let tx = tx.clone();
        let body = completion_body(&p, cfg);
        let plen = p.prompt.len();
        let timeout = cfg.timeout;
        let pool = pool.clone();
        handles.push(std::thread::spawn(move || {
            let res = match &pool {
                Some(pool) => pool.send(&body, timeout),
                None => send_completion(&addr, &body, timeout),
            };
            let _ = tx.send((plen, hit, res));
        }));
    }
    drop(tx);
    let mut latency = Histogram::new();
    let mut ttft = Histogram::new();
    // TTFT per prompt-length bucket: where the chunked-prefill win
    // shows up (long prompts), instead of hiding in the aggregate p95
    let mut bucket_ttft: Vec<Histogram> =
        (0..PROMPT_BUCKETS.len()).map(|_| Histogram::new()).collect();
    // cache-armed runs additionally split TTFT by the client-side hit
    // prediction, so the BENCH row shows the warm-vs-cold gap directly
    let (mut hit_ttft, mut miss_ttft) = (Histogram::new(), Histogram::new());
    let mut predicted_hits = 0u64;
    let (mut ok, mut rejected, mut dropped, mut errors) = (0u64, 0u64, 0u64, 0u64);
    let mut tokens = 0usize;
    for (plen, hit, outcome) in rx {
        predicted_hits += hit as u64;
        match outcome {
            Ok(o) => {
                tokens += o.tokens;
                if o.rejected {
                    rejected += 1;
                } else if o.dropped {
                    dropped += 1;
                } else if o.ok {
                    ok += 1;
                    // latency percentiles cover *completions* only —
                    // folding in sub-ms 429s/drops would dilute p50/p99
                    // exactly under the oversubscription this measures
                    // (rejections are already counted in rejected_429)
                    latency.observe(o.latency);
                    if let Some(t) = o.ttft {
                        ttft.observe(t);
                        bucket_ttft[prompt_bucket_idx(plen)].observe(t);
                        if hit {
                            hit_ttft.observe(t);
                        } else {
                            miss_ttft.observe(t);
                        }
                    }
                } else {
                    errors += 1;
                }
            }
            Err(_) => errors += 1,
        }
    }
    for h in handles {
        let _ = h.join();
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let server_metrics = fetch_metrics(&addr).unwrap_or(Json::Null);
    let ttft_rows: Vec<Json> = PROMPT_BUCKETS
        .iter()
        .zip(&bucket_ttft)
        .filter(|(_, h)| h.count() > 0)
        .map(|(&(label, _), h)| {
            json::obj(vec![
                ("prompt_len", json::s(label)),
                ("ttft", h.to_json()),
            ])
        })
        .collect();
    let mut fields = vec![
        ("mode", json::s(mode)),
        ("requests", json::num(n as f64)),
        ("target_rps", json::num(cfg.rps)),
        ("achieved_rps", json::num(n as f64 / wall)),
        ("stream_fraction", json::num(cfg.stream_fraction)),
        ("prompt_dist", json::s(cfg.prompt_dist.as_str())),
        ("ok", json::num(ok as f64)),
        ("rejected_429", json::num(rejected as f64)),
        ("dropped", json::num(dropped as f64)),
        ("errors", json::num(errors as f64)),
        ("tokens_total", json::num(tokens as f64)),
        ("tokens_per_sec", json::num(tokens as f64 / wall)),
        ("wall_s", json::num(wall)),
        ("keep_alive", Json::Bool(cfg.keep_alive)),
        ("latency", latency.to_json()),
        ("ttft", ttft.to_json()),
        ("ttft_by_prompt_len", json::arr(ttft_rows)),
    ];
    if let Some(budget) = cfg.prefix_cache {
        fields.push(("prefix_cache_budget_bytes", json::num(budget as f64)));
        fields.push((
            "prefix_cache_predicted_hit_rate",
            json::num(predicted_hits as f64 / (n as f64).max(1.0)),
        ));
        // authoritative rate + per-prompt-length buckets come from the
        // server's shared cache, not the client-side prediction
        let cache = server_metrics.opt("prefix_cache");
        let rate = cache
            .and_then(|c| c.opt("hit_rate"))
            .and_then(|v| v.as_f64().ok())
            .unwrap_or(0.0);
        fields.push(("prefix_cache_hit_rate", json::num(rate)));
        if let Some(buckets) = cache.and_then(|c| c.opt("buckets")) {
            fields
                .push(("prefix_cache_by_prompt_len", buckets.clone()));
        }
        fields.push(("ttft_cache_hit", hit_ttft.to_json()));
        fields.push(("ttft_cache_miss", miss_ttft.to_json()));
    }
    fields.extend(telemetry_columns(&server_metrics));
    fields.push(("server_metrics", server_metrics));
    Ok(json::obj(fields))
}

/// Run `f` against an in-process HTTP server over the device-free
/// [`MockBackend`] (bound to an ephemeral localhost port), shutting the
/// server down afterwards.  `cfg.prefill_chunk` configures both the
/// scheduler's prompt costing and the mock backend's chunked prompt
/// ingestion.  Used by the serving tests and the `serve_load` bench;
/// `loadgen --dry-run` goes through [`with_mock_fleet`] instead so its
/// rows always include the router.
pub fn with_mock_server<T>(
    lanes: usize,
    vocab: usize,
    step_delay: Duration,
    cfg: ServerConfig,
    f: impl FnOnce(SocketAddr) -> Result<T>,
) -> Result<T> {
    let listener = TcpListener::bind(("127.0.0.1", 0))?;
    let addr = listener.local_addr()?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let server_shutdown = shutdown.clone();
    let chunk = cfg.prefill_chunk;
    let speculate = cfg.speculate;
    let handle = std::thread::spawn(move || {
        server::serve(listener, cfg, server_shutdown, move |driver| {
            let mut backend = MockBackend::new(lanes, vocab)
                .with_step_delay(step_delay)
                .with_prefill_chunk(chunk)
                .with_speculate(speculate);
            driver.drive(&mut backend)
        })
    });
    let result = f(addr);
    shutdown.store(true, Ordering::SeqCst);
    match handle.join() {
        Ok(Ok(())) => result,
        Ok(Err(e)) => result.and(Err(e)),
        Err(_) => result.and(Err(Error::Serving(
            "mock server thread panicked".into(),
        ))),
    }
}

/// Run `f` against an in-process HTTP *fleet* frontend: `rcfg.engines`
/// driver threads, each with its own device-free [`MockBackend`]
/// (`lanes` lanes, `step_delay` per pump), behind the multi-engine
/// router.  `faults[i]` optionally poisons engine `i`; stalled engines
/// are released at shutdown so every thread joins.  Used by
/// `loadgen --dry-run --engines N`, the router tests, and the
/// mock-fleet scaling rows in BENCH_serve.json.
pub fn with_mock_fleet<T>(
    lanes: usize,
    vocab: usize,
    step_delay: Duration,
    cfg: ServerConfig,
    rcfg: RouterCfg,
    faults: &[Option<MockFault>],
    f: impl FnOnce(SocketAddr) -> Result<T>,
) -> Result<T> {
    let engines = rcfg.engines.max(1);
    let listener = TcpListener::bind(("127.0.0.1", 0))?;
    let addr = listener.local_addr()?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let stall_release = Arc::new(AtomicBool::new(false));
    let server_shutdown = shutdown.clone();
    let rcfg = RouterCfg { engines, ..rcfg };
    let faults: Vec<Option<MockFault>> = (0..engines)
        .map(|i| faults.get(i).cloned().flatten())
        .collect();
    let release = stall_release.clone();
    let chunk = cfg.prefill_chunk;
    let speculate = cfg.speculate;
    let handle = std::thread::spawn(move || {
        router::serve_fleet(
            listener,
            cfg,
            rcfg,
            server_shutdown,
            move |id, fleet| {
                let mut backend = MockBackend::new(lanes, vocab)
                    .with_step_delay(step_delay)
                    .with_prefill_chunk(chunk)
                    .with_speculate(speculate)
                    .with_stall_release(release.clone());
                if let Some(fault) = faults[id].clone() {
                    backend = backend.with_fault(fault);
                }
                fleet.run_engine(id, &mut backend)
            },
        )
    });
    let result = f(addr);
    shutdown.store(true, Ordering::SeqCst);
    // unwedge any StallAfter engine so its driver thread can join
    stall_release.store(true, Ordering::SeqCst);
    match handle.join() {
        Ok(Ok(())) => result,
        Ok(Err(e)) => result.and(Err(e)),
        Err(_) => result.and(Err(Error::Serving(
            "mock fleet server thread panicked".into(),
        ))),
    }
}

/// Per-pump latency of the dry-run mock engines: large enough that the
/// engine, not the HTTP/scheduler layers, is the throughput bound —
/// which is what makes the 1→2→4-engine scaling rows meaningful.
pub const DRY_RUN_STEP_DELAY: Duration = Duration::from_micros(200);

/// The `loadgen --dry-run` path: full client/server/scheduler stack
/// over `engines` mock engine(s); returns the report row.  Every row —
/// including `engines == 1` — goes through the multi-engine router, so
/// a 1→2→4 sweep compares identical stacks and the reported scaling
/// factor is router scaling, not router-overhead-vs-no-router.
pub fn dry_run(
    cfg: &LoadgenCfg,
    lanes: usize,
    engines: usize,
) -> Result<Json> {
    dry_run_with_prom(cfg, lanes, engines).map(|(row, _)| row)
}

/// [`dry_run`] plus a validated Prometheus scrape of the mock fleet's
/// `/metrics?format=prom` taken after the plan completes.  The scrape
/// is checked with [`telemetry::validate_prom`] — when telemetry is on,
/// the stage and expert families must be present *and populated*, so a
/// device-free CI run proves the whole exposition path end to end.
pub fn dry_run_with_prom(
    cfg: &LoadgenCfg,
    lanes: usize,
    engines: usize,
) -> Result<(Json, String)> {
    let server_cfg = ServerConfig {
        vocab: Some(cfg.vocab),
        prefill_chunk: cfg.prefill_chunk.max(1),
        telemetry: cfg.telemetry,
        speculate: cfg.speculate,
        prefix_cache: cfg.prefix_cache,
        ..Default::default()
    };
    let engines = engines.max(1);
    let (mut row, prom) = with_mock_fleet(
        lanes,
        cfg.vocab,
        DRY_RUN_STEP_DELAY,
        server_cfg,
        RouterCfg { engines, ..Default::default() },
        &[],
        |addr| {
            let row = run(addr, cfg, "mock-dry-run")?;
            // speculation only counts once a decode round actually
            // verifies drafts (chunk 1 silently disables it), so the
            // exposition check requires the spec_* families exactly
            // when the mock fleet can speculate
            let speculating =
                cfg.speculate > 0 && cfg.prefill_chunk.max(1) > 1;
            let mut require: Vec<&str> = Vec::new();
            if cfg.telemetry {
                require.push("sigma_moe_stage_");
                require.push("sigma_moe_experts_");
                if speculating {
                    require.push("sigma_moe_engine_spec_");
                }
            }
            if cfg.prefix_cache.is_some() {
                // armed runs must expose both the per-engine counters
                // and the shared-cache document section
                require.push("sigma_moe_engine_prefix_cache_");
                require.push("sigma_moe_prefix_cache_");
            }
            let require = require.as_slice();
            // expert counts drain on the drivers' publish cadence, so
            // the scrape may land just before the final drain — retry
            // briefly rather than flake
            let mut prom = fetch_metrics_prom(&addr)?;
            let mut verdict = telemetry::validate_prom(&prom, require);
            for _ in 0..40 {
                if verdict.is_ok() {
                    break;
                }
                std::thread::sleep(Duration::from_millis(50));
                prom = fetch_metrics_prom(&addr)?;
                verdict = telemetry::validate_prom(&prom, require);
            }
            verdict?;
            Ok((row, prom))
        },
    )?;
    if let Json::Obj(m) = &mut row {
        m.insert("engines".into(), json::num(engines as f64));
        m.insert(
            "prefill_chunk".into(),
            json::num(cfg.prefill_chunk.max(1) as f64),
        );
        m.insert("telemetry".into(), Json::Bool(cfg.telemetry));
        m.insert("speculate".into(), json::num(cfg.speculate as f64));
        m.insert(
            "prefix_cache".into(),
            json::num(cfg.prefix_cache.unwrap_or(0) as f64),
        );
    }
    Ok((row, prom))
}

/// The telemetry A/B pair: the same dry-run plan with telemetry on and
/// off, plus the relative throughput cost.  Always-on observability is
/// only "always-on" if this stays small; the row makes the price a
/// tracked number instead of folklore.
pub fn dry_run_telemetry_ab(
    cfg: &LoadgenCfg,
    lanes: usize,
    engines: usize,
) -> Result<Json> {
    let on = dry_run(&LoadgenCfg { telemetry: true, ..cfg.clone() }, lanes, engines)?;
    let off = dry_run(&LoadgenCfg { telemetry: false, ..cfg.clone() }, lanes, engines)?;
    let tps = |row: &Json| {
        row.opt("tokens_per_sec")
            .and_then(|v| v.as_f64().ok())
            .unwrap_or(0.0)
    };
    let (t_on, t_off) = (tps(&on), tps(&off));
    let overhead = if t_off > 0.0 { 1.0 - t_on / t_off } else { 0.0 };
    Ok(json::obj(vec![
        ("mode", json::s("mock-dry-run-telemetry-ab")),
        ("engines", json::num(engines.max(1) as f64)),
        ("tokens_per_sec_on", json::num(t_on)),
        ("tokens_per_sec_off", json::num(t_off)),
        ("telemetry_overhead_frac", json::num(overhead)),
        ("on", on),
        ("off", off),
    ]))
}

/// Per-pump latency of the degrade-A/B mock engines at full expert-k:
/// 4x the normal dry-run delay, so the same Poisson plan that the
/// normal rows absorb becomes an *overload* here — the queue builds,
/// the degrade watermark trips, and the k-vs-p99 comparison measures
/// the policy under the pressure it exists for.  The mock scales its
/// step delay by `k_eff / MOCK_TOP_K`, mirroring the real engine's
/// expert-FLOPs reduction at lower k.
pub const DEGRADE_AB_STEP_DELAY: Duration = Duration::from_micros(800);

/// One overloaded dry-run leg of the degrade A/B (`degrade = None` is
/// the fixed-k baseline).
fn dry_run_overloaded(
    cfg: &LoadgenCfg,
    lanes: usize,
    engines: usize,
    degrade: Option<DegradeCfg>,
    mode: &str,
) -> Result<Json> {
    let server_cfg = ServerConfig {
        vocab: Some(cfg.vocab),
        prefill_chunk: cfg.prefill_chunk.max(1),
        telemetry: cfg.telemetry,
        expert_k_max: Some(MOCK_TOP_K),
        degrade_k: degrade,
        ..Default::default()
    };
    let engines = engines.max(1);
    let mut row = with_mock_fleet(
        lanes,
        cfg.vocab,
        DEGRADE_AB_STEP_DELAY,
        server_cfg,
        RouterCfg { engines, ..Default::default() },
        &[],
        |addr| run(addr, cfg, mode),
    )?;
    if let Json::Obj(m) = &mut row {
        m.insert("engines".into(), json::num(engines as f64));
    }
    Ok(row)
}

/// The adaptive expert-k A/B pair: the same overloaded dry-run plan
/// with expert top-k pinned at the ceiling vs degraded under queue
/// pressure (`min_k = 1`, watermarks 4:1), plus the p99 comparison and
/// the degraded leg's k-transition counters pulled from the scheduler
/// metrics.  The row makes the quality-for-latency trade a tracked
/// number: how much tail latency the floor k buys back when the queue
/// is shedding work.
pub fn dry_run_degrade_ab(
    cfg: &LoadgenCfg,
    lanes: usize,
    engines: usize,
) -> Result<Json> {
    let degrade = DegradeCfg { min_k: 1, hi_wm: 4, lo_wm: 1 };
    let full = dry_run_overloaded(
        cfg,
        lanes,
        engines,
        None,
        "mock-dry-run-degrade-off",
    )?;
    let degraded = dry_run_overloaded(
        cfg,
        lanes,
        engines,
        Some(degrade),
        "mock-dry-run-degrade-on",
    )?;
    let p99 = |row: &Json| {
        row.opt("latency")
            .and_then(|l| l.opt("p99_ms"))
            .and_then(|v| v.as_f64().ok())
            .unwrap_or(0.0)
    };
    let sched_gauge = |row: &Json, key: &str| {
        row.opt("server_metrics")
            .and_then(|m| m.opt("scheduler"))
            .and_then(|s| s.opt(key))
            .and_then(|v| v.as_f64().ok())
            .unwrap_or(0.0)
    };
    let (p_full, p_deg) = (p99(&full), p99(&degraded));
    let speedup = if p_deg > 0.0 { p_full / p_deg } else { 0.0 };
    Ok(json::obj(vec![
        ("mode", json::s("mock-dry-run-degrade-ab")),
        ("engines", json::num(engines.max(1) as f64)),
        ("expert_k_max", json::num(MOCK_TOP_K as f64)),
        ("min_k", json::num(degrade.min_k as f64)),
        ("p99_ms_full_k", json::num(p_full)),
        ("p99_ms_degraded", json::num(p_deg)),
        ("p99_speedup", json::num(speedup)),
        (
            "k_degrades",
            json::num(sched_gauge(&degraded, "expert_k_degrades")),
        ),
        (
            "k_restores",
            json::num(sched_gauge(&degraded, "expert_k_restores")),
        ),
        (
            "expert_k_final",
            json::num(sched_gauge(&degraded, "expert_k_current")),
        ),
        ("full_k", full),
        ("degraded", degraded),
    ]))
}

/// The speculative-decode A/B pair: the same dry-run plan with
/// speculation off vs drafting K tokens per verify round, on the
/// repetitive workload the drafter exists for — a tiny vocabulary
/// makes the mock's deterministic stream periodic (step 7 mod vocab),
/// so prompt-lookup drafting locks on once a lane has seen one period.
/// The row carries the throughput ratio plus the speculative counters
/// (accept rate, rollbacks, and the accepted-length histogram) pulled
/// from the fleet's summed engine stats, making the speedup-vs-accept
/// trade a tracked number.
pub fn dry_run_speculate_ab(
    cfg: &LoadgenCfg,
    lanes: usize,
    engines: usize,
) -> Result<Json> {
    let k = cfg.speculate.max(1);
    // repetitive decode-heavy mix: short prompts, long generations,
    // vocab 10 (period 10), chunk wide enough for 1 + K verify rows
    let leg = |speculate: usize| LoadgenCfg {
        vocab: 10,
        prompt_len: (3, 6),
        max_new: (48, 64),
        prefill_chunk: cfg.prefill_chunk.max(k + 1),
        speculate,
        ..cfg.clone()
    };
    let off = dry_run(&leg(0), lanes, engines)?;
    let on = dry_run(&leg(k), lanes, engines)?;
    let tps = |row: &Json| {
        row.opt("tokens_per_sec")
            .and_then(|v| v.as_f64().ok())
            .unwrap_or(0.0)
    };
    let engine_total = |row: &Json, key: &str| {
        row.opt("server_metrics")
            .and_then(|m| m.opt("engine"))
            .and_then(|e| e.opt(key))
            .and_then(|v| v.as_f64().ok())
            .unwrap_or(0.0)
    };
    let (t_off, t_on) = (tps(&off), tps(&on));
    let speedup = if t_off > 0.0 { t_on / t_off } else { 0.0 };
    let drafted = engine_total(&on, "spec_drafted");
    let accepted = engine_total(&on, "spec_accepted");
    let accept_rate = if drafted > 0.0 { accepted / drafted } else { 0.0 };
    let accept_hist: Vec<Json> = (0..=k)
        .map(|n| engine_total(&on, &format!("spec_hist_{n}")))
        .map(json::num)
        .collect();
    Ok(json::obj(vec![
        ("mode", json::s("mock-dry-run-speculate-ab")),
        ("engines", json::num(engines.max(1) as f64)),
        ("speculate", json::num(k as f64)),
        ("tokens_per_sec_off", json::num(t_off)),
        ("tokens_per_sec_on", json::num(t_on)),
        ("speculate_speedup", json::num(speedup)),
        ("spec_rounds", json::num(engine_total(&on, "spec_rounds"))),
        ("spec_drafted", json::num(drafted)),
        ("spec_accepted", json::num(accepted)),
        ("spec_accept_rate", json::num(accept_rate)),
        (
            "spec_rollbacks",
            json::num(engine_total(&on, "spec_rollbacks")),
        ),
        ("spec_accept_hist", json::arr(accept_hist)),
        ("off", off),
        ("on", on),
    ]))
}

/// The prefix-cache A/B pair: the same `shared-prefix` dry-run plan
/// with the cache disarmed (cold prefill for every request) vs armed
/// with `cfg.prefix_cache` bytes.  The workload is prompt-heavy —
/// long shared prefixes, short generations — so the warm leg's saved
/// prefill dispatches show up in tokens/sec and the TTFT hit/miss
/// split, and the row carries the server-side hit rate and
/// tokens-saved counters that make the win a tracked number.
pub fn dry_run_prefix_ab(
    cfg: &LoadgenCfg,
    lanes: usize,
    engines: usize,
) -> Result<Json> {
    let budget = cfg.prefix_cache.unwrap_or(8 << 20);
    // prompt-heavy shared-prefix mix: prompts long enough that several
    // chunk boundaries fall inside the common prefix, generations short
    // enough that prefill dominates the wall clock
    let leg = |prefix_cache: Option<u64>| LoadgenCfg {
        prompt_dist: PromptDist::SharedPrefix,
        prompt_len: (cfg.prompt_len.0.max(24), cfg.prompt_len.1.max(48)),
        max_new: (4, 8),
        prefill_chunk: cfg.prefill_chunk.clamp(4, 8),
        prefix_cache,
        ..cfg.clone()
    };
    let cold = dry_run(&leg(None), lanes, engines)?;
    let warm = dry_run(&leg(Some(budget)), lanes, engines)?;
    let tps = |row: &Json| {
        row.opt("tokens_per_sec")
            .and_then(|v| v.as_f64().ok())
            .unwrap_or(0.0)
    };
    let engine_total = |row: &Json, key: &str| {
        row.opt("server_metrics")
            .and_then(|m| m.opt("engine"))
            .and_then(|e| e.opt(key))
            .and_then(|v| v.as_f64().ok())
            .unwrap_or(0.0)
    };
    let col = |row: &Json, key: &str| {
        row.opt(key).and_then(|v| v.as_f64().ok()).unwrap_or(0.0)
    };
    let ttft_p50 = |row: &Json, key: &str| {
        row.opt(key)
            .and_then(|h| h.opt("p50_ms"))
            .and_then(|v| v.as_f64().ok())
            .unwrap_or(0.0)
    };
    let (t_cold, t_warm) = (tps(&cold), tps(&warm));
    let speedup = if t_cold > 0.0 { t_warm / t_cold } else { 0.0 };
    Ok(json::obj(vec![
        ("mode", json::s("mock-dry-run-prefix-ab")),
        ("engines", json::num(engines.max(1) as f64)),
        ("prefix_cache_budget_bytes", json::num(budget as f64)),
        ("tokens_per_sec_cold", json::num(t_cold)),
        ("tokens_per_sec_warm", json::num(t_warm)),
        ("prefix_cache_speedup", json::num(speedup)),
        (
            "prefix_cache_hit_rate",
            json::num(col(&warm, "prefix_cache_hit_rate")),
        ),
        (
            "prefix_cache_hits",
            json::num(engine_total(&warm, "prefix_cache_hits")),
        ),
        (
            "prefix_cache_misses",
            json::num(engine_total(&warm, "prefix_cache_misses")),
        ),
        (
            "prefix_cache_tokens_saved",
            json::num(engine_total(&warm, "prefix_cache_tokens_saved")),
        ),
        (
            "ttft_p50_ms_hit",
            json::num(ttft_p50(&warm, "ttft_cache_hit")),
        ),
        (
            "ttft_p50_ms_miss",
            json::num(ttft_p50(&warm, "ttft_cache_miss")),
        ),
        ("cold", cold),
        ("warm", warm),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn cfg() -> LoadgenCfg {
        LoadgenCfg { requests: 16, seed: 9, ..Default::default() }
    }

    #[test]
    fn plan_is_deterministic_and_monotonic() {
        let a = plan(&cfg());
        let b = plan(&cfg());
        assert_eq!(a.len(), 16);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new, y.max_new);
            assert_eq!(x.stream, y.stream);
        }
        for w in a.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        let c = plan(&LoadgenCfg { seed: 10, ..cfg() });
        assert!(a.iter().zip(&c).any(|(x, y)| x.prompt != y.prompt));
    }

    #[test]
    fn plan_respects_ranges() {
        let cfg = LoadgenCfg {
            requests: 64,
            prompt_len: (3, 5),
            max_new: (7, 7),
            vocab: 11,
            ..Default::default()
        };
        for p in plan(&cfg) {
            assert!((3..=5).contains(&p.prompt.len()));
            assert_eq!(p.max_new, 7);
            assert!(p.prompt.iter().all(|&t| (0..11).contains(&t)));
        }
    }

    #[test]
    fn poisson_mean_interarrival_tracks_rate() {
        let cfg = LoadgenCfg {
            requests: 2000,
            rps: 50.0,
            ..Default::default()
        };
        let p = plan(&cfg);
        let total = p.last().unwrap().at.as_secs_f64();
        let mean_dt = total / p.len() as f64;
        assert!((mean_dt - 0.02).abs() < 0.004, "mean dt {mean_dt}");
    }

    #[test]
    fn prompt_dist_fixed_and_lognormal_respect_range() {
        let base = LoadgenCfg {
            requests: 256,
            prompt_len: (4, 256),
            seed: 11,
            ..Default::default()
        };
        let fixed = plan(&LoadgenCfg {
            prompt_dist: PromptDist::Fixed,
            ..base.clone()
        });
        assert!(fixed.iter().all(|p| p.prompt.len() == 256));
        let logn = plan(&LoadgenCfg {
            prompt_dist: PromptDist::Lognormal,
            ..base.clone()
        });
        assert!(logn
            .iter()
            .all(|p| (4..=256).contains(&p.prompt.len())));
        // heavy tail: the median sits near the geometric mean (32),
        // far below the arithmetic midpoint (130)
        let mut lens: Vec<usize> =
            logn.iter().map(|p| p.prompt.len()).collect();
        lens.sort_unstable();
        let median = lens[lens.len() / 2];
        assert!(
            (8..=96).contains(&median),
            "lognormal median {median} out of the expected band"
        );
        // and the two shapes genuinely differ
        assert!(lens.iter().any(|&l| l != 256));
    }

    #[test]
    fn prompt_dist_parse_roundtrip() {
        for d in [
            PromptDist::Fixed,
            PromptDist::Uniform,
            PromptDist::Lognormal,
            PromptDist::SharedPrefix,
        ] {
            assert_eq!(PromptDist::parse(d.as_str()).unwrap(), d);
        }
        assert!(PromptDist::parse("zipf").is_err());
    }

    #[test]
    fn shared_prefix_plan_shares_head_and_keeps_unique_tail() {
        let cfg = LoadgenCfg {
            requests: 64,
            prompt_len: (8, 32),
            prompt_dist: PromptDist::SharedPrefix,
            shared_prefix_overlap: 0.5,
            seed: 13,
            ..Default::default()
        };
        let p = plan(&cfg);
        // overlap 0.5 of hi=32 → a 16-token common prefix
        let longest = p.iter().max_by_key(|r| r.prompt.len()).unwrap();
        let shared_len = 16.min(longest.prompt.len() - 1);
        let shared = &longest.prompt[..shared_len];
        for r in &p {
            assert!((8..=32).contains(&r.prompt.len()));
            let keep = shared_len.min(r.prompt.len() - 1);
            assert_eq!(&r.prompt[..keep], &shared[..keep]);
            // at least one slot past the shared head is always drawn
            assert!(r.prompt.len() > keep);
        }
        // tails genuinely differ across requests of equal length
        let same_len: Vec<_> = p
            .iter()
            .filter(|r| r.prompt.len() == longest.prompt.len())
            .collect();
        if same_len.len() >= 2 {
            assert!(same_len.iter().any(
                |r| r.prompt[shared_len..] != same_len[0].prompt[shared_len..]
            ));
        }
        // other dists' RNG streams are untouched by the feature
        let uniform = plan(&LoadgenCfg {
            prompt_dist: PromptDist::Uniform,
            shared_prefix_overlap: 0.9,
            ..cfg.clone()
        });
        let uniform2 = plan(&LoadgenCfg {
            prompt_dist: PromptDist::Uniform,
            shared_prefix_overlap: 0.1,
            ..cfg
        });
        for (a, b) in uniform.iter().zip(&uniform2) {
            assert_eq!(a.prompt, b.prompt);
        }
    }

    #[test]
    fn predicted_hits_mirror_chunk_boundary_probes() {
        let mk = |prompt: Vec<i32>| Planned {
            at: Duration::ZERO,
            prompt,
            max_new: 1,
            stream: false,
        };
        // 12-token shared head, chunk 4: first toucher seeds the
        // boundaries (miss), later requests sharing ≥ one boundary hit
        let head: Vec<i32> = (0..12).collect();
        let planned = vec![
            mk(head.iter().copied().chain([90]).collect()),
            mk(head.iter().copied().chain([91, 92]).collect()),
            mk(head[..4].iter().copied().chain([93]).collect()),
            mk(vec![70, 71, 72]), // too short for any boundary
        ];
        assert_eq!(
            predict_cache_hits(&planned, 4),
            vec![false, true, true, false]
        );
        // a shared prefix shorter than one chunk can never hit
        assert_eq!(
            predict_cache_hits(&planned, 64),
            vec![false, false, false, false]
        );
    }

    #[test]
    fn prompt_buckets_cover_all_lengths_in_order() {
        assert_eq!(prompt_bucket_idx(1), 0);
        assert_eq!(prompt_bucket_idx(8), 0);
        assert_eq!(prompt_bucket_idx(9), 1);
        assert_eq!(prompt_bucket_idx(256), 5);
        assert_eq!(prompt_bucket_idx(257), 6);
        assert_eq!(prompt_bucket_idx(100_000), PROMPT_BUCKETS.len() - 1);
        // monotone: longer prompts never map to an earlier bucket
        let mut last = 0;
        for len in 1..3000 {
            let b = prompt_bucket_idx(len);
            assert!(b >= last);
            last = b;
        }
    }

    #[test]
    fn chunked_decoding_reassembles_and_reports_chunks() {
        let raw = b"5\r\nhello\r\n6\r\n world\r\n0\r\n\r\n";
        let mut seen = Vec::new();
        let body = read_chunked(&mut Cursor::new(&raw[..]), |c| {
            seen.push(c.len());
        })
        .unwrap();
        assert_eq!(body, b"hello world");
        assert_eq!(seen, vec![5, 6]);
    }

    #[test]
    fn chunked_decoding_rejects_garbage() {
        assert!(read_chunked(
            &mut Cursor::new(b"zz\r\nhello\r\n" as &[u8]),
            |_| {}
        )
        .is_err());
        // missing CRLF after chunk data
        assert!(read_chunked(
            &mut Cursor::new(b"5\r\nhelloXX0\r\n\r\n" as &[u8]),
            |_| {}
        )
        .is_err());
    }

    #[test]
    fn completion_body_carries_the_mix() {
        let c = LoadgenCfg {
            greedy: true,
            deadline_ms: Some(500),
            ..Default::default()
        };
        let p = Planned {
            at: Duration::ZERO,
            prompt: vec![1, 2],
            max_new: 9,
            stream: true,
        };
        let b = completion_body(&p, &c);
        assert_eq!(b.get("max_tokens").unwrap().as_usize().unwrap(), 9);
        assert!(b.get("stream").unwrap().as_bool().unwrap());
        assert!(b.get("greedy").unwrap().as_bool().unwrap());
        assert_eq!(
            b.get("deadline_ms").unwrap().as_usize().unwrap(),
            500
        );
        assert_eq!(b.get("prompt").unwrap().as_arr().unwrap().len(), 2);
    }
}
