//! Host-side draft-token sources for speculative decode.
//!
//! A [`Drafter`] proposes likely continuations of a lane's token stream
//! so the engine can verify several tokens through one prefill-shaped
//! dispatch instead of one `step_fwd` per token.  The trait is
//! deliberately model-free: the built-in [`NgramDrafter`] is a per-lane
//! n-gram/prefix cache over the tokens already streamed (prompt-lookup
//! decoding — no second model, no extra artifacts), but a small draft
//! preset can slot in behind the same trait later.
//!
//! Drafting is strictly advisory: a wrong draft costs one wasted verify
//! position, never a wrong output token, because the engine accepts
//! only the prefix the full model agrees with and rolls back the rest.

use std::collections::HashMap;

/// Maximum n-gram order [`NgramDrafter`] matches on (longest suffix
/// tried first; longer matches are better predictors).
pub const NGRAM_MAX: usize = 3;

/// A source of speculative continuation tokens, keyed by engine lane.
pub trait Drafter: Send {
    /// Forget everything about `lane` (a new request occupies it).
    fn reset(&mut self, lane: usize);
    /// Record `token` as the next token of `lane`'s stream — prompt
    /// tokens at admission, then every emitted continuation token, in
    /// order.
    fn observe(&mut self, lane: usize, token: i32);
    /// Propose up to `max` continuation tokens for `lane`.  Empty means
    /// the drafter is cold (no basis to speculate) and the caller must
    /// fall back to plain single-token decode for this lane.
    fn draft(&self, lane: usize, max: usize) -> Vec<i32>;
}

/// Per-lane history plus a bigram → positions index (the "prefix
/// cache"), maintained incrementally by [`Drafter::observe`].
#[derive(Debug, Default)]
struct LaneHistory {
    toks: Vec<i32>,
    /// positions `p` such that `toks[p-1..=p]` is the keyed bigram —
    /// most recent last, so suffix lookup is O(1) amortized
    bigrams: HashMap<(i32, i32), Vec<usize>>,
}

impl LaneHistory {
    fn push(&mut self, token: i32) {
        if let Some(&prev) = self.toks.last() {
            self.bigrams
                .entry((prev, token))
                .or_default()
                .push(self.toks.len());
        }
        self.toks.push(token);
    }

    /// Prompt-lookup: find the most recent earlier occurrence of the
    /// longest (≤ [`NGRAM_MAX`]) suffix of the history and propose the
    /// tokens that followed it.  Candidate positions come from the
    /// bigram index; longer suffixes only re-rank among those, so the
    /// scan stays proportional to the match count, not the history.
    fn draft(&self, max: usize) -> Vec<i32> {
        let n = self.toks.len();
        if n < 2 || max == 0 {
            return Vec::new();
        }
        let key = (self.toks[n - 2], self.toks[n - 1]);
        let Some(positions) = self.bigrams.get(&key) else {
            return Vec::new();
        };
        // candidates are end positions `p < n-1` of earlier occurrences
        // (the last entry is the history suffix itself); prefer the
        // longest suffix agreement, then recency
        let mut best: Option<(usize, usize)> = None; // (match_len, pos)
        for &p in positions.iter().rev() {
            if p + 1 >= n {
                continue;
            }
            let mut len = 2;
            while len < NGRAM_MAX
                && len <= p
                && n >= len + 1
                && self.toks[p - len] == self.toks[n - 2 - len + 1]
            {
                len += 1;
            }
            match best {
                Some((bl, _)) if bl >= len => {}
                _ => best = Some((len, p)),
            }
            if best.is_some_and(|(bl, _)| bl >= NGRAM_MAX) {
                break;
            }
        }
        let Some((_, p)) = best else {
            return Vec::new();
        };
        let start = p + 1;
        let end = (start + max).min(n);
        self.toks[start..end].to_vec()
    }
}

/// The built-in prompt-lookup drafter: proposes the continuation that
/// followed the most recent earlier occurrence of the stream's current
/// suffix.  Cold (returns no draft) until the suffix has repeated —
/// exactly when speculation can't pay for itself anyway.
#[derive(Debug, Default)]
pub struct NgramDrafter {
    lanes: HashMap<usize, LaneHistory>,
}

impl NgramDrafter {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Drafter for NgramDrafter {
    fn reset(&mut self, lane: usize) {
        self.lanes.remove(&lane);
    }

    fn observe(&mut self, lane: usize, token: i32) {
        self.lanes.entry(lane).or_default().push(token);
    }

    fn draft(&self, lane: usize, max: usize) -> Vec<i32> {
        self.lanes
            .get(&lane)
            .map(|h| h.draft(max))
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seeded(tokens: &[i32]) -> NgramDrafter {
        let mut d = NgramDrafter::new();
        for &t in tokens {
            d.observe(0, t);
        }
        d
    }

    #[test]
    fn cold_lane_or_unseen_suffix_drafts_nothing() {
        let d = NgramDrafter::new();
        assert!(d.draft(0, 4).is_empty());
        // too short for a bigram
        assert!(seeded(&[7]).draft(0, 4).is_empty());
        // bigram (3, 4) never occurred before the suffix itself
        assert!(seeded(&[1, 2, 3, 4]).draft(0, 4).is_empty());
    }

    #[test]
    fn repeated_suffix_proposes_its_continuation() {
        // ... 1 2 [5 9 7] ... 1 2 → expect 5 9 7
        let d = seeded(&[1, 2, 5, 9, 7, 8, 1, 2]);
        assert_eq!(d.draft(0, 3), vec![5, 9, 7]);
        // max truncates the proposal
        assert_eq!(d.draft(0, 2), vec![5, 9]);
        assert_eq!(d.draft(0, 0), Vec::<i32>::new());
    }

    #[test]
    fn prefers_most_recent_match_at_equal_suffix_length() {
        // bigram 1 2 occurs twice with different continuations; the
        // later one (→ 6) wins
        let d = seeded(&[1, 2, 5, 0, 1, 2, 6, 3, 1, 2]);
        assert_eq!(d.draft(0, 1), vec![6]);
    }

    #[test]
    fn longer_suffix_agreement_outranks_recency() {
        // suffix ... 9 1 2: the early occurrence matches 3 tokens
        // (9 1 2 → 4), the late one only 2 (0 1 2 → 8)
        let d = seeded(&[9, 1, 2, 4, 7, 0, 1, 2, 8, 5, 9, 1, 2]);
        assert_eq!(d.draft(0, 1), vec![4]);
    }

    #[test]
    fn periodic_stream_is_drafted_near_perfectly() {
        // the repetitive-workload shape the bench leans on: once the
        // period has been seen, every draft is correct
        let stream: Vec<i32> = (0..40).map(|i| i % 8).collect();
        let d = seeded(&stream);
        assert_eq!(d.draft(0, 4), vec![0, 1, 2, 3]);
    }

    #[test]
    fn reset_isolates_lanes_and_forgets_history() {
        let mut d = seeded(&[1, 2, 3, 1, 2]);
        d.observe(1, 1);
        d.observe(1, 2);
        // lane 1 never saw the bigram repeat
        assert!(d.draft(1, 2).is_empty());
        assert_eq!(d.draft(0, 1), vec![3]);
        d.reset(0);
        assert!(d.draft(0, 1).is_empty());
    }
}
