//! A deterministic, device-free [`EngineBackend`]: same lane /
//! continuous-batching shape as the real [`crate::serving::Engine`]
//! (one token per active lane per pump, prompt phase first, FIFO
//! internal queue) but tokens are a pure function of the prompt, so the
//! scheduler and HTTP layers can be tested — and `loadgen --dry-run`
//! exercised end to end — without artifacts or a PJRT device.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::serving::engine::{
    EngineBackend, GenRequest, GenResult, StreamEvent,
};

/// Deterministic, device-free ways to break a [`MockBackend`] — the
/// test fleet's stand-ins for a wedged device, a crashing runtime, and
/// numerically poisoned state.  All trigger off `steps_executed`, so a
/// faulty engine behaves identically run to run.
#[derive(Debug, Clone)]
pub enum MockFault {
    /// After `n` executed pumps, `pump` blocks (a wedged device: the
    /// driver thread stops heartbeating and the router must detect it).
    /// The block is released — returning an error — when the backend's
    /// [`MockBackend::stall_release`] flag is set, so tests can always
    /// join their driver threads.
    StallAfter(u64),
    /// After `n` executed pumps, every `pump` returns an error (a
    /// crashed runtime: the driver's consecutive-error counter trips).
    ErrorAfter(u64),
    /// Every pump that would sample a token errors with a
    /// "non-finite logits" failure — emulating *engine-wide* numeric
    /// corruption (poisoned weights: every lane's logits are NaN, so
    /// the runtime cannot make progress).  Per-lane poisoning is
    /// different: the real [`Engine`]'s guard contains that to the one
    /// request (dropped with `engine-failure`, `lanes_poisoned`
    /// counter) without erroring the pump.
    ///
    /// [`Engine`]: crate::serving::Engine
    NanLogits,
}

struct MockLane {
    prompt_left: usize,
    generated: Vec<i32>,
    budget: usize,
    prompt: Vec<i32>,
    events: mpsc::Sender<StreamEvent>,
    queued_at: Instant,
    admitted_at: Instant,
}

struct QueuedMock {
    req: GenRequest,
    events: mpsc::Sender<StreamEvent>,
    queued_at: Instant,
}

/// Deterministic mock engine: lane `generated[i] =
/// (sum(prompt) + 7 * i) % vocab`.
pub struct MockBackend {
    lanes: Vec<Option<MockLane>>,
    queue: VecDeque<QueuedMock>,
    vocab: i32,
    /// artificial per-pump latency, to simulate device step time in
    /// backpressure tests and dry-run load generation
    step_delay: Duration,
    fault: Option<MockFault>,
    /// releases a [`MockFault::StallAfter`] block (shared with the
    /// test / fleet harness so wedged driver threads can be joined)
    stall_release: Arc<AtomicBool>,
    pub steps_executed: u64,
    pub tokens_generated: u64,
}

impl MockBackend {
    pub fn new(n_lanes: usize, vocab: usize) -> Self {
        MockBackend {
            lanes: (0..n_lanes.max(1)).map(|_| None).collect(),
            queue: VecDeque::new(),
            vocab: vocab.max(2) as i32,
            step_delay: Duration::ZERO,
            fault: None,
            stall_release: Arc::new(AtomicBool::new(false)),
            steps_executed: 0,
            tokens_generated: 0,
        }
    }

    pub fn with_step_delay(mut self, d: Duration) -> Self {
        self.step_delay = d;
        self
    }

    /// Inject a deterministic fault (see [`MockFault`]).
    pub fn with_fault(mut self, f: MockFault) -> Self {
        self.fault = Some(f);
        self
    }

    /// Use a caller-owned release flag for [`MockFault::StallAfter`]
    /// (set it to unblock a wedged `pump`, e.g. at test shutdown).
    pub fn with_stall_release(mut self, flag: Arc<AtomicBool>) -> Self {
        self.stall_release = flag;
        self
    }

    /// The flag that releases a [`MockFault::StallAfter`] block.
    pub fn stall_release(&self) -> Arc<AtomicBool> {
        self.stall_release.clone()
    }

    /// Apply the injected fault, if it has triggered.  Called after
    /// admission with at least one active lane.
    fn check_fault(&mut self) -> Result<()> {
        match self.fault {
            None => Ok(()),
            Some(MockFault::ErrorAfter(n)) if self.steps_executed >= n => {
                Err(Error::Serving(format!(
                    "mock engine failed after {n} pumps (ErrorAfter)"
                )))
            }
            Some(MockFault::StallAfter(n)) if self.steps_executed >= n => {
                // wedge until released — the driver thread stops
                // heartbeating, which is exactly what the router's
                // health check must catch
                while !self.stall_release.load(Ordering::Relaxed) {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(Error::Serving(
                    "stalled mock engine released (StallAfter)".into(),
                ))
            }
            Some(MockFault::NanLogits)
                if self
                    .lanes
                    .iter()
                    .flatten()
                    .any(|l| l.prompt_left <= 1) =>
            {
                // same failure shape as the real engine's poisoned-
                // state guard: raised the moment a token would be
                // sampled from the corrupt row
                Err(Error::Serving(
                    "non-finite logits on lane 0 — engine state is \
                     poisoned (mock NanLogits fault)"
                        .into(),
                ))
            }
            Some(_) => Ok(()),
        }
    }

    /// The token the mock emits at generation index `i` for `prompt`.
    pub fn expected_token(prompt: &[i32], i: usize, vocab: usize) -> i32 {
        let sum: i64 = prompt.iter().map(|&t| t as i64).sum();
        ((sum + 7 * i as i64).rem_euclid(vocab.max(2) as i64)) as i32
    }

    fn active(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }

    fn admit(&mut self) {
        for slot in self.lanes.iter_mut() {
            if slot.is_none() {
                let Some(q) = self.queue.pop_front() else {
                    break;
                };
                let _ = q.events.send(StreamEvent::Admitted);
                *slot = Some(MockLane {
                    prompt_left: q.req.prompt.len(),
                    generated: Vec::new(),
                    budget: q.req.max_new_tokens.max(1),
                    prompt: q.req.prompt,
                    events: q.events,
                    queued_at: q.queued_at,
                    admitted_at: Instant::now(),
                });
            }
        }
    }
}

impl EngineBackend for MockBackend {
    fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    fn free_lanes(&self) -> usize {
        self.lanes
            .iter()
            .filter(|l| l.is_none())
            .count()
            .saturating_sub(self.queue.len())
    }

    fn submit_streaming(
        &mut self,
        req: GenRequest,
        events: mpsc::Sender<StreamEvent>,
    ) {
        self.queue.push_back(QueuedMock {
            req,
            events,
            queued_at: Instant::now(),
        });
    }

    fn pump(&mut self) -> Result<usize> {
        self.admit();
        if self.active() == 0 {
            return Ok(self.queue.len());
        }
        self.check_fault()?;
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        self.steps_executed += 1;
        for slot in self.lanes.iter_mut() {
            let Some(lane) = slot else { continue };
            if lane.prompt_left > 0 {
                // prompt phase: consume one token, emit nothing
                lane.prompt_left -= 1;
                if lane.prompt_left > 0 {
                    continue;
                }
                // matches the real engine: the pump that feeds the last
                // prompt token already samples a continuation
            }
            let tok = Self::expected_token(
                &lane.prompt,
                lane.generated.len(),
                self.vocab as usize,
            );
            lane.generated.push(tok);
            self.tokens_generated += 1;
            let _ = lane.events.send(StreamEvent::Token(tok));
            if lane.generated.len() >= lane.budget {
                let lane = slot.take().unwrap();
                let res = GenResult {
                    prompt_len: lane.prompt.len(),
                    prompt: lane.prompt,
                    tokens: lane.generated,
                    queue_time: lane.admitted_at - lane.queued_at,
                    run_time: lane.admitted_at.elapsed(),
                };
                let _ = lane.events.send(StreamEvent::Done(res));
            }
        }
        Ok(self.active() + self.queue.len())
    }

    fn stats(&self) -> BTreeMap<String, f64> {
        let mut m = BTreeMap::new();
        m.insert("steps_executed".into(), self.steps_executed as f64);
        m.insert("tokens_generated".into(), self.tokens_generated as f64);
        m.insert("n_lanes".into(), self.lanes.len() as f64);
        m.insert("mock".into(), 1.0);
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::sampler::Sampler;

    fn req(prompt: Vec<i32>, max_new: usize) -> GenRequest {
        GenRequest {
            prompt,
            max_new_tokens: max_new,
            sampler: Sampler::greedy(),
        }
    }

    #[test]
    fn generates_budget_tokens_deterministically() {
        let mut b = MockBackend::new(2, 50);
        let (tx, rx) = mpsc::channel();
        b.submit_streaming(req(vec![3, 4], 3), tx);
        while b.pump().unwrap() > 0 {}
        let mut toks = Vec::new();
        let mut done: Option<GenResult> = None;
        while let Ok(ev) = rx.try_recv() {
            match ev {
                StreamEvent::Token(t) => toks.push(t),
                StreamEvent::Done(r) => done = Some(r),
                _ => {}
            }
        }
        let expect: Vec<i32> = (0..3)
            .map(|i| MockBackend::expected_token(&[3, 4], i, 50))
            .collect();
        assert_eq!(toks, expect);
        let done = done.expect("Done event");
        assert_eq!(done.tokens, expect);
        assert_eq!(done.prompt_len, 2);
    }

    #[test]
    fn prompt_phase_costs_extra_pumps() {
        // prompt of 3 + 2 generated: the pump consuming the last prompt
        // token already samples, so 2 prompt-only pumps + 2 gen pumps
        let mut b = MockBackend::new(1, 10);
        let (tx, _rx) = mpsc::channel();
        b.submit_streaming(req(vec![1, 2, 3], 2), tx);
        while b.pump().unwrap() > 0 {}
        assert_eq!(b.steps_executed, 4);
        assert_eq!(b.tokens_generated, 2);
    }

    #[test]
    fn free_lanes_accounts_for_internal_queue() {
        let mut b = MockBackend::new(2, 10);
        assert_eq!(b.free_lanes(), 2);
        let (tx, _rx) = mpsc::channel();
        b.submit_streaming(req(vec![1], 4), tx.clone());
        assert_eq!(b.free_lanes(), 1);
        b.submit_streaming(req(vec![1], 4), tx.clone());
        b.submit_streaming(req(vec![1], 4), tx);
        assert_eq!(b.free_lanes(), 0);
        b.pump().unwrap();
        // two admitted to lanes, one still queued
        assert_eq!(b.free_lanes(), 0);
    }

    #[test]
    fn error_after_fault_is_deterministic() {
        let mut b = MockBackend::new(1, 10)
            .with_fault(MockFault::ErrorAfter(2));
        let (tx, _rx) = mpsc::channel();
        b.submit_streaming(req(vec![1], 8), tx);
        assert!(b.pump().is_ok());
        assert!(b.pump().is_ok());
        assert!(b.pump().is_err());
        // and it keeps failing (crashed runtime, not a transient)
        assert!(b.pump().is_err());
        assert_eq!(b.steps_executed, 2);
        // an idle faulty engine does not error — the fault needs work
        let mut idle = MockBackend::new(1, 10)
            .with_fault(MockFault::ErrorAfter(0));
        assert!(idle.pump().is_ok());
    }

    #[test]
    fn stall_after_fault_blocks_until_released() {
        let release = Arc::new(AtomicBool::new(false));
        let mut b = MockBackend::new(1, 10)
            .with_fault(MockFault::StallAfter(1))
            .with_stall_release(release.clone());
        let (tx, _rx) = mpsc::channel();
        b.submit_streaming(req(vec![1], 8), tx);
        assert!(b.pump().is_ok());
        let t = std::thread::spawn(move || b.pump().is_err());
        std::thread::sleep(Duration::from_millis(30));
        assert!(!t.is_finished(), "pump returned while stalled");
        release.store(true, Ordering::SeqCst);
        assert!(t.join().unwrap(), "released stall must surface an error");
    }

    #[test]
    fn nan_logits_fault_errors_when_sampling_would_start() {
        let mut b =
            MockBackend::new(1, 10).with_fault(MockFault::NanLogits);
        let (tx, _rx) = mpsc::channel();
        // 2 prompt tokens: the first pump only feeds the prompt...
        b.submit_streaming(req(vec![1, 2], 4), tx);
        assert!(b.pump().is_ok());
        // ...the pump that would sample (last prompt token fed) errors,
        // matching the real engine's poisoned-state guard
        let err = b.pump().unwrap_err();
        assert!(err.to_string().contains("non-finite logits"), "{err}");
    }

    #[test]
    fn lanes_refill_continuously() {
        let mut b = MockBackend::new(1, 10);
        let (tx, rx) = mpsc::channel();
        b.submit_streaming(req(vec![1], 1), tx.clone());
        b.submit_streaming(req(vec![2], 1), tx);
        let mut pumps = 0;
        while b.pump().unwrap() > 0 {
            pumps += 1;
            assert!(pumps < 10);
        }
        let dones = std::iter::from_fn(|| rx.try_recv().ok())
            .filter(|e| matches!(e, StreamEvent::Done(_)))
            .count();
        assert_eq!(dones, 2);
    }
}
