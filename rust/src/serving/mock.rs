//! A deterministic, device-free [`EngineBackend`]: same lane /
//! continuous-batching shape as the real [`crate::serving::Engine`]
//! (chunked prefill — up to C prompt tokens per lane per pump via
//! [`MockBackend::with_prefill_chunk`], default single-token; prompt
//! phase first, FIFO internal queue) but tokens are a pure function of
//! the prompt, so the scheduler and HTTP layers can be tested — and
//! `loadgen --dry-run` exercised end to end — without artifacts or a
//! PJRT device.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::serving::clock::{Clock, SharedClock, WallClock};
use crate::serving::drafter::{Drafter, NgramDrafter};
use crate::serving::engine::{
    EngineBackend, GenRequest, GenResult, StreamEvent,
};
use crate::serving::prefix_cache::PrefixCache;

/// Deterministic, device-free ways to break a [`MockBackend`] — the
/// test fleet's stand-ins for a wedged device, a crashing runtime, and
/// numerically poisoned state.  All trigger off `steps_executed`, so a
/// faulty engine behaves identically run to run.
#[derive(Debug, Clone)]
pub enum MockFault {
    /// After `n` executed pumps, `pump` blocks (a wedged device: the
    /// driver thread stops heartbeating and the router must detect it).
    /// The block is released — returning one error, after which the
    /// fault is cleared and the backend pumps cleanly again (an
    /// unwedged device, the router's re-admission candidate) — when the
    /// backend's [`MockBackend::stall_release`] flag is set, so tests
    /// can always join their driver threads.
    StallAfter(u64),
    /// After `n` executed pumps, every `pump` returns an error (a
    /// crashed runtime: the driver's consecutive-error counter trips).
    ErrorAfter(u64),
    /// Every pump that would sample a token errors with a
    /// "non-finite logits" failure — emulating *engine-wide* numeric
    /// corruption (poisoned weights: every lane's logits are NaN, so
    /// the runtime cannot make progress).  Per-lane poisoning is
    /// different: the real [`Engine`]'s guard contains that to the one
    /// request (dropped with `engine-failure`, `lanes_poisoned`
    /// counter) without erroring the pump.
    ///
    /// [`Engine`]: crate::serving::Engine
    NanLogits,
    /// After `n` executed pumps the engine "restarts": all in-flight
    /// lanes plus the internal queue are dropped on the floor (their
    /// event senders close without a terminal event, so the router's
    /// relay sees a disconnect), and `pump` errors for the next
    /// [`RESTART_ERRORS`] calls — long enough to trip any sane
    /// consecutive-error threshold, so the router quarantines the
    /// engine and fails its lost requests over.  After that the fault
    /// is fully cleared and pumps are clean again, modelling a
    /// crashed-and-restarted runtime that lost its device state but is
    /// otherwise healthy (the router's re-admission candidate).
    /// Counters are cumulative across the restart, like a
    /// supervisor-side metrics scrape.
    RestartAfter(u64),
}

/// How many consecutive `pump` calls fail while a
/// [`MockFault::RestartAfter`] restart is in progress (> the default
/// router `error_threshold`, so the quarantine/failover path runs).
pub const RESTART_ERRORS: u64 = 6;

/// σ-MoE layers the mock's synthetic router reports.
pub const MOCK_EXPERT_LAYERS: usize = 2;
/// Experts per layer in the synthetic router.
pub const MOCK_EXPERTS: usize = 8;
/// Experts selected per token per layer (the mock's top-K).
pub const MOCK_TOP_K: usize = 2;

/// Synthetic bytes one cached prompt token "occupies" in the mock's
/// prefix-cache mirror (the mock stores no payload — its stream is a
/// pure function of the prompt — but charges the budget what a real
/// per-layer memory snapshot would weigh, so eviction behaves
/// identically device-free).
pub const MOCK_SNAPSHOT_TOKEN_BYTES: u64 = 1024;

/// The mock's synthetic σ-MoE router: token value `t` at layer `l`
/// selects experts `(t + 7l) % NE` and `(t + 13l + 3) % NE` (distinct
/// for NE = 8: their difference `6l + 3` is odd), truncated to the
/// first `k` selections under a degraded runtime expert top-k.  A pure
/// function of the token values and k — not of scheduling — so
/// per-request totals are identical across chunk widths and lane
/// placements, which is what lets the chaos harness byte-diff expert
/// metrics across replays.
fn route_token(counts: &mut [Vec<u64>], t: i32, k: usize) {
    for (l, layer) in counts.iter_mut().enumerate() {
        let ne = layer.len() as i64;
        if ne == 0 {
            continue;
        }
        let (t, l) = (t as i64, l as i64);
        layer[(t + 7 * l).rem_euclid(ne) as usize] += 1;
        if k > 1 {
            layer[(t + 13 * l + 3).rem_euclid(ne) as usize] += 1;
        }
    }
}

struct MockLane {
    prompt_left: usize,
    generated: Vec<i32>,
    budget: usize,
    prompt: Vec<i32>,
    events: mpsc::Sender<StreamEvent>,
    queued_at: Instant,
    admitted_at: Instant,
    /// per-request expert top-k ceiling carried from the [`GenRequest`]
    req_expert_k: Option<usize>,
}

struct QueuedMock {
    req: GenRequest,
    events: mpsc::Sender<StreamEvent>,
    queued_at: Instant,
}

/// Deterministic mock engine: lane `generated[i] =
/// (sum(prompt) + 7 * i) % vocab`.
pub struct MockBackend {
    lanes: Vec<Option<MockLane>>,
    queue: VecDeque<QueuedMock>,
    vocab: i32,
    /// artificial per-pump latency, to simulate device step time in
    /// backpressure tests and dry-run load generation
    step_delay: Duration,
    /// prompt tokens one pump ingests per lane (chunked prefill width
    /// C); 1 mirrors an artifact without the `prefill` program
    prefill_chunk: usize,
    fault: Option<MockFault>,
    /// releases a [`MockFault::StallAfter`] block (shared with the
    /// test / fleet harness so wedged driver threads can be joined)
    stall_release: Arc<AtomicBool>,
    pub steps_executed: u64,
    pub tokens_generated: u64,
    /// pumps that ingested prompt tokens through the chunked path
    /// (chunk > 1), mirroring the engine's `prefill_steps_device`
    pub prefill_steps_device: u64,
    /// pumps that ingested prompt tokens one-per-lane (chunk == 1),
    /// mirroring the engine's `prefill_steps_host` fallback counter
    pub prefill_steps_host: u64,
    /// prompt tokens consumed through the chunked path
    pub prefill_tokens: u64,
    /// injectable time source for queue/run timing (wall clock by
    /// default; simulated under the deterministic harness)
    clock: SharedClock,
    /// pumps still erroring while a [`MockFault::RestartAfter`]
    /// restart is in progress
    restart_down: u64,
    /// synthetic per-layer expert selections since the last
    /// [`EngineBackend::take_expert_counts`] drain:
    /// `expert_counts[layer][expert]`
    expert_counts: Vec<Vec<u64>>,
    /// scheduler-set expert top-k target ([`MOCK_TOP_K`] = full
    /// quality); the effective k of a pump further folds in per-request
    /// ceilings, mirroring the real engine
    expert_k: usize,
    /// requested max drafted tokens per lane per verify round (0 = off);
    /// the effective K of a pump is additionally capped at C−1, exactly
    /// like the real engine's verify chunk — with chunk 1 speculation
    /// stays silently off, mirroring an artifact without `verify_logits`
    speculate: usize,
    /// host-side draft source, mirroring the engine's prompt lookup
    drafter: NgramDrafter,
    pub spec_rounds: u64,
    pub spec_drafted: u64,
    pub spec_accepted: u64,
    pub spec_rollbacks: u64,
    pub spec_commit_steps: u64,
    /// speculating lanes per round by accepted-prefix length
    spec_accept_hist: Vec<u64>,
    /// (drafted, accepted) totals already drained through
    /// [`EngineBackend::take_spec_feedback`]
    spec_fb_drained: (u64, u64),
    /// fleet-shared prefix-cache mirror: admissions probe it (a hit
    /// skips the cached prompt prefix) and prompt pumps record chunk
    /// boundaries into it — entries carry no payload, only the
    /// synthetic byte weight, since the mock's stream is a pure
    /// function of the full prompt either way
    prefix_cache: Option<Arc<PrefixCache>>,
    pub prefix_cache_hits: u64,
    pub prefix_cache_misses: u64,
    pub prefix_cache_tokens_saved: u64,
    pub prefix_cache_snapshots: u64,
    pub prefix_cache_restores_host: u64,
}

impl MockBackend {
    pub fn new(n_lanes: usize, vocab: usize) -> Self {
        MockBackend {
            lanes: (0..n_lanes.max(1)).map(|_| None).collect(),
            queue: VecDeque::new(),
            vocab: vocab.max(2) as i32,
            step_delay: Duration::ZERO,
            prefill_chunk: 1,
            fault: None,
            stall_release: Arc::new(AtomicBool::new(false)),
            steps_executed: 0,
            tokens_generated: 0,
            prefill_steps_device: 0,
            prefill_steps_host: 0,
            prefill_tokens: 0,
            clock: WallClock::shared(),
            restart_down: 0,
            expert_counts: vec![
                vec![0; MOCK_EXPERTS];
                MOCK_EXPERT_LAYERS
            ],
            expert_k: MOCK_TOP_K,
            speculate: 0,
            drafter: NgramDrafter::new(),
            spec_rounds: 0,
            spec_drafted: 0,
            spec_accepted: 0,
            spec_rollbacks: 0,
            spec_commit_steps: 0,
            spec_accept_hist: Vec::new(),
            spec_fb_drained: (0, 0),
            prefix_cache: None,
            prefix_cache_hits: 0,
            prefix_cache_misses: 0,
            prefix_cache_tokens_saved: 0,
            prefix_cache_snapshots: 0,
            prefix_cache_restores_host: 0,
        }
    }

    /// Arm the prefix-cache mirror (builder form of
    /// [`EngineBackend::set_prefix_cache`]).
    pub fn with_prefix_cache(mut self, cache: Arc<PrefixCache>) -> Self {
        self.prefix_cache = Some(cache);
        self
    }

    /// Enable speculative decode: up to `k` drafted tokens verified per
    /// lane per pure-decode pump, with the same dispatch accounting as
    /// the real engine (one verify pump per round, plus one commit pump
    /// when any lane rejects part of its draft).  The effective K is
    /// capped at `prefill_chunk - 1` at pump time, so builder order
    /// doesn't matter; with chunk 1 speculation stays off.
    pub fn with_speculate(mut self, k: usize) -> Self {
        self.speculate = k;
        self
    }

    /// The effective per-lane draft cap of a pump (0 = speculation off).
    fn spec_k(&self) -> usize {
        self.speculate.min(self.prefill_chunk.saturating_sub(1))
    }

    pub fn with_step_delay(mut self, d: Duration) -> Self {
        self.step_delay = d;
        self
    }

    /// Replace the backend's time source (deterministic harnesses).
    pub fn with_clock(mut self, clock: SharedClock) -> Self {
        self.clock = clock;
        self
    }

    /// Ingest up to `c` prompt tokens per lane per pump (the mock's
    /// chunked prefill — same pump accounting as the real engine's
    /// `prefill` dispatch, so the scheduler/router/loadgen stack
    /// exercises chunked prompt ingestion without a device).
    pub fn with_prefill_chunk(mut self, c: usize) -> Self {
        self.prefill_chunk = c.max(1);
        self
    }

    /// Inject a deterministic fault (see [`MockFault`]).
    pub fn with_fault(mut self, f: MockFault) -> Self {
        self.fault = Some(f);
        self
    }

    /// Use a caller-owned release flag for [`MockFault::StallAfter`]
    /// (set it to unblock a wedged `pump`, e.g. at test shutdown).
    pub fn with_stall_release(mut self, flag: Arc<AtomicBool>) -> Self {
        self.stall_release = flag;
        self
    }

    /// The flag that releases a [`MockFault::StallAfter`] block.
    pub fn stall_release(&self) -> Arc<AtomicBool> {
        self.stall_release.clone()
    }

    /// Apply the injected fault, if it has triggered.  Called after
    /// admission with at least one active lane.
    fn check_fault(&mut self) -> Result<()> {
        match self.fault {
            None => Ok(()),
            Some(MockFault::ErrorAfter(n)) if self.steps_executed >= n => {
                Err(Error::Serving(format!(
                    "mock engine failed after {n} pumps (ErrorAfter)"
                )))
            }
            Some(MockFault::RestartAfter(n))
                if self.steps_executed >= n =>
            {
                // the restart loses all device-resident state: lanes
                // and queue vanish, their senders drop without a
                // terminal event (the relay observes a disconnect).
                // The runtime stays down for RESTART_ERRORS pumps —
                // enough consecutive errors to trip quarantine, so the
                // router re-places the lost requests on survivors.
                for slot in self.lanes.iter_mut() {
                    *slot = None;
                }
                self.queue.clear();
                self.fault = None;
                self.restart_down = RESTART_ERRORS.saturating_sub(1);
                Err(Error::Serving(format!(
                    "mock engine restarted after {n} pumps \
                     (RestartAfter): all lanes lost"
                )))
            }
            Some(MockFault::StallAfter(n)) if self.steps_executed >= n => {
                // wedge until released — the driver thread stops
                // heartbeating, which is exactly what the router's
                // health check must catch
                while !self.stall_release.load(Ordering::Relaxed) {
                    std::thread::sleep(Duration::from_millis(1));
                }
                // unwedged: surface one error, then pump cleanly (the
                // recovered device the router may re-admit)
                self.fault = None;
                Err(Error::Serving(
                    "stalled mock engine released (StallAfter)".into(),
                ))
            }
            Some(MockFault::NanLogits)
                if self
                    .lanes
                    .iter()
                    .flatten()
                    .any(|l| l.prompt_left <= self.prefill_chunk) =>
            {
                // same failure shape as the real engine's poisoned-
                // state guard: raised the moment a token would be
                // sampled from the corrupt row
                Err(Error::Serving(
                    "non-finite logits on lane 0 — engine state is \
                     poisoned (mock NanLogits fault)"
                        .into(),
                ))
            }
            Some(_) => Ok(()),
        }
    }

    /// The token the mock emits at generation index `i` for `prompt`.
    pub fn expected_token(prompt: &[i32], i: usize, vocab: usize) -> i32 {
        let sum: i64 = prompt.iter().map(|&t| t as i64).sum();
        ((sum + 7 * i as i64).rem_euclid(vocab.max(2) as i64)) as i32
    }

    fn active(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }

    /// Effective expert top-k of the next pump: the scheduler target
    /// folded with every active lane's per-request ceiling (same rule
    /// as the real engine's per-dispatch scalar).
    fn effective_expert_k(&self) -> usize {
        let mut k = self.expert_k;
        for lane in self.lanes.iter().flatten() {
            if let Some(rk) = lane.req_expert_k {
                k = k.min(rk);
            }
        }
        k.clamp(1, MOCK_TOP_K)
    }

    /// Simulated device step time for one dispatch: a degraded expert
    /// top-k proportionally cuts it (k/K of the expert FLOPs) — this is
    /// the mechanism the --degrade-ab overload A/B measures as a p99
    /// win.
    fn step_sleep(&mut self, k_eff: usize) {
        if !self.step_delay.is_zero() {
            let delay = self
                .step_delay
                .mul_f64(k_eff as f64 / MOCK_TOP_K as f64);
            self.clock.sleep(delay);
        }
    }

    /// One speculative verify round over a pure-decode batch, mirroring
    /// the real engine's dispatch accounting device-free: all lanes
    /// share one verify pump (each lane's drafted tokens scored against
    /// the deterministic [`Self::expected_token`] stream, longest
    /// matching prefix accepted plus the correction/bonus token), and
    /// one extra commit pump is charged when any lane rejects part of
    /// its draft (the engine's memory rollback).  Emitted tokens are
    /// always the true stream — a wrong draft costs a pump, never a
    /// wrong token — and every emitted token routes through the
    /// synthetic expert router exactly once, so per-request expert
    /// totals stay schedule-invariant across speculation settings.
    ///
    /// Returns `None` — charging nothing — when speculation is off or
    /// every drafter is cold, so the caller's plain path stays
    /// bit-for-bit identical to a non-speculating backend.
    fn pump_speculate(&mut self, k_eff: usize) -> Option<usize> {
        let spec_k = self.spec_k();
        if spec_k == 0 {
            return None;
        }
        let b = self.lanes.len();
        let mut drafts: Vec<Vec<i32>> = vec![Vec::new(); b];
        let mut any = false;
        for (i, slot) in self.lanes.iter().enumerate() {
            let Some(lane) = slot else { continue };
            let room = lane.budget.saturating_sub(lane.generated.len());
            if room <= 1 {
                continue;
            }
            let d = self.drafter.draft(i, spec_k.min(room - 1));
            if !d.is_empty() {
                any = true;
            }
            drafts[i] = d;
        }
        if !any {
            return None;
        }
        // the verify dispatch
        self.step_sleep(k_eff);
        self.steps_executed += 1;
        self.spec_rounds += 1;
        if self.spec_accept_hist.len() <= spec_k {
            self.spec_accept_hist.resize(spec_k + 1, 0);
        }
        let vocab = self.vocab as usize;
        let mut rollback = false;
        for (i, slot) in self.lanes.iter_mut().enumerate() {
            let Some(lane) = slot else { continue };
            let m = drafts[i].len();
            let mut accepted = 0;
            while accepted < m
                && drafts[i][accepted]
                    == Self::expected_token(
                        &lane.prompt,
                        lane.generated.len() + accepted,
                        vocab,
                    )
            {
                accepted += 1;
            }
            if m > 0 {
                self.spec_drafted += m as u64;
                self.spec_accepted += accepted as u64;
                self.spec_accept_hist[accepted] += 1;
                if accepted < m {
                    rollback = true;
                }
            }
            // accepted drafts + the correction/bonus token, all from
            // the true stream (lanes that drafted nothing ride the
            // dispatch 1-active, exactly step semantics)
            for _ in 0..=accepted {
                let tok = Self::expected_token(
                    &lane.prompt,
                    lane.generated.len(),
                    vocab,
                );
                route_token(&mut self.expert_counts, tok, k_eff);
                lane.generated.push(tok);
                self.tokens_generated += 1;
                self.drafter.observe(i, tok);
                let _ = lane.events.send(StreamEvent::Token(tok));
                if lane.generated.len() >= lane.budget {
                    break;
                }
            }
            if lane.generated.len() >= lane.budget {
                let lane = slot.take().unwrap();
                let res = GenResult {
                    prompt_len: lane.prompt.len(),
                    prompt: lane.prompt,
                    tokens: lane.generated,
                    queue_time: lane.admitted_at - lane.queued_at,
                    run_time: self
                        .clock
                        .now()
                        .duration_since(lane.admitted_at),
                };
                let _ = lane.events.send(StreamEvent::Done(res));
            }
        }
        if rollback {
            // the ragged commit dispatch that rolls memories back
            self.step_sleep(k_eff);
            self.steps_executed += 1;
            self.spec_commit_steps += 1;
            self.spec_rollbacks += 1;
        }
        Some(self.active() + self.queue.len())
    }

    fn admit(&mut self) {
        let cache = self.prefix_cache.clone();
        for (i, slot) in self.lanes.iter_mut().enumerate() {
            if slot.is_none() {
                let Some(q) = self.queue.pop_front() else {
                    break;
                };
                let _ = q.events.send(StreamEvent::Admitted);
                if self.speculate > 0 {
                    // seed prompt lookup with the new occupant's prompt
                    self.drafter.reset(i);
                    for &t in &q.req.prompt {
                        self.drafter.observe(i, t);
                    }
                }
                let mut prompt_left = q.req.prompt.len();
                if let Some(c) = &cache {
                    match c.probe(&q.req.prompt, self.prefill_chunk) {
                        Some(hit) => {
                            self.prefix_cache_hits += 1;
                            self.prefix_cache_tokens_saved +=
                                hit.len as u64;
                            self.prefix_cache_restores_host += 1;
                            // the restored prefix never re-runs, but
                            // its tokens still route exactly once so
                            // per-request expert totals stay invariant
                            // across cache settings
                            let k = self
                                .expert_k
                                .min(q.req.expert_k.unwrap_or(MOCK_TOP_K))
                                .clamp(1, MOCK_TOP_K);
                            for &t in &q.req.prompt[..hit.len] {
                                route_token(&mut self.expert_counts, t, k);
                            }
                            prompt_left -= hit.len;
                        }
                        None => self.prefix_cache_misses += 1,
                    }
                }
                *slot = Some(MockLane {
                    prompt_left,
                    generated: Vec::new(),
                    budget: q.req.max_new_tokens.max(1),
                    prompt: q.req.prompt,
                    events: q.events,
                    queued_at: q.queued_at,
                    admitted_at: self.clock.now(),
                    req_expert_k: q.req.expert_k,
                });
            }
        }
    }
}

impl EngineBackend for MockBackend {
    fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    fn free_lanes(&self) -> usize {
        self.lanes
            .iter()
            .filter(|l| l.is_none())
            .count()
            .saturating_sub(self.queue.len())
    }

    fn submit_streaming(
        &mut self,
        req: GenRequest,
        events: mpsc::Sender<StreamEvent>,
    ) {
        self.queue.push_back(QueuedMock {
            req,
            events,
            queued_at: self.clock.now(),
        });
    }

    fn pump(&mut self) -> Result<usize> {
        if self.restart_down > 0 {
            // mid-restart: the runtime is down regardless of load —
            // checked before admission so even an idle pump errors
            // (the router must see the consecutive-error streak)
            self.restart_down -= 1;
            return Err(Error::Serving(
                "mock engine restarting (RestartAfter): runtime \
                 unavailable"
                    .into(),
            ));
        }
        self.admit();
        if self.active() == 0 {
            return Ok(self.queue.len());
        }
        self.check_fault()?;
        let k_eff = self.effective_expert_k();
        let in_prompt = self
            .lanes
            .iter()
            .flatten()
            .any(|l| l.prompt_left > 0);
        if !in_prompt {
            if let Some(n) = self.pump_speculate(k_eff) {
                return Ok(n);
            }
        }
        self.step_sleep(k_eff);
        self.steps_executed += 1;
        let chunk = self.prefill_chunk;
        let cache = self.prefix_cache.clone();
        let mut prompt_tokens = 0u64;
        for (i, slot) in self.lanes.iter_mut().enumerate() {
            let Some(lane) = slot else { continue };
            if lane.prompt_left > 0 {
                // prompt phase: consume up to `chunk` tokens, emit
                // nothing until the prompt drains
                let k = lane.prompt_left.min(chunk);
                let start = lane.prompt.len() - lane.prompt_left;
                for &t in &lane.prompt[start..start + k] {
                    route_token(&mut self.expert_counts, t, k_eff);
                }
                lane.prompt_left -= k;
                prompt_tokens += k as u64;
                if let Some(c) = &cache {
                    // chunk-boundary snapshot, exactly the real
                    // engine's post-absorb hook (payload-free: the
                    // synthetic weight keeps eviction honest)
                    let consumed = lane.prompt.len() - lane.prompt_left;
                    if consumed % chunk == 0
                        && c.wants(&lane.prompt[..consumed])
                        && c.insert_weighted(
                            &lane.prompt[..consumed],
                            Vec::new(),
                            consumed as u64 * MOCK_SNAPSHOT_TOKEN_BYTES,
                        )
                    {
                        self.prefix_cache_snapshots += 1;
                    }
                }
                if lane.prompt_left > 0 {
                    continue;
                }
                // matches the real engine: the pump that feeds the last
                // prompt token already samples a continuation
            }
            let tok = Self::expected_token(
                &lane.prompt,
                lane.generated.len(),
                self.vocab as usize,
            );
            route_token(&mut self.expert_counts, tok, k_eff);
            lane.generated.push(tok);
            self.tokens_generated += 1;
            if self.speculate > 0 {
                self.drafter.observe(i, tok);
            }
            let _ = lane.events.send(StreamEvent::Token(tok));
            if lane.generated.len() >= lane.budget {
                let lane = slot.take().unwrap();
                let res = GenResult {
                    prompt_len: lane.prompt.len(),
                    prompt: lane.prompt,
                    tokens: lane.generated,
                    queue_time: lane.admitted_at - lane.queued_at,
                    run_time: self
                        .clock
                        .now()
                        .duration_since(lane.admitted_at),
                };
                let _ = lane.events.send(StreamEvent::Done(res));
            }
        }
        if prompt_tokens > 0 {
            if chunk > 1 {
                self.prefill_steps_device += 1;
                self.prefill_tokens += prompt_tokens;
            } else {
                self.prefill_steps_host += 1;
            }
        }
        Ok(self.active() + self.queue.len())
    }

    fn prefill_chunk(&self) -> usize {
        self.prefill_chunk
    }

    fn expert_k_max(&self) -> Option<usize> {
        Some(MOCK_TOP_K)
    }

    fn set_expert_k(&mut self, k: usize) {
        self.expert_k = k.clamp(1, MOCK_TOP_K);
    }

    fn set_prefix_cache(&mut self, cache: Arc<PrefixCache>) {
        self.prefix_cache = Some(cache);
    }

    fn set_speculate(&mut self, k: usize) {
        // spec_k() re-caps at C−1 per pump, so no clamp needed here
        self.speculate = k;
    }

    fn take_spec_feedback(&mut self) -> (u64, u64) {
        let d = self.spec_drafted - self.spec_fb_drained.0;
        let a = self.spec_accepted - self.spec_fb_drained.1;
        self.spec_fb_drained = (self.spec_drafted, self.spec_accepted);
        (d, a)
    }

    fn stats(&self) -> BTreeMap<String, f64> {
        let mut m = BTreeMap::new();
        m.insert("steps_executed".into(), self.steps_executed as f64);
        m.insert("tokens_generated".into(), self.tokens_generated as f64);
        m.insert("prefill_chunk".into(), self.prefill_chunk as f64);
        m.insert(
            "prefill_steps_device".into(),
            self.prefill_steps_device as f64,
        );
        m.insert(
            "prefill_steps_host".into(),
            self.prefill_steps_host as f64,
        );
        m.insert("prefill_tokens".into(), self.prefill_tokens as f64);
        m.insert("n_lanes".into(), self.lanes.len() as f64);
        m.insert("expert_layers".into(), MOCK_EXPERT_LAYERS as f64);
        m.insert("experts_per_layer".into(), MOCK_EXPERTS as f64);
        m.insert("expert_k_max".into(), MOCK_TOP_K as f64);
        m.insert("expert_k_current".into(), self.expert_k as f64);
        // speculative families only on speculating backends, mirroring
        // the real engine's conditional export
        let spec_k = self.spec_k();
        if spec_k > 0 {
            m.insert("speculate".into(), spec_k as f64);
            m.insert("spec_rounds".into(), self.spec_rounds as f64);
            m.insert("spec_drafted".into(), self.spec_drafted as f64);
            m.insert("spec_accepted".into(), self.spec_accepted as f64);
            m.insert(
                "spec_accept_rate".into(),
                if self.spec_drafted > 0 {
                    self.spec_accepted as f64 / self.spec_drafted as f64
                } else {
                    0.0
                },
            );
            m.insert("spec_rollbacks".into(), self.spec_rollbacks as f64);
            m.insert(
                "spec_commit_steps".into(),
                self.spec_commit_steps as f64,
            );
            for n in 0..=spec_k {
                let count =
                    self.spec_accept_hist.get(n).copied().unwrap_or(0);
                m.insert(format!("spec_hist_{n}"), count as f64);
            }
        }
        // prefix-cache families only on cache-armed backends, same
        // conditional export as the real engine
        if self.prefix_cache.is_some() {
            m.insert(
                "prefix_cache_hits".into(),
                self.prefix_cache_hits as f64,
            );
            m.insert(
                "prefix_cache_misses".into(),
                self.prefix_cache_misses as f64,
            );
            m.insert(
                "prefix_cache_tokens_saved".into(),
                self.prefix_cache_tokens_saved as f64,
            );
            m.insert(
                "prefix_cache_snapshots".into(),
                self.prefix_cache_snapshots as f64,
            );
            m.insert(
                "prefix_cache_restores_host".into(),
                self.prefix_cache_restores_host as f64,
            );
        }
        m.insert("mock".into(), 1.0);
        m
    }

    fn take_expert_counts(&mut self) -> Option<Vec<Vec<u64>>> {
        // drain-and-zero (rather than `mem::take`) so the accumulator
        // keeps its [layers][experts] shape for the next pump
        let drained = self.expert_counts.clone();
        for layer in self.expert_counts.iter_mut() {
            layer.fill(0);
        }
        Some(drained)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::sampler::Sampler;

    fn req(prompt: Vec<i32>, max_new: usize) -> GenRequest {
        GenRequest {
            prompt,
            max_new_tokens: max_new,
            sampler: Sampler::greedy(),
            ..Default::default()
        }
    }

    #[test]
    fn generates_budget_tokens_deterministically() {
        let mut b = MockBackend::new(2, 50);
        let (tx, rx) = mpsc::channel();
        b.submit_streaming(req(vec![3, 4], 3), tx);
        while b.pump().unwrap() > 0 {}
        let mut toks = Vec::new();
        let mut done: Option<GenResult> = None;
        while let Ok(ev) = rx.try_recv() {
            match ev {
                StreamEvent::Token(t) => toks.push(t),
                StreamEvent::Done(r) => done = Some(r),
                _ => {}
            }
        }
        let expect: Vec<i32> = (0..3)
            .map(|i| MockBackend::expected_token(&[3, 4], i, 50))
            .collect();
        assert_eq!(toks, expect);
        let done = done.expect("Done event");
        assert_eq!(done.tokens, expect);
        assert_eq!(done.prompt_len, 2);
    }

    #[test]
    fn prompt_phase_costs_extra_pumps() {
        // prompt of 3 + 2 generated: the pump consuming the last prompt
        // token already samples, so 2 prompt-only pumps + 2 gen pumps
        let mut b = MockBackend::new(1, 10);
        let (tx, _rx) = mpsc::channel();
        b.submit_streaming(req(vec![1, 2, 3], 2), tx);
        while b.pump().unwrap() > 0 {}
        assert_eq!(b.steps_executed, 4);
        assert_eq!(b.tokens_generated, 2);
    }

    #[test]
    fn free_lanes_accounts_for_internal_queue() {
        let mut b = MockBackend::new(2, 10);
        assert_eq!(b.free_lanes(), 2);
        let (tx, _rx) = mpsc::channel();
        b.submit_streaming(req(vec![1], 4), tx.clone());
        assert_eq!(b.free_lanes(), 1);
        b.submit_streaming(req(vec![1], 4), tx.clone());
        b.submit_streaming(req(vec![1], 4), tx);
        assert_eq!(b.free_lanes(), 0);
        b.pump().unwrap();
        // two admitted to lanes, one still queued
        assert_eq!(b.free_lanes(), 0);
    }

    #[test]
    fn error_after_fault_is_deterministic() {
        let mut b = MockBackend::new(1, 10)
            .with_fault(MockFault::ErrorAfter(2));
        let (tx, _rx) = mpsc::channel();
        b.submit_streaming(req(vec![1], 8), tx);
        assert!(b.pump().is_ok());
        assert!(b.pump().is_ok());
        assert!(b.pump().is_err());
        // and it keeps failing (crashed runtime, not a transient)
        assert!(b.pump().is_err());
        assert_eq!(b.steps_executed, 2);
        // an idle faulty engine does not error — the fault needs work
        let mut idle = MockBackend::new(1, 10)
            .with_fault(MockFault::ErrorAfter(0));
        assert!(idle.pump().is_ok());
    }

    #[test]
    fn stall_after_fault_blocks_until_released() {
        let release = Arc::new(AtomicBool::new(false));
        let mut b = MockBackend::new(1, 10)
            .with_fault(MockFault::StallAfter(1))
            .with_stall_release(release.clone());
        let (tx, _rx) = mpsc::channel();
        b.submit_streaming(req(vec![1], 8), tx);
        assert!(b.pump().is_ok());
        let t = std::thread::spawn(move || b.pump().is_err());
        std::thread::sleep(Duration::from_millis(30));
        assert!(!t.is_finished(), "pump returned while stalled");
        release.store(true, Ordering::SeqCst);
        assert!(t.join().unwrap(), "released stall must surface an error");
    }

    #[test]
    fn restart_after_drops_lanes_then_pumps_cleanly() {
        let mut b = MockBackend::new(2, 10)
            .with_fault(MockFault::RestartAfter(2));
        let (tx, rx) = mpsc::channel();
        b.submit_streaming(req(vec![1], 8), tx);
        assert!(b.pump().is_ok());
        assert!(b.pump().is_ok());
        // the restart: lanes + queue gone, senders dropped without a
        // terminal event, and the pump errors for RESTART_ERRORS calls
        // (the quarantine-worthy streak)
        for i in 0..RESTART_ERRORS {
            assert!(b.pump().is_err(), "restart error {i} expected");
        }
        assert_eq!(b.active(), 0);
        assert_eq!(b.free_lanes(), 2);
        let mut saw_terminal = false;
        loop {
            match rx.try_recv() {
                Ok(StreamEvent::Done(_))
                | Ok(StreamEvent::Dropped(_)) => saw_terminal = true,
                Ok(_) => {}
                Err(_) => break,
            }
        }
        assert!(
            !saw_terminal,
            "a restart must lose lanes without a terminal event \
             (the router's relay sees the disconnect)"
        );
        // restarted: new work runs cleanly, counters stay cumulative
        let steps_before = b.steps_executed;
        let (tx, rx) = mpsc::channel();
        b.submit_streaming(req(vec![2], 1), tx);
        while b.pump().unwrap() > 0 {}
        assert!(b.steps_executed > steps_before);
        let toks: Vec<i32> = std::iter::from_fn(|| rx.try_recv().ok())
            .filter_map(|ev| match ev {
                StreamEvent::Token(t) => Some(t),
                _ => None,
            })
            .collect();
        assert_eq!(toks, vec![MockBackend::expected_token(&[2], 0, 10)]);
    }

    #[test]
    fn nan_logits_fault_errors_when_sampling_would_start() {
        let mut b =
            MockBackend::new(1, 10).with_fault(MockFault::NanLogits);
        let (tx, _rx) = mpsc::channel();
        // 2 prompt tokens: the first pump only feeds the prompt...
        b.submit_streaming(req(vec![1, 2], 4), tx);
        assert!(b.pump().is_ok());
        // ...the pump that would sample (last prompt token fed) errors,
        // matching the real engine's poisoned-state guard
        let err = b.pump().unwrap_err();
        assert!(err.to_string().contains("non-finite logits"), "{err}");
    }

    /// Drain a backend, splitting one receiver's events into (tokens,
    /// done results).
    fn drain(
        b: &mut MockBackend,
        rx: &mpsc::Receiver<StreamEvent>,
    ) -> (Vec<i32>, Vec<GenResult>) {
        while b.pump().unwrap() > 0 {}
        let mut toks = Vec::new();
        let mut dones = Vec::new();
        while let Ok(ev) = rx.try_recv() {
            match ev {
                StreamEvent::Token(t) => toks.push(t),
                StreamEvent::Done(r) => dones.push(r),
                _ => {}
            }
        }
        (toks, dones)
    }

    #[test]
    fn chunked_prefill_matches_single_token_for_ragged_lengths() {
        // prompt lengths straddling the chunk boundary must produce
        // bit-identical streams at C and C=1, with ⌈L/C⌉ prompt pumps
        // instead of L (the pump consuming the last prompt token
        // already samples, so total pumps = ⌈L/C⌉ + budget - 1)
        const C: usize = 4;
        for len in [C - 1, C, C + 1, 2 * C + 3] {
            let prompt: Vec<i32> =
                (0..len as i32).map(|t| t % 10).collect();
            let budget = 5;
            let mut chunked =
                MockBackend::new(1, 50).with_prefill_chunk(C);
            let (tx, rx) = mpsc::channel();
            chunked.submit_streaming(req(prompt.clone(), budget), tx);
            let (toks_c, dones_c) = drain(&mut chunked, &rx);

            let mut single = MockBackend::new(1, 50);
            let (tx, rx) = mpsc::channel();
            single.submit_streaming(req(prompt.clone(), budget), tx);
            let (toks_s, dones_s) = drain(&mut single, &rx);

            assert_eq!(toks_c, toks_s, "len {len}");
            assert_eq!(dones_c.len(), 1);
            assert_eq!(dones_c[0].tokens, dones_s[0].tokens);
            assert_eq!(dones_c[0].prompt_len, len);
            assert_eq!(
                chunked.steps_executed as usize,
                len.div_ceil(C) + budget - 1,
                "len {len}: chunked pump count"
            );
            assert_eq!(
                single.steps_executed as usize,
                len + budget - 1,
                "len {len}: single-token pump count"
            );
            assert_eq!(chunked.prefill_tokens as usize, len);
            assert!(chunked.prefill_steps_device as usize >= 1);
            assert_eq!(chunked.prefill_steps_host, 0);
            // the single-token path is the fallback counter's domain
            assert_eq!(single.prefill_steps_device, 0);
            assert!(single.prefill_steps_host as usize >= 1);
        }
    }

    #[test]
    fn chunked_prefill_cuts_dispatches_3x_for_256_token_prompts() {
        // the BENCH_serve acceptance bar: ≥3x fewer engine dispatches
        // per 256-token prompt at C=16 (measured: 31 vs 271)
        let run = |chunk: usize| -> u64 {
            let mut b = MockBackend::new(1, 50).with_prefill_chunk(chunk);
            let (tx, _rx) = mpsc::channel();
            b.submit_streaming(req((0..256).collect(), 16), tx);
            while b.pump().unwrap() > 0 {}
            b.steps_executed
        };
        let single = run(1);
        let chunked = run(16);
        assert!(
            single >= 3 * chunked,
            "C=16 must cut dispatches ≥3x: {single} vs {chunked}"
        );
    }

    #[test]
    fn mixed_prefill_and_decode_lanes_share_one_pump() {
        // lane 0 is mid-decode while lane 1 prefills a long prompt in
        // the same pumps; both streams must stay correct and the
        // chunked accounting must only count lane 1's prompt tokens
        const C: usize = 4;
        let mut b = MockBackend::new(2, 50).with_prefill_chunk(C);
        let (tx0, rx0) = mpsc::channel();
        b.submit_streaming(req(vec![1], 8), tx0);
        // lane 0 consumes its 1-token prompt and samples
        b.pump().unwrap();
        let (tx1, rx1) = mpsc::channel();
        b.submit_streaming(req((0..9).collect(), 2), tx1);
        while b.pump().unwrap() > 0 {}
        let toks0: Vec<i32> = std::iter::from_fn(|| rx0.try_recv().ok())
            .filter_map(|ev| match ev {
                StreamEvent::Token(t) => Some(t),
                _ => None,
            })
            .collect();
        let toks1: Vec<i32> = std::iter::from_fn(|| rx1.try_recv().ok())
            .filter_map(|ev| match ev {
                StreamEvent::Token(t) => Some(t),
                _ => None,
            })
            .collect();
        let expect0: Vec<i32> = (0..8)
            .map(|i| MockBackend::expected_token(&[1], i, 50))
            .collect();
        let p1: Vec<i32> = (0..9).collect();
        let expect1: Vec<i32> = (0..2)
            .map(|i| MockBackend::expected_token(&p1, i, 50))
            .collect();
        assert_eq!(toks0, expect0);
        assert_eq!(toks1, expect1);
        // all 10 prompt tokens (lane 0's 1 + lane 1's 9) flowed
        // through the chunked ingest accounting
        assert_eq!(b.prefill_tokens, 10);
    }

    #[test]
    fn synthetic_router_counts_every_token_schedule_invariantly() {
        // counts[layer][expert]: every consumed (prompt) and generated
        // token selects MOCK_TOP_K experts per layer, and — because the
        // router is a pure function of token values — the totals are
        // identical across prefill chunk widths
        let run = |chunk: usize| -> Vec<Vec<u64>> {
            let mut b = MockBackend::new(2, 50).with_prefill_chunk(chunk);
            let (tx, _rx) = mpsc::channel();
            b.submit_streaming(req(vec![3, 4, 5], 4), tx);
            let (tx, _rx) = mpsc::channel();
            b.submit_streaming(req(vec![9], 2), tx);
            while b.pump().unwrap() > 0 {}
            b.take_expert_counts().expect("mock always observes routing")
        };
        let counts = run(1);
        assert_eq!(counts.len(), MOCK_EXPERT_LAYERS);
        let tokens = 3 + 4 + 1 + 2; // prompts + budgets, both requests
        for layer in &counts {
            assert_eq!(layer.len(), MOCK_EXPERTS);
            let total: u64 = layer.iter().sum();
            assert_eq!(total, (tokens * MOCK_TOP_K) as u64);
        }
        assert_eq!(counts, run(4), "routing must not depend on chunking");
        // the drain zeroed the accumulator but kept its shape
        let mut b = MockBackend::new(1, 10);
        let first = b.take_expert_counts().unwrap();
        assert_eq!(first, vec![vec![0; MOCK_EXPERTS]; MOCK_EXPERT_LAYERS]);
    }

    #[test]
    fn degraded_expert_k_truncates_routing_and_respects_request_ceiling() {
        let mut b = MockBackend::new(1, 50);
        assert_eq!(b.expert_k_max(), Some(MOCK_TOP_K));
        b.set_expert_k(1);
        let (tx, _rx) = mpsc::channel();
        b.submit_streaming(req(vec![3, 4], 2), tx);
        while b.pump().unwrap() > 0 {}
        // 2 prompt + 2 generated tokens, each selecting 1 expert/layer
        for layer in b.take_expert_counts().unwrap() {
            assert_eq!(layer.iter().sum::<u64>(), 4);
        }
        // restore (clamped down to the mock ceiling); a per-request
        // ceiling then degrades only the pumps that lane is active in
        b.set_expert_k(99);
        let (tx, _rx) = mpsc::channel();
        let mut r = req(vec![5], 1);
        r.expert_k = Some(1);
        b.submit_streaming(r, tx);
        while b.pump().unwrap() > 0 {}
        for layer in b.take_expert_counts().unwrap() {
            assert_eq!(layer.iter().sum::<u64>(), 2);
        }
        let m = b.stats();
        assert_eq!(m["expert_k_current"], MOCK_TOP_K as f64);
        assert_eq!(m["expert_k_max"], MOCK_TOP_K as f64);
    }

    #[test]
    fn speculative_decode_matches_plain_streams_with_fewer_pumps() {
        // vocab 10 makes the generated stream periodic (step 7 mod 10),
        // so prompt lookup goes near-perfect once one period has been
        // seen — the repetitive workload speculation targets
        let budget = 60;
        let run = |k: usize| -> (Vec<i32>, u64, BTreeMap<String, f64>) {
            let mut b = MockBackend::new(1, 10)
                .with_prefill_chunk(8)
                .with_speculate(k);
            let (tx, rx) = mpsc::channel();
            b.submit_streaming(req(vec![1, 2, 3], budget), tx);
            let (toks, dones) = drain(&mut b, &rx);
            assert_eq!(dones.len(), 1);
            assert_eq!(dones[0].tokens, toks);
            (toks, b.steps_executed, b.stats())
        };
        let (plain, plain_steps, plain_stats) = run(0);
        let (spec, spec_steps, spec_stats) = run(3);
        assert_eq!(spec, plain, "speculation must never change tokens");
        assert!(
            spec_steps * 2 < plain_steps,
            "speculation must cut pumps >2x on a periodic stream: \
             {spec_steps} vs {plain_steps}"
        );
        assert!(
            plain_stats.get("spec_rounds").is_none(),
            "non-speculating backends export no spec_* families"
        );
        assert_eq!(spec_stats["speculate"], 3.0);
        assert!(spec_stats["spec_rounds"] > 0.0);
        assert!(spec_stats["spec_accept_rate"] > 0.5);
        assert_eq!(spec_stats["spec_rollbacks"], 0.0);
        // the histogram covers 0..=K and its rounds sum to spec_rounds
        let hist: f64 = (0..=3)
            .map(|n| spec_stats[&format!("spec_hist_{n}")])
            .sum();
        assert_eq!(hist, spec_stats["spec_rounds"]);
    }

    #[test]
    fn rejected_draft_rolls_back_for_exactly_one_extra_pump() {
        // prompt [5, 2, 5]: after the first generated token (2) the
        // history suffix (5, 2) repeats a prompt bigram whose
        // continuation (5) disagrees with the true stream (9) — the
        // draft is rejected wholesale and charged one commit pump
        let run = |k: usize| -> (Vec<i32>, u64, MockBackend) {
            let mut b = MockBackend::new(1, 10)
                .with_prefill_chunk(8)
                .with_speculate(k);
            let (tx, rx) = mpsc::channel();
            b.submit_streaming(req(vec![5, 2, 5], 4), tx);
            let (toks, _) = drain(&mut b, &rx);
            let steps = b.steps_executed;
            (toks, steps, b)
        };
        let (plain, plain_steps, _) = run(0);
        let (spec, spec_steps, b) = run(3);
        assert_eq!(spec, plain, "a wrong draft must never change tokens");
        assert_eq!(
            spec_steps,
            plain_steps + 1,
            "one rejected round = its verify pump emits the correction \
             (free) but the rollback commit costs one extra pump"
        );
        assert_eq!(b.spec_rounds, 1);
        assert_eq!(b.spec_accepted, 0);
        assert_eq!(b.spec_rollbacks, 1);
        assert_eq!(b.spec_commit_steps, 1);
        assert!(b.spec_drafted > 0);
    }

    #[test]
    fn speculative_routing_totals_stay_schedule_invariant() {
        // the synthetic router is a pure function of token values, so
        // per-request expert totals must not depend on whether tokens
        // were emitted one-per-pump or in accepted speculative runs
        let run = |k: usize| -> Vec<Vec<u64>> {
            let mut b = MockBackend::new(2, 10)
                .with_prefill_chunk(4)
                .with_speculate(k);
            let (tx, _rx) = mpsc::channel();
            b.submit_streaming(req(vec![3, 4, 5], 24), tx);
            let (tx, _rx) = mpsc::channel();
            b.submit_streaming(req(vec![9], 12), tx);
            while b.pump().unwrap() > 0 {}
            b.take_expert_counts().unwrap()
        };
        assert_eq!(run(0), run(3));
    }

    #[test]
    fn chunk_one_disables_speculation_silently() {
        // mirrors the engine against an artifact without verify_logits:
        // armed speculation stays off, streams and counters untouched
        let mut b = MockBackend::new(1, 10).with_speculate(4);
        let (tx, rx) = mpsc::channel();
        b.submit_streaming(req(vec![1, 2, 1, 2], 8), tx);
        let (toks, _) = drain(&mut b, &rx);
        let expect: Vec<i32> = (0..8)
            .map(|i| MockBackend::expected_token(&[1, 2, 1, 2], i, 10))
            .collect();
        assert_eq!(toks, expect);
        assert_eq!(b.spec_rounds, 0);
        assert!(b.stats().get("speculate").is_none());
    }

    #[test]
    fn prefix_cache_hit_streams_bitwise_identical_with_fewer_pumps() {
        // the tentpole property: a request whose prompt prefix is
        // cached must stream bit-for-bit what the same request served
        // cold streams, while its prefill costs ⌈tail/C⌉ pumps instead
        // of ⌈L/C⌉ — swept across ragged tails straddling every chunk
        // boundary (1, C−1, C, C+1, 2C+3)
        const C: usize = 4;
        let budget = 5;
        let prefix: Vec<i32> = (1..=(2 * C) as i32).collect();
        for tail_len in [1usize, C - 1, C, C + 1, 2 * C + 3] {
            let mut b_prompt = prefix.clone();
            b_prompt.extend((0..tail_len as i32).map(|t| 30 + t % 10));

            // cold reference: no cache anywhere
            let mut cold = MockBackend::new(1, 50).with_prefill_chunk(C);
            let (tx, rx) = mpsc::channel();
            cold.submit_streaming(req(b_prompt.clone(), budget), tx);
            let (toks_cold, _) = drain(&mut cold, &rx);
            assert_eq!(
                cold.steps_executed as usize,
                b_prompt.len().div_ceil(C) + budget - 1
            );

            // warm: request A (same prefix, different tail) seeds the
            // cache at every chunk boundary it crosses
            let cache = PrefixCache::shared(1 << 20);
            let mut warm = MockBackend::new(1, 50)
                .with_prefill_chunk(C)
                .with_prefix_cache(cache.clone());
            let mut a_prompt = prefix.clone();
            a_prompt.extend([91, 92, 93]);
            let (tx, rx) = mpsc::channel();
            warm.submit_streaming(req(a_prompt, budget), tx);
            let _ = drain(&mut warm, &rx);
            assert_eq!(warm.prefix_cache_misses, 1);
            assert!(cache.entries() >= 2, "boundaries C and 2C cached");

            let steps_before = warm.steps_executed;
            let (tx, rx) = mpsc::channel();
            warm.submit_streaming(req(b_prompt.clone(), budget), tx);
            let (toks_warm, dones) = drain(&mut warm, &rx);
            assert_eq!(
                toks_warm, toks_cold,
                "tail {tail_len}: a cache hit must never change tokens"
            );
            assert_eq!(dones.len(), 1);
            assert_eq!(dones[0].tokens, toks_cold);
            assert_eq!(dones[0].prompt_len, b_prompt.len());
            assert_eq!(warm.prefix_cache_hits, 1);
            assert_eq!(
                warm.prefix_cache_tokens_saved,
                (2 * C) as u64,
                "tail {tail_len}: the full shared prefix is restored"
            );
            let pumps = (warm.steps_executed - steps_before) as usize;
            assert_eq!(
                pumps,
                tail_len.div_ceil(C) + budget - 1,
                "tail {tail_len}: hit prefill must cost ⌈tail/C⌉ pumps"
            );
        }
    }

    #[test]
    fn prefix_cache_preserves_expert_routing_totals() {
        // the synthetic router is a pure function of token values, so
        // per-request expert totals must be identical with the cache
        // armed or not (cached prefix tokens route once at restore)
        const C: usize = 4;
        let run = |armed: bool| -> Vec<Vec<u64>> {
            let mut b = MockBackend::new(1, 50).with_prefill_chunk(C);
            if armed {
                b = b.with_prefix_cache(PrefixCache::shared(1 << 20));
            }
            for tail in [vec![70, 71], vec![80, 81, 82]] {
                let mut p: Vec<i32> = (1..=8).collect();
                p.extend(tail);
                let (tx, _rx) = mpsc::channel();
                b.submit_streaming(req(p, 3), tx);
                while b.pump().unwrap() > 0 {}
            }
            if armed {
                assert_eq!(b.prefix_cache_hits, 1);
            }
            b.take_expert_counts().unwrap()
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn prefix_cache_stats_export_only_when_armed() {
        let plain = MockBackend::new(1, 10);
        assert!(plain.stats().get("prefix_cache_hits").is_none());
        let cache = PrefixCache::shared(4096);
        let mut armed = MockBackend::new(1, 10)
            .with_prefill_chunk(4)
            .with_prefix_cache(cache);
        let (tx, rx) = mpsc::channel();
        armed.submit_streaming(req((0..10).collect(), 2), tx);
        let _ = drain(&mut armed, &rx);
        let m = armed.stats();
        assert_eq!(m["prefix_cache_misses"], 1.0);
        assert!(m["prefix_cache_snapshots"] >= 1.0);
        assert_eq!(m["prefix_cache_hits"], 0.0);
    }

    #[test]
    fn spec_feedback_drains_deltas_once() {
        let mut b = MockBackend::new(1, 10)
            .with_prefill_chunk(8)
            .with_speculate(3);
        assert_eq!(b.take_spec_feedback(), (0, 0));
        let (tx, rx) = mpsc::channel();
        b.submit_streaming(req(vec![1, 2, 3], 30), tx);
        let _ = drain(&mut b, &rx);
        let (d, a) = b.take_spec_feedback();
        assert_eq!((d, a), (b.spec_drafted, b.spec_accepted));
        assert!(d > 0);
        // drained: a second take reports only new work
        assert_eq!(b.take_spec_feedback(), (0, 0));
        // the autotune knob takes effect for subsequent pumps
        b.set_speculate(1);
        assert_eq!(b.spec_k(), 1);
        b.set_speculate(0);
        assert_eq!(b.spec_k(), 0);
    }

    #[test]
    fn lanes_refill_continuously() {
        let mut b = MockBackend::new(1, 10);
        let (tx, rx) = mpsc::channel();
        b.submit_streaming(req(vec![1], 1), tx.clone());
        b.submit_streaming(req(vec![2], 1), tx);
        let mut pumps = 0;
        while b.pump().unwrap() > 0 {
            pumps += 1;
            assert!(pumps < 10);
        }
        let dones = std::iter::from_fn(|| rx.try_recv().ok())
            .filter(|e| matches!(e, StreamEvent::Done(_)))
            .count();
        assert_eq!(dones, 2);
    }
}
