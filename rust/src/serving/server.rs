//! Std-only HTTP/1.1 serving frontend over the continuous-batching
//! engine.
//!
//! Threading model: one *dedicated engine-driver thread* owns the PJRT
//! client, the compiled bundle, and the device-resident [`Engine`] —
//! none of which are `Send` — and pumps it in a loop; connection
//! threads only touch the shared [`Scheduler`] and per-request
//! channels.  The driver admits queued requests per the configured
//! [`Policy`] whenever lanes free up, so the device never idles while
//! requests wait and HTTP I/O never blocks a decode step.
//!
//! Endpoints (all JSON, hand-rolled on the repo's `json.rs`):
//!
//! * `POST /v1/completions` — body `{"prompt": [ints], "max_tokens",
//!   "temperature", "top_k", "greedy", "stream", "deadline_ms"}`.
//!   Non-streaming answers one JSON document; `"stream": true` answers
//!   `Transfer-Encoding: chunked` with one NDJSON line per sampled
//!   token as it leaves the device.
//! * `GET /healthz` — liveness.
//! * `GET /metrics` — engine counters ([`EngineBackend::stats`] +
//!   transfer bytes), scheduler queue/latency histograms, uptime.
//!
//! Backpressure: the scheduler queue is bounded; overflow is answered
//! `429 Too Many Requests` with `Retry-After` before any engine work
//! happens.
//!
//! [`Engine`]: crate::serving::Engine

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::json::{self, Json};
use crate::serving::clock::{Clock, SharedClock, WallClock};
use crate::serving::engine::{EngineBackend, GenRequest, StreamEvent};
use crate::serving::prefix_cache::PrefixCache;
use crate::serving::sampler::Sampler;
use crate::serving::scheduler::{DegradeCfg, Policy, Rejection, Scheduler};
use crate::serving::telemetry::{self, Telemetry};

const MAX_LINE: usize = 8 * 1024;
const MAX_HEADERS: usize = 64;
const MAX_BODY: usize = 1024 * 1024;
/// How often the driver republishes engine stats for `/metrics`.
const PUBLISH_EVERY: Duration = Duration::from_millis(50);
/// Driver idle wait and connection event-poll granularity.
const TICK: Duration = Duration::from_millis(25);

/// Serving frontend configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bounded scheduler queue; overflow answers 429.
    pub queue_cap: usize,
    pub policy: Policy,
    /// `max_tokens` default when the request omits it.
    pub default_max_new: usize,
    /// Hard cap on `max_tokens` (requests are clamped, not rejected).
    pub max_new_cap: usize,
    /// Requests with longer prompts are rejected with 400.
    pub max_prompt_len: usize,
    /// When known (from the manifest), prompt token ids are range-checked.
    pub vocab: Option<usize>,
    /// Give up on a request (504 / error chunk) after this long.
    pub request_timeout: Duration,
    /// Keep-alive: close an idle connection after this long without a
    /// new request (also the read timeout while parsing one).
    pub keepalive_idle: Duration,
    /// Keep-alive: maximum requests served on one connection before the
    /// server closes it (bounds how long a single client can pin a
    /// connection thread).
    pub keepalive_max_requests: usize,
    /// Engine prefill chunk width C (from the artifact manifest): the
    /// scheduler's shortest-prompt policy costs prompts in ⌈len/C⌉
    /// prefill dispatches instead of raw tokens.  1 = single-token
    /// prompt ingestion.
    pub prefill_chunk: usize,
    /// Completed request spans kept for `GET /v1/trace/<id>` (a bounded
    /// ring; stage histograms observe every request regardless).
    pub trace_ring: usize,
    /// Per-mille of request ids retained in the trace ring.  1000 (the
    /// default) keeps every span, so `X-Request-Id` always resolves.
    pub span_sample_permille: u64,
    /// Request-lifecycle + expert telemetry.  On by default (the whole
    /// point is always-on observability); the off switch exists so the
    /// loadgen A/B bench can price it.
    pub telemetry: bool,
    /// Compile-time expert top-k ceiling from the artifact manifest.
    /// Bounds the per-request `expert_k` override (validated at the
    /// HTTP boundary — out-of-range answers 400, never a silent clamp);
    /// `None` on non-MoE artifacts, where the override is rejected.
    pub expert_k_max: Option<usize>,
    /// Adaptive expert top-k under load (`--degrade-k
    /// min_k:hi_wm:lo_wm`); `None` pins k at `expert_k_max`.
    pub degrade_k: Option<DegradeCfg>,
    /// Speculative decode draft length K (`--speculate K`; 0 = off).
    /// Validated against the artifact's `verify_logits` flag at CLI
    /// config time; flows into the scheduler's shortest-prompt cost
    /// model, and the engine backend is armed by the caller.
    pub speculate: usize,
    /// Prefix-cache byte budget (`--prefix-cache BYTES`; `None` = off).
    /// Post-prefill lane snapshots are kept keyed by a content hash of
    /// the chunk-aligned token prefix; admissions that share a cached
    /// prefix skip straight to the residual tail.
    pub prefix_cache: Option<u64>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            queue_cap: 64,
            policy: Policy::Fifo,
            default_max_new: 32,
            max_new_cap: 512,
            max_prompt_len: 4096,
            vocab: None,
            request_timeout: Duration::from_secs(300),
            keepalive_idle: Duration::from_secs(5),
            keepalive_max_requests: 128,
            prefill_chunk: 1,
            trace_ring: telemetry::DEFAULT_RING_CAP,
            span_sample_permille: 1000,
            telemetry: true,
            expert_k_max: None,
            degrade_k: None,
            speculate: 0,
            prefix_cache: None,
        }
    }
}

/// A parsed HTTP request (header names lowercased).
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    /// Raw query string (after `?`, empty when absent) — `/metrics`
    /// uses it for `format=prom`.
    pub query: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }
}

/// Read one CRLF-terminated line, capped at [`MAX_LINE`]; `None` on
/// clean EOF before any byte.
fn read_line(r: &mut impl BufRead) -> Result<Option<String>> {
    let mut buf = Vec::new();
    let n = r
        .by_ref()
        .take(MAX_LINE as u64)
        .read_until(b'\n', &mut buf)?;
    if n == 0 {
        return Ok(None);
    }
    if buf.last() != Some(&b'\n') && n >= MAX_LINE {
        return Err(Error::Serving("header line too long".into()));
    }
    while matches!(buf.last(), Some(b'\n') | Some(b'\r')) {
        buf.pop();
    }
    String::from_utf8(buf)
        .map(Some)
        .map_err(|_| Error::Serving("non-utf8 header line".into()))
}

/// Parse one HTTP/1.1 request (request line, headers, content-length
/// body).  `Ok(None)` when the peer closed before sending anything.
pub fn read_request(r: &mut impl BufRead) -> Result<Option<HttpRequest>> {
    let Some(line) = read_line(r)? else {
        return Ok(None);
    };
    let mut parts = line.split_whitespace();
    let (method, target) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v)) if v.starts_with("HTTP/1.") => {
            (m.to_string(), t.to_string())
        }
        _ => {
            return Err(Error::Serving(format!("bad request line {line:?}")))
        }
    };
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    let mut headers = Vec::new();
    loop {
        let Some(line) = read_line(r)? else {
            return Err(Error::Serving("eof in headers".into()));
        };
        if line.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(Error::Serving("too many headers".into()));
        }
        let Some((k, v)) = line.split_once(':') else {
            return Err(Error::Serving(format!("bad header {line:?}")));
        };
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    let req =
        HttpRequest { method, path, query, headers, body: Vec::new() };
    if req.header("transfer-encoding").is_some() {
        return Err(Error::Serving(
            "chunked request bodies not supported".into(),
        ));
    }
    let len = match req.header("content-length") {
        None => 0,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| Error::Serving("bad content-length".into()))?,
    };
    if len > MAX_BODY {
        return Err(Error::Serving("request body too large".into()));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(Some(HttpRequest { body, ..req }))
}

fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// The `Connection` response-header value for a close decision.
pub fn conn_header(close: bool) -> &'static str {
    if close {
        "close"
    } else {
        "keep-alive"
    }
}

/// Serialize a complete (non-chunked) response.  The `Connection`
/// header is the caller's to add (via `extra_headers`): the server
/// decides keep-alive per connection, not per serializer call.
pub fn http_response(
    status: u16,
    content_type: &str,
    body: &[u8],
    extra_headers: &[(&str, &str)],
) -> Vec<u8> {
    let mut out = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\n",
        status_reason(status),
        body.len()
    )
    .into_bytes();
    for (k, v) in extra_headers {
        out.extend_from_slice(format!("{k}: {v}\r\n").as_bytes());
    }
    out.extend_from_slice(b"\r\n");
    out.extend_from_slice(body);
    out
}

/// Response head that opens a chunked stream.
pub fn chunked_response_head(content_type: &str, close: bool) -> Vec<u8> {
    chunked_response_head_with(content_type, close, &[])
}

/// [`chunked_response_head`] with extra response headers (e.g. the
/// completion stream's `X-Request-Id`).
pub fn chunked_response_head_with(
    content_type: &str,
    close: bool,
    extra_headers: &[(&str, &str)],
) -> Vec<u8> {
    let mut out = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: {content_type}\r\n\
         Transfer-Encoding: chunked\r\nConnection: {}\r\n",
        conn_header(close)
    )
    .into_bytes();
    for (k, v) in extra_headers {
        out.extend_from_slice(format!("{k}: {v}\r\n").as_bytes());
    }
    out.extend_from_slice(b"\r\n");
    out
}

/// One chunk of a chunked transfer: `<hex len>\r\n<data>\r\n`.
pub fn encode_chunk(data: &[u8]) -> Vec<u8> {
    let mut out = format!("{:x}\r\n", data.len()).into_bytes();
    out.extend_from_slice(data);
    out.extend_from_slice(b"\r\n");
    out
}

/// The terminal chunk of a chunked transfer.
pub const LAST_CHUNK: &[u8] = b"0\r\n\r\n";

fn write_json(
    w: &mut impl Write,
    status: u16,
    body: &Json,
    extra_headers: &[(&str, &str)],
    close: bool,
) -> std::io::Result<()> {
    let mut headers: Vec<(&str, &str)> =
        vec![("Connection", conn_header(close))];
    headers.extend_from_slice(extra_headers);
    let bytes = http_response(
        status,
        "application/json",
        body.to_string_compact().as_bytes(),
        &headers,
    );
    w.write_all(&bytes)
}

fn err_json(msg: &str) -> Json {
    json::obj(vec![("error", json::s(msg))])
}

/// A parsed `/v1/completions` body.
#[derive(Debug, Clone)]
pub struct CompletionRequest {
    pub gen: GenRequest,
    pub stream: bool,
    pub deadline: Option<Duration>,
}

/// Parse and validate a completion request body against the server
/// limits; `Err` carries the client-facing message (answered as 400).
pub fn parse_completion(
    body: &[u8],
    cfg: &ServerConfig,
) -> std::result::Result<CompletionRequest, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not utf-8")?;
    let doc = Json::parse(text).map_err(|e| format!("bad json: {e}"))?;
    let prompt_json = doc
        .opt("prompt")
        .ok_or("missing field \"prompt\" (array of token ids)")?;
    let arr = prompt_json
        .as_arr()
        .map_err(|_| "\"prompt\" must be an array of token ids")?;
    if arr.is_empty() {
        return Err("\"prompt\" must not be empty".into());
    }
    if arr.len() > cfg.max_prompt_len {
        return Err(format!(
            "prompt too long ({} > max {})",
            arr.len(),
            cfg.max_prompt_len
        ));
    }
    let mut prompt = Vec::with_capacity(arr.len());
    for v in arr {
        let t = v
            .as_i64()
            .map_err(|_| "prompt entries must be integers".to_string())?;
        if t < 0 || t > i32::MAX as i64 {
            return Err(format!("prompt token {t} out of range"));
        }
        if let Some(vocab) = cfg.vocab {
            if t as usize >= vocab {
                return Err(format!(
                    "prompt token {t} >= vocab_size {vocab}"
                ));
            }
        }
        prompt.push(t as i32);
    }
    let max_tokens = match doc.opt("max_tokens") {
        None => cfg.default_max_new,
        Some(v) => v
            .as_usize()
            .map_err(|_| "\"max_tokens\" must be a non-negative integer")?,
    }
    .clamp(1, cfg.max_new_cap.max(1));
    let temperature = match doc.opt("temperature") {
        None => 1.0f32,
        Some(v) => {
            let t = v.as_f64().map_err(|_| "\"temperature\" must be a number")?;
            if !(t > 0.0 && t.is_finite()) {
                return Err("\"temperature\" must be positive".into());
            }
            t as f32
        }
    };
    let top_k = match doc.opt("top_k") {
        None => 0,
        Some(v) => v
            .as_usize()
            .map_err(|_| "\"top_k\" must be a non-negative integer")?,
    };
    // a top_k past the vocabulary is a client bug (it silently meant
    // "no filtering"); refuse it rather than guess intent
    if let Some(vocab) = cfg.vocab {
        if top_k > vocab {
            return Err(format!("\"top_k\" {top_k} > vocab_size {vocab}"));
        }
    }
    let expert_k = match doc.opt("expert_k") {
        None => None,
        Some(v) => {
            let k = v
                .as_usize()
                .map_err(|_| "\"expert_k\" must be a positive integer")?;
            let Some(k_max) = cfg.expert_k_max else {
                return Err("\"expert_k\" is not supported by this \
                            artifact (not a MoE model)"
                    .into());
            };
            if k < 1 || k > k_max {
                return Err(format!(
                    "\"expert_k\" {k} outside [1, {k_max}]"
                ));
            }
            Some(k)
        }
    };
    let greedy = match doc.opt("greedy") {
        None => false,
        Some(v) => v.as_bool().map_err(|_| "\"greedy\" must be a bool")?,
    };
    let stream = match doc.opt("stream") {
        None => false,
        Some(v) => v.as_bool().map_err(|_| "\"stream\" must be a bool")?,
    };
    let deadline = match doc.opt("deadline_ms") {
        None => None,
        Some(v) => {
            let ms = v
                .as_usize()
                .map_err(|_| "\"deadline_ms\" must be a non-negative integer")?;
            Some(Duration::from_millis(ms.min(86_400_000) as u64))
        }
    };
    Ok(CompletionRequest {
        gen: GenRequest {
            prompt,
            max_new_tokens: max_tokens,
            sampler: Sampler { temperature, top_k, greedy },
            expert_k,
        },
        stream,
        deadline,
    })
}

/// What the connection-handling layer needs from the serving topology
/// behind it.  Implemented by the single-engine [`Shared`] state here
/// and by the multi-engine fleet in [`crate::serving::router`], so the
/// HTTP frontend (request parsing, keep-alive, routing, backpressure
/// mapping) is written once.
pub(crate) trait ServeState: Send + Sync {
    fn cfg(&self) -> &ServerConfig;
    fn sched(&self) -> &Scheduler;
    /// False once no engine can make progress (driver dead / whole
    /// fleet unhealthy) — new completions answer 503 immediately.
    fn alive(&self) -> bool;
    /// Server teardown began — keep-alive loops must stop accepting
    /// further requests on their connection so the accept scope can
    /// join promptly.
    fn shutting_down(&self) -> bool;
    /// The full `/metrics` document.
    fn metrics_json(&self) -> Json;
    /// Time source for request latency stamps (wall clock in
    /// production; the fleet's injected clock behind the router).
    fn clock(&self) -> &SharedClock;
    /// Request-lifecycle span registry (trace lookups, stage
    /// histograms, expert utilization).
    fn telemetry(&self) -> &Arc<Telemetry>;
    /// Whether the connection handlers should derive span stages
    /// (prefill / tokens / terminal) from the event stream they relay.
    /// True for the single-engine topology, where stream events flow
    /// straight from the backend to the connection thread; false
    /// behind the fleet router, whose relay records the same stages —
    /// recording in both places would double-count tokens.
    fn stream_observes_stages(&self) -> bool {
        false
    }
}

/// State shared between the accept loop, connection threads, and the
/// engine-driver thread.
struct Shared {
    cfg: ServerConfig,
    sched: Scheduler,
    engine_stats: Mutex<BTreeMap<String, f64>>,
    shutdown: Arc<AtomicBool>,
    driver_dead: AtomicBool,
    started: Instant,
    clock: SharedClock,
    telemetry: Arc<Telemetry>,
    /// Shared post-prefill snapshot cache (`--prefix-cache BYTES`).
    /// The driver arms its backend with a clone; `/metrics` reads the
    /// global entry/byte/eviction state from here.
    prefix_cache: Option<Arc<PrefixCache>>,
}

impl ServeState for Shared {
    fn cfg(&self) -> &ServerConfig {
        &self.cfg
    }

    fn sched(&self) -> &Scheduler {
        &self.sched
    }

    fn alive(&self) -> bool {
        !self.driver_dead.load(Ordering::Relaxed)
    }

    fn shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::Relaxed)
    }

    fn metrics_json(&self) -> Json {
        metrics_document(self)
    }

    fn clock(&self) -> &SharedClock {
        &self.clock
    }

    fn telemetry(&self) -> &Arc<Telemetry> {
        &self.telemetry
    }

    fn stream_observes_stages(&self) -> bool {
        true
    }
}

/// Handle passed to the engine-init closure on the driver thread; call
/// [`Driver::drive`] with the backend once it is constructed.  The
/// backend is built *inside* the driver thread because the PJRT client,
/// bundle, and engine are not `Send`.
pub struct Driver {
    shared: Arc<Shared>,
}

impl Driver {
    fn publish(&self, backend: &mut dyn EngineBackend) {
        let mut stats = backend.stats();
        stats.insert(
            "free_lanes".into(),
            backend.free_lanes() as f64,
        );
        *self.shared.engine_stats.lock().unwrap() = stats;
        // drain the per-layer expert-selection accumulator into the
        // telemetry aggregate (None: non-MoE / pre-counts artifact)
        match backend.take_expert_counts() {
            Some(counts) => self
                .shared
                .telemetry
                .record_expert_counts(0, &counts),
            None => {
                self.shared.telemetry.note_expert_stats_unavailable()
            }
        }
    }

    /// The engine-driver loop: admit per policy while lanes are free,
    /// pump, republish stats, idle on the scheduler condvar when
    /// drained.  Returns when the server shuts down.
    pub fn drive(self, backend: &mut dyn EngineBackend) -> Result<()> {
        let sh = &self.shared;
        // the manifest promised a chunk width; the engine reports what
        // it actually mapped (1 after a prefill-signature fallback) so
        // spf keeps costing prompts in real dispatch units
        sh.sched.observe_prefill_chunk(backend.prefill_chunk());
        // ...and its expert top-k ceiling, which seeds the scheduler's
        // adaptive-k target (and the /metrics k gauges) on MoE backends
        if let Some(k) = backend.expert_k_max() {
            sh.sched.observe_expert_k_max(k);
        }
        // arm the shared prefix cache: the backend snapshots lanes on
        // chunk boundaries and seeds cache-hit admissions from them
        // (Engine no-ops if the artifact lacks the snapshot programs)
        if let Some(cache) = sh.prefix_cache.clone() {
            backend.set_prefix_cache(cache);
        }
        self.publish(backend);
        let mut last_publish = sh.clock.now();
        while !sh.shutdown.load(Ordering::Relaxed) {
            let now = sh.clock.now();
            // expire first, even with zero free lanes: dead requests
            // must not hold queue slots or keep their clients waiting
            sh.sched.expire(now);
            // adaptive expert top-k: evaluate the hysteresis once per
            // iteration (journals k_degrade/k_restore), then run the
            // engine at the current fleet target — applying the target
            // rather than the transition keeps late-started drivers
            // consistent, and the engine re-uploads only on change
            sh.sched.eval_degrade();
            if let Some(k) = sh.sched.target_expert_k() {
                backend.set_expert_k(k);
            }
            // speculative-K autotune: feed the live accept-rate window,
            // evaluate the hysteresis (journals spec_k_lower/raise),
            // and run the backend at the current target — same
            // target-not-transition discipline as adaptive expert-k
            let (drafted, accepted) = backend.take_spec_feedback();
            sh.sched.observe_spec(drafted, accepted);
            if sh.sched.eval_spec().is_some() {
                backend.set_speculate(sh.sched.target_speculate());
            }
            while backend.free_lanes() > 0 {
                match sh.sched.take_next(now) {
                    Some(q) => {
                        // single-engine "placed": handed to the one
                        // backend (no engine id to attribute)
                        sh.telemetry.placed(q.id, None);
                        backend.submit_streaming(q.req, q.events)
                    }
                    None => break,
                }
            }
            let remaining = backend.pump()?;
            let after = sh.clock.now();
            if after.duration_since(last_publish) >= PUBLISH_EVERY {
                self.publish(backend);
                last_publish = after;
            }
            if remaining == 0 {
                sh.sched.wait_for_work(TICK);
            }
        }
        sh.sched.drain_shutdown();
        self.publish(backend);
        Ok(())
    }
}

/// Run the serving frontend until `shutdown` is set.
///
/// `driver_fn` runs on the dedicated engine-driver thread; it must
/// construct the backend (PJRT client + bundle + [`Engine`], or a
/// [`MockBackend`]) and hand it to [`Driver::drive`].  If it returns an
/// error — e.g. artifacts failed to load — the server shuts down and
/// that error is returned.
///
/// [`Engine`]: crate::serving::Engine
/// [`MockBackend`]: crate::serving::MockBackend
pub fn serve<F>(
    listener: TcpListener,
    cfg: ServerConfig,
    shutdown: Arc<AtomicBool>,
    driver_fn: F,
) -> Result<()>
where
    F: FnOnce(Driver) -> Result<()> + Send,
{
    let clock = WallClock::shared();
    let telemetry = if cfg.telemetry {
        Telemetry::new(clock.clone())
            .with_ring_cap(cfg.trace_ring)
            .with_sample_permille(cfg.span_sample_permille)
            .shared()
    } else {
        Telemetry::disabled(clock.clone()).shared()
    };
    let sched = Scheduler::new(cfg.queue_cap, cfg.policy)
        .with_prefill_chunk(cfg.prefill_chunk)
        .with_speculate(cfg.speculate)
        .with_clock(clock.clone())
        .with_telemetry(telemetry.clone());
    let sched = match (cfg.degrade_k, cfg.expert_k_max) {
        (Some(d), Some(k)) => sched.with_degrade_k(d, k),
        _ => sched,
    };
    let prefix_cache = cfg.prefix_cache.map(PrefixCache::shared);
    let sched = match &prefix_cache {
        Some(c) => sched.with_prefix_cache(c.clone()),
        None => sched,
    };
    let shared = Arc::new(Shared {
        sched,
        cfg,
        engine_stats: Mutex::new(BTreeMap::new()),
        shutdown,
        driver_dead: AtomicBool::new(false),
        started: clock.now(),
        clock,
        telemetry,
        prefix_cache,
    });
    listener.set_nonblocking(true)?;
    std::thread::scope(|scope| -> Result<()> {
        let driver_shared = shared.clone();
        let driver = scope.spawn(move || {
            let r = driver_fn(Driver { shared: driver_shared.clone() });
            driver_shared.driver_dead.store(true, Ordering::SeqCst);
            driver_shared.shutdown.store(true, Ordering::SeqCst);
            // drive() drains on a clean exit, but an early driver_fn
            // failure (e.g. artifacts missing) must also terminate any
            // requests enqueued while the engine was still loading —
            // otherwise their connection threads block serve()'s scope
            // until request_timeout
            driver_shared.sched.drain_shutdown();
            r
        });
        while !shared.shutdown.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let conn_shared = shared.clone();
                    scope.spawn(move || handle_connection(stream, conn_shared));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => {
                    shared.shutdown.store(true, Ordering::SeqCst);
                    let _ = driver.join();
                    return Err(e.into());
                }
            }
        }
        match driver.join() {
            Ok(r) => r,
            Err(_) => Err(Error::Serving("engine driver panicked".into())),
        }
    })
}

/// Serve one connection: an HTTP/1.1 keep-alive loop.  Up to
/// `keepalive_max_requests` requests are answered on the same socket;
/// the connection closes on `Connection: close`, a parse or write
/// error, or `keepalive_idle` passing without a new request.
pub(crate) fn handle_connection<S: ServeState>(
    stream: TcpStream,
    sh: Arc<S>,
) {
    // BSD-derived platforms make accepted sockets inherit the
    // listener's O_NONBLOCK (set for the shutdown-aware accept loop);
    // reads here must block
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_nodelay(true);
    // doubles as the keep-alive idle timeout: a connection holding no
    // in-flight request is closed when the next read times out
    let _ = stream.set_read_timeout(Some(sh.cfg().keepalive_idle));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    let max_requests = sh.cfg().keepalive_max_requests.max(1);
    for served in 0..max_requests {
        let req = match read_request(&mut reader) {
            Ok(Some(r)) => r,
            // clean close between requests
            Ok(None) => return,
            Err(Error::Io(ref e))
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                // keep-alive idle timeout: close quietly
                return;
            }
            Err(e) => {
                let _ = write_json(
                    &mut writer,
                    400,
                    &err_json(&e.to_string()),
                    &[],
                    true,
                );
                return;
            }
        };
        // teardown in progress: answer this request, advertise close,
        // and release the connection thread so the accept scope joins
        // without waiting out keepalive_max_requests
        let close = served + 1 >= max_requests
            || sh.shutting_down()
            || req
                .header("connection")
                .is_some_and(|v| v.eq_ignore_ascii_case("close"));
        if route(&mut writer, &req, sh.as_ref(), close).is_err() || close {
            return;
        }
    }
}

fn route<S: ServeState>(
    w: &mut TcpStream,
    req: &HttpRequest,
    sh: &S,
    close: bool,
) -> std::io::Result<()> {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => write_json(
            w,
            200,
            &json::obj(vec![("status", json::s("ok"))]),
            &[],
            close,
        ),
        ("GET", "/metrics") => {
            let doc = sh.metrics_json();
            // ?format=prom: the same registry rendered as Prometheus
            // text exposition (JSON stays the default view)
            if req.query.split('&').any(|kv| kv == "format=prom") {
                let body = telemetry::render_prom(&doc);
                w.write_all(&http_response(
                    200,
                    telemetry::PROM_CONTENT_TYPE,
                    body.as_bytes(),
                    &[("Connection", conn_header(close))],
                ))
            } else {
                write_json(w, 200, &doc, &[], close)
            }
        }
        ("GET", path) if path.starts_with("/v1/trace/") => {
            let id = path["/v1/trace/".len()..].parse::<u64>().ok();
            match id.and_then(|id| sh.telemetry().trace_json(id)) {
                Some(doc) => write_json(w, 200, &doc, &[], close),
                None => write_json(
                    w,
                    404,
                    &err_json("unknown or evicted trace id"),
                    &[],
                    close,
                ),
            }
        }
        ("POST", "/v1/completions") => {
            handle_completion(w, &req.body, sh, close)
        }
        (_, "/healthz") | (_, "/metrics") | (_, "/v1/completions") => {
            write_json(w, 405, &err_json("method not allowed"), &[], close)
        }
        (_, path) if path.starts_with("/v1/trace/") => {
            write_json(w, 405, &err_json("method not allowed"), &[], close)
        }
        _ => write_json(w, 404, &err_json("not found"), &[], close),
    }
}

fn metrics_document(sh: &Shared) -> Json {
    let engine = Json::Obj(
        sh.engine_stats
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), json::num(*v)))
            .collect(),
    );
    let mut doc = vec![
        ("engine", engine),
        ("experts", sh.telemetry.experts_json()),
        ("scheduler", sh.sched.metrics_json()),
        ("stages", sh.telemetry.stages_json()),
    ];
    if let Some(cache) = &sh.prefix_cache {
        doc.push(("prefix_cache", cache.metrics_json()));
    }
    doc.push((
        "server",
        json::obj(vec![
            (
                "uptime_s",
                json::num(
                    sh.clock
                        .now()
                        .duration_since(sh.started)
                        .as_secs_f64(),
                ),
            ),
            (
                "driver_alive",
                Json::Bool(!sh.driver_dead.load(Ordering::Relaxed)),
            ),
        ]),
    ));
    json::obj(doc)
}

fn handle_completion<S: ServeState>(
    w: &mut TcpStream,
    body: &[u8],
    sh: &S,
    close: bool,
) -> std::io::Result<()> {
    let creq = match parse_completion(body, sh.cfg()) {
        Ok(c) => c,
        Err(msg) => return write_json(w, 400, &err_json(&msg), &[], close),
    };
    if !sh.alive() {
        return write_json(
            w,
            503,
            &err_json("no engine available"),
            &[],
            close,
        );
    }
    let (tx, rx) = mpsc::channel();
    let t0 = sh.clock().now();
    let stream_mode = creq.stream;
    let id = match sh.sched().enqueue(creq.gen, creq.deadline, tx) {
        Ok(id) => id,
        Err(Rejection::QueueFull) => {
            return write_json(
                w,
                429,
                &err_json("queue full"),
                &[("Retry-After", "1")],
                close,
            )
        }
        Err(Rejection::ShuttingDown) => {
            return write_json(w, 503, &err_json("shutting down"), &[], close)
        }
    };
    if stream_mode {
        stream_completion(w, &rx, id, t0, sh, close)
    } else {
        unary_completion(w, &rx, id, t0, sh, close)
    }
}

/// Record a span stage from the event stream, but only on topologies
/// whose connection threads see the raw backend events (single-engine;
/// the fleet's relay records these itself).
fn observe_stage<S: ServeState>(sh: &S, f: impl FnOnce(&Telemetry)) {
    if sh.stream_observes_stages() {
        f(sh.telemetry());
    }
}

/// Wait out a request's event stream and answer one JSON document.
fn unary_completion<S: ServeState>(
    w: &mut TcpStream,
    rx: &mpsc::Receiver<StreamEvent>,
    id: u64,
    t0: Instant,
    sh: &S,
    close: bool,
) -> std::io::Result<()> {
    // queue_ms is measured here, enqueue -> Admitted: the engine's own
    // queue_time misses the scheduler-queue wait (the engine only sees
    // a request once a lane is about to take it)
    let mut queue_ms: Option<f64> = None;
    let rid = id.to_string();
    let rid_hdr: &[(&str, &str)] = &[("X-Request-Id", rid.as_str())];
    loop {
        match rx.recv_timeout(TICK) {
            Ok(StreamEvent::Admitted) => {
                observe_stage(sh, |t| t.prefill_started(id));
                let waited = sh.clock().now().duration_since(t0);
                queue_ms = Some(waited.as_secs_f64() * 1e3);
            }
            Ok(StreamEvent::Token(_)) => {
                observe_stage(sh, |t| t.token(id));
            }
            Ok(StreamEvent::Done(res)) => {
                observe_stage(sh, |t| t.terminal(id, "done"));
                let e2e = sh.clock().now().duration_since(t0);
                sh.sched().observe_completion(e2e, res.tokens.len());
                let tokens =
                    res.tokens.iter().map(|&t| json::num(t as f64)).collect();
                let body = json::obj(vec![
                    ("id", json::num(id as f64)),
                    ("tokens", json::arr(tokens)),
                    ("prompt_len", json::num(res.prompt_len as f64)),
                    (
                        "queue_ms",
                        json::num(queue_ms.unwrap_or_else(|| {
                            res.queue_time.as_secs_f64() * 1e3
                        })),
                    ),
                    ("run_ms", json::num(res.run_time.as_secs_f64() * 1e3)),
                ]);
                return write_json(w, 200, &body, rid_hdr, close);
            }
            Ok(StreamEvent::Dropped(reason)) => {
                observe_stage(sh, |t| t.terminal(id, "dropped"));
                return write_json(
                    w,
                    503,
                    &err_json(reason.as_str()),
                    rid_hdr,
                    close,
                );
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let waited = sh.clock().now().duration_since(t0);
                if waited > sh.cfg().request_timeout {
                    return write_json(
                        w,
                        504,
                        &err_json("request timed out"),
                        rid_hdr,
                        close,
                    );
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                return write_json(
                    w,
                    500,
                    &err_json("engine driver gone"),
                    rid_hdr,
                    close,
                );
            }
        }
    }
}

/// Stream a request's tokens as NDJSON lines over chunked transfer
/// encoding, one chunk per sampled token.
fn stream_completion<S: ServeState>(
    w: &mut TcpStream,
    rx: &mpsc::Receiver<StreamEvent>,
    id: u64,
    t0: Instant,
    sh: &S,
    close: bool,
) -> std::io::Result<()> {
    let rid = id.to_string();
    w.write_all(&chunked_response_head_with(
        "application/x-ndjson",
        close,
        &[("X-Request-Id", rid.as_str())],
    ))?;
    let send_line = |w: &mut TcpStream, doc: &Json| -> std::io::Result<()> {
        let mut line = doc.to_string_compact().into_bytes();
        line.push(b'\n');
        w.write_all(&encode_chunk(&line))
    };
    // enqueue -> Admitted, covering the scheduler-queue wait the
    // engine's own queue_time can't see
    let mut queue_ms: Option<f64> = None;
    loop {
        match rx.recv_timeout(TICK) {
            Ok(StreamEvent::Admitted) => {
                observe_stage(sh, |t| t.prefill_started(id));
                let waited = sh.clock().now().duration_since(t0);
                queue_ms = Some(waited.as_secs_f64() * 1e3);
                send_line(
                    w,
                    &json::obj(vec![
                        ("event", json::s("admitted")),
                        ("id", json::num(id as f64)),
                    ]),
                )?;
            }
            Ok(StreamEvent::Token(t)) => {
                observe_stage(sh, |tel| tel.token(id));
                send_line(
                    w,
                    &json::obj(vec![("token", json::num(t as f64))]),
                )?;
            }
            Ok(StreamEvent::Done(res)) => {
                observe_stage(sh, |t| t.terminal(id, "done"));
                let e2e = sh.clock().now().duration_since(t0);
                sh.sched().observe_completion(e2e, res.tokens.len());
                send_line(
                    w,
                    &json::obj(vec![
                        ("done", Json::Bool(true)),
                        ("id", json::num(id as f64)),
                        ("tokens", json::num(res.tokens.len() as f64)),
                        (
                            "queue_ms",
                            json::num(queue_ms.unwrap_or_else(|| {
                                res.queue_time.as_secs_f64() * 1e3
                            })),
                        ),
                        (
                            "run_ms",
                            json::num(res.run_time.as_secs_f64() * 1e3),
                        ),
                    ]),
                )?;
                return w.write_all(LAST_CHUNK);
            }
            Ok(StreamEvent::Dropped(reason)) => {
                observe_stage(sh, |t| t.terminal(id, "dropped"));
                send_line(
                    w,
                    &json::obj(vec![("error", json::s(reason.as_str()))]),
                )?;
                return w.write_all(LAST_CHUNK);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let waited = sh.clock().now().duration_since(t0);
                if waited > sh.cfg().request_timeout {
                    send_line(
                        w,
                        &json::obj(vec![(
                            "error",
                            json::s("request timed out"),
                        )]),
                    )?;
                    return w.write_all(LAST_CHUNK);
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                send_line(
                    w,
                    &json::obj(vec![(
                        "error",
                        json::s("engine driver gone"),
                    )]),
                )?;
                return w.write_all(LAST_CHUNK);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_request_with_body() {
        let raw = b"POST /v1/completions HTTP/1.1\r\nHost: x\r\n\
                    Content-Length: 4\r\n\r\nabcd";
        let req = read_request(&mut Cursor::new(&raw[..]))
            .unwrap()
            .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/completions");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn strips_query_and_handles_no_body() {
        let raw = b"GET /metrics?pretty=1 HTTP/1.1\r\n\r\n";
        let req = read_request(&mut Cursor::new(&raw[..]))
            .unwrap()
            .unwrap();
        assert_eq!(req.path, "/metrics");
        assert_eq!(req.query, "pretty=1");
        assert!(req.body.is_empty());
    }

    #[test]
    fn eof_and_garbage_are_distinguished() {
        assert!(read_request(&mut Cursor::new(b"" as &[u8]))
            .unwrap()
            .is_none());
        assert!(read_request(&mut Cursor::new(b"nonsense\r\n\r\n" as &[u8]))
            .is_err());
    }

    #[test]
    fn oversized_body_rejected() {
        let raw = format!(
            "POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY + 1
        );
        assert!(read_request(&mut Cursor::new(raw.as_bytes())).is_err());
    }

    #[test]
    fn chunk_framing_roundtrip() {
        let c = encode_chunk(b"hello");
        assert_eq!(c, b"5\r\nhello\r\n");
        assert_eq!(encode_chunk(b""), b"0\r\n\r\n");
        assert_eq!(LAST_CHUNK, b"0\r\n\r\n");
        // 16+ byte payload exercises multi-digit hex length
        let c = encode_chunk(&[b'x'; 26]);
        assert!(c.starts_with(b"1a\r\n"));
    }

    #[test]
    fn completion_parsing_applies_defaults_and_overrides() {
        let cfg = ServerConfig::default();
        let c = parse_completion(
            br#"{"prompt": [1, 2, 3]}"#,
            &cfg,
        )
        .unwrap();
        assert_eq!(c.gen.prompt, vec![1, 2, 3]);
        assert_eq!(c.gen.max_new_tokens, cfg.default_max_new);
        assert!(!c.gen.sampler.greedy);
        assert_eq!(c.gen.sampler.top_k, 0);
        assert!(!c.stream);
        assert!(c.deadline.is_none());

        let c = parse_completion(
            br#"{"prompt": [5], "max_tokens": 7, "temperature": 0.5,
                 "top_k": 40, "greedy": true, "stream": true,
                 "deadline_ms": 250}"#,
            &cfg,
        )
        .unwrap();
        assert_eq!(c.gen.max_new_tokens, 7);
        assert!((c.gen.sampler.temperature - 0.5).abs() < 1e-6);
        assert_eq!(c.gen.sampler.top_k, 40);
        assert!(c.gen.sampler.greedy);
        assert!(c.stream);
        assert_eq!(c.deadline, Some(Duration::from_millis(250)));
    }

    #[test]
    fn completion_parsing_rejects_bad_input() {
        let cfg = ServerConfig { vocab: Some(100), ..Default::default() };
        for body in [
            &br#"{}"#[..],
            br#"{"prompt": []}"#,
            br#"{"prompt": "text"}"#,
            br#"{"prompt": [1.5]}"#,
            br#"{"prompt": [-1]}"#,
            br#"{"prompt": [100]}"#,
            br#"{"prompt": [1], "temperature": 0}"#,
            br#"{"prompt": [1], "max_tokens": "many"}"#,
            br#"not json"#,
        ] {
            assert!(
                parse_completion(body, &cfg).is_err(),
                "{}",
                String::from_utf8_lossy(body)
            );
        }
    }

    #[test]
    fn completion_parsing_validates_overrides_at_the_boundary() {
        let cfg = ServerConfig {
            vocab: Some(100),
            expert_k_max: Some(4),
            ..Default::default()
        };
        // in-range overrides thread through untouched
        let c = parse_completion(br#"{"prompt": [1], "expert_k": 2}"#, &cfg)
            .unwrap();
        assert_eq!(c.gen.expert_k, Some(2));
        let c = parse_completion(br#"{"prompt": [1], "top_k": 100}"#, &cfg)
            .unwrap();
        assert_eq!(c.gen.sampler.top_k, 100);
        assert_eq!(c.gen.expert_k, None);
        // out-of-range answers 400 — never a silent clamp
        for body in [
            &br#"{"prompt": [1], "top_k": 101}"#[..],
            br#"{"prompt": [1], "expert_k": 0}"#,
            br#"{"prompt": [1], "expert_k": 5}"#,
            br#"{"prompt": [1], "expert_k": "two"}"#,
        ] {
            assert!(
                parse_completion(body, &cfg).is_err(),
                "{}",
                String::from_utf8_lossy(body)
            );
        }
        // non-MoE artifact: the expert_k override itself is unsupported
        let dense = ServerConfig { vocab: Some(100), ..Default::default() };
        assert!(parse_completion(
            br#"{"prompt": [1], "expert_k": 1}"#,
            &dense
        )
        .is_err());
        // without a known vocab, top_k has no bound to check against
        let novocab = ServerConfig::default();
        assert!(parse_completion(
            br#"{"prompt": [1], "top_k": 9999}"#,
            &novocab
        )
        .is_ok());
    }

    #[test]
    fn completion_parsing_clamps_max_tokens() {
        let cfg = ServerConfig { max_new_cap: 10, ..Default::default() };
        let c = parse_completion(
            br#"{"prompt": [1], "max_tokens": 99999}"#,
            &cfg,
        )
        .unwrap();
        assert_eq!(c.gen.max_new_tokens, 10);
    }

    #[test]
    fn response_bytes_have_expected_shape() {
        let r = http_response(429, "application/json", b"{}", &[(
            "Retry-After",
            "1",
        )]);
        let text = String::from_utf8(r).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains("Content-Length: 2\r\n"));
        assert!(text.ends_with("\r\n\r\n{}"));
        let head =
            String::from_utf8(chunked_response_head("text/plain", true))
                .unwrap();
        assert!(head.contains("Transfer-Encoding: chunked\r\n"));
        assert!(head.contains("Connection: close\r\n"));
        let head =
            String::from_utf8(chunked_response_head("text/plain", false))
                .unwrap();
        assert!(head.contains("Connection: keep-alive\r\n"));
        assert_eq!(conn_header(true), "close");
        assert_eq!(conn_header(false), "keep-alive");
    }
}
