//! The inference engine: continuous batching over `step_fwd`.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::rng::Rng;
use crate::runtime::ModelBundle;
use crate::serving::sampler::Sampler;
use crate::tensor::{DType, HostTensor};

/// A generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub sampler: Sampler,
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub prompt: Vec<i32>,
    pub tokens: Vec<i32>,
    pub queue_time: Duration,
    /// time from admission to completion
    pub run_time: Duration,
    pub prompt_len: usize,
}

#[derive(Debug)]
struct Lane {
    /// tokens not yet fed to the model (prompt remainder first)
    pending: VecDeque<i32>,
    generated: Vec<i32>,
    budget: usize,
    sampler: Sampler,
    request: GenRequest,
    queued_at: Instant,
    admitted_at: Instant,
    done_tx: Option<mpsc::Sender<GenResult>>,
}

/// Continuous-batching engine: `serve_batch` lanes step together in one
/// `step_fwd` call per token.
pub struct Engine<'a> {
    bundle: &'a ModelBundle,
    /// indices of the per-layer memory inputs within the input vector
    mem_slots: Vec<usize>,
    tok_idx: usize,
    inputs: Vec<HostTensor>,
    mem_feedback: Vec<(usize, usize)>,
    lanes: Vec<Option<Lane>>,
    queue: VecDeque<Lane>,
    rng: Rng,
    pub steps_executed: u64,
    pub tokens_generated: u64,
}

impl<'a> Engine<'a> {
    /// Create an engine using the given parameters (name, tensor) pairs —
    /// typically `Trainer::params()` or a loaded checkpoint.
    pub fn new(
        bundle: &'a ModelBundle,
        params: &[(String, HostTensor)],
        seed: u64,
    ) -> Result<Self> {
        let fwd = bundle.program("step_fwd")?;
        let spec = &fwd.spec;
        let by_name: HashMap<&str, usize> = spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, b)| (b.name.as_str(), i))
            .collect();
        let mut inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|b| HostTensor::zeros(b.dtype, &b.shape))
            .collect();
        for (name, t) in params {
            if let Some(&i) = by_name.get(format!("0.{name}").as_str()) {
                inputs[i] = t.clone();
            }
        }
        let mem_slots: Vec<usize> = spec
            .inputs
            .iter()
            .enumerate()
            .filter(|(_, b)| b.name.starts_with("1."))
            .map(|(i, _)| i)
            .collect();
        let tok_idx = *by_name
            .get("2")
            .ok_or_else(|| Error::Manifest("step_fwd: no token input".into()))?;
        if spec.inputs[tok_idx].dtype != DType::I32 {
            return Err(Error::Manifest("token input must be i32".into()));
        }
        // outputs: "0" logits, "1.<mems>" -> feed back into "1.<mems>"
        let mem_feedback: Vec<(usize, usize)> = spec
            .outputs
            .iter()
            .enumerate()
            .filter_map(|(oi, ob)| {
                ob.name
                    .strip_prefix("1.")
                    .and_then(|rest| by_name.get(format!("1.{rest}").as_str()))
                    .map(|&ii| (oi, ii))
            })
            .collect();
        let n_lanes = spec.inputs[tok_idx].shape[0];
        Ok(Engine {
            bundle,
            mem_slots,
            tok_idx,
            inputs,
            mem_feedback,
            lanes: (0..n_lanes).map(|_| None).collect(),
            queue: VecDeque::new(),
            rng: Rng::new(seed),
            steps_executed: 0,
            tokens_generated: 0,
        })
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Enqueue a request; the result is delivered on the returned channel
    /// when `pump` drives it to completion.
    pub fn submit(&mut self, req: GenRequest) -> mpsc::Receiver<GenResult> {
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        self.queue.push_back(Lane {
            pending: req.prompt.iter().copied().collect(),
            generated: Vec::new(),
            budget: req.max_new_tokens,
            sampler: req.sampler.clone(),
            request: req,
            queued_at: now,
            admitted_at: now,
            done_tx: Some(tx),
        });
        rx
    }

    /// Zero lane `b`'s XL memory (fresh sequence).
    fn reset_lane_memory(&mut self, lane: usize) {
        for &slot in &self.mem_slots {
            let t = &mut self.inputs[slot];
            // shape [B, M, D]; zero row `lane`
            let row = t.data.len() / t.shape[0];
            let start = lane * row;
            t.data[start..start + row].fill(0);
        }
    }

    fn admit(&mut self) {
        for lane_idx in 0..self.lanes.len() {
            if self.lanes[lane_idx].is_none() {
                if let Some(mut lane) = self.queue.pop_front() {
                    lane.admitted_at = Instant::now();
                    self.reset_lane_memory(lane_idx);
                    self.lanes[lane_idx] = Some(lane);
                } else {
                    break;
                }
            }
        }
    }

    fn active(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }

    /// Run one engine iteration (admit + one step_fwd over all lanes).
    /// Returns the number of still-active lanes.
    pub fn pump(&mut self) -> Result<usize> {
        self.admit();
        if self.active() == 0 {
            return Ok(0);
        }
        let fwd = self.bundle.program("step_fwd")?;
        let b = self.lanes.len();
        // token for each lane: next pending (prompt) token, or last
        // generated token; idle lanes feed 0.
        let mut toks = vec![0i32; b];
        let mut prompt_phase = vec![false; b];
        for (i, slot) in self.lanes.iter_mut().enumerate() {
            if let Some(lane) = slot {
                if let Some(t) = lane.pending.pop_front() {
                    toks[i] = t;
                    // still in prompt phase if more prompt tokens remain
                    prompt_phase[i] = !lane.pending.is_empty();
                } else if let Some(&t) = lane.generated.last() {
                    toks[i] = t;
                }
            }
        }
        self.inputs[self.tok_idx] =
            HostTensor::from_i32(&[b, 1], &toks)?;
        let out = fwd.run(&self.inputs)?;
        self.steps_executed += 1;
        let logits = out[0].as_f32()?;
        let vocab = fwd.spec.outputs[0].shape[1];
        for (oi, ii) in &self.mem_feedback {
            self.inputs[*ii] = out[*oi].clone();
        }
        for i in 0..b {
            let mut finished = false;
            if let Some(lane) = &mut self.lanes[i] {
                if !prompt_phase[i] {
                    let row = &logits[i * vocab..(i + 1) * vocab];
                    let tok = lane.sampler.sample(row, &mut self.rng) as i32;
                    lane.generated.push(tok);
                    self.tokens_generated += 1;
                    if lane.generated.len() >= lane.budget {
                        finished = true;
                    }
                }
            }
            if finished {
                let lane = self.lanes[i].take().unwrap();
                let res = GenResult {
                    prompt: lane.request.prompt.clone(),
                    tokens: lane.generated,
                    queue_time: lane.admitted_at - lane.queued_at,
                    run_time: lane.admitted_at.elapsed(),
                    prompt_len: lane.request.prompt.len(),
                };
                if let Some(tx) = lane.done_tx {
                    let _ = tx.send(res);
                }
            }
        }
        Ok(self.active() + self.queue.len())
    }

    /// Drive all submitted requests to completion, collecting results.
    pub fn run_to_completion(
        &mut self,
        receivers: Vec<mpsc::Receiver<GenResult>>,
    ) -> Result<Vec<GenResult>> {
        while self.pump()? > 0 {}
        let mut out = Vec::new();
        for rx in receivers {
            out.push(rx.recv().map_err(|_| {
                Error::Serving("request dropped without result".into())
            })?);
        }
        Ok(out)
    }

    /// Throughput summary over the engine's lifetime.
    pub fn stats(&self) -> BTreeMap<String, f64> {
        let mut m = BTreeMap::new();
        m.insert("steps_executed".into(), self.steps_executed as f64);
        m.insert("tokens_generated".into(), self.tokens_generated as f64);
        m.insert(
            "mean_batch_occupancy".into(),
            if self.steps_executed > 0 {
                self.tokens_generated as f64 / self.steps_executed as f64
            } else {
                0.0
            },
        );
        m
    }
}
