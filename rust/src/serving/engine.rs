//! The inference engine: continuous batching over `step_fwd` with
//! chunked prefill.
//!
//! Parameters and per-lane XL memories are device-resident
//! ([`DeviceState`]): per `pump` only the token tensor goes
//! host→device and only the logits come back; memory outputs are fed
//! buffer-to-buffer into the next step.  Prompt ingestion is *chunked*
//! when the artifact provides the AOT'd `prefill` program: a pump with
//! any lane still in prompt phase feeds up to `C` pending tokens per
//! lane through one `prefill` dispatch (`[B, C]` tokens + `[B]`
//! active-length vector up, one logits row down) — decode-phase lanes
//! ride the same dispatch as 1-active chunks, idle lanes as 0-active
//! (their memory passes through untouched), so an L-token prompt costs
//! ⌈L/C⌉ dispatches instead of L.  Pure-decode pumps fall through to
//! the cheaper single-token `step_fwd`.  Artifacts without `prefill`
//! use the validated single-token fallback for the prompt phase,
//! counted separately (`prefill_steps_host`).  Lane admission zeroes
//! the lane's memory rows *on device* through the AOT'd `reset_lanes`
//! mask program when the artifact provides it (a `[B]` keep-mask is
//! the only upload); older artifacts fall back to the host zero-row
//! path, counted separately in [`Engine::stats`].
//!
//! Speculative multi-token decode ([`Engine::with_speculate`]) rides
//! the same `prefill` program when the artifact emits logits at *all*
//! C positions (manifest `verify_logits`): on a pure-decode pump each
//! lane's unfed last token plus up to K tokens proposed by a host-side
//! [`Drafter`] (n-gram prompt lookup — no second model) go through one
//! verify dispatch, the longest prefix the model itself agrees with is
//! accepted plus one correction/bonus token, and on any rejection the
//! lane memories are rolled back by discarding the verify outputs
//! (inputs are never donated) and re-feeding exactly the accepted
//! prefixes through one ragged commit dispatch.  A cold drafter — or
//! `--speculate 0`, or an artifact without `verify_logits` — falls
//! back bit-for-bit to the single-token `step_fwd` path.
//!
//! Two submission surfaces: [`Engine::submit`] returns a one-shot
//! completion channel (the in-process demo path), and
//! [`Engine::submit_streaming`] delivers per-token [`StreamEvent`]s —
//! what the HTTP frontend's chunked responses are fed from.  The
//! [`EngineBackend`] trait abstracts the engine for the serving driver
//! thread so scheduler/server tests can run against a mock.

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::rng::Rng;
use crate::runtime::device::{download, upload};
use crate::runtime::{DeviceState, ModelBundle, Program, TransferSnapshot};
use crate::serving::clock::{Clock, SharedClock, WallClock};
use crate::serving::drafter::{Drafter, NgramDrafter};
use crate::serving::prefix_cache::PrefixCache;
use crate::serving::sampler::Sampler;
use crate::tensor::{DType, HostTensor};

/// A generation request.
#[derive(Debug, Clone, Default)]
pub struct GenRequest {
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub sampler: Sampler,
    /// Per-request ceiling on the σ-MoE runtime expert top-k, already
    /// validated against `[1, expert_k_max]` at the HTTP boundary.
    /// The engine feeds one scalar per dispatch, so the effective k of
    /// a pump is the minimum over the scheduler's degrade target and
    /// every active lane's ceiling.  `None` = no request preference.
    pub expert_k: Option<usize>,
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub prompt: Vec<i32>,
    pub tokens: Vec<i32>,
    pub queue_time: Duration,
    /// time from admission to completion
    pub run_time: Duration,
    pub prompt_len: usize,
}

/// Per-request progress events delivered on the channel passed to
/// [`Engine::submit_streaming`] — the feed behind the HTTP frontend's
/// chunked token streaming.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    /// The request left the queue and occupies a lane.
    Admitted,
    /// One sampled continuation token.
    Token(i32),
    /// Generation finished (terminal).
    Done(GenResult),
    /// The request was abandoned before completion (terminal).
    Dropped(DropReason),
}

/// Why a request was dropped without completing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DropReason {
    /// Its deadline expired while still queued (deadline-aware policy).
    Deadline,
    /// The server shut down before the request ran to completion.
    Shutdown,
    /// Every engine that could run the request failed (wedged,
    /// erroring, or poisoned) and the router's bounded retries were
    /// exhausted — the HTTP layer answers 503.
    EngineFailure,
}

impl DropReason {
    pub fn as_str(self) -> &'static str {
        match self {
            DropReason::Deadline => "deadline",
            DropReason::Shutdown => "shutdown",
            DropReason::EngineFailure => "engine-failure",
        }
    }
}

/// The surface the serving driver thread needs from a generation
/// backend — implemented by [`Engine`] over the real AOT executables
/// and by [`crate::serving::MockBackend`] for artifact-free scheduler /
/// HTTP tests and `loadgen --dry-run`.
pub trait EngineBackend {
    fn n_lanes(&self) -> usize;
    /// Requests that could be admitted on the next pump: free lanes
    /// minus requests already waiting in the internal queue.
    fn free_lanes(&self) -> usize;
    /// Prompt tokens one pump can ingest per lane — the prefill chunk
    /// width C.  1 means single-token prompt feeding (no chunked
    /// prefill); the scheduler costs prompts in ⌈len/C⌉ chunks.
    fn prefill_chunk(&self) -> usize {
        1
    }
    /// Enqueue a request whose progress is reported via `events`.
    fn submit_streaming(
        &mut self,
        req: GenRequest,
        events: mpsc::Sender<StreamEvent>,
    );
    /// One engine iteration (admit + one step over all lanes); returns
    /// the number of active plus internally-queued requests.
    fn pump(&mut self) -> Result<usize>;
    /// Cumulative throughput/perf counters for `/metrics`.
    fn stats(&self) -> BTreeMap<String, f64>;
    /// Drain the per-layer expert-selection counts accumulated since
    /// the last call (`counts[layer][expert]` token selections from the
    /// σ-MoE router's top-K).  `None` means the backend cannot observe
    /// expert routing — a dense/topk/pkm artifact, or one predating the
    /// counts output — and the driver bumps the
    /// `expert_stats_unavailable` fallback counter instead.
    fn take_expert_counts(&mut self) -> Option<Vec<Vec<u64>>> {
        None
    }
    /// Compile-time expert top-k ceiling of the runtime `expert_k`
    /// scalar input (adaptive expert sparsity).  `None` means the
    /// backend has no runtime-k knob — a dense/topk/pkm artifact, or a
    /// MoE artifact predating the scalar input — and degrade-k policy
    /// decisions are no-ops against it.
    fn expert_k_max(&self) -> Option<usize> {
        None
    }
    /// Set the scheduler's expert top-k target for subsequent pumps
    /// (clamped into `[1, expert_k_max]`; no-op without a runtime-k
    /// knob).  Called by the serving driver before pumping whenever
    /// the degrade-k policy transitions.
    fn set_expert_k(&mut self, _k: usize) {}
    /// Arm the fleet-shared prefix cache: subsequent admissions probe
    /// it and seed cache-hit lanes from the matching snapshot, and
    /// prefill pumps snapshot lanes crossing chunk boundaries into it.
    /// Default no-op for backends without snapshot/restore machinery.
    fn set_prefix_cache(&mut self, _cache: Arc<PrefixCache>) {}
    /// Set the effective speculative draft length for subsequent
    /// pumps (clamped into the backend's own `[0, C−1]` ceiling; no-op
    /// on backends without a verifier).  Called by the serving driver
    /// whenever the spec-K autotune controller transitions.
    fn set_speculate(&mut self, _k: usize) {}
    /// Drain the (drafted, accepted) speculative-token deltas since
    /// the last call — the live accept-rate feed the scheduler's
    /// spec-K autotune controller integrates.  `(0, 0)` from backends
    /// that are not speculating.
    fn take_spec_feedback(&mut self) -> (u64, u64) {
        (0, 0)
    }
}

#[derive(Debug)]
struct Lane {
    /// tokens not yet fed to the model (prompt remainder first)
    pending: VecDeque<i32>,
    generated: Vec<i32>,
    budget: usize,
    sampler: Sampler,
    request: GenRequest,
    queued_at: Instant,
    admitted_at: Instant,
    done_tx: Option<mpsc::Sender<GenResult>>,
    events: Option<mpsc::Sender<StreamEvent>>,
}

impl Lane {
    fn new(
        req: GenRequest,
        done_tx: Option<mpsc::Sender<GenResult>>,
        events: Option<mpsc::Sender<StreamEvent>>,
        now: Instant,
    ) -> Self {
        Lane {
            pending: req.prompt.iter().copied().collect(),
            generated: Vec::new(),
            budget: req.max_new_tokens,
            sampler: req.sampler.clone(),
            request: req,
            queued_at: now,
            admitted_at: now,
            done_tx,
            events,
        }
    }
}

/// Admit queued requests into free lanes, oldest request first into the
/// lowest-index free lane.  Returns the indices of the lanes filled this
/// round (their XL memory must be reset by the caller).
fn admit_fifo(
    lanes: &mut [Option<Lane>],
    queue: &mut VecDeque<Lane>,
    now: Instant,
) -> Vec<usize> {
    let mut admitted = Vec::new();
    for (i, slot) in lanes.iter_mut().enumerate() {
        if slot.is_none() {
            if let Some(mut lane) = queue.pop_front() {
                lane.admitted_at = now;
                *slot = Some(lane);
                admitted.push(i);
            } else {
                break;
            }
        }
    }
    admitted
}

/// Zero row `lane` of a `[B, ...]` tensor (one lane's slice of a
/// batched XL-memory buffer).
fn zero_lane_row(t: &mut HostTensor, lane: usize) {
    let row = t.data.len() / t.shape[0];
    let start = lane * row;
    t.data[start..start + row].fill(0);
}

/// One input of the AOT'd `reset_lanes` program, mapped onto the
/// engine's `step_fwd` device state: either a memory slot index or the
/// `[B]` keep-mask.
#[derive(Debug, Clone, Copy)]
enum ResetInput {
    Mem(usize),
    Mask,
}

/// One input of the AOT'd `prefill` program, mapped onto the engine's
/// `step_fwd` device state: a shared param/memory slot, the `[B, C]`
/// token chunk, the `[B]` active-length vector, or the runtime
/// expert-k scalar (MoE adaptive-sparsity artifacts only).
#[derive(Debug, Clone, Copy)]
enum PrefillInput {
    State(usize),
    Tokens,
    ActiveLen,
    ExpertK,
}

/// One input of the AOT'd `snapshot_lanes` program, mapped onto the
/// engine's `step_fwd` device state: a per-layer memory slot or the
/// `[B]` i32 source-lane vector (lane index to gather, −1 to emit
/// zeros).
#[derive(Debug, Clone, Copy)]
enum SnapshotInput {
    Mem(usize),
    Src,
}

/// One input of the AOT'd `restore_lanes` program: a per-layer memory
/// slot, the `[n_layers, B, mem_len, d_model]` cached payload, or the
/// `[B]` f32 keep-mask (1.0 preserves the lane's memory, 0.0 adopts
/// the payload rows).
#[derive(Debug, Clone, Copy)]
enum RestoreInput {
    Mem(usize),
    Payload,
    Keep,
}

/// Continuous-batching engine: `serve_batch` lanes step together in one
/// `step_fwd` call per token.
pub struct Engine<'a> {
    bundle: &'a ModelBundle,
    /// device-resident step_fwd inputs: "0.*" params, "1.*" mems,
    /// "2" toks, "3" the runtime expert-k scalar (adaptive-k MoE only)
    state: DeviceState,
    /// indices of the per-layer memory inputs within the input vector
    mem_slots: Vec<usize>,
    tok_idx: usize,
    mem_feedback: Vec<(usize, usize)>,
    /// `reset_lanes` program inputs in program order, mapped onto
    /// `state` slots (`None` when the artifact lacks the program or its
    /// signature doesn't line up — host fallback then).
    reset_inputs: Option<Vec<ResetInput>>,
    /// `reset_lanes` program outputs in program order -> `state` slots
    reset_outputs: Vec<usize>,
    /// `prefill` program inputs in program order, mapped onto `state`
    /// slots plus the two per-dispatch uploads (`None` when the
    /// artifact lacks the program or its signature doesn't line up —
    /// single-token prompt feeding then).
    prefill_inputs: Option<Vec<PrefillInput>>,
    /// `prefill` memory outputs: (output index, `state` slot) pairs
    prefill_feedback: Vec<(usize, usize)>,
    /// prefill chunk width C (from the program's `[B, C]` token input);
    /// 1 when the program is unavailable
    prefill_chunk: usize,
    /// whether the `prefill` program emits logits at all C positions
    /// (`[B, C, V]` output `0`, manifest `verify_logits`) — the
    /// speculative verifier.  False on the legacy `[B, V]` signature,
    /// which also disables speculation.
    prefill_verify_all: bool,
    /// vocab size V (from the step_fwd logits output) — the prefill
    /// output's trailing dim can no longer be read as `shape[1]` once
    /// verify artifacts widen it to `[B, C, V]`
    vocab: usize,
    /// max drafted tokens per lane per verify round (0 = speculation
    /// off; the bit-for-bit single-token path)
    speculate: usize,
    /// host-side draft source for speculative decode
    drafter: Box<dyn Drafter>,
    /// `step_fwd` output index of the trailing `[layers, n_experts]`
    /// expert-count tensor (MoE artifacts only; `None` on the
    /// two-output signature)
    counts_idx_step: Option<usize>,
    /// same for the `prefill` program's outputs
    counts_idx_prefill: Option<usize>,
    /// `step_fwd` input slot of the runtime expert-k scalar ("3";
    /// adaptive-sparsity MoE artifacts only — `None` disables the knob)
    expert_k_idx_step: Option<usize>,
    /// compile-time top-k ceiling of the runtime scalar (manifest
    /// `expert_k_max`); present iff the artifact takes the input
    expert_k_max: Option<usize>,
    /// scheduler degrade target, applied as a ceiling on every pump
    sched_expert_k: usize,
    /// effective expert-k fed on the most recent dispatch
    expert_k_current: usize,
    /// value resident in the `step_fwd` expert-k device slot.  Tracked
    /// separately from `expert_k_current` because `pump_prefill`
    /// uploads a transient per-dispatch buffer that never touches the
    /// step slot — conflating the two would make [`Self::sync_expert_k`]
    /// skip the upload after a prefill and run decode at a stale k.
    expert_k_step_resident: usize,
    /// expert selections accumulated since the last
    /// [`EngineBackend::take_expert_counts`] drain:
    /// `expert_counts[layer][expert]`
    expert_counts: Vec<Vec<u64>>,
    lanes: Vec<Option<Lane>>,
    queue: VecDeque<Lane>,
    rng: Rng,
    /// injectable time source for queue/run timing (wall clock in
    /// production; a simulated clock under the record/replay harness)
    clock: SharedClock,
    pub steps_executed: u64,
    /// sampled continuation tokens only
    pub tokens_generated: u64,
    /// every token consumed by an active lane, prompt phase included
    pub tokens_processed: u64,
    /// admissions whose memory reset ran on device via `reset_lanes`
    pub lane_resets_device: u64,
    /// admissions that fell back to the host zero-row path
    pub lane_resets_host: u64,
    /// pumps that ingested prompt tokens through the chunked `prefill`
    /// dispatch
    pub prefill_steps_device: u64,
    /// pumps that ingested prompt tokens one-per-lane through the
    /// single-token `step_fwd` fallback (artifact predates `prefill`)
    pub prefill_steps_host: u64,
    /// prompt tokens consumed through the chunked prefill path
    pub prefill_tokens: u64,
    /// requests dropped because their lane produced non-finite logits
    /// (the per-lane poison guard)
    pub lanes_poisoned: u64,
    /// pumps that could not observe expert routing (artifact without
    /// the counts output — dense/topk/pkm, or pre-telemetry MoE)
    pub expert_stats_unavailable: u64,
    /// speculative verify rounds executed (each is one prefill-shaped
    /// dispatch over the drafted tokens)
    pub spec_rounds: u64,
    /// tokens drafted into verify dispatches
    pub spec_drafted: u64,
    /// drafted tokens the model confirmed (emitted without their own
    /// dispatch — the speculation win)
    pub spec_accepted: u64,
    /// verify rounds where some lane rejected part of its draft and
    /// lane memories were rolled back via a commit dispatch
    pub spec_rollbacks: u64,
    /// ragged commit dispatches issued for those rollbacks
    pub spec_commit_steps: u64,
    /// rounds by per-lane accepted-prefix length: `spec_accept_hist[n]`
    /// = speculating lanes whose round accepted exactly n drafts
    /// (len `speculate + 1`)
    pub spec_accept_hist: Vec<u64>,
    /// (drafted, accepted) totals already drained through
    /// [`EngineBackend::take_spec_feedback`] — the high-water marks the
    /// next drain subtracts
    spec_fb_drained: (u64, u64),
    /// fleet-shared post-prefill snapshot store (`None` = cache off,
    /// the bit-for-bit cold-prefill path)
    prefix_cache: Option<Arc<PrefixCache>>,
    /// `snapshot_lanes` program inputs in program order (`None` when
    /// the artifact predates the program or its signature doesn't line
    /// up — admissions then cold-prefill, counter-visible)
    snapshot_inputs: Option<Vec<SnapshotInput>>,
    /// `restore_lanes` program inputs in program order (same fallback)
    restore_inputs: Option<Vec<RestoreInput>>,
    /// `restore_lanes` program outputs in program order -> `state` slots
    restore_outputs: Vec<usize>,
    /// elements of one lane's one-layer memory row (`mem_len * d_model`)
    /// — the payload stride snapshots are sliced with
    mem_row_elems: usize,
    /// admissions whose probe matched and seeded the lane from a
    /// snapshot
    pub prefix_cache_hits: u64,
    /// admissions that probed and found no covering snapshot
    pub prefix_cache_misses: u64,
    /// prompt tokens skipped by cache-hit admissions (the dispatches
    /// they would have cost are the TTFT win)
    pub prefix_cache_tokens_saved: u64,
    /// boundary snapshots inserted into the cache
    pub prefix_cache_snapshots: u64,
    /// restore dispatches run on device
    pub prefix_cache_restores_device: u64,
    /// restores written through the host memory mirror (memories not
    /// yet device-resident)
    pub prefix_cache_restores_host: u64,
    /// admissions while the cache was armed but the artifact lacks the
    /// snapshot/restore programs — the validated cold-prefill fallback
    pub prefix_cache_unavailable: u64,
}

impl<'a> Engine<'a> {
    /// Create an engine using the given parameters (name, tensor) pairs —
    /// typically `Trainer::params()` or a loaded checkpoint.  Parameters
    /// are uploaded once here and stay device-resident for the engine's
    /// lifetime.
    pub fn new(
        bundle: &'a ModelBundle,
        params: &[(String, HostTensor)],
        seed: u64,
    ) -> Result<Self> {
        let fwd = bundle.program("step_fwd")?;
        let spec = &fwd.spec;
        let mut state =
            DeviceState::for_inputs(&bundle.client, "step_fwd", &spec.inputs);
        for (name, t) in params {
            if let Some(i) = state.position(&format!("0.{name}")) {
                state.set_host(i, t.clone())?;
            }
        }
        let mem_slots: Vec<usize> = spec
            .inputs
            .iter()
            .enumerate()
            .filter(|(_, b)| b.name.starts_with("1."))
            .map(|(i, _)| i)
            .collect();
        let tok_idx = state
            .position("2")
            .ok_or_else(|| Error::Manifest("step_fwd: no token input".into()))?;
        if state.slot_spec(tok_idx).dtype != DType::I32 {
            return Err(Error::Manifest("token input must be i32".into()));
        }
        // outputs: "0" logits, "1.<mems>" -> feed back into "1.<mems>"
        let mem_feedback: Vec<(usize, usize)> = spec
            .outputs
            .iter()
            .enumerate()
            .filter_map(|(oi, ob)| {
                ob.name
                    .strip_prefix("1.")
                    .and_then(|rest| state.position(&format!("1.{rest}")))
                    .map(|ii| (oi, ii))
            })
            .collect();
        let n_lanes = state.slot_spec(tok_idx).shape[0];
        let (reset_inputs, reset_outputs) =
            Self::map_reset_program(bundle, &state, n_lanes, &mem_slots);
        let vocab = spec.outputs[0].shape[1];
        // MoE artifacts append a trailing [layers, n_experts] f32
        // expert-count output "2"; older / non-MoE artifacts don't.
        let counts_idx_step =
            Self::find_counts_output(&spec.outputs, mem_slots.len());
        // Adaptive-sparsity MoE artifacts take a trailing runtime
        // expert-k i32 scalar input "3"; older / non-MoE artifacts
        // don't, and the knob stays disabled (fixed-k serving).
        let mut expert_k_idx_step = state.position("3").filter(|&i| {
            state.slot_spec(i).dtype == DType::I32
                && state.slot_spec(i).shape.is_empty()
        });
        // expert_k_max lands in the manifest alongside the input; the
        // ablation-config k is an equivalent fallback.  Both absent
        // (or 0) means no usable ceiling — disable the knob entirely
        // rather than feed an unset input.
        let expert_k_max = match expert_k_idx_step {
            Some(_) => bundle
                .manifest
                .expert_k_max
                .or(Some(bundle.manifest.model.expert_k))
                .filter(|&k| k > 0),
            None => None,
        };
        match (expert_k_idx_step, expert_k_max) {
            (Some(idx), Some(mx)) => {
                state.set_host(idx, HostTensor::from_i32(&[], &[mx as i32])?)?;
            }
            _ => expert_k_idx_step = None,
        }
        let k0 = expert_k_max.unwrap_or(0);
        let (
            prefill_inputs,
            prefill_feedback,
            prefill_chunk,
            counts_idx_prefill,
            prefill_verify_all,
        ) = Self::map_prefill_program(
            bundle, &state, n_lanes, &mem_slots, vocab,
        );
        let snapshot_inputs =
            Self::map_snapshot_program(bundle, &state, n_lanes, &mem_slots);
        let (restore_inputs, restore_outputs) =
            Self::map_restore_program(bundle, &state, n_lanes, &mem_slots);
        let mem_row_elems = mem_slots
            .first()
            .map(|&s| {
                let shape = &state.slot_spec(s).shape;
                shape.iter().skip(1).product()
            })
            .unwrap_or(0);
        Ok(Engine {
            bundle,
            state,
            mem_slots,
            tok_idx,
            mem_feedback,
            reset_inputs,
            reset_outputs,
            prefill_inputs,
            prefill_feedback,
            prefill_chunk,
            prefill_verify_all,
            vocab,
            speculate: 0,
            drafter: Box::new(NgramDrafter::new()),
            counts_idx_step,
            counts_idx_prefill,
            expert_k_idx_step,
            expert_k_max,
            sched_expert_k: k0.max(1),
            expert_k_current: k0,
            expert_k_step_resident: k0,
            expert_counts: Vec::new(),
            lanes: (0..n_lanes).map(|_| None).collect(),
            queue: VecDeque::new(),
            rng: Rng::new(seed),
            clock: WallClock::shared(),
            steps_executed: 0,
            tokens_generated: 0,
            tokens_processed: 0,
            lane_resets_device: 0,
            lane_resets_host: 0,
            prefill_steps_device: 0,
            prefill_steps_host: 0,
            prefill_tokens: 0,
            lanes_poisoned: 0,
            expert_stats_unavailable: 0,
            spec_rounds: 0,
            spec_drafted: 0,
            spec_accepted: 0,
            spec_rollbacks: 0,
            spec_commit_steps: 0,
            spec_accept_hist: Vec::new(),
            spec_fb_drained: (0, 0),
            prefix_cache: None,
            snapshot_inputs,
            restore_inputs,
            restore_outputs,
            mem_row_elems,
            prefix_cache_hits: 0,
            prefix_cache_misses: 0,
            prefix_cache_tokens_saved: 0,
            prefix_cache_snapshots: 0,
            prefix_cache_restores_device: 0,
            prefix_cache_restores_host: 0,
            prefix_cache_unavailable: 0,
        })
    }

    /// Replace the engine's time source (used by deterministic
    /// harnesses; production keeps the wall-clock default).
    pub fn with_clock(mut self, clock: SharedClock) -> Self {
        self.clock = clock;
        self
    }

    /// Enable speculative decode: up to `k` drafted tokens verified per
    /// lane per pure-decode pump.  Silently stays off (`speculate = 0`,
    /// the bit-for-bit single-token path) when the artifact's `prefill`
    /// program is unavailable or lacks the all-position `verify_logits`
    /// output; the CLI surfaces that as a config error instead.  `k` is
    /// capped at C−1 so the lane's unfed last token plus the draft fit
    /// one chunk.
    pub fn with_speculate(mut self, k: usize) -> Self {
        self.speculate =
            if self.prefill_verify_all && self.prefill_inputs.is_some() {
                k.min(self.prefill_chunk.saturating_sub(1))
            } else {
                0
            };
        self.spec_accept_hist = vec![0; self.speculate + 1];
        self
    }

    /// Whether speculative decode is armed (drafting may still be cold).
    pub fn speculate(&self) -> usize {
        self.speculate
    }

    /// Arm the fleet-shared prefix cache.  With an artifact that lacks
    /// the snapshot/restore programs the engine keeps serving through
    /// cold prefill, bit-for-bit unchanged, counting each skipped
    /// probe in `prefix_cache_unavailable`.
    pub fn with_prefix_cache(mut self, cache: Arc<PrefixCache>) -> Self {
        self.prefix_cache = Some(cache);
        self
    }

    /// Map the optional AOT'd `reset_lanes` program onto the step_fwd
    /// device state.  Its manifest contract (checked per buffer, with a
    /// silent host fallback on any mismatch so old artifacts keep
    /// working): inputs `0.<layer>` are the per-layer memories matching
    /// step_fwd input `1.<layer>`, input `1` is the `[B]` f32 keep-mask;
    /// outputs `<layer>` are the masked memories in layer order — and
    /// the program must cover *every* memory slot, since a
    /// subset-coverage program would leave the uncovered layers holding
    /// a previous request's memory (cross-request leakage) while
    /// counting the reset as successful.
    fn map_reset_program(
        bundle: &ModelBundle,
        state: &DeviceState,
        n_lanes: usize,
        mem_slots: &[usize],
    ) -> (Option<Vec<ResetInput>>, Vec<usize>) {
        let Ok(prog) = bundle.program("reset_lanes") else {
            return (None, Vec::new());
        };
        let mut inputs = Vec::with_capacity(prog.spec.inputs.len());
        for b in &prog.spec.inputs {
            if b.name == "1" {
                if b.dtype != DType::F32 || b.shape != [n_lanes] {
                    return (None, Vec::new());
                }
                inputs.push(ResetInput::Mask);
            } else if let Some(layer) = b.name.strip_prefix("0.") {
                match state.position(&format!("1.{layer}")) {
                    Some(i) if state.slot_spec(i).shape == b.shape => {
                        inputs.push(ResetInput::Mem(i))
                    }
                    _ => return (None, Vec::new()),
                }
            } else {
                return (None, Vec::new());
            }
        }
        let mut outputs = Vec::with_capacity(prog.spec.outputs.len());
        for b in &prog.spec.outputs {
            match state.position(&format!("1.{}", b.name)) {
                Some(i) => outputs.push(i),
                None => return (None, Vec::new()),
            }
        }
        let need: std::collections::BTreeSet<usize> =
            mem_slots.iter().copied().collect();
        let covered: std::collections::BTreeSet<usize> = inputs
            .iter()
            .filter_map(|ri| match ri {
                ResetInput::Mem(i) => Some(*i),
                ResetInput::Mask => None,
            })
            .collect();
        let written: std::collections::BTreeSet<usize> =
            outputs.iter().copied().collect();
        if covered != need || written != need || need.is_empty() {
            return (None, Vec::new());
        }
        (Some(inputs), outputs)
    }

    /// Find a program's trailing expert-count output: named `2`, f32,
    /// shaped `[n_layers, n_experts]`.  MoE artifacts append it to both
    /// `step_fwd` and `prefill`; its absence is not an error (dense /
    /// topk / pkm presets keep the two-output signature, and the
    /// drivers count the fallback as `expert_stats_unavailable`).
    fn find_counts_output(
        outputs: &[crate::runtime::manifest::BufferSpec],
        n_layers: usize,
    ) -> Option<usize> {
        let (oi, b) = outputs.iter().enumerate().last()?;
        (b.name == "2"
            && b.dtype == DType::F32
            && b.shape.len() == 2
            && b.shape[0] == n_layers
            && b.shape[1] > 0)
            .then_some(oi)
    }

    /// Map the optional AOT'd `prefill` program onto the step_fwd
    /// device state.  Its manifest contract (checked per buffer, with a
    /// silent single-token fallback on any mismatch so old artifacts
    /// keep working): inputs `0.*`/`1.*` are the params/memories shared
    /// with step_fwd, input `2` the `[B, C]` i32 token chunk, input `3`
    /// the `[B]` i32 active-length vector, input `4` (adaptive-k MoE
    /// artifacts) the runtime expert-k i32 scalar; output `0` is the
    /// last-valid-position logits `[B, vocab]` — or, on
    /// `verify_logits` artifacts, the all-position logits
    /// `[B, C, vocab]` (the final tuple element reports which) — and
    /// outputs `1.*` the updated memories in layer order.  Like
    /// `reset_lanes`, the program must read *and* write every memory
    /// slot — a subset-coverage program would advance some layers'
    /// memories and leave others stale, silently corrupting every lane.
    fn map_prefill_program(
        bundle: &ModelBundle,
        state: &DeviceState,
        n_lanes: usize,
        mem_slots: &[usize],
        vocab: usize,
    ) -> (
        Option<Vec<PrefillInput>>,
        Vec<(usize, usize)>,
        usize,
        Option<usize>,
        bool,
    ) {
        const NONE: (
            Option<Vec<PrefillInput>>,
            Vec<(usize, usize)>,
            usize,
            Option<usize>,
            bool,
        ) = (None, Vec::new(), 1, None, false);
        let Ok(prog) = bundle.program("prefill") else {
            return NONE;
        };
        let mut chunk = 0usize;
        let mut inputs = Vec::with_capacity(prog.spec.inputs.len());
        for b in &prog.spec.inputs {
            if b.name == "2" {
                if b.dtype != DType::I32
                    || b.shape.len() != 2
                    || b.shape[0] != n_lanes
                    || b.shape[1] == 0
                {
                    return NONE;
                }
                chunk = b.shape[1];
                inputs.push(PrefillInput::Tokens);
            } else if b.name == "3" {
                if b.dtype != DType::I32 || b.shape != [n_lanes] {
                    return NONE;
                }
                inputs.push(PrefillInput::ActiveLen);
            } else if b.name == "4" {
                // runtime expert-k scalar (adaptive-sparsity MoE
                // artifacts; uploaded fresh per dispatch)
                if b.dtype != DType::I32 || !b.shape.is_empty() {
                    return NONE;
                }
                inputs.push(PrefillInput::ExpertK);
            } else {
                match state.position(&b.name) {
                    Some(i)
                        if state.slot_spec(i).shape == b.shape
                            && state.slot_spec(i).dtype == b.dtype =>
                    {
                        inputs.push(PrefillInput::State(i))
                    }
                    _ => return NONE,
                }
            }
        }
        if chunk == 0
            || !inputs
                .iter()
                .any(|i| matches!(i, PrefillInput::ActiveLen))
        {
            return NONE;
        }
        // output 0: logits — the legacy last-valid gather [B, vocab],
        // or the all-position [B, C, vocab] that `verify_logits`
        // artifacts emit (the speculative verifier); outputs 1.*:
        // memories
        let verify_all = match prog.spec.outputs.first() {
            Some(b)
                if b.name == "0"
                    && b.shape == [n_lanes, vocab]
                    && b.dtype == DType::F32 =>
            {
                false
            }
            Some(b)
                if b.name == "0"
                    && b.shape == [n_lanes, chunk, vocab]
                    && b.dtype == DType::F32 =>
            {
                true
            }
            _ => return NONE,
        };
        let mut feedback = Vec::new();
        let mut counts_idx = None;
        for (oi, b) in prog.spec.outputs.iter().enumerate().skip(1) {
            // The trailing expert-count output is named "2", which
            // collides with step_fwd's *token input* slot "2" in the
            // state map — match it explicitly before the positional
            // lookup, or the shape check below would reject the whole
            // program and silently disable chunked prefill.
            if counts_idx.is_none()
                && b.name == "2"
                && b.dtype == DType::F32
                && b.shape.len() == 2
                && b.shape[0] == mem_slots.len()
                && b.shape[1] > 0
            {
                counts_idx = Some(oi);
                continue;
            }
            match state.position(&b.name) {
                Some(i)
                    if state.slot_spec(i).shape == b.shape
                        && state.slot_spec(i).dtype == b.dtype =>
                {
                    feedback.push((oi, i))
                }
                _ => return NONE,
            }
        }
        let need: std::collections::BTreeSet<usize> =
            mem_slots.iter().copied().collect();
        let covered: std::collections::BTreeSet<usize> = inputs
            .iter()
            .filter_map(|pi| match pi {
                PrefillInput::State(i) if need.contains(i) => Some(*i),
                _ => None,
            })
            .collect();
        let written: std::collections::BTreeSet<usize> =
            feedback.iter().map(|&(_, i)| i).collect();
        if covered != need || written != need || need.is_empty() {
            return NONE;
        }
        (Some(inputs), feedback, chunk, counts_idx, verify_all)
    }

    /// Map the optional AOT'd `snapshot_lanes` program onto the
    /// step_fwd device state.  Its manifest contract (checked per
    /// buffer, with a silent cold-prefill fallback on any mismatch so
    /// old artifacts keep serving unchanged): inputs `0.<layer>` are
    /// the per-layer memories matching step_fwd input `1.<layer>`,
    /// input `1` the `[B]` i32 source-lane vector; the single output
    /// `0` is the gathered `[n_layers, B, mem_len, d_model]` payload.
    /// The program must read *every* memory slot — a subset snapshot
    /// would seed future lanes with some layers' state missing.
    fn map_snapshot_program(
        bundle: &ModelBundle,
        state: &DeviceState,
        n_lanes: usize,
        mem_slots: &[usize],
    ) -> Option<Vec<SnapshotInput>> {
        if !bundle.manifest.prefix_cache {
            return None;
        }
        let prog = bundle.program("snapshot_lanes").ok()?;
        let mut inputs = Vec::with_capacity(prog.spec.inputs.len());
        for b in &prog.spec.inputs {
            if b.name == "1" {
                if b.dtype != DType::I32 || b.shape != [n_lanes] {
                    return None;
                }
                inputs.push(SnapshotInput::Src);
            } else if let Some(layer) = b.name.strip_prefix("0.") {
                match state.position(&format!("1.{layer}")) {
                    Some(i)
                        if state.slot_spec(i).shape == b.shape
                            && state.slot_spec(i).dtype == DType::F32 =>
                    {
                        inputs.push(SnapshotInput::Mem(i))
                    }
                    _ => return None,
                }
            } else {
                return None;
            }
        }
        let need: std::collections::BTreeSet<usize> =
            mem_slots.iter().copied().collect();
        let covered: std::collections::BTreeSet<usize> = inputs
            .iter()
            .filter_map(|si| match si {
                SnapshotInput::Mem(i) => Some(*i),
                SnapshotInput::Src => None,
            })
            .collect();
        if covered != need || need.is_empty() {
            return None;
        }
        let [out] = prog.spec.outputs.as_slice() else {
            return None;
        };
        let mem_shape = &state.slot_spec(mem_slots[0]).shape;
        let mut want = vec![mem_slots.len()];
        want.extend_from_slice(mem_shape);
        if out.name != "0" || out.dtype != DType::F32 || out.shape != want {
            return None;
        }
        Some(inputs)
    }

    /// Map the optional AOT'd `restore_lanes` program — the
    /// cache-hit admission path.  Contract (same silent fallback):
    /// inputs `0.<layer>` the per-layer memories, `1` the
    /// `[n_layers, B, mem_len, d_model]` payload, `2` the `[B]` f32
    /// keep-mask; outputs `<layer>` the merged memories in layer
    /// order, covering every memory slot on both sides (a partial
    /// restore would splice two different requests' state together).
    fn map_restore_program(
        bundle: &ModelBundle,
        state: &DeviceState,
        n_lanes: usize,
        mem_slots: &[usize],
    ) -> (Option<Vec<RestoreInput>>, Vec<usize>) {
        if !bundle.manifest.prefix_cache || mem_slots.is_empty() {
            return (None, Vec::new());
        }
        let Ok(prog) = bundle.program("restore_lanes") else {
            return (None, Vec::new());
        };
        let mem_shape = &state.slot_spec(mem_slots[0]).shape;
        let mut payload_shape = vec![mem_slots.len()];
        payload_shape.extend_from_slice(mem_shape);
        let mut inputs = Vec::with_capacity(prog.spec.inputs.len());
        for b in &prog.spec.inputs {
            if b.name == "1" {
                if b.dtype != DType::F32 || b.shape != payload_shape {
                    return (None, Vec::new());
                }
                inputs.push(RestoreInput::Payload);
            } else if b.name == "2" {
                if b.dtype != DType::F32 || b.shape != [n_lanes] {
                    return (None, Vec::new());
                }
                inputs.push(RestoreInput::Keep);
            } else if let Some(layer) = b.name.strip_prefix("0.") {
                match state.position(&format!("1.{layer}")) {
                    Some(i)
                        if state.slot_spec(i).shape == b.shape
                            && state.slot_spec(i).dtype == DType::F32 =>
                    {
                        inputs.push(RestoreInput::Mem(i))
                    }
                    _ => return (None, Vec::new()),
                }
            } else {
                return (None, Vec::new());
            }
        }
        let mut outputs = Vec::with_capacity(prog.spec.outputs.len());
        for b in &prog.spec.outputs {
            match state.position(&format!("1.{}", b.name)) {
                Some(i) => outputs.push(i),
                None => return (None, Vec::new()),
            }
        }
        let need: std::collections::BTreeSet<usize> =
            mem_slots.iter().copied().collect();
        let covered: std::collections::BTreeSet<usize> = inputs
            .iter()
            .filter_map(|ri| match ri {
                RestoreInput::Mem(i) => Some(*i),
                _ => None,
            })
            .collect();
        let written: std::collections::BTreeSet<usize> =
            outputs.iter().copied().collect();
        if covered != need || written != need {
            return (None, Vec::new());
        }
        (Some(inputs), outputs)
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Requests admissible on the next pump: free lanes minus requests
    /// already waiting in the internal FIFO.  The serving scheduler
    /// holds its policy queue in front of the engine and only submits
    /// while this is positive, so ordering stays under policy control.
    pub fn free_lanes(&self) -> usize {
        self.lanes
            .iter()
            .filter(|l| l.is_none())
            .count()
            .saturating_sub(self.queue.len())
    }

    /// Enqueue a request; the result is delivered on the returned channel
    /// when `pump` drives it to completion.
    pub fn submit(&mut self, req: GenRequest) -> mpsc::Receiver<GenResult> {
        let (tx, rx) = mpsc::channel();
        self.queue.push_back(Lane::new(req, Some(tx), None, self.clock.now()));
        rx
    }

    /// Enqueue a request whose progress (admission, every sampled token,
    /// completion) is delivered as [`StreamEvent`]s on `events` — the
    /// feed for the HTTP frontend's chunked streaming responses.  Send
    /// failures are ignored: a hung-up receiver just discards events
    /// while the lane runs its budget out.
    pub fn submit_streaming(
        &mut self,
        req: GenRequest,
        events: mpsc::Sender<StreamEvent>,
    ) {
        self.queue.push_back(Lane::new(req, None, Some(events), self.clock.now()));
    }

    /// Zero lane `lane`'s XL memory on the host (fresh sequence).  This
    /// dirties the memory slots' host mirrors; the re-upload (and, after
    /// a first generation, one download to materialize the mirror)
    /// happens once per admission, not per token.  Fallback path for
    /// artifacts without the `reset_lanes` program.
    fn reset_lane_memory(&mut self, lane: usize) -> Result<()> {
        for &slot in &self.mem_slots {
            let t = self.state.host_mut(slot)?;
            zero_lane_row(t, lane);
        }
        Ok(())
    }

    /// Zero the admitted lanes' XL memories on device via the AOT'd
    /// `reset_lanes` mask program: the only host traffic is the `[B]`
    /// keep-mask upload; memory buffers are fed back buffer-to-buffer.
    /// Returns false (caller must use the host path) when the program is
    /// absent or some memory slot is not yet device-resident.
    fn reset_lanes_on_device(&mut self, admitted: &[usize]) -> Result<bool> {
        let Some(reset_inputs) = &self.reset_inputs else {
            return Ok(false);
        };
        if self.mem_slots.iter().any(|&s| !self.state.device_ready(s)) {
            return Ok(false);
        }
        let prog = self.bundle.program("reset_lanes")?;
        let mut keep = vec![1.0f32; self.lanes.len()];
        for &i in admitted {
            keep[i] = 0.0;
        }
        let mask = upload(
            &self.bundle.client,
            &HostTensor::from_f32(&[self.lanes.len()], &keep)?,
        )?;
        let out = {
            let bufs: Vec<&xla::PjRtBuffer> = reset_inputs
                .iter()
                .map(|ri| match ri {
                    ResetInput::Mask => Ok(&mask),
                    ResetInput::Mem(slot) => self.state.buffer(*slot),
                })
                .collect::<Result<_>>()?;
            prog.run_buffers(&bufs)?
        };
        for (buf, &slot) in out.into_iter().zip(self.reset_outputs.iter()) {
            self.state.set_device(slot, buf);
        }
        Ok(true)
    }

    fn admit(&mut self) -> Result<()> {
        let admitted = admit_fifo(&mut self.lanes, &mut self.queue, self.clock.now());
        if admitted.is_empty() {
            return Ok(());
        }
        for &i in &admitted {
            if let Some(lane) = &self.lanes[i] {
                if let Some(tx) = &lane.events {
                    let _ = tx.send(StreamEvent::Admitted);
                }
            }
        }
        if self.reset_lanes_on_device(&admitted)? {
            self.lane_resets_device += admitted.len() as u64;
        } else {
            for &i in &admitted {
                self.reset_lane_memory(i)?;
            }
            self.lane_resets_host += admitted.len() as u64;
        }
        if self.speculate > 0 {
            // seed the drafter with the new occupant's prompt (prompt
            // lookup draws continuations from it from the first decode
            // pump) and drop the previous occupant's history
            for &i in &admitted {
                self.drafter.reset(i);
                if let Some(lane) = &self.lanes[i] {
                    for &t in &lane.request.prompt {
                        self.drafter.observe(i, t);
                    }
                }
            }
        }
        self.restore_from_cache(&admitted)?;
        Ok(())
    }

    /// Probe the prefix cache for each freshly-admitted lane and seed
    /// hit lanes from the longest covering snapshot — the cached
    /// prompt prefix is then dropped from `pending` so prefill starts
    /// at the tail.  One batched `restore_lanes` dispatch covers every
    /// hit lane when the memories are device-resident; otherwise the
    /// payload is written through the host mirrors (identical bits —
    /// the restore select with keep = 0 adopts the payload wholesale).
    /// With the cache armed but the artifact predating the programs,
    /// every admission cold-prefills unchanged and bumps
    /// `prefix_cache_unavailable`.
    fn restore_from_cache(&mut self, admitted: &[usize]) -> Result<()> {
        let Some(cache) = self.prefix_cache.clone() else {
            return Ok(());
        };
        if self.restore_inputs.is_none() {
            self.prefix_cache_unavailable += admitted.len() as u64;
            return Ok(());
        }
        let chunk = self.prefill_chunk();
        let n_layers = self.mem_slots.len();
        let row = self.mem_row_elems;
        let expect = n_layers * row;
        let mut hits: Vec<(usize, crate::serving::PrefixHit)> = Vec::new();
        for &i in admitted {
            let Some(lane) = &self.lanes[i] else { continue };
            match cache.probe(&lane.request.prompt, chunk) {
                // a snapshot from a different model geometry (or a
                // device-free mirror) cannot seed this engine's lanes
                Some(hit) if hit.payload.len() == expect => {
                    self.prefix_cache_hits += 1;
                    self.prefix_cache_tokens_saved += hit.len as u64;
                    hits.push((i, hit));
                }
                Some(_) | None => self.prefix_cache_misses += 1,
            }
        }
        if hits.is_empty() {
            return Ok(());
        }
        let b = self.lanes.len();
        if self.mem_slots.iter().all(|&s| self.state.device_ready(s)) {
            let mut payload = vec![0f32; n_layers * b * row];
            let mut keep = vec![1.0f32; b];
            for (lane, hit) in &hits {
                keep[*lane] = 0.0;
                for l in 0..n_layers {
                    let dst = (l * b + lane) * row;
                    payload[dst..dst + row].copy_from_slice(
                        &hit.payload[l * row..(l + 1) * row],
                    );
                }
            }
            let mut shape = vec![n_layers];
            shape.extend_from_slice(
                &self.state.slot_spec(self.mem_slots[0]).shape,
            );
            let prog = self.bundle.program("restore_lanes")?;
            let pay_buf = upload(
                &self.bundle.client,
                &HostTensor::from_f32(&shape, &payload)?,
            )?;
            let keep_buf = upload(
                &self.bundle.client,
                &HostTensor::from_f32(&[b], &keep)?,
            )?;
            let out = {
                let inputs = self.restore_inputs.as_ref().unwrap();
                let bufs: Vec<&xla::PjRtBuffer> = inputs
                    .iter()
                    .map(|ri| match ri {
                        RestoreInput::Mem(s) => self.state.buffer(*s),
                        RestoreInput::Payload => Ok(&pay_buf),
                        RestoreInput::Keep => Ok(&keep_buf),
                    })
                    .collect::<Result<_>>()?;
                prog.run_buffers(&bufs)?
            };
            for (buf, &slot) in
                out.into_iter().zip(self.restore_outputs.iter())
            {
                self.state.set_device(slot, buf);
            }
            self.prefix_cache_restores_device += 1;
        } else {
            let mem_slots = self.mem_slots.clone();
            for (lane, hit) in &hits {
                for (l, &slot) in mem_slots.iter().enumerate() {
                    let t = self.state.host_mut(slot)?;
                    let row_bytes = t.data.len() / t.shape[0];
                    let start = lane * row_bytes;
                    for (j, v) in
                        hit.payload[l * row..(l + 1) * row].iter().enumerate()
                    {
                        t.data[start + j * 4..start + j * 4 + 4]
                            .copy_from_slice(&v.to_le_bytes());
                    }
                }
            }
            self.prefix_cache_restores_host += hits.len() as u64;
        }
        // the snapshot already carries these tokens' effect on the
        // lane memory: drop them from pending so prefill starts at the
        // uncached tail (at least one tail token always remains)
        for (lane_idx, hit) in &hits {
            let lane = self.lanes[*lane_idx].as_mut().unwrap();
            for _ in 0..hit.len {
                lane.pending.pop_front();
            }
        }
        Ok(())
    }

    /// Snapshot every lane that crossed a prefill chunk boundary this
    /// pump into the prefix cache: one batched `snapshot_lanes`
    /// dispatch gathers the selected lanes' memory rows (source index
    /// per snapshotting lane, −1 emits zeros for the rest), the
    /// payload is downloaded once, and each lane's block is inserted
    /// keyed by its consumed prompt prefix.  Boundaries whose prefix
    /// is already cached are deduped before spending the dispatch.
    fn snapshot_to_cache(&mut self, fed_prompt: &[bool]) -> Result<()> {
        let Some(cache) = self.prefix_cache.clone() else {
            return Ok(());
        };
        let Some(snap_inputs) = self.snapshot_inputs.clone() else {
            return Ok(()); // fallback counted at admission
        };
        let b = self.lanes.len();
        let chunk = self.prefill_chunk;
        let mut src = vec![-1i32; b];
        let mut targets: Vec<(usize, usize)> = Vec::new();
        for (i, slot) in self.lanes.iter().enumerate() {
            if !fed_prompt[i] {
                continue; // decode/idle lane: memory is not a prompt
                          // prefix (or didn't advance this pump)
            }
            let Some(lane) = slot else { continue };
            let consumed =
                lane.request.prompt.len() - lane.pending.len();
            if consumed == 0 || consumed % chunk != 0 {
                continue; // mid-chunk tail: not a probe-able boundary
            }
            if !cache.wants(&lane.request.prompt[..consumed]) {
                continue;
            }
            src[i] = i as i32;
            targets.push((i, consumed));
        }
        if targets.is_empty() {
            return Ok(());
        }
        let prog = self.bundle.program("snapshot_lanes")?;
        let src_buf = upload(
            &self.bundle.client,
            &HostTensor::from_i32(&[b], &src)?,
        )?;
        let out = {
            let bufs: Vec<&xla::PjRtBuffer> = snap_inputs
                .iter()
                .map(|si| match si {
                    SnapshotInput::Mem(s) => self.state.buffer(*s),
                    SnapshotInput::Src => Ok(&src_buf),
                })
                .collect::<Result<_>>()?;
            prog.run_buffers(&bufs)?
        };
        let payload = download(&self.bundle.client, &out[0])?.as_f32()?;
        let n_layers = self.mem_slots.len();
        let row = self.mem_row_elems;
        for (lane_idx, prefix_len) in targets {
            let lane = self.lanes[lane_idx].as_ref().unwrap();
            let mut entry = Vec::with_capacity(n_layers * row);
            for l in 0..n_layers {
                let start = (l * b + lane_idx) * row;
                entry.extend_from_slice(&payload[start..start + row]);
            }
            if cache.insert(&lane.request.prompt[..prefix_len], entry) {
                self.prefix_cache_snapshots += 1;
            }
        }
        Ok(())
    }

    fn active(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }

    /// Effective expert top-k for the next dispatch: the scheduler's
    /// degrade target capped by every active lane's per-request
    /// ceiling, clamped into `[1, expert_k_max]`.  `None` when the
    /// artifact has no runtime-k knob.
    fn effective_expert_k(&self) -> Option<usize> {
        let max = self.expert_k_max?;
        let mut k = self.sched_expert_k.min(max);
        for lane in self.lanes.iter().flatten() {
            if let Some(rk) = lane.request.expert_k {
                k = k.min(rk);
            }
        }
        Some(k.max(1))
    }

    /// Refresh the device-resident expert-k scalar for `step_fwd` if
    /// the effective k changed since the last dispatch (a 4-byte
    /// upload, and only on transitions).
    fn sync_expert_k(&mut self) -> Result<()> {
        let (Some(idx), Some(k)) =
            (self.expert_k_idx_step, self.effective_expert_k())
        else {
            return Ok(());
        };
        if k != self.expert_k_step_resident {
            self.state
                .set_host(idx, HostTensor::from_i32(&[], &[k as i32])?)?;
            self.expert_k_step_resident = k;
        }
        self.expert_k_current = k;
        Ok(())
    }

    /// Run one engine iteration: admit, then either one chunked
    /// `prefill` dispatch (some lane still has pending prompt tokens —
    /// decode lanes ride along as 1-active chunks) or one single-token
    /// `step_fwd` over all lanes (pure decode, and the fallback when
    /// the artifact has no `prefill` program).  Returns active lanes
    /// plus internally-queued requests — 0 means fully drained (the
    /// [`EngineBackend`] contract the serving driver idles on), not
    /// "no lane is occupied".
    pub fn pump(&mut self) -> Result<usize> {
        self.admit()?;
        if self.active() == 0 {
            return Ok(0);
        }
        let in_prompt = self
            .lanes
            .iter()
            .flatten()
            .any(|l| !l.pending.is_empty());
        if in_prompt && self.prefill_inputs.is_some() {
            self.pump_prefill()?;
        } else if !in_prompt && self.speculate > 0 && self.pump_speculate()? {
            // speculative verify round ran (pump_speculate returns
            // false — before touching the device — when every drafter
            // is cold, so the fallback below stays bit-for-bit)
        } else {
            if in_prompt {
                // single-token fallback is about to consume prompt
                // tokens (artifact predates the `prefill` program)
                self.prefill_steps_host += 1;
            }
            self.pump_step_fwd()?;
        }
        Ok(self.active() + self.queue.len())
    }

    /// One single-token `step_fwd` over all lanes (the original decode
    /// step, and the prompt-phase fallback for old artifacts).
    fn pump_step_fwd(&mut self) -> Result<()> {
        let n_active = self.active();
        let fwd = self.bundle.program("step_fwd")?;
        let b = self.lanes.len();
        // token for each lane: next pending (prompt) token, or last
        // generated token; idle lanes feed 0.
        let mut toks = vec![0i32; b];
        let mut sample = vec![false; b];
        for (i, slot) in self.lanes.iter_mut().enumerate() {
            if let Some(lane) = slot {
                if let Some(t) = lane.pending.pop_front() {
                    toks[i] = t;
                    // the pump feeding the last prompt token already
                    // samples a continuation from its logits
                    sample[i] = lane.pending.is_empty();
                } else {
                    if let Some(&t) = lane.generated.last() {
                        toks[i] = t;
                    }
                    sample[i] = true;
                }
            }
        }
        self.state
            .set_host(self.tok_idx, HostTensor::from_i32(&[b, 1], &toks)?)?;
        self.sync_expert_k()?;
        let out = {
            let bufs = self.state.buffers()?;
            fwd.run_buffers(&bufs)?
        };
        self.steps_executed += 1;
        self.tokens_processed += n_active as u64;
        if self.counts_idx_step.is_none() {
            self.expert_stats_unavailable += 1;
        }
        let vocab = self.vocab;
        let logits = self.absorb_outputs(out, false)?;
        self.sample_and_finish(&logits, vocab, &sample);
        Ok(())
    }

    /// Shared dispatch epilogue: download the logits row (output 0 —
    /// the only host-bound traffic) and adopt the memory outputs back
    /// into the device state buffer-to-buffer, per the step_fwd
    /// (`prefill == false`) or prefill feedback table.
    fn absorb_outputs(
        &mut self,
        out: Vec<xla::PjRtBuffer>,
        prefill: bool,
    ) -> Result<Vec<f32>> {
        let logits = download(&self.bundle.client, &out[0])?.as_f32()?;
        self.absorb_feedback(out, prefill)?;
        Ok(logits)
    }

    /// The memory/counts half of [`Self::absorb_outputs`], without the
    /// logits download — the speculative paths download (or, for a
    /// rollback commit, discard) the logits themselves.
    fn absorb_feedback(
        &mut self,
        out: Vec<xla::PjRtBuffer>,
        prefill: bool,
    ) -> Result<()> {
        let mut out: Vec<Option<xla::PjRtBuffer>> =
            out.into_iter().map(Some).collect();
        let feedback = if prefill {
            &self.prefill_feedback
        } else {
            &self.mem_feedback
        };
        for &(oi, ii) in feedback {
            let buf = out[oi]
                .take()
                .ok_or_else(|| Error::other("mem output consumed twice"))?;
            self.state.set_device(ii, buf);
        }
        let counts_idx = if prefill {
            self.counts_idx_prefill
        } else {
            self.counts_idx_step
        };
        if let Some(ci) = counts_idx {
            let buf = out[ci]
                .take()
                .ok_or_else(|| Error::other("counts output consumed twice"))?;
            let t = download(&self.bundle.client, &buf)?;
            let ne = t.shape[1];
            let vals = t.as_f32()?;
            if self.expert_counts.len() < t.shape[0] {
                self.expert_counts.resize(t.shape[0], Vec::new());
            }
            for (l, row) in vals.chunks_exact(ne).enumerate() {
                let acc = &mut self.expert_counts[l];
                if acc.len() < ne {
                    acc.resize(ne, 0);
                }
                for (e, &v) in row.iter().enumerate() {
                    // counts are integral by construction; round guards
                    // against f32 accumulation error in wide layers
                    acc[e] += v.round().max(0.0) as u64;
                }
            }
        }
        Ok(())
    }

    /// One chunked `prefill` dispatch: up to C pending prompt tokens
    /// per prompt-phase lane, the last sampled token (1-active) for
    /// decode-phase lanes, 0-active for idle lanes (memory passes
    /// through bit-for-bit on device).  Host traffic is the `[B, C]`
    /// token chunk + `[B]` active vector up and one logits row down —
    /// memories stay buffer-to-buffer, exactly like `step_fwd`.
    fn pump_prefill(&mut self) -> Result<()> {
        let prog = self.bundle.program("prefill")?;
        let b = self.lanes.len();
        let c = self.prefill_chunk;
        let mut toks = vec![0i32; b * c];
        let mut active = vec![0i32; b];
        // lanes whose last fed token completes their context get a
        // continuation sampled from logits_last
        let mut sample = vec![false; b];
        // lanes that ingested prompt tokens this pump — the only ones
        // whose post-dispatch memory is a snapshot-able prompt prefix
        let mut fed_prompt = vec![false; b];
        let mut prompt_tokens = 0u64;
        for (i, slot) in self.lanes.iter_mut().enumerate() {
            let Some(lane) = slot else { continue };
            if lane.pending.is_empty() {
                // decode lane: its last token as a 1-active chunk is
                // exactly step_fwd semantics
                if let Some(&t) = lane.generated.last() {
                    toks[i * c] = t;
                }
                active[i] = 1;
                sample[i] = true;
                continue;
            }
            let k = lane.pending.len().min(c);
            for j in 0..k {
                toks[i * c + j] = lane.pending.pop_front().unwrap();
            }
            active[i] = k as i32;
            prompt_tokens += k as u64;
            fed_prompt[i] = true;
            // drained this pump: logits_last is the distribution after
            // the final prompt token — sample the first continuation
            sample[i] = lane.pending.is_empty();
        }
        self.state.upload_dirty()?;
        let tok_buf = upload(
            &self.bundle.client,
            &HostTensor::from_i32(&[b, c], &toks)?,
        )?;
        let act_buf = upload(
            &self.bundle.client,
            &HostTensor::from_i32(&[b], &active)?,
        )?;
        let ek_buf = self.prefill_expert_k_buf()?;
        let out =
            self.run_prefill_dispatch(prog, &tok_buf, &act_buf, ek_buf.as_ref())?;
        self.steps_executed += 1;
        self.prefill_steps_device += 1;
        self.prefill_tokens += prompt_tokens;
        if self.counts_idx_prefill.is_none() {
            self.expert_stats_unavailable += 1;
        }
        // every consumed token counts: C-chunk prompt lanes, 1-token
        // decode lanes — idle lanes contribute their 0
        self.tokens_processed +=
            active.iter().map(|&a| a as u64).sum::<u64>();
        let vocab = self.vocab;
        let logits = self.absorb_outputs(out, true)?;
        // memories are device-resident here; snapshot dispatches are
        // not counted in steps_executed (they are cache maintenance,
        // not token progress)
        self.snapshot_to_cache(&fed_prompt)?;
        let logits = if self.prefill_verify_all {
            // all-position output [B, C, V]: gather each lane's
            // last-valid row host-side so the epilogue sees the legacy
            // last-position layout (bit-for-bit the on-device gather —
            // pinned in python/tests/test_prefill.py)
            let mut rows = vec![0f32; b * vocab];
            for i in 0..b {
                let j = (active[i].max(1) as usize) - 1;
                let src = (i * c + j) * vocab;
                rows[i * vocab..(i + 1) * vocab]
                    .copy_from_slice(&logits[src..src + vocab]);
            }
            rows
        } else {
            logits
        };
        self.sample_and_finish(&logits, vocab, &sample);
        Ok(())
    }

    /// Upload the runtime expert-k scalar for a prefill-shaped dispatch
    /// when the mapped program takes it (`None` otherwise): a fresh
    /// 4-byte upload per dispatch, mirroring the step_fwd slot.
    fn prefill_expert_k_buf(&mut self) -> Result<Option<xla::PjRtBuffer>> {
        let needs_ek = self
            .prefill_inputs
            .as_ref()
            .is_some_and(|ins| {
                ins.iter().any(|pi| matches!(pi, PrefillInput::ExpertK))
            });
        if !needs_ek {
            return Ok(None);
        }
        // step-side knob disabled (no step input or no usable
        // ceiling) but the prefill program still takes the scalar:
        // feed the compile-time K so prefill quality matches the
        // fixed-k step path rather than degrading to top-1
        let k = self.effective_expert_k().unwrap_or_else(|| {
            self.bundle
                .manifest
                .expert_k_max
                .unwrap_or(self.bundle.manifest.model.expert_k)
                .max(1)
        });
        self.expert_k_current = k;
        Ok(Some(upload(
            &self.bundle.client,
            &HostTensor::from_i32(&[], &[k as i32])?,
        )?))
    }

    /// Run one prefill-shaped dispatch over the mapped program inputs
    /// (shared by chunked prompt ingestion, speculative verify, and the
    /// rollback commit — they differ only in what the token/active
    /// tensors carry).
    fn run_prefill_dispatch(
        &self,
        prog: &Program,
        tok_buf: &xla::PjRtBuffer,
        act_buf: &xla::PjRtBuffer,
        ek_buf: Option<&xla::PjRtBuffer>,
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let inputs = self
            .prefill_inputs
            .as_ref()
            .ok_or_else(|| Error::other("prefill program unmapped"))?;
        let bufs: Vec<&xla::PjRtBuffer> = inputs
            .iter()
            .map(|pi| match pi {
                PrefillInput::State(s) => self.state.buffer(*s),
                PrefillInput::Tokens => Ok(tok_buf),
                PrefillInput::ActiveLen => Ok(act_buf),
                PrefillInput::ExpertK => ek_buf
                    .ok_or_else(|| Error::other("expert_k buffer unmapped")),
            })
            .collect::<Result<_>>()?;
        prog.run_buffers(&bufs)
    }

    /// One speculative verify round over a pure-decode batch: each
    /// lane's unfed last token plus up to K drafted continuation tokens
    /// go through one prefill-shaped dispatch, whose all-position
    /// logits score every draft in parallel.  Per lane the longest
    /// prefix where the sampled token equals the draft is accepted, and
    /// the sample after it is emitted as the correction/bonus token
    /// (greedy sampling consumes no RNG, so acceptance is exact
    /// argmax agreement; temperature sampling accepts a draft exactly
    /// when the sampler would have drawn it).  If every lane accepts
    /// its whole draft the verify outputs are adopted as-is (one
    /// dispatch emitted up to K+1 tokens per lane); any rejection
    /// rolls lane memories back by *discarding* the verify outputs —
    /// dispatch inputs are never donated, so the pre-round memory
    /// buffers are still the live device state — and re-feeding exactly
    /// the accepted per-lane prefixes through one ragged commit
    /// dispatch.
    ///
    /// Returns `Ok(false)` — before touching the device — when no lane
    /// produced a draft (drafters cold, budgets nearly exhausted), so
    /// the caller's single-token fallback stays bit-for-bit identical
    /// to a non-speculating engine.
    fn pump_speculate(&mut self) -> Result<bool> {
        let b = self.lanes.len();
        let c = self.prefill_chunk;
        let mut drafts: Vec<Vec<i32>> = vec![Vec::new(); b];
        let mut any = false;
        for (i, slot) in self.lanes.iter().enumerate() {
            let Some(lane) = slot else { continue };
            // the round emits at least one token; drafting past the
            // budget would only feed tokens we'd have to throw away
            let room = lane.budget.saturating_sub(lane.generated.len());
            if room <= 1 {
                continue;
            }
            let cap = self.speculate.min(c - 1).min(room - 1);
            let d = self.drafter.draft(i, cap);
            if !d.is_empty() {
                any = true;
            }
            drafts[i] = d;
        }
        if !any {
            return Ok(false);
        }
        let prog = self.bundle.program("prefill")?;
        // verify chunk per lane: [t0, d1..dm], t0 the sampled-but-unfed
        // last token (exactly what single-token decode would feed)
        let mut toks = vec![0i32; b * c];
        let mut active = vec![0i32; b];
        for (i, slot) in self.lanes.iter().enumerate() {
            let Some(lane) = slot else { continue };
            toks[i * c] = lane.generated.last().copied().unwrap_or(0);
            for (j, &d) in drafts[i].iter().enumerate() {
                toks[i * c + 1 + j] = d;
            }
            active[i] = 1 + drafts[i].len() as i32;
        }
        self.state.upload_dirty()?;
        let tok_buf = upload(
            &self.bundle.client,
            &HostTensor::from_i32(&[b, c], &toks)?,
        )?;
        let act_buf = upload(
            &self.bundle.client,
            &HostTensor::from_i32(&[b], &active)?,
        )?;
        let ek_buf = self.prefill_expert_k_buf()?;
        let out =
            self.run_prefill_dispatch(prog, &tok_buf, &act_buf, ek_buf.as_ref())?;
        self.steps_executed += 1;
        self.spec_rounds += 1;
        self.spec_drafted +=
            drafts.iter().map(|d| d.len() as u64).sum::<u64>();
        if self.counts_idx_prefill.is_none() {
            self.expert_stats_unavailable += 1;
        }
        // score the drafts before deciding what to do with the memory
        // outputs: row j of lane i is the next-token distribution after
        // feeding toks[i*c + j]
        let v = self.vocab;
        let logits = download(&self.bundle.client, &out[0])?.as_f32()?;
        let mut accepted = vec![0usize; b];
        let mut emitted: Vec<Vec<i32>> = vec![Vec::new(); b];
        let mut poisoned = vec![false; b];
        for i in 0..b {
            let Some(lane) = &mut self.lanes[i] else { continue };
            let m = drafts[i].len();
            for j in 0..=m {
                let row = &logits[(i * c + j) * v..(i * c + j + 1) * v];
                // same per-lane poison containment as the plain paths
                if row.iter().any(|x| !x.is_finite()) {
                    poisoned[i] = true;
                    break;
                }
                match lane.sampler.sample(row, &mut self.rng) {
                    None => {
                        poisoned[i] = true;
                        break;
                    }
                    Some(tok) => {
                        let tok = tok as i32;
                        emitted[i].push(tok);
                        if j < m && tok == drafts[i][j] {
                            accepted[i] += 1;
                        } else {
                            // first disagreement: `tok` is the
                            // correction; nothing after it is valid
                            break;
                        }
                    }
                }
            }
        }
        if self.spec_accept_hist.len() <= self.speculate {
            self.spec_accept_hist.resize(self.speculate + 1, 0);
        }
        for i in 0..b {
            if self.lanes[i].is_some() && !drafts[i].is_empty() && !poisoned[i]
            {
                self.spec_accepted += accepted[i] as u64;
                self.spec_accept_hist[accepted[i]] += 1;
            }
        }
        // poisoned lanes don't force a rollback: they are dropped below
        // and their memory rows are reset at the lane's next admission
        let all_accept = (0..b).all(|i| match &self.lanes[i] {
            Some(_) => poisoned[i] || accepted[i] == drafts[i].len(),
            None => true,
        });
        if all_accept {
            // every fed token is committed; adopt the verify outputs
            self.tokens_processed +=
                active.iter().map(|&a| a as u64).sum::<u64>();
            self.absorb_feedback(out, true)?;
        } else {
            // roll back: drop the verify outputs (pre-round memories
            // are still live) and re-commit only the accepted prefixes
            drop(out);
            self.spec_rollbacks += 1;
            let mut ctoks = vec![0i32; b * c];
            let mut cactive = vec![0i32; b];
            for i in 0..b {
                if self.lanes[i].is_none() || poisoned[i] {
                    continue;
                }
                let n = 1 + accepted[i];
                ctoks[i * c..i * c + n]
                    .copy_from_slice(&toks[i * c..i * c + n]);
                cactive[i] = n as i32;
            }
            let ctok_buf = upload(
                &self.bundle.client,
                &HostTensor::from_i32(&[b, c], &ctoks)?,
            )?;
            let cact_buf = upload(
                &self.bundle.client,
                &HostTensor::from_i32(&[b], &cactive)?,
            )?;
            // ek_buf is reusable: dispatch inputs are never donated
            let cout = self.run_prefill_dispatch(
                prog,
                &ctok_buf,
                &cact_buf,
                ek_buf.as_ref(),
            )?;
            self.steps_executed += 1;
            self.spec_commit_steps += 1;
            if self.counts_idx_prefill.is_none() {
                self.expert_stats_unavailable += 1;
            }
            self.tokens_processed +=
                cactive.iter().map(|&a| a as u64).sum::<u64>();
            // logits (output 0) of the commit are discarded — the
            // correction token was already sampled from the verify pass
            self.absorb_feedback(cout, true)?;
        }
        // emission + retirement (the speculative sibling of
        // sample_and_finish: a round can emit several tokens per lane)
        for i in 0..b {
            if self.lanes[i].is_none() {
                continue;
            }
            if poisoned[i] {
                let lane = self.lanes[i].take().unwrap();
                self.lanes_poisoned += 1;
                if let Some(tx) = lane.events {
                    let _ = tx
                        .send(StreamEvent::Dropped(DropReason::EngineFailure));
                }
                continue;
            }
            let mut finished = false;
            {
                let lane = self.lanes[i].as_mut().unwrap();
                for &tok in &emitted[i] {
                    lane.generated.push(tok);
                    self.tokens_generated += 1;
                    self.drafter.observe(i, tok);
                    if let Some(tx) = &lane.events {
                        let _ = tx.send(StreamEvent::Token(tok));
                    }
                    if lane.generated.len() >= lane.budget {
                        finished = true;
                        break;
                    }
                }
            }
            if finished {
                let lane = self.lanes[i].take().unwrap();
                let res = GenResult {
                    prompt: lane.request.prompt.clone(),
                    tokens: lane.generated,
                    queue_time: lane.admitted_at - lane.queued_at,
                    run_time: self
                        .clock
                        .now()
                        .duration_since(lane.admitted_at),
                    prompt_len: lane.request.prompt.len(),
                };
                if let Some(tx) = lane.done_tx {
                    let _ = tx.send(res.clone());
                }
                if let Some(tx) = lane.events {
                    let _ = tx.send(StreamEvent::Done(res));
                }
            }
        }
        Ok(true)
    }

    /// Post-dispatch bookkeeping shared by both pump paths: for each
    /// lane flagged in `sample`, guard against non-finite logits
    /// (per-lane poison containment), sample one continuation token,
    /// stream it, and retire lanes that hit their budget.
    fn sample_and_finish(
        &mut self,
        logits: &[f32],
        vocab: usize,
        sample: &[bool],
    ) {
        for i in 0..self.lanes.len() {
            let mut finished = false;
            let mut poisoned = false;
            if let Some(lane) = &mut self.lanes[i] {
                if sample[i] {
                    let row = &logits[i * vocab..(i + 1) * vocab];
                    // poisoned-lane guard: a NaN/Inf logits row means
                    // this lane's state is numerically corrupt and
                    // every later token from it would be garbage.  The
                    // corruption is per-lane (each lane's memories are
                    // independent rows, and both the prefill and reset
                    // masks are select-based, NaN-safe), so only this
                    // request is failed — the lane's memory is zeroed
                    // by the normal reset path on its next admission
                    // and the engine keeps serving its other lanes.
                    if row.iter().any(|v| !v.is_finite()) {
                        poisoned = true;
                    } else {
                        match lane.sampler.sample(row, &mut self.rng) {
                            Some(tok) => {
                                let tok = tok as i32;
                                lane.generated.push(tok);
                                self.tokens_generated += 1;
                                if self.speculate > 0 {
                                    self.drafter.observe(i, tok);
                                }
                                if let Some(tx) = &lane.events {
                                    let _ =
                                        tx.send(StreamEvent::Token(tok));
                                }
                                if lane.generated.len() >= lane.budget {
                                    finished = true;
                                }
                            }
                            // second line of defense: the sampler saw
                            // nothing finite (unreachable behind the
                            // row guard above, but the contract is
                            // poisoned-lane, never token 0)
                            None => poisoned = true,
                        }
                    }
                }
            }
            if poisoned {
                let lane = self.lanes[i].take().unwrap();
                self.lanes_poisoned += 1;
                if let Some(tx) = lane.events {
                    let _ = tx
                        .send(StreamEvent::Dropped(DropReason::EngineFailure));
                }
                // the in-process path (done_tx) learns via the channel
                // disconnecting instead of a result
            }
            if finished {
                let lane = self.lanes[i].take().unwrap();
                let res = GenResult {
                    prompt: lane.request.prompt.clone(),
                    tokens: lane.generated,
                    queue_time: lane.admitted_at - lane.queued_at,
                    run_time: self.clock.now().duration_since(lane.admitted_at),
                    prompt_len: lane.request.prompt.len(),
                };
                if let Some(tx) = lane.done_tx {
                    let _ = tx.send(res.clone());
                }
                if let Some(tx) = lane.events {
                    let _ = tx.send(StreamEvent::Done(res));
                }
            }
        }
    }

    /// Drive all submitted requests to completion, collecting results.
    pub fn run_to_completion(
        &mut self,
        receivers: Vec<mpsc::Receiver<GenResult>>,
    ) -> Result<Vec<GenResult>> {
        while self.pump()? > 0 {}
        let mut out = Vec::new();
        for rx in receivers {
            out.push(rx.recv().map_err(|_| {
                Error::Serving("request dropped without result".into())
            })?);
        }
        Ok(out)
    }

    /// Host↔device traffic of the underlying client so far.
    pub fn transfer_stats(&self) -> TransferSnapshot {
        self.state.transfers()
    }

    /// Prompt tokens one pump can ingest per lane (the `prefill`
    /// program's chunk width C); 1 when the artifact predates the
    /// program and prompts stream one token per pump.
    pub fn prefill_chunk(&self) -> usize {
        if self.prefill_inputs.is_some() {
            self.prefill_chunk
        } else {
            1
        }
    }

    /// Throughput summary over the engine's lifetime.
    ///
    /// `mean_batch_occupancy` counts every token an active lane consumed
    /// per step — prompt phase included (the seed divided *generated*
    /// tokens by steps, understating occupancy during prefill; that
    /// metric survives as `mean_gen_occupancy`).  With chunked prefill
    /// a pump can consume up to C tokens per lane, so this can exceed
    /// `n_lanes` — it measures tokens per dispatch, the quantity the
    /// chunking amortizes dispatch overhead over.
    pub fn stats(&self) -> BTreeMap<String, f64> {
        let mut m = BTreeMap::new();
        let steps = self.steps_executed as f64;
        m.insert("steps_executed".into(), steps);
        m.insert("tokens_generated".into(), self.tokens_generated as f64);
        m.insert("tokens_processed".into(), self.tokens_processed as f64);
        m.insert(
            "mean_batch_occupancy".into(),
            if self.steps_executed > 0 {
                self.tokens_processed as f64 / steps
            } else {
                0.0
            },
        );
        m.insert(
            "mean_gen_occupancy".into(),
            if self.steps_executed > 0 {
                self.tokens_generated as f64 / steps
            } else {
                0.0
            },
        );
        m.insert("n_lanes".into(), self.lanes.len() as f64);
        m.insert(
            "lane_resets_device".into(),
            self.lane_resets_device as f64,
        );
        m.insert("lane_resets_host".into(), self.lane_resets_host as f64);
        m.insert(
            "prefill_steps_device".into(),
            self.prefill_steps_device as f64,
        );
        m.insert(
            "prefill_steps_host".into(),
            self.prefill_steps_host as f64,
        );
        m.insert("prefill_tokens".into(), self.prefill_tokens as f64);
        m.insert("prefill_chunk".into(), self.prefill_chunk() as f64);
        m.insert("lanes_poisoned".into(), self.lanes_poisoned as f64);
        m.insert(
            "expert_stats_unavailable".into(),
            self.expert_stats_unavailable as f64,
        );
        if let Some(mx) = self.expert_k_max {
            m.insert("expert_k_max".into(), mx as f64);
            m.insert(
                "expert_k_current".into(),
                self.expert_k_current as f64,
            );
        }
        // speculative-decode families appear only on speculating
        // engines, mirroring the expert-k gauges above — a fleet with
        // `--speculate 0` exports no spec_* series at all
        if self.speculate > 0 {
            m.insert("speculate".into(), self.speculate as f64);
            m.insert("spec_rounds".into(), self.spec_rounds as f64);
            m.insert("spec_drafted".into(), self.spec_drafted as f64);
            m.insert("spec_accepted".into(), self.spec_accepted as f64);
            m.insert(
                "spec_accept_rate".into(),
                if self.spec_drafted > 0 {
                    self.spec_accepted as f64 / self.spec_drafted as f64
                } else {
                    0.0
                },
            );
            m.insert("spec_rollbacks".into(), self.spec_rollbacks as f64);
            m.insert(
                "spec_commit_steps".into(),
                self.spec_commit_steps as f64,
            );
            for (n, &count) in self.spec_accept_hist.iter().enumerate() {
                m.insert(format!("spec_hist_{n}"), count as f64);
            }
        }
        // prefix-cache families appear only on cache-armed engines,
        // mirroring the spec_* gauges above — an un-armed fleet
        // exports no prefix_cache_* series at all.  These are the
        // engine-local counters; the shared cache's global state
        // (entries/bytes/evictions) is exported once per document.
        if self.prefix_cache.is_some() {
            m.insert(
                "prefix_cache_hits".into(),
                self.prefix_cache_hits as f64,
            );
            m.insert(
                "prefix_cache_misses".into(),
                self.prefix_cache_misses as f64,
            );
            m.insert(
                "prefix_cache_tokens_saved".into(),
                self.prefix_cache_tokens_saved as f64,
            );
            m.insert(
                "prefix_cache_snapshots".into(),
                self.prefix_cache_snapshots as f64,
            );
            m.insert(
                "prefix_cache_restores_device".into(),
                self.prefix_cache_restores_device as f64,
            );
            m.insert(
                "prefix_cache_restores_host".into(),
                self.prefix_cache_restores_host as f64,
            );
            m.insert(
                "prefix_cache_unavailable".into(),
                self.prefix_cache_unavailable as f64,
            );
        }
        let xfer = self.state.transfers();
        m.insert("h2d_bytes".into(), xfer.h2d_bytes as f64);
        m.insert("d2h_bytes".into(), xfer.d2h_bytes as f64);
        m
    }
}

impl EngineBackend for Engine<'_> {
    fn n_lanes(&self) -> usize {
        Engine::n_lanes(self)
    }

    fn free_lanes(&self) -> usize {
        Engine::free_lanes(self)
    }

    fn prefill_chunk(&self) -> usize {
        Engine::prefill_chunk(self)
    }

    fn submit_streaming(
        &mut self,
        req: GenRequest,
        events: mpsc::Sender<StreamEvent>,
    ) {
        Engine::submit_streaming(self, req, events)
    }

    fn pump(&mut self) -> Result<usize> {
        Engine::pump(self)
    }

    fn stats(&self) -> BTreeMap<String, f64> {
        Engine::stats(self)
    }

    fn take_expert_counts(&mut self) -> Option<Vec<Vec<u64>>> {
        if self.counts_idx_step.is_none() && self.counts_idx_prefill.is_none()
        {
            return None;
        }
        Some(std::mem::take(&mut self.expert_counts))
    }

    fn expert_k_max(&self) -> Option<usize> {
        self.expert_k_max
    }

    fn set_expert_k(&mut self, k: usize) {
        self.sched_expert_k = k.max(1);
    }

    fn set_prefix_cache(&mut self, cache: Arc<PrefixCache>) {
        self.prefix_cache = Some(cache);
    }

    fn set_speculate(&mut self, k: usize) {
        // speculation needs the all-position verifier; without it the
        // knob stays pinned at whatever new() resolved (0)
        if !self.prefill_verify_all || self.prefill_inputs.is_none() {
            return;
        }
        self.speculate = k.min(self.prefill_chunk.saturating_sub(1));
        if self.spec_accept_hist.len() < self.speculate + 1 {
            self.spec_accept_hist.resize(self.speculate + 1, 0);
        }
    }

    fn take_spec_feedback(&mut self) -> (u64, u64) {
        let d = self.spec_drafted - self.spec_fb_drained.0;
        let a = self.spec_accepted - self.spec_fb_drained.1;
        self.spec_fb_drained = (self.spec_drafted, self.spec_accepted);
        (d, a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_lane(tag: i32) -> Lane {
        let (tx, _rx) = mpsc::channel();
        Lane::new(
            GenRequest {
                prompt: vec![tag],
                max_new_tokens: 1,
                sampler: Sampler::greedy(),
                ..Default::default()
            },
            Some(tx),
            None,
            Instant::now(),
        )
    }

    fn tag_of(lane: &Option<Lane>) -> i32 {
        lane.as_ref().unwrap().request.prompt[0]
    }

    #[test]
    fn admit_is_fifo_into_lowest_free_lanes() {
        let mut lanes: Vec<Option<Lane>> = (0..3).map(|_| None).collect();
        let mut queue: VecDeque<Lane> =
            (0..5).map(|i| mk_lane(i as i32)).collect();
        let admitted = admit_fifo(&mut lanes, &mut queue, Instant::now());
        assert_eq!(admitted, vec![0, 1, 2]);
        assert_eq!(queue.len(), 2);
        // oldest request landed in the lowest lane
        for (i, lane) in lanes.iter().enumerate() {
            assert_eq!(tag_of(lane), i as i32);
        }
        // free lane 1; the next queued request (tag 3) must take it
        lanes[1] = None;
        let admitted = admit_fifo(&mut lanes, &mut queue, Instant::now());
        assert_eq!(admitted, vec![1]);
        assert_eq!(tag_of(&lanes[1]), 3);
        assert_eq!(queue.front().unwrap().request.prompt[0], 4);
    }

    #[test]
    fn admit_with_empty_queue_is_noop() {
        let mut lanes: Vec<Option<Lane>> = (0..2).map(|_| None).collect();
        let mut queue: VecDeque<Lane> = VecDeque::new();
        assert!(admit_fifo(&mut lanes, &mut queue, Instant::now()).is_empty());
        assert!(lanes.iter().all(|l| l.is_none()));
    }

    #[test]
    fn lane_new_queues_whole_prompt_and_keeps_sinks() {
        let (tx, rx) = mpsc::channel();
        let lane = Lane::new(
            GenRequest {
                prompt: vec![3, 1, 4],
                max_new_tokens: 5,
                sampler: Sampler::greedy(),
                ..Default::default()
            },
            None,
            Some(tx),
            Instant::now(),
        );
        assert_eq!(lane.pending, VecDeque::from(vec![3, 1, 4]));
        assert_eq!(lane.budget, 5);
        assert!(lane.done_tx.is_none());
        lane.events
            .as_ref()
            .unwrap()
            .send(StreamEvent::Token(42))
            .unwrap();
        assert!(matches!(rx.try_recv(), Ok(StreamEvent::Token(42))));
    }

    #[test]
    fn zero_lane_row_zeroes_only_that_row() {
        // [3, 2, 2] memory filled with ones; zero lane 1
        let mut t =
            HostTensor::from_f32(&[3, 2, 2], &[1.0f32; 12]).unwrap();
        zero_lane_row(&mut t, 1);
        let vals = t.as_f32().unwrap();
        assert_eq!(&vals[0..4], &[1.0; 4]);
        assert_eq!(&vals[4..8], &[0.0; 4]);
        assert_eq!(&vals[8..12], &[1.0; 4]);
    }
}
