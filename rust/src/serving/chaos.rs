//! Seeded chaos harness + deterministic record/replay over the mock
//! fleet.
//!
//! The harness runs the *real* router — [`Fleet::placer_step`] and
//! [`Fleet::engine_step`] are the same code the production threads
//! loop over — but single-threaded, on a [`SimClock`], against
//! [`MockBackend`]s with seeded faults.  Every scheduling decision the
//! fleet makes lands in a [`Journal`] as a logically-timestamped JSONL
//! event, so a run is fully described by its [`ChaosCfg`] (itself
//! fully described by a seed): re-running the same config MUST
//! reproduce the identical decision stream and the identical final
//! metrics snapshot, byte for byte.  That is what [`replay`] asserts.
//!
//! A chaos run layers four failure modes over the fleet:
//!
//! * [`MockFault::ErrorAfter`] — an engine starts erroring forever
//!   (consecutive-error quarantine, permanent loss),
//! * [`MockFault::RestartAfter`] — an engine drops all device state
//!   and errors for a bounded streak (quarantine → failover →
//!   re-admission),
//! * [`MockFault::NanLogits`] — poisoned device state surfaces at
//!   sample time,
//! * [`MockFault::StallAfter`] with a pre-released flag — a wedge
//!   that resolves into a single error (a blocking wedge would
//!   deadlock a single-threaded harness; true wedges are modelled as
//!   *outage windows* instead: the schedule simply stops stepping an
//!   engine, its heartbeat goes stale, and the staleness quarantine
//!   path runs).
//!
//! After the storm the harness checks the serving invariants that the
//! multi-threaded integration tests check statistically, but here
//! exhaustively and reproducibly:
//!
//! 1. **exactly-once** — every accepted request sees exactly one
//!    terminal event (`Done` or `Dropped`), never zero, never two;
//! 2. **never-double-send** — a completed request's token stream is
//!    exactly the deterministic greedy continuation of its prompt, at
//!    exactly its budget length: a replayed-after-failover request
//!    must not leak duplicate or stale tokens through the relay;
//! 3. **row-sum-equals-totals** — per-engine completion counters sum
//!    to the number of `Done` events observed at the frontends.
//!
//! Any violation carries the seed and the trace; `replay` re-executes
//! the trace device-free from its header alone.

use std::path::Path;
use std::sync::atomic::AtomicBool;
use std::sync::{mpsc, Arc};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::json::{self, Json};
use crate::rng::Rng;
use crate::serving::clock::{Clock, SharedClock, SimClock};
use crate::serving::engine::{GenRequest, StreamEvent};
use crate::serving::journal::{Journal, Trace};
use crate::serving::mock::{MockBackend, MockFault, MOCK_TOP_K};
use crate::serving::prefix_cache::PrefixCache;
use crate::serving::router::{Fleet, Placement, RouterCfg};
use crate::serving::sampler::Sampler;
use crate::serving::scheduler::{DegradeCfg, Policy};

/// Simulated time per harness round (placer step + one step per
/// live engine).  Matches the production placer tick.
pub const CHAOS_TICK: Duration = Duration::from_millis(10);

/// Heartbeat staleness bound for harness fleets.  Must exceed the
/// worst-case simulated time a single round can advance (the tick
/// plus one error-backoff sleep per faulty engine), with margin, so
/// an engine that *is* stepped every round is never spuriously
/// quarantined.
const HEARTBEAT_TIMEOUT: Duration = Duration::from_millis(500);

/// Extra drain rounds after the scheduled storm before undelivered
/// terminals are declared a liveness violation.
const DRAIN_ROUNDS: u64 = 20_000;

/// How many violations are itemized before the rest are summarized.
const MAX_REPORTED: usize = 20;

/// One seeded chaos/record run, fully describing the deterministic
/// schedule: same config ⇒ same decision stream, byte for byte.
#[derive(Debug, Clone)]
pub struct ChaosCfg {
    /// Mock engines in the fleet (engine 0 is always kept fault- and
    /// outage-free so the storm cannot extinguish the whole fleet).
    pub engines: usize,
    /// Lanes per mock engine.
    pub lanes: usize,
    /// Mock vocabulary (token values are `< vocab`).
    pub vocab: usize,
    /// Requests injected over the first half of the storm.
    pub requests: usize,
    /// Scheduled storm rounds (the drain grace comes on top).
    pub pumps: u64,
    /// Master seed: requests, arrival times, deadlines, faults and
    /// outage windows all derive from it.
    pub seed: u64,
    /// Inject the fault storm.  Off = a clean deterministic load run
    /// (the `loadgen --record` path).
    pub storm: bool,
    /// Adaptive expert-k policy on the shared scheduler (ceiling
    /// [`MOCK_TOP_K`]).  `None` = fixed k, the pre-adaptive behavior;
    /// traces recorded before this field parse as `None`.
    pub degrade: Option<DegradeCfg>,
    /// Speculative draft length per lane per verify round on the mock
    /// engines (`0` = plain single-token decode).  Traces recorded
    /// before speculation carry no field and parse as `0`.
    pub speculate: usize,
    /// Fleet-wide prefix-cache byte budget (`None` = off).  Traces
    /// recorded before the cache carry no field and parse as `None`,
    /// so they replay against the cold-prefill path unchanged.
    pub prefix_cache: Option<u64>,
}

impl Default for ChaosCfg {
    fn default() -> Self {
        ChaosCfg {
            engines: 3,
            lanes: 2,
            vocab: 64,
            requests: 24,
            pumps: 600,
            seed: 1,
            storm: true,
            degrade: None,
            speculate: 0,
            prefix_cache: None,
        }
    }
}

impl ChaosCfg {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("engines", json::num(self.engines as f64)),
            ("lanes", json::num(self.lanes as f64)),
            ("vocab", json::num(self.vocab as f64)),
            ("requests", json::num(self.requests as f64)),
            ("pumps", json::num(self.pumps as f64)),
            ("seed", json::num(self.seed as f64)),
            ("storm", Json::Bool(self.storm)),
        ];
        if let Some(d) = self.degrade {
            fields.push(("degrade", json::s(&d.to_flag())));
        }
        if self.speculate > 0 {
            fields.push(("speculate", json::num(self.speculate as f64)));
        }
        if let Some(b) = self.prefix_cache {
            fields.push(("prefix_cache", json::num(b as f64)));
        }
        json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<ChaosCfg> {
        Ok(ChaosCfg {
            engines: j.get("engines")?.as_usize()?,
            lanes: j.get("lanes")?.as_usize()?,
            vocab: j.get("vocab")?.as_usize()?,
            requests: j.get("requests")?.as_usize()?,
            pumps: j.get("pumps")?.as_f64()? as u64,
            seed: j.get("seed")?.as_f64()? as u64,
            storm: j.get("storm")?.as_bool()?,
            // absent on traces recorded before adaptive-k: fixed k
            degrade: j
                .opt("degrade")
                .map(|v| DegradeCfg::parse(v.as_str()?))
                .transpose()?,
            // absent on traces recorded before speculative decode
            speculate: j
                .opt("speculate")
                .map(|v| v.as_usize())
                .transpose()?
                .unwrap_or(0),
            // absent on traces recorded before the prefix cache: cold
            // prefill, so old traces replay bit-for-bit
            prefix_cache: j
                .opt("prefix_cache")
                .map(|v| v.as_f64().map(|b| b as u64))
                .transpose()?,
        })
    }
}

/// What happened in one chaos run: counts, invariant violations, the
/// recorded decision stream, and the deterministic metrics snapshot.
pub struct ChaosReport {
    pub cfg: ChaosCfg,
    /// Rounds actually executed (storm + drain until quiescent).
    pub rounds: u64,
    /// Requests the scheduler accepted (vs. rejected at the queue).
    pub accepted: usize,
    pub rejected: usize,
    pub dones: usize,
    pub drops: usize,
    pub failovers: u64,
    pub readmissions: u64,
    /// Invariant violations (empty on a clean run).  Each line is
    /// self-contained; the seed reproduces all of them.
    pub violations: Vec<String>,
    /// The journal's event stream (JSONL) — the byte stream replay
    /// diffs.
    pub events: String,
    /// The full trace document (header + events).
    pub trace: String,
    /// Deterministic final metrics (fleet + scheduler JSON).
    pub metrics: Json,
}

impl ChaosReport {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    /// Flush the trace document to `path` (creating parent
    /// directories).
    pub fn write_trace(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, &self.trace)?;
        Ok(())
    }

    /// One summary row for the CLI / CI log.
    pub fn summary_json(&self) -> Json {
        json::obj(vec![
            ("mode", json::s("chaos")),
            ("seed", json::num(self.cfg.seed as f64)),
            ("engines", json::num(self.cfg.engines as f64)),
            ("requests", json::num(self.cfg.requests as f64)),
            ("rounds", json::num(self.rounds as f64)),
            ("accepted", json::num(self.accepted as f64)),
            ("rejected", json::num(self.rejected as f64)),
            ("done", json::num(self.dones as f64)),
            ("dropped", json::num(self.drops as f64)),
            ("failovers", json::num(self.failovers as f64)),
            ("readmissions", json::num(self.readmissions as f64)),
            ("events", json::num(self.events.lines().count() as f64)),
            ("violations", json::num(self.violations.len() as f64)),
        ])
    }
}

/// One frontend: the receiver half of an accepted request plus what
/// has been observed on it.
struct Client {
    prompt: Vec<i32>,
    budget: usize,
    deadline: Option<Duration>,
    arrival: u64,
    rx: Option<mpsc::Receiver<StreamEvent>>,
    rejected: bool,
    admitted: u32,
    dones: u32,
    drops: u32,
    tokens: Vec<i32>,
    done_len: usize,
}

impl Client {
    fn terminal(&self) -> bool {
        self.rejected || self.dones + self.drops > 0
    }

    fn drain(&mut self) {
        let Some(rx) = &self.rx else { return };
        while let Ok(ev) = rx.try_recv() {
            match ev {
                StreamEvent::Admitted => self.admitted += 1,
                StreamEvent::Token(t) => self.tokens.push(t),
                StreamEvent::Done(res) => {
                    self.dones += 1;
                    self.done_len = res.tokens.len();
                }
                StreamEvent::Dropped(_) => self.drops += 1,
            }
        }
    }
}

/// The seeded per-engine trouble assignment.
enum Trouble {
    None,
    Fault(MockFault),
    /// Pre-released stall: the wedge resolves into one error the
    /// moment it trips (a live wedge would deadlock the
    /// single-threaded harness — see the module docs).
    ReleasedStall(u64),
    /// The schedule stops stepping this engine for rounds in
    /// `[start, start + len)`: its heartbeat goes stale and the
    /// staleness-quarantine / re-admission path runs.
    Outage { start: u64, len: u64 },
}

/// Derive the full deterministic schedule from the seed: request
/// specs, arrival rounds, and per-engine trouble.
fn build_schedule(
    cfg: &ChaosCfg,
    rng: &mut Rng,
) -> (Vec<(Vec<i32>, usize, Option<Duration>, u64)>, Vec<Trouble>) {
    let horizon = (cfg.pumps / 2).max(1) as usize;
    let mut reqs = Vec::with_capacity(cfg.requests);
    for _ in 0..cfg.requests {
        let plen = 1 + rng.below(6);
        let prompt: Vec<i32> =
            (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect();
        let budget = 1 + rng.below(6);
        let deadline = if rng.coin(0.15) {
            Some(Duration::from_millis(100 + rng.below(400) as u64))
        } else {
            None
        };
        let arrival = rng.below(horizon) as u64;
        reqs.push((prompt, budget, deadline, arrival));
    }
    let mut trouble = Vec::with_capacity(cfg.engines);
    for e in 0..cfg.engines {
        if !cfg.storm || e == 0 {
            // engine 0 never fails: the storm degrades the fleet, it
            // must not be able to extinguish it
            trouble.push(Trouble::None);
            continue;
        }
        let after = 5 + rng.below(40) as u64;
        trouble.push(match rng.below(5) {
            0 => Trouble::Fault(MockFault::ErrorAfter(after)),
            1 => Trouble::Fault(MockFault::RestartAfter(after)),
            2 => Trouble::Fault(MockFault::NanLogits),
            3 => Trouble::ReleasedStall(after),
            _ => Trouble::Outage {
                start: cfg.pumps / 4 + rng.below((cfg.pumps / 4).max(1) as usize) as u64,
                len: 80 + rng.below(80) as u64,
            },
        });
    }
    (reqs, trouble)
}

/// Run one seeded chaos/record schedule to quiescence and check the
/// serving invariants.  Pure simulation: no threads, no sockets, no
/// wall clock — same config in, same bytes out.
pub fn run(cfg: &ChaosCfg) -> Result<ChaosReport> {
    if cfg.engines == 0 || cfg.lanes == 0 || cfg.vocab == 0 {
        return Err(Error::Serving(
            "chaos: engines, lanes and vocab must be positive".into(),
        ));
    }
    let sim = SimClock::shared();
    let clock: SharedClock = sim.clone();
    let journal = Arc::new(Journal::new(clock.clone()));
    let shutdown = Arc::new(AtomicBool::new(false));
    let rcfg = RouterCfg {
        engines: cfg.engines,
        placement: Placement::LeastLoaded,
        heartbeat_timeout: HEARTBEAT_TIMEOUT,
        error_threshold: 3,
        max_retries: 3,
        readmit_after: 5,
    };
    let fleet = Fleet::with_clock_journal(
        rcfg,
        cfg.requests.max(1),
        Policy::Deadline,
        shutdown,
        1,
        clock.clone(),
        journal.clone(),
    );
    let fleet = match cfg.degrade {
        Some(d) => fleet.with_degrade_k(d, MOCK_TOP_K),
        None => fleet,
    };
    let fleet = if cfg.speculate > 0 {
        // arms the shared scheduler's spec-K autotune: the hysteresis
        // transitions journal deterministically and replay byte-for-byte
        fleet.with_speculate(cfg.speculate)
    } else {
        fleet
    };
    let fleet = match cfg.prefix_cache {
        Some(budget) => {
            fleet.with_prefix_cache(PrefixCache::shared(budget))
        }
        None => fleet,
    };

    let mut rng = Rng::new(cfg.seed);
    let (reqs, trouble) = build_schedule(cfg, &mut rng);

    let mut backends: Vec<MockBackend> = Vec::with_capacity(cfg.engines);
    let mut outages: Vec<Option<(u64, u64)>> = Vec::with_capacity(cfg.engines);
    for t in &trouble {
        let mut b = MockBackend::new(cfg.lanes, cfg.vocab)
            .with_clock(clock.clone());
        if cfg.speculate > 0 {
            // the mock verifies drafts through its chunked-prefill
            // path, so the chunk must leave room for 1 + K tokens
            b = b
                .with_prefill_chunk(cfg.speculate + 1)
                .with_speculate(cfg.speculate);
        }
        // the harness calls engine_step directly (never run_engine),
        // so backends are armed here rather than by the fleet
        if let Some(cache) = fleet.prefix_cache() {
            b = b.with_prefix_cache(cache.clone());
        }
        let mut window = None;
        match t {
            Trouble::None => {}
            Trouble::Fault(f) => b = b.with_fault(f.clone()),
            Trouble::ReleasedStall(after) => {
                b = b.with_fault(MockFault::StallAfter(*after));
                b.stall_release()
                    .store(true, std::sync::atomic::Ordering::Relaxed);
            }
            Trouble::Outage { start, len } => {
                window = Some((*start, *start + *len));
            }
        }
        backends.push(b);
        outages.push(window);
    }

    let mut inflights: Vec<Vec<(u64, mpsc::Receiver<StreamEvent>)>> =
        (0..cfg.engines).map(|_| Vec::new()).collect();
    let mut results: Vec<Result<()>> = (0..cfg.engines).map(|_| Ok(())).collect();

    // bucket arrivals by round
    let horizon = (cfg.pumps / 2).max(1) as usize;
    let mut arrivals: Vec<Vec<usize>> = vec![Vec::new(); horizon];
    let mut clients: Vec<Client> = Vec::with_capacity(cfg.requests);
    for (i, (prompt, budget, deadline, arrival)) in reqs.into_iter().enumerate() {
        arrivals[arrival as usize].push(i);
        clients.push(Client {
            prompt,
            budget,
            deadline,
            arrival,
            rx: None,
            rejected: false,
            admitted: 0,
            dones: 0,
            drops: 0,
            tokens: Vec::new(),
            done_len: 0,
        });
    }

    let mut accepted = 0usize;
    let mut rejected = 0usize;
    let max_rounds = cfg.pumps + DRAIN_ROUNDS;
    let mut round: u64 = 0;
    while round < max_rounds {
        if let Some(due) = arrivals.get(round as usize) {
            for &ci in due {
                let c = &mut clients[ci];
                let (tx, rx) = mpsc::channel();
                let req = GenRequest {
                    prompt: c.prompt.clone(),
                    max_new_tokens: c.budget,
                    sampler: Sampler::greedy(),
                    ..Default::default()
                };
                match fleet.sched().enqueue(req, c.deadline, tx) {
                    Ok(_) => {
                        c.rx = Some(rx);
                        accepted += 1;
                    }
                    Err(_) => {
                        c.rejected = true;
                        rejected += 1;
                    }
                }
            }
        }
        fleet.placer_step(clock.now());
        for e in 0..cfg.engines {
            if let Some((start, end)) = outages[e] {
                if round >= start && round < end {
                    continue; // wedged: no beat, no pump, no relay
                }
            }
            let _ = fleet.engine_step(
                e,
                &mut backends[e],
                &mut inflights[e],
                &mut results[e],
            );
        }
        for c in clients.iter_mut() {
            c.drain();
        }
        sim.advance(CHAOS_TICK);
        round += 1;
        if round as usize >= horizon && clients.iter().all(Client::terminal) {
            break;
        }
    }
    // late events can still sit in channels after the final step
    for c in clients.iter_mut() {
        c.drain();
    }

    let mut violations = Vec::new();
    let push = |violations: &mut Vec<String>, msg: String| {
        if violations.len() < MAX_REPORTED {
            violations.push(msg);
        } else if violations.len() == MAX_REPORTED {
            violations.push("... further violations elided".to_string());
        }
    };
    let mut dones = 0usize;
    let mut drops = 0usize;
    for (i, c) in clients.iter().enumerate() {
        dones += c.dones as usize;
        drops += c.drops as usize;
        if c.rejected {
            continue;
        }
        let terminals = c.dones + c.drops;
        if terminals == 0 {
            push(
                &mut violations,
                format!(
                    "liveness: request {i} (arrival round {}) never \
                     reached a terminal event after {round} rounds",
                    c.arrival
                ),
            );
            continue;
        }
        if terminals > 1 {
            push(
                &mut violations,
                format!(
                    "exactly-once: request {i} saw {terminals} terminal \
                     events ({} done, {} dropped)",
                    c.dones, c.drops
                ),
            );
        }
        if c.admitted > 1 {
            push(
                &mut violations,
                format!(
                    "exactly-once: request {i} saw {} Admitted events",
                    c.admitted
                ),
            );
        }
        if c.dones > 0 {
            // never-double-send: the frontend stream must be exactly
            // the deterministic greedy continuation, at exactly the
            // budget length — failover replays must not leak stale or
            // duplicate tokens through the relay
            if c.tokens.len() != c.budget || c.done_len != c.budget {
                push(
                    &mut violations,
                    format!(
                        "double-send: request {i} streamed {} tokens \
                         (result carried {}) for budget {}",
                        c.tokens.len(),
                        c.done_len,
                        c.budget
                    ),
                );
            }
            for (k, &t) in c.tokens.iter().enumerate() {
                let want =
                    MockBackend::expected_token(&c.prompt, k, cfg.vocab);
                if t != want {
                    push(
                        &mut violations,
                        format!(
                            "double-send: request {i} token {k} is {t}, \
                             expected {want}"
                        ),
                    );
                    break;
                }
            }
        }
    }
    let completions: u64 =
        (0..cfg.engines).map(|e| fleet.engine_completions(e)).sum();
    if completions != dones as u64 {
        push(
            &mut violations,
            format!(
                "row-sum: per-engine completions sum to {completions} \
                 but frontends observed {dones} Done events"
            ),
        );
    }

    let metrics = json::obj(vec![
        ("fleet", fleet.fleet_json()),
        ("scheduler", fleet.sched().metrics_json()),
    ]);
    journal.set_meta(json::obj(vec![
        ("kind", json::s("chaos")),
        ("seed", json::num(cfg.seed as f64)),
        ("cfg", cfg.to_json()),
        ("metrics", metrics.clone()),
        ("rounds", json::num(round as f64)),
    ]));

    Ok(ChaosReport {
        cfg: cfg.clone(),
        rounds: round,
        accepted,
        rejected,
        dones,
        drops,
        failovers: fleet.failovers(),
        readmissions: fleet.readmissions(),
        violations,
        events: journal.events_jsonl(),
        trace: journal.to_trace(),
        metrics,
    })
}

/// Run a schedule and flush its trace to `path` (the `loadgen
/// --record` / `chaos --record` path).
pub fn record(cfg: &ChaosCfg, path: &Path) -> Result<ChaosReport> {
    let report = run(cfg)?;
    report.write_trace(path)?;
    Ok(report)
}

/// The verdict of replaying a recorded trace: the fresh report plus
/// whether its decision stream and metrics snapshot reproduced the
/// recording bit-for-bit.
pub struct ReplayOutcome {
    pub report: ChaosReport,
    pub events_match: bool,
    pub metrics_match: bool,
    /// First mismatching event (line number + both lines), if any.
    pub divergence: Option<String>,
}

impl ReplayOutcome {
    pub fn ok(&self) -> bool {
        self.events_match && self.metrics_match
    }
}

/// Re-execute a recorded trace from its header alone and diff the
/// fresh decision stream and metrics against the recording.
///
/// Refuses truncated traces outright: a ring-evicted prefix can never
/// byte-match a fresh run, so diffing one would report a spurious
/// divergence instead of the real problem (an undersized journal ring
/// — see `dropped_events` on `/metrics`).
pub fn replay(trace: &Trace) -> Result<ReplayOutcome> {
    if trace
        .header
        .opt("truncated")
        .map(|t| t.as_bool().unwrap_or(false))
        .unwrap_or(false)
    {
        let evicted = trace
            .header
            .opt("evicted")
            .and_then(|v| v.as_f64().ok())
            .unwrap_or(0.0);
        return Err(Error::Serving(format!(
            "refusing to replay a truncated trace ({evicted} events \
             ring-evicted before flush); re-record with a larger \
             journal ring"
        )));
    }
    let cfg = ChaosCfg::from_json(trace.header.get("cfg")?)?;
    let report = run(&cfg)?;
    let recorded = trace.events_jsonl();
    let events_match = report.events == recorded;
    let divergence = if events_match {
        None
    } else {
        let old: Vec<&str> = recorded.lines().collect();
        let new: Vec<&str> = report.events.lines().collect();
        let mut d = format!(
            "recorded {} events, replay produced {}",
            old.len(),
            new.len()
        );
        for i in 0..old.len().max(new.len()) {
            let a = old.get(i).copied().unwrap_or("<missing>");
            let b = new.get(i).copied().unwrap_or("<missing>");
            if a != b {
                d = format!(
                    "event {i} diverged:\n  recorded: {a}\n  replayed: {b}"
                );
                break;
            }
        }
        Some(d)
    };
    let metrics_match = match trace.header.opt("metrics") {
        Some(m) => {
            m.to_string_compact() == report.metrics.to_string_compact()
        }
        None => false,
    };
    Ok(ReplayOutcome {
        report,
        events_match,
        metrics_match,
        divergence,
    })
}

/// [`replay`] from a trace file on disk.
pub fn replay_path(path: &Path) -> Result<ReplayOutcome> {
    replay(&Trace::read(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small(storm: bool, seed: u64) -> ChaosCfg {
        ChaosCfg {
            engines: 3,
            lanes: 2,
            vocab: 32,
            requests: 12,
            pumps: 400,
            seed,
            storm,
            degrade: None,
            speculate: 0,
            prefix_cache: None,
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!(
            "sigma-moe-chaos-{}-{name}",
            std::process::id()
        ))
    }

    #[test]
    fn clean_run_holds_invariants_and_is_deterministic() {
        let cfg = small(false, 7);
        let a = run(&cfg).unwrap();
        let b = run(&cfg).unwrap();
        assert!(a.ok(), "violations: {:?}", a.violations);
        assert_eq!(a.accepted, cfg.requests);
        assert!(a.dones > 0);
        assert_eq!(a.events, b.events, "decision streams diverged");
        assert_eq!(
            a.metrics.to_string_compact(),
            b.metrics.to_string_compact(),
            "metrics snapshots diverged"
        );
    }

    #[test]
    fn storm_run_holds_invariants_and_is_deterministic() {
        let cfg = small(true, 3);
        let a = run(&cfg).unwrap();
        let b = run(&cfg).unwrap();
        assert!(a.ok(), "violations: {:?}", a.violations);
        assert_eq!(a.events, b.events, "decision streams diverged");
        assert_eq!(
            a.metrics.to_string_compact(),
            b.metrics.to_string_compact()
        );
        // every request still ends terminally under the storm
        assert_eq!(a.dones + a.drops + a.rejected, cfg.requests);
    }

    #[test]
    fn record_then_replay_matches_bit_for_bit() {
        let cfg = small(true, 11);
        let path = tmp("roundtrip.jsonl");
        let rec = record(&cfg, &path).unwrap();
        assert!(rec.ok(), "violations: {:?}", rec.violations);
        let out = replay_path(&path).unwrap();
        assert!(
            out.events_match,
            "divergence: {:?}",
            out.divergence
        );
        assert!(out.metrics_match, "metrics snapshot diverged");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn replay_flags_a_tampered_trace() {
        let cfg = small(true, 13);
        let rec = run(&cfg).unwrap();
        let mut trace = Trace::parse(&rec.trace).unwrap();
        assert!(!trace.event_lines.is_empty());
        trace.event_lines.pop();
        let out = replay(&trace).unwrap();
        assert!(!out.events_match, "a truncated trace must not verify");
        assert!(out.divergence.is_some());
    }

    #[test]
    fn replay_refuses_truncated_trace() {
        let cfg = small(true, 13);
        let rec = run(&cfg).unwrap();
        let tampered =
            rec.trace.replace("\"truncated\":false", "\"truncated\":true");
        assert_ne!(tampered, rec.trace, "header must carry the flag");
        let trace = Trace::parse(&tampered).unwrap();
        let err = replay(&trace).unwrap_err();
        assert!(
            err.to_string().contains("truncated"),
            "error must name the truncation: {err}"
        );
    }

    /// Property: over storm and clean runs across seeds, the journal
    /// event stream always yields well-formed spans — monotone stage
    /// timestamps, at most one terminal per request (enforced inside
    /// `spans_from_events`, which errors otherwise), exactly one
    /// terminal for every accepted request, and a failover storm
    /// produces at least one span with a second `place` segment.
    #[test]
    fn journal_streams_yield_well_formed_spans() {
        use crate::serving::telemetry::spans_from_events;
        for (storm, seed) in
            [(false, 7), (true, 3), (true, 11), (true, 29), (true, 57)]
        {
            let cfg = small(storm, seed);
            let report = run(&cfg).unwrap();
            assert!(report.ok(), "violations: {:?}", report.violations);
            let trace = Trace::parse(&report.trace).unwrap();
            assert!(
                !trace.header.get("truncated").unwrap().as_bool().unwrap(),
                "seed {seed}: property needs the full stream"
            );
            let lines: Vec<String> = report
                .events
                .lines()
                .map(str::to_string)
                .collect();
            let spans = spans_from_events(&lines)
                .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            let complete =
                spans.iter().filter(|s| s.terminal.is_some()).count();
            assert_eq!(
                complete, report.accepted,
                "seed {seed}: every accepted request must reach \
                 exactly one terminal"
            );
            let refused: Vec<u64> = spans
                .iter()
                .filter(|s| s.terminal.is_none())
                .map(|s| s.id)
                .collect();
            assert!(
                refused.is_empty(),
                "seed {seed}: spans without terminals: {refused:?}"
            );
            if report.failovers > 0 {
                assert!(
                    spans.iter().any(|s| s.segments.len() > 1),
                    "seed {seed}: {} failovers but no span shows a \
                     re-placement segment",
                    report.failovers
                );
            }
        }
    }

    #[test]
    fn from_json_roundtrips_cfg() {
        let cfg = ChaosCfg { seed: 42, ..ChaosCfg::default() };
        let back = ChaosCfg::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.seed, 42);
        assert_eq!(back.engines, cfg.engines);
        assert_eq!(back.pumps, cfg.pumps);
        assert_eq!(back.storm, cfg.storm);
        // pre-adaptive-k traces carry no "degrade" key: fixed k
        assert_eq!(back.degrade, None);
        let d = DegradeCfg { min_k: 1, hi_wm: 4, lo_wm: 1 };
        let with = ChaosCfg { degrade: Some(d), ..ChaosCfg::default() };
        let back = ChaosCfg::from_json(&with.to_json()).unwrap();
        assert_eq!(back.degrade, Some(d));
        // pre-speculation traces carry no "speculate" key: plain decode
        assert_eq!(back.speculate, 0);
        assert!(!with.to_json().to_string_compact().contains("speculate"));
        let spec = ChaosCfg { speculate: 3, ..ChaosCfg::default() };
        let back = ChaosCfg::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.speculate, 3);
        // pre-prefix-cache traces carry no key: cold prefill on replay
        assert_eq!(back.prefix_cache, None);
        assert!(
            !spec.to_json().to_string_compact().contains("prefix_cache")
        );
        let cached = ChaosCfg {
            prefix_cache: Some(1 << 20),
            ..ChaosCfg::default()
        };
        let back = ChaosCfg::from_json(&cached.to_json()).unwrap();
        assert_eq!(back.prefix_cache, Some(1 << 20));
    }

    /// Property: a fault storm over a *cache-armed* fleet still holds
    /// every serving invariant — never-double-send pins each completed
    /// stream to the exact greedy continuation, so a lane seeded from
    /// a stale or wrong snapshot would surface here — the metrics
    /// snapshot carries the cache section, and a recorded cache-armed
    /// trace replays byte-for-byte.
    #[test]
    fn prefix_cache_storms_hold_invariants_and_replay() {
        for seed in [3, 11] {
            let cfg = ChaosCfg {
                prefix_cache: Some(1 << 20),
                ..small(true, seed)
            };
            let a = run(&cfg).unwrap();
            assert!(a.ok(), "seed {seed}: violations: {:?}", a.violations);
            assert_eq!(a.dones + a.drops + a.rejected, cfg.requests);
            let doc = a.metrics.to_string_compact();
            assert!(
                doc.contains("prefix_cache"),
                "seed {seed}: no cache section in metrics: {doc}"
            );
            let b = run(&cfg).unwrap();
            assert_eq!(
                a.events, b.events,
                "seed {seed}: decision streams diverged"
            );
            assert_eq!(
                a.metrics.to_string_compact(),
                b.metrics.to_string_compact(),
                "seed {seed}: metrics snapshots diverged"
            );
            let path = tmp(&format!("prefix-cache-{seed}.jsonl"));
            let rec = record(&cfg, &path).unwrap();
            assert!(rec.ok(), "violations: {:?}", rec.violations);
            let out = replay_path(&path).unwrap();
            assert!(
                out.events_match,
                "seed {seed}: divergence: {:?}",
                out.divergence
            );
            assert!(out.metrics_match, "seed {seed}: metrics diverged");
            std::fs::remove_file(&path).ok();
        }
    }

    /// Property: a fault storm over a *speculating* fleet still holds
    /// every serving invariant — in particular never-double-send, which
    /// pins each completed stream to the exact greedy continuation, so
    /// a wrong draft accepted past verification would be caught here —
    /// and a recorded speculative trace replays byte-for-byte.
    #[test]
    fn speculative_storms_hold_invariants_and_replay() {
        for seed in [3, 11] {
            let cfg = ChaosCfg { speculate: 3, ..small(true, seed) };
            let a = run(&cfg).unwrap();
            assert!(a.ok(), "seed {seed}: violations: {:?}", a.violations);
            assert_eq!(a.dones + a.drops + a.rejected, cfg.requests);
            // the snapshot must show the engines actually speculated
            let doc = a.metrics.to_string_compact();
            assert!(
                doc.contains("spec_rounds"),
                "seed {seed}: no speculative counters in metrics: {doc}"
            );
            let b = run(&cfg).unwrap();
            assert_eq!(
                a.events, b.events,
                "seed {seed}: decision streams diverged"
            );
            let path = tmp(&format!("speculate-{seed}.jsonl"));
            let rec = record(&cfg, &path).unwrap();
            assert!(rec.ok(), "violations: {:?}", rec.violations);
            let out = replay_path(&path).unwrap();
            assert!(
                out.events_match,
                "seed {seed}: divergence: {:?}",
                out.divergence
            );
            assert!(out.metrics_match, "seed {seed}: metrics diverged");
            std::fs::remove_file(&path).ok();
        }
    }

    /// Property: under a fault storm with adaptive expert-k enabled,
    /// the serving invariants still hold (exactly-once terminals,
    /// well-formed spans), the journal carries the k-transition
    /// events, the scheduler gauges surface the hysteresis, and a
    /// recorded trace replays the transitions byte-for-byte.
    #[test]
    fn degrade_k_storms_replay_transitions_byte_for_byte() {
        use crate::serving::telemetry::spans_from_events;
        let degrade = DegradeCfg { min_k: 1, hi_wm: 1, lo_wm: 0 };
        for seed in [3, 11, 29] {
            let cfg = ChaosCfg {
                degrade: Some(degrade),
                ..small(true, seed)
            };
            let a = run(&cfg).unwrap();
            assert!(a.ok(), "seed {seed}: violations: {:?}", a.violations);
            assert_eq!(a.dones + a.drops + a.rejected, cfg.requests);
            assert!(
                a.events.contains("k_degrade"),
                "seed {seed}: the storm never tripped the watermark"
            );
            // the id-less k-transition events must not disturb span
            // assembly: every accepted request still reaches exactly
            // one terminal
            let lines: Vec<String> =
                a.events.lines().map(str::to_string).collect();
            let spans = spans_from_events(&lines)
                .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            let complete =
                spans.iter().filter(|s| s.terminal.is_some()).count();
            assert_eq!(complete, a.accepted, "seed {seed}");
            let sched = a.metrics.get("scheduler").unwrap();
            let g = |k: &str| sched.get(k).unwrap().as_f64().unwrap();
            assert!(g("expert_k_degrades") >= 1.0, "seed {seed}");
            assert_eq!(g("expert_k_max"), MOCK_TOP_K as f64);
            let b = run(&cfg).unwrap();
            assert_eq!(
                a.events, b.events,
                "seed {seed}: decision streams diverged"
            );
            let path = tmp(&format!("degrade-{seed}.jsonl"));
            let rec = record(&cfg, &path).unwrap();
            assert!(rec.ok(), "violations: {:?}", rec.violations);
            let out = replay_path(&path).unwrap();
            assert!(
                out.events_match,
                "seed {seed}: divergence: {:?}",
                out.divergence
            );
            assert!(out.metrics_match, "seed {seed}: metrics diverged");
            std::fs::remove_file(&path).ok();
        }
    }
}
