//! Request-lifecycle spans + σ-MoE expert-utilization telemetry +
//! Prometheus text exposition.
//!
//! Three always-on observability surfaces over the serving stack, all
//! fed from sites the stack already passes through (no new event
//! variants, no extra channel hops):
//!
//! * **Spans** — every request walks `queued → placed → prefill →
//!   first_token → … → terminal`.  Stage transitions are recorded by
//!   the scheduler (enqueue, drop sites), the router (dispatch, relay,
//!   failover) and the single-engine driver; per-stage latency
//!   [`Histogram`]s (queue-wait, placement, TTFT, inter-token gap) are
//!   *always* observed, while full span retention for `GET
//!   /v1/trace/<id>` is deterministically sampled into a bounded ring.
//!   A failed-over request gets a second `placed` segment on its new
//!   engine — never a second terminal.
//! * **Expert utilization** — MoE artifacts append a per-layer
//!   expert-selection count output to `step_fwd`/`prefill` (a pure
//!   reduction of the router's top-K one-hot; logits are bit-for-bit
//!   untouched).  Engines accumulate those counts here per engine per
//!   layer; `/metrics` derives load-imbalance (max/mean), routing
//!   entropy, and dead-expert counts — the signals the paper's
//!   §6 balance analysis is built on.  Artifacts without the output
//!   bump `expert_stats_unavailable` instead of failing.
//! * **Prometheus exposition** — [`render_prom`] renders the whole
//!   `/metrics` JSON document as `text/plain; version=0.0.4`.  JSON and
//!   prom are two views of one registry: same numbers, stable
//!   `sigma_moe_*` names, no duplicates (namespaces are split per
//!   section and samples dedup through a `BTreeMap`).
//!
//! Everything here is deterministic under a [`SimClock`]: timestamps
//! are `Clock::now_ms` (logical under simulation), maps are `BTreeMap`
//! ordered, and span sampling hashes the request id rather than
//! consulting an RNG — so the chaos harness can byte-diff telemetry
//! the way it byte-diffs the journal.
//!
//! [`SimClock`]: super::clock::SimClock

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::json::{self, Json};
use crate::serving::clock::{Clock, SharedClock};
use crate::serving::scheduler::Histogram;

/// Content-Type for the Prometheus text exposition format.
pub const PROM_CONTENT_TYPE: &str = "text/plain; version=0.0.4";

/// Default span-ring capacity (terminal spans retained for
/// `GET /v1/trace/<id>`).
pub const DEFAULT_RING_CAP: usize = 4096;

/// Hard bound on concurrently *active* spans — far above any sane
/// queue+lane population; a leak evicts the oldest instead of growing.
const MAX_ACTIVE: usize = 1 << 16;

/// Fibonacci-hash multiplier for deterministic span sampling.
const SAMPLE_HASH: u64 = 0x9E37_79B9_7F4A_7C15;

// ---------------------------------------------------------------- spans

/// One placement of a request onto an engine.  Failover opens a new
/// segment; a span's segment list is its placement history.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanSegment {
    /// Engine index; `None` in single-engine mode (no fleet placement).
    pub engine: Option<usize>,
    pub placed_ms: u64,
    /// First engine-side activity (lane admission / prefill start).
    pub prefill_ms: Option<u64>,
}

/// Terminal outcome of a span: the journal kind that ended it
/// (`done`, `dropped`, `drop_deadline`, `drop_deadline_post`,
/// `drop_dead`, `drop_shutdown`, `retry_exhausted`).
#[derive(Debug, Clone, PartialEq)]
pub struct SpanTerminal {
    pub outcome: String,
    pub t_ms: u64,
}

/// The lifecycle of one request, from admission to its single terminal.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub id: u64,
    pub queued_ms: u64,
    pub segments: Vec<SpanSegment>,
    pub first_token_ms: Option<u64>,
    pub last_token_ms: Option<u64>,
    pub tokens: u64,
    pub terminal: Option<SpanTerminal>,
}

impl Span {
    fn new(id: u64, queued_ms: u64) -> Self {
        Span {
            id,
            queued_ms,
            segments: Vec::new(),
            first_token_ms: None,
            last_token_ms: None,
            tokens: 0,
            terminal: None,
        }
    }

    /// The flat, time-ordered stage list (the "span tree" `/v1/trace`
    /// serves): queued, then per segment placed/prefill, then
    /// first_token and the terminal.
    pub fn to_json(&self) -> Json {
        let mut stages = vec![json::obj(vec![
            ("stage", json::s("queued")),
            ("t_ms", json::num(self.queued_ms as f64)),
        ])];
        for seg in &self.segments {
            let mut f = vec![
                ("stage", json::s("placed")),
                ("t_ms", json::num(seg.placed_ms as f64)),
            ];
            if let Some(e) = seg.engine {
                f.push(("engine", json::num(e as f64)));
            }
            stages.push(json::obj(f));
            if let Some(p) = seg.prefill_ms {
                let mut f = vec![
                    ("stage", json::s("prefill")),
                    ("t_ms", json::num(p as f64)),
                ];
                if let Some(e) = seg.engine {
                    f.push(("engine", json::num(e as f64)));
                }
                stages.push(json::obj(f));
            }
        }
        if let Some(t) = self.first_token_ms {
            stages.push(json::obj(vec![
                ("stage", json::s("first_token")),
                ("t_ms", json::num(t as f64)),
            ]));
        }
        if let Some(term) = &self.terminal {
            stages.push(json::obj(vec![
                ("stage", json::s("terminal")),
                ("outcome", json::s(&term.outcome)),
                ("t_ms", json::num(term.t_ms as f64)),
            ]));
        }
        let mut fields = vec![
            ("id", json::num(self.id as f64)),
            ("queued_ms", json::num(self.queued_ms as f64)),
            ("tokens", json::num(self.tokens as f64)),
            ("placements", json::num(self.segments.len() as f64)),
            ("complete", Json::Bool(self.terminal.is_some())),
            ("stages", json::arr(stages)),
        ];
        if let Some(t) = self.first_token_ms {
            fields.push((
                "ttft_ms",
                json::num(t.saturating_sub(self.queued_ms) as f64),
            ));
        }
        if let Some(term) = &self.terminal {
            fields.push((
                "e2e_ms",
                json::num(term.t_ms.saturating_sub(self.queued_ms) as f64),
            ));
            fields.push(("outcome", json::s(&term.outcome)));
        }
        json::obj(fields)
    }
}

/// Journal kinds that terminate a span.  Exactly one of these per
/// request; failover re-placement must never synthesize a second one.
pub const TERMINAL_KINDS: [&str; 7] = [
    "done",
    "dropped",
    "drop_deadline",
    "drop_deadline_post",
    "drop_dead",
    "drop_shutdown",
    "retry_exhausted",
];

fn is_terminal_kind(kind: &str) -> bool {
    TERMINAL_KINDS.contains(&kind)
}

/// Derive well-formed spans from a journal event stream (the NDJSON
/// lines of a trace).  Enforces the span invariants — monotone stage
/// timestamps within a span, at most one terminal per request, no
/// lifecycle events after the terminal — and errors on any violation,
/// so replay tooling can refuse a corrupt trace instead of rendering
/// nonsense.  Events without a request `id` (heartbeats, pumps,
/// quarantines, failovers) are skipped; `place` after a `retry` opens
/// a new segment (the failover re-placement).
pub fn spans_from_events(lines: &[String]) -> Result<Vec<Span>> {
    let mut spans: BTreeMap<u64, Span> = BTreeMap::new();
    let mut last_ms: BTreeMap<u64, u64> = BTreeMap::new();
    for (i, line) in lines.iter().enumerate() {
        let ev = Json::parse(line).map_err(|e| {
            Error::Serving(format!("bad journal event on line {}: {e}", i + 1))
        })?;
        let kind = match ev.opt("kind").and_then(|k| k.as_str().ok()) {
            Some(k) => k.to_string(),
            None => continue,
        };
        let id = match ev.opt("id").and_then(|v| v.as_f64().ok()) {
            Some(n) if n >= 0.0 => n as u64,
            _ => continue, // engine-scoped event (beat/pump/quarantine/…)
        };
        let t_ms = ev
            .opt("t_ms")
            .and_then(|v| v.as_f64().ok())
            .unwrap_or(0.0) as u64;
        // a ring-evicted prefix means a span can first appear mid-life
        let span = spans
            .entry(id)
            .or_insert_with(|| Span::new(id, t_ms));
        if let Some(term) = &span.terminal {
            return Err(Error::Serving(format!(
                "request {id}: event {kind:?} at t={t_ms}ms after \
                 terminal {:?} at t={}ms",
                term.outcome, term.t_ms
            )));
        }
        let prev = last_ms.get(&id).copied().unwrap_or(span.queued_ms);
        if t_ms < prev {
            return Err(Error::Serving(format!(
                "request {id}: event {kind:?} at t={t_ms}ms is earlier \
                 than the previous stage at t={prev}ms"
            )));
        }
        last_ms.insert(id, t_ms);
        match kind.as_str() {
            "admit" => span.queued_ms = t_ms,
            "take" => {
                // single-engine placement (a fleet journal follows the
                // take with a "place" carrying the engine id)
                span.segments.push(SpanSegment {
                    engine: None,
                    placed_ms: t_ms,
                    prefill_ms: None,
                });
            }
            "place" => {
                let engine = ev
                    .opt("engine")
                    .and_then(|v| v.as_f64().ok())
                    .map(|n| n as usize);
                match span.segments.last_mut() {
                    // fill in the engine on the segment the preceding
                    // "take" opened (same placement, two records)
                    Some(seg)
                        if seg.engine.is_none()
                            && seg.prefill_ms.is_none() =>
                    {
                        seg.engine = engine;
                        seg.placed_ms = t_ms;
                    }
                    _ => span.segments.push(SpanSegment {
                        engine,
                        placed_ms: t_ms,
                        prefill_ms: None,
                    }),
                }
            }
            "retry" => {} // requeued; the next "place" opens a segment
            k if is_terminal_kind(k) => {
                if k == "done" {
                    if let Some(n) =
                        ev.opt("tokens").and_then(|v| v.as_f64().ok())
                    {
                        span.tokens = n as u64;
                    }
                }
                span.terminal = Some(SpanTerminal {
                    outcome: kind.clone(),
                    t_ms,
                });
            }
            _ => {}
        }
    }
    Ok(spans.into_values().collect())
}

// ------------------------------------------------------------ telemetry

struct TelInner {
    active: BTreeMap<u64, Span>,
    /// Terminal spans retained for `/v1/trace/<id>` (sampled ring).
    done: VecDeque<Span>,
    /// queued → placed (first placement only; failover re-placements
    /// are router internals, not client-visible queue wait)
    queue_wait: Histogram,
    /// placed → prefill start (engine admission latency)
    placement: Histogram,
    /// queued → first token (the client-visible TTFT)
    ttft: Histogram,
    /// token → next token gap (steady-state decode cadence)
    inter_token: Histogram,
    /// spans evicted from the ring (so a missing trace id is
    /// distinguishable from one that was never recorded)
    spans_evicted: u64,
}

/// Always-on request-lifecycle + expert-utilization recorder, shared by
/// the scheduler, the router/driver threads, and the HTTP frontend.
///
/// All recording methods are cheap (one short mutex hold) and total
/// no-ops on a [`Telemetry::disabled`] instance, mirroring the
/// [`Journal`](super::journal::Journal) discipline.
pub struct Telemetry {
    enabled: bool,
    clock: SharedClock,
    ring_cap: usize,
    /// Per-mille of request ids whose full span is retained in the
    /// ring (histograms observe every request regardless).  1000 keeps
    /// everything — the default, so `X-Request-Id` always resolves.
    sample_permille: u64,
    inner: Mutex<TelInner>,
    /// engine id → per-layer per-expert token counts.
    experts: Mutex<BTreeMap<usize, Vec<Vec<u64>>>>,
    /// Pumps on artifacts without the expert-counts output (dense /
    /// topk / pkm presets, or pre-telemetry artifacts).
    unavailable: AtomicU64,
}

impl Telemetry {
    pub fn new(clock: SharedClock) -> Self {
        Telemetry {
            enabled: true,
            clock,
            ring_cap: DEFAULT_RING_CAP,
            sample_permille: 1000,
            inner: Mutex::new(TelInner {
                active: BTreeMap::new(),
                done: VecDeque::new(),
                queue_wait: Histogram::new(),
                placement: Histogram::new(),
                ttft: Histogram::new(),
                inter_token: Histogram::new(),
                spans_evicted: 0,
            }),
            experts: Mutex::new(BTreeMap::new()),
            unavailable: AtomicU64::new(0),
        }
    }

    /// A no-op recorder: every method returns before touching a lock.
    pub fn disabled(clock: SharedClock) -> Self {
        let mut t = Telemetry::new(clock);
        t.enabled = false;
        t
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Span-ring capacity (terminal spans kept for `/v1/trace/<id>`).
    pub fn with_ring_cap(mut self, cap: usize) -> Self {
        self.ring_cap = cap.max(1);
        self
    }

    /// Per-mille of request ids retained in the span ring (histograms
    /// are unaffected).  Clamped to [0, 1000].
    pub fn with_sample_permille(mut self, pm: u64) -> Self {
        self.sample_permille = pm.min(1000);
        self
    }

    pub fn shared(self) -> Arc<Telemetry> {
        Arc::new(self)
    }

    /// Deterministic id-hash sampling: no RNG, so simulated runs that
    /// assign the same ids retain the same spans.
    fn sampled(&self, id: u64) -> bool {
        id.wrapping_mul(SAMPLE_HASH) % 1000 < self.sample_permille
    }

    // -- span recording ------------------------------------------------

    /// Request admitted by the scheduler.
    pub fn queued(&self, id: u64) {
        if !self.enabled {
            return;
        }
        let now = self.clock.now_ms();
        let mut inner = self.inner.lock().unwrap();
        if inner.active.len() >= MAX_ACTIVE {
            let oldest = *inner.active.keys().next().unwrap();
            inner.active.remove(&oldest);
            inner.spans_evicted += 1;
        }
        inner.active.insert(id, Span::new(id, now));
    }

    /// Request handed to an engine (fleet `dispatch`, or the
    /// single-engine driver's `take_next → submit`).  Failover
    /// re-placement calls this again and opens a second segment.
    pub fn placed(&self, id: u64, engine: Option<usize>) {
        if !self.enabled {
            return;
        }
        let now = self.clock.now_ms();
        let mut inner = self.inner.lock().unwrap();
        let Some(span) = inner.active.get_mut(&id) else {
            return;
        };
        let first = span.segments.is_empty();
        let queued_ms = span.queued_ms;
        span.segments.push(SpanSegment {
            engine,
            placed_ms: now,
            prefill_ms: None,
        });
        if first {
            let wait = now.saturating_sub(queued_ms) as f64 / 1e3;
            inner.queue_wait.observe_secs(wait);
        }
    }

    /// Engine-side admission observed (the relay's `Admitted`, or the
    /// lane actually starting prefill).
    pub fn prefill_started(&self, id: u64) {
        if !self.enabled {
            return;
        }
        let now = self.clock.now_ms();
        let mut inner = self.inner.lock().unwrap();
        let Some(span) = inner.active.get_mut(&id) else {
            return;
        };
        let Some(seg) = span.segments.last_mut() else {
            return;
        };
        if seg.prefill_ms.is_some() {
            return;
        }
        seg.prefill_ms = Some(now);
        let placed = seg.placed_ms;
        let lat = now.saturating_sub(placed) as f64 / 1e3;
        inner.placement.observe_secs(lat);
    }

    /// One generated token relayed to the client.
    pub fn token(&self, id: u64) {
        if !self.enabled {
            return;
        }
        let now = self.clock.now_ms();
        let mut inner = self.inner.lock().unwrap();
        let Some(span) = inner.active.get_mut(&id) else {
            return;
        };
        span.tokens += 1;
        match span.last_token_ms {
            None => {
                span.first_token_ms = Some(now);
                let ttft =
                    now.saturating_sub(span.queued_ms) as f64 / 1e3;
                span.last_token_ms = Some(now);
                inner.ttft.observe_secs(ttft);
            }
            Some(prev) => {
                span.last_token_ms = Some(now);
                let gap = now.saturating_sub(prev) as f64 / 1e3;
                inner.inter_token.observe_secs(gap);
            }
        }
    }

    /// The request's single terminal (`done`, `dropped`,
    /// `drop_deadline`, …).  Retires the span into the sampled ring.
    pub fn terminal(&self, id: u64, outcome: &str) {
        if !self.enabled {
            return;
        }
        let now = self.clock.now_ms();
        let mut inner = self.inner.lock().unwrap();
        let Some(mut span) = inner.active.remove(&id) else {
            return;
        };
        span.terminal = Some(SpanTerminal {
            outcome: outcome.to_string(),
            t_ms: now,
        });
        if !self.sampled(id) {
            return;
        }
        if inner.done.len() >= self.ring_cap {
            inner.done.pop_front();
            inner.spans_evicted += 1;
        }
        inner.done.push_back(span);
    }

    /// The span for `/v1/trace/<id>`: in-flight spans first, then the
    /// retained ring (newest match wins).
    pub fn trace_json(&self, id: u64) -> Option<Json> {
        if !self.enabled {
            return None;
        }
        let inner = self.inner.lock().unwrap();
        if let Some(span) = inner.active.get(&id) {
            return Some(span.to_json());
        }
        inner
            .done
            .iter()
            .rev()
            .find(|s| s.id == id)
            .map(Span::to_json)
    }

    // -- expert utilization --------------------------------------------

    /// Accumulate one pump's per-layer expert-selection counts
    /// (`counts[layer][expert]` tokens routed) for `engine`.
    pub fn record_expert_counts(&self, engine: usize, counts: &[Vec<u64>]) {
        if !self.enabled || counts.is_empty() {
            return;
        }
        let mut map = self.experts.lock().unwrap();
        let acc = map.entry(engine).or_default();
        if acc.len() < counts.len() {
            acc.resize(counts.len(), Vec::new());
        }
        for (layer, row) in counts.iter().enumerate() {
            let dst = &mut acc[layer];
            if dst.len() < row.len() {
                dst.resize(row.len(), 0);
            }
            for (e, &c) in row.iter().enumerate() {
                dst[e] += c;
            }
        }
    }

    /// A pump produced no expert counts (non-MoE or pre-telemetry
    /// artifact): the Rust-side fallback counter.
    pub fn note_expert_stats_unavailable(&self) {
        if self.enabled {
            self.unavailable.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn expert_stats_unavailable(&self) -> u64 {
        self.unavailable.load(Ordering::Relaxed)
    }

    // -- metrics documents ---------------------------------------------

    /// The `stages` section of `/metrics`: always-on per-stage latency
    /// histograms plus span-ring occupancy.
    pub fn stages_json(&self) -> Json {
        let inner = self.inner.lock().unwrap();
        json::obj(vec![
            ("queue_wait", inner.queue_wait.to_json()),
            ("placement", inner.placement.to_json()),
            ("ttft", inner.ttft.to_json()),
            ("inter_token", inner.inter_token.to_json()),
            ("active_spans", json::num(inner.active.len() as f64)),
            ("retained_spans", json::num(inner.done.len() as f64)),
            ("spans_evicted", json::num(inner.spans_evicted as f64)),
            (
                "span_sample_permille",
                json::num(self.sample_permille as f64),
            ),
        ])
    }

    /// The `experts` section of `/metrics`: raw per-engine per-layer
    /// counts plus the derived balance signals (load-imbalance
    /// max/mean, routing entropy in nats, dead-expert count), and a
    /// fleet-level aggregate across engines.
    pub fn experts_json(&self) -> Json {
        let map = self.experts.lock().unwrap();
        let mut engines: Vec<(String, Json)> = Vec::new();
        let mut fleet: Vec<Vec<u64>> = Vec::new();
        for (engine, layers) in map.iter() {
            if fleet.len() < layers.len() {
                fleet.resize(layers.len(), Vec::new());
            }
            for (l, row) in layers.iter().enumerate() {
                if fleet[l].len() < row.len() {
                    fleet[l].resize(row.len(), 0);
                }
                for (e, &c) in row.iter().enumerate() {
                    fleet[l][e] += c;
                }
            }
            engines.push((engine.to_string(), layers_json(layers)));
        }
        json::obj(vec![
            (
                "unavailable",
                json::num(self.expert_stats_unavailable() as f64),
            ),
            (
                "engines",
                Json::Obj(engines.into_iter().collect()),
            ),
            ("fleet", layers_json(&fleet)),
        ])
    }
}

/// Render one engine's (or the fleet aggregate's) per-layer expert
/// counts with the derived balance metrics.
fn layers_json(layers: &[Vec<u64>]) -> Json {
    let rows: Vec<Json> = layers
        .iter()
        .enumerate()
        .map(|(l, row)| {
            let d = ExpertBalance::of(row);
            json::obj(vec![
                ("layer", json::num(l as f64)),
                (
                    "counts",
                    json::arr(
                        row.iter().map(|&c| json::num(c as f64)).collect(),
                    ),
                ),
                ("tokens_k", json::num(d.total as f64)),
                ("imbalance", json::num(d.imbalance)),
                ("entropy", json::num(d.entropy)),
                ("dead_experts", json::num(d.dead as f64)),
            ])
        })
        .collect();
    json::obj(vec![("layers", json::arr(rows))])
}

/// Derived balance signals for one layer's expert-count row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExpertBalance {
    /// Total expert selections (tokens × K summed into the row).
    pub total: u64,
    /// max(count) / mean(count); 1.0 is perfectly balanced, `N_E` is
    /// full collapse onto one expert.  0 when no tokens routed yet.
    pub imbalance: f64,
    /// Shannon entropy of the selection distribution in nats;
    /// `ln(N_E)` is uniform, 0 is collapse.
    pub entropy: f64,
    /// Experts with zero selections.
    pub dead: usize,
}

impl ExpertBalance {
    pub fn of(counts: &[u64]) -> ExpertBalance {
        let total: u64 = counts.iter().sum();
        let dead = counts.iter().filter(|&&c| c == 0).count();
        if total == 0 || counts.is_empty() {
            return ExpertBalance {
                total,
                imbalance: 0.0,
                entropy: 0.0,
                dead,
            };
        }
        let mean = total as f64 / counts.len() as f64;
        let max = counts.iter().copied().max().unwrap_or(0) as f64;
        let entropy = -counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / total as f64;
                p * p.ln()
            })
            .sum::<f64>();
        ExpertBalance {
            total,
            imbalance: max / mean,
            entropy,
            dead,
        }
    }
}

// ------------------------------------------------- prometheus rendering

/// One metric family in the exposition: a TYPE plus samples keyed by
/// their label string (the `BTreeMap` dedups and orders them).
struct Family {
    mtype: &'static str,
    samples: BTreeMap<String, f64>,
}

#[derive(Default)]
struct Registry {
    families: BTreeMap<String, Family>,
}

impl Registry {
    fn put(&mut self, name: &str, labels: &str, mtype: &'static str, v: f64) {
        let fam = self
            .families
            .entry(sanitize(name))
            .or_insert_with(|| Family {
                mtype,
                samples: BTreeMap::new(),
            });
        fam.samples.insert(labels.to_string(), v);
    }

    fn render(&self) -> String {
        let mut out = String::new();
        for (name, fam) in &self.families {
            out.push_str(&format!("# TYPE {name} {}\n", fam.mtype));
            for (labels, v) in &fam.samples {
                // `_sum` / `_count` series of a summary carry their
                // suffix inside the label key (see `put_histogram`)
                let (suffix, labels) = match labels.strip_prefix('!') {
                    Some(rest) => {
                        let (sfx, l) =
                            rest.split_once('|').unwrap_or((rest, ""));
                        (format!("_{sfx}"), l.to_string())
                    }
                    None => (String::new(), labels.clone()),
                };
                out.push_str(&format!("{name}{suffix}{labels} "));
                out.push_str(&fmt_value(*v));
                out.push('\n');
            }
        }
        out
    }
}

fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 2f64.powi(53) {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' }
        })
        .collect()
}

fn label_set(pairs: &[(&str, String)]) -> String {
    if pairs.is_empty() {
        return String::new();
    }
    let body: Vec<String> = pairs
        .iter()
        .map(|(k, v)| format!("{}=\"{}\"", sanitize(k), v))
        .collect();
    format!("{{{}}}", body.join(","))
}

/// Is this JSON object one of our [`Histogram::to_json`] summaries?
fn is_histogram_obj(v: &Json) -> bool {
    v.opt("count").is_some()
        && v.opt("p50_ms").is_some()
        && v.opt("max_ms").is_some()
}

/// Emit a [`Histogram::to_json`] object as a prom summary (quantile
/// values converted ms → seconds, per prom convention).
fn put_histogram(reg: &mut Registry, name: &str, labels: &[(&str, String)], h: &Json) {
    let getf = |k: &str| h.opt(k).and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
    let count = getf("count");
    for (q, key) in [
        ("0.5", "p50_ms"),
        ("0.95", "p95_ms"),
        ("0.99", "p99_ms"),
        ("0.999", "p999_ms"),
        ("1", "max_ms"),
    ] {
        if h.opt(key).is_none() {
            continue;
        }
        let mut l = labels.to_vec();
        l.push(("quantile", q.to_string()));
        reg.put(name, &label_set(&l), "summary", getf(key) / 1e3);
    }
    let base = label_set(labels);
    // '!' prefix smuggles the _sum/_count suffix past the label key
    reg.put(
        name,
        &format!("!sum|{base}"),
        "summary",
        getf("mean_ms") / 1e3 * count,
    );
    reg.put(name, &format!("!count|{base}"), "summary", count);
}

/// Flatten one level of scalar fields from a JSON object into
/// `<prefix>_<key>` gauges; histogram-shaped sub-objects become
/// summaries; strings become `<prefix>_info{<key>="v"} 1`.
fn put_section(
    reg: &mut Registry,
    prefix: &str,
    labels: &[(&str, String)],
    obj: &Json,
) {
    let Ok(map) = obj.as_obj() else { return };
    for (k, v) in map {
        let name = format!("{prefix}_{k}");
        match v {
            Json::Num(n) => reg.put(&name, &label_set(labels), "gauge", *n),
            Json::Bool(b) => reg.put(
                &name,
                &label_set(labels),
                "gauge",
                if *b { 1.0 } else { 0.0 },
            ),
            Json::Str(s) => {
                let mut l = labels.to_vec();
                l.push((k.as_str(), s.clone()));
                reg.put(
                    &format!("{prefix}_info"),
                    &label_set(&l),
                    "gauge",
                    1.0,
                );
            }
            Json::Obj(_) if is_histogram_obj(v) => {
                put_histogram(reg, &name, labels, v);
            }
            _ => {}
        }
    }
}

/// Emit one `layers` expert document (from [`layers_json`]) under
/// `prefix` with `labels`.
fn put_expert_layers(
    reg: &mut Registry,
    prefix: &str,
    labels: &[(&str, String)],
    doc: &Json,
) {
    let Some(layers) = doc.opt("layers").and_then(|l| l.as_arr().ok())
    else {
        return;
    };
    for row in layers {
        let layer = row
            .opt("layer")
            .and_then(|v| v.as_f64().ok())
            .unwrap_or(0.0);
        let mut l = labels.to_vec();
        l.push(("layer", fmt_value(layer)));
        if let Some(counts) = row.opt("counts").and_then(|c| c.as_arr().ok())
        {
            for (e, c) in counts.iter().enumerate() {
                let mut le = l.clone();
                le.push(("expert", e.to_string()));
                reg.put(
                    &format!("{prefix}_tokens_total"),
                    &label_set(&le),
                    "counter",
                    c.as_f64().unwrap_or(0.0),
                );
            }
        }
        for key in ["imbalance", "entropy", "dead_experts", "tokens_k"] {
            if let Some(v) = row.opt(key).and_then(|v| v.as_f64().ok()) {
                reg.put(
                    &format!("{prefix}_{key}"),
                    &label_set(&l),
                    "gauge",
                    v,
                );
            }
        }
    }
}

/// Render a `/metrics` JSON document (single-engine or fleet) in the
/// Prometheus text exposition format.  Stable names under the
/// `sigma_moe_` prefix; per-section namespaces guarantee no duplicate
/// families, and the registry's `BTreeMap`s make the byte stream
/// deterministic for a given document.
pub fn render_prom(doc: &Json) -> String {
    let mut reg = Registry::default();
    if let Some(v) = doc.opt("engine") {
        put_section(&mut reg, "sigma_moe_fleet", &[], v);
    }
    if let Some(rows) = doc.opt("engines").and_then(|v| v.as_arr().ok()) {
        for row in rows {
            let id = row
                .opt("id")
                .and_then(|v| v.as_f64().ok())
                .unwrap_or(0.0);
            let labels = vec![("engine", fmt_value(id))];
            put_section(&mut reg, "sigma_moe_engine", &labels, row);
            if let Some(stats) = row.opt("stats") {
                put_section(&mut reg, "sigma_moe_engine", &labels, stats);
            }
        }
    }
    if let Some(v) = doc.opt("router") {
        put_section(&mut reg, "sigma_moe_router", &[], v);
    }
    if let Some(v) = doc.opt("scheduler") {
        put_section(&mut reg, "sigma_moe_scheduler", &[], v);
    }
    if let Some(v) = doc.opt("server") {
        put_section(&mut reg, "sigma_moe_server", &[], v);
    }
    if let Some(v) = doc.opt("journal") {
        put_section(&mut reg, "sigma_moe_journal", &[], v);
    }
    if let Some(v) = doc.opt("stages") {
        put_section(&mut reg, "sigma_moe_stage", &[], v);
    }
    if let Some(v) = doc.opt("prefix_cache") {
        // the shared cache's document section: scalar counters flatten
        // as usual, the per-prompt-length hit/miss buckets become
        // labeled families
        put_section(&mut reg, "sigma_moe_prefix_cache", &[], v);
        if let Some(buckets) = v.opt("buckets").and_then(|b| b.as_obj().ok())
        {
            for (bucket, row) in buckets {
                let labels = vec![("prompt_len", bucket.clone())];
                for key in ["hits", "misses"] {
                    if let Some(n) =
                        row.opt(key).and_then(|n| n.as_f64().ok())
                    {
                        reg.put(
                            &format!("sigma_moe_prefix_cache_bucket_{key}"),
                            &label_set(&labels),
                            "counter",
                            n,
                        );
                    }
                }
            }
        }
    }
    if let Some(v) = doc.opt("experts") {
        if let Some(u) = v.opt("unavailable").and_then(|u| u.as_f64().ok())
        {
            reg.put(
                "sigma_moe_experts_unavailable",
                "",
                "counter",
                u,
            );
        }
        if let Some(fleet) = v.opt("fleet") {
            put_expert_layers(&mut reg, "sigma_moe_experts", &[], fleet);
        }
        if let Some(engines) = v.opt("engines").and_then(|e| e.as_obj().ok())
        {
            for (engine, layers) in engines {
                put_expert_layers(
                    &mut reg,
                    "sigma_moe_engine_experts",
                    &[("engine", engine.clone())],
                    layers,
                );
            }
        }
    }
    reg.render()
}

/// Sanity-check a rendered exposition the way a scraper's parser would
/// (`promtool check metrics`, approximately): every `# TYPE` line is
/// well-formed and announced at most once, every sample line carries a
/// legal metric name belonging to the family announced immediately
/// above it (modulo summary `_sum`/`_count` suffixes) and a numeric
/// value.  `require` lists name prefixes at least one *non-empty*
/// family must match — the CI smoke passes the stage/expert prefixes so
/// a silently-empty telemetry section fails the build instead of
/// shipping an empty dashboard.
pub fn validate_prom(text: &str, require: &[&str]) -> Result<()> {
    fn legal_name(name: &str) -> bool {
        !name.is_empty()
            && !name.starts_with(|c: char| c.is_ascii_digit())
            && name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
    }
    let mut families: BTreeMap<String, usize> = BTreeMap::new();
    let mut current: Option<String> = None;
    for (i, line) in text.lines().enumerate() {
        let lineno = i + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let (Some(name), Some(mtype)) = (it.next(), it.next()) else {
                return Err(Error::Serving(format!(
                    "prom line {lineno}: malformed TYPE line {line:?}"
                )));
            };
            if !matches!(
                mtype,
                "counter" | "gauge" | "summary" | "histogram" | "untyped"
            ) {
                return Err(Error::Serving(format!(
                    "prom line {lineno}: unknown metric type {mtype:?}"
                )));
            }
            if !legal_name(name) {
                return Err(Error::Serving(format!(
                    "prom line {lineno}: illegal family name {name:?}"
                )));
            }
            if families.insert(name.to_string(), 0).is_some() {
                return Err(Error::Serving(format!(
                    "prom line {lineno}: duplicate TYPE for {name:?}"
                )));
            }
            current = Some(name.to_string());
            continue;
        }
        if line.starts_with('#') {
            continue; // HELP / comment
        }
        let name_end = line
            .find(|c: char| c == '{' || c == ' ')
            .ok_or_else(|| {
                Error::Serving(format!(
                    "prom line {lineno}: sample without a value: {line:?}"
                ))
            })?;
        let name = &line[..name_end];
        if !legal_name(name) {
            return Err(Error::Serving(format!(
                "prom line {lineno}: illegal metric name {name:?}"
            )));
        }
        let fam = current.as_deref().ok_or_else(|| {
            Error::Serving(format!(
                "prom line {lineno}: sample {name:?} before any TYPE line"
            ))
        })?;
        let in_family = name == fam
            || name
                .strip_prefix(fam)
                .is_some_and(|sfx| sfx == "_sum" || sfx == "_count");
        if !in_family {
            return Err(Error::Serving(format!(
                "prom line {lineno}: sample {name:?} outside the \
                 announced family {fam:?}"
            )));
        }
        let value = line.rsplit(' ').next().unwrap_or("");
        if value.parse::<f64>().is_err()
            && !matches!(value, "NaN" | "+Inf" | "-Inf")
        {
            return Err(Error::Serving(format!(
                "prom line {lineno}: non-numeric value {value:?}"
            )));
        }
        *families.get_mut(fam).unwrap() += 1;
    }
    for req in require {
        let hit = families
            .iter()
            .any(|(name, &n)| name.starts_with(req) && n > 0);
        if !hit {
            return Err(Error::Serving(format!(
                "prom exposition has no non-empty family matching \
                 {req:?} (got: {:?})",
                families.keys().collect::<Vec<_>>()
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::clock::SimClock;
    use std::time::Duration;

    fn sim() -> (Arc<SimClock>, Telemetry) {
        let clock = SimClock::shared();
        let tel = Telemetry::new(clock.clone());
        (clock, tel)
    }

    #[test]
    fn span_walks_all_stages_with_latency_histograms() {
        let (clock, tel) = sim();
        tel.queued(1);
        clock.advance(Duration::from_millis(5));
        tel.placed(1, Some(0));
        clock.advance(Duration::from_millis(2));
        tel.prefill_started(1);
        clock.advance(Duration::from_millis(10));
        tel.token(1);
        clock.advance(Duration::from_millis(3));
        tel.token(1);
        tel.token(1);
        clock.advance(Duration::from_millis(1));
        tel.terminal(1, "done");

        let t = tel.trace_json(1).expect("span retained");
        assert_eq!(t.get("id").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(t.get("tokens").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(t.get("ttft_ms").unwrap().as_f64().unwrap(), 17.0);
        assert_eq!(t.get("e2e_ms").unwrap().as_f64().unwrap(), 21.0);
        assert_eq!(t.get("outcome").unwrap().as_str().unwrap(), "done");
        assert!(t.get("complete").unwrap().as_bool().unwrap());
        let stages = t.get("stages").unwrap().as_arr().unwrap();
        let kinds: Vec<&str> = stages
            .iter()
            .map(|s| s.get("stage").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(
            kinds,
            ["queued", "placed", "prefill", "first_token", "terminal"]
        );
        // timestamps are monotone along the stage list
        let ts: Vec<f64> = stages
            .iter()
            .map(|s| s.get("t_ms").unwrap().as_f64().unwrap())
            .collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "{ts:?}");

        let stages = tel.stages_json();
        for h in ["queue_wait", "placement", "ttft", "inter_token"] {
            let c = stages
                .get(h)
                .unwrap()
                .get("count")
                .unwrap()
                .as_f64()
                .unwrap();
            assert!(c >= 1.0, "{h} unobserved");
        }
        // 2 inter-token gaps for 3 tokens
        assert_eq!(
            stages
                .get("inter_token")
                .unwrap()
                .get("count")
                .unwrap()
                .as_f64()
                .unwrap(),
            2.0
        );
    }

    #[test]
    fn failover_opens_second_segment_not_second_terminal() {
        let (clock, tel) = sim();
        tel.queued(7);
        clock.advance(Duration::from_millis(1));
        tel.placed(7, Some(0));
        tel.prefill_started(7);
        clock.advance(Duration::from_millis(4));
        // engine 0 dies; router requeues and re-places on engine 1
        tel.placed(7, Some(1));
        clock.advance(Duration::from_millis(1));
        tel.prefill_started(7);
        tel.token(7);
        tel.terminal(7, "done");
        let t = tel.trace_json(7).unwrap();
        assert_eq!(t.get("placements").unwrap().as_f64().unwrap(), 2.0);
        let stages = t.get("stages").unwrap().as_arr().unwrap();
        let terminals = stages
            .iter()
            .filter(|s| {
                s.get("stage").unwrap().as_str().unwrap() == "terminal"
            })
            .count();
        assert_eq!(terminals, 1);
        let engines: Vec<f64> = stages
            .iter()
            .filter(|s| {
                s.get("stage").unwrap().as_str().unwrap() == "placed"
            })
            .map(|s| s.get("engine").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(engines, [0.0, 1.0]);
        // queue_wait observed once (first placement only)
        assert_eq!(
            tel.stages_json()
                .get("queue_wait")
                .unwrap()
                .get("count")
                .unwrap()
                .as_f64()
                .unwrap(),
            1.0
        );
    }

    #[test]
    fn disabled_telemetry_records_nothing() {
        let tel = Telemetry::disabled(SimClock::shared());
        tel.queued(1);
        tel.placed(1, None);
        tel.token(1);
        tel.terminal(1, "done");
        tel.record_expert_counts(0, &[vec![1, 2]]);
        tel.note_expert_stats_unavailable();
        assert!(tel.trace_json(1).is_none());
        assert_eq!(tel.expert_stats_unavailable(), 0);
        let e = tel.experts_json();
        assert!(e.get("engines").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn span_ring_is_bounded_and_sampling_is_deterministic() {
        let clock = SimClock::shared();
        let tel = Telemetry::new(clock.clone()).with_ring_cap(4);
        for id in 0..10u64 {
            tel.queued(id);
            tel.terminal(id, "done");
        }
        let stages = tel.stages_json();
        assert_eq!(
            stages.get("retained_spans").unwrap().as_f64().unwrap(),
            4.0
        );
        assert_eq!(
            stages.get("spans_evicted").unwrap().as_f64().unwrap(),
            6.0
        );
        // newest survive
        assert!(tel.trace_json(9).is_some());
        assert!(tel.trace_json(0).is_none());

        // sample_permille=0 retains nothing but still histograms
        let tel0 = Telemetry::new(SimClock::shared())
            .with_sample_permille(0);
        tel0.queued(1);
        tel0.placed(1, None);
        tel0.terminal(1, "done");
        assert!(tel0.trace_json(1).is_none());
        assert_eq!(
            tel0.stages_json()
                .get("queue_wait")
                .unwrap()
                .get("count")
                .unwrap()
                .as_f64()
                .unwrap(),
            1.0
        );
    }

    #[test]
    fn expert_counts_accumulate_and_derive_balance() {
        let (_clock, tel) = sim();
        tel.record_expert_counts(0, &[vec![2, 0, 0, 2], vec![1, 1, 1, 1]]);
        tel.record_expert_counts(0, &[vec![2, 0, 0, 2], vec![1, 1, 1, 1]]);
        tel.record_expert_counts(1, &[vec![0, 8, 0, 0], vec![2, 2, 2, 2]]);
        let doc = tel.experts_json();
        let fleet = doc.get("fleet").unwrap();
        let rows = fleet.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        // layer 0 fleet counts: [4, 8, 0, 4]
        let c0: Vec<f64> = rows[0]
            .get("counts")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        assert_eq!(c0, [4.0, 8.0, 0.0, 4.0]);
        assert_eq!(
            rows[0].get("dead_experts").unwrap().as_f64().unwrap(),
            1.0
        );
        // imbalance: max 8 / mean 4 = 2
        assert_eq!(
            rows[0].get("imbalance").unwrap().as_f64().unwrap(),
            2.0
        );
        // layer 1 fleet is uniform [4,4,4,4]: imbalance 1, entropy ln 4
        assert_eq!(
            rows[1].get("imbalance").unwrap().as_f64().unwrap(),
            1.0
        );
        let ent = rows[1].get("entropy").unwrap().as_f64().unwrap();
        assert!((ent - 4f64.ln()).abs() < 1e-12, "{ent}");
        assert_eq!(
            rows[1].get("dead_experts").unwrap().as_f64().unwrap(),
            0.0
        );
        // per-engine sections present
        let engines = doc.get("engines").unwrap().as_obj().unwrap();
        assert_eq!(engines.len(), 2);
        assert!(engines.contains_key("0") && engines.contains_key("1"));
    }

    #[test]
    fn expert_balance_edge_cases() {
        let b = ExpertBalance::of(&[]);
        assert_eq!((b.total, b.dead), (0, 0));
        let b = ExpertBalance::of(&[0, 0, 0]);
        assert_eq!((b.total, b.dead), (0, 3));
        assert_eq!(b.imbalance, 0.0);
        assert_eq!(b.entropy, 0.0);
        // full collapse: imbalance = N_E, entropy = 0
        let b = ExpertBalance::of(&[9, 0, 0]);
        assert_eq!(b.imbalance, 3.0);
        assert_eq!(b.entropy, 0.0);
        assert_eq!(b.dead, 2);
    }

    fn lines(evs: &[&str]) -> Vec<String> {
        evs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn spans_from_events_derives_the_lifecycle() {
        let evs = lines(&[
            r#"{"id":0,"kind":"admit","prompt_len":4,"seq":0,"t_ms":0}"#,
            r#"{"id":0,"kind":"take","seq":1,"t_ms":2}"#,
            r#"{"engine":1,"id":0,"kind":"place","seq":2,"t_ms":2}"#,
            r#"{"engine":1,"free":3,"kind":"beat","seq":3,"t_ms":5}"#,
            r#"{"engine":1,"id":0,"kind":"done","seq":4,"t_ms":9,"tokens":6}"#,
        ]);
        let spans = spans_from_events(&evs).unwrap();
        assert_eq!(spans.len(), 1);
        let s = &spans[0];
        assert_eq!(s.id, 0);
        assert_eq!(s.queued_ms, 0);
        // take + place collapse into one segment carrying the engine
        assert_eq!(s.segments.len(), 1);
        assert_eq!(s.segments[0].engine, Some(1));
        assert_eq!(s.tokens, 6);
        assert_eq!(s.terminal.as_ref().unwrap().outcome, "done");
    }

    #[test]
    fn spans_from_events_failover_yields_second_segment() {
        let evs = lines(&[
            r#"{"id":3,"kind":"admit","seq":0,"t_ms":0}"#,
            r#"{"id":3,"kind":"take","seq":1,"t_ms":1}"#,
            r#"{"engine":0,"id":3,"kind":"place","seq":2,"t_ms":1}"#,
            r#"{"engine":0,"kind":"quarantine","reason":"errors","seq":3,"t_ms":8}"#,
            r#"{"engine":0,"exhausted":0,"kind":"failover","requeued":1,"seq":4,"t_ms":8}"#,
            r#"{"id":3,"kind":"retry","seq":5,"t_ms":8}"#,
            r#"{"id":3,"kind":"take","seq":6,"t_ms":9}"#,
            r#"{"engine":1,"id":3,"kind":"place","seq":7,"t_ms":9}"#,
            r#"{"engine":1,"id":3,"kind":"done","seq":8,"t_ms":20,"tokens":2}"#,
        ]);
        let spans = spans_from_events(&evs).unwrap();
        assert_eq!(spans.len(), 1);
        let s = &spans[0];
        assert_eq!(s.segments.len(), 2, "failover must re-place");
        assert_eq!(s.segments[0].engine, Some(0));
        assert_eq!(s.segments[1].engine, Some(1));
        assert_eq!(s.terminal.as_ref().unwrap().outcome, "done");
    }

    #[test]
    fn spans_from_events_rejects_double_terminal_and_time_travel() {
        let double = lines(&[
            r#"{"id":1,"kind":"admit","seq":0,"t_ms":0}"#,
            r#"{"id":1,"kind":"drop_deadline","seq":1,"t_ms":4}"#,
            r#"{"engine":0,"id":1,"kind":"done","seq":2,"t_ms":5,"tokens":1}"#,
        ]);
        let err = spans_from_events(&double).unwrap_err().to_string();
        assert!(err.contains("after terminal"), "{err}");

        let warp = lines(&[
            r#"{"id":1,"kind":"admit","seq":0,"t_ms":10}"#,
            r#"{"engine":0,"id":1,"kind":"place","seq":1,"t_ms":3}"#,
        ]);
        let err = spans_from_events(&warp).unwrap_err().to_string();
        assert!(err.contains("earlier"), "{err}");
    }

    #[test]
    fn prom_rendering_has_unique_typed_families() {
        let (clock, tel) = sim();
        tel.queued(1);
        clock.advance(Duration::from_millis(2));
        tel.placed(1, Some(0));
        tel.prefill_started(1);
        tel.token(1);
        tel.terminal(1, "done");
        tel.record_expert_counts(0, &[vec![3, 1, 0, 4]]);
        tel.note_expert_stats_unavailable();
        let doc = json::obj(vec![
            (
                "engine",
                json::obj(vec![
                    ("tokens_generated", json::num(12.0)),
                    ("steps_executed", json::num(9.0)),
                ]),
            ),
            (
                "engines",
                json::arr(vec![json::obj(vec![
                    ("id", json::num(0.0)),
                    ("healthy", Json::Bool(true)),
                    ("completions", json::num(1.0)),
                    (
                        "stats",
                        json::obj(vec![("n_lanes", json::num(4.0))]),
                    ),
                ])]),
            ),
            (
                "router",
                json::obj(vec![
                    ("placement", json::s("least_loaded")),
                    ("failovers", json::num(0.0)),
                ]),
            ),
            (
                "scheduler",
                json::obj(vec![
                    ("enqueued", json::num(1.0)),
                    (
                        "queue_wait",
                        Histogram::new().to_json(),
                    ),
                ]),
            ),
            ("server", json::obj(vec![("uptime_s", json::num(2.0))])),
            ("journal", json::obj(vec![("dropped_events", json::num(0.0))])),
            ("stages", tel.stages_json()),
            ("experts", tel.experts_json()),
        ]);
        let text = render_prom(&doc);

        // every family has exactly one TYPE line and no duplicate names
        let mut seen = std::collections::BTreeSet::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let name = rest.split_whitespace().next().unwrap();
                assert!(seen.insert(name.to_string()), "dup TYPE {name}");
            }
        }
        // every sample line's family has a TYPE line
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let metric = line.split([' ', '{']).next().unwrap();
            let family = seen.iter().any(|n| {
                metric == n.as_str()
                    || metric
                        .strip_prefix(n.as_str())
                        .is_some_and(|s| s == "_sum" || s == "_count")
            });
            assert!(family, "sample {metric} lacks a TYPE line");
        }
        // the load-bearing families are present and populated
        for needle in [
            "sigma_moe_fleet_tokens_generated 12",
            "sigma_moe_engine_completions{engine=\"0\"} 1",
            "sigma_moe_engine_healthy{engine=\"0\"} 1",
            "sigma_moe_router_info{placement=\"least_loaded\"} 1",
            "sigma_moe_stage_ttft{quantile=\"0.5\"}",
            "sigma_moe_stage_queue_wait_count",
            "sigma_moe_experts_tokens_total{layer=\"0\",expert=\"3\"} 4",
            "sigma_moe_experts_unavailable 1",
            "sigma_moe_experts_imbalance{layer=\"0\"} 2",
            "sigma_moe_engine_experts_tokens_total{engine=\"0\",layer=\"0\",expert=\"0\"} 3",
            "sigma_moe_journal_dropped_events 0",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // rendering is deterministic
        assert_eq!(text, render_prom(&doc));

        // the scraper-shaped validator accepts what we render, with
        // the CI smoke's required prefixes satisfied
        validate_prom(
            &text,
            &["sigma_moe_stage_", "sigma_moe_experts_"],
        )
        .unwrap();
    }

    #[test]
    fn prom_rendering_exposes_adaptive_expert_k_gauges() {
        use crate::serving::scheduler::{DegradeCfg, Policy, Scheduler};
        let sched = Scheduler::new(8, Policy::Fifo).with_degrade_k(
            DegradeCfg { min_k: 1, hi_wm: 2, lo_wm: 1 },
            4,
        );
        let doc = json::obj(vec![("scheduler", sched.metrics_json())]);
        let text = render_prom(&doc);
        for needle in [
            "sigma_moe_scheduler_expert_k_max 4",
            "sigma_moe_scheduler_expert_k_current 4",
            "sigma_moe_scheduler_expert_k_degrades 0",
            "sigma_moe_scheduler_expert_k_restores 0",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        validate_prom(&text, &["sigma_moe_scheduler_expert_k_"]).unwrap();
        // a dense scheduler (no MoE ceiling) exposes none of them —
        // absent, not zero, so dashboards don't chart a fake k
        let dense = Scheduler::new(8, Policy::Fifo);
        let text =
            render_prom(&json::obj(vec![("scheduler", dense.metrics_json())]));
        assert!(!text.contains("expert_k"), "dense must omit k gauges");
    }

    #[test]
    fn prom_rendering_exposes_speculative_decode_gauges() {
        // a speculating engine's stats surface as the
        // `sigma_moe_engine_spec_*` families through the fleet
        // exposition, and the CI smoke's required-prefix check can
        // gate on them; a non-speculating fleet exposes none of them —
        // absent, not zero, so dashboards don't chart a dead
        // accept-rate
        use crate::serving::{
            EngineBackend, GenRequest, MockBackend, Sampler,
        };
        use std::sync::mpsc;
        let run = |speculate: usize| {
            let mut b = MockBackend::new(1, 10)
                .with_prefill_chunk(4)
                .with_speculate(speculate);
            let (tx, _rx) = mpsc::channel();
            b.submit_streaming(
                GenRequest {
                    prompt: vec![1, 2, 3],
                    max_new_tokens: 32,
                    sampler: Sampler::greedy(),
                    ..Default::default()
                },
                tx,
            );
            while b.pump().unwrap() > 0 {}
            let stats = b.stats();
            let row = json::obj(vec![
                ("id", json::num(0.0)),
                (
                    "stats",
                    Json::Obj(
                        stats
                            .iter()
                            .map(|(k, v)| (k.clone(), json::num(*v)))
                            .collect(),
                    ),
                ),
            ]);
            render_prom(&json::obj(vec![(
                "engines",
                json::arr(vec![row]),
            )]))
        };
        let text = run(3);
        for needle in [
            "sigma_moe_engine_speculate{engine=\"0\"} 3",
            "sigma_moe_engine_spec_rounds{engine=\"0\"}",
            "sigma_moe_engine_spec_accept_rate{engine=\"0\"}",
            "sigma_moe_engine_spec_hist_3{engine=\"0\"}",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // the CI smoke gates on this prefix being present AND populated
        validate_prom(&text, &["sigma_moe_engine_spec_"]).unwrap();
        let plain = run(0);
        assert!(
            !plain.contains("spec_"),
            "non-speculating fleet must omit the spec families"
        );
        assert!(
            validate_prom(&plain, &["sigma_moe_engine_spec_"]).is_err(),
            "the required-prefix gate must fail closed without speculation"
        );
    }

    #[test]
    fn prom_rendering_exposes_prefix_cache_families() {
        // the shared cache's document section renders as the
        // `sigma_moe_prefix_cache_*` families — scalars as gauges, the
        // per-prompt-length buckets as labeled counters — and a
        // cache-less document exposes none of them (absent, not zero)
        use crate::serving::PrefixCache;
        let cache = PrefixCache::new(1 << 20);
        let prompt: Vec<i32> = (0..12).collect();
        assert!(cache.probe(&prompt, 4).is_none()); // cold miss
        assert!(cache.insert(&prompt[..8], vec![0.5f32; 16]));
        assert!(cache.probe(&prompt, 4).is_some()); // warm hit
        let doc = json::obj(vec![("prefix_cache", cache.metrics_json())]);
        let text = render_prom(&doc);
        for needle in [
            "sigma_moe_prefix_cache_budget_bytes 1048576",
            "sigma_moe_prefix_cache_entries 1",
            "sigma_moe_prefix_cache_hits 1",
            "sigma_moe_prefix_cache_misses 1",
            "sigma_moe_prefix_cache_hit_rate 0.5",
            "sigma_moe_prefix_cache_bucket_hits{prompt_len=",
            "sigma_moe_prefix_cache_bucket_misses{prompt_len=",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // the CI smoke gates on this prefix being present AND populated
        validate_prom(&text, &["sigma_moe_prefix_cache_"]).unwrap();
        let cold = render_prom(&json::obj(vec![(
            "scheduler",
            json::obj(vec![("depth", json::num(0.0))]),
        )]));
        assert!(
            !cold.contains("prefix_cache"),
            "cache-less documents must omit the families"
        );
        assert!(
            validate_prom(&cold, &["sigma_moe_prefix_cache_"]).is_err(),
            "the required-prefix gate must fail closed without the cache"
        );
    }

    #[test]
    fn validate_prom_rejects_malformed_expositions() {
        // duplicate TYPE
        let dup = "# TYPE a gauge\na 1\n# TYPE a gauge\na 2\n";
        assert!(validate_prom(dup, &[]).unwrap_err().to_string().contains("duplicate"));
        // sample before any TYPE line
        assert!(validate_prom("a 1\n", &[]).is_err());
        // sample outside the announced family
        let stray = "# TYPE a gauge\nb 1\n";
        assert!(validate_prom(stray, &[]).unwrap_err().to_string().contains("outside"));
        // non-numeric value
        let bad = "# TYPE a gauge\na pancake\n";
        assert!(validate_prom(bad, &[]).is_err());
        // unknown metric type
        assert!(validate_prom("# TYPE a widget\n", &[]).is_err());
        // a required prefix with no populated family
        let empty = "# TYPE a gauge\na 1\n";
        assert!(validate_prom(empty, &["sigma_moe_stage_"]).is_err());
        // summary suffixes stay inside their family
        let summary = "# TYPE s summary\ns{quantile=\"0.5\"} 1\n\
                       s_sum 2\ns_count 3\n";
        validate_prom(summary, &["s"]).unwrap();
    }

    #[test]
    fn trace_json_resolves_in_flight_spans() {
        let (_clock, tel) = sim();
        tel.queued(42);
        tel.placed(42, None);
        let t = tel.trace_json(42).unwrap();
        assert!(!t.get("complete").unwrap().as_bool().unwrap());
        assert!(t.opt("e2e_ms").is_none());
    }
}
