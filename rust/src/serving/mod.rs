//! Serving: a threaded, dynamically-batched inference engine over the
//! AOT-compiled `step_fwd` executable (vLLM-router-flavored, scaled to
//! this model family).
//!
//! `step_fwd` advances `serve_batch` independent sequences by one token,
//! carrying each sequence's Transformer-XL memory.  The engine keeps one
//! *slot* per batch lane; requests queue until a lane frees up, lanes
//! step together in one executable call (continuous batching at token
//! granularity — a finished lane is refilled on the next step without
//! draining the others).

pub mod engine;
pub mod sampler;

pub use engine::{Engine, GenRequest, GenResult};
pub use sampler::Sampler;
