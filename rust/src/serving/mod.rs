//! Serving: a network-facing, continuously-batched inference stack
//! over the AOT-compiled `step_fwd` executable.
//!
//! Layers, front to back:
//!
//! * [`server`] — std-only HTTP/1.1 frontend (`POST /v1/completions`
//!   with chunked token streaming, `/healthz`, `/metrics`).  Connection
//!   threads never touch the device; a dedicated driver thread owns the
//!   non-`Send` PJRT state.
//! * [`scheduler`] — bounded admission queue between the frontend and
//!   the engine lanes: FIFO / shortest-prompt-first / deadline-aware
//!   policies, 429 backpressure on overflow, queue + latency
//!   histograms.
//! * [`engine`] — the continuous-batching [`Engine`]: `serve_batch`
//!   device-resident lanes stepping together — chunked `prefill`
//!   dispatches ingest up to C prompt tokens per lane per pump (decode
//!   lanes ride along 1-active), pure-decode pumps use single-token
//!   `step_fwd` — finished lanes refilled without draining the others,
//!   lane memory reset on device via the AOT'd `reset_lanes` mask
//!   program.
//! * [`router`] — the multi-engine fleet: N driver threads each owning
//!   an independent backend behind one shared admission scheduler,
//!   with placement policies, heartbeat/error health tracking, and
//!   exactly-once failover of in-flight requests.
//! * [`loadgen`] — open-loop Poisson load generator + hand-rolled HTTP
//!   client; writes `BENCH_serve.json` (latency percentiles,
//!   tokens/sec).
//! * [`mock`] — a deterministic device-free [`EngineBackend`] (with
//!   injectable [`MockFault`]s) so the scheduler/HTTP/router layers
//!   test — and `loadgen --dry-run` runs — without artifacts.
//! * [`clock`] — the injectable time source behind all of the above:
//!   wall clock in production, [`SimClock`] under the deterministic
//!   harness.
//! * [`journal`] — the seeded, logically-timestamped decision journal
//!   (admissions, placements, heartbeats, quarantines, failovers,
//!   re-admissions) flushed as a JSONL trace.
//! * [`chaos`] — the seeded chaos + record/replay harness: the real
//!   placer/engine steps, single-threaded on a [`SimClock`] over mock
//!   fleets with fault storms; replays a recorded trace bit-for-bit.
//! * [`telemetry`] — request-lifecycle spans (queued → placed →
//!   prefill → first-token → terminal, with always-on per-stage
//!   latency histograms and a sampled trace ring behind
//!   `GET /v1/trace/<id>`), σ-MoE expert-utilization aggregation
//!   (per-engine per-layer counts, load-imbalance, routing entropy,
//!   dead experts), and the Prometheus text renderer behind
//!   `GET /metrics?format=prom`.

pub mod chaos;
pub mod clock;
pub mod drafter;
pub mod engine;
pub mod journal;
pub mod loadgen;
pub mod mock;
pub mod prefix_cache;
pub mod router;
pub mod sampler;
pub mod scheduler;
pub mod server;
pub mod telemetry;

pub use chaos::{ChaosCfg, ChaosReport, ReplayOutcome};
pub use clock::{Clock, SharedClock, SimClock, WallClock};
pub use drafter::{Drafter, NgramDrafter};
pub use engine::{
    DropReason, Engine, EngineBackend, GenRequest, GenResult, StreamEvent,
};
pub use journal::{Journal, Trace};
pub use mock::{MockBackend, MockFault};
pub use prefix_cache::{PrefixCache, PrefixHit};
pub use router::{Fleet, Placement, RouterCfg};
pub use sampler::Sampler;
pub use scheduler::{
    DegradeCfg, Histogram, KTransition, Policy, Rejection, Scheduler,
    SpecTransition,
};
pub use server::{Driver, ServerConfig};
pub use telemetry::Telemetry;
