//! Prefix cache: content-hash-keyed snapshots of post-prefill lane
//! state, shared across the fleet, with LRU eviction under a byte
//! budget.
//!
//! Serving millions of users means heavy prompt overlap — shared
//! system prompts and few-shot templates re-prefill the same tokens on
//! every request.  After a lane crosses a prefill chunk boundary, the
//! engine snapshots its XL-memory rows (one `[n_layers, mem_len,
//! d_model]` block, gathered on device by the AOT'd `snapshot_lanes`
//! program) keyed by a content hash of the token prefix *at
//! chunk-boundary granularity*, so one entry covers a prefix of any
//! longer prompt sharing those tokens.  On admission the engine probes
//! longest-boundary-first and seeds the new lane from the match via
//! `restore_lanes` instead of re-prefilling, leaving only the tail
//! chunks to dispatch: a hit completes prefill in ⌈tail/C⌉ + 1
//! dispatches instead of ⌈L/C⌉.
//!
//! Because prefill is deterministic and the snapshot captures the
//! complete per-lane state (the banded XL memory is the *only*
//! sequence state; position is the prefix length itself), a cache-hit
//! stream is bitwise identical to the same request served cold — the
//! equivalence the property tests pin.  The same snapshot/restore
//! machinery is the paging primitive for prompts longer than
//! `mem_len`: a follow-up can walk attention state through the banded
//! window chunk-by-chunk using exactly these two programs.
//!
//! Everything is deterministic under the chaos harness: recency is a
//! logical tick counter (never a wall clock), the table is a
//! `BTreeMap`, and `metrics_json` renders in fixed key order so replay
//! can byte-diff the metrics document.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::json::{self, Json};

/// Prompt-length buckets for hit-rate reporting — the same power-of-two
/// edges loadgen buckets TTFT by, so the two reports line up row for
/// row (the last bucket is open-ended).
pub const PROMPT_BUCKETS: [(&str, usize); 9] = [
    ("1-8", 8),
    ("9-16", 16),
    ("17-32", 32),
    ("33-64", 64),
    ("65-128", 128),
    ("129-256", 256),
    ("257-512", 512),
    ("513-1024", 1024),
    (">1024", usize::MAX),
];

fn bucket_idx(len: usize) -> usize {
    PROMPT_BUCKETS
        .iter()
        .position(|&(_, hi)| len <= hi)
        .unwrap_or(PROMPT_BUCKETS.len() - 1)
}

/// FNV-1a over the token prefix — stable across runs/platforms (no
/// RandomState), cheap enough to hash every boundary of every probe.
fn hash_tokens(tokens: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// One cached snapshot: the exact token prefix it covers (the
/// collision guard — a hash match alone never seeds a lane) plus the
/// flattened `[n_layers, mem_len, d_model]` memory payload.
struct Entry {
    tokens: Vec<i32>,
    payload: Arc<Vec<f32>>,
    bytes: u64,
    last_used: u64,
}

/// A successful probe: seed the lane from `payload` and prefill only
/// `prompt[len..]`.
#[derive(Clone)]
pub struct PrefixHit {
    /// Number of prompt tokens the snapshot covers (a multiple of the
    /// chunk width, always < the prompt length so at least one tail
    /// token remains to produce the first logits).
    pub len: usize,
    /// Flattened `[n_layers, mem_len, d_model]` memory rows; empty in
    /// device-free mirrors (the mock backend caches weight, not state).
    pub payload: Arc<Vec<f32>>,
}

#[derive(Default)]
struct Counters {
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
    rejected_oversize: u64,
    collisions: u64,
    tokens_saved: u64,
    bucket_hits: [u64; PROMPT_BUCKETS.len()],
    bucket_misses: [u64; PROMPT_BUCKETS.len()],
}

struct Inner {
    entries: BTreeMap<u64, Entry>,
    bytes: u64,
    /// Logical recency clock: bumped on every probe hit / insert.
    /// Deterministic (unlike `Instant`) so chaos replay can byte-diff
    /// eviction order.
    tick: u64,
    c: Counters,
}

/// The fleet-shared snapshot store.  One `Arc<PrefixCache>` is handed
/// to every backend and to the scheduler (which prices admissions at
/// the residual chunk count via [`peek`](PrefixCache::peek)).
pub struct PrefixCache {
    budget_bytes: u64,
    inner: Mutex<Inner>,
}

impl PrefixCache {
    pub fn new(budget_bytes: u64) -> Self {
        PrefixCache {
            budget_bytes,
            inner: Mutex::new(Inner {
                entries: BTreeMap::new(),
                bytes: 0,
                tick: 0,
                c: Counters::default(),
            }),
        }
    }

    pub fn shared(budget_bytes: u64) -> Arc<Self> {
        Arc::new(Self::new(budget_bytes))
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    /// The chunk boundaries a probe walks for an `len`-token prompt,
    /// longest first: ⌊(len−1)/C⌋·C down to C.  Capping at `len − 1`
    /// (not `len`) keeps at least one tail token uncached, so a hit
    /// still runs a prefill dispatch that produces the first logits.
    fn boundaries(len: usize, chunk: usize) -> impl Iterator<Item = usize> {
        let chunk = chunk.max(1);
        let top = if len == 0 { 0 } else { (len - 1) / chunk * chunk };
        (1..=top / chunk).rev().map(move |i| i * chunk)
    }

    /// Longest-boundary match for `prompt`, counting hit/miss (per
    /// prompt-length bucket) and touching LRU recency.
    pub fn probe(&self, prompt: &[i32], chunk: usize) -> Option<PrefixHit> {
        let mut inner = self.inner.lock().unwrap();
        let b = bucket_idx(prompt.len());
        for k in Self::boundaries(prompt.len(), chunk) {
            let h = hash_tokens(&prompt[..k]);
            if let Some(e) = inner.entries.get(&h) {
                if e.tokens != prompt[..k] {
                    continue; // hash collision: never seed from it
                }
                let payload = e.payload.clone();
                inner.tick += 1;
                let tick = inner.tick;
                inner.entries.get_mut(&h).unwrap().last_used = tick;
                inner.c.hits += 1;
                inner.c.bucket_hits[b] += 1;
                inner.c.tokens_saved += k as u64;
                return Some(PrefixHit { len: k, payload });
            }
        }
        inner.c.misses += 1;
        inner.c.bucket_misses[b] += 1;
        None
    }

    /// Longest-boundary match length without touching counters or
    /// recency — the scheduler's admission-cost probe (costing a queue
    /// must not perturb eviction order or hit-rate accounting).
    pub fn peek(&self, prompt: &[i32], chunk: usize) -> usize {
        let inner = self.inner.lock().unwrap();
        for k in Self::boundaries(prompt.len(), chunk) {
            if let Some(e) = inner.entries.get(&hash_tokens(&prompt[..k])) {
                if e.tokens == prompt[..k] {
                    return k;
                }
            }
        }
        0
    }

    /// Is `prefix` worth snapshotting?  False when an entry for these
    /// exact tokens already exists (dedupe before spending a snapshot
    /// dispatch on it).
    pub fn wants(&self, prefix: &[i32]) -> bool {
        let inner = self.inner.lock().unwrap();
        match inner.entries.get(&hash_tokens(prefix)) {
            Some(e) => e.tokens != prefix,
            None => true,
        }
    }

    /// Insert a snapshot, charging `payload` + key bytes against the
    /// budget and evicting least-recently-used entries until it fits.
    /// Returns false (and leaves the cache untouched) when the entry
    /// alone exceeds the whole budget, when these tokens are already
    /// cached, or on a hash collision with a different prefix.
    pub fn insert(&self, tokens: &[i32], payload: Vec<f32>) -> bool {
        let bytes = (payload.len() * 4 + tokens.len() * 4) as u64;
        self.insert_weighted(tokens, payload, bytes)
    }

    /// [`insert`](Self::insert) with an explicit byte weight — the
    /// device-free mock charges the bytes a real snapshot *would*
    /// occupy so budget/eviction behave identically without the
    /// payload allocation.
    pub fn insert_weighted(
        &self,
        tokens: &[i32],
        payload: Vec<f32>,
        bytes: u64,
    ) -> bool {
        if tokens.is_empty() {
            return false;
        }
        let mut inner = self.inner.lock().unwrap();
        if bytes > self.budget_bytes {
            inner.c.rejected_oversize += 1;
            return false;
        }
        let h = hash_tokens(tokens);
        if let Some(e) = inner.entries.get(&h) {
            if e.tokens != tokens {
                inner.c.collisions += 1;
            }
            return false; // already cached (or unusably aliased)
        }
        while inner.bytes + bytes > self.budget_bytes {
            let lru = inner
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&k, _)| k)
                .expect("bytes > 0 implies a resident entry");
            let evicted = inner.entries.remove(&lru).unwrap();
            inner.bytes -= evicted.bytes;
            inner.c.evictions += 1;
        }
        inner.tick += 1;
        let last_used = inner.tick;
        inner.entries.insert(
            h,
            Entry {
                tokens: tokens.to_vec(),
                payload: Arc::new(payload),
                bytes,
                last_used,
            },
        );
        inner.bytes += bytes;
        inner.c.insertions += 1;
        true
    }

    pub fn entries(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn bytes(&self) -> u64 {
        self.inner.lock().unwrap().bytes
    }

    /// (hits, misses) so far — loadgen derives the headline hit rate
    /// from the same counters `/metrics` exports.
    pub fn hit_miss(&self) -> (u64, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.c.hits, inner.c.misses)
    }

    /// The `prefix_cache` section of `/metrics`: global store state +
    /// hit/miss per prompt-length bucket.  Fixed key order and
    /// logical-tick recency keep the document byte-stable under chaos
    /// replay.
    pub fn metrics_json(&self) -> Json {
        let inner = self.inner.lock().unwrap();
        let c = &inner.c;
        let total = c.hits + c.misses;
        let rate = if total > 0 {
            c.hits as f64 / total as f64
        } else {
            0.0
        };
        let buckets: Vec<(String, Json)> = PROMPT_BUCKETS
            .iter()
            .enumerate()
            .filter(|&(i, _)| c.bucket_hits[i] + c.bucket_misses[i] > 0)
            .map(|(i, &(label, _))| {
                (
                    label.to_string(),
                    json::obj(vec![
                        ("hits", json::num(c.bucket_hits[i] as f64)),
                        ("misses", json::num(c.bucket_misses[i] as f64)),
                    ]),
                )
            })
            .collect();
        json::obj(vec![
            ("budget_bytes", json::num(self.budget_bytes as f64)),
            ("bytes", json::num(inner.bytes as f64)),
            ("entries", json::num(inner.entries.len() as f64)),
            ("hits", json::num(c.hits as f64)),
            ("misses", json::num(c.misses as f64)),
            ("hit_rate", json::num(rate)),
            ("insertions", json::num(c.insertions as f64)),
            ("evictions", json::num(c.evictions as f64)),
            (
                "rejected_oversize",
                json::num(c.rejected_oversize as f64),
            ),
            ("collisions", json::num(c.collisions as f64)),
            ("tokens_saved", json::num(c.tokens_saved as f64)),
            ("buckets", Json::Obj(buckets.into_iter().collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(n: usize, seed: i32) -> Vec<i32> {
        (0..n).map(|i| seed + i as i32).collect()
    }

    #[test]
    fn probe_matches_longest_chunk_boundary_only() {
        let c = PrefixCache::new(1 << 20);
        let p = toks(13, 0);
        // entries at boundaries 4 and 8 of the same prompt family
        assert!(c.insert(&p[..4], vec![1.0; 4]));
        assert!(c.insert(&p[..8], vec![2.0; 4]));
        let hit = c.probe(&p, 4).expect("hit");
        assert_eq!(hit.len, 8, "longest boundary wins");
        assert_eq!(*hit.payload, vec![2.0; 4]);
        // ragged boundary cases: hit length relative to C
        assert!(c.insert(&p[..12], vec![3.0; 4]));
        for (plen, want) in [(5, 4), (8, 4), (9, 8), (12, 8), (13, 12)] {
            assert_eq!(c.peek(&p[..plen], 4), want, "prompt len {plen}");
        }
        // a hit never covers the whole prompt: len 4 with a 4-entry
        // present still leaves the final token to prefill
        assert_eq!(c.peek(&p[..4], 4), 0);
        // different tail beyond the boundary still hits the prefix
        let mut q = p[..8].to_vec();
        q.extend(toks(5, 100));
        assert_eq!(c.peek(&q, 4), 8);
        // different tokens *inside* the boundary miss
        let mut r = p[..8].to_vec();
        r[2] += 1;
        r.push(0);
        assert_eq!(c.peek(&r, 4), 0);
    }

    #[test]
    fn lru_eviction_holds_byte_budget_invariant() {
        // budget fits two 4-token/4-float entries (4*4+4*4 = 32 bytes)
        let c = PrefixCache::new(64);
        let a = toks(4, 0);
        let b = toks(4, 50);
        let d = toks(4, 90);
        assert!(c.insert(&a, vec![0.0; 4]));
        assert!(c.insert(&b, vec![0.0; 4]));
        assert_eq!((c.entries(), c.bytes()), (2, 64));
        // touch `a` so `b` is LRU, then insert a third entry
        let mut pa = a.clone();
        pa.push(9);
        assert!(c.probe(&pa, 4).is_some());
        assert!(c.insert(&d, vec![0.0; 4]));
        assert!(c.bytes() <= c.budget_bytes(), "budget invariant");
        assert_eq!(c.entries(), 2);
        assert_eq!(c.peek(&pa, 4), 4, "recently-used survived");
        let mut pb = b.clone();
        pb.push(9);
        assert_eq!(c.peek(&pb, 4), 0, "LRU evicted");
        let m = c.metrics_json();
        assert_eq!(m.get("evictions").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(m.get("insertions").unwrap().as_f64().unwrap(), 3.0);

        // an entry bigger than the whole budget is rejected, not
        // admitted by evicting everything
        assert!(!c.insert(&toks(4, 200), vec![0.0; 1000]));
        assert_eq!(c.entries(), 2);
        assert_eq!(
            c.metrics_json()
                .get("rejected_oversize")
                .unwrap()
                .as_f64()
                .unwrap(),
            1.0
        );
    }

    #[test]
    fn duplicate_insert_is_refused_and_wants_dedupes() {
        let c = PrefixCache::new(1 << 20);
        let a = toks(8, 3);
        assert!(c.wants(&a));
        assert!(c.insert(&a, vec![1.0; 8]));
        assert!(!c.wants(&a), "already cached");
        assert!(!c.insert(&a, vec![2.0; 8]), "dup refused");
        assert_eq!(c.entries(), 1);
        let mut p = a.clone();
        p.push(0);
        // the original payload is untouched by the refused insert
        assert_eq!(*c.probe(&p, 8).unwrap().payload, vec![1.0; 8]);
    }

    #[test]
    fn peek_is_side_effect_free() {
        let c = PrefixCache::new(1 << 20);
        let a = toks(4, 0);
        c.insert(&a, vec![0.0; 2]);
        let mut p = a.clone();
        p.push(1);
        let before = c.metrics_json().to_string();
        assert_eq!(c.peek(&p, 4), 4);
        assert_eq!(c.peek(&toks(9, 77), 4), 0);
        assert_eq!(c.metrics_json().to_string(), before);
    }

    #[test]
    fn counters_and_buckets_track_probe_traffic() {
        let c = PrefixCache::new(1 << 20);
        let a = toks(16, 0);
        c.insert(&a[..16], vec![0.0; 4]);
        let mut long = a.clone();
        long.extend(toks(4, 500)); // 20 tokens → bucket "17-32"
        assert!(c.probe(&long, 16).is_some());
        assert!(c.probe(&toks(6, 900), 16).is_none());
        let (h, m) = c.hit_miss();
        assert_eq!((h, m), (1, 1));
        let doc = c.metrics_json();
        assert_eq!(doc.get("hit_rate").unwrap().as_f64().unwrap(), 0.5);
        assert_eq!(
            doc.get("tokens_saved").unwrap().as_f64().unwrap(),
            16.0
        );
        let buckets = doc.get("buckets").unwrap();
        assert_eq!(
            buckets
                .get("17-32")
                .unwrap()
                .get("hits")
                .unwrap()
                .as_f64()
                .unwrap(),
            1.0
        );
        assert_eq!(
            buckets
                .get("1-8")
                .unwrap()
                .get("misses")
                .unwrap()
                .as_f64()
                .unwrap(),
            1.0
        );
        // untouched buckets are omitted, not zero-filled
        assert!(buckets.opt("513-1024").is_none());
    }

    #[test]
    fn boundary_walk_respects_chunk_and_leaves_a_tail() {
        let walk = |len, chunk| {
            PrefixCache::boundaries(len, chunk).collect::<Vec<_>>()
        };
        assert_eq!(walk(13, 4), [12, 8, 4]);
        assert_eq!(walk(12, 4), [8, 4], "full-length cover excluded");
        assert_eq!(walk(4, 4), Vec::<usize>::new());
        assert_eq!(walk(5, 4), [4]);
        assert_eq!(walk(0, 4), Vec::<usize>::new());
        assert_eq!(walk(7, 1), [6, 5, 4, 3, 2, 1]);
        // chunk 0 is clamped, not a divide-by-zero
        assert_eq!(walk(3, 0), [2, 1]);
    }
}
