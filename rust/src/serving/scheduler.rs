//! Continuous-batching admission layer between the HTTP frontend and
//! the engine lanes.
//!
//! Connection threads [`Scheduler::enqueue`] requests into a bounded
//! queue (overflow is rejected synchronously — the frontend answers
//! 429); the single engine-driver thread [`Scheduler::take_next`]s one
//! request per free lane according to the configured admission
//! [`Policy`] and feeds it to the engine, so ordering is decided here,
//! never by the engine's internal FIFO.  All counters and latency
//! [`Histogram`]s for `/metrics` live behind the same lock.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::json::{self, Json};
use crate::serving::clock::{Clock, SharedClock, WallClock};
use crate::serving::engine::{DropReason, GenRequest, StreamEvent};
use crate::serving::journal::Journal;
use crate::serving::prefix_cache::PrefixCache;
use crate::serving::telemetry::Telemetry;

/// Admission ordering policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Oldest request first.
    Fifo,
    /// Shortest prompt first (FIFO tiebreak) — minimizes mean wait under
    /// mixed prompt lengths at the cost of long-prompt fairness.  With
    /// chunked prefill ([`Scheduler::with_prefill_chunk`]) "shortest"
    /// means fewest ⌈len/C⌉ prefill dispatches, the engine's actual
    /// cost unit: prompts that drain in the same number of chunks are
    /// served FIFO rather than micro-ordered by a token-count
    /// difference the engine cannot even observe.
    ShortestPrompt,
    /// Earliest deadline first; requests whose deadline already expired
    /// are dropped at take time (their stream gets
    /// [`StreamEvent::Dropped`]).  Requests without a deadline rank
    /// last, FIFO among themselves.
    Deadline,
}

impl Policy {
    pub fn parse(s: &str) -> Result<Policy> {
        match s {
            "fifo" => Ok(Policy::Fifo),
            "spf" | "shortest-prompt" => Ok(Policy::ShortestPrompt),
            "deadline" => Ok(Policy::Deadline),
            other => Err(Error::Config(format!(
                "unknown scheduler policy {other:?} \
                 (expected fifo | spf | deadline)"
            ))),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::ShortestPrompt => "spf",
            Policy::Deadline => "deadline",
        }
    }
}

/// Adaptive expert top-k degradation policy (`--degrade-k
/// min_k:hi_wm:lo_wm`): under queue pressure the scheduler lowers the
/// fleet's expert top-k from the artifact's compile-time ceiling
/// `expert_k_max` down to `min_k`, trading model quality for per-step
/// latency, and restores the full k once the queue drains.
///
/// The two watermarks make the policy hysteretic: degrade when queue
/// depth reaches `hi_wm` (or a deadline drop occurred since the last
/// evaluation — the queue is shedding promised work), restore only once
/// depth has fallen to `lo_wm` *and* no new deadline drops arrived, so
/// a queue oscillating between the watermarks never flaps k every
/// driver iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DegradeCfg {
    /// Floor the scheduler may degrade expert top-k to (≥ 1).
    pub min_k: usize,
    /// Queue depth at or above which k degrades to `min_k`.
    pub hi_wm: usize,
    /// Queue depth at or below which k restores to `expert_k_max`.
    pub lo_wm: usize,
}

impl DegradeCfg {
    /// Parse the `min_k:hi_wm:lo_wm` CLI form.
    pub fn parse(s: &str) -> Result<DegradeCfg> {
        let parts: Vec<&str> = s.split(':').collect();
        let err = || {
            Error::Config(format!(
                "bad --degrade-k {s:?} (expected min_k:hi_wm:lo_wm \
                 with min_k >= 1 and hi_wm > lo_wm)"
            ))
        };
        if parts.len() != 3 {
            return Err(err());
        }
        let nums: Vec<usize> = parts
            .iter()
            .map(|p| p.parse::<usize>().map_err(|_| err()))
            .collect::<Result<_>>()?;
        let (min_k, hi_wm, lo_wm) = (nums[0], nums[1], nums[2]);
        if min_k < 1 || hi_wm <= lo_wm {
            return Err(err());
        }
        Ok(DegradeCfg { min_k, hi_wm, lo_wm })
    }

    /// The `min_k:hi_wm:lo_wm` CLI form (journal/config echo).
    pub fn to_flag(self) -> String {
        format!("{}:{}:{}", self.min_k, self.hi_wm, self.lo_wm)
    }
}

/// One expert top-k transition decided by [`Scheduler::eval_degrade`].
/// The driver applies `to` to its engine backend; the journal already
/// recorded the decision (id-less event — not a request span).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KTransition {
    pub from: usize,
    pub to: usize,
    /// Queue depth at decision time.
    pub depth: usize,
    /// Deadline drops since the previous evaluation.
    pub drop_delta: u64,
}

/// Accept-rate floor: a decision window whose rate falls below this
/// steps the effective speculative K down by one (wasted draft work —
/// each rejected token cost a share of a verify dispatch plus a
/// possible rollback commit).
pub const SPEC_TUNE_LO: f64 = 0.4;
/// Accept-rate ceiling: a window above this steps K back up toward the
/// CLI `--speculate K` (the drafter is predicting well; longer drafts
/// amortize more dispatches).
pub const SPEC_TUNE_HI: f64 = 0.75;
/// Drafted tokens one autotune decision integrates over — windows
/// shorter than this carry too much sampling noise to act on.
pub const SPEC_TUNE_WINDOW: u64 = 64;

/// One effective-speculative-K transition decided by
/// [`Scheduler::eval_spec`].  The driver applies `to` to its backend
/// via [`crate::serving::EngineBackend::set_speculate`]; the decision
/// is already journaled (`spec_k_lower` / `spec_k_raise`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpecTransition {
    pub from: usize,
    pub to: usize,
    /// Accept rate of the decision window.
    pub accept_rate: f64,
    /// Drafted tokens the window integrated.
    pub drafted: u64,
}

/// Why an enqueue was refused (the HTTP layer maps this to a status).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rejection {
    /// The bounded queue is at capacity — backpressure, answer 429.
    QueueFull,
    /// The server is shutting down (the driver already drained the
    /// queue; accepting more would strand the request forever) — 503.
    ShuttingDown,
}

/// One queued request: the generation spec plus its event stream and
/// admission bookkeeping.
#[derive(Debug)]
pub struct QueuedRequest {
    pub id: u64,
    pub req: GenRequest,
    pub events: mpsc::Sender<StreamEvent>,
    pub enqueued_at: Instant,
    pub deadline: Option<Instant>,
}

/// Log-bucketed latency histogram: bucket `i` covers
/// `[2^i, 2^(i+1))` microseconds, which spans 1 µs .. ~18 min in 40
/// buckets.  Percentiles interpolate linearly within a bucket —
/// plenty for p50/p95/p99 serving reports.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum_s: f64,
    max_s: f64,
}

const HIST_BUCKETS: usize = 40;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: vec![0; HIST_BUCKETS],
            count: 0,
            sum_s: 0.0,
            max_s: 0.0,
        }
    }

    pub fn observe(&mut self, d: Duration) {
        self.observe_secs(d.as_secs_f64());
    }

    pub fn observe_secs(&mut self, secs: f64) {
        let us = (secs * 1e6).max(0.0);
        let bucket = if us < 1.0 {
            0
        } else {
            (us.log2() as usize).min(HIST_BUCKETS - 1)
        };
        self.counts[bucket] += 1;
        self.count += 1;
        self.sum_s += secs.max(0.0);
        self.max_s = self.max_s.max(secs);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_secs(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_s / self.count as f64
        }
    }

    pub fn max_secs(&self) -> f64 {
        self.max_s
    }

    /// Percentile (`p` in [0, 1]) in seconds, linearly interpolated
    /// within the containing bucket; 0 when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (p.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0);
        let mut seen = 0.0;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = seen + c as f64;
            if rank <= next {
                let lo = if i == 0 { 0.0 } else { (1u64 << i) as f64 };
                let hi = (1u64 << (i + 1)) as f64;
                let frac = (rank - seen) / c as f64;
                let us = lo + (hi - lo) * frac;
                // interpolation can overshoot the observed maximum
                // (the containing bucket's upper edge); cap there
                return (us / 1e6).min(self.max_s);
            }
            seen = next;
        }
        self.max_s
    }

    /// Summary as a JSON object (milliseconds, serving-report style).
    pub fn to_json(&self) -> Json {
        let ms = 1e3;
        json::obj(vec![
            ("count", json::num(self.count as f64)),
            ("mean_ms", json::num(self.mean_secs() * ms)),
            ("p50_ms", json::num(self.percentile(0.50) * ms)),
            ("p95_ms", json::num(self.percentile(0.95) * ms)),
            ("p99_ms", json::num(self.percentile(0.99) * ms)),
            ("p999_ms", json::num(self.percentile(0.999) * ms)),
            ("max_ms", json::num(self.max_s * ms)),
        ])
    }
}

/// Counters + histograms the scheduler maintains for `/metrics` and the
/// loadgen report.
#[derive(Debug, Default)]
pub struct SchedMetrics {
    pub enqueued: u64,
    pub rejected: u64,
    pub dropped_deadline: u64,
    pub dropped_shutdown: u64,
    /// requests whose client hung up (timeout/disconnect) before a lane
    /// took them — detected at take time, never reach the engine
    pub dropped_dead: u64,
    pub started: u64,
    pub completed: u64,
    pub tokens_streamed: u64,
    pub max_depth: usize,
    /// enqueue -> take (scheduler wait only)
    pub queue_wait: Histogram,
    /// enqueue -> final event observed by the frontend
    pub e2e_latency: Histogram,
}

/// Mutable adaptive-k state (behind the scheduler lock).
#[derive(Debug)]
struct DegradeState {
    /// Current expert top-k target the drivers should run at.
    target: usize,
    degrades: u64,
    restores: u64,
    /// `dropped_deadline` as of the previous [`Scheduler::eval_degrade`]
    /// — the delta is the drop *rate* signal.
    last_deadline_drops: u64,
}

/// Mutable speculative-K autotune state (behind the scheduler lock):
/// a rolling (drafted, accepted) window fed by the drivers'
/// [`crate::serving::EngineBackend::take_spec_feedback`] drains.
#[derive(Debug)]
struct SpecTuneState {
    /// Effective draft length drivers should run at (≤ the CLI K).
    target: usize,
    /// Drafted tokens accumulated since the last closed window.
    drafted: u64,
    accepted: u64,
    lowers: u64,
    raises: u64,
}

#[derive(Debug)]
struct Inner {
    queue: VecDeque<QueuedRequest>,
    next_id: u64,
    metrics: SchedMetrics,
    degrade: DegradeState,
    spec_tune: SpecTuneState,
    /// set by [`Scheduler::drain_shutdown`]; enqueues after it would
    /// never be consumed, so they are rejected under the same lock
    draining: bool,
}

/// Bounded, policy-ordered request queue shared between connection
/// threads (producers) and the engine-driver thread (consumer).
pub struct Scheduler {
    capacity: usize,
    policy: Policy,
    /// Engine prefill chunk width C: the shortest-prompt policy costs a
    /// request as ⌈prompt_len/C⌉ dispatches rather than raw tokens.
    /// Seeded from the manifest, then clamped down by every driver's
    /// *actual* engine chunk ([`Scheduler::observe_prefill_chunk`]) —
    /// an engine whose `prefill` program failed validation falls back
    /// to C = 1, and the scheduler must not keep costing prompts in
    /// chunks the engine doesn't have.
    prefill_chunk: AtomicUsize,
    /// Speculative-decode draft length K the fleet serves with (0 =
    /// off).  The shortest-prompt policy folds it into
    /// [`Scheduler::request_cost`]: a speculating engine spends verify
    /// (and worst-case rollback-commit) dispatches on decode, so a
    /// request's cost is no longer its prefill chunks alone.
    speculate: AtomicUsize,
    /// Time source for enqueue stamps, deadline arithmetic, and the
    /// freshness clamp (wall clock in production, simulated under the
    /// record/replay harness).
    clock: SharedClock,
    /// Adaptive expert top-k policy; `None` leaves k pinned at the
    /// artifact ceiling (fixed-k serving, and every non-MoE preset).
    degrade: Option<DegradeCfg>,
    /// Compile-time expert top-k ceiling from the artifact manifest
    /// (0 = unknown / non-MoE: adaptive k disabled, no k gauges).
    expert_k_max: AtomicUsize,
    /// Fleet-shared prefix cache: the shortest-prompt policy prices a
    /// prompt whose prefix is cached at its *residual* chunk count
    /// (side-effect-free [`PrefixCache::peek`] probes, so admission
    /// ordering never perturbs hit/miss counters or LRU order).
    /// `None` costs every prompt cold.
    prefix_cache: Mutex<Option<Arc<PrefixCache>>>,
    /// Decision recorder (the disabled no-op journal in production).
    journal: Arc<Journal>,
    /// Request-lifecycle span recorder (always-on in the server/fleet
    /// paths; a disabled no-op by default).  The scheduler records the
    /// `queued` stage and its own drop terminals; placement and token
    /// stages are recorded by the router/driver layers.
    telemetry: Arc<Telemetry>,
    inner: Mutex<Inner>,
    nonempty: Condvar,
}

impl Scheduler {
    pub fn new(capacity: usize, policy: Policy) -> Self {
        let clock = WallClock::shared();
        Scheduler {
            capacity: capacity.max(1),
            policy,
            prefill_chunk: AtomicUsize::new(1),
            speculate: AtomicUsize::new(0),
            degrade: None,
            expert_k_max: AtomicUsize::new(0),
            prefix_cache: Mutex::new(None),
            journal: Arc::new(Journal::disabled(clock.clone())),
            telemetry: Arc::new(Telemetry::disabled(clock.clone())),
            clock,
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                next_id: 0,
                metrics: SchedMetrics::default(),
                degrade: DegradeState {
                    target: 0,
                    degrades: 0,
                    restores: 0,
                    last_deadline_drops: 0,
                },
                spec_tune: SpecTuneState {
                    target: 0,
                    drafted: 0,
                    accepted: 0,
                    lowers: 0,
                    raises: 0,
                },
                draining: false,
            }),
            nonempty: Condvar::new(),
        }
    }

    /// Replace the scheduler's time source (deterministic harnesses).
    pub fn with_clock(mut self, clock: SharedClock) -> Self {
        self.clock = clock;
        self
    }

    /// Attach a recording decision journal.
    pub fn with_journal(mut self, journal: Arc<Journal>) -> Self {
        self.journal = journal;
        self
    }

    /// Attach a request-lifecycle telemetry recorder.  The scheduler
    /// records span starts (`queued`) and its own drop terminals
    /// (`drop_deadline`, `drop_dead`, `drop_shutdown`).
    pub fn with_telemetry(mut self, telemetry: Arc<Telemetry>) -> Self {
        self.telemetry = telemetry;
        self
    }

    /// The attached telemetry recorder (a disabled no-op unless
    /// [`Scheduler::with_telemetry`] wired one in).
    pub fn telemetry(&self) -> &Arc<Telemetry> {
        &self.telemetry
    }

    /// Cost prompts in prefill chunks of `c` tokens (the engine's
    /// dispatch granularity) for the shortest-prompt policy.
    pub fn with_prefill_chunk(self, c: usize) -> Self {
        self.prefill_chunk.store(c.max(1), Ordering::Relaxed);
        self
    }

    /// A driver reporting its engine's real chunk width.  Clamps the
    /// costing chunk *down* (min over the fleet): one engine on the
    /// single-token fallback makes token-granular costing the honest
    /// common denominator.
    pub fn observe_prefill_chunk(&self, c: usize) {
        self.prefill_chunk.fetch_min(c.max(1), Ordering::Relaxed);
    }

    /// Cost decode budgets as speculative verify rounds of up to `k`
    /// drafted tokens (the fleet's `--speculate K`; 0 leaves the
    /// shortest-prompt policy costing prompts only, the pre-speculation
    /// behavior).
    pub fn with_speculate(self, k: usize) -> Self {
        self.speculate.store(k, Ordering::Relaxed);
        // the autotune controller starts at the CLI ceiling (full
        // draft length until the live accept rate argues otherwise)
        self.inner.lock().unwrap().spec_tune.target = k;
        self
    }

    pub fn speculate(&self) -> usize {
        self.speculate.load(Ordering::Relaxed)
    }

    /// Cost cache-hit prompts at their residual chunk count (builder
    /// form of [`Scheduler::set_prefix_cache`]).
    pub fn with_prefix_cache(self, cache: Arc<PrefixCache>) -> Self {
        self.set_prefix_cache(cache);
        self
    }

    /// Attach the fleet-shared prefix cache after construction (the
    /// fleet arms its scheduler and every engine from the same `Arc`).
    pub fn set_prefix_cache(&self, cache: Arc<PrefixCache>) {
        *self.prefix_cache.lock().unwrap() = Some(cache);
    }

    /// Fold the per-window (drafted, accepted) speculative feedback a
    /// driver drained from its backend into the autotune window.
    pub fn observe_spec(&self, drafted: u64, accepted: u64) {
        if drafted == 0 {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.spec_tune.drafted += drafted;
        inner.spec_tune.accepted += accepted;
    }

    /// Effective speculative draft length drivers should run at: the
    /// CLI `--speculate K` adjusted by the accept-rate autotune (0 when
    /// the fleet isn't speculating at all).
    pub fn target_speculate(&self) -> usize {
        let k = self.speculate();
        if k == 0 {
            return 0;
        }
        self.inner.lock().unwrap().spec_tune.target.clamp(1, k)
    }

    /// Evaluate the speculative-K autotune hysteresis once (the driver
    /// calls this every loop iteration, after feeding
    /// [`Scheduler::observe_spec`]).  A decision closes only when the
    /// window holds at least [`SPEC_TUNE_WINDOW`] drafted tokens; its
    /// accept rate below [`SPEC_TUNE_LO`] steps the effective K down by
    /// one (floor 1), above [`SPEC_TUNE_HI`] steps it back up toward
    /// the CLI K, and the band between holds — so a borderline drafter
    /// never flaps K every iteration.  Returns the transition when the
    /// target changed; the decision is already journaled
    /// (`spec_k_lower` / `spec_k_raise`).
    pub fn eval_spec(&self) -> Option<SpecTransition> {
        let k_cli = self.speculate();
        if k_cli == 0 {
            return None;
        }
        let mut inner = self.inner.lock().unwrap();
        let st = &mut inner.spec_tune;
        if st.drafted < SPEC_TUNE_WINDOW {
            return None;
        }
        let drafted = st.drafted;
        let rate = st.accepted as f64 / st.drafted as f64;
        // the window is consumed by the decision either way (holds
        // included) — stale acceptance must not dilute the next one
        st.drafted = 0;
        st.accepted = 0;
        let from = st.target.clamp(1, k_cli);
        let to = if rate < SPEC_TUNE_LO {
            (from - 1).max(1)
        } else if rate > SPEC_TUNE_HI {
            (from + 1).min(k_cli)
        } else {
            from
        };
        if to == from {
            return None;
        }
        st.target = to;
        let event = if to < from {
            st.lowers += 1;
            "spec_k_lower"
        } else {
            st.raises += 1;
            "spec_k_raise"
        };
        drop(inner);
        self.journal.record(
            event,
            vec![
                ("from", json::num(from as f64)),
                ("to", json::num(to as f64)),
                ("accept_rate", json::num(rate)),
                ("drafted", json::num(drafted as f64)),
            ],
        );
        Some(SpecTransition { from, to, accept_rate: rate, drafted })
    }

    /// Enable adaptive expert top-k under load.  `k_max` is the
    /// artifact's compile-time ceiling (`expert_k_max` in the
    /// manifest); the policy degrades the fleet target to
    /// `cfg.min_k.min(k_max)` under pressure and restores it to `k_max`
    /// once drained.
    pub fn with_degrade_k(mut self, cfg: DegradeCfg, k_max: usize) -> Self {
        self.degrade = Some(cfg);
        self.observe_expert_k_max(k_max);
        self
    }

    /// A driver reporting its artifact's expert top-k ceiling.  Seeds
    /// the current target (full quality) and turns on the k gauges in
    /// [`Scheduler::metrics_json`]; heterogeneous fleets clamp to the
    /// smallest reported ceiling so one target fits every engine.
    pub fn observe_expert_k_max(&self, k_max: usize) {
        if k_max == 0 {
            return;
        }
        // CAS min-clamp: two drivers reporting concurrently must both
        // land (a plain load/min/store can lose the smaller ceiling)
        let _ = self.expert_k_max.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |prev| {
                if prev == 0 || k_max < prev {
                    Some(k_max)
                } else {
                    None
                }
            },
        );
        let k_max = self.expert_k_max.load(Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        if inner.degrade.target == 0 || inner.degrade.target > k_max {
            inner.degrade.target = k_max;
        }
    }

    /// The adaptive-k policy, if one was configured.
    pub fn degrade_cfg(&self) -> Option<DegradeCfg> {
        self.degrade
    }

    /// Current expert top-k target drivers should run at (`None` until
    /// a ceiling is known — non-MoE presets never get one).
    pub fn target_expert_k(&self) -> Option<usize> {
        match self.inner.lock().unwrap().degrade.target {
            0 => None,
            k => Some(k),
        }
    }

    /// Evaluate the adaptive-k hysteresis once (the engine driver calls
    /// this every loop iteration).  Returns the transition when the
    /// target changed — the caller applies `t.to` to its backend; the
    /// decision is already journaled (`k_degrade` / `k_restore`,
    /// id-less events that replay byte-identically but never join
    /// request spans).
    pub fn eval_degrade(&self) -> Option<KTransition> {
        let cfg = self.degrade?;
        let k_max = self.expert_k_max.load(Ordering::Relaxed);
        let min_k = cfg.min_k.min(k_max);
        if k_max == 0 || min_k == k_max {
            return None;
        }
        let mut inner = self.inner.lock().unwrap();
        let depth = inner.queue.len();
        let drops = inner.metrics.dropped_deadline;
        let drop_delta = drops - inner.degrade.last_deadline_drops;
        inner.degrade.last_deadline_drops = drops;
        let from = inner.degrade.target;
        let to = if from > min_k {
            // full (or partial) quality: degrade on pressure
            if depth >= cfg.hi_wm || drop_delta > 0 {
                min_k
            } else {
                from
            }
        } else {
            // degraded: restore only once genuinely drained
            if depth <= cfg.lo_wm && drop_delta == 0 {
                k_max
            } else {
                from
            }
        };
        if to == from {
            return None;
        }
        inner.degrade.target = to;
        let event = if to < from {
            inner.degrade.degrades += 1;
            "k_degrade"
        } else {
            inner.degrade.restores += 1;
            "k_restore"
        };
        drop(inner);
        self.journal.record(
            event,
            vec![
                ("from", json::num(from as f64)),
                ("to", json::num(to as f64)),
                ("depth", json::num(depth as f64)),
                ("drop_delta", json::num(drop_delta as f64)),
            ],
        );
        Some(KTransition { from, to, depth, drop_delta })
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn prefill_chunk(&self) -> usize {
        self.prefill_chunk.load(Ordering::Relaxed)
    }

    /// Admission cost of a prompt: prefill dispatches needed to ingest
    /// it (⌈len/C⌉; plain token count when C is 1).
    pub fn prompt_cost(&self, prompt_len: usize) -> usize {
        prompt_len.div_ceil(self.prefill_chunk())
    }

    /// Admission cost of a *specific* prompt, folding in the prefix
    /// cache when one is armed: a prompt whose longest cached prefix
    /// covers `h` tokens costs ⌈(len−h)/C⌉ + 1 (residual chunks plus
    /// the restore dispatch) instead of ⌈len/C⌉.  The probe is the
    /// side-effect-free [`PrefixCache::peek`], so costing a queue full
    /// of candidates touches neither hit/miss counters nor LRU order.
    pub fn prompt_cost_cached(&self, prompt: &[i32]) -> usize {
        if let Some(cache) = self.prefix_cache.lock().unwrap().as_ref() {
            let c = self.prefill_chunk();
            let hit = cache.peek(prompt, c);
            if hit > 0 {
                return (prompt.len() - hit).div_ceil(c) + 1;
            }
        }
        self.prompt_cost(prompt.len())
    }

    /// Dispatch cost of a whole request under the shortest-prompt
    /// policy.  Prefill chunks as in [`Scheduler::prompt_cost`]; on a
    /// speculating fleet (`--speculate K`) the decode budget adds its
    /// verify dispatches too — `max_new` tokens arrive in rounds of up
    /// to K+1, each charged a verify dispatch plus the worst-case
    /// rollback commit, so two requests with equal prompts but very
    /// different budgets no longer tie.  With speculation off the cost
    /// is the prompt alone, exactly the pre-speculation ordering.
    pub fn request_cost(&self, prompt_len: usize, max_new: usize) -> usize {
        let spec = self.speculate();
        let decode = if spec > 0 {
            2 * max_new.div_ceil(spec + 1)
        } else {
            0
        };
        self.prompt_cost(prompt_len) + decode
    }

    /// [`request_cost`](Self::request_cost) with the actual prompt
    /// tokens, so the prefix-cache residual discount applies — the
    /// form the shortest-prompt policy orders the queue by.
    pub fn request_cost_cached(
        &self,
        prompt: &[i32],
        max_new: usize,
    ) -> usize {
        let spec = self.speculate();
        let decode = if spec > 0 {
            2 * max_new.div_ceil(spec + 1)
        } else {
            0
        };
        self.prompt_cost_cached(prompt) + decode
    }

    /// Enqueue a request, or reject it synchronously when the queue is
    /// at capacity (the caller answers 429 — requests already running on
    /// lanes don't count against the queue bound).
    pub fn enqueue(
        &self,
        req: GenRequest,
        deadline: Option<Duration>,
        events: mpsc::Sender<StreamEvent>,
    ) -> std::result::Result<u64, Rejection> {
        let mut inner = self.inner.lock().unwrap();
        if inner.draining {
            return Err(Rejection::ShuttingDown);
        }
        if inner.queue.len() >= self.capacity {
            inner.metrics.rejected += 1;
            return Err(Rejection::QueueFull);
        }
        let now = self.clock.now();
        let id = inner.next_id;
        inner.next_id += 1;
        let prompt_len = req.prompt.len();
        inner.queue.push_back(QueuedRequest {
            id,
            req,
            events,
            enqueued_at: now,
            deadline: deadline.map(|d| now + d),
        });
        inner.metrics.enqueued += 1;
        let depth = inner.queue.len();
        inner.metrics.max_depth = inner.metrics.max_depth.max(depth);
        drop(inner);
        self.journal.record(
            "admit",
            vec![
                ("id", json::num(id as f64)),
                ("prompt_len", json::num(prompt_len as f64)),
            ],
        );
        self.telemetry.queued(id);
        self.nonempty.notify_all();
        Ok(id)
    }

    fn drop_expired(&self, inner: &mut Inner, now: Instant) {
        let expired: Vec<usize> = inner
            .queue
            .iter()
            .enumerate()
            .filter(|(_, q)| q.deadline.is_some_and(|d| d <= now))
            .map(|(i, _)| i)
            .collect();
        for i in expired.into_iter().rev() {
            let q = inner.queue.remove(i).unwrap();
            let _ = q.events.send(StreamEvent::Dropped(DropReason::Deadline));
            inner.metrics.dropped_deadline += 1;
            self.journal
                .record("drop_deadline", vec![("id", json::num(q.id as f64))]);
            self.telemetry.terminal(q.id, "drop_deadline");
        }
    }

    /// Drop expired-deadline requests now (deadline policy only).  The
    /// driver calls this every iteration — not just when a lane is
    /// free — so under full-lane saturation dead requests neither hold
    /// bounded-queue slots (causing spurious 429s) nor keep their
    /// clients waiting for a lane to free before learning they were
    /// dropped.
    pub fn expire(&self, now: Instant) {
        if self.policy != Policy::Deadline {
            return;
        }
        let now = self.freshen(now);
        let mut inner = self.inner.lock().unwrap();
        self.drop_expired(&mut inner, now);
    }

    /// Expiry must never be checked against a timestamp older than the
    /// wall clock: drivers capture `now` once per loop iteration, and a
    /// request whose deadline passes while the driver is blocked in
    /// `wait_for_work` (or inside a long device `pump`) would otherwise
    /// be *admitted* by the next `take_next(stale_now)` — completing a
    /// request the deadline policy promised to drop, and splitting the
    /// outcome between `deadline_drops` and completions depending on
    /// thread timing.  Callers may still pass a *future* instant
    /// (simulated time in tests); only the past is disallowed.  The
    /// clamp reads the scheduler's injected clock, so a simulated-time
    /// run is never polluted by the wall clock.
    fn freshen(&self, now: Instant) -> Instant {
        now.max(self.clock.now())
    }

    /// Pop the next request per policy, dropping expired-deadline
    /// requests first (deadline policy only; their event stream gets a
    /// terminal [`StreamEvent::Dropped`]).  Returns `None` when nothing
    /// is admissible.
    ///
    /// The [`StreamEvent::Admitted`] sent here doubles as a liveness
    /// probe: a request whose client already hung up (timeout or
    /// disconnect dropped the receiver) fails the send, is discarded
    /// without ever reaching the engine — no lane spends decode steps
    /// streaming into a closed channel — and the next candidate is
    /// taken instead.  The engine re-announces `Admitted` when the lane
    /// actually starts; receivers treat the duplicate as a refresh.
    pub fn take_next(&self, now: Instant) -> Option<QueuedRequest> {
        let now = self.freshen(now);
        let mut inner = self.inner.lock().unwrap();
        if self.policy == Policy::Deadline {
            self.drop_expired(&mut inner, now);
        }
        loop {
            let idx = match self.policy {
                Policy::Fifo => {
                    if inner.queue.is_empty() {
                        return None;
                    }
                    0
                }
                Policy::ShortestPrompt => {
                    let mut best: Option<(usize, usize)> = None;
                    for (i, q) in inner.queue.iter().enumerate() {
                        let cost = self.request_cost_cached(
                            &q.req.prompt,
                            q.req.max_new_tokens,
                        );
                        if best.is_none_or(|(_, b)| cost < b) {
                            best = Some((i, cost));
                        }
                    }
                    best?.0
                }
                Policy::Deadline => {
                    let mut best: Option<(usize, Option<Instant>)> = None;
                    for (i, q) in inner.queue.iter().enumerate() {
                        let better = match (&best, q.deadline) {
                            (None, _) => true,
                            (Some((_, None)), Some(_)) => true,
                            (Some((_, Some(b))), Some(d)) => d < *b,
                            _ => false,
                        };
                        if better {
                            best = Some((i, q.deadline));
                        }
                    }
                    best?.0
                }
            };
            let q = inner.queue.remove(idx).unwrap();
            if q.events.send(StreamEvent::Admitted).is_err() {
                inner.metrics.dropped_dead += 1;
                self.journal
                    .record("drop_dead", vec![("id", json::num(q.id as f64))]);
                self.telemetry.terminal(q.id, "drop_dead");
                continue;
            }
            let wait = now.saturating_duration_since(q.enqueued_at);
            inner.metrics.queue_wait.observe(wait);
            inner.metrics.started += 1;
            self.journal
                .record("take", vec![("id", json::num(q.id as f64))]);
            return Some(q);
        }
    }

    /// Block until the queue is non-empty or `timeout` elapses; returns
    /// whether work is available.  Driver idle-wait.
    pub fn wait_for_work(&self, timeout: Duration) -> bool {
        let inner = self.inner.lock().unwrap();
        if !inner.queue.is_empty() {
            return true;
        }
        let (inner, _) = self
            .nonempty
            .wait_timeout_while(inner, timeout, |i| i.queue.is_empty())
            .unwrap();
        !inner.queue.is_empty()
    }

    /// Drop every queued request with a terminal `Dropped(Shutdown)`
    /// event and refuse all further enqueues (server teardown) — an
    /// enqueue racing past the frontend's liveness check after this
    /// would otherwise sit unconsumed until its client times out.
    pub fn drain_shutdown(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.draining = true;
        while let Some(q) = inner.queue.pop_front() {
            let _ = q.events.send(StreamEvent::Dropped(DropReason::Shutdown));
            inner.metrics.dropped_shutdown += 1;
            self.journal
                .record("drop_shutdown", vec![("id", json::num(q.id as f64))]);
            self.telemetry.terminal(q.id, "drop_shutdown");
        }
    }

    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// Frontend callback when a request reached its terminal event:
    /// feeds the end-to-end latency histogram and token counters.
    pub fn observe_completion(&self, e2e: Duration, tokens: usize) {
        let mut inner = self.inner.lock().unwrap();
        inner.metrics.e2e_latency.observe(e2e);
        inner.metrics.completed += 1;
        inner.metrics.tokens_streamed += tokens as u64;
    }

    /// Scheduler section of the `/metrics` document.
    pub fn metrics_json(&self) -> Json {
        let inner = self.inner.lock().unwrap();
        let m = &inner.metrics;
        let mut fields = vec![
            ("policy", json::s(self.policy.as_str())),
            ("capacity", json::num(self.capacity as f64)),
            ("prefill_chunk", json::num(self.prefill_chunk() as f64)),
            ("depth", json::num(inner.queue.len() as f64)),
            ("max_depth", json::num(m.max_depth as f64)),
            ("enqueued", json::num(m.enqueued as f64)),
            ("rejected", json::num(m.rejected as f64)),
            ("dropped_deadline", json::num(m.dropped_deadline as f64)),
            ("dropped_shutdown", json::num(m.dropped_shutdown as f64)),
            ("dropped_dead", json::num(m.dropped_dead as f64)),
            ("started", json::num(m.started as f64)),
            ("completed", json::num(m.completed as f64)),
            ("tokens_streamed", json::num(m.tokens_streamed as f64)),
            ("queue_wait", m.queue_wait.to_json()),
            ("e2e_latency", m.e2e_latency.to_json()),
        ];
        // adaptive expert top-k gauges: only once a MoE ceiling is
        // known, so non-MoE fleets don't grow meaningless zero gauges
        // (scalar fields here render on /metrics as
        // `sigma_moe_scheduler_expert_k_*` Prometheus families)
        // speculation gauge: only on speculating fleets, mirroring the
        // engine's conditional spec_* export
        let spec = self.speculate();
        if spec > 0 {
            fields.push(("speculate", json::num(spec as f64)));
            let st = &inner.spec_tune;
            fields.push((
                "spec_k_target",
                json::num(st.target.clamp(1, spec) as f64),
            ));
            fields.push(("spec_k_lowers", json::num(st.lowers as f64)));
            fields.push(("spec_k_raises", json::num(st.raises as f64)));
        }
        let k_max = self.expert_k_max.load(Ordering::Relaxed);
        if k_max > 0 {
            let d = &inner.degrade;
            fields.push(("expert_k_max", json::num(k_max as f64)));
            fields.push(("expert_k_current", json::num(d.target as f64)));
            fields.push(("expert_k_degrades", json::num(d.degrades as f64)));
            fields.push(("expert_k_restores", json::num(d.restores as f64)));
        }
        json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::sampler::Sampler;

    fn req(prompt_len: usize) -> GenRequest {
        GenRequest {
            prompt: vec![1; prompt_len.max(1)],
            max_new_tokens: 4,
            sampler: Sampler::greedy(),
            ..Default::default()
        }
    }

    fn chan() -> (mpsc::Sender<StreamEvent>, mpsc::Receiver<StreamEvent>) {
        mpsc::channel()
    }

    /// Enqueue keeping the receiver alive (take_next's liveness probe
    /// discards requests whose receiver was dropped).
    fn enq(
        s: &Scheduler,
        prompt_len: usize,
        deadline: Option<Duration>,
        held: &mut Vec<mpsc::Receiver<StreamEvent>>,
    ) -> u64 {
        let (tx, rx) = chan();
        held.push(rx);
        s.enqueue(req(prompt_len), deadline, tx).unwrap()
    }

    #[test]
    fn fifo_pops_in_arrival_order() {
        let s = Scheduler::new(8, Policy::Fifo);
        let mut held = Vec::new();
        for n in [3, 1, 2] {
            enq(&s, n, None, &mut held);
        }
        let now = Instant::now();
        let lens: Vec<usize> = (0..3)
            .map(|_| s.take_next(now).unwrap().req.prompt.len())
            .collect();
        assert_eq!(lens, vec![3, 1, 2]);
        assert!(s.take_next(now).is_none());
    }

    #[test]
    fn shortest_prompt_first_with_fifo_tiebreak() {
        let s = Scheduler::new(8, Policy::ShortestPrompt);
        let mut held = Vec::new();
        let ids: Vec<u64> = [5, 2, 7, 2]
            .iter()
            .map(|&n| enq(&s, n, None, &mut held))
            .collect();
        let now = Instant::now();
        let order: Vec<u64> =
            (0..4).map(|_| s.take_next(now).unwrap().id).collect();
        // both len-2 prompts first, in arrival order; then 5; then 7
        assert_eq!(order, vec![ids[1], ids[3], ids[0], ids[2]]);
    }

    #[test]
    fn shortest_prompt_costs_in_prefill_chunks() {
        // C=8: 5- and 8-token prompts are both one chunk (FIFO between
        // them), 9 tokens is two chunks, 17 is three
        let s = Scheduler::new(8, Policy::ShortestPrompt)
            .with_prefill_chunk(8);
        assert_eq!(s.prompt_cost(5), 1);
        assert_eq!(s.prompt_cost(8), 1);
        assert_eq!(s.prompt_cost(9), 2);
        assert_eq!(s.prompt_cost(17), 3);
        let mut held = Vec::new();
        let ids: Vec<u64> = [17, 8, 9, 5]
            .iter()
            .map(|&n| enq(&s, n, None, &mut held))
            .collect();
        let now = Instant::now();
        let order: Vec<u64> =
            (0..4).map(|_| s.take_next(now).unwrap().id).collect();
        // one-chunk prompts first in arrival order (8 before 5 — same
        // cost, FIFO), then two chunks, then three
        assert_eq!(order, vec![ids[1], ids[3], ids[2], ids[0]]);
        let m = s.metrics_json();
        assert_eq!(
            m.get("prefill_chunk").unwrap().as_f64().unwrap(),
            8.0
        );
        // a driver on the single-token fallback clamps costing back to
        // token granularity; a wider report never raises it again
        s.observe_prefill_chunk(1);
        assert_eq!(s.prompt_cost(17), 17);
        s.observe_prefill_chunk(8);
        assert_eq!(s.prompt_cost(17), 17);
    }

    #[test]
    fn shortest_prompt_costs_cache_hits_at_the_residual() {
        // C=4, an 8-token prefix snapshot cached: a 20-token prompt
        // sharing it costs 3 residual chunks + 1 restore dispatch = 4,
        // beating an uncached 17-token prompt (5 chunks) that plain
        // length ordering would admit first
        let cache = PrefixCache::shared(1 << 20);
        let s = Scheduler::new(8, Policy::ShortestPrompt)
            .with_prefill_chunk(4)
            .with_prefix_cache(cache.clone());
        let prefix: Vec<i32> = (1..=8).collect();
        assert!(cache.insert_weighted(&prefix, Vec::new(), 1024));
        let mut long = prefix.clone();
        long.extend(9..=20);
        assert_eq!(s.prompt_cost(long.len()), 5);
        assert_eq!(s.prompt_cost_cached(&long), 4);
        // an uncached prompt of equal length stays at the cold cost
        let cold: Vec<i32> = (100..120).collect();
        assert_eq!(s.prompt_cost_cached(&cold), 5);
        let mk = |prompt: Vec<i32>| GenRequest {
            prompt,
            max_new_tokens: 4,
            sampler: Sampler::greedy(),
            ..Default::default()
        };
        let mut held = Vec::new();
        let (tx, rx) = chan();
        held.push(rx);
        let uncached =
            s.enqueue(mk((100..117).collect()), None, tx).unwrap();
        let (tx, rx) = chan();
        held.push(rx);
        let cached = s.enqueue(mk(long), None, tx).unwrap();
        let now = Instant::now();
        assert_eq!(s.take_next(now).unwrap().id, cached);
        assert_eq!(s.take_next(now).unwrap().id, uncached);
        // ordering probes are peek-only: no hit/miss counter movement
        assert_eq!(cache.hit_miss(), (0, 0));
    }

    #[test]
    fn shortest_prompt_costs_speculative_verify_dispatches() {
        // C=8, K=3: decode budgets are charged 2·⌈max_new/(K+1)⌉ verify
        // + worst-case commit dispatches, so a one-chunk prompt with a
        // huge budget loses to a two-chunk prompt with a tiny one
        let s = Scheduler::new(8, Policy::ShortestPrompt)
            .with_prefill_chunk(8)
            .with_speculate(3);
        assert_eq!(s.speculate(), 3);
        // prompt 8 (1 chunk) + 40 tokens → 1 + 2·10 = 21
        assert_eq!(s.request_cost(8, 40), 21);
        // prompt 9 (2 chunks) + 4 tokens → 2 + 2·1 = 4
        assert_eq!(s.request_cost(9, 4), 4);
        let mut held = Vec::new();
        let mk = |prompt_len: usize, max_new: usize| {
            let mut r = req(prompt_len);
            r.max_new_tokens = max_new;
            r
        };
        let (tx, rx) = chan();
        held.push(rx);
        let big_budget = s.enqueue(mk(8, 40), None, tx).unwrap();
        let (tx, rx) = chan();
        held.push(rx);
        let small_budget = s.enqueue(mk(9, 4), None, tx).unwrap();
        let now = Instant::now();
        assert_eq!(s.take_next(now).unwrap().id, small_budget);
        assert_eq!(s.take_next(now).unwrap().id, big_budget);
        // the gauge appears on /metrics only when speculating
        assert_eq!(
            s.metrics_json().get("speculate").unwrap().as_f64().unwrap(),
            3.0
        );
        let off = Scheduler::new(8, Policy::ShortestPrompt)
            .with_prefill_chunk(8);
        // speculation off: decode budgets cost nothing (pre-speculation
        // ordering preserved) and no gauge is exported
        assert_eq!(off.request_cost(8, 40), 1);
        assert!(off.metrics_json().opt("speculate").is_none());
    }

    #[test]
    fn bounded_queue_rejects_overflow() {
        let s = Scheduler::new(2, Policy::Fifo);
        let mut held = Vec::new();
        enq(&s, 1, None, &mut held);
        enq(&s, 1, None, &mut held);
        assert_eq!(
            s.enqueue(req(1), None, chan().0),
            Err(Rejection::QueueFull)
        );
        // freeing a slot re-opens admission
        assert!(s.take_next(Instant::now()).is_some());
        enq(&s, 1, None, &mut held);
        let m = s.metrics_json();
        assert_eq!(m.get("rejected").unwrap().as_f64().unwrap(), 1.0);
    }

    #[test]
    fn deadline_policy_drops_expired_and_orders_by_deadline() {
        let s = Scheduler::new(8, Policy::Deadline);
        let mut held = Vec::new();
        let (tx_expired, rx_expired) = chan();
        s.enqueue(req(1), Some(Duration::ZERO), tx_expired).unwrap();
        let far = enq(&s, 2, Some(Duration::from_secs(60)), &mut held);
        let near = enq(&s, 3, Some(Duration::from_secs(5)), &mut held);
        let none = enq(&s, 4, None, &mut held);
        // take after the first deadline passed
        let now = Instant::now() + Duration::from_millis(1);
        let order: Vec<u64> =
            (0..3).map(|_| s.take_next(now).unwrap().id).collect();
        assert_eq!(order, vec![near, far, none]);
        assert!(matches!(
            rx_expired.try_recv(),
            Ok(StreamEvent::Dropped(DropReason::Deadline))
        ));
        let m = s.metrics_json();
        assert_eq!(
            m.get("dropped_deadline").unwrap().as_f64().unwrap(),
            1.0
        );
    }

    #[test]
    fn take_skips_requests_whose_client_hung_up() {
        let s = Scheduler::new(8, Policy::Fifo);
        // first request's client is gone (receiver dropped)...
        s.enqueue(req(1), None, chan().0).unwrap();
        // ...second is live
        let (tx, rx) = chan();
        let live = s.enqueue(req(2), None, tx).unwrap();
        let taken = s.take_next(Instant::now()).unwrap();
        assert_eq!(taken.id, live);
        assert!(matches!(rx.try_recv(), Ok(StreamEvent::Admitted)));
        assert_eq!(s.depth(), 0);
        let m = s.metrics_json();
        assert_eq!(m.get("dropped_dead").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(m.get("started").unwrap().as_f64().unwrap(), 1.0);
    }

    #[test]
    fn expire_frees_queue_slots_without_a_take() {
        // lane-saturation shape: the driver never calls take_next, yet
        // expired requests must be dropped and their slots reopened
        let s = Scheduler::new(2, Policy::Deadline);
        let (tx, rx) = chan();
        s.enqueue(req(1), Some(Duration::ZERO), tx).unwrap();
        s.enqueue(req(2), Some(Duration::ZERO), chan().0).unwrap();
        assert_eq!(
            s.enqueue(req(3), None, chan().0),
            Err(Rejection::QueueFull)
        );
        s.expire(Instant::now() + Duration::from_millis(1));
        assert_eq!(s.depth(), 0);
        assert!(matches!(
            rx.try_recv(),
            Ok(StreamEvent::Dropped(DropReason::Deadline))
        ));
        assert!(s.enqueue(req(3), None, chan().0).is_ok());
        // expire is a no-op for other policies
        let f = Scheduler::new(2, Policy::Fifo);
        f.enqueue(req(1), Some(Duration::ZERO), chan().0).unwrap();
        f.expire(Instant::now() + Duration::from_millis(1));
        assert_eq!(f.depth(), 1);
    }

    #[test]
    fn stale_now_cannot_admit_an_expired_request() {
        // regression: the driver captures `now`, blocks in
        // wait_for_work / a long pump, and only then calls
        // take_next(now).  A request whose deadline passed inside that
        // window must be dropped (counted once in dropped_deadline) —
        // never admitted and later completed as well.
        let s = Scheduler::new(4, Policy::Deadline);
        let stale = Instant::now();
        let (tx, rx) = chan();
        s.enqueue(req(1), Some(Duration::from_millis(5)), tx).unwrap();
        assert!(s.wait_for_work(Duration::ZERO));
        std::thread::sleep(Duration::from_millis(20));
        // driver wakes up and uses the pre-wait timestamp
        assert!(s.take_next(stale).is_none());
        assert!(matches!(
            rx.try_recv(),
            Ok(StreamEvent::Dropped(DropReason::Deadline))
        ));
        // exactly one terminal outcome was recorded
        assert!(rx.try_recv().is_err());
        let m = s.metrics_json();
        assert_eq!(
            m.get("dropped_deadline").unwrap().as_f64().unwrap(),
            1.0
        );
        assert_eq!(m.get("started").unwrap().as_f64().unwrap(), 0.0);
        // same clamp covers expire()
        let (tx, rx) = chan();
        s.enqueue(req(1), Some(Duration::from_millis(5)), tx).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        s.expire(stale);
        assert!(matches!(
            rx.try_recv(),
            Ok(StreamEvent::Dropped(DropReason::Deadline))
        ));
        assert_eq!(s.depth(), 0);
    }

    #[test]
    fn non_deadline_policies_ignore_deadlines() {
        let s = Scheduler::new(8, Policy::Fifo);
        let (tx, rx) = chan();
        let id = s.enqueue(req(1), Some(Duration::ZERO), tx).unwrap();
        let now = Instant::now() + Duration::from_millis(1);
        assert_eq!(s.take_next(now).unwrap().id, id);
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn drain_shutdown_notifies_all_queued() {
        let s = Scheduler::new(8, Policy::Fifo);
        let rxs: Vec<_> = (0..3)
            .map(|_| {
                let (tx, rx) = chan();
                s.enqueue(req(1), None, tx).unwrap();
                rx
            })
            .collect();
        s.drain_shutdown();
        assert_eq!(s.depth(), 0);
        for rx in rxs {
            assert!(matches!(
                rx.try_recv(),
                Ok(StreamEvent::Dropped(DropReason::Shutdown))
            ));
        }
        // a racing enqueue after the drain must be refused, not stranded
        assert_eq!(
            s.enqueue(req(1), None, chan().0),
            Err(Rejection::ShuttingDown)
        );
    }

    #[test]
    fn wait_for_work_wakes_on_enqueue() {
        use std::sync::Arc;
        let s = Arc::new(Scheduler::new(8, Policy::Fifo));
        let s2 = s.clone();
        let t = std::thread::spawn(move || {
            s2.wait_for_work(Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(20));
        let (tx, _rx) = chan();
        s.enqueue(req(1), None, tx).unwrap();
        assert!(t.join().unwrap());
        // empty queue + short timeout -> false
        s.take_next(Instant::now()).unwrap();
        assert!(!s.wait_for_work(Duration::from_millis(5)));
    }

    #[test]
    fn histogram_percentiles_are_ordered_and_bracketed() {
        let mut h = Histogram::new();
        for ms in 1..=100u64 {
            h.observe(Duration::from_millis(ms));
        }
        let (p50, p95, p99, p999) = (
            h.percentile(0.5),
            h.percentile(0.95),
            h.percentile(0.99),
            h.percentile(0.999),
        );
        assert!(p50 > 0.0 && p50 <= p95 && p95 <= p99 && p99 <= p999);
        assert!(p999 <= h.max_secs() + 1e-9);
        // p50 of 1..=100ms must land within the right order of magnitude
        assert!((0.02..0.13).contains(&p50), "p50 {p50}");
        assert_eq!(h.count(), 100);
        let j = h.to_json();
        assert!(j.get("p95_ms").unwrap().as_f64().unwrap() >= 1.0);
        // p999 is part of the serialized summary and brackets p99..max
        let j999 = j.get("p999_ms").unwrap().as_f64().unwrap();
        assert!(j999 >= j.get("p99_ms").unwrap().as_f64().unwrap());
        assert!(j999 <= j.get("max_ms").unwrap().as_f64().unwrap() + 1e-6);
    }

    /// Property sweep: for any adversarial observation set, percentiles
    /// stay monotone in p and bracketed by [0, max] — including p999.
    #[test]
    fn histogram_percentile_monotonicity_property() {
        let cases: Vec<Vec<f64>> = vec![
            vec![0.000_001],
            vec![5.0; 17],
            (1..=1000).map(|i| i as f64 * 1e-4).collect(),
            (0..200).map(|i| 2f64.powi(i % 20) * 1e-6).collect(),
            vec![0.0, 0.0, 1e3],
        ];
        for (ci, obs) in cases.iter().enumerate() {
            let mut h = Histogram::new();
            for &s in obs {
                h.observe_secs(s);
            }
            let ps = [0.0, 0.5, 0.9, 0.95, 0.99, 0.999, 1.0];
            let vals: Vec<f64> =
                ps.iter().map(|&p| h.percentile(p)).collect();
            for w in vals.windows(2) {
                assert!(w[0] <= w[1] + 1e-12, "case {ci}: {vals:?}");
            }
            assert!(vals[ps.len() - 1] <= h.max_secs() + 1e-9);
            assert!(vals[0] >= 0.0);
        }
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.percentile(0.99), 0.0);
        assert_eq!(h.mean_secs(), 0.0);
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [Policy::Fifo, Policy::ShortestPrompt, Policy::Deadline] {
            assert_eq!(Policy::parse(p.as_str()).unwrap(), p);
        }
        assert!(Policy::parse("lifo").is_err());
    }

    #[test]
    fn degrade_cfg_parse_roundtrip_and_rejects_malformed() {
        let c = DegradeCfg::parse("1:8:2").unwrap();
        assert_eq!(c, DegradeCfg { min_k: 1, hi_wm: 8, lo_wm: 2 });
        assert_eq!(c.to_flag(), "1:8:2");
        for bad in ["", "1:2", "0:8:2", "1:2:2", "1:2:4", "a:8:2", "1:8:2:9"]
        {
            assert!(DegradeCfg::parse(bad).is_err(), "{bad:?} parsed");
        }
    }

    #[test]
    fn degrade_hysteresis_on_queue_depth() {
        let s = Scheduler::new(16, Policy::Fifo)
            .with_degrade_k(DegradeCfg::parse("1:3:1").unwrap(), 4);
        assert_eq!(s.target_expert_k(), Some(4));
        assert!(s.eval_degrade().is_none());
        let mut held = Vec::new();
        for _ in 0..3 {
            enq(&s, 1, None, &mut held);
        }
        let t = s.eval_degrade().unwrap();
        assert_eq!((t.from, t.to, t.depth), (4, 1, 3));
        assert_eq!(s.target_expert_k(), Some(1));
        // between the watermarks the degraded state holds — no flapping
        let now = Instant::now();
        s.take_next(now).unwrap();
        assert_eq!(s.depth(), 2);
        assert!(s.eval_degrade().is_none());
        assert_eq!(s.target_expert_k(), Some(1));
        // drained to lo_wm -> full quality restored
        s.take_next(now).unwrap();
        let t = s.eval_degrade().unwrap();
        assert_eq!((t.from, t.to), (1, 4));
        let m = s.metrics_json();
        for (key, want) in [
            ("expert_k_max", 4.0),
            ("expert_k_current", 4.0),
            ("expert_k_degrades", 1.0),
            ("expert_k_restores", 1.0),
        ] {
            assert_eq!(
                m.get(key).unwrap().as_f64().unwrap(),
                want,
                "{key}"
            );
        }
    }

    #[test]
    fn spec_autotune_hysteresis_on_accept_rate() {
        let s = Scheduler::new(8, Policy::Fifo).with_speculate(3);
        assert_eq!(s.target_speculate(), 3);
        // a sub-window of feedback decides nothing (and is retained)
        s.observe_spec(SPEC_TUNE_WINDOW / 2, 0);
        assert!(s.eval_spec().is_none());
        // the window fills with poor acceptance: K steps down by one
        s.observe_spec(SPEC_TUNE_WINDOW, 0);
        let t = s.eval_spec().unwrap();
        assert_eq!((t.from, t.to), (3, 2));
        assert!(t.accept_rate < SPEC_TUNE_LO);
        assert_eq!(s.target_speculate(), 2);
        // mid-band acceptance holds — the hysteresis band, no flapping
        s.observe_spec(SPEC_TUNE_WINDOW, SPEC_TUNE_WINDOW / 2);
        assert!(s.eval_spec().is_none());
        assert_eq!(s.target_speculate(), 2);
        // sustained high acceptance raises K back toward the CLI K...
        s.observe_spec(SPEC_TUNE_WINDOW, SPEC_TUNE_WINDOW);
        let t = s.eval_spec().unwrap();
        assert_eq!((t.from, t.to), (2, 3));
        // ...but never above it
        s.observe_spec(SPEC_TUNE_WINDOW, SPEC_TUNE_WINDOW);
        assert!(s.eval_spec().is_none());
        // and never below 1 on the way down
        for _ in 0..5 {
            s.observe_spec(SPEC_TUNE_WINDOW, 0);
            let _ = s.eval_spec();
        }
        assert_eq!(s.target_speculate(), 1);
        let m = s.metrics_json();
        assert_eq!(
            m.get("spec_k_target").unwrap().as_f64().unwrap(),
            1.0
        );
        assert!(
            m.get("spec_k_lowers").unwrap().as_f64().unwrap() >= 2.0
        );
        assert_eq!(
            m.get("spec_k_raises").unwrap().as_f64().unwrap(),
            1.0
        );
        // a non-speculating fleet has no controller and no gauges
        let off = Scheduler::new(8, Policy::Fifo);
        off.observe_spec(10 * SPEC_TUNE_WINDOW, 0);
        assert!(off.eval_spec().is_none());
        assert_eq!(off.target_speculate(), 0);
        assert!(off.metrics_json().opt("spec_k_target").is_none());
    }

    #[test]
    fn degrade_triggers_on_deadline_drops_then_restores_when_clean() {
        // hi_wm unreachable: only the deadline-drop delta can degrade
        let s = Scheduler::new(16, Policy::Deadline)
            .with_degrade_k(DegradeCfg::parse("2:100:0").unwrap(), 4);
        let (tx, _rx) = chan();
        s.enqueue(req(1), Some(Duration::ZERO), tx).unwrap();
        s.expire(Instant::now() + Duration::from_millis(1));
        let t = s.eval_degrade().unwrap();
        assert_eq!((t.from, t.to, t.drop_delta), (4, 2, 1));
        // queue empty and no new drops since: restore on the next eval
        let t = s.eval_degrade().unwrap();
        assert_eq!((t.from, t.to, t.drop_delta), (2, 4, 0));
    }

    #[test]
    fn no_adaptive_k_without_a_moe_ceiling() {
        // non-MoE preset: no ceiling reported, no k gauges, no policy
        let s = Scheduler::new(4, Policy::Fifo)
            .with_degrade_k(DegradeCfg::parse("1:2:0").unwrap(), 0);
        assert!(s.target_expert_k().is_none());
        assert!(s.eval_degrade().is_none());
        assert!(s.metrics_json().opt("expert_k_max").is_none());
        // a fleet ceiling clamps to the smallest engine's ceiling, and
        // a fixed-k scheduler still reports the gauges once known
        let f = Scheduler::new(4, Policy::Fifo);
        f.observe_expert_k_max(4);
        f.observe_expert_k_max(2);
        f.observe_expert_k_max(8);
        assert_eq!(f.target_expert_k(), Some(2));
        assert!(f.eval_degrade().is_none());
        let m = f.metrics_json();
        assert_eq!(
            m.get("expert_k_current").unwrap().as_f64().unwrap(),
            2.0
        );
    }

    #[test]
    fn queue_wait_observed_on_take() {
        let s = Scheduler::new(4, Policy::Fifo);
        let (tx, _rx) = chan();
        s.enqueue(req(1), None, tx).unwrap();
        std::thread::sleep(Duration::from_millis(2));
        s.take_next(Instant::now()).unwrap();
        let m = s.metrics_json();
        let wait = m.get("queue_wait").unwrap();
        assert_eq!(wait.get("count").unwrap().as_f64().unwrap(), 1.0);
        assert!(wait.get("p50_ms").unwrap().as_f64().unwrap() > 0.0);
    }
}
