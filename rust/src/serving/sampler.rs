//! Token sampling strategies over next-token logits.

use crate::rng::Rng;

/// Sampling configuration.
#[derive(Debug, Clone)]
pub struct Sampler {
    pub temperature: f32,
    /// 0 disables top-k filtering.
    pub top_k: usize,
    pub greedy: bool,
}

impl Default for Sampler {
    fn default() -> Self {
        Sampler { temperature: 1.0, top_k: 0, greedy: false }
    }
}

impl Sampler {
    pub fn greedy() -> Self {
        Sampler { greedy: true, ..Default::default() }
    }

    /// Sample a token id from raw logits.
    ///
    /// Returns `None` when *every* logit is non-finite — a fully
    /// poisoned lane.  Silently falling back to an argmax over NaNs
    /// used to stream token 0 as if healthy; the caller (the engine's
    /// NaN-containment path) must treat `None` as a poisoned lane and
    /// fail the request instead of emitting garbage.
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> Option<usize> {
        if self.greedy {
            // argmax over *finite* entries only: the raw `>` scan never
            // displaced a NaN at index 0, so a poisoned lane under
            // greedy=true deterministically emitted token 0
            return argmax_finite(logits);
        }
        let t = self.temperature.max(1e-4);
        // softmax with temperature over the (optionally top-k-filtered)
        // set.  Sampler settings come from the network
        // (/v1/completions) and logits from possibly-poisoned lanes, so
        // non-finite logits are excluded up front on every path: in the
        // weights they would turn the categorical total NaN
        // (deterministically emitting the last candidate), and in a
        // top-k sort NaN ranks above +inf and crowds out real tokens
        // (total_cmp, not partial_cmp().unwrap() — no panics on the
        // single engine-driver thread behind the whole server).
        let mut idx: Vec<usize> =
            (0..logits.len()).filter(|&i| logits[i].is_finite()).collect();
        if idx.is_empty() {
            return None;
        }
        if self.top_k > 0 && self.top_k < idx.len() {
            idx.sort_unstable_by(|&a, &b| logits[b].total_cmp(&logits[a]));
            idx.truncate(self.top_k);
        }
        let maxl = idx
            .iter()
            .map(|&i| logits[i])
            .fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f64> = idx
            .iter()
            .map(|&i| (((logits[i] - maxl) / t) as f64).exp())
            .collect();
        Some(idx[rng.categorical(&weights)])
    }
}

/// NaN-safe argmax: the maximum over *finite* entries (total_cmp, ties
/// to the lowest index, matching the old `>` scan on clean input), or
/// `None` when nothing is finite.
fn argmax_finite(xs: &[f32]) -> Option<usize> {
    xs.iter()
        .enumerate()
        .filter(|(_, v)| v.is_finite())
        .max_by(|(ia, a), (ib, b)| a.total_cmp(b).then(ib.cmp(ia)))
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let s = Sampler::greedy();
        let mut rng = Rng::new(0);
        assert_eq!(s.sample(&[0.1, 2.0, -1.0], &mut rng), Some(1));
        // exact ties resolve to the lowest index, like the old `>` scan
        assert_eq!(s.sample(&[2.0, 2.0, -1.0], &mut rng), Some(0));
    }

    #[test]
    fn greedy_skips_non_finite_logits() {
        // a NaN at index 0 used to win every comparison by default:
        // `x > xs[best]` is false for NaN on either side, so a poisoned
        // lane under greedy deterministically emitted token 0
        let s = Sampler::greedy();
        let mut rng = Rng::new(4);
        assert_eq!(s.sample(&[f32::NAN, 1.0, 0.5], &mut rng), Some(1));
        assert_eq!(
            s.sample(&[f32::INFINITY, 1.0, f32::NAN, 3.0], &mut rng),
            Some(3)
        );
        assert_eq!(
            s.sample(&[f32::NEG_INFINITY, -2.0, -1.0], &mut rng),
            Some(2)
        );
    }

    #[test]
    fn all_non_finite_signals_poisoned_lane() {
        // every strategy must report the poisoned lane instead of
        // streaming token 0 as if healthy
        let mut rng = Rng::new(5);
        let rows: [&[f32]; 3] = [
            &[f32::NAN, f32::NAN],
            &[f32::INFINITY, f32::NEG_INFINITY, f32::NAN],
            &[],
        ];
        for greedy in [true, false] {
            let s = Sampler { temperature: 1.0, top_k: 2, greedy };
            for row in rows {
                assert_eq!(s.sample(row, &mut rng), None);
            }
        }
    }

    #[test]
    fn low_temperature_concentrates() {
        let s = Sampler { temperature: 0.01, top_k: 0, greedy: false };
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            assert_eq!(s.sample(&[0.0, 5.0, 1.0], &mut rng), Some(1));
        }
    }

    #[test]
    fn top_k_filters_tail() {
        let s = Sampler { temperature: 1.0, top_k: 2, greedy: false };
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let t = s.sample(&[5.0, 4.0, -100.0, -100.0], &mut rng);
            assert!(t.unwrap() < 2);
        }
    }

    #[test]
    fn nan_logits_neither_panic_nor_crowd_out_finite_tokens() {
        let s = Sampler { temperature: 1.0, top_k: 2, greedy: false };
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            // NaNs sort above every finite logit in the total order, so
            // without filtering they would fill the whole top-2 set
            let t = s
                .sample(&[f32::NAN, 1.0, f32::NAN, 0.5], &mut rng)
                .unwrap();
            assert!(t == 1 || t == 3, "sampled NaN-logit token {t}");
        }
        // top_k disabled (the server default) takes a different path
        // and must also exclude the NaN entry from the weights
        let s0 = Sampler { temperature: 1.0, top_k: 0, greedy: false };
        for _ in 0..50 {
            let t = s0.sample(&[1.0, f32::NAN, 0.5], &mut rng).unwrap();
            assert!(t != 1, "sampled NaN-logit token");
        }
    }

    #[test]
    fn high_temperature_spreads() {
        let s = Sampler { temperature: 100.0, top_k: 0, greedy: false };
        let mut rng = Rng::new(3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            seen.insert(s.sample(&[1.0, 0.9, 0.8, 0.7], &mut rng));
        }
        assert!(seen.len() >= 3);
    }
}
