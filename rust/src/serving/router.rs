//! Multi-engine router: the serving stack's horizontal axis.
//!
//! A [`Fleet`] fronts N engine-driver threads — each owning an
//! independent, non-`Send` [`EngineBackend`] — with the single shared
//! admission [`Scheduler`] the HTTP layer already enqueues into.  A
//! placer thread moves requests from the scheduler onto per-engine
//! mailboxes according to a [`Placement`] policy, and watches per-engine
//! heartbeats + consecutive-error counters to take failed engines out of
//! rotation:
//!
//! * **Placement** — `least-loaded` (most free capacity wins),
//!   `round-robin` (rotate over engines with capacity), or `affinity`
//!   (a hash of the prompt prefix pins related requests to one engine,
//!   trading balance for state locality).
//! * **Health** — every driver iteration stores a heartbeat and
//!   publishes `free_lanes`; a driver that stops beating (wedged device)
//!   or crosses `error_threshold` consecutive `pump` failures is marked
//!   unhealthy and receives no new placements.  Quarantine is not
//!   permanent: the driver keeps beating and pumping, and after
//!   `readmit_after` consecutive clean pumps (with a fresh heartbeat)
//!   the placer returns the engine to rotation — a recovered engine
//!   serves again without a process restart.  A driver wedged inside a
//!   device call never beats, so it can never ride back in.
//! * **Failover** — an unhealthy engine's placed + in-flight requests
//!   are re-queued onto survivors *exactly once per failure* (the
//!   request registry is the single source of truth: ownership changes
//!   and terminal-event delivery happen under one lock, so a request
//!   can never complete twice).  Tokens already streamed to the client
//!   are suppressed on the replay attempt, keeping the client's stream
//!   continuous.  After `max_retries` failed placements the request is
//!   dropped with [`DropReason::EngineFailure`] (HTTP 503).
//! * **Metrics** — `/metrics` gains one row per engine plus fleet
//!   totals and a `router` section (failovers, re-queues, exhausted
//!   retries).
//!
//! Replay caveat: a failed-over request is re-generated from scratch on
//! the survivor.  Deterministic backends (greedy sampling, the mock)
//! reproduce the original stream exactly; stochastic sampling may
//! diverge from the already-streamed prefix — the suppressed-prefix
//! replay keeps the stream *continuous*, not bit-identical.

use std::collections::{BTreeMap, VecDeque};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::json::{self, Json};
use crate::serving::clock::{Clock, SharedClock, WallClock};
use crate::serving::engine::{DropReason, EngineBackend, GenRequest, StreamEvent};
use crate::serving::journal::Journal;
use crate::serving::prefix_cache::PrefixCache;
use crate::serving::scheduler::{DegradeCfg, Policy, QueuedRequest, Scheduler};
use crate::serving::server::{self, ServeState, ServerConfig};
use crate::serving::telemetry::Telemetry;

/// How the placer distributes admitted requests over healthy engines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// The engine with the most free capacity (free lanes minus
    /// already-placed mailbox depth) wins; ties go to the lowest id.
    LeastLoaded,
    /// Rotate over engines, skipping those without capacity.
    RoundRobin,
    /// Hash of the prompt prefix (first 8 tokens) picks the engine
    /// among the currently-healthy set: requests sharing a prompt
    /// prefix land together (state locality), even if that engine is
    /// momentarily busy.
    Affinity,
}

impl Placement {
    pub fn parse(s: &str) -> Result<Placement> {
        match s {
            "least-loaded" | "ll" => Ok(Placement::LeastLoaded),
            "round-robin" | "rr" => Ok(Placement::RoundRobin),
            "affinity" => Ok(Placement::Affinity),
            other => Err(Error::Config(format!(
                "unknown placement {other:?} \
                 (expected least-loaded | round-robin | affinity)"
            ))),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Placement::LeastLoaded => "least-loaded",
            Placement::RoundRobin => "round-robin",
            Placement::Affinity => "affinity",
        }
    }
}

/// Fleet-level configuration.
#[derive(Debug, Clone)]
pub struct RouterCfg {
    /// Number of engine-driver threads.
    pub engines: usize,
    pub placement: Placement,
    /// A driver that hasn't heartbeat for this long is considered
    /// wedged and taken out of rotation.  Must comfortably exceed the
    /// worst-case device step time.
    pub heartbeat_timeout: Duration,
    /// Consecutive `pump` errors before a driver declares itself
    /// unhealthy.
    pub error_threshold: u64,
    /// How many times a request may be re-placed after an engine
    /// failure before it is dropped with 503 `engine-failure`.
    pub max_retries: usize,
    /// Consecutive clean (error-free) pumps a quarantined engine must
    /// log before it rejoins the placement set.  A quarantined driver
    /// keeps beating and pumping its (drained) backend; once it proves
    /// itself for this many iterations — and is still heartbeating
    /// fresh — the placer re-admits it without a restart.  0 disables
    /// re-admission (quarantine is then permanent, the pre-readmission
    /// behavior).  An engine wedged *inside* a device call never beats,
    /// so it can never ride this back in.
    pub readmit_after: u64,
}

impl Default for RouterCfg {
    fn default() -> Self {
        RouterCfg {
            engines: 2,
            placement: Placement::LeastLoaded,
            heartbeat_timeout: Duration::from_secs(5),
            error_threshold: 3,
            max_retries: 1,
            readmit_after: 20,
        }
    }
}

/// Placer loop granularity when saturated (placement-latency bound).
const SPIN_TICK: Duration = Duration::from_millis(2);
/// Placer idle wait / health-check granularity.
const PLACER_TICK: Duration = Duration::from_millis(10);
/// Engine-driver idle wait.
const ENGINE_TICK: Duration = Duration::from_millis(10);
/// How often drivers republish backend stats for `/metrics`.
const PUBLISH_EVERY: Duration = Duration::from_millis(50);
/// `last_beat_ms` sentinel: the driver thread has not beaten yet
/// (backend still constructing) — staleness doesn't apply.
const NEVER_BEAT: u64 = u64::MAX;

/// Per-engine shared state (driver thread ⇄ placer ⇄ metrics).
struct EngineState {
    /// Request ids placed on this engine but not yet submitted to its
    /// backend.  Paired with `work` for the driver's idle wait.
    mailbox: Mutex<VecDeque<u64>>,
    work: Condvar,
    /// Published by the driver each iteration (admission capacity).
    free_lanes: AtomicUsize,
    healthy: AtomicBool,
    /// Milliseconds since fleet start of the driver's last loop
    /// iteration; [`NEVER_BEAT`] until the backend is constructed.
    last_beat_ms: AtomicU64,
    consec_errors: AtomicU64,
    /// Set once the placer has re-queued this engine's work after it
    /// went unhealthy (the requeue must happen exactly once per
    /// failure; cleared again on re-admission).
    drained: AtomicBool,
    /// Consecutive clean pumps while quarantined — the driver's
    /// evidence for re-admission; reset by any pump error.
    clean_beats: AtomicU64,
    /// Clean-pump streak currently required for re-admission: starts
    /// at `cfg.readmit_after` on first quarantine and doubles on every
    /// relapse (0 = not yet quarantined).  A drained backend's idle
    /// pumps are weak evidence — an engine that only fails under load
    /// would otherwise flap in and out of rotation at a constant rate,
    /// burning request retries forever; the exponential backoff bounds
    /// that to a geometrically decaying rate while leaving a genuinely
    /// recovered engine's first re-admission prompt.
    readmit_threshold: AtomicU64,
    /// The driver thread returned (cleanly or not).
    thread_done: AtomicBool,
    placements: AtomicU64,
    completions: AtomicU64,
    tokens_done: AtomicU64,
    /// Latest `backend.stats()` snapshot.
    stats: Mutex<BTreeMap<String, f64>>,
}

impl EngineState {
    fn new() -> Self {
        EngineState {
            mailbox: Mutex::new(VecDeque::new()),
            work: Condvar::new(),
            free_lanes: AtomicUsize::new(0),
            healthy: AtomicBool::new(true),
            last_beat_ms: AtomicU64::new(NEVER_BEAT),
            consec_errors: AtomicU64::new(0),
            drained: AtomicBool::new(false),
            clean_beats: AtomicU64::new(0),
            readmit_threshold: AtomicU64::new(0),
            thread_done: AtomicBool::new(false),
            placements: AtomicU64::new(0),
            completions: AtomicU64::new(0),
            tokens_done: AtomicU64::new(0),
            stats: Mutex::new(BTreeMap::new()),
        }
    }
}

/// One routed request: everything needed to relay its events, detect
/// its terminal outcome, and replay it on a survivor after a failure.
/// Ownership (`owner`) and terminal delivery are only ever mutated
/// under the registry lock — the exactly-once backbone.
struct Entry {
    req: GenRequest,
    frontend: mpsc::Sender<StreamEvent>,
    /// Engine currently responsible; `None` while waiting in the retry
    /// queue.
    owner: Option<usize>,
    /// The owning driver has submitted it to its backend (a placed but
    /// unsubmitted request doesn't consume a retry on failover).
    submitted: bool,
    /// Failed placements so far.
    attempts: usize,
    /// Tokens already forwarded to the client (suppress this many on a
    /// replay attempt so the client stream stays continuous).
    sent_tokens: usize,
    /// Remaining replay tokens to suppress.
    skip_tokens: usize,
    deadline: Option<Instant>,
}

/// The multi-engine router: shared admission scheduler, per-engine
/// mailboxes, request registry, and health/failover state.  Create it,
/// spawn one [`Fleet::run_engine`] thread per engine and one
/// [`Fleet::run_placer`] thread, then enqueue into [`Fleet::sched`] —
/// or use [`serve_fleet`] for the full HTTP frontend.
pub struct Fleet {
    cfg: RouterCfg,
    sched: Scheduler,
    engines: Vec<EngineState>,
    registry: Mutex<BTreeMap<u64, Entry>>,
    retry_queue: Mutex<VecDeque<u64>>,
    rr: AtomicUsize,
    started: Instant,
    /// Time source shared with the scheduler: wall clock in production,
    /// simulated under the deterministic record/replay harness.
    clock: SharedClock,
    /// Decision recorder (no-op in production; shared with the
    /// scheduler so the trace interleaves both layers' events).
    journal: Arc<Journal>,
    /// Request-lifecycle spans + per-stage latency histograms + expert
    /// utilization (always-on; shared with the scheduler, which records
    /// the `queued` stage and its own drop terminals).
    telemetry: Arc<Telemetry>,
    shutdown: Arc<AtomicBool>,
    /// Engines taken out of rotation (failure events).
    failovers: AtomicU64,
    /// Requests re-queued onto survivors.
    requeues: AtomicU64,
    /// Requests dropped with `engine-failure` after `max_retries`.
    retries_exhausted: AtomicU64,
    /// Deadline drops detected after admission (retry queue).
    dropped_deadline: AtomicU64,
    /// Quarantined engines returned to rotation after `readmit_after`
    /// consecutive clean pumps.
    readmissions: AtomicU64,
    /// Shared post-prefill snapshot cache (`--prefix-cache BYTES`):
    /// one cache for the whole fleet, so a prefix prefilled on any
    /// engine seeds cache-hit admissions on every engine.  `None` = off.
    prefix_cache: Option<Arc<PrefixCache>>,
}

impl Fleet {
    pub fn new(
        cfg: RouterCfg,
        queue_cap: usize,
        policy: Policy,
        shutdown: Arc<AtomicBool>,
    ) -> Self {
        Self::with_prefill_chunk(cfg, queue_cap, policy, shutdown, 1)
    }

    /// [`Fleet::new`] with the engines' prefill chunk width C so the
    /// shared scheduler costs prompts in ⌈len/C⌉ prefill dispatches.
    pub fn with_prefill_chunk(
        cfg: RouterCfg,
        queue_cap: usize,
        policy: Policy,
        shutdown: Arc<AtomicBool>,
        prefill_chunk: usize,
    ) -> Self {
        let clock = WallClock::shared();
        let journal = Arc::new(Journal::disabled(clock.clone()));
        Self::with_clock_journal(
            cfg,
            queue_cap,
            policy,
            shutdown,
            prefill_chunk,
            clock,
            journal,
        )
    }

    /// Full constructor: the deterministic record/replay and chaos
    /// harnesses inject a [`SimClock`](super::clock::SimClock) and a
    /// recording [`Journal`]; production uses the wall-clock/disabled
    /// defaults via [`Fleet::new`] / [`Fleet::with_prefill_chunk`].
    #[allow(clippy::too_many_arguments)]
    pub fn with_clock_journal(
        cfg: RouterCfg,
        queue_cap: usize,
        policy: Policy,
        shutdown: Arc<AtomicBool>,
        prefill_chunk: usize,
        clock: SharedClock,
        journal: Arc<Journal>,
    ) -> Self {
        let n = cfg.engines.max(1);
        let telemetry = Telemetry::new(clock.clone()).shared();
        Fleet {
            cfg,
            sched: Scheduler::new(queue_cap, policy)
                .with_prefill_chunk(prefill_chunk)
                .with_clock(clock.clone())
                .with_journal(journal.clone())
                .with_telemetry(telemetry.clone()),
            engines: (0..n).map(|_| EngineState::new()).collect(),
            registry: Mutex::new(BTreeMap::new()),
            retry_queue: Mutex::new(VecDeque::new()),
            rr: AtomicUsize::new(0),
            started: clock.now(),
            clock,
            journal,
            telemetry,
            shutdown,
            failovers: AtomicU64::new(0),
            requeues: AtomicU64::new(0),
            retries_exhausted: AtomicU64::new(0),
            dropped_deadline: AtomicU64::new(0),
            readmissions: AtomicU64::new(0),
            prefix_cache: None,
        }
    }

    pub fn n_engines(&self) -> usize {
        self.engines.len()
    }

    /// The shared admission scheduler (the HTTP layer enqueues here).
    pub fn sched(&self) -> &Scheduler {
        &self.sched
    }

    pub fn healthy_count(&self) -> usize {
        self.engines
            .iter()
            .filter(|e| e.healthy.load(Ordering::Relaxed))
            .count()
    }

    /// At least one engine can still make progress.
    pub fn alive(&self) -> bool {
        self.healthy_count() > 0
    }

    pub fn requeues(&self) -> u64 {
        self.requeues.load(Ordering::Relaxed)
    }

    pub fn failovers(&self) -> u64 {
        self.failovers.load(Ordering::Relaxed)
    }

    pub fn retries_exhausted(&self) -> u64 {
        self.retries_exhausted.load(Ordering::Relaxed)
    }

    pub fn readmissions(&self) -> u64 {
        self.readmissions.load(Ordering::Relaxed)
    }

    pub fn engine_placements(&self, id: usize) -> u64 {
        self.engines[id].placements.load(Ordering::Relaxed)
    }

    pub fn engine_completions(&self, id: usize) -> u64 {
        self.engines[id].completions.load(Ordering::Relaxed)
    }

    pub fn engine_healthy(&self, id: usize) -> bool {
        self.engines[id].healthy.load(Ordering::Relaxed)
    }

    /// The fleet's clock (the harness advances it between steps).
    pub fn clock(&self) -> &SharedClock {
        &self.clock
    }

    /// The fleet's decision journal.
    pub fn journal(&self) -> &Arc<Journal> {
        &self.journal
    }

    /// Enable adaptive expert-k degradation on the shared scheduler
    /// (see [`Scheduler::with_degrade_k`]).  Every engine driver applies
    /// the scheduler's current target each iteration, so the whole
    /// fleet degrades and restores together.
    pub fn with_degrade_k(mut self, cfg: DegradeCfg, k_max: usize) -> Self {
        self.sched = self.sched.with_degrade_k(cfg, k_max);
        self
    }

    /// Thread the CLI speculative draft length K into the shared
    /// scheduler: spf prices decode in verify dispatches, and the
    /// spec-K autotune hysteresis gets its ceiling/initial target.
    pub fn with_speculate(mut self, k: usize) -> Self {
        self.sched = self.sched.with_speculate(k);
        self
    }

    /// Arm the fleet-wide prefix cache: every driver hands its backend
    /// a clone at startup, and the shared scheduler prices cache-hit
    /// prompts at their residual (uncached) chunk count.
    pub fn with_prefix_cache(mut self, cache: Arc<PrefixCache>) -> Self {
        self.sched.set_prefix_cache(cache.clone());
        self.prefix_cache = Some(cache);
        self
    }

    /// The fleet-wide prefix cache, when armed.
    pub fn prefix_cache(&self) -> Option<&Arc<PrefixCache>> {
        self.prefix_cache.as_ref()
    }

    /// Replace the fleet's telemetry (ring size / sampling come from
    /// the server config; the shared scheduler is re-pointed too so
    /// both layers record into the same span registry).
    pub fn with_telemetry(mut self, telemetry: Arc<Telemetry>) -> Self {
        self.sched = self.sched.with_telemetry(telemetry.clone());
        self.telemetry = telemetry;
        self
    }

    /// The fleet's span/stage/expert telemetry.
    pub fn telemetry(&self) -> &Arc<Telemetry> {
        &self.telemetry
    }

    fn now_ms(&self) -> u64 {
        self.clock.now().duration_since(self.started).as_millis() as u64
    }

    /// FNV-1a over the prompt prefix — the session-affinity key.
    fn affinity_hash(prompt: &[i32]) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        for &t in prompt.iter().take(8) {
            h ^= t as u32 as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    /// Admission capacity of engine `i`: published free lanes minus
    /// placed-but-unsubmitted mailbox depth; 0 when unhealthy.
    fn capacity(&self, i: usize) -> usize {
        let e = &self.engines[i];
        if !e.healthy.load(Ordering::Relaxed) {
            return 0;
        }
        let pending = e.mailbox.lock().unwrap().len();
        e.free_lanes.load(Ordering::Relaxed).saturating_sub(pending)
    }

    fn total_capacity(&self) -> usize {
        (0..self.engines.len()).map(|i| self.capacity(i)).sum()
    }

    /// Affinity's early binding is allowed to queue ahead of the lanes,
    /// but only this deep per engine — beyond it, matching requests
    /// stay in the shared admission queue so 429 backpressure and
    /// deadline expiry keep working under overload.
    const AFFINITY_BACKLOG: usize = 8;

    /// How many more requests affinity placement may pin onto engine
    /// `i` right now: free lanes plus the bounded backlog, minus what
    /// is already placed.  0 when unhealthy.
    fn affinity_capacity(&self, i: usize) -> usize {
        let e = &self.engines[i];
        if !e.healthy.load(Ordering::Relaxed) {
            return 0;
        }
        let pending = e.mailbox.lock().unwrap().len();
        (e.free_lanes.load(Ordering::Relaxed) + Self::AFFINITY_BACKLOG)
            .saturating_sub(pending)
    }

    /// Pick a target engine for `prompt` per the placement policy, or
    /// `None` when nothing can take it right now.
    fn choose_engine(&self, prompt: &[i32]) -> Option<usize> {
        let n = self.engines.len();
        match self.cfg.placement {
            Placement::LeastLoaded => (0..n)
                .map(|i| (self.capacity(i), i))
                .filter(|&(c, _)| c > 0)
                // max_by_key returns the *last* max; key on (cap, -i)
                // via rev() is overkill — scan for the first max
                .fold(None, |best: Option<(usize, usize)>, (c, i)| {
                    match best {
                        Some((bc, _)) if bc >= c => best,
                        _ => Some((c, i)),
                    }
                })
                .map(|(_, i)| i),
            Placement::RoundRobin => {
                let start = self.rr.fetch_add(1, Ordering::Relaxed);
                (0..n)
                    .map(|k| (start + k) % n)
                    .find(|&i| self.capacity(i) > 0)
            }
            Placement::Affinity => {
                let healthy: Vec<usize> = (0..n)
                    .filter(|&i| {
                        self.engines[i].healthy.load(Ordering::Relaxed)
                    })
                    .collect();
                if healthy.is_empty() {
                    return None;
                }
                let h = Self::affinity_hash(prompt) as usize;
                let target = healthy[h % healthy.len()];
                // pinned engine's bounded backlog is full: the request
                // waits (in the shared queue / retry slot) rather than
                // piling unboundedly onto its mailbox
                (self.affinity_capacity(target) > 0).then_some(target)
            }
        }
    }

    /// Record a freshly-admitted request in the registry.
    fn register(&self, q: QueuedRequest, owner: Option<usize>) {
        let entry = Entry {
            req: q.req,
            frontend: q.events,
            owner,
            submitted: false,
            attempts: 0,
            sent_tokens: 0,
            skip_tokens: 0,
            deadline: q.deadline,
        };
        self.registry.lock().unwrap().insert(q.id, entry);
    }

    /// Push an (already-registered, owner-set) request id onto its
    /// engine's mailbox and wake the driver.
    fn dispatch(&self, id: u64, target: usize) {
        let e = &self.engines[target];
        e.mailbox.lock().unwrap().push_back(id);
        e.placements.fetch_add(1, Ordering::Relaxed);
        self.journal.record(
            "place",
            vec![
                ("id", json::num(id as f64)),
                ("engine", json::num(target as f64)),
            ],
        );
        self.telemetry.placed(id, Some(target));
        e.work.notify_all();
    }

    /// Re-place requests parked in the retry queue (failover survivors
    /// and affinity requests whose pinned engine was full).  One pass
    /// over the current contents; an unplaceable request rotates to
    /// the back instead of blocking the ones behind it, whose targets
    /// may have capacity.  Returns whether anything was dispatched.
    fn place_retries(&self, now: Instant) -> bool {
        let mut placed = false;
        let parked = self.retry_queue.lock().unwrap().len();
        for _ in 0..parked {
            let Some(id) = self.retry_queue.lock().unwrap().pop_front()
            else {
                break;
            };
            let prompt = {
                let mut reg = self.registry.lock().unwrap();
                let Some(e) = reg.get(&id) else { continue };
                if e.deadline.is_some_and(|d| d <= now) {
                    let e = reg.remove(&id).unwrap();
                    let _ = e
                        .frontend
                        .send(StreamEvent::Dropped(DropReason::Deadline));
                    self.dropped_deadline.fetch_add(1, Ordering::Relaxed);
                    self.journal.record(
                        "drop_deadline_post",
                        vec![("id", json::num(id as f64))],
                    );
                    self.telemetry.terminal(id, "drop_deadline_post");
                    continue;
                }
                e.req.prompt.clone()
            };
            match self.choose_engine(&prompt) {
                Some(target) => {
                    let still_there = {
                        let mut reg = self.registry.lock().unwrap();
                        match reg.get_mut(&id) {
                            Some(e) => {
                                e.owner = Some(target);
                                true
                            }
                            None => false,
                        }
                    };
                    if still_there {
                        self.dispatch(id, target);
                        placed = true;
                    }
                }
                None => {
                    // its target has no room right now; rotate so the
                    // requests behind it still get their shot
                    self.retry_queue.lock().unwrap().push_back(id);
                }
            }
        }
        placed
    }

    /// Move fresh work from the shared scheduler onto engine mailboxes.
    fn place_fresh(&self, now: Instant) -> bool {
        let mut placed = false;
        loop {
            let can_place = match self.cfg.placement {
                // affinity binds early, but only into bounded
                // per-engine backlogs.  A request whose pinned engine
                // is full parks in the retry queue (so requests bound
                // for *other* engines keep flowing), and once the
                // parked count hits the backlog bound, fresh taking
                // pauses — overload then backs up into the *shared*
                // queue where 429 backpressure and deadline expiry
                // apply
                Placement::Affinity => {
                    self.retry_queue.lock().unwrap().len()
                        < Self::AFFINITY_BACKLOG
                        && (0..self.engines.len())
                            .any(|i| self.affinity_capacity(i) > 0)
                }
                _ => self.total_capacity() > 0,
            };
            if !can_place {
                break;
            }
            let Some(q) = self.sched.take_next(now) else { break };
            let id = q.id;
            match self.choose_engine(&q.req.prompt) {
                Some(target) => {
                    self.register(q, Some(target));
                    self.dispatch(id, target);
                    placed = true;
                }
                None => {
                    // capacity raced away between the gate and the
                    // choice: hold the request in the retry queue (it
                    // consumes no attempt) until capacity returns
                    self.register(q, None);
                    self.retry_queue.lock().unwrap().push_back(id);
                    break;
                }
            }
        }
        placed
    }

    /// Mark engines that stopped heartbeating (wedged) or whose driver
    /// exited as unhealthy, re-queue each unhealthy engine's work
    /// exactly once — and return a quarantined engine to rotation once
    /// it has proven itself with `readmit_after` consecutive clean
    /// pumps while still heartbeating (its driver thread must be
    /// alive; re-admission re-arms the drain guard so a relapse
    /// re-queues exactly once again).
    fn health_check(&self, _now: Instant) {
        let timeout_ms = self.cfg.heartbeat_timeout.as_millis() as u64;
        let now_ms = self.now_ms();
        for i in 0..self.engines.len() {
            let e = &self.engines[i];
            if !e.healthy.load(Ordering::Relaxed)
                && self.cfg.readmit_after > 0
                && !e.thread_done.load(Ordering::Relaxed)
                && e.drained.load(Ordering::Relaxed)
                && e.clean_beats.load(Ordering::Relaxed)
                    >= e.readmit_threshold
                        .load(Ordering::Relaxed)
                        .max(self.cfg.readmit_after)
            {
                let beat = e.last_beat_ms.load(Ordering::Relaxed);
                let fresh = beat != NEVER_BEAT
                    && now_ms.saturating_sub(beat) <= timeout_ms;
                if fresh {
                    e.clean_beats.store(0, Ordering::Relaxed);
                    e.consec_errors.store(0, Ordering::Relaxed);
                    // re-arm the exactly-once drain guard *before*
                    // flipping healthy: a relapse after re-admission
                    // must re-queue this engine's work again
                    e.drained.store(false, Ordering::SeqCst);
                    e.healthy.store(true, Ordering::SeqCst);
                    self.readmissions.fetch_add(1, Ordering::Relaxed);
                    self.journal.record(
                        "readmit",
                        vec![("engine", json::num(i as f64))],
                    );
                }
            }
            if e.healthy.load(Ordering::Relaxed) {
                let beat = e.last_beat_ms.load(Ordering::Relaxed);
                // an engine that never beat is still constructing its
                // backend, and bundle loading can dwarf both a step
                // and the heartbeat timeout — so construction gets its
                // own generous grace (floored at 2 minutes).  But not
                // forever: a driver wedged *inside construction* must
                // also leave rotation, or affinity placement would pin
                // matching requests onto it until their timeouts.  A
                // slow loader quarantined here that *does* come up
                // rides back in through the clean-pump re-admission
                // path above.
                let stale = if beat == NEVER_BEAT {
                    now_ms > timeout_ms.saturating_mul(4).max(120_000)
                } else {
                    now_ms.saturating_sub(beat) > timeout_ms
                };
                if stale || e.thread_done.load(Ordering::Relaxed) {
                    e.healthy.store(false, Ordering::Relaxed);
                    let reason = if e.thread_done.load(Ordering::Relaxed) {
                        "thread_done"
                    } else {
                        "stale"
                    };
                    self.journal.record(
                        "quarantine",
                        vec![
                            ("engine", json::num(i as f64)),
                            ("reason", json::s(reason)),
                        ],
                    );
                }
            }
            if !e.healthy.load(Ordering::Relaxed)
                && !e.drained.swap(true, Ordering::SeqCst)
            {
                // each quarantine raises the clean-streak bar for the
                // next re-admission (exponential backoff against
                // fails-only-under-load flapping)
                let t = e.readmit_threshold.load(Ordering::Relaxed);
                e.readmit_threshold.store(
                    if t == 0 {
                        self.cfg.readmit_after
                    } else {
                        t.saturating_mul(2)
                    },
                    Ordering::Relaxed,
                );
                self.requeue_engine(i);
            }
        }
    }

    /// Take engine `dead` out of rotation: clear its mailbox and move
    /// every request it owns back through placement (or drop with 503
    /// once retries are exhausted).  Runs exactly once per failure
    /// (guarded by `drained`).
    fn requeue_engine(&self, dead: usize) {
        self.failovers.fetch_add(1, Ordering::Relaxed);
        self.engines[dead].mailbox.lock().unwrap().clear();
        let mut retry = Vec::new();
        let mut exhausted = Vec::new();
        {
            let mut reg = self.registry.lock().unwrap();
            for (id, e) in reg.iter_mut() {
                if e.owner != Some(dead) {
                    continue;
                }
                if e.submitted {
                    e.attempts += 1;
                }
                e.owner = None;
                e.submitted = false;
                e.skip_tokens = e.sent_tokens;
                if e.attempts > self.cfg.max_retries {
                    exhausted.push(*id);
                } else {
                    retry.push(*id);
                }
            }
            for id in &exhausted {
                if let Some(e) = reg.remove(id) {
                    let _ = e.frontend.send(StreamEvent::Dropped(
                        DropReason::EngineFailure,
                    ));
                }
            }
        }
        self.journal.record(
            "failover",
            vec![
                ("engine", json::num(dead as f64)),
                ("requeued", json::num(retry.len() as f64)),
                ("exhausted", json::num(exhausted.len() as f64)),
            ],
        );
        for id in &exhausted {
            self.journal.record(
                "retry_exhausted",
                vec![("id", json::num(*id as f64))],
            );
            self.telemetry.terminal(*id, "retry_exhausted");
        }
        self.retries_exhausted
            .fetch_add(exhausted.len() as u64, Ordering::Relaxed);
        if !retry.is_empty() {
            self.requeues
                .fetch_add(retry.len() as u64, Ordering::Relaxed);
            let mut q = self.retry_queue.lock().unwrap();
            for id in retry {
                self.journal
                    .record("retry", vec![("id", json::num(id as f64))]);
                q.push_back(id);
            }
        }
    }

    /// Drop everything queued or in flight (shutdown, or no healthy
    /// engine left).
    fn drain_all(&self, reason: DropReason) {
        let outcome = match reason {
            DropReason::Shutdown => "drop_shutdown",
            _ => "dropped",
        };
        if matches!(reason, DropReason::Shutdown) {
            self.sched.drain_shutdown();
        } else {
            let now = self.clock.now();
            while let Some(q) = self.sched.take_next(now) {
                let _ = q.events.send(StreamEvent::Dropped(reason));
                self.telemetry.terminal(q.id, outcome);
            }
        }
        let drained = std::mem::take(&mut *self.registry.lock().unwrap());
        for (id, e) in drained {
            let _ = e.frontend.send(StreamEvent::Dropped(reason));
            self.telemetry.terminal(id, outcome);
        }
        self.retry_queue.lock().unwrap().clear();
        for e in &self.engines {
            e.work.notify_all();
        }
    }

    /// One placer iteration at `now`: expire deadlines, watch health,
    /// place retries then fresh work.  Returns whether anything was
    /// dispatched.  [`Fleet::run_placer`] loops over this with real
    /// idle waits; the deterministic harness calls it directly between
    /// simulated-clock advances, so the placement decision sequence is
    /// an exact function of the schedule.
    pub fn placer_step(&self, now: Instant) -> bool {
        self.sched.expire(now);
        // re-evaluate the adaptive expert-k hysteresis exactly once per
        // placer iteration (the single sequencing point shared by all
        // engines), so k-transitions are journaled in one total order
        // and replay deterministically; drivers pick the target up on
        // their next step
        self.sched.eval_degrade();
        // same sequencing point for the speculative-K autotune: the
        // accept-rate window the drivers feed is evaluated once here,
        // so spec_k_lower/raise transitions journal in one total order
        self.sched.eval_spec();
        self.health_check(now);
        if self.healthy_count() == 0 {
            // nothing can ever run; fail pending work fast (new
            // arrivals are rejected up front via `alive()`)
            self.drain_all(DropReason::EngineFailure);
            return false;
        }
        self.place_retries(now) | self.place_fresh(now)
    }

    /// The placer loop: expire deadlines, watch health, place retries
    /// then fresh work, idle briefly.  Returns at shutdown after
    /// draining everything still queued.
    pub fn run_placer(&self) {
        loop {
            if self.shutdown.load(Ordering::Relaxed) {
                self.drain_all(DropReason::Shutdown);
                return;
            }
            let now = self.clock.now();
            let placed = self.placer_step(now);
            if self.healthy_count() == 0 {
                self.clock.sleep(PLACER_TICK);
                continue;
            }
            if !placed {
                if self.sched.depth() == 0 {
                    self.sched.wait_for_work(PLACER_TICK);
                } else {
                    // work is queued but no engine has capacity —
                    // bounded nap instead of a hot spin
                    self.clock.sleep(SPIN_TICK);
                }
            }
        }
    }

    fn beat(&self, id: usize, backend: &dyn EngineBackend) {
        let e = &self.engines[id];
        let free = backend.free_lanes();
        e.last_beat_ms.store(self.now_ms(), Ordering::Relaxed);
        e.free_lanes.store(free, Ordering::Relaxed);
        self.journal.record(
            "beat",
            vec![
                ("engine", json::num(id as f64)),
                ("free", json::num(free as f64)),
            ],
        );
    }

    fn publish(&self, id: usize, backend: &mut dyn EngineBackend) {
        let mut stats = backend.stats();
        stats.insert("free_lanes".into(), backend.free_lanes() as f64);
        *self.engines[id].stats.lock().unwrap() = stats;
        // drain the backend's per-layer expert-selection accumulator
        // into the fleet-wide utilization aggregate (None: the backend
        // cannot observe routing — dense artifact or pre-counts MoE)
        match backend.take_expert_counts() {
            Some(counts) => {
                self.telemetry.record_expert_counts(id, &counts)
            }
            None => self.telemetry.note_expert_stats_unavailable(),
        }
    }

    /// Relay one in-flight request's events from the backend channel to
    /// the frontend, exactly once, suppressing replayed tokens.
    /// Returns whether the driver should keep polling this receiver.
    fn relay(
        &self,
        engine: usize,
        rid: u64,
        rx: &mpsc::Receiver<StreamEvent>,
    ) -> bool {
        loop {
            match rx.try_recv() {
                Ok(ev) => {
                    let mut reg = self.registry.lock().unwrap();
                    let Some(e) = reg.get_mut(&rid) else { return false };
                    if e.owner != Some(engine) {
                        // failed over to a survivor; this attempt's
                        // events are dead
                        return false;
                    }
                    match ev {
                        StreamEvent::Admitted => {
                            // admission into a lane is where prompt
                            // ingestion (prefill) begins — every
                            // attempt marks its own segment
                            self.telemetry.prefill_started(rid);
                            // only the first attempt's admission is the
                            // client's: a replay's Admitted would emit
                            // a second "admitted" stream event mid-
                            // token-stream and overwrite queue_ms with
                            // failover-inflated time
                            if e.attempts == 0 {
                                let _ =
                                    e.frontend.send(StreamEvent::Admitted);
                            }
                        }
                        StreamEvent::Token(t) => {
                            if e.skip_tokens > 0 {
                                e.skip_tokens -= 1;
                            } else {
                                e.sent_tokens += 1;
                                self.telemetry.token(rid);
                                let _ =
                                    e.frontend.send(StreamEvent::Token(t));
                            }
                        }
                        StreamEvent::Done(res) => {
                            let e = reg.remove(&rid).unwrap();
                            let st = &self.engines[engine];
                            st.completions.fetch_add(1, Ordering::Relaxed);
                            st.tokens_done.fetch_add(
                                res.tokens.len() as u64,
                                Ordering::Relaxed,
                            );
                            self.journal.record(
                                "done",
                                vec![
                                    ("id", json::num(rid as f64)),
                                    ("engine", json::num(engine as f64)),
                                    (
                                        "tokens",
                                        json::num(res.tokens.len() as f64),
                                    ),
                                ],
                            );
                            self.telemetry.terminal(rid, "done");
                            let _ =
                                e.frontend.send(StreamEvent::Done(res));
                            return false;
                        }
                        StreamEvent::Dropped(r) => {
                            let e = reg.remove(&rid).unwrap();
                            self.journal.record(
                                "dropped",
                                vec![
                                    ("id", json::num(rid as f64)),
                                    ("engine", json::num(engine as f64)),
                                ],
                            );
                            self.telemetry.terminal(rid, "dropped");
                            let _ =
                                e.frontend.send(StreamEvent::Dropped(r));
                            return false;
                        }
                    }
                }
                Err(mpsc::TryRecvError::Empty) => return true,
                // backend dropped the sender without a terminal event
                // (engine dying mid-request): the health path will
                // re-queue the entry — stop polling
                Err(mpsc::TryRecvError::Disconnected) => return false,
            }
        }
    }

    /// The engine-driver loop: submit placed work, pump the backend,
    /// relay events, heartbeat, publish stats.  Call from a dedicated
    /// thread owning `backend`; returns at shutdown.  A driver whose
    /// engine is quarantined keeps beating and pumping its (drained)
    /// backend — the consecutive-clean-pump streak it logs is what the
    /// placer's health check uses to re-admit it (`readmit_after`);
    /// with re-admission disabled it idles in quarantine until
    /// shutdown.
    /// One driver iteration: heartbeat, submit placed work, pump the
    /// backend once, relay events.  Returns the backend's remaining
    /// busy-lane count (inflight length on a pump error).  Extracted
    /// from [`Fleet::run_engine`] so the deterministic harness can
    /// interleave engine iterations with placer iterations on a
    /// simulated clock, one step at a time.
    pub fn engine_step(
        &self,
        id: usize,
        backend: &mut dyn EngineBackend,
        inflight: &mut Vec<(u64, mpsc::Receiver<StreamEvent>)>,
        result: &mut Result<()>,
    ) -> usize {
        let me = &self.engines[id];
        self.beat(id, backend);
        // apply the scheduler's current adaptive expert-k target (set
        // by the placer's hysteresis pass).  Applying the *target*
        // rather than reacting to transitions keeps late-started or
        // re-admitted drivers consistent with the fleet; the backend
        // only re-uploads on change, so this is idempotent and cheap.
        if let Some(k) = self.sched.target_expert_k() {
            backend.set_expert_k(k);
        }
        // speculative-K autotune: feed this backend's accept-rate
        // deltas into the shared window and run at the fleet target
        // (the placer evaluates the hysteresis; target-not-transition
        // keeps late-started drivers consistent)
        let (drafted, accepted) = backend.take_spec_feedback();
        self.sched.observe_spec(drafted, accepted);
        let spec = self.sched.target_speculate();
        if spec > 0 {
            backend.set_speculate(spec);
        }
        // submit placed work (ownership re-checked under the
        // registry lock: a request re-placed since its mailbox
        // entry was written must not run here too)
        loop {
            let rid = me.mailbox.lock().unwrap().pop_front();
            let Some(rid) = rid else { break };
            let req = {
                let mut reg = self.registry.lock().unwrap();
                match reg.get_mut(&rid) {
                    Some(e) if e.owner == Some(id) => {
                        e.submitted = true;
                        Some(e.req.clone())
                    }
                    _ => None,
                }
            };
            if let Some(req) = req {
                let (tx, rx) = mpsc::channel();
                backend.submit_streaming(req, tx);
                inflight.push((rid, rx));
            }
        }
        // re-publish capacity now that the mailbox is drained into
        // the backend: the placer must not read an empty mailbox
        // against the pre-submit free_lanes and overplace into the
        // backend's internal FIFO (where policy ordering and
        // deadline expiry no longer apply)
        me.free_lanes.store(backend.free_lanes(), Ordering::Relaxed);
        let remaining = match backend.pump() {
            Ok(n) => {
                me.consec_errors.store(0, Ordering::Relaxed);
                if n > 0 {
                    self.journal.record(
                        "pump",
                        vec![
                            ("engine", json::num(id as f64)),
                            ("busy", json::num(n as f64)),
                        ],
                    );
                }
                if me.healthy.load(Ordering::Relaxed) {
                    // a re-admitted engine serving again must not
                    // report its stale quarantine error at
                    // shutdown as if it had died
                    if result.is_err() {
                        *result = Ok(());
                    }
                } else if n == 0 {
                    // quarantined, pumping cleanly, AND fully
                    // drained: build the streak the placer
                    // re-admits on
                    me.clean_beats.fetch_add(1, Ordering::Relaxed);
                } else {
                    // still draining pre-quarantine lanes.
                    // Their requests were already re-placed
                    // elsewhere (or parked for retry) at
                    // requeue time; re-admitting before the
                    // backend is empty could place one of
                    // them HERE a second time while its first
                    // attempt still runs on a lane — two
                    // generations interleaving into one
                    // client stream.  Not clean evidence.
                    me.clean_beats.store(0, Ordering::Relaxed);
                }
                n
            }
            Err(err) => {
                me.clean_beats.store(0, Ordering::Relaxed);
                let n =
                    me.consec_errors.fetch_add(1, Ordering::Relaxed) + 1;
                self.journal.record(
                    "pump_err",
                    vec![("engine", json::num(id as f64))],
                );
                if !me.healthy.load(Ordering::Relaxed) {
                    // already quarantined: back off and keep
                    // probing; the clean streak restarts from zero
                    self.clock.sleep(ENGINE_TICK);
                } else if n >= self.cfg.error_threshold {
                    me.healthy.store(false, Ordering::Relaxed);
                    self.journal.record(
                        "quarantine",
                        vec![
                            ("engine", json::num(id as f64)),
                            ("reason", json::s("errors")),
                        ],
                    );
                    *result = Err(err);
                } else {
                    // transient? brief backoff, then retry
                    self.clock.sleep(Duration::from_millis(1));
                }
                inflight.len()
            }
        };
        inflight.retain(|(rid, rx)| self.relay(id, *rid, rx));
        remaining
    }

    pub fn run_engine(
        &self,
        id: usize,
        backend: &mut dyn EngineBackend,
    ) -> Result<()> {
        let me = &self.engines[id];
        let mut inflight: Vec<(u64, mpsc::Receiver<StreamEvent>)> =
            Vec::new();
        let mut last_publish = self.clock.now();
        // clamp the shared scheduler's prompt costing down to this
        // engine's real chunk width (1 after a prefill fallback)
        self.sched.observe_prefill_chunk(backend.prefill_chunk());
        // a heterogeneous fleet degrades to the *tightest* ceiling:
        // the scheduler min-clamps across engines, so a target k is
        // always dispatchable everywhere
        if let Some(k) = backend.expert_k_max() {
            self.sched.observe_expert_k_max(k);
        }
        // arm the fleet-wide prefix cache (Engine no-ops when the
        // artifact lacks the snapshot/restore programs)
        if let Some(cache) = self.prefix_cache.clone() {
            backend.set_prefix_cache(cache);
        }
        self.publish(id, backend);
        let mut result = Ok(());
        loop {
            if self.shutdown.load(Ordering::Relaxed) {
                break;
            }
            let remaining =
                self.engine_step(id, backend, &mut inflight, &mut result);
            let now = self.clock.now();
            if now.duration_since(last_publish) >= PUBLISH_EVERY {
                self.publish(id, backend);
                last_publish = now;
            }
            if remaining == 0 && inflight.is_empty() {
                let mb = me.mailbox.lock().unwrap();
                if mb.is_empty() && !self.shutdown.load(Ordering::Relaxed) {
                    let _ = me.work.wait_timeout(mb, ENGINE_TICK).unwrap();
                }
            }
        }
        self.publish(id, backend);
        me.healthy.store(false, Ordering::Relaxed);
        me.thread_done.store(true, Ordering::SeqCst);
        result
    }

    /// Mark an engine's driver thread as gone (wrapper for threads that
    /// fail before reaching [`Fleet::run_engine`], e.g. backend
    /// construction errors).
    pub fn engine_exited(&self, id: usize) {
        let e = &self.engines[id];
        e.healthy.store(false, Ordering::Relaxed);
        e.thread_done.store(true, Ordering::SeqCst);
    }

    /// The router + per-engine sections of the `/metrics` document:
    /// `{"engine": <summed totals>, "engines": [rows...],
    /// "router": {...}}`.
    pub fn fleet_json(&self) -> Json {
        let mut totals: BTreeMap<String, f64> = BTreeMap::new();
        let mut rows = Vec::with_capacity(self.engines.len());
        for (i, e) in self.engines.iter().enumerate() {
            let stats = e.stats.lock().unwrap().clone();
            for (k, v) in &stats {
                // fleet totals sum counters and capacity gauges; a
                // summed mean (occupancy) would read N-x inflated next
                // to the single-engine metric of the same name — those
                // stay per-row only
                if k.starts_with("mean_") {
                    continue;
                }
                *totals.entry(k.clone()).or_insert(0.0) += *v;
            }
            let stats_json = Json::Obj(
                stats
                    .iter()
                    .map(|(k, v)| (k.clone(), json::num(*v)))
                    .collect(),
            );
            rows.push(json::obj(vec![
                ("id", json::num(i as f64)),
                (
                    "healthy",
                    Json::Bool(e.healthy.load(Ordering::Relaxed)),
                ),
                (
                    "placements",
                    json::num(e.placements.load(Ordering::Relaxed) as f64),
                ),
                (
                    "completions",
                    json::num(
                        e.completions.load(Ordering::Relaxed) as f64
                    ),
                ),
                (
                    "tokens_done",
                    json::num(
                        e.tokens_done.load(Ordering::Relaxed) as f64
                    ),
                ),
                (
                    "consec_errors",
                    json::num(
                        e.consec_errors.load(Ordering::Relaxed) as f64
                    ),
                ),
                (
                    "free_lanes",
                    json::num(e.free_lanes.load(Ordering::Relaxed) as f64),
                ),
                (
                    "mailbox_depth",
                    json::num(e.mailbox.lock().unwrap().len() as f64),
                ),
                ("stats", stats_json),
            ]));
        }
        let engine_totals = Json::Obj(
            totals
                .iter()
                .map(|(k, v)| (k.clone(), json::num(*v)))
                .collect(),
        );
        let mut doc = vec![
            ("engine", engine_totals),
            ("engines", json::arr(rows)),
            ("experts", self.telemetry.experts_json()),
            ("stages", self.telemetry.stages_json()),
        ];
        if let Some(cache) = &self.prefix_cache {
            doc.push(("prefix_cache", cache.metrics_json()));
        }
        doc.push((
            "journal",
            json::obj(vec![
                (
                    "enabled",
                    Json::Bool(self.journal.is_enabled()),
                ),
                (
                    "events_recorded",
                    json::num(self.journal.total_recorded() as f64),
                ),
                (
                    "dropped_events",
                    json::num(self.journal.dropped_events() as f64),
                ),
                (
                    "truncated",
                    Json::Bool(self.journal.dropped_events() > 0),
                ),
            ]),
        ));
        doc.push((
            "router",
            json::obj(vec![
                (
                    "placement",
                    json::s(self.cfg.placement.as_str()),
                ),
                (
                    "engines",
                    json::num(self.engines.len() as f64),
                ),
                (
                    "healthy_engines",
                    json::num(self.healthy_count() as f64),
                ),
                (
                    "failovers",
                    json::num(
                        self.failovers.load(Ordering::Relaxed) as f64
                    ),
                ),
                (
                    "requeues",
                    json::num(
                        self.requeues.load(Ordering::Relaxed) as f64
                    ),
                ),
                (
                    "retries_exhausted",
                    json::num(self
                        .retries_exhausted
                        .load(Ordering::Relaxed)
                        as f64),
                ),
                (
                    "readmissions",
                    json::num(
                        self.readmissions.load(Ordering::Relaxed)
                            as f64,
                    ),
                ),
                (
                    "readmit_after",
                    json::num(self.cfg.readmit_after as f64),
                ),
                (
                    "dropped_deadline_post_admission",
                    json::num(self
                        .dropped_deadline
                        .load(Ordering::Relaxed)
                        as f64),
                ),
                (
                    "inflight",
                    json::num(
                        self.registry.lock().unwrap().len() as f64
                    ),
                ),
                (
                    "retry_queue_depth",
                    json::num(
                        self.retry_queue.lock().unwrap().len() as f64,
                    ),
                ),
            ]),
        ));
        json::obj(doc)
    }
}

/// HTTP frontend state over a [`Fleet`].
struct FleetState {
    cfg: ServerConfig,
    fleet: Arc<Fleet>,
    started: Instant,
}

impl ServeState for FleetState {
    fn cfg(&self) -> &ServerConfig {
        &self.cfg
    }

    fn sched(&self) -> &Scheduler {
        self.fleet.sched()
    }

    fn alive(&self) -> bool {
        self.fleet.alive()
    }

    fn shutting_down(&self) -> bool {
        self.fleet.shutdown.load(Ordering::Relaxed)
    }

    fn clock(&self) -> &SharedClock {
        self.fleet.clock()
    }

    fn telemetry(&self) -> &Arc<Telemetry> {
        self.fleet.telemetry()
    }

    fn metrics_json(&self) -> Json {
        let fleet = self.fleet.fleet_json();
        let mut doc: BTreeMap<String, Json> = match fleet {
            Json::Obj(m) => m,
            _ => BTreeMap::new(),
        };
        doc.insert("scheduler".into(), self.fleet.sched().metrics_json());
        doc.insert(
            "server".into(),
            json::obj(vec![
                (
                    "uptime_s",
                    json::num(
                        self.fleet
                            .clock()
                            .now()
                            .duration_since(self.started)
                            .as_secs_f64(),
                    ),
                ),
                ("driver_alive", Json::Bool(self.fleet.alive())),
            ]),
        );
        Json::Obj(doc)
    }
}

/// Run the HTTP serving frontend over a multi-engine fleet until
/// `shutdown` is set.
///
/// `engine_fn` runs once on each of the `rcfg.engines` dedicated driver
/// threads; it must construct that engine's backend (PJRT state is not
/// `Send`, so construction happens inside the thread) and hand it to
/// [`Fleet::run_engine`].  Individual engine failures are *handled*
/// (failover), not returned: they surface in `/metrics` and the logs.
///
/// Known limitation: a driver wedged inside a device call can only be
/// routed around, not reaped — process supervision owns hard kills.
pub fn serve_fleet<F>(
    listener: TcpListener,
    cfg: ServerConfig,
    rcfg: RouterCfg,
    shutdown: Arc<AtomicBool>,
    engine_fn: F,
) -> Result<()>
where
    F: Fn(usize, &Fleet) -> Result<()> + Send + Sync,
{
    let fleet = Fleet::with_prefill_chunk(
        rcfg,
        cfg.queue_cap,
        cfg.policy,
        shutdown.clone(),
        cfg.prefill_chunk,
    );
    let fleet = match (cfg.degrade_k, cfg.expert_k_max) {
        (Some(d), Some(k)) => fleet.with_degrade_k(d, k),
        _ => fleet,
    };
    let fleet = match cfg.prefix_cache {
        Some(budget) => {
            fleet.with_prefix_cache(PrefixCache::shared(budget))
        }
        None => fleet,
    };
    let fleet = if cfg.speculate > 0 {
        fleet.with_speculate(cfg.speculate)
    } else {
        fleet
    };
    let telemetry = if cfg.telemetry {
        Telemetry::new(fleet.clock().clone())
            .with_ring_cap(cfg.trace_ring)
            .with_sample_permille(cfg.span_sample_permille)
            .shared()
    } else {
        Telemetry::disabled(fleet.clock().clone()).shared()
    };
    let fleet = Arc::new(fleet.with_telemetry(telemetry));
    let started = fleet.clock().now();
    let state = Arc::new(FleetState {
        cfg,
        fleet: fleet.clone(),
        started,
    });
    listener.set_nonblocking(true)?;
    std::thread::scope(|scope| -> Result<()> {
        let engine_fn = &engine_fn;
        for id in 0..fleet.n_engines() {
            let fleet = fleet.clone();
            scope.spawn(move || {
                let r = engine_fn(id, &fleet);
                if let Err(e) = &r {
                    eprintln!("[router] engine {id} exited: {e}");
                }
                fleet.engine_exited(id);
            });
        }
        let placer_fleet = fleet.clone();
        let placer = scope.spawn(move || placer_fleet.run_placer());
        while !shutdown.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let conn_state = state.clone();
                    scope.spawn(move || {
                        server::handle_connection(stream, conn_state)
                    });
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => {
                    shutdown.store(true, Ordering::SeqCst);
                    let _ = placer.join();
                    return Err(e.into());
                }
            }
        }
        placer
            .join()
            .map_err(|_| Error::Serving("placer panicked".into()))?;
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_parse_roundtrip() {
        for p in [
            Placement::LeastLoaded,
            Placement::RoundRobin,
            Placement::Affinity,
        ] {
            assert_eq!(Placement::parse(p.as_str()).unwrap(), p);
        }
        assert!(Placement::parse("random").is_err());
        assert_eq!(
            Placement::parse("rr").unwrap(),
            Placement::RoundRobin
        );
        assert_eq!(
            Placement::parse("ll").unwrap(),
            Placement::LeastLoaded
        );
    }

    #[test]
    fn affinity_hash_is_prefix_stable() {
        let a = Fleet::affinity_hash(&[1, 2, 3, 4, 5, 6, 7, 8, 9]);
        let b = Fleet::affinity_hash(&[1, 2, 3, 4, 5, 6, 7, 8, 100]);
        assert_eq!(a, b, "suffix beyond the prefix must not matter");
        let c = Fleet::affinity_hash(&[2, 2, 3, 4, 5, 6, 7, 8]);
        assert_ne!(a, c);
    }

    #[test]
    fn fleet_json_shape_is_stable_when_idle() {
        let fleet = Fleet::new(
            RouterCfg { engines: 3, ..Default::default() },
            8,
            Policy::Fifo,
            Arc::new(AtomicBool::new(false)),
        );
        let doc = fleet.fleet_json();
        assert_eq!(
            doc.get("engines").unwrap().as_arr().unwrap().len(),
            3
        );
        let router = doc.get("router").unwrap();
        assert_eq!(
            router.get("healthy_engines").unwrap().as_f64().unwrap(),
            3.0
        );
        assert_eq!(
            router.get("placement").unwrap().as_str().unwrap(),
            "least-loaded"
        );
        assert!(fleet.alive());
    }
}
