//! Runtime: PJRT client, artifact manifests, compiled programs.
//!
//! Layer boundary: everything below here executes AOT-compiled HLO that
//! `python -m compile.aot` produced at build time — Python is never on
//! the request path.

pub mod device;
pub mod manifest;
pub mod program;

pub use device::{DeviceState, TransferSnapshot, TransferStats};
pub use manifest::{BufferSpec, FunctionSpec, Manifest, ModelInfo};
pub use program::{Client, Program};

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::Result;

/// A fully-loaded model artifact: manifest + all compiled programs.
pub struct ModelBundle {
    pub manifest: Manifest,
    pub programs: BTreeMap<String, Program>,
    /// The client everything was compiled on — device-resident state
    /// (trainer / engine) allocates its buffers here.
    pub client: Client,
}

impl ModelBundle {
    /// Load and compile every function of a preset directory.
    pub fn load(client: &Client, dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let mut programs = BTreeMap::new();
        for (name, spec) in &manifest.functions {
            let path = manifest.hlo_path(name)?;
            programs.insert(
                name.clone(),
                Program::load(client, name, &path, spec.clone())?,
            );
        }
        Ok(ModelBundle { manifest, programs, client: client.clone() })
    }

    /// Load only the listed functions (e.g. just `step_fwd` for serving).
    pub fn load_subset(
        client: &Client,
        dir: impl AsRef<Path>,
        names: &[&str],
    ) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let mut programs = BTreeMap::new();
        for name in names {
            let spec = manifest.function(name)?.clone();
            let path = manifest.hlo_path(name)?;
            programs.insert(
                name.to_string(),
                Program::load(client, name, &path, spec)?,
            );
        }
        Ok(ModelBundle { manifest, programs, client: client.clone() })
    }

    pub fn program(&self, name: &str) -> Result<&Program> {
        self.programs.get(name).ok_or_else(|| {
            crate::error::Error::Manifest(format!("program {name:?} not loaded"))
        })
    }
}
