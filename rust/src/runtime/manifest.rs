//! Artifact manifest: the contract between the Python AOT compiler and
//! the Rust runtime.
//!
//! `python -m compile.aot` writes, next to each preset's HLO files, a
//! `manifest.json` describing every function's flattened input/output
//! buffers (name, shape, dtype) in the exact order jax.jit flattened
//! them, plus the model configuration and analytic FLOPs summary.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::json::Json;
use crate::tensor::DType;

/// One flattened buffer of a function signature.
#[derive(Debug, Clone)]
pub struct BufferSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl BufferSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.element_count() * self.dtype.size_bytes()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let name = j.get("name")?.as_str()?.to_string();
        let shape = j
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<std::result::Result<Vec<_>, _>>()?;
        let dtype = DType::parse(j.get("dtype")?.as_str()?)?;
        Ok(BufferSpec { name, shape, dtype })
    }
}

/// Signature of one AOT'd function.
#[derive(Debug, Clone)]
pub struct FunctionSpec {
    pub file: String,
    pub inputs: Vec<BufferSpec>,
    pub outputs: Vec<BufferSpec>,
}

impl FunctionSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let parse_list = |key: &str| -> Result<Vec<BufferSpec>> {
            j.get(key)?
                .as_arr()?
                .iter()
                .map(BufferSpec::from_json)
                .collect()
        };
        Ok(FunctionSpec {
            file: j.get("file")?.as_str()?.to_string(),
            inputs: parse_list("inputs")?,
            outputs: parse_list("outputs")?,
        })
    }

    /// Index of the output whose name starts with `prefix`.
    pub fn output_index(&self, prefix: &str) -> Option<usize> {
        self.outputs.iter().position(|b| b.name.starts_with(prefix))
    }

    /// All output indices whose name starts with `prefix`, in order.
    pub fn output_indices(&self, prefix: &str) -> Vec<usize> {
        self.outputs
            .iter()
            .enumerate()
            .filter(|(_, b)| b.name.starts_with(prefix))
            .map(|(i, _)| i)
            .collect()
    }

    /// All input indices whose name starts with `prefix`, in order.
    pub fn input_indices(&self, prefix: &str) -> Vec<usize> {
        self.inputs
            .iter()
            .enumerate()
            .filter(|(_, b)| b.name.starts_with(prefix))
            .map(|(i, _)| i)
            .collect()
    }

    /// Total bytes of all inputs — what one seed-path `run` uploads.
    pub fn total_input_bytes(&self) -> usize {
        self.inputs.iter().map(|b| b.size_bytes()).sum()
    }

    /// Total bytes of all outputs — what one seed-path `run` downloads.
    pub fn total_output_bytes(&self) -> usize {
        self.outputs.iter().map(|b| b.size_bytes()).sum()
    }
}

/// Model-configuration subset the runtime needs (full config stays in the
/// manifest JSON for inspection).
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub context: usize,
    pub mem_len: usize,
    pub ff_variant: String,
    pub unit: String,
    pub n_experts: usize,
    pub expert_k: usize,
    pub group_size: usize,
}

/// Parsed manifest for one preset directory.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub preset: String,
    pub model: ModelInfo,
    pub batch_size: usize,
    pub total_steps: usize,
    pub eval_mem_len: usize,
    pub serve_batch: usize,
    /// Serving prefill chunk width C (tokens per `prefill` dispatch per
    /// lane); 1 for artifacts that predate the `prefill` program.
    pub prefill_chunk: usize,
    /// Compile-time expert top-k ceiling for the runtime `expert_k`
    /// scalar input on MoE `step_fwd`/`prefill` (adaptive expert
    /// sparsity).  `None` on non-MoE presets and on MoE artifacts that
    /// predate the runtime-k input (fixed-k serving then).
    pub expert_k_max: Option<usize>,
    /// Whether the `prefill` program emits logits at *all* C positions
    /// (`[B, C, V]` output `0`) instead of the last-valid gather
    /// (`[B, V]`) — the verifier a speculative decoder needs.  False
    /// for artifacts that predate the flag (old last-position
    /// signature; speculation is disabled against them).
    pub verify_logits: bool,
    /// Whether `snapshot_lanes`/`restore_lanes` are present so the
    /// serving engine may snapshot post-prefill lane memory into the
    /// prefix cache and seed cache-hit lanes from it.  False on
    /// artifacts that predate the programs — the engine then serves
    /// every prompt through cold prefill, bit-for-bit unchanged.
    pub prefix_cache: bool,
    pub functions: BTreeMap<String, FunctionSpec>,
    pub flops: BTreeMap<String, f64>,
    pub raw: Json,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {} (run `make artifacts`?): {e}",
                path.display()
            ))
        })?;
        let raw = Json::parse(&text)?;

        let cfg = raw.get("config")?;
        let moe = cfg.get("moe")?;
        let model = ModelInfo {
            name: cfg.get("name")?.as_str()?.to_string(),
            vocab_size: cfg.get("vocab_size")?.as_usize()?,
            d_model: cfg.get("d_model")?.as_usize()?,
            d_ff: cfg.get("d_ff")?.as_usize()?,
            n_layers: cfg.get("n_layers")?.as_usize()?,
            context: cfg.get("context")?.as_usize()?,
            mem_len: cfg.get("mem_len")?.as_usize()?,
            ff_variant: cfg.get("ff_variant")?.as_str()?.to_string(),
            unit: cfg.get("unit")?.as_str()?.to_string(),
            n_experts: moe.get("n_experts")?.as_usize()?,
            expert_k: moe.get("k")?.as_usize()?,
            group_size: moe.get("group_size")?.as_usize()?,
        };

        let mut functions = BTreeMap::new();
        for (name, j) in raw.get("functions")?.as_obj()? {
            functions.insert(name.clone(), FunctionSpec::from_json(j)?);
        }
        let mut flops = BTreeMap::new();
        if let Some(f) = raw.opt("flops") {
            for (k, v) in f.as_obj()? {
                flops.insert(k.clone(), v.as_f64()?);
            }
        }

        Ok(Manifest {
            preset: raw.get("preset")?.as_str()?.to_string(),
            batch_size: raw.get("train_config")?.get("batch_size")?.as_usize()?,
            total_steps: raw.get("train_config")?.get("total_steps")?.as_usize()?,
            eval_mem_len: raw.get("eval_mem_len")?.as_usize()?,
            serve_batch: raw.get("serve_batch")?.as_usize()?,
            prefill_chunk: raw
                .opt("prefill_chunk")
                .and_then(|v| v.as_usize().ok())
                .unwrap_or(1)
                .max(1),
            expert_k_max: raw
                .opt("expert_k_max")
                .and_then(|v| v.as_usize().ok())
                .filter(|&k| k > 0),
            verify_logits: raw
                .opt("verify_logits")
                .and_then(|v| v.as_bool().ok())
                .unwrap_or(false),
            prefix_cache: raw
                .opt("prefix_cache")
                .and_then(|v| v.as_bool().ok())
                .unwrap_or(false),
            model,
            functions,
            flops,
            raw,
            dir,
        })
    }

    pub fn function(&self, name: &str) -> Result<&FunctionSpec> {
        self.functions
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("no function {name:?} in manifest")))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.function(name)?.file))
    }
}
