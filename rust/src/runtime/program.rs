//! A compiled AOT program: HLO text -> PJRT executable + typed execute.

use std::sync::Arc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::tensor::HostTensor;

use super::device::TransferStats;
use super::manifest::FunctionSpec;

/// Shared PJRT client handle plus host↔device transfer counters.
#[derive(Clone)]
pub struct Client {
    inner: Arc<xla::PjRtClient>,
    transfers: Arc<TransferStats>,
}

impl Client {
    /// Create the CPU PJRT client (the only backend in this testbed; the
    /// same artifacts compile for TPU with a TPU PJRT plugin).
    pub fn cpu() -> Result<Self> {
        Ok(Client {
            inner: Arc::new(xla::PjRtClient::cpu()?),
            transfers: Arc::new(TransferStats::default()),
        })
    }

    pub fn platform(&self) -> String {
        self.inner.platform_name()
    }

    pub fn raw(&self) -> &xla::PjRtClient {
        &self.inner
    }

    /// Cumulative transfer counters for every upload/download performed
    /// through this client (all clones share the same counters).
    pub fn transfers(&self) -> &TransferStats {
        &self.transfers
    }
}

/// One compiled function plus its manifest signature.
pub struct Program {
    pub name: String,
    pub spec: FunctionSpec,
    exe: xla::PjRtLoadedExecutable,
    client: Client,
    /// Cumulative on-device execution time (for the perf report) —
    /// excludes host transfers since the device-resident rework.
    pub exec_time: std::cell::Cell<std::time::Duration>,
    pub exec_count: std::cell::Cell<u64>,
    /// Times `run_buffers` had to fall back to a host round-trip to
    /// untuple the result (0 on backends that return flat outputs).
    pub untuple_fallbacks: std::cell::Cell<u64>,
}

impl Program {
    /// Load HLO text from `path`, compile it on `client`.
    pub fn load(
        client: &Client,
        name: &str,
        path: &std::path::Path,
        spec: FunctionSpec,
    ) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::other("non-utf8 artifact path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.raw().compile(&comp)?;
        Ok(Program {
            name: name.to_string(),
            spec,
            exe,
            client: client.clone(),
            exec_time: std::cell::Cell::new(std::time::Duration::ZERO),
            exec_count: std::cell::Cell::new(0),
            untuple_fallbacks: std::cell::Cell::new(0),
        })
    }

    /// Execute with host tensors; validates shapes/dtypes against the
    /// manifest and returns one host tensor per manifest output, in
    /// manifest order.
    ///
    /// This is the full round-trip path — every input uploaded, every
    /// output downloaded, per call — built on [`Program::run_buffers`]
    /// so the transfer counters attribute upload/download cost to the
    /// transfers (not to exec time) on both paths.  Hot loops use
    /// `run_buffers` directly and keep state device-resident.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.validate_inputs(inputs)?;
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| super::device::upload(&self.client, t))
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let out = self.run_buffers(&refs)?;
        out.iter()
            .map(|b| super::device::download(&self.client, b))
            .collect()
    }

    /// Execute directly on device buffers and return one device buffer
    /// per manifest output — no host transfer on this path.
    ///
    /// If the backend hands the result back as a single tuple buffer
    /// instead of flat leaves, we untuple via one host round-trip and
    /// count it in `untuple_fallbacks` so the perf report can flag the
    /// degradation (the CPU PJRT used here returns flat leaves).
    pub fn run_buffers(
        &self,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(Error::Shape(format!(
                "{}: {} buffers given, manifest says {}",
                self.name,
                inputs.len(),
                self.spec.inputs.len()
            )));
        }
        let t0 = Instant::now();
        let mut result = self.exe.execute_b(inputs)?;
        self.exec_time.set(self.exec_time.get() + t0.elapsed());
        self.exec_count.set(self.exec_count.get() + 1);
        if result.is_empty() {
            return Err(Error::other("execute_b returned no replicas"));
        }
        let replica = result.swap_remove(0);
        if replica.len() == self.spec.outputs.len()
            && !(self.spec.outputs.len() == 1 && is_tuple(&replica[0]))
        {
            return Ok(replica);
        }
        if replica.len() == 1 {
            // tuple result: download once, re-upload the leaves
            self.untuple_fallbacks
                .set(self.untuple_fallbacks.get() + 1);
            let t_down = Instant::now();
            let tuple = replica[0].to_literal_sync()?;
            let tuple_bytes = tuple.size_bytes();
            let parts = tuple.to_tuple()?;
            self.client
                .transfers()
                .note_d2h(tuple_bytes, t_down.elapsed());
            if parts.len() != self.spec.outputs.len() {
                return Err(Error::Shape(format!(
                    "{}: tuple has {} leaves, manifest says {}",
                    self.name,
                    parts.len(),
                    self.spec.outputs.len()
                )));
            }
            let t_up = Instant::now();
            let bufs: Vec<xla::PjRtBuffer> = parts
                .iter()
                .map(|p| {
                    Ok(self.client.raw().buffer_from_host_literal(None, p)?)
                })
                .collect::<Result<_>>()?;
            self.client
                .transfers()
                .note_h2d(tuple_bytes, t_up.elapsed());
            return Ok(bufs);
        }
        Err(Error::Shape(format!(
            "{}: {} output buffers returned, manifest says {}",
            self.name,
            replica.len(),
            self.spec.outputs.len()
        )))
    }

    fn validate_inputs(&self, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(Error::Shape(format!(
                "{}: {} inputs given, manifest says {}",
                self.name,
                inputs.len(),
                self.spec.inputs.len()
            )));
        }
        for (i, (t, s)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            if t.shape != s.shape || t.dtype != s.dtype {
                return Err(Error::Shape(format!(
                    "{}: input #{i} ({}) expects {:?} {:?}, got {:?} {:?}",
                    self.name, s.name, s.dtype, s.shape, t.dtype, t.shape
                )));
            }
        }
        Ok(())
    }

    /// Mean wall-clock execution time over all runs so far.
    pub fn mean_exec_time(&self) -> Option<std::time::Duration> {
        let n = self.exec_count.get();
        (n > 0).then(|| self.exec_time.get() / n as u32)
    }
}

/// Whether a result buffer is a tuple wrapper rather than a flat leaf —
/// disambiguates a single-output program from a 1-tuple result, where
/// the buffer count alone can't.
fn is_tuple(buf: &xla::PjRtBuffer) -> bool {
    matches!(buf.on_device_shape(), Ok(xla::Shape::Tuple(_)))
}
