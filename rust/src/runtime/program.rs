//! A compiled AOT program: HLO text -> PJRT executable + typed execute.

use std::sync::Arc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::tensor::HostTensor;

use super::manifest::FunctionSpec;

/// Shared PJRT client handle.
#[derive(Clone)]
pub struct Client {
    inner: Arc<xla::PjRtClient>,
}

impl Client {
    /// Create the CPU PJRT client (the only backend in this testbed; the
    /// same artifacts compile for TPU with a TPU PJRT plugin).
    pub fn cpu() -> Result<Self> {
        Ok(Client { inner: Arc::new(xla::PjRtClient::cpu()?) })
    }

    pub fn platform(&self) -> String {
        self.inner.platform_name()
    }

    pub fn raw(&self) -> &xla::PjRtClient {
        &self.inner
    }
}

/// One compiled function plus its manifest signature.
pub struct Program {
    pub name: String,
    pub spec: FunctionSpec,
    exe: xla::PjRtLoadedExecutable,
    /// Cumulative on-device execution time (for the perf report).
    pub exec_time: std::cell::Cell<std::time::Duration>,
    pub exec_count: std::cell::Cell<u64>,
}

impl Program {
    /// Load HLO text from `path`, compile it on `client`.
    pub fn load(
        client: &Client,
        name: &str,
        path: &std::path::Path,
        spec: FunctionSpec,
    ) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::other("non-utf8 artifact path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.raw().compile(&comp)?;
        Ok(Program {
            name: name.to_string(),
            spec,
            exe,
            exec_time: std::cell::Cell::new(std::time::Duration::ZERO),
            exec_count: std::cell::Cell::new(0),
        })
    }

    /// Execute with host tensors; validates shapes/dtypes against the
    /// manifest, unwraps the 1-tuple result and returns one host tensor
    /// per manifest output, in manifest order.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.validate_inputs(inputs)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let buffer = result
            .first()
            .and_then(|replica| replica.first())
            .ok_or_else(|| Error::other("execute returned no buffers"))?;
        let tuple = buffer.to_literal_sync()?;
        self.exec_time
            .set(self.exec_time.get() + t0.elapsed());
        self.exec_count.set(self.exec_count.get() + 1);
        let parts = tuple.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            return Err(Error::Shape(format!(
                "{}: {} outputs returned, manifest says {}",
                self.name,
                parts.len(),
                self.spec.outputs.len()
            )));
        }
        parts.iter().map(HostTensor::from_literal).collect()
    }

    fn validate_inputs(&self, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(Error::Shape(format!(
                "{}: {} inputs given, manifest says {}",
                self.name,
                inputs.len(),
                self.spec.inputs.len()
            )));
        }
        for (i, (t, s)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            if t.shape != s.shape || t.dtype != s.dtype {
                return Err(Error::Shape(format!(
                    "{}: input #{i} ({}) expects {:?} {:?}, got {:?} {:?}",
                    self.name, s.name, s.dtype, s.shape, t.dtype, t.shape
                )));
            }
        }
        Ok(())
    }

    /// Mean wall-clock execution time over all `run` calls so far.
    pub fn mean_exec_time(&self) -> Option<std::time::Duration> {
        let n = self.exec_count.get();
        (n > 0).then(|| self.exec_time.get() / n as u32)
    }
}
