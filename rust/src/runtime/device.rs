//! Device-resident state: named `PjRtBuffer` slots bound to a program's
//! manifest input signature.
//!
//! The seed runtime round-tripped *every* input — parameters, Adam
//! moments, XL memories — through host `Vec<u8>` → `xla::Literal` →
//! device buffer and back on each `train_step` / `eval_step` /
//! `step_fwd` call.  `DeviceState` keeps persistent state on device
//! across steps: a host tensor is uploaded only when its slot is
//! dirtied, program outputs are fed back buffer-to-buffer via
//! [`DeviceState::set_device`], and a download happens only on an
//! explicit host sync ([`DeviceState::host`] / [`DeviceState::sync_to_host`]
//! — the checkpoint / analysis boundary).  See EXPERIMENTS.md §Perf.

use std::cell::Cell;
use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::tensor::HostTensor;

use super::manifest::BufferSpec;
use super::program::Client;

/// Cumulative host↔device transfer counters (interior-mutable so the
/// shared [`Client`] can own them; snapshot with [`TransferStats::snapshot`]).
#[derive(Debug, Default)]
pub struct TransferStats {
    pub h2d_bytes: Cell<u64>,
    pub d2h_bytes: Cell<u64>,
    pub h2d_count: Cell<u64>,
    pub d2h_count: Cell<u64>,
    pub h2d_time: Cell<Duration>,
    pub d2h_time: Cell<Duration>,
}

impl TransferStats {
    pub fn note_h2d(&self, bytes: usize, elapsed: Duration) {
        self.h2d_bytes.set(self.h2d_bytes.get() + bytes as u64);
        self.h2d_count.set(self.h2d_count.get() + 1);
        self.h2d_time.set(self.h2d_time.get() + elapsed);
    }

    pub fn note_d2h(&self, bytes: usize, elapsed: Duration) {
        self.d2h_bytes.set(self.d2h_bytes.get() + bytes as u64);
        self.d2h_count.set(self.d2h_count.get() + 1);
        self.d2h_time.set(self.d2h_time.get() + elapsed);
    }

    pub fn snapshot(&self) -> TransferSnapshot {
        TransferSnapshot {
            h2d_bytes: self.h2d_bytes.get(),
            d2h_bytes: self.d2h_bytes.get(),
            h2d_count: self.h2d_count.get(),
            d2h_count: self.d2h_count.get(),
            h2d_time: self.h2d_time.get(),
            d2h_time: self.d2h_time.get(),
        }
    }
}

/// A point-in-time copy of [`TransferStats`], subtractable for
/// per-phase deltas (benches, the `[perf]` report).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransferSnapshot {
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    pub h2d_count: u64,
    pub d2h_count: u64,
    pub h2d_time: Duration,
    pub d2h_time: Duration,
}

impl TransferSnapshot {
    /// Traffic since `earlier` (saturating; both must come from the same
    /// counters).
    pub fn since(&self, earlier: &TransferSnapshot) -> TransferSnapshot {
        TransferSnapshot {
            h2d_bytes: self.h2d_bytes.saturating_sub(earlier.h2d_bytes),
            d2h_bytes: self.d2h_bytes.saturating_sub(earlier.d2h_bytes),
            h2d_count: self.h2d_count.saturating_sub(earlier.h2d_count),
            d2h_count: self.d2h_count.saturating_sub(earlier.d2h_count),
            h2d_time: self.h2d_time.saturating_sub(earlier.h2d_time),
            d2h_time: self.d2h_time.saturating_sub(earlier.d2h_time),
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.h2d_bytes + self.d2h_bytes
    }

    /// One-line `h2d x MB / d2h y MB` summary normalized per `steps`.
    pub fn report_per_step(&self, steps: u64) -> String {
        let n = steps.max(1) as f64;
        format!(
            "h2d {:.3} MB/step ({} xfers) | d2h {:.3} MB/step ({} xfers)",
            self.h2d_bytes as f64 / n / 1e6,
            self.h2d_count,
            self.d2h_bytes as f64 / n / 1e6,
            self.d2h_count,
        )
    }
}

/// Upload one host tensor to the device, counting the traffic.
pub fn upload(client: &Client, t: &HostTensor) -> Result<xla::PjRtBuffer> {
    let lit = t.to_literal()?;
    let t0 = Instant::now();
    let buf = client.raw().buffer_from_host_literal(None, &lit)?;
    client.transfers().note_h2d(t.data.len(), t0.elapsed());
    Ok(buf)
}

/// Download one device buffer to a host tensor, counting the traffic.
pub fn download(client: &Client, buf: &xla::PjRtBuffer) -> Result<HostTensor> {
    let t0 = Instant::now();
    let lit = buf.to_literal_sync()?;
    let t = HostTensor::from_literal(&lit)?;
    client.transfers().note_d2h(t.data.len(), t0.elapsed());
    Ok(t)
}

/// One named slot: the authoritative copy lives on device unless `dirty`.
///
/// Invariants:
///   * `dirty` ⇒ `host` is `Some` and newer than `device`;
///   * `!dirty` and `host` is `Some` ⇒ host mirror equals device content
///     (programs never mutate their input buffers);
///   * [`Slot::device`] is `None` only before the first upload.
struct Slot {
    spec: BufferSpec,
    host: Option<HostTensor>,
    device: Option<xla::PjRtBuffer>,
    dirty: bool,
}

/// Named device-buffer slots matching a manifest input signature, in
/// manifest order.
pub struct DeviceState {
    client: Client,
    name: String,
    slots: Vec<Slot>,
    index: HashMap<String, usize>,
}

impl DeviceState {
    /// One zero-initialized slot per manifest input.  Nothing is uploaded
    /// until the first [`DeviceState::buffers`] call.
    pub fn for_inputs(client: &Client, name: &str, inputs: &[BufferSpec]) -> Self {
        let slots = inputs
            .iter()
            .map(|b| Slot {
                spec: b.clone(),
                host: Some(HostTensor::zeros(b.dtype, &b.shape)),
                device: None,
                dirty: true,
            })
            .collect();
        let index = inputs
            .iter()
            .enumerate()
            .map(|(i, b)| (b.name.clone(), i))
            .collect();
        DeviceState { client: client.clone(), name: name.to_string(), slots, index }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Slot index of the input named `name`.
    pub fn position(&self, name: &str) -> Option<usize> {
        self.index.get(name).copied()
    }

    pub fn slot_spec(&self, idx: usize) -> &BufferSpec {
        &self.slots[idx].spec
    }

    /// Replace a slot's contents from the host; uploaded lazily on the
    /// next [`DeviceState::buffers`] call.
    pub fn set_host(&mut self, idx: usize, t: HostTensor) -> Result<()> {
        let slot = &mut self.slots[idx];
        if t.shape != slot.spec.shape || t.dtype != slot.spec.dtype {
            return Err(Error::Shape(format!(
                "{}: slot {} ({}) expects {:?} {:?}, got {:?} {:?}",
                self.name, idx, slot.spec.name, slot.spec.dtype,
                slot.spec.shape, t.dtype, t.shape
            )));
        }
        slot.host = Some(t);
        slot.dirty = true;
        Ok(())
    }

    /// Adopt a device buffer (typically a program output fed straight
    /// back) — zero host traffic.  Any host mirror becomes stale and is
    /// dropped; the next [`DeviceState::host`] re-downloads.
    pub fn set_device(&mut self, idx: usize, buf: xla::PjRtBuffer) {
        let slot = &mut self.slots[idx];
        slot.device = Some(buf);
        slot.host = None;
        slot.dirty = false;
    }

    /// Upload every dirtied slot.
    pub fn upload_dirty(&mut self) -> Result<()> {
        for slot in self.slots.iter_mut() {
            if slot.dirty {
                let t = slot
                    .host
                    .as_ref()
                    .ok_or_else(|| Error::other("dirty slot without host copy"))?;
                slot.device = Some(upload(&self.client, t)?);
                slot.dirty = false;
            }
        }
        Ok(())
    }

    /// Device buffer of one slot; the slot must be clean (uploaded).
    pub fn buffer(&self, idx: usize) -> Result<&xla::PjRtBuffer> {
        let slot = &self.slots[idx];
        if slot.dirty {
            return Err(Error::other(format!(
                "{}: slot {} ({}) is dirty — call upload_dirty first",
                self.name, idx, slot.spec.name
            )));
        }
        slot.device.as_ref().ok_or_else(|| {
            Error::other(format!(
                "{}: slot {} ({}) has no device buffer",
                self.name, idx, slot.spec.name
            ))
        })
    }

    /// All slots as device buffers in manifest order, uploading dirty
    /// ones first — the argument vector for `Program::run_buffers`.
    pub fn buffers(&mut self) -> Result<Vec<&xla::PjRtBuffer>> {
        self.upload_dirty()?;
        let mut out = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            out.push(slot.device.as_ref().ok_or_else(|| {
                Error::other(format!(
                    "{}: slot {} has no device buffer after upload",
                    self.name, slot.spec.name
                ))
            })?);
        }
        Ok(out)
    }

    /// Host view of one slot, downloading from device only when no valid
    /// mirror exists (the explicit host-sync boundary).
    pub fn host(&mut self, idx: usize) -> Result<&HostTensor> {
        if self.slots[idx].host.is_none() {
            let buf = self.slots[idx]
                .device
                .as_ref()
                .ok_or_else(|| Error::other("slot has neither host nor device copy"))?;
            let t = download(&self.client, buf)?;
            self.slots[idx].host = Some(t);
        }
        Ok(self.slots[idx].host.as_ref().unwrap())
    }

    /// Whether a slot's authoritative copy is on device right now (clean
    /// and uploaded) — i.e. it can be passed to `run_buffers` without
    /// triggering any host traffic.  The engine's on-device lane reset
    /// uses this to decide between the zero-copy `reset_lanes` program
    /// and the host zero-row fallback.
    pub fn device_ready(&self, idx: usize) -> bool {
        let slot = &self.slots[idx];
        !slot.dirty && slot.device.is_some()
    }

    /// Mutable host view; marks the slot dirty so the mutation is
    /// uploaded before the next execution.
    pub fn host_mut(&mut self, idx: usize) -> Result<&mut HostTensor> {
        self.host(idx)?;
        let slot = &mut self.slots[idx];
        slot.dirty = true;
        Ok(slot.host.as_mut().unwrap())
    }

    /// Materialize host mirrors for every slot (checkpoint boundary).
    pub fn sync_to_host(&mut self) -> Result<()> {
        for i in 0..self.slots.len() {
            self.host(i)?;
        }
        Ok(())
    }

    /// Transfer counters of the underlying client (shared across all
    /// states and programs on that client).
    pub fn transfers(&self) -> TransferSnapshot {
        self.client.transfers().snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_stats_accumulate_and_delta() {
        let s = TransferStats::default();
        s.note_h2d(100, Duration::from_millis(2));
        s.note_h2d(50, Duration::from_millis(1));
        s.note_d2h(8, Duration::from_millis(3));
        let a = s.snapshot();
        assert_eq!(a.h2d_bytes, 150);
        assert_eq!(a.h2d_count, 2);
        assert_eq!(a.d2h_bytes, 8);
        assert_eq!(a.total_bytes(), 158);
        s.note_h2d(25, Duration::ZERO);
        let b = s.snapshot();
        let d = b.since(&a);
        assert_eq!(d.h2d_bytes, 25);
        assert_eq!(d.h2d_count, 1);
        assert_eq!(d.d2h_bytes, 0);
    }

    #[test]
    fn snapshot_report_is_per_step() {
        let s = TransferStats::default();
        s.note_h2d(2_000_000, Duration::ZERO);
        let snap = s.snapshot();
        let line = snap.report_per_step(2);
        assert!(line.contains("1.000 MB/step"), "{line}");
    }
}
