//! Training metrics: loss EMA, throughput, CSV logging.

use std::io::Write;
use std::path::Path;
use std::time::Instant;

use crate::coordinator::trainer::StepOutput;
use crate::Result;

/// Rolling training metrics + optional CSV sink.
pub struct Metrics {
    start: Instant,
    last_report: Instant,
    tokens_per_step: usize,
    steps_since_report: usize,
    pub loss_ema: Option<f64>,
    ema_alpha: f64,
    csv: Option<std::io::BufWriter<std::fs::File>>,
    pub history: Vec<(i64, f32)>,
}

impl Metrics {
    pub fn new(tokens_per_step: usize) -> Self {
        Metrics {
            start: Instant::now(),
            last_report: Instant::now(),
            tokens_per_step,
            steps_since_report: 0,
            loss_ema: None,
            ema_alpha: 0.05,
            csv: None,
            history: Vec::new(),
        }
    }

    /// Also append rows to a CSV file (step,loss,grad_norm,lr,tps).
    pub fn with_csv(mut self, path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "step,loss,grad_norm,lr,tokens_per_sec")?;
        self.csv = Some(f);
        Ok(self)
    }

    pub fn observe(&mut self, so: &StepOutput) -> Result<()> {
        self.steps_since_report += 1;
        let l = so.loss as f64;
        self.loss_ema = Some(match self.loss_ema {
            None => l,
            Some(e) => e * (1.0 - self.ema_alpha) + l * self.ema_alpha,
        });
        self.history.push((so.step, so.loss));
        let tps = self.instantaneous_tps();
        if let Some(csv) = &mut self.csv {
            writeln!(
                csv,
                "{},{},{},{},{:.1}",
                so.step, so.loss, so.grad_norm, so.lr, tps
            )?;
        }
        Ok(())
    }

    fn instantaneous_tps(&self) -> f64 {
        let dt = self.last_report.elapsed().as_secs_f64().max(1e-9);
        (self.steps_since_report * self.tokens_per_step) as f64 / dt
    }

    /// Human-readable progress line, resets the reporting window.
    pub fn report(&mut self, so: &StepOutput) -> String {
        let tps = self.instantaneous_tps();
        self.last_report = Instant::now();
        self.steps_since_report = 0;
        format!(
            "step {:>6}  loss {:.4}  ema {:.4}  |g| {:.3}  lr {:.2e}  {:>8.0} tok/s",
            so.step,
            so.loss,
            self.loss_ema.unwrap_or(so.loss as f64),
            so.grad_norm,
            so.lr,
            tps
        )
    }

    pub fn elapsed(&self) -> std::time::Duration {
        self.start.elapsed()
    }

    pub fn flush(&mut self) -> Result<()> {
        if let Some(csv) = &mut self.csv {
            csv.flush()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn so(step: i64, loss: f32) -> StepOutput {
        StepOutput { step, loss, grad_norm: 1.0, lr: 1e-4,
                     stats: BTreeMap::new() }
    }

    #[test]
    fn ema_moves_toward_loss() {
        let mut m = Metrics::new(10);
        m.observe(&so(0, 10.0)).unwrap();
        m.observe(&so(1, 0.0)).unwrap();
        let e = m.loss_ema.unwrap();
        assert!(e < 10.0 && e > 0.0);
    }

    #[test]
    fn csv_written() {
        let dir = std::env::temp_dir().join("sigma_moe_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.csv");
        let mut m = Metrics::new(4).with_csv(&path).unwrap();
        m.observe(&so(0, 1.0)).unwrap();
        m.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("step,loss"));
        assert!(text.lines().count() >= 2);
    }

    #[test]
    fn history_accumulates() {
        let mut m = Metrics::new(1);
        for i in 0..5 {
            m.observe(&so(i, i as f32)).unwrap();
        }
        assert_eq!(m.history.len(), 5);
    }
}
