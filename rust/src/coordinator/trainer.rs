//! The training coordinator: drives the AOT-compiled `train_step`
//! executable, owns all model/optimizer/XL-memory state, and wires
//! buffers **by manifest name** (positions shift when jax prunes unused
//! inputs, names never do).
//!
//! Since the device-resident rework (EXPERIMENTS.md §Perf) the state
//! lives in a [`DeviceState`]: parameters, Adam moments, and XL memories
//! stay on device across steps; per step only the token window and two
//! scalars go host→device and only the loss/grad-norm/lr scalars plus
//! the small `7.*` stats come back.  `params()` / `opt_state()` are the
//! explicit host-sync boundaries for checkpointing and analysis.
//!
//! Signature conventions (see python/compile/api.py):
//!   train_step inputs : "0.<param>" "1.<m>" "2.<v>" "3.<mems>" "4"=tokens
//!                       "5"=step "6"=seed(optional)
//!   train_step outputs: "0"=loss "1"=grad_norm "2"=lr "3.<param>"
//!                       "4.<m>" "5.<v>" "6.<mems>" "7.<stats>"
//!   eval_step inputs  : "0.<param>" "1.<mems>" "2"=tokens
//!   eval_step outputs : "0"=nll_sum "1"=count "2.<mems>" "3.<stats>"

use std::collections::{BTreeMap, HashMap};

use crate::data::XlBatcher;
use crate::error::{Error, Result};
use crate::runtime::device::{download, upload};
use crate::runtime::{DeviceState, ModelBundle, Program, TransferSnapshot};
use crate::tensor::HostTensor;

/// Result of one optimization step.
#[derive(Debug, Clone)]
pub struct StepOutput {
    pub step: i64,
    pub loss: f32,
    pub grad_norm: f32,
    pub lr: f32,
    /// Named auxiliary statistics ("7.usage", "7.mean_prob", ...).
    pub stats: BTreeMap<String, HostTensor>,
}

/// Result of an evaluation pass.
#[derive(Debug, Clone)]
pub struct EvalOutput {
    pub nll_sum: f64,
    pub token_count: f64,
    /// mean nll in nats/token
    pub nll: f64,
    pub stats: BTreeMap<String, HostTensor>,
}

impl EvalOutput {
    /// Perplexity (word-level metric).
    pub fn perplexity(&self) -> f64 {
        self.nll.exp()
    }

    /// Bits per character (char-level metric).
    pub fn bpc(&self) -> f64 {
        self.nll / std::f64::consts::LN_2
    }
}

/// Maps outputs of a program back onto its own (or another program's)
/// inputs by renaming name prefixes.
fn feedback_map(
    prog: &Program,
    renames: &[(&str, &str)],
) -> Vec<(usize, usize)> {
    let by_name: HashMap<&str, usize> = prog
        .spec
        .inputs
        .iter()
        .enumerate()
        .map(|(i, b)| (b.name.as_str(), i))
        .collect();
    let mut out = Vec::new();
    for (oi, ob) in prog.spec.outputs.iter().enumerate() {
        for (from, to) in renames {
            if let Some(rest) = ob.name.strip_prefix(from) {
                let target = format!("{to}{rest}");
                if let Some(&ii) = by_name.get(target.as_str()) {
                    out.push((oi, ii));
                }
            }
        }
    }
    out
}

/// Where each `eval_step` input comes from when evaluating with shared,
/// device-resident training parameters.
enum EvalSrc {
    /// borrow the train-state param buffer at this slot index
    Param(usize),
    /// the j-th persistent eval memory buffer
    Mem(usize),
    /// the per-segment token window
    Tokens,
    /// a constant zero buffer (inputs outside the known convention)
    Zero(usize),
}

/// The trainer: owns the device-resident train_step input state.
pub struct Trainer<'a> {
    pub bundle: &'a ModelBundle,
    state: DeviceState,
    feedback: Vec<(usize, usize)>,
    /// indices of param inputs ("0.*") in `state`, and the matching names
    param_slots: Vec<(String, usize)>,
    opt_slots: Vec<(String, usize)>,
    tok_idx: usize,
    step_idx: usize,
    seed_idx: Option<usize>,
    pub step: i64,
    pub seed: u32,
    /// eval-side XL memory, device-resident across evaluate() calls
    /// (shape differs from train mems)
    eval_mems: Option<Vec<xla::PjRtBuffer>>,
}

impl<'a> Trainer<'a> {
    /// Initialize model parameters via the `init` program and set up all
    /// buffer wiring.  Init outputs are adopted as device buffers
    /// directly — only the 4-byte seed scalar crosses the host boundary.
    pub fn new(bundle: &'a ModelBundle, seed: u32) -> Result<Self> {
        let ts = bundle.program("train_step")?;
        let spec = &ts.spec;
        let mut state =
            DeviceState::for_inputs(&bundle.client, "train_step", &spec.inputs);

        // run init on device and adopt params into "0.<name>" slots
        let init = bundle.program("init")?;
        let seed_buf = upload(&bundle.client, &HostTensor::scalar_u32(seed))?;
        let params = init.run_buffers(&[&seed_buf])?;
        if params.len() != init.spec.outputs.len() {
            return Err(Error::Shape("init output arity mismatch".into()));
        }
        let mut param_slots = Vec::new();
        for (buf, ob) in params.into_iter().zip(&init.spec.outputs) {
            let name = format!("0.{}", ob.name);
            let idx = state.position(&name).ok_or_else(|| {
                Error::Manifest(format!("train_step has no input {name}"))
            })?;
            state.set_device(idx, buf);
            param_slots.push((ob.name.clone(), idx));
        }
        let opt_slots = spec
            .inputs
            .iter()
            .enumerate()
            .filter(|(_, b)| b.name.starts_with("1.") || b.name.starts_with("2."))
            .map(|(i, b)| (b.name.clone(), i))
            .collect();

        let tok_idx = state
            .position("4")
            .ok_or_else(|| Error::Manifest("no tokens input '4'".into()))?;
        let step_idx = state
            .position("5")
            .ok_or_else(|| Error::Manifest("no step input '5'".into()))?;
        let seed_idx = state.position("6");
        let feedback = feedback_map(
            ts,
            &[("3.", "0."), ("4.", "1."), ("5.", "2."), ("6.", "3.")],
        );

        Ok(Trainer {
            bundle,
            state,
            feedback,
            param_slots,
            opt_slots,
            tok_idx,
            step_idx,
            seed_idx,
            step: 0,
            seed,
            eval_mems: None,
        })
    }

    /// Expected `[B, T+1]` token-window shape.
    pub fn token_shape(&self) -> &[usize] {
        &self.state.slot_spec(self.tok_idx).shape
    }

    /// Run one optimization step on a token window.  Per step only the
    /// tokens + step/seed scalars are uploaded; params, Adam moments, and
    /// XL memories are fed back output-buffer → input-buffer on device.
    pub fn step_on(&mut self, tokens: HostTensor) -> Result<StepOutput> {
        let ts = self.bundle.program("train_step")?;
        self.state.set_host(self.tok_idx, tokens)?;
        self.state
            .set_host(self.step_idx, HostTensor::scalar_i32(self.step as i32))?;
        if let Some(si) = self.seed_idx {
            self.state.set_host(si, HostTensor::scalar_u32(self.seed))?;
        }
        let out = {
            let bufs = self.state.buffers()?;
            ts.run_buffers(&bufs)?
        };
        // download only the scalars and the small "7.*" stats
        let loss = download(&self.bundle.client, &out[0])?.scalar_as_f32()?;
        let grad_norm = download(&self.bundle.client, &out[1])?.scalar_as_f32()?;
        let lr = download(&self.bundle.client, &out[2])?.scalar_as_f32()?;
        if !loss.is_finite() {
            return Err(Error::other(format!(
                "non-finite loss {loss} at step {}",
                self.step
            )));
        }
        let mut stats = BTreeMap::new();
        for (oi, ob) in ts.spec.outputs.iter().enumerate() {
            if ob.name.starts_with("7.") {
                stats.insert(ob.name.clone(), download(&self.bundle.client, &out[oi])?);
            }
        }
        // Feed new state back by *moving* the output buffers into the
        // input slots — zero host traffic (see EXPERIMENTS.md §Perf).
        let mut out: Vec<Option<xla::PjRtBuffer>> =
            out.into_iter().map(Some).collect();
        for (oi, ii) in &self.feedback {
            let buf = out[*oi]
                .take()
                .ok_or_else(|| Error::other("feedback output consumed twice"))?;
            self.state.set_device(*ii, buf);
        }
        let so = StepOutput { step: self.step, loss, grad_norm, lr, stats };
        self.step += 1;
        Ok(so)
    }

    /// Train for `n` steps pulling windows from `batcher`; calls `on_step`
    /// after every step (metrics, logging, early stop).
    pub fn train(
        &mut self,
        batcher: &mut XlBatcher,
        n: usize,
        mut on_step: impl FnMut(&StepOutput),
    ) -> Result<Vec<StepOutput>> {
        let mut outs = Vec::with_capacity(n);
        for _ in 0..n {
            let w = batcher.next_window()?;
            let so = self.step_on(w)?;
            on_step(&so);
            outs.push(so);
        }
        Ok(outs)
    }

    /// Current parameters as (name, tensor) pairs — an explicit host-sync
    /// boundary (downloads any slot without a valid host mirror).
    pub fn params(&mut self) -> Result<Vec<(String, HostTensor)>> {
        let mut out = Vec::with_capacity(self.param_slots.len());
        for (name, idx) in &self.param_slots {
            out.push((name.clone(), self.state.host(*idx)?.clone()));
        }
        Ok(out)
    }

    /// Current optimizer state (m then v) as (name, tensor) pairs — an
    /// explicit host-sync boundary like [`Trainer::params`].
    pub fn opt_state(&mut self) -> Result<Vec<(String, HostTensor)>> {
        let mut out = Vec::with_capacity(self.opt_slots.len());
        for (name, idx) in &self.opt_slots {
            out.push((name.clone(), self.state.host(*idx)?.clone()));
        }
        Ok(out)
    }

    /// Host↔device traffic of the underlying client so far (shared with
    /// every program/state on this client; snapshot deltas per phase).
    pub fn transfer_stats(&self) -> TransferSnapshot {
        self.state.transfers()
    }

    /// Restore parameters / optimizer state / step counter (from a
    /// checkpoint).  Missing names are an error; shapes and dtypes are
    /// validated eagerly against the manifest.  The restored tensors are
    /// uploaded lazily on the next step.
    pub fn restore(
        &mut self,
        params: &[(String, HostTensor)],
        opt: &[(String, HostTensor)],
        step: i64,
    ) -> Result<()> {
        for (name, t) in params {
            let key = format!("0.{name}");
            let idx = self.state.position(&key).ok_or_else(|| {
                Error::Checkpoint(format!("unknown param {name}"))
            })?;
            self.state.set_host(idx, t.clone())?;
        }
        for (name, t) in opt {
            let idx = self.state.position(name).ok_or_else(|| {
                Error::Checkpoint(format!("unknown opt slot {name}"))
            })?;
            self.state.set_host(idx, t.clone())?;
        }
        self.step = step;
        Ok(())
    }

    /// Evaluate on `segments` consecutive windows from `batcher` with the
    /// long XL memory, using the *current* parameters.
    ///
    /// The resident training param buffers are shared with `eval_step`
    /// directly — evaluation no longer clones the full parameter set into
    /// fresh host inputs.  Per segment only the `[B, T+1]` token window
    /// is uploaded; eval memories persist on device across segments and
    /// across calls (until [`Trainer::reset_eval_memory`]).
    pub fn evaluate(
        &mut self,
        batcher: &mut XlBatcher,
        segments: usize,
    ) -> Result<EvalOutput> {
        let ev = self.bundle.program("eval_step")?;
        let spec = &ev.spec;
        let client = &self.bundle.client;

        // classify inputs: shared params / persistent mems / tokens
        let mut srcs: Vec<EvalSrc> = Vec::with_capacity(spec.inputs.len());
        let mut mem_in: Vec<usize> = Vec::new();
        let mut zeros: Vec<xla::PjRtBuffer> = Vec::new();
        let mut found_tokens = false;
        for b in &spec.inputs {
            if b.name.starts_with("0.") {
                let ti = self.state.position(&b.name).ok_or_else(|| {
                    Error::Manifest(format!(
                        "eval_step param {} not in train_step state",
                        b.name
                    ))
                })?;
                srcs.push(EvalSrc::Param(ti));
            } else if b.name.starts_with("1.") {
                srcs.push(EvalSrc::Mem(mem_in.len()));
                mem_in.push(srcs.len() - 1);
            } else if b.name == "2" {
                srcs.push(EvalSrc::Tokens);
                found_tokens = true;
            } else {
                srcs.push(EvalSrc::Zero(zeros.len()));
                zeros.push(upload(
                    client,
                    &HostTensor::zeros(b.dtype, &b.shape),
                )?);
            }
        }
        if !found_tokens {
            return Err(Error::Manifest("no eval token input".into()));
        }

        // persistent eval mems: reuse the resident buffers, else zeros
        let mut mems: Vec<xla::PjRtBuffer> = match self.eval_mems.take() {
            Some(prev) if prev.len() == mem_in.len() => prev,
            _ => mem_in
                .iter()
                .map(|&i| {
                    let b = &spec.inputs[i];
                    upload(client, &HostTensor::zeros(b.dtype, &b.shape))
                })
                .collect::<Result<_>>()?,
        };
        // "2.<mems>" outputs feed the j-th persistent mem buffer
        let mem_feedback: Vec<(usize, usize)> = feedback_map(ev, &[("2.", "1.")])
            .into_iter()
            .filter_map(|(oi, ii)| {
                mem_in.iter().position(|&m| m == ii).map(|j| (oi, j))
            })
            .collect();

        // make sure the shared params are resident before borrowing them
        self.state.upload_dirty()?;

        let mut nll_sum = 0f64;
        let mut count = 0f64;
        let mut stats: BTreeMap<String, HostTensor> = BTreeMap::new();
        for _ in 0..segments {
            let tok = upload(client, &batcher.next_window()?)?;
            let out = {
                let refs: Vec<&xla::PjRtBuffer> = srcs
                    .iter()
                    .map(|s| match s {
                        EvalSrc::Param(ti) => self.state.buffer(*ti),
                        EvalSrc::Mem(j) => Ok(&mems[*j]),
                        EvalSrc::Tokens => Ok(&tok),
                        EvalSrc::Zero(z) => Ok(&zeros[*z]),
                    })
                    .collect::<Result<_>>()?;
                ev.run_buffers(&refs)?
            };
            nll_sum += download(client, &out[0])?.scalar_as_f32()? as f64;
            count += download(client, &out[1])?.scalar_as_f32()? as f64;
            for (oi, ob) in ev.spec.outputs.iter().enumerate() {
                if ob.name.starts_with("3.") {
                    stats.insert(ob.name.clone(), download(client, &out[oi])?);
                }
            }
            let mut out: Vec<Option<xla::PjRtBuffer>> =
                out.into_iter().map(Some).collect();
            for (oi, j) in &mem_feedback {
                let buf = out[*oi].take().ok_or_else(|| {
                    Error::other("eval feedback output consumed twice")
                })?;
                mems[*j] = buf;
            }
        }
        self.eval_mems = Some(mems);
        if count == 0.0 {
            return Err(Error::other("evaluate: zero tokens"));
        }
        Ok(EvalOutput {
            nll_sum,
            token_count: count,
            nll: nll_sum / count,
            stats,
        })
    }

    /// Reset the persistent eval memory (e.g. between eval corpora).
    pub fn reset_eval_memory(&mut self) {
        self.eval_mems = None;
    }
}
