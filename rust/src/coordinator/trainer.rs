//! The training coordinator: drives the AOT-compiled `train_step`
//! executable, owns all model/optimizer/XL-memory state, and wires
//! buffers **by manifest name** (positions shift when jax prunes unused
//! inputs, names never do).
//!
//! Signature conventions (see python/compile/api.py):
//!   train_step inputs : "0.<param>" "1.<m>" "2.<v>" "3.<mems>" "4"=tokens
//!                       "5"=step "6"=seed(optional)
//!   train_step outputs: "0"=loss "1"=grad_norm "2"=lr "3.<param>"
//!                       "4.<m>" "5.<v>" "6.<mems>" "7.<stats>"
//!   eval_step inputs  : "0.<param>" "1.<mems>" "2"=tokens
//!   eval_step outputs : "0"=nll_sum "1"=count "2.<mems>" "3.<stats>"

use std::collections::{BTreeMap, HashMap};

use crate::data::XlBatcher;
use crate::error::{Error, Result};
use crate::runtime::{ModelBundle, Program};
use crate::tensor::HostTensor;

/// Result of one optimization step.
#[derive(Debug, Clone)]
pub struct StepOutput {
    pub step: i64,
    pub loss: f32,
    pub grad_norm: f32,
    pub lr: f32,
    /// Named auxiliary statistics ("7.usage", "7.mean_prob", ...).
    pub stats: BTreeMap<String, HostTensor>,
}

/// Result of an evaluation pass.
#[derive(Debug, Clone)]
pub struct EvalOutput {
    pub nll_sum: f64,
    pub token_count: f64,
    /// mean nll in nats/token
    pub nll: f64,
    pub stats: BTreeMap<String, HostTensor>,
}

impl EvalOutput {
    /// Perplexity (word-level metric).
    pub fn perplexity(&self) -> f64 {
        self.nll.exp()
    }

    /// Bits per character (char-level metric).
    pub fn bpc(&self) -> f64 {
        self.nll / std::f64::consts::LN_2
    }
}

/// Maps outputs of a program back onto its own (or another program's)
/// inputs by renaming name prefixes.
fn feedback_map(
    prog: &Program,
    renames: &[(&str, &str)],
) -> Vec<(usize, usize)> {
    let by_name: HashMap<&str, usize> = prog
        .spec
        .inputs
        .iter()
        .enumerate()
        .map(|(i, b)| (b.name.as_str(), i))
        .collect();
    let mut out = Vec::new();
    for (oi, ob) in prog.spec.outputs.iter().enumerate() {
        for (from, to) in renames {
            if let Some(rest) = ob.name.strip_prefix(from) {
                let target = format!("{to}{rest}");
                if let Some(&ii) = by_name.get(target.as_str()) {
                    out.push((oi, ii));
                }
            }
        }
    }
    out
}

/// The trainer: owns the flattened train_step input state.
pub struct Trainer<'a> {
    pub bundle: &'a ModelBundle,
    state: Vec<HostTensor>,
    input_index: HashMap<String, usize>,
    feedback: Vec<(usize, usize)>,
    /// indices of param inputs ("0.*") in `state`, and the matching names
    param_slots: Vec<(String, usize)>,
    opt_slots: Vec<(String, usize)>,
    tok_idx: usize,
    step_idx: usize,
    seed_idx: Option<usize>,
    pub step: i64,
    pub seed: u32,
    /// eval-side XL memory (shape differs from train mems)
    eval_mems: Option<Vec<HostTensor>>,
}

impl<'a> Trainer<'a> {
    /// Initialize model parameters via the `init` program and set up all
    /// buffer wiring.
    pub fn new(bundle: &'a ModelBundle, seed: u32) -> Result<Self> {
        let ts = bundle.program("train_step")?;
        let spec = &ts.spec;
        let input_index: HashMap<String, usize> = spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, b)| (b.name.clone(), i))
            .collect();
        let mut state: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|b| HostTensor::zeros(b.dtype, &b.shape))
            .collect();

        // run init and scatter params into "0.<name>" slots
        let init = bundle.program("init")?;
        let params = init.run(&[HostTensor::scalar_u32(seed)])?;
        if params.len() != init.spec.outputs.len() {
            return Err(Error::Shape("init output arity mismatch".into()));
        }
        let mut param_slots = Vec::new();
        for (out, ob) in params.into_iter().zip(&init.spec.outputs) {
            let name = format!("0.{}", ob.name);
            let idx = *input_index.get(&name).ok_or_else(|| {
                Error::Manifest(format!("train_step has no input {name}"))
            })?;
            state[idx] = out;
            param_slots.push((ob.name.clone(), idx));
        }
        let opt_slots = spec
            .inputs
            .iter()
            .enumerate()
            .filter(|(_, b)| b.name.starts_with("1.") || b.name.starts_with("2."))
            .map(|(i, b)| (b.name.clone(), i))
            .collect();

        let tok_idx = *input_index
            .get("4")
            .ok_or_else(|| Error::Manifest("no tokens input '4'".into()))?;
        let step_idx = *input_index
            .get("5")
            .ok_or_else(|| Error::Manifest("no step input '5'".into()))?;
        let seed_idx = input_index.get("6").copied();
        let feedback = feedback_map(
            ts,
            &[("3.", "0."), ("4.", "1."), ("5.", "2."), ("6.", "3.")],
        );

        Ok(Trainer {
            bundle,
            state,
            input_index,
            feedback,
            param_slots,
            opt_slots,
            tok_idx,
            step_idx,
            seed_idx,
            step: 0,
            seed,
            eval_mems: None,
        })
    }

    /// Expected `[B, T+1]` token-window shape.
    pub fn token_shape(&self) -> &[usize] {
        &self.bundle.program("train_step").unwrap().spec.inputs[self.tok_idx].shape
    }

    /// Run one optimization step on a token window.
    pub fn step_on(&mut self, tokens: HostTensor) -> Result<StepOutput> {
        let ts = self.bundle.program("train_step")?;
        self.state[self.tok_idx] = tokens;
        self.state[self.step_idx] = HostTensor::scalar_i32(self.step as i32);
        if let Some(si) = self.seed_idx {
            self.state[si] = HostTensor::scalar_u32(self.seed);
        }
        let out = ts.run(&self.state)?;
        let loss = out[0].scalar_as_f32()?;
        let grad_norm = out[1].scalar_as_f32()?;
        let lr = out[2].scalar_as_f32()?;
        if !loss.is_finite() {
            return Err(Error::other(format!(
                "non-finite loss {loss} at step {}",
                self.step
            )));
        }
        let mut stats = BTreeMap::new();
        for (oi, ob) in ts.spec.outputs.iter().enumerate() {
            if ob.name.starts_with("7.") {
                stats.insert(ob.name.clone(), out[oi].clone());
            }
        }
        // Feed new state back by *moving* the output tensors into the
        // input slots (a clone here would memcpy every parameter +
        // optimizer tensor each step — see EXPERIMENTS.md §Perf).
        let mut out = out;
        for (oi, ii) in &self.feedback {
            self.state[*ii] =
                std::mem::replace(&mut out[*oi], HostTensor::zeros(
                    crate::tensor::DType::F32, &[]));
        }
        let so = StepOutput { step: self.step, loss, grad_norm, lr, stats };
        self.step += 1;
        Ok(so)
    }

    /// Train for `n` steps pulling windows from `batcher`; calls `on_step`
    /// after every step (metrics, logging, early stop).
    pub fn train(
        &mut self,
        batcher: &mut XlBatcher,
        n: usize,
        mut on_step: impl FnMut(&StepOutput),
    ) -> Result<Vec<StepOutput>> {
        let mut outs = Vec::with_capacity(n);
        for _ in 0..n {
            let w = batcher.next_window()?;
            let so = self.step_on(w)?;
            on_step(&so);
            outs.push(so);
        }
        Ok(outs)
    }

    /// Current parameters as (name, tensor) pairs.
    pub fn params(&self) -> Vec<(String, HostTensor)> {
        self.param_slots
            .iter()
            .map(|(name, idx)| (name.clone(), self.state[*idx].clone()))
            .collect()
    }

    /// Current optimizer state (m then v) as (name, tensor) pairs.
    pub fn opt_state(&self) -> Vec<(String, HostTensor)> {
        self.opt_slots
            .iter()
            .map(|(name, idx): &(String, usize)| {
                (name.clone(), self.state[*idx].clone())
            })
            .collect()
    }

    /// Restore parameters / optimizer state / step counter (from a
    /// checkpoint).  Missing names are an error; shapes are validated by
    /// the program on the next run.
    pub fn restore(
        &mut self,
        params: &[(String, HostTensor)],
        opt: &[(String, HostTensor)],
        step: i64,
    ) -> Result<()> {
        for (name, t) in params {
            let key = format!("0.{name}");
            let idx = *self.input_index.get(&key).ok_or_else(|| {
                Error::Checkpoint(format!("unknown param {name}"))
            })?;
            self.state[idx] = t.clone();
        }
        for (name, t) in opt {
            let idx = *self.input_index.get(name).ok_or_else(|| {
                Error::Checkpoint(format!("unknown opt slot {name}"))
            })?;
            self.state[idx] = t.clone();
        }
        self.step = step;
        Ok(())
    }

    /// Evaluate on `segments` consecutive windows from `batcher` with the
    /// long XL memory, using the *current* parameters.
    pub fn evaluate(
        &mut self,
        batcher: &mut XlBatcher,
        segments: usize,
    ) -> Result<EvalOutput> {
        let ev = self.bundle.program("eval_step")?;
        let spec = &ev.spec;
        let mut inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|b| HostTensor::zeros(b.dtype, &b.shape))
            .collect();
        let by_name: HashMap<&str, usize> = spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, b)| (b.name.as_str(), i))
            .collect();
        // params
        for (name, idx) in &self.param_slots {
            let key = format!("0.{name}");
            if let Some(&ii) = by_name.get(key.as_str()) {
                inputs[ii] = self.state[*idx].clone();
            }
        }
        // persistent eval mems across segments within this call
        let mem_slots: Vec<usize> = spec
            .inputs
            .iter()
            .enumerate()
            .filter(|(_, b)| b.name.starts_with("1."))
            .map(|(i, _)| i)
            .collect();
        if let Some(prev) = &self.eval_mems {
            if prev.len() == mem_slots.len()
                && prev
                    .iter()
                    .zip(&mem_slots)
                    .all(|(t, &i)| t.shape == spec.inputs[i].shape)
            {
                for (t, &i) in prev.iter().zip(&mem_slots) {
                    inputs[i] = t.clone();
                }
            }
        }
        let tok_idx = *by_name
            .get("2")
            .ok_or_else(|| Error::Manifest("no eval token input".into()))?;
        let mem_feedback = feedback_map(ev, &[("2.", "1.")]);

        let mut nll_sum = 0f64;
        let mut count = 0f64;
        let mut stats: BTreeMap<String, HostTensor> = BTreeMap::new();
        for _ in 0..segments {
            inputs[tok_idx] = batcher.next_window()?;
            let out = ev.run(&inputs)?;
            nll_sum += out[0].scalar_as_f32()? as f64;
            count += out[1].scalar_as_f32()? as f64;
            for (oi, ob) in ev.spec.outputs.iter().enumerate() {
                if ob.name.starts_with("3.") {
                    stats.insert(ob.name.clone(), out[oi].clone());
                }
            }
            for (oi, ii) in &mem_feedback {
                inputs[*ii] = out[*oi].clone();
            }
        }
        self.eval_mems = Some(
            mem_slots.iter().map(|&i| inputs[i].clone()).collect(),
        );
        if count == 0.0 {
            return Err(Error::other("evaluate: zero tokens"));
        }
        Ok(EvalOutput {
            nll_sum,
            token_count: count,
            nll: nll_sum / count,
            stats,
        })
    }

    /// Reset the persistent eval memory (e.g. between eval corpora).
    pub fn reset_eval_memory(&mut self) {
        self.eval_mems = None;
    }
}
