//! L3 coordination: training loop, evaluation, metrics, checkpointing.

pub mod checkpoint;
pub mod metrics;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use metrics::Metrics;
pub use trainer::{EvalOutput, StepOutput, Trainer};
