//! Checkpoints: params + optimizer state + step counter, in a simple
//! self-describing container (JSON header + raw little-endian blobs).
//!
//! Layout:
//!   magic "SMOE1\n"
//!   u64 header_len, then header JSON:
//!     {"step": n, "preset": "...", "entries": [{"name","dtype","shape",
//!      "offset","bytes"}...]}
//!   raw data blobs, concatenated.

use std::io::{Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::json::{self, Json};
use crate::tensor::{DType, HostTensor};

const MAGIC: &[u8] = b"SMOE1\n";

/// A named tensor collection with a step counter.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub step: i64,
    pub preset: String,
    pub params: Vec<(String, HostTensor)>,
    pub opt: Vec<(String, HostTensor)>,
}

impl Checkpoint {
    /// Snapshot a trainer's current state — the explicit host-sync
    /// boundary: device-resident params / optimizer tensors are
    /// downloaded here (and only here) before serialization.
    pub fn from_trainer(
        trainer: &mut super::trainer::Trainer,
        preset: impl Into<String>,
    ) -> Result<Self> {
        Ok(Checkpoint {
            step: trainer.step,
            preset: preset.into(),
            params: trainer.params()?,
            opt: trainer.opt_state()?,
        })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut entries = Vec::new();
        let mut blobs: Vec<&[u8]> = Vec::new();
        let mut offset = 0u64;
        for (section, list) in [("p", &self.params), ("o", &self.opt)] {
            for (name, t) in list.iter() {
                entries.push(json::obj(vec![
                    ("name", json::s(&format!("{section}:{name}"))),
                    ("dtype", json::s(t.dtype.name())),
                    (
                        "shape",
                        json::arr(
                            t.shape.iter().map(|&d| json::num(d as f64)).collect(),
                        ),
                    ),
                    ("offset", json::num(offset as f64)),
                    ("bytes", json::num(t.data.len() as f64)),
                ]));
                blobs.push(&t.data);
                offset += t.data.len() as u64;
            }
        }
        let header = json::obj(vec![
            ("step", json::num(self.step as f64)),
            ("preset", json::s(&self.preset)),
            ("entries", json::arr(entries)),
        ])
        .to_string_compact();

        let tmp = path.as_ref().with_extension("tmp");
        {
            let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
            f.write_all(MAGIC)?;
            f.write_all(&(header.len() as u64).to_le_bytes())?;
            f.write_all(header.as_bytes())?;
            for b in blobs {
                f.write_all(b)?;
            }
            f.flush()?;
        }
        std::fs::rename(&tmp, path.as_ref())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 6];
        f.read_exact(&mut magic)?;
        if magic != MAGIC {
            return Err(Error::Checkpoint("bad magic".into()));
        }
        let mut len8 = [0u8; 8];
        f.read_exact(&mut len8)?;
        let hlen = u64::from_le_bytes(len8) as usize;
        if hlen > 64 << 20 {
            return Err(Error::Checkpoint("header too large".into()));
        }
        let mut hbytes = vec![0u8; hlen];
        f.read_exact(&mut hbytes)?;
        let header = Json::parse(
            std::str::from_utf8(&hbytes)
                .map_err(|_| Error::Checkpoint("non-utf8 header".into()))?,
        )?;
        let step = header.get("step")?.as_i64()?;
        let preset = header.get("preset")?.as_str()?.to_string();
        let mut rest = Vec::new();
        f.read_to_end(&mut rest)?;

        let mut params = Vec::new();
        let mut opt = Vec::new();
        for e in header.get("entries")?.as_arr()? {
            let full = e.get("name")?.as_str()?;
            let (section, name) = full
                .split_once(':')
                .ok_or_else(|| Error::Checkpoint("bad entry name".into()))?;
            let dtype = DType::parse(e.get("dtype")?.as_str()?)?;
            let shape: Vec<usize> = e
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<std::result::Result<_, _>>()?;
            let off = e.get("offset")?.as_usize()?;
            let nbytes = e.get("bytes")?.as_usize()?;
            if off + nbytes > rest.len() {
                return Err(Error::Checkpoint("blob out of range".into()));
            }
            let expected: usize =
                shape.iter().product::<usize>() * dtype.size_bytes();
            if nbytes != expected {
                return Err(Error::Checkpoint(format!(
                    "{full}: blob size {nbytes} != shape size {expected}"
                )));
            }
            let t = HostTensor {
                dtype,
                shape,
                data: rest[off..off + nbytes].to_vec(),
            };
            match section {
                "p" => params.push((name.to_string(), t)),
                "o" => opt.push((name.to_string(), t)),
                other => {
                    return Err(Error::Checkpoint(format!(
                        "unknown section {other:?}"
                    )))
                }
            }
        }
        Ok(Checkpoint { step, preset, params, opt })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("sigma_moe_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let ck = Checkpoint {
            step: 42,
            preset: "tiny-moe".into(),
            params: vec![
                ("embed".into(),
                 HostTensor::from_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.])
                     .unwrap()),
                ("w".into(), HostTensor::scalar_f32(7.5)),
            ],
            opt: vec![("1.embed".into(),
                       HostTensor::from_i32(&[2], &[1, 2]).unwrap())],
        };
        let path = tmpfile("rt.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.preset, "tiny-moe");
        assert_eq!(back.params.len(), 2);
        assert_eq!(back.params[0].1.as_f32().unwrap(),
                   vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(back.opt[0].1.as_i32().unwrap(), vec![1, 2]);
    }

    #[test]
    fn rejects_corrupt_magic() {
        let path = tmpfile("bad.ckpt");
        std::fs::write(&path, b"NOPE!!rest").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn rejects_truncated_blob() {
        let ck = Checkpoint {
            step: 1,
            preset: "t".into(),
            params: vec![("w".into(),
                          HostTensor::from_f32(&[4], &[1., 2., 3., 4.])
                              .unwrap())],
            opt: vec![],
        };
        let path = tmpfile("trunc.ckpt");
        ck.save(&path).unwrap();
        let data = std::fs::read(&path).unwrap();
        std::fs::write(&path, &data[..data.len() - 4]).unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }
}
