//! sigma-moe: a three-layer (Rust ⇄ XLA/PJRT ⇄ JAX+Pallas) reproduction
//! of "Approximating Two-Layer Feedforward Networks for Efficient
//! Transformers" (Csordás, Irie & Schmidhuber, EMNLP 2023 Findings).
//!
//! * L1 (build time): Pallas kernels — CVMM, Top-K activation, PKM
//!   candidate search (`python/compile/kernels/`).
//! * L2 (build time): JAX Transformer-XL with σ-MoE / PKM / Top-K / dense
//!   feedforward variants, AOT-lowered to HLO text (`python/compile/`).
//! * L3 (this crate): the coordinator — data pipeline, training loop,
//!   evaluation, checkpointing, serving, analysis — driving the
//!   AOT-compiled executables through PJRT.  Python never runs on the
//!   request path.

pub mod analysis;
pub mod bench_util;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod flops;
pub mod json;
pub mod rng;
pub mod runtime;
pub mod serving;
pub mod tensor;

pub use error::{Error, Result};

/// Default artifacts directory: `$SIGMA_MOE_ARTIFACTS` or `./artifacts`.
pub fn artifacts_root() -> std::path::PathBuf {
    std::env::var_os("SIGMA_MOE_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
