//! Minimal benchmarking harness (criterion is not in the offline vendor
//! set): warmup + timed iterations with mean / median / p10 / p90,
//! criterion-like one-line reports, and a machine-readable JSON sink so
//! the perf trajectory is tracked across PRs (BENCH_train.json).

use std::path::Path;
use std::time::{Duration, Instant};

use crate::json::{self, Json};

/// Timing summary over N iterations.
#[derive(Debug, Clone)]
pub struct Summary {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
}

impl Summary {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10.3?} median  {:>10.3?} mean  [{:.3?} .. {:.3?}]  n={}",
            self.name, self.median, self.mean, self.p10, self.p90, self.iters
        )
    }

    /// Timing fields as a JSON object (seconds), for [`write_bench_json`].
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("name", json::s(&self.name)),
            ("iters", json::num(self.iters as f64)),
            ("mean_s", json::num(self.mean.as_secs_f64())),
            ("median_s", json::num(self.median.as_secs_f64())),
            ("p10_s", json::num(self.p10.as_secs_f64())),
            ("p90_s", json::num(self.p90.as_secs_f64())),
        ])
    }
}

/// Write bench results as a machine-readable JSON document:
/// `{"schema": "...", "results": [...]}`.  Benches call this with one
/// object per (preset, mode) so CI / later PRs can diff the numbers.
pub fn write_bench_json(
    path: impl AsRef<Path>,
    schema: &str,
    results: Vec<Json>,
) -> std::io::Result<()> {
    let doc = json::obj(vec![
        ("schema", json::s(schema)),
        ("results", json::arr(results)),
    ]);
    std::fs::write(path, doc.to_string_compact())
}

/// Run `f` for `warmup` unmeasured and `iters` measured iterations.
pub fn bench(name: &str, warmup: usize, iters: usize,
             mut f: impl FnMut()) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let mean = times.iter().sum::<Duration>() / iters.max(1) as u32;
    let q = |p: f64| times[((times.len() - 1) as f64 * p) as usize];
    Summary {
        name: name.to_string(),
        iters,
        mean,
        median: q(0.5),
        p10: q(0.1),
        p90: q(0.9),
    }
}

/// Like [`bench`] but stops early once `budget` wall time is spent.
pub fn bench_budget(name: &str, warmup: usize, max_iters: usize,
                    budget: Duration, mut f: impl FnMut()) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let start = Instant::now();
    let mut times = Vec::new();
    for _ in 0..max_iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
        if start.elapsed() > budget {
            break;
        }
    }
    times.sort();
    let n = times.len().max(1);
    let mean = times.iter().sum::<Duration>() / n as u32;
    let q = |p: f64| times[((times.len() - 1) as f64 * p) as usize];
    Summary {
        name: name.to_string(),
        iters: times.len(),
        mean,
        median: q(0.5),
        p10: q(0.1),
        p90: q(0.9),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let s = bench("x", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.iters, 5);
        assert!(s.mean >= Duration::ZERO);
    }

    #[test]
    fn budget_stops_early() {
        let s = bench_budget("y", 0, 1_000_000, Duration::from_millis(30),
                             || std::thread::sleep(Duration::from_millis(5)));
        assert!(s.iters < 100);
    }
}
