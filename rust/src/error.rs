//! Library-wide error type.

/// Unified error for the sigma-moe library.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("xla error: {0}")]
    Xla(#[from] xla::Error),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("json error: {0}")]
    Json(#[from] crate::json::JsonError),
    #[error("manifest error: {0}")]
    Manifest(String),
    #[error("shape error: {0}")]
    Shape(String),
    #[error("config error: {0}")]
    Config(String),
    #[error("checkpoint error: {0}")]
    Checkpoint(String),
    #[error("data error: {0}")]
    Data(String),
    #[error("serving error: {0}")]
    Serving(String),
    #[error("{0}")]
    Other(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn other(msg: impl Into<String>) -> Self {
        Error::Other(msg.into())
    }
}
