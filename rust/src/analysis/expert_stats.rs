//! Expert-utilization statistics accumulated from the `stats` outputs of
//! train/eval steps.
//!
//! The paper's Fig. 3/7 plot, per layer, the total proportion of
//! selection weight assigned to each expert over the validation set,
//! sorted by popularity — expert collapse shows up as a near-delta
//! distribution.  Fig. 6 plots the co-occurrence of experts selected
//! together for the same token (K > 1).

use crate::error::{Error, Result};
use crate::tensor::HostTensor;

/// Accumulator over per-layer expert statistics.
#[derive(Debug, Clone)]
pub struct ExpertStats {
    pub n_layers: usize,
    pub n_experts: usize,
    /// summed selection weights per layer/expert [L][E]
    pub sel_weight: Vec<Vec<f64>>,
    /// summed selection counts per layer/expert [L][E]
    pub usage: Vec<Vec<f64>>,
    /// summed co-occurrence per layer [L][E*E] (row-major), optional
    pub cooccurrence: Option<Vec<Vec<f64>>>,
    pub segments: usize,
}

impl ExpertStats {
    pub fn new(n_layers: usize, n_experts: usize) -> Self {
        ExpertStats {
            n_layers,
            n_experts,
            sel_weight: vec![vec![0.0; n_experts]; n_layers],
            usage: vec![vec![0.0; n_experts]; n_layers],
            cooccurrence: None,
            segments: 0,
        }
    }

    /// Accumulate one step's stats map (keys like "7.usage" /
    /// "3.sel_weight" / "...cooccurrence", each an [L, E] or [L, E, E]
    /// f32 tensor).
    pub fn accumulate(
        &mut self,
        stats: &std::collections::BTreeMap<String, HostTensor>,
    ) -> Result<()> {
        for (key, t) in stats {
            if key.ends_with(".usage") {
                self.add_le(&mut |s: &mut Self| &mut s.usage, t)?;
            } else if key.ends_with(".sel_weight") {
                self.add_le(&mut |s: &mut Self| &mut s.sel_weight, t)?;
            } else if key.ends_with(".cooccurrence") {
                self.add_cooc(t)?;
            }
        }
        self.segments += 1;
        Ok(())
    }

    fn add_le(
        &mut self,
        field: &mut impl FnMut(&mut Self) -> &mut Vec<Vec<f64>>,
        t: &HostTensor,
    ) -> Result<()> {
        let (l, e) = (self.n_layers, self.n_experts);
        if t.shape != [l, e] {
            return Err(Error::Shape(format!(
                "expected [{l}, {e}] stats, got {:?}",
                t.shape
            )));
        }
        let vals = t.as_f32()?;
        let dst = field(self);
        for li in 0..l {
            for ei in 0..e {
                dst[li][ei] += vals[li * e + ei] as f64;
            }
        }
        Ok(())
    }

    fn add_cooc(&mut self, t: &HostTensor) -> Result<()> {
        let (l, e) = (self.n_layers, self.n_experts);
        if t.shape != [l, e, e] {
            return Err(Error::Shape(format!(
                "expected [{l}, {e}, {e}] cooccurrence, got {:?}",
                t.shape
            )));
        }
        let vals = t.as_f32()?;
        let cooc = self
            .cooccurrence
            .get_or_insert_with(|| vec![vec![0.0; e * e]; l]);
        for li in 0..l {
            for i in 0..e * e {
                cooc[li][i] += vals[li * e * e + i] as f64;
            }
        }
        Ok(())
    }

    /// Fig. 3/7 series for one layer: proportions of total selection
    /// weight per expert, sorted descending.
    pub fn sorted_proportions(&self, layer: usize) -> Vec<f64> {
        let total: f64 = self.sel_weight[layer].iter().sum();
        let mut p: Vec<f64> = self.sel_weight[layer]
            .iter()
            .map(|w| if total > 0.0 { w / total } else { 0.0 })
            .collect();
        p.sort_by(|a, b| b.partial_cmp(a).unwrap());
        p
    }

    /// Report for a whole model.
    pub fn report(&self) -> UtilizationReport {
        let mut layers = Vec::new();
        for l in 0..self.n_layers {
            let p = self.sorted_proportions(l);
            layers.push(LayerUtilization {
                proportions: p.clone(),
                entropy: entropy(&p),
                max_share: p.first().copied().unwrap_or(0.0),
                unused: p.iter().filter(|&&x| x < 1e-6).count(),
            });
        }
        UtilizationReport { n_experts: self.n_experts, layers }
    }
}

/// Shannon entropy in nats of a probability vector.
fn entropy(p: &[f64]) -> f64 {
    -p.iter()
        .filter(|&&x| x > 0.0)
        .map(|&x| x * x.ln())
        .sum::<f64>()
}

/// Per-layer utilization summary.
#[derive(Debug, Clone)]
pub struct LayerUtilization {
    pub proportions: Vec<f64>,
    pub entropy: f64,
    pub max_share: f64,
    pub unused: usize,
}

/// Whole-model utilization report with collapse detection.
#[derive(Debug, Clone)]
pub struct UtilizationReport {
    pub n_experts: usize,
    pub layers: Vec<LayerUtilization>,
}

impl UtilizationReport {
    /// The paper's collapse criterion (informal): a layer is collapsed
    /// when a few experts hold almost all selection weight.  We flag a
    /// layer when its utilization entropy is below half the uniform
    /// entropy or > 25% of experts are unused.
    pub fn collapsed_layers(&self) -> Vec<usize> {
        let uniform = (self.n_experts as f64).ln();
        self.layers
            .iter()
            .enumerate()
            .filter(|(_, l)| {
                l.entropy < 0.5 * uniform
                    || l.unused * 4 > self.n_experts
            })
            .map(|(i, _)| i)
            .collect()
    }

    /// Render the Fig. 3-style table for one layer.
    pub fn format_layer(&self, layer: usize) -> String {
        let l = &self.layers[layer];
        let mut s = format!(
            "layer {layer}: entropy {:.3} nats (uniform {:.3}), top share {:.1}%, unused {}\n",
            l.entropy,
            (self.n_experts as f64).ln(),
            100.0 * l.max_share,
            l.unused
        );
        s.push_str("  proportions (sorted): ");
        for p in &l.proportions {
            s.push_str(&format!("{:.3} ", p));
        }
        s.push('\n');
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn stats_with(key: &str, t: HostTensor) -> BTreeMap<String, HostTensor> {
        let mut m = BTreeMap::new();
        m.insert(key.to_string(), t);
        m
    }

    #[test]
    fn accumulates_and_sorts() {
        let mut s = ExpertStats::new(1, 4);
        let t = HostTensor::from_f32(&[1, 4], &[1.0, 3.0, 0.0, 0.0]).unwrap();
        s.accumulate(&stats_with("7.sel_weight", t.clone())).unwrap();
        s.accumulate(&stats_with("7.sel_weight", t)).unwrap();
        let p = s.sorted_proportions(0);
        assert!((p[0] - 0.75).abs() < 1e-9);
        assert!((p[1] - 0.25).abs() < 1e-9);
        assert_eq!(s.segments, 2);
    }

    #[test]
    fn collapse_detection() {
        let mut s = ExpertStats::new(2, 8);
        // layer 0: uniform; layer 1: fully collapsed onto expert 0
        let mut vals = vec![1.0f32; 8];
        vals.extend([100.0, 0., 0., 0., 0., 0., 0., 0.]);
        let t = HostTensor::from_f32(&[2, 8], &vals).unwrap();
        s.accumulate(&stats_with("7.sel_weight", t)).unwrap();
        let rep = s.report();
        assert_eq!(rep.collapsed_layers(), vec![1]);
        assert!(rep.layers[0].entropy > rep.layers[1].entropy);
    }

    #[test]
    fn cooccurrence_shape_checked() {
        let mut s = ExpertStats::new(1, 2);
        let bad = HostTensor::from_f32(&[1, 3, 3], &[0.0; 9]).unwrap();
        assert!(s
            .accumulate(&stats_with("3.cooccurrence", bad))
            .is_err());
        let good = HostTensor::from_f32(&[1, 2, 2], &[1., 2., 3., 4.]).unwrap();
        let mut s2 = ExpertStats::new(1, 2);
        s2.accumulate(&stats_with("3.cooccurrence", good)).unwrap();
        assert_eq!(s2.cooccurrence.as_ref().unwrap()[0], vec![1., 2., 3., 4.]);
    }
}
