//! Analysis tooling: expert utilization (Figs. 3 & 7), co-occurrence
//! (Fig. 6), active-channel counts (Figs. 1/4/5), collapse detection.

pub mod expert_stats;

pub use expert_stats::{ExpertStats, UtilizationReport};
