//! Synthetic corpus generators — the data substitution for WikiText-103,
//! Enwik8, C4 and peS2o (see DESIGN.md §Substitutions).
//!
//! * [`ZipfMarkov`] ("wikitext-like"): a power-law unigram distribution
//!   composed with an order-2 Markov chain over a latent topic state, so
//!   the stream has both the heavy-tailed vocabulary statistics and the
//!   local predictability real text has.  Different `flavor` seeds play
//!   the role of different corpora (C4, peS2o).
//! * [`MarkupBytes`] ("enwik8-like"): a byte stream of nested wiki-style
//!   markup with embedded pseudo-natural words — structured enough that
//!   bits/character improves rapidly with context, like enwik8.

use crate::rng::{Rng, Zipf};

/// A source of token/byte streams.
pub trait Corpus {
    /// Vocabulary size of the stream.
    fn vocab_size(&self) -> usize;
    /// Generate the next token.
    fn next_token(&mut self) -> u32;
    /// Fill a buffer with consecutive tokens.
    fn fill(&mut self, out: &mut [i32]) {
        for slot in out {
            *slot = self.next_token() as i32;
        }
    }
    /// Generate n tokens.
    fn take_vec(&mut self, n: usize) -> Vec<i32> {
        let mut v = vec![0i32; n];
        self.fill(&mut v);
        v
    }
}

/// Heavy-tailed Markov token stream over a configurable vocabulary.
pub struct ZipfMarkov {
    vocab: usize,
    zipf: Zipf,
    rng: Rng,
    /// per-(state) preferred continuation table: state -> candidate set
    table: Vec<Vec<u32>>,
    /// probability of following the Markov table vs drawing fresh Zipf
    coherence: f64,
    state: (u32, u32),
}

impl ZipfMarkov {
    /// `flavor` selects a different deterministic transition table —
    /// our stand-in for "different dataset" (0 = wikitext-ish, 1 = c4-ish,
    /// 2 = pes2o-ish).
    pub fn new(vocab: usize, seed: u64, flavor: u64) -> Self {
        assert!(vocab >= 16, "vocab too small: {vocab}");
        let mut table_rng = Rng::new(0xC0FFEE ^ flavor.wrapping_mul(0x9E37));
        let zipf = Zipf::new(vocab, 1.05);
        // Order-2-ish: hash the last two tokens into 4096 states; each
        // state prefers a small candidate set of continuations -> the
        // stream is locally predictable (learnable by a small LM).
        let n_states = 4096.min(vocab * 8);
        let mut table = Vec::with_capacity(n_states);
        for _ in 0..n_states {
            let k = 2 + table_rng.below(6);
            let cands: Vec<u32> = (0..k)
                .map(|_| zipf.sample(&mut table_rng) as u32)
                .collect();
            table.push(cands);
        }
        ZipfMarkov {
            vocab,
            zipf,
            rng: Rng::new(seed),
            table,
            coherence: 0.85,
            state: (0, 1),
        }
    }

    fn state_index(&self) -> usize {
        let (a, b) = self.state;
        let h = (a as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((b as u64).wrapping_mul(0x94D049BB133111EB));
        (h >> 17) as usize % self.table.len()
    }
}

impl Corpus for ZipfMarkov {
    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn next_token(&mut self) -> u32 {
        let tok = if self.rng.coin(self.coherence) {
            let cands = &self.table[self.state_index()];
            cands[self.rng.below(cands.len())]
        } else {
            self.zipf.sample(&mut self.rng) as u32
        };
        self.state = (self.state.1, tok);
        tok
    }
}

/// Enwik8-like structured byte stream: nested tags, attributes, words.
pub struct MarkupBytes {
    rng: Rng,
    buf: Vec<u8>,
    pos: usize,
    depth: usize,
    words: Vec<Vec<u8>>,
}

impl MarkupBytes {
    pub fn new(seed: u64) -> Self {
        let mut word_rng = Rng::new(0xBEEF ^ seed.rotate_left(13));
        // a fixed pseudo-vocabulary of word shapes
        let zipf = Zipf::new(800, 1.1);
        let mut words = Vec::with_capacity(800);
        for _ in 0..800 {
            let len = 2 + word_rng.below(8);
            let w: Vec<u8> = (0..len)
                .map(|_| b"etaoinshrdlucmfwypvbgkqjxz"[word_rng.below(26)])
                .collect();
            words.push(w);
        }
        let _ = zipf;
        MarkupBytes { rng: Rng::new(seed), buf: Vec::new(), pos: 0,
                      depth: 0, words }
    }

    fn refill(&mut self) {
        self.buf.clear();
        self.pos = 0;
        let tags: [&[u8]; 4] = [b"page", b"title", b"text", b"ref"];
        // emit one structural element
        if self.depth < 3 && self.rng.coin(0.3) {
            let t = tags[self.rng.below(tags.len())];
            self.buf.push(b'<');
            self.buf.extend_from_slice(t);
            self.buf.push(b'>');
            self.depth += 1;
        } else if self.depth > 0 && self.rng.coin(0.3) {
            let t = tags[self.rng.below(tags.len())];
            self.buf.extend_from_slice(b"</");
            self.buf.extend_from_slice(t);
            self.buf.push(b'>');
            self.depth -= 1;
        } else {
            // a short sentence of zipf-ish words
            let zipf = Zipf::new(self.words.len(), 1.1);
            let n = 3 + self.rng.below(9);
            for i in 0..n {
                if i > 0 {
                    self.buf.push(b' ');
                }
                let w = &self.words[zipf.sample(&mut self.rng)];
                self.buf.extend_from_slice(w);
            }
            self.buf.extend_from_slice(if self.rng.coin(0.5) {
                b". "
            } else {
                b",\n"
            });
        }
    }
}

impl Corpus for MarkupBytes {
    fn vocab_size(&self) -> usize {
        256
    }

    fn next_token(&mut self) -> u32 {
        if self.pos >= self.buf.len() {
            self.refill();
        }
        let b = self.buf[self.pos];
        self.pos += 1;
        b as u32
    }
}

/// Build a corpus by name ("wikitext" | "c4" | "pes2o" | "enwik8").
pub fn by_name(name: &str, vocab: usize, seed: u64) -> crate::Result<Box<dyn Corpus + Send>> {
    match name {
        "wikitext" => Ok(Box::new(ZipfMarkov::new(vocab, seed, 0))),
        "c4" => Ok(Box::new(ZipfMarkov::new(vocab, seed, 1))),
        "pes2o" => Ok(Box::new(ZipfMarkov::new(vocab, seed, 2))),
        "enwik8" => Ok(Box::new(MarkupBytes::new(seed))),
        other => Err(crate::Error::Data(format!(
            "unknown corpus {other:?} (wikitext|c4|pes2o|enwik8)"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_markov_in_vocab_and_deterministic() {
        let mut a = ZipfMarkov::new(512, 1, 0);
        let mut b = ZipfMarkov::new(512, 1, 0);
        let ta = a.take_vec(2000);
        let tb = b.take_vec(2000);
        assert_eq!(ta, tb);
        assert!(ta.iter().all(|&t| (t as usize) < 512));
    }

    #[test]
    fn zipf_markov_flavors_differ() {
        let mut a = ZipfMarkov::new(512, 1, 0);
        let mut b = ZipfMarkov::new(512, 1, 1);
        assert_ne!(a.take_vec(500), b.take_vec(500));
    }

    #[test]
    fn zipf_markov_is_heavy_tailed() {
        let mut c = ZipfMarkov::new(1024, 2, 0);
        let toks = c.take_vec(20_000);
        let mut counts = vec![0usize; 1024];
        for t in toks {
            counts[t as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top20: usize = counts[..20].iter().sum();
        assert!(top20 * 2 > 20_000, "not heavy tailed: top20={top20}");
    }

    #[test]
    fn zipf_markov_is_locally_predictable() {
        // bigram entropy must be far below unigram entropy
        let mut c = ZipfMarkov::new(256, 3, 0);
        let toks = c.take_vec(60_000);
        let mut uni = vec![0f64; 256];
        let mut big = std::collections::HashMap::new();
        for w in toks.windows(2) {
            uni[w[0] as usize] += 1.0;
            *big.entry((w[0], w[1])).or_insert(0f64) += 1.0;
        }
        let n = (toks.len() - 1) as f64;
        let h_uni: f64 = uni
            .iter()
            .filter(|&&c| c > 0.0)
            .map(|&c| -(c / n) * (c / n).ln())
            .sum();
        let h_joint: f64 = big
            .values()
            .map(|&c| -(c / n) * (c / n).ln())
            .sum();
        let h_cond = h_joint - h_uni;
        // order-2 structure measured with a bigram probe: expect a clear
        // but not total reduction vs the unigram entropy.
        assert!(h_cond < 0.85 * h_uni,
                "conditional entropy {h_cond} vs unigram {h_uni}");
    }

    #[test]
    fn markup_bytes_look_like_markup() {
        let mut c = MarkupBytes::new(4);
        let bytes = c.take_vec(5000);
        assert!(bytes.iter().all(|&b| (0..256).contains(&b)));
        let text: Vec<u8> = bytes.iter().map(|&b| b as u8).collect();
        let s = String::from_utf8_lossy(&text);
        assert!(s.contains('<') && s.contains('>') && s.contains(' '));
    }

    #[test]
    fn by_name_dispatch() {
        assert!(by_name("wikitext", 256, 0).is_ok());
        assert!(by_name("enwik8", 256, 0).is_ok());
        assert!(by_name("nope", 256, 0).is_err());
    }
}
