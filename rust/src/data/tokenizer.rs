//! Tokenizers for serving and for ingesting real text files when the
//! user supplies them (the training path normally consumes synthetic
//! token streams directly).
//!
//! * [`CharTokenizer`] — byte-level (enwik8-style), identity vocab of 256.
//! * [`WordTokenizer`] — whitespace/punctuation word-level with a
//!   frequency-built vocabulary and `<unk>`, mirroring the paper's
//!   subword setup at our scale.

use std::collections::HashMap;

use crate::error::{Error, Result};

pub const UNK: u32 = 0;

/// Byte-level tokenizer: token = byte value.
#[derive(Debug, Default, Clone)]
pub struct CharTokenizer;

impl CharTokenizer {
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| b as i32).collect()
    }

    pub fn decode(&self, toks: &[i32]) -> String {
        let bytes: Vec<u8> = toks
            .iter()
            .filter(|&&t| (0..256).contains(&t))
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn vocab_size(&self) -> usize {
        256
    }
}

/// Word-level tokenizer with a built vocabulary.
#[derive(Debug, Clone)]
pub struct WordTokenizer {
    vocab: Vec<String>,
    index: HashMap<String, u32>,
}

impl WordTokenizer {
    /// Build from a training text, keeping the `max_vocab - 1` most
    /// frequent words (id 0 is `<unk>`).  Ties break lexicographically
    /// so vocabularies are deterministic.
    pub fn build(text: &str, max_vocab: usize) -> Result<Self> {
        if max_vocab < 2 {
            return Err(Error::Data("max_vocab must be >= 2".into()));
        }
        let mut counts: HashMap<&str, u64> = HashMap::new();
        for w in text.split(|c: char| c.is_whitespace()) {
            if !w.is_empty() {
                *counts.entry(w).or_insert(0) += 1;
            }
        }
        let mut by_freq: Vec<(&str, u64)> = counts.into_iter().collect();
        by_freq.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
        let mut vocab = vec!["<unk>".to_string()];
        vocab.extend(
            by_freq
                .into_iter()
                .take(max_vocab - 1)
                .map(|(w, _)| w.to_string()),
        );
        let index = vocab
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as u32))
            .collect();
        Ok(WordTokenizer { vocab, index })
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.split(|c: char| c.is_whitespace())
            .filter(|w| !w.is_empty())
            .map(|w| *self.index.get(w).unwrap_or(&UNK) as i32)
            .collect()
    }

    pub fn decode(&self, toks: &[i32]) -> String {
        toks.iter()
            .map(|&t| {
                self.vocab
                    .get(t.max(0) as usize)
                    .map(|s| s.as_str())
                    .unwrap_or("<unk>")
            })
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn char_roundtrip() {
        let t = CharTokenizer;
        let s = "hello <page> world\n";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn word_build_and_encode() {
        let t = WordTokenizer::build("a b b c c c", 10).unwrap();
        assert_eq!(t.vocab_size(), 4); // unk a b c
        let enc = t.encode("c b a zzz");
        assert_eq!(enc.len(), 4);
        assert_eq!(enc[3], UNK as i32);
        assert_eq!(t.decode(&enc), "c b a <unk>");
    }

    #[test]
    fn word_vocab_truncation_keeps_most_frequent() {
        let t = WordTokenizer::build("x x x y y z", 3).unwrap();
        // vocab: <unk>, x, y
        assert_eq!(t.vocab_size(), 3);
        assert_ne!(t.encode("x")[0], UNK as i32);
        assert_ne!(t.encode("y")[0], UNK as i32);
        assert_eq!(t.encode("z")[0], UNK as i32);
    }

    #[test]
    fn word_vocab_deterministic() {
        let a = WordTokenizer::build("p q r p q p", 5).unwrap();
        let b = WordTokenizer::build("p q r p q p", 5).unwrap();
        assert_eq!(a.encode("p q r"), b.encode("p q r"));
    }
}
