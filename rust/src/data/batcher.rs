//! Transformer-XL batching: B independent contiguous token streams.
//!
//! XL training requires that consecutive segments of one batch row be
//! consecutive in the underlying stream (the memory carries state across
//! the segment boundary).  The batcher therefore maintains `batch_size`
//! independent corpus streams, each filling one row.  Targets are the
//! inputs shifted by one, so each call produces a `[B, T+1]` window whose
//! last token of call *n* equals the first token of call *n+1*.

use crate::data::corpus::Corpus;
use crate::tensor::HostTensor;
use crate::Result;

/// Produces consecutive `[B, T+1]` token windows for XL training.
pub struct XlBatcher {
    streams: Vec<Box<dyn Corpus + Send>>,
    /// carry-over: last token of the previous window per row
    carry: Vec<Option<i32>>,
    pub batch: usize,
    pub seg_len: usize,
    pub tokens_served: u64,
}

impl XlBatcher {
    pub fn new(streams: Vec<Box<dyn Corpus + Send>>, seg_len: usize) -> Self {
        let batch = streams.len();
        XlBatcher {
            streams,
            carry: vec![None; batch],
            batch,
            seg_len,
            tokens_served: 0,
        }
    }

    /// Next `[B, T+1]` window as a HostTensor (i32).
    pub fn next_window(&mut self) -> Result<HostTensor> {
        let t1 = self.seg_len + 1;
        let mut data = vec![0i32; self.batch * t1];
        for (b, stream) in self.streams.iter_mut().enumerate() {
            let row = &mut data[b * t1..(b + 1) * t1];
            match self.carry[b] {
                Some(tok) => {
                    row[0] = tok;
                    stream.fill(&mut row[1..]);
                }
                None => stream.fill(row),
            }
            self.carry[b] = Some(row[t1 - 1]);
        }
        self.tokens_served += (self.batch * self.seg_len) as u64;
        HostTensor::from_i32(&[self.batch, t1], &data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::ZipfMarkov;

    fn mk(batch: usize, seg: usize) -> XlBatcher {
        let streams: Vec<Box<dyn Corpus + Send>> = (0..batch)
            .map(|i| {
                Box::new(ZipfMarkov::new(128, 42 + i as u64, 0))
                    as Box<dyn Corpus + Send>
            })
            .collect();
        XlBatcher::new(streams, seg)
    }

    #[test]
    fn window_shape() {
        let mut b = mk(4, 16);
        let w = b.next_window().unwrap();
        assert_eq!(w.shape, vec![4, 17]);
    }

    #[test]
    fn windows_are_contiguous_per_row() {
        let mut b = mk(3, 8);
        let w1 = b.next_window().unwrap().as_i32().unwrap();
        let w2 = b.next_window().unwrap().as_i32().unwrap();
        for row in 0..3 {
            // last token of w1 row == first token of w2 row
            assert_eq!(w1[row * 9 + 8], w2[row * 9]);
        }
    }

    #[test]
    fn window_continuity_holds_over_many_windows() {
        // last token of window n == first token of window n+1, per row,
        // sustained over a long horizon (the XL memory contract)
        let mut b = mk(4, 6);
        let t1 = 7;
        let mut prev: Option<Vec<i32>> = None;
        for _ in 0..12 {
            let w = b.next_window().unwrap().as_i32().unwrap();
            if let Some(p) = &prev {
                for row in 0..4 {
                    assert_eq!(
                        p[row * t1 + t1 - 1],
                        w[row * t1],
                        "row {row} breaks continuity"
                    );
                }
            }
            prev = Some(w);
        }
    }

    #[test]
    fn rows_are_independent_streams() {
        let mut b = mk(2, 32);
        let w = b.next_window().unwrap().as_i32().unwrap();
        assert_ne!(&w[..33], &w[33..66]);
    }

    #[test]
    fn token_accounting() {
        let mut b = mk(2, 8);
        b.next_window().unwrap();
        b.next_window().unwrap();
        assert_eq!(b.tokens_served, 2 * 2 * 8);
    }
}
