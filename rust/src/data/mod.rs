//! Data pipeline: synthetic corpora, tokenizers, and the XL batcher.

pub mod batcher;
pub mod corpus;
pub mod tokenizer;

pub use batcher::XlBatcher;
pub use corpus::{by_name, Corpus, MarkupBytes, ZipfMarkov};
pub use tokenizer::{CharTokenizer, WordTokenizer};

use crate::Result;

/// A corpus over a fixed token buffer (cycled), used to ingest real
/// text files through a tokenizer.  Each stream starts at a different
/// phase so batch rows are decorrelated.
pub struct TokenSlice {
    tokens: std::sync::Arc<Vec<i32>>,
    pos: usize,
    vocab: usize,
}

impl TokenSlice {
    pub fn new(tokens: std::sync::Arc<Vec<i32>>, start: usize,
               vocab: usize) -> Result<Self> {
        if tokens.is_empty() {
            return Err(crate::Error::Data("empty token buffer".into()));
        }
        let pos = start % tokens.len();
        Ok(TokenSlice { tokens, pos, vocab })
    }
}

impl Corpus for TokenSlice {
    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn next_token(&mut self) -> u32 {
        let t = self.tokens[self.pos];
        self.pos = (self.pos + 1) % self.tokens.len();
        t.max(0) as u32
    }
}

/// Ingest a real text file: tokenize (char-level when `vocab <= 256`,
/// word-level otherwise) and build an [`XlBatcher`] whose rows start at
/// evenly-spaced offsets — the standard contiguous-stream XL setup.
pub fn batcher_from_file(
    path: impl AsRef<std::path::Path>,
    vocab: usize,
    batch: usize,
    seg_len: usize,
) -> Result<XlBatcher> {
    let text = std::fs::read_to_string(path)?;
    let tokens: Vec<i32> = if vocab <= 256 {
        CharTokenizer.encode(&text)
    } else {
        let tok = WordTokenizer::build(&text, vocab)?;
        tok.encode(&text)
    };
    let tokens = std::sync::Arc::new(tokens);
    let n = tokens.len();
    let streams: Vec<Box<dyn Corpus + Send>> = (0..batch)
        .map(|i| -> Result<Box<dyn Corpus + Send>> {
            Ok(Box::new(TokenSlice::new(
                tokens.clone(),
                i * n / batch.max(1),
                vocab,
            )?))
        })
        .collect::<Result<_>>()?;
    Ok(XlBatcher::new(streams, seg_len))
}

/// Build an [`XlBatcher`] with `batch` independent streams of the named
/// corpus, deterministically seeded from `seed`.
pub fn batcher_for(
    corpus: &str,
    vocab: usize,
    batch: usize,
    seg_len: usize,
    seed: u64,
) -> Result<XlBatcher> {
    let streams = (0..batch)
        .map(|i| by_name(corpus, vocab, seed.wrapping_add(i as u64 * 7919)))
        .collect::<Result<Vec<_>>>()?;
    Ok(XlBatcher::new(streams, seg_len))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn file_ingestion_char_level() {
        let dir = std::env::temp_dir().join("sigma_moe_data");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.txt");
        std::fs::write(&path, "hello world, hello again. ").unwrap();
        let mut b = batcher_from_file(&path, 256, 2, 8).unwrap();
        let w = b.next_window().unwrap();
        assert_eq!(w.shape, vec![2, 9]);
        let vals = w.as_i32().unwrap();
        assert!(vals.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn file_ingestion_word_level() {
        let dir = std::env::temp_dir().join("sigma_moe_data");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.txt");
        std::fs::write(&path, "a b c d e f g h a b c d").unwrap();
        let mut b = batcher_from_file(&path, 1000, 1, 4).unwrap();
        let w = b.next_window().unwrap();
        assert_eq!(w.shape, vec![1, 5]);
    }

    #[test]
    fn token_slice_cycles() {
        let toks = std::sync::Arc::new(vec![1, 2, 3]);
        let mut s = TokenSlice::new(toks, 2, 256).unwrap();
        assert_eq!(s.take_vec(5), vec![3, 1, 2, 3, 1]);
    }
}
