//! sigma-moe launcher: train / eval / serve / loadgen / analyze /
//! paper-table drivers over AOT-compiled artifacts.
//!
//! Examples:
//!   sigma-moe train --preset tiny-moe --steps 300 --corpus wikitext
//!   sigma-moe eval  --preset tiny-moe --checkpoint ck.smoe --segments 20
//!   sigma-moe serve --preset tiny-moe --requests 16 --max-new 32
//!   sigma-moe serve --preset tiny-moe --http 127.0.0.1:8077 --policy spf
//!   sigma-moe loadgen --addr 127.0.0.1:8077 --requests 64 --rps 16
//!   sigma-moe loadgen --dry-run --requests 32
//!   sigma-moe loadgen --record trace.jsonl --requests 32
//!   sigma-moe loadgen --replay trace.jsonl
//!   sigma-moe chaos --engines 3 --seed 7 --pumps 600
//!   sigma-moe flops --table 7
//!   sigma-moe paper --table 3 --steps 300
//!   sigma-moe analyze --preset tiny-moe --fig 3

use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

use sigma_moe::analysis::ExpertStats;
use sigma_moe::bench_util;
use sigma_moe::cli::{Args, Parsed};
use sigma_moe::coordinator::{Checkpoint, Metrics, Trainer};
use sigma_moe::data;
use sigma_moe::json::Json;
use sigma_moe::runtime::{Client, Manifest, ModelBundle};
use sigma_moe::serving::{
    chaos, loadgen, router, server, DegradeCfg, Engine, GenRequest,
    Placement, Policy, RouterCfg, Sampler, ServerConfig,
};
use sigma_moe::tensor::HostTensor;
use sigma_moe::{flops, Error, Result};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(Error::Config(msg)) => {
            eprintln!("{msg}");
            2
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let (cmd, rest) = argv
        .split_first()
        .map(|(c, r)| (c.as_str(), r))
        .unwrap_or(("help", &[]));
    match cmd {
        "train" => cmd_train(rest),
        "eval" => cmd_eval(rest),
        "serve" => cmd_serve(rest),
        "loadgen" => cmd_loadgen(rest),
        "chaos" => cmd_chaos(rest),
        "flops" => cmd_flops(rest),
        "analyze" => cmd_analyze(rest),
        "paper" => cmd_paper(rest),
        "list" => cmd_list(),
        _ => {
            println!(
                "sigma-moe — σ-MoE / PKM / Top-K Transformer-XL (EMNLP 2023 reproduction)\n\n\
                 commands:\n\
                 \x20 train    train a preset on a synthetic corpus\n\
                 \x20 eval     evaluate a checkpoint (ppl / bpc)\n\
                 \x20 serve    batched inference: in-process demo, or --http for the\n\
                 \x20          continuous-batching HTTP frontend (streaming, /metrics)\n\
                 \x20 loadgen  open-loop Poisson load generator against `serve --http`\n\
                 \x20          (writes BENCH_serve.json; --dry-run needs no device;\n\
                 \x20          --record / --replay for deterministic traces)\n\
                 \x20 chaos    seeded fault storm over a simulated mock fleet with\n\
                 \x20          record/replay (a failing seed reproduces exactly)\n\
                 \x20 flops    analytic resource tables (Tab. 3 %FLOPs, Tab. 7)\n\
                 \x20 analyze  expert utilization / active channels (Figs. 1,3,6,7)\n\
                 \x20 paper    regenerate a paper table (scaled)\n\
                 \x20 list     list built artifact presets\n\n\
                 run '<command> --help' for options"
            );
            Ok(())
        }
    }
}

fn load_bundle(client: &Client, preset: &str) -> Result<ModelBundle> {
    let dir = sigma_moe::artifacts_root().join(preset);
    ModelBundle::load(client, dir)
}

fn corpus_default(unit: &str) -> &'static str {
    if unit == "char" {
        "enwik8"
    } else {
        "wikitext"
    }
}

fn resolve_corpus(arg: &str, unit: &str) -> Result<String> {
    match arg {
        "auto" => Ok(corpus_default(unit).to_string()),
        "wikitext" | "c4" | "pes2o" | "enwik8" => Ok(arg.to_string()),
        other => Err(Error::Config(format!("bad corpus {other}"))),
    }
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let p = Args::new("train a preset on a synthetic corpus")
        .opt("preset", "tiny-moe", "artifact preset name")
        .opt("steps", "200", "number of optimization steps")
        .opt("seed", "42", "init + data seed")
        .opt("corpus", "auto", "wikitext | c4 | pes2o | enwik8 | auto")
        .opt("eval-every", "0", "run eval every N steps (0 = only at end)")
        .opt("eval-segments", "16", "eval segments per evaluation")
        .opt("log-every", "20", "print a progress line every N steps")
        .optional("checkpoint", "write final checkpoint here")
        .optional("resume", "resume from this checkpoint")
        .optional("csv", "write per-step metrics CSV here")
        .parse_from(argv)?;

    let preset = p.str("preset")?;
    let client = Client::cpu()?;
    eprintln!("[train] loading artifacts for {preset} ...");
    let bundle = load_bundle(&client, preset)?;
    let m = &bundle.manifest;
    let corpus = resolve_corpus(p.str("corpus")?, &m.model.unit)?;
    let seed = p.u64("seed")?;
    let steps = p.usize("steps")?;
    eprintln!(
        "[train] {} | {} layers x d_model {} | ff {} | batch {} x context {} | corpus {}",
        m.preset, m.model.n_layers, m.model.d_model, m.model.ff_variant,
        m.batch_size, m.model.context, corpus
    );

    let mut trainer = Trainer::new(&bundle, seed as u32)?;
    if let Some(ck_path) = p.get("resume") {
        let ck = Checkpoint::load(ck_path)?;
        trainer.restore(&ck.params, &ck.opt, ck.step)?;
        eprintln!("[train] resumed from {ck_path} at step {}", ck.step);
    }
    let mut batcher = data::batcher_for(
        &corpus, m.model.vocab_size, m.batch_size, m.model.context, seed)?;
    let mut eval_batcher = data::batcher_for(
        &corpus, m.model.vocab_size, m.batch_size, m.model.context,
        seed ^ 0xEBA1)?;

    let mut metrics = Metrics::new(m.batch_size * m.model.context);
    if let Some(csv) = p.get("csv") {
        metrics = metrics.with_csv(csv)?;
    }
    let log_every = p.usize("log-every")?.max(1);
    let eval_every = p.usize("eval-every")?;
    let eval_segments = p.usize("eval-segments")?;

    for step in 0..steps {
        let w = batcher.next_window()?;
        let so = trainer.step_on(w)?;
        metrics.observe(&so)?;
        if (step + 1) % log_every == 0 || step + 1 == steps {
            eprintln!("{}", metrics.report(&so));
        }
        if eval_every > 0 && (step + 1) % eval_every == 0 {
            let ev = trainer.evaluate(&mut eval_batcher, eval_segments)?;
            eprintln!(
                "[eval] step {} nll {:.4} ppl {:.2} bpc {:.4}",
                step + 1, ev.nll, ev.perplexity(), ev.bpc()
            );
        }
    }
    let ev = trainer.evaluate(&mut eval_batcher, eval_segments)?;
    let metric = if m.model.unit == "char" {
        format!("bpc {:.4}", ev.bpc())
    } else {
        format!("ppl {:.3}", ev.perplexity())
    };
    println!(
        "final: preset={} steps={} train_loss={:.4} eval_nll={:.4} {}",
        preset, steps,
        metrics.loss_ema.unwrap_or(f64::NAN),
        ev.nll, metric
    );
    metrics.flush()?;

    // perf report: on-device execute vs host transfer, bytes-moved/step
    // (the seed path moved every param/opt/mem tensor both ways per step)
    let ts_prog = bundle.program("train_step")?;
    let xfer = trainer.transfer_stats();
    let n_steps = steps.max(1) as u64;
    eprintln!(
        "[perf] train_step exec {:.3?}/step over {} execs | client transfers \
         (train + eval): {} | h2d {:.3?} d2h {:.3?} total",
        ts_prog.mean_exec_time().unwrap_or_default(),
        ts_prog.exec_count.get(),
        xfer.report_per_step(n_steps),
        xfer.h2d_time,
        xfer.d2h_time,
    );
    eprintln!(
        "[perf] seed host-roundtrip path would move {:.3} MB/step; untuple fallbacks: {}",
        (ts_prog.spec.total_input_bytes() + ts_prog.spec.total_output_bytes())
            as f64
            / 1e6,
        ts_prog.untuple_fallbacks.get(),
    );

    if let Some(ck_path) = p.get("checkpoint") {
        let ck = Checkpoint::from_trainer(&mut trainer, preset)?;
        ck.save(ck_path)?;
        eprintln!("[train] checkpoint written to {ck_path}");
    }
    Ok(())
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let p = Args::new("evaluate a checkpoint")
        .opt("preset", "tiny-moe", "artifact preset name")
        .optional("checkpoint", "checkpoint to evaluate (default: fresh init)")
        .opt("segments", "32", "number of eval segments")
        .opt("seed", "7", "data seed")
        .opt("corpus", "auto", "wikitext | c4 | pes2o | enwik8 | auto")
        .parse_from(argv)?;
    let preset = p.str("preset")?;
    let client = Client::cpu()?;
    let bundle = load_bundle(&client, preset)?;
    let m = &bundle.manifest;
    let corpus = resolve_corpus(p.str("corpus")?, &m.model.unit)?;
    let mut trainer = Trainer::new(&bundle, 1)?;
    if let Some(ck_path) = p.get("checkpoint") {
        let ck = Checkpoint::load(ck_path)?;
        trainer.restore(&ck.params, &ck.opt, ck.step)?;
    }
    let mut batcher = data::batcher_for(
        &corpus, m.model.vocab_size, m.batch_size, m.model.context,
        p.u64("seed")?)?;
    let ev = trainer.evaluate(&mut batcher, p.usize("segments")?)?;
    println!(
        "eval: preset={preset} nll={:.4} ppl={:.3} bpc={:.4} tokens={}",
        ev.nll, ev.perplexity(), ev.bpc(), ev.token_count
    );
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let p = Args::new(
        "batched inference: in-process demo, or an HTTP frontend with \
         --http (POST /v1/completions with optional chunked streaming, \
         GET /healthz, GET /metrics; Ctrl-C stops it)",
    )
    .opt("preset", "tiny-moe", "artifact preset name")
    .optional("checkpoint", "serve this checkpoint (default fresh init)")
    .opt("requests", "16", "number of synthetic requests (demo mode)")
    .opt("prompt-len", "12", "prompt length per request (demo mode)")
    .opt("max-new", "24", "tokens to generate per request \
                           (HTTP: default max_tokens)")
    .opt("temperature", "0.8", "sampling temperature (demo mode)")
    .opt("seed", "5", "rng seed")
    .optional("http", "serve over HTTP at this address \
                       (e.g. 127.0.0.1:8077)")
    .opt("policy", "fifo", "HTTP admission policy: fifo | spf | deadline")
    .opt("queue-cap", "64", "HTTP bounded request queue \
                             (overflow answers 429)")
    .opt("engines", "1", "HTTP: engine-driver threads behind the router \
                          (each loads its own bundle copy)")
    .opt("placement", "least-loaded", "router placement: least-loaded | \
                                       round-robin | affinity")
    .opt("heartbeat-ms", "5000", "router: mark an engine wedged after \
                                  this long without a driver heartbeat")
    .opt("error-threshold", "3", "router: consecutive pump errors before \
                                  an engine is unhealthy")
    .opt("max-retries", "1", "router: failovers per request before 503")
    .opt("readmit-after", "20", "router: consecutive clean pumps before \
                                 a quarantined engine rejoins (0 = \
                                 quarantine is permanent)")
    .opt("trace-ring", "4096", "HTTP: completed request spans retained \
                                for GET /v1/trace/<id> (stage \
                                histograms observe every request \
                                regardless)")
    .opt("span-sample", "1000", "HTTP: per-mille of request ids \
                                 retained in the trace ring (1000 \
                                 keeps every span)")
    .optional("degrade-k", "HTTP: adaptive expert top-k under load, \
                            as min_k:hi_wm:lo_wm — degrade expert_k \
                            to min_k when queue depth reaches hi_wm \
                            (or deadlines drop), restore the full k \
                            once depth falls to lo_wm (MoE artifacts \
                            with runtime-k support only)")
    .opt("speculate", "0", "draft up to K tokens per lane via host \
                            n-gram lookup and verify them in one \
                            chunked-prefill dispatch (capped at \
                            prefill_chunk - 1; artifacts built with \
                            verify_logits only; 0 = plain decode)")
    .optional("prefix-cache", "HTTP: snapshot post-prefill lane state \
                               keyed by the prompt's content hash and \
                               seed later prompts sharing a prefix \
                               from it, within this LRU byte budget \
                               (artifacts without snapshot/restore \
                               programs fall back to cold prefill, \
                               counted in prefix_cache_unavailable)")
    .parse_from(argv)?;
    if let Some(addr) = p.get("http") {
        let addr = addr.to_string();
        return cmd_serve_http(&p, &addr);
    }
    let preset = p.str("preset")?;
    let client = Client::cpu()?;
    let bundle = load_bundle(&client, preset)?;
    let m = &bundle.manifest;
    let params = match p.get("checkpoint") {
        Some(path) => Checkpoint::load(path)?.params,
        None => {
            let init = bundle.program("init")?;
            let out = init.run(&[sigma_moe::tensor::HostTensor::scalar_u32(
                p.u64("seed")? as u32,
            )])?;
            init.spec
                .outputs
                .iter()
                .map(|b| b.name.clone())
                .zip(out)
                .collect()
        }
    };
    let speculate = p.usize("speculate")?;
    if speculate > 0 && !m.verify_logits {
        return Err(Error::Config(format!(
            "--speculate: preset {preset} was not built with \
             all-position verify logits (dense artifact, or a MoE \
             artifact predating speculative decode — rebuild it)"
        )));
    }
    let mut engine = Engine::new(&bundle, &params, p.u64("seed")?)?
        .with_speculate(speculate);
    let mut corpus = data::by_name(
        corpus_default(&m.model.unit), m.model.vocab_size, p.u64("seed")?)?;
    let n_req = p.usize("requests")?;
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for _ in 0..n_req {
        let prompt = corpus.take_vec(p.usize("prompt-len")?);
        rxs.push(engine.submit(GenRequest {
            prompt,
            max_new_tokens: p.usize("max-new")?,
            sampler: Sampler {
                temperature: p.f64("temperature")? as f32,
                top_k: 50,
                greedy: false,
            },
            ..Default::default()
        }));
    }
    let results = engine.run_to_completion(rxs)?;
    let wall = t0.elapsed().as_secs_f64();
    let total_new: usize = results.iter().map(|r| r.tokens.len()).sum();
    let mean_queue: f64 = results
        .iter()
        .map(|r| r.queue_time.as_secs_f64())
        .sum::<f64>()
        / results.len() as f64;
    let mean_run: f64 = results
        .iter()
        .map(|r| r.run_time.as_secs_f64())
        .sum::<f64>()
        / results.len() as f64;
    let stats = engine.stats();
    println!(
        "serve: {} requests x {} new tokens | lanes {} | wall {:.2}s | \
         {:.1} tok/s | mean queue {:.3}s | mean run {:.3}s | \
         occupancy {:.2} (gen-only {:.2})",
        results.len(),
        p.usize("max-new")?,
        engine.n_lanes(),
        wall,
        total_new as f64 / wall,
        mean_queue,
        mean_run,
        stats["mean_batch_occupancy"],
        stats["mean_gen_occupancy"],
    );
    eprintln!(
        "[perf] decode: {} over {} steps",
        engine.transfer_stats().report_per_step(engine.steps_executed),
        engine.steps_executed,
    );
    if engine.speculate() > 0 {
        println!(
            "speculative: K={} | {} verify rounds | accept rate {:.2} \
             | {} rollbacks",
            engine.speculate(),
            stats["spec_rounds"],
            stats["spec_accept_rate"],
            stats["spec_rollbacks"],
        );
    }
    Ok(())
}

/// Load one serving engine's bundle + params on its driver thread
/// (PJRT state is not `Send`, so this runs inside the thread): its own
/// client, the `step_fwd`(+`init`+`prefill`+`reset_lanes`) subset, and
/// either the checkpoint's params or a fresh `init` run.  Returns the
/// bundle, the params, and whether on-device lane reset is available.
/// Shared by the single-engine and fleet `serve --http` paths.
fn load_serving_engine(
    dir: &std::path::Path,
    checkpoint: &Option<Vec<(String, HostTensor)>>,
    seed: u64,
) -> Result<(ModelBundle, Vec<(String, HostTensor)>, bool)> {
    let client = Client::cpu()?;
    let manifest = Manifest::load(dir)?;
    let mut names = vec!["step_fwd"];
    if checkpoint.is_none() {
        names.push("init");
    }
    let device_reset = manifest.functions.contains_key("reset_lanes");
    if device_reset {
        names.push("reset_lanes");
    }
    if manifest.functions.contains_key("prefill") {
        names.push("prefill");
    }
    // prefix-cache snapshot/restore ride along when the artifact has
    // them; engines without them serve unchanged (cold prefill)
    for name in ["snapshot_lanes", "restore_lanes"] {
        if manifest.functions.contains_key(name) {
            names.push(name);
        }
    }
    let bundle = ModelBundle::load_subset(&client, dir, &names)?;
    let params = match checkpoint {
        Some(params) => params.clone(),
        None => {
            let init = bundle.program("init")?;
            let out = init.run(&[HostTensor::scalar_u32(seed as u32)])?;
            init.spec
                .outputs
                .iter()
                .map(|b| b.name.clone())
                .zip(out)
                .collect()
        }
    };
    Ok((bundle, params, device_reset))
}

/// `serve --http`: the continuous-batching HTTP frontend.  The PJRT
/// client, bundle, and engine are not `Send`, so everything
/// device-facing is constructed *inside* the dedicated driver thread;
/// the main thread runs the accept loop.
fn cmd_serve_http(p: &Parsed, addr: &str) -> Result<()> {
    let preset = p.str("preset")?.to_string();
    let dir = sigma_moe::artifacts_root().join(&preset);
    // cheap JSON-only manifest read for vocab / lane-count reporting
    let manifest = Manifest::load(&dir)?;
    let degrade_k = match p.get("degrade-k") {
        None => None,
        Some(spec) => {
            let cfg = DegradeCfg::parse(spec)?;
            if manifest.expert_k_max.is_none() {
                return Err(Error::Config(format!(
                    "--degrade-k: preset {preset} has no runtime \
                     expert-k input (dense artifact, or a MoE artifact \
                     predating adaptive-k — rebuild it)"
                )));
            }
            Some(cfg)
        }
    };
    let speculate = p.usize("speculate")?;
    if speculate > 0
        && !(manifest.verify_logits
            && manifest.functions.contains_key("prefill"))
    {
        return Err(Error::Config(format!(
            "--speculate: preset {preset} was not built with \
             all-position verify logits (dense artifact, or a MoE \
             artifact predating speculative decode — rebuild it)"
        )));
    }
    let cfg = ServerConfig {
        queue_cap: p.usize("queue-cap")?,
        policy: Policy::parse(p.str("policy")?)?,
        default_max_new: p.usize("max-new")?,
        vocab: Some(manifest.model.vocab_size),
        // spf costs prompts in ⌈len/C⌉ prefill dispatches; artifacts
        // predating the prefill program report C = 1
        prefill_chunk: if manifest.functions.contains_key("prefill") {
            manifest.prefill_chunk
        } else {
            1
        },
        trace_ring: p.usize("trace-ring")?.max(1),
        span_sample_permille: p.u64("span-sample")?.min(1000),
        expert_k_max: manifest.expert_k_max,
        degrade_k,
        speculate,
        prefix_cache: p.opt_u64("prefix-cache")?,
        ..Default::default()
    };
    let checkpoint: Option<Vec<(String, HostTensor)>> =
        match p.get("checkpoint") {
            Some(path) => Some(Checkpoint::load(path)?.params),
            None => None,
        };
    let seed = p.u64("seed")?;
    let engines = p.usize("engines")?;
    let listener = std::net::TcpListener::bind(addr)?;
    eprintln!(
        "[serve] http://{} | preset {} | {} engine(s) x {} lanes | \
         prefill chunk {} | policy {} | queue cap {} (Ctrl-C stops)",
        listener.local_addr()?,
        preset,
        engines.max(1),
        manifest.serve_batch,
        cfg.prefill_chunk,
        cfg.policy.as_str(),
        cfg.queue_cap,
    );
    if let (Some(d), Some(k)) = (cfg.degrade_k, cfg.expert_k_max) {
        eprintln!(
            "[serve] adaptive expert-k: ceiling {k} | floor {} | \
             degrade at depth >= {} | restore at depth <= {}",
            d.min_k, d.hi_wm, d.lo_wm,
        );
    }
    if cfg.speculate > 0 {
        eprintln!(
            "[serve] speculative decode: drafting up to {} token(s) \
             per lane per verify round (n-gram prompt lookup)",
            cfg.speculate.min(cfg.prefill_chunk.saturating_sub(1)),
        );
    }
    if let Some(budget) = cfg.prefix_cache {
        eprintln!(
            "[serve] prefix cache: {budget} byte LRU budget{}",
            if manifest.prefix_cache {
                ""
            } else {
                // validated fallback: the flag is accepted so a mixed
                // fleet config works, but this artifact prefills cold
                " (preset has no snapshot/restore programs — cold \
                 prefill, probes counted in prefix_cache_unavailable)"
            },
        );
    }
    let shutdown = Arc::new(AtomicBool::new(false));
    if engines > 1 {
        let rcfg = RouterCfg {
            engines,
            placement: Placement::parse(p.str("placement")?)?,
            heartbeat_timeout: Duration::from_millis(
                p.u64("heartbeat-ms")?,
            ),
            error_threshold: p.u64("error-threshold")?,
            max_retries: p.usize("max-retries")?,
            readmit_after: p.u64("readmit-after")?,
        };
        eprintln!(
            "[serve] router: {} placement | heartbeat {:?} | \
             {} retries",
            rcfg.placement.as_str(),
            rcfg.heartbeat_timeout,
            rcfg.max_retries,
        );
        // each driver thread loads its own client + bundle copy (the
        // PJRT state is not Send); params come from the same
        // checkpoint / init seed so all engines serve the same model
        return router::serve_fleet(
            listener,
            cfg,
            rcfg,
            shutdown,
            move |id, fleet| {
                let (bundle, params, device_reset) =
                    load_serving_engine(&dir, &checkpoint, seed)?;
                // distinct sampling streams per engine, same params
                let mut engine = Engine::new(
                    &bundle,
                    &params,
                    seed ^ ((id as u64) << 32),
                )?
                .with_speculate(speculate);
                eprintln!(
                    "[serve] engine {id} ready: {} lanes | prefill \
                     chunk {} | lane reset: {}",
                    engine.n_lanes(),
                    engine.prefill_chunk(),
                    if device_reset {
                        "on-device"
                    } else {
                        "host fallback"
                    },
                );
                fleet.run_engine(id, &mut engine)
            },
        );
    }
    server::serve(listener, cfg, shutdown, move |driver| {
        let (bundle, params, device_reset) =
            load_serving_engine(&dir, &checkpoint, seed)?;
        let mut engine =
            Engine::new(&bundle, &params, seed)?.with_speculate(speculate);
        eprintln!(
            "[serve] engine ready: {} lanes | prefill chunk {} | \
             lane reset: {}",
            engine.n_lanes(),
            engine.prefill_chunk(),
            if device_reset { "on-device" } else { "host fallback" },
        );
        driver.drive(&mut engine)
    })
}

/// `chaos`: a seeded fault storm over a simulated mock fleet.  Runs
/// the real placer/engine-driver steps single-threaded on a simulated
/// clock — no device, no sockets, no wall time — so every scheduling
/// decision is journaled and the whole run replays bit-for-bit from
/// its seed.
fn cmd_chaos(argv: &[String]) -> Result<()> {
    let p = Args::new(
        "seeded chaos storm over a simulated mock fleet: stalls, error \
         storms, NaN logits, restarts and outage windows, with every \
         scheduling decision journaled; a tripped invariant dumps its \
         trace and the seed reproduces the run exactly (no device)",
    )
    .opt("engines", "3", "mock engines (engine 0 is never faulted, so \
                          the storm cannot extinguish the fleet)")
    .opt("lanes", "2", "lanes per mock engine")
    .opt("vocab", "64", "mock vocabulary size")
    .opt("requests", "24", "requests injected over the storm")
    .opt("pumps", "600", "scheduled storm rounds (10ms simulated each; \
                          the run drains to quiescence after)")
    .opt("seed", "1", "master seed: requests, arrivals, deadlines, \
                       faults and outage windows all derive from it")
    .opt("trace", "chaos_trace.jsonl", "where the trace is dumped when \
                                        an invariant trips")
    .optional("record", "also write the trace here on a clean run")
    .optional("replay", "replay this recorded trace instead of running \
                         a storm: re-executes from the trace header and \
                         verifies the decision stream + final metrics \
                         bit-for-bit")
    .flag("no-storm", "disable fault injection (clean load run)")
    .optional("degrade-k", "adaptive expert top-k under load, as \
                            min_k:hi_wm:lo_wm — the storm then also \
                            exercises (and journals) the scheduler's \
                            k-degrade/restore hysteresis")
    .opt("speculate", "0", "draft K tokens per verify round on the \
                            mock engines — the storm then also \
                            exercises speculative verify/rollback \
                            accounting under faults (0 = plain decode)")
    .optional("prefix-cache", "arm the fleet-shared prefix cache with \
                               this LRU byte budget — the storm then \
                               also exercises snapshot/restore and \
                               eviction under faults, deterministically")
    .parse_from(argv)?;

    if let Some(path) = p.get("replay") {
        return run_replay(std::path::Path::new(path));
    }
    let cfg = chaos::ChaosCfg {
        engines: p.usize("engines")?.max(1),
        lanes: p.usize("lanes")?.max(1),
        vocab: p.usize("vocab")?.max(2),
        requests: p.usize("requests")?,
        pumps: p.u64("pumps")?.max(2),
        seed: p.u64("seed")?,
        storm: !p.flag("no-storm"),
        degrade: match p.get("degrade-k") {
            Some(spec) => Some(DegradeCfg::parse(spec)?),
            None => None,
        },
        speculate: p.usize("speculate")?,
        prefix_cache: p.opt_u64("prefix-cache")?,
    };
    eprintln!(
        "[chaos] seed {} | {} engine(s) x {} lanes | {} requests over \
         {} rounds | storm {} | speculate {} | prefix cache {}",
        cfg.seed,
        cfg.engines,
        cfg.lanes,
        cfg.requests,
        cfg.pumps,
        if cfg.storm { "on" } else { "off" },
        cfg.speculate,
        match cfg.prefix_cache {
            Some(b) => format!("{b} bytes"),
            None => "off".into(),
        },
    );
    let report = chaos::run(&cfg)?;
    println!("{}", report.summary_json().to_string_compact());
    if let Some(rec) = p.get("record") {
        report.write_trace(std::path::Path::new(rec))?;
        eprintln!(
            "[chaos] trace recorded to {rec}; verify with: \
             sigma-moe chaos --replay {rec}"
        );
    }
    if report.ok() {
        eprintln!(
            "[chaos] clean: {} done + {} dropped + {} rejected = {} \
             requests; {} failovers, {} readmissions; all invariants \
             held",
            report.dones,
            report.drops,
            report.rejected,
            report.cfg.requests,
            report.failovers,
            report.readmissions,
        );
        return Ok(());
    }
    let trace_path = p.str("trace")?;
    report.write_trace(std::path::Path::new(trace_path))?;
    for v in &report.violations {
        eprintln!("[chaos] VIOLATION: {v}");
    }
    eprintln!(
        "[chaos] seed {}: trace dumped to {trace_path} — reproduce \
         this exact run with:\n  sigma-moe chaos --replay {trace_path}",
        cfg.seed,
    );
    Err(Error::Serving(format!(
        "chaos: {} invariant violation(s) at seed {}",
        report.violations.len(),
        cfg.seed
    )))
}

/// Shared by `chaos --replay` and `loadgen --replay`: re-execute a
/// recorded trace from its header and verify the decision stream and
/// final metrics snapshot reproduce bit-for-bit.
fn run_replay(path: &std::path::Path) -> Result<()> {
    eprintln!("[replay] re-executing {} ...", path.display());
    let out = chaos::replay_path(path)?;
    println!("{}", out.report.summary_json().to_string_compact());
    // a failure dump replays *with* its violations — reproducing them
    // is the point; replay verdict is about determinism alone
    for v in &out.report.violations {
        eprintln!("[replay] reproduced violation: {v}");
    }
    if out.ok() {
        eprintln!(
            "[replay] {} events and the final metrics snapshot \
             reproduced bit-for-bit",
            out.report.events.lines().count(),
        );
        return Ok(());
    }
    if let Some(d) = &out.divergence {
        eprintln!("[replay] decision stream diverged: {d}");
    }
    if !out.metrics_match {
        eprintln!("[replay] final metrics snapshot diverged");
    }
    Err(Error::Serving(
        "replay did not reproduce the recorded run".into(),
    ))
}

fn cmd_loadgen(argv: &[String]) -> Result<()> {
    let p = Args::new(
        "open-loop Poisson load generator for `serve --http`; writes a \
         machine-readable latency/throughput report",
    )
    .opt("addr", "127.0.0.1:8077", "server address to load")
    .opt("requests", "64", "number of requests")
    .opt("rps", "8", "target offered load, requests/sec (Poisson)")
    .opt("prompt-min", "4", "min prompt length")
    .opt("prompt-max", "16", "max prompt length")
    .opt("prompt-dist", "uniform", "prompt-length distribution over \
                                    [prompt-min, prompt-max]: fixed | \
                                    uniform | lognormal (heavy tail) | \
                                    shared-prefix (one common prefix + \
                                    per-request random tails — the \
                                    prefix-cache workload)")
    .opt("shared-prefix-overlap", "0.5", "--prompt-dist shared-prefix: \
                                          fraction of prompt-max \
                                          covered by the common prefix")
    .opt("max-new-min", "8", "min tokens to generate")
    .opt("max-new-max", "32", "max tokens to generate")
    .opt("vocab", "2048", "prompt token ids drawn from [0, vocab)")
    .opt("stream-fraction", "0.5", "fraction using chunked streaming")
    .opt("temperature", "0.8", "sampling temperature sent with requests")
    .opt("top-k", "50", "top_k sent with requests")
    .opt("seed", "1", "schedule + prompt rng seed")
    .optional("deadline-ms", "per-request deadline \
                              (pair with serve --policy deadline)")
    .opt("out", "BENCH_serve.json", "report path")
    .opt("timeout-s", "120", "per-request client timeout, seconds")
    .flag("dry-run", "run against in-process mock engine(s) \
                      (no device, ignores --addr)")
    .opt("mock-lanes", "4", "mock engine lanes for --dry-run")
    .opt("prefill-chunk", "16", "--dry-run: mock chunked-prefill width \
                                 C (1 = single-token prompt feeding)")
    .opt("engines", "1", "--dry-run: comma-separated mock fleet sizes \
                          (e.g. 1,2,4) — one report row per size, same \
                          Poisson plan, for scaling comparisons")
    .flag("keep-alive", "reuse connections (HTTP keep-alive pool) \
                         instead of one connection per request")
    .optional("prom-out", "--dry-run: write the validated Prometheus \
                           text exposition scraped from the mock fleet \
                           here (next to the BENCH report)")
    .flag("telemetry-ab", "--dry-run: append an A/B row running the \
                           same plan with telemetry on and off, \
                           pricing always-on observability")
    .flag("degrade-ab", "--dry-run: append an A/B row running the same \
                         plan over an overloaded mock fleet with \
                         adaptive expert-k off vs on (--degrade-k \
                         1:4:1), pricing the p99 the degraded k buys \
                         back under queue pressure")
    .opt("speculate", "0", "--dry-run: draft K tokens per verify round \
                            on the mock engines, and append a \
                            speculation off-vs-on A/B row on a \
                            repetitive decode-heavy workload with the \
                            accept-rate histogram (0 = plain decode)")
    .optional("prefix-cache", "--dry-run: arm the mock fleet's prefix \
                               cache with this LRU byte budget — rows \
                               gain hit-rate and TTFT hit-vs-miss \
                               columns, and a cold-vs-warm A/B row is \
                               appended on a shared-prefix workload")
    .optional("record", "deterministic device-free run over the mock \
                         fleet on a simulated clock; writes the full \
                         decision trace here (see --replay)")
    .optional("replay", "re-execute a recorded trace and verify the \
                         decision stream + metrics bit-for-bit")
    .opt("pumps", "600", "--record: simulated rounds (10ms each)")
    .parse_from(argv)?;

    if let Some(path) = p.get("replay") {
        return run_replay(std::path::Path::new(path));
    }
    if let Some(path) = p.get("record") {
        let engines = p
            .str("engines")?
            .split(',')
            .next()
            .unwrap_or("1")
            .trim()
            .parse::<usize>()
            .map_err(|e| Error::Config(format!("--engines: {e}")))?;
        let cfg = chaos::ChaosCfg {
            engines: engines.max(1),
            lanes: p.usize("mock-lanes")?.max(1),
            vocab: p.usize("vocab")?.max(2),
            requests: p.usize("requests")?,
            pumps: p.u64("pumps")?.max(2),
            seed: p.u64("seed")?,
            storm: false,
            degrade: None,
            speculate: p.usize("speculate")?,
            prefix_cache: p.opt_u64("prefix-cache")?,
        };
        eprintln!(
            "[loadgen] recording a deterministic run: seed {} | {} \
             mock engine(s) x {} lanes | {} requests",
            cfg.seed, cfg.engines, cfg.lanes, cfg.requests,
        );
        let report = chaos::record(&cfg, std::path::Path::new(path))?;
        println!("{}", report.summary_json().to_string_compact());
        if !report.ok() {
            for v in &report.violations {
                eprintln!("[loadgen] VIOLATION: {v}");
            }
            return Err(Error::Serving(format!(
                "record: {} invariant violation(s) at seed {}",
                report.violations.len(),
                cfg.seed
            )));
        }
        eprintln!(
            "[loadgen] trace recorded to {path}; replay with: \
             sigma-moe loadgen --replay {path}"
        );
        return Ok(());
    }

    let cfg = loadgen::LoadgenCfg {
        requests: p.usize("requests")?,
        rps: p.f64("rps")?,
        prompt_len: (p.usize("prompt-min")?, p.usize("prompt-max")?),
        prompt_dist: loadgen::PromptDist::parse(p.str("prompt-dist")?)?,
        max_new: (p.usize("max-new-min")?, p.usize("max-new-max")?),
        vocab: p.usize("vocab")?,
        stream_fraction: p.f64("stream-fraction")?,
        temperature: p.f64("temperature")?,
        top_k: p.usize("top-k")?,
        greedy: false,
        deadline_ms: p.opt_u64("deadline-ms")?,
        seed: p.u64("seed")?,
        timeout: Duration::from_secs(p.u64("timeout-s")?),
        keep_alive: p.flag("keep-alive"),
        prefill_chunk: p.usize("prefill-chunk")?,
        telemetry: true,
        speculate: p.usize("speculate")?,
        shared_prefix_overlap: p.f64("shared-prefix-overlap")?,
        prefix_cache: p.opt_u64("prefix-cache")?,
    };
    let mut ab_row: Option<Json> = None;
    let mut degrade_row: Option<Json> = None;
    let mut speculate_row: Option<Json> = None;
    let mut prefix_row: Option<Json> = None;
    let mut prom_artifact: Option<String> = None;
    let mut rows: Vec<Json> = if p.flag("dry-run") {
        let engine_counts: Vec<usize> = p
            .str("engines")?
            .split(',')
            .map(|s| {
                s.trim().parse::<usize>().map_err(|e| {
                    Error::Config(format!("--engines: {e}"))
                })
            })
            .collect::<Result<_>>()?;
        let lanes = p.usize("mock-lanes")?;
        let mut rows = Vec::with_capacity(engine_counts.len());
        for (i, &engines) in engine_counts.iter().enumerate() {
            eprintln!(
                "[loadgen] dry run: {engines} in-process mock engine(s) \
                 x {lanes} lanes"
            );
            if i == 0 {
                let (row, prom) =
                    loadgen::dry_run_with_prom(&cfg, lanes, engines)?;
                prom_artifact = Some(prom);
                rows.push(row);
            } else {
                rows.push(loadgen::dry_run(&cfg, lanes, engines)?);
            }
        }
        if p.flag("telemetry-ab") {
            let engines = engine_counts.first().copied().unwrap_or(1);
            eprintln!(
                "[loadgen] telemetry A/B: re-running the plan with \
                 telemetry off ({engines} engine(s))"
            );
            ab_row =
                Some(loadgen::dry_run_telemetry_ab(&cfg, lanes, engines)?);
        }
        if p.flag("degrade-ab") {
            let engines = engine_counts.first().copied().unwrap_or(1);
            eprintln!(
                "[loadgen] degrade A/B: re-running the plan over an \
                 overloaded mock fleet, fixed expert-k vs adaptive \
                 ({engines} engine(s))"
            );
            degrade_row =
                Some(loadgen::dry_run_degrade_ab(&cfg, lanes, engines)?);
        }
        if cfg.speculate > 0 {
            let engines = engine_counts.first().copied().unwrap_or(1);
            eprintln!(
                "[loadgen] speculate A/B: re-running a repetitive \
                 decode-heavy plan with drafting off vs K={} \
                 ({engines} engine(s))",
                cfg.speculate,
            );
            speculate_row =
                Some(loadgen::dry_run_speculate_ab(&cfg, lanes, engines)?);
        }
        if cfg.prefix_cache.is_some() {
            let engines = engine_counts.first().copied().unwrap_or(1);
            eprintln!(
                "[loadgen] prefix A/B: re-running a shared-prefix \
                 plan with the cache disarmed vs armed \
                 ({engines} engine(s))"
            );
            prefix_row =
                Some(loadgen::dry_run_prefix_ab(&cfg, lanes, engines)?);
        }
        rows
    } else {
        if p.flag("telemetry-ab")
            || p.flag("degrade-ab")
            || p.usize("speculate")? > 0
            || p.get("prefix-cache").is_some()
            || p.get("prom-out").is_some()
        {
            return Err(Error::Config(
                "--telemetry-ab, --degrade-ab, --speculate, \
                 --prefix-cache and --prom-out are --dry-run options \
                 (a live server arms its cache via serve --prefix-cache)"
                    .into(),
            ));
        }
        if p.str("engines")? != "1" {
            return Err(Error::Config(
                "--engines is a --dry-run option; a live run measures \
                 whatever fleet the server at --addr is running"
                    .into(),
            ));
        }
        let addr: std::net::SocketAddr =
            p.str("addr")?.parse().map_err(|e| {
                Error::Config(format!("--addr: {e}"))
            })?;
        eprintln!("[loadgen] loading http://{addr} ...");
        vec![loadgen::run(addr, &cfg, "live")?]
    };
    let num = |doc: &Json, k: &str| {
        doc.get(k).ok().and_then(|v| v.as_f64().ok()).unwrap_or(0.0)
    };
    for row in &rows {
        let lat = |k: &str| {
            row.get("latency")
                .ok()
                .and_then(|l| l.get(k).ok())
                .and_then(|v| v.as_f64().ok())
                .unwrap_or(0.0)
        };
        println!(
            "loadgen[{} engine(s)]: {} requests @ {:.1} rps target \
             ({:.1} achieved) | ok {} | 429 {} | dropped {} | errors {} \
             | {:.1} tok/s | latency ms p50 {:.1} p95 {:.1} p99 {:.1} \
             max {:.1}",
            num(row, "engines").max(1.0),
            num(row, "requests"),
            num(row, "target_rps"),
            num(row, "achieved_rps"),
            num(row, "ok"),
            num(row, "rejected_429"),
            num(row, "dropped"),
            num(row, "errors"),
            num(row, "tokens_per_sec"),
            lat("p50_ms"),
            lat("p95_ms"),
            lat("p99_ms"),
            lat("max_ms"),
        );
    }
    if rows.len() > 1 {
        let base = num(&rows[0], "tokens_per_sec").max(1e-9);
        for row in &rows[1..] {
            println!(
                "scaling: {} engines -> {:.2}x token throughput vs {} \
                 engine(s)",
                num(row, "engines"),
                num(row, "tokens_per_sec") / base,
                num(&rows[0], "engines").max(1.0),
            );
        }
    }
    if let Some(ab) = ab_row {
        println!(
            "telemetry A/B: {:.1} tok/s on vs {:.1} tok/s off -> \
             {:.2}% overhead",
            num(&ab, "tokens_per_sec_on"),
            num(&ab, "tokens_per_sec_off"),
            100.0 * num(&ab, "telemetry_overhead_frac"),
        );
        rows.push(ab);
    }
    if let Some(d) = degrade_row {
        println!(
            "degrade A/B: p99 {:.1} ms at full k vs {:.1} ms degraded \
             -> {:.2}x under overload | {} degrade(s), {} restore(s), \
             final k {}",
            num(&d, "p99_ms_full_k"),
            num(&d, "p99_ms_degraded"),
            num(&d, "p99_speedup"),
            num(&d, "k_degrades"),
            num(&d, "k_restores"),
            num(&d, "expert_k_final"),
        );
        rows.push(d);
    }
    if let Some(s) = speculate_row {
        println!(
            "speculate A/B: {:.1} tok/s off vs {:.1} tok/s at K={} -> \
             {:.2}x | accept rate {:.2} | {} rollback(s)",
            num(&s, "tokens_per_sec_off"),
            num(&s, "tokens_per_sec_on"),
            num(&s, "speculate"),
            num(&s, "speculate_speedup"),
            num(&s, "spec_accept_rate"),
            num(&s, "spec_rollbacks"),
        );
        rows.push(s);
    }
    if let Some(pr) = prefix_row {
        println!(
            "prefix A/B: {:.1} tok/s cold vs {:.1} tok/s warm -> \
             {:.2}x | hit rate {:.2} | TTFT p50 {:.1} ms hit vs \
             {:.1} ms miss | {} prompt token(s) saved",
            num(&pr, "tokens_per_sec_cold"),
            num(&pr, "tokens_per_sec_warm"),
            num(&pr, "prefix_cache_speedup"),
            num(&pr, "prefix_cache_hit_rate"),
            num(&pr, "ttft_p50_ms_hit"),
            num(&pr, "ttft_p50_ms_miss"),
            num(&pr, "prefix_cache_tokens_saved"),
        );
        rows.push(pr);
    }
    if let Some(path) = p.get("prom-out") {
        if let Some(text) = &prom_artifact {
            std::fs::write(path, text)?;
            eprintln!(
                "[loadgen] validated prom exposition written to {path}"
            );
        }
    }
    let out = p.str("out")?;
    bench_util::write_bench_json(out, "sigma-moe/serve/v1", rows)?;
    eprintln!("[loadgen] report written to {out}");
    Ok(())
}

fn cmd_flops(argv: &[String]) -> Result<()> {
    let p = Args::new("analytic resource tables")
        .opt("table", "7", "3 (%FLOPs column) or 7 (fraction table)")
        .parse_from(argv)?;
    match p.str("table")? {
        "3" => {
            println!("Tab. 3 '% FLOPs' column (MLP blocks, parameter-matched):");
            for (label, d_model, ne, g, k, dff) in [
                ("WT-S  (47M)", 412usize, 16usize, 128usize, 4usize, 2053usize),
                ("WT-B  (262M)", 1024, 32, 128, 4, 4110),
                ("E8    (41M)", 512, 16, 128, 4, 2053),
                ("WT-S* (238M)", 412, 128, 128, 4, 16480),
            ] {
                let f = flops::moe_fraction(d_model, ne, g, k, dff);
                println!("  {label}: {:.1}%", 100.0 * f);
            }
        }
        "7" => {
            println!(
                "Tab. 7: relative FLOPs/memory of the MoE FF block vs dense \
                 (WT-S family, d_model=412, dense d_ff=2048):"
            );
            let rows = flops::table7_rows(
                412,
                2048,
                &[
                    ("sigma-MoE (G=128,K=4)", 128, 4),
                    ("K=8, G=64", 64, 8),
                    ("K=2, G=256", 256, 2),
                    ("K=1, G=512", 512, 1),
                    ("K=1, G=128", 128, 1),
                    ("K=2, G=128", 128, 2),
                    ("K=8, G=128", 128, 8),
                ],
            );
            for r in rows {
                println!(
                    "  {:<24} G={:<4} K={:<2} flops {:>6.1}%  mem {:>6.1}%",
                    r.label, r.g, r.k,
                    100.0 * r.flops_fraction,
                    100.0 * r.memory_fraction
                );
            }
        }
        other => return Err(Error::Config(format!("unknown table {other}"))),
    }
    Ok(())
}

fn cmd_analyze(argv: &[String]) -> Result<()> {
    let p = Args::new("expert utilization / active-channel analysis")
        .opt("preset", "tiny-moe", "artifact preset name")
        .optional("checkpoint", "analyze this checkpoint")
        .opt("fig", "3",
             "1 (active channels) | 3 (utilization) | 6 (co-occurrence)")
        .opt("segments", "16", "eval segments to accumulate")
        .opt("seed", "11", "data seed")
        .parse_from(argv)?;
    let preset = p.str("preset")?;
    let client = Client::cpu()?;
    let bundle = load_bundle(&client, preset)?;
    let m = &bundle.manifest;
    let mut trainer = Trainer::new(&bundle, 1)?;
    if let Some(ck_path) = p.get("checkpoint") {
        let ck = Checkpoint::load(ck_path)?;
        trainer.restore(&ck.params, &ck.opt, ck.step)?;
    }
    let mut batcher = data::batcher_for(
        corpus_default(&m.model.unit), m.model.vocab_size, m.batch_size,
        m.model.context, p.u64("seed")?)?;

    let mut stats = ExpertStats::new(m.model.n_layers, m.model.n_experts);
    let mut active: Vec<f64> = vec![0.0; m.model.n_layers];
    let segments = p.usize("segments")?;
    for _ in 0..segments {
        let ev = trainer.evaluate(&mut batcher, 1)?;
        stats.accumulate(&ev.stats).ok();
        if let Some(t) = ev.stats.get("3.active_channels") {
            for (l, v) in t.as_f32()?.iter().enumerate() {
                active[l] += *v as f64 / segments as f64;
            }
        }
    }
    match p.str("fig")? {
        "1" => {
            println!(
                "Fig. 1 — mean active channels per layer (of {} available):",
                if m.model.ff_variant == "moe" {
                    m.model.group_size * m.model.expert_k
                } else {
                    m.model.d_ff
                }
            );
            for (l, a) in active.iter().enumerate() {
                println!("  layer {l:>2}: {a:8.1}");
            }
        }
        "3" => {
            let rep = stats.report();
            println!("Fig. 3/7 — expert selection-weight proportions (sorted):");
            for l in 0..m.model.n_layers {
                print!("{}", rep.format_layer(l));
            }
            let collapsed = rep.collapsed_layers();
            if collapsed.is_empty() {
                println!("no expert collapse detected");
            } else {
                println!("COLLAPSED layers: {collapsed:?}");
            }
        }
        "6" => {
            let Some(cooc) = &stats.cooccurrence else {
                return Err(Error::other(
                    "no co-occurrence stats (dense model?)",
                ));
            };
            let e = m.model.n_experts;
            let l = m.model.n_layers / 2;
            println!(
                "Fig. 6 — expert co-occurrence, layer {l} (row-normalized %):"
            );
            for i in 0..e {
                let row: Vec<f64> =
                    (0..e).map(|j| cooc[l][i * e + j]).collect();
                let sum: f64 = row.iter().sum::<f64>().max(1e-9);
                let cells: Vec<String> = row
                    .iter()
                    .map(|v| format!("{:4.0}", 100.0 * v / sum))
                    .collect();
                println!("  e{i:<2} {}", cells.join(" "));
            }
        }
        other => return Err(Error::Config(format!("unknown fig {other}"))),
    }
    Ok(())
}

fn cmd_paper(argv: &[String]) -> Result<()> {
    let p = Args::new("regenerate a paper table at reproduction scale")
        .opt("table", "3", "1 | 2 | 3 | 4")
        .opt("steps", "200", "training steps per model")
        .opt("seed", "42", "seed")
        .opt("eval-segments", "24", "eval segments")
        .parse_from(argv)?;
    let steps = p.usize("steps")?;
    let seed = p.u64("seed")?;
    let segs = p.usize("eval-segments")?;
    let rows: Vec<(&str, &str)> = match p.str("table")? {
        "1" => vec![
            ("dense baseline", "tiny-dense"),
            ("top-k", "tiny-topk"),
        ],
        "2" => vec![
            ("dense baseline", "tiny-dense"),
            ("pkm (relu)", "tiny-pkm"),
        ],
        "3" => vec![
            ("dense baseline", "tiny-dense"),
            ("sigma-moe", "tiny-moe"),
        ],
        "4" => vec![
            ("sigma-moe (ours)", "tiny-moe"),
            ("softmax (renorm.)", "tiny-moe-softmax_renorm"),
            ("switch transformer", "tiny-moe-switch"),
        ],
        other => return Err(Error::Config(format!("unknown table {other}"))),
    };
    let client = Client::cpu()?;
    println!(
        "table {} @ {} steps (scaled reproduction — see EXPERIMENTS.md):",
        p.str("table")?, steps
    );
    println!("{:<22} {:>10} {:>10} {:>9}", "model", "train-loss",
             "eval-nll", "ppl");
    for (label, preset) in rows {
        let bundle = match load_bundle(&client, preset) {
            Ok(b) => b,
            Err(e) => {
                println!("{label:<22} [artifacts missing: {e}]");
                continue;
            }
        };
        let m = &bundle.manifest;
        let mut trainer = Trainer::new(&bundle, seed as u32)?;
        let mut batcher = data::batcher_for(
            corpus_default(&m.model.unit), m.model.vocab_size,
            m.batch_size, m.model.context, seed)?;
        let mut eval_batcher = data::batcher_for(
            corpus_default(&m.model.unit), m.model.vocab_size,
            m.batch_size, m.model.context, seed ^ 0xEBA1)?;
        let mut last_loss = f32::NAN;
        trainer.train(&mut batcher, steps, |so| last_loss = so.loss)?;
        let ev = trainer.evaluate(&mut eval_batcher, segs)?;
        println!(
            "{label:<22} {last_loss:>10.4} {:>10.4} {:>9.3}",
            ev.nll,
            ev.perplexity()
        );
    }
    Ok(())
}

fn cmd_list() -> Result<()> {
    let root = sigma_moe::artifacts_root();
    println!("artifacts root: {}", root.display());
    let mut found = false;
    if let Ok(entries) = std::fs::read_dir(&root) {
        for e in entries.flatten() {
            if e.path().join("manifest.json").exists() {
                println!("  {}", e.file_name().to_string_lossy());
                found = true;
            }
        }
    }
    if !found {
        println!("  (none — run `make artifacts`)");
    }
    Ok(())
}
