//! Integration test: the full AOT round trip (init -> train_step loop).
//!
//! Requires `make artifacts` (skips gracefully when artifacts are absent
//! so `cargo test` works in a fresh checkout).

use std::collections::HashMap;

use sigma_moe::runtime::{Client, ModelBundle};
use sigma_moe::tensor::{DType, HostTensor};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts")
        .join("tiny-moe");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn init_then_train_step_decreases_loss() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let client = Client::cpu().expect("pjrt cpu client");
    let bundle = ModelBundle::load(&client, &dir).expect("load bundle");

    // --- init: outputs are params named "embed", "layers.0...", ... ---
    let init = bundle.program("init").unwrap();
    let params = init.run(&[HostTensor::scalar_u32(42)]).expect("init run");
    let some_param = params
        .iter()
        .find(|t| t.dtype == DType::F32 && t.element_count() > 100)
        .unwrap();
    assert!(
        some_param.as_f32().unwrap().iter().any(|v| v.abs() > 1e-6),
        "init produced zeros"
    );

    // --- train_step: inputs named "0.<param>", "1.<m>", "2.<v>",
    //     "3.<mems>", "4" (tokens), "5" (step), "6" (seed, may be pruned);
    //     outputs "0"=loss, "1"=gnorm, "2"=lr, "3.<param>", ... ---
    let ts = bundle.program("train_step").unwrap();
    let spec = ts.spec.clone();
    let by_name: HashMap<&str, usize> = spec
        .inputs
        .iter()
        .enumerate()
        .map(|(i, b)| (b.name.as_str(), i))
        .collect();

    let mut state: Vec<HostTensor> = spec
        .inputs
        .iter()
        .map(|b| HostTensor::zeros(b.dtype, &b.shape))
        .collect();
    // init params map to inputs "0.<name>" in order
    let param_inputs: Vec<usize> = spec
        .inputs
        .iter()
        .enumerate()
        .filter(|(_, b)| b.name.starts_with("0."))
        .map(|(i, _)| i)
        .collect();
    assert_eq!(param_inputs.len(), params.len());
    for (slot, p) in param_inputs.iter().zip(params.into_iter()) {
        state[*slot] = p;
    }

    let tok_idx = *by_name.get("4").expect("tokens input");
    let step_idx = *by_name.get("5").expect("step input");
    let tok_spec = spec.inputs[tok_idx].clone();
    assert_eq!(tok_spec.dtype, DType::I32);
    let vocab = bundle.manifest.model.vocab_size as i32;

    // Map outputs back to inputs by renaming "3."->"0." etc.
    let feedback: Vec<(usize, usize)> = spec
        .outputs
        .iter()
        .enumerate()
        .filter_map(|(oi, ob)| {
            let renamed = rename_output(&ob.name)?;
            by_name.get(renamed.as_str()).map(|ii| (oi, *ii))
        })
        .collect();
    assert!(feedback.len() >= spec.inputs.len() - 3);

    let mut losses = Vec::new();
    for step in 0..10 {
        let n = tok_spec.element_count();
        // learnable periodic token pattern
        let toks: Vec<i32> = (0..n).map(|i| ((i % 16) as i32 * 7) % vocab).collect();
        state[tok_idx] = HostTensor::from_i32(&tok_spec.shape, &toks).unwrap();
        state[step_idx] = HostTensor::scalar_i32(step);
        if let Some(&seed_idx) = by_name.get("6") {
            state[seed_idx] = HostTensor::scalar_u32(7);
        }
        let out = ts.run(&state).expect("train_step run");
        let loss = out[0].scalar_as_f32().unwrap();
        let gnorm = out[1].scalar_as_f32().unwrap();
        assert!(loss.is_finite(), "loss not finite at step {step}");
        assert!(gnorm.is_finite() && gnorm >= 0.0);
        losses.push(loss);
        for (oi, ii) in &feedback {
            state[*ii] = out[*oi].clone();
        }
    }
    eprintln!("losses: {losses:?}");
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease on a learnable pattern: {losses:?}"
    );
}

/// "3.x" -> "0.x" (params), "4.x" -> "1.x" (m), "5.x" -> "2.x" (v),
/// "6.x" -> "3.x" (mems).
fn rename_output(name: &str) -> Option<String> {
    let (head, rest) = name.split_once('.')?;
    let new_head = match head {
        "3" => "0",
        "4" => "1",
        "5" => "2",
        "6" => "3",
        _ => return None,
    };
    Some(format!("{new_head}.{rest}"))
}
