//! Deterministic record/replay + chaos-harness tests (device-free).
//!
//! These drive the real router/scheduler code single-threaded on a
//! simulated clock: same seed in, same decision stream out, byte for
//! byte — including under fault storms with quarantine, failover and
//! re-admission in the schedule.

use std::sync::{mpsc, Arc};
use std::time::Duration;

use sigma_moe::json::Json;
use sigma_moe::serving::chaos::{self, ChaosCfg};
use sigma_moe::serving::{
    Clock, GenRequest, Journal, Policy, Sampler, Scheduler,
    SharedClock, SimClock, StreamEvent,
};

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "sigma-moe-chaos-it-{}-{name}",
        std::process::id()
    ))
}

fn storm_cfg(seed: u64) -> ChaosCfg {
    ChaosCfg {
        engines: 3,
        lanes: 2,
        vocab: 32,
        requests: 16,
        pumps: 500,
        seed,
        storm: true,
        degrade: None,
        speculate: 0,
        prefix_cache: None,
    }
}

/// Property (over the recorded artifact): two independent replays of
/// the same recorded chaos trace produce identical journals AND
/// identical final metrics snapshots — and both match the recording.
#[test]
fn recorded_chaos_trace_replays_identically_twice() {
    let cfg = storm_cfg(29);
    let path = tmp("prop.jsonl");
    let rec = chaos::record(&cfg, &path).unwrap();
    assert!(rec.ok(), "recording violated invariants: {:?}", rec.violations);
    assert!(!rec.events.is_empty(), "a storm must journal decisions");

    let r1 = chaos::replay_path(&path).unwrap();
    let r2 = chaos::replay_path(&path).unwrap();
    assert!(
        r1.events_match && r1.metrics_match,
        "first replay diverged: {:?}",
        r1.divergence
    );
    assert!(
        r2.events_match && r2.metrics_match,
        "second replay diverged: {:?}",
        r2.divergence
    );
    assert_eq!(
        r1.report.events, r2.report.events,
        "two replays of one trace produced different journals"
    );
    assert_eq!(
        r1.report.metrics.to_string_compact(),
        r2.report.metrics.to_string_compact(),
        "two replays of one trace produced different metrics"
    );
    std::fs::remove_file(&path).ok();
}

/// Seeded sweep: the serving invariants (exactly-once terminals,
/// greedy-exact token streams, row-sums) hold across fault storms,
/// and the sweep actually exercises the failover machinery.
#[test]
fn chaos_invariants_hold_across_seeds() {
    let mut any_failover = false;
    let mut any_readmission = false;
    for seed in 1..=8 {
        let cfg = ChaosCfg {
            requests: 14,
            pumps: 400,
            ..storm_cfg(seed)
        };
        let r = chaos::run(&cfg).unwrap();
        assert!(r.ok(), "seed {seed}: {:?}", r.violations);
        assert_eq!(
            r.dones + r.drops + r.rejected,
            cfg.requests,
            "seed {seed}: terminal accounting is incomplete"
        );
        any_failover |= r.failovers > 0;
        any_readmission |= r.readmissions > 0;
    }
    assert!(
        any_failover,
        "no seed in the sweep exercised the failover path — the storm \
         is too tame to be a chaos test"
    );
    // re-admission depends on an outage/restart draw landing in the
    // sweep; it almost always does, but it is not an invariant
    let _ = any_readmission;
}

/// Run one fixed deadline-expiry schedule against a simulated-clock
/// scheduler and return (journal, admitted ids, per-client terminal
/// observations).
fn sim_deadline_run() -> (String, Vec<u64>, Vec<&'static str>) {
    let sim = SimClock::shared();
    let clock: SharedClock = sim.clone();
    let journal = Arc::new(Journal::new(clock.clone()));
    let sched = Scheduler::new(8, Policy::Deadline)
        .with_clock(clock.clone())
        .with_journal(journal.clone());

    let mut rxs = Vec::new();
    for i in 0..6u64 {
        let (tx, rx) = mpsc::channel();
        let deadline = if i % 2 == 0 {
            Duration::from_millis(50) // expires under the advance below
        } else {
            Duration::from_millis(500)
        };
        sched
            .enqueue(
                GenRequest {
                    prompt: vec![i as i32 + 1],
                    max_new_tokens: 4,
                    sampler: Sampler::greedy(),
                    ..Default::default()
                },
                Some(deadline),
                tx,
            )
            .unwrap();
        rxs.push(rx);
    }
    sim.advance(Duration::from_millis(100));
    sched.expire(clock.now());
    let mut taken = Vec::new();
    while let Some(q) = sched.take_next(clock.now()) {
        taken.push(q.id);
    }
    let outcomes = rxs
        .iter()
        .map(|rx| {
            let mut out = "none";
            while let Ok(ev) = rx.try_recv() {
                out = match ev {
                    StreamEvent::Admitted => "admitted",
                    StreamEvent::Dropped(_) => "dropped",
                    _ => out,
                };
            }
            out
        })
        .collect();
    (journal.events_jsonl(), taken, outcomes)
}

/// Satellite: a simulated-clock scheduler expires deadlines
/// identically across runs — same drop set, same admission order,
/// same journal bytes.
#[test]
fn sim_clock_scheduler_expires_deadlines_identically() {
    let (j1, taken1, out1) = sim_deadline_run();
    let (j2, taken2, out2) = sim_deadline_run();
    assert_eq!(j1, j2, "scheduler journals diverged across runs");
    assert_eq!(taken1, taken2);
    assert_eq!(out1, out2);
    // the 50ms deadlines (even ids) expired under the 100ms advance;
    // the 500ms ones (odd ids) survived and were admitted in order
    assert_eq!(taken1, vec![1, 3, 5]);
    assert_eq!(
        out1,
        vec![
            "dropped", "admitted", "dropped", "admitted", "dropped",
            "admitted"
        ]
    );
    // the journal recorded each decision exactly once
    assert_eq!(j1.matches("\"kind\":\"admit\"").count(), 6);
    assert_eq!(j1.matches("\"kind\":\"drop_deadline\"").count(), 3);
    assert_eq!(j1.matches("\"kind\":\"take\"").count(), 3);
}

/// Pinned regression fixture: a speculative fault storm configured
/// from a checked-in document records cleanly, carries the speculative
/// counters in its fleet metrics, and replays byte-for-byte. Also pins
/// back-compat: the same document minus the `speculate` key (a trace
/// recorded before speculative decode existed) parses as 0.
#[test]
fn pinned_speculative_storm_fixture_records_and_replays() {
    let text = include_str!("fixtures/chaos_spec_storm.json");
    let cfg = ChaosCfg::from_json(&Json::parse(text).unwrap()).unwrap();
    assert_eq!(
        cfg.speculate, 3,
        "fixture must exercise speculative decode"
    );
    assert!(cfg.storm, "fixture must run a fault storm");

    let path = tmp("spec-fixture.jsonl");
    let rec = chaos::record(&cfg, &path).unwrap();
    assert!(
        rec.ok(),
        "speculative storm violated invariants: {:?}",
        rec.violations
    );
    assert_eq!(
        rec.dones + rec.drops + rec.rejected,
        cfg.requests,
        "terminal accounting is incomplete under speculation"
    );
    // the fleet snapshot carries the speculative counters end to end
    let metrics = rec.metrics.to_string_compact();
    assert!(
        metrics.contains("spec_rounds"),
        "speculative counters missing from fleet metrics"
    );

    let rep = chaos::replay_path(&path).unwrap();
    assert!(
        rep.events_match && rep.metrics_match,
        "speculative trace diverged on replay: {:?}",
        rep.divergence
    );
    std::fs::remove_file(&path).ok();

    // back-compat: a cfg document predating the `speculate` key
    let legacy = text.replace(",\"speculate\":3", "");
    assert_ne!(legacy, text, "fixture edit broke the back-compat probe");
    let old = ChaosCfg::from_json(&Json::parse(&legacy).unwrap()).unwrap();
    assert_eq!(old.speculate, 0, "absent key must parse as no speculation");
}

/// A tampered trace must fail replay verification with a pointed
/// divergence message (the CI failure-reproduction path relies on
/// this distinguishing real nondeterminism from artifact corruption).
#[test]
fn replay_rejects_modified_traces() {
    let cfg = storm_cfg(5);
    let path = tmp("tamper.jsonl");
    let rec = chaos::record(&cfg, &path).unwrap();
    assert!(rec.ok(), "{:?}", rec.violations);
    let text = std::fs::read_to_string(&path).unwrap();
    // flip one decision event in the middle of the stream
    let mut lines: Vec<&str> = text.lines().collect();
    let mid = lines.len() / 2;
    let swapped = lines[mid].replace("\"kind\":\"", "\"kind\":\"x");
    lines[mid] = &swapped;
    let tampered = lines.join("\n");
    std::fs::write(&path, tampered).unwrap();
    let out = chaos::replay_path(&path).unwrap();
    assert!(!out.events_match, "a tampered trace must not verify");
    assert!(out.divergence.is_some());
    std::fs::remove_file(&path).ok();
}
